//! End-to-end contracts of the inference server ([`mls_train::serve`]):
//!
//! 1. A served forward on the quantize-once weight/panel cache is
//!    **bit-identical** to the heap-path [`NativeModel::eval_logits`]
//!    oracle on the same batch — logits bits and all five audit
//!    counters — across {1, 2, 8} worker threads and every SIMD
//!    dispatch level this CPU supports, with the weight cache on or
//!    off, on a fresh model or one restored from a step checkpoint.
//! 2. The framed protocol round-trips: per-stream FIFO response order,
//!    coalesced-batch demux (each response's `batch` field names the
//!    group it rode in, and the group's logits match the oracle on
//!    exactly that coalesced batch), logits transported bit-exactly
//!    through JSON.
//! 3. Malformed input is contained: JSON-level garbage gets an error
//!    response and the stream continues; a framing-level error (length
//!    prefix pointing past the bytes) gets an error and the stream is
//!    dropped.
//!
//! [`NativeModel::eval_logits`]: mls_train::nn::train::NativeModel::eval_logits

use std::collections::BTreeMap;
use std::io::Cursor;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

use mls_train::coordinator::{train_native, TrainConfig};
use mls_train::data::{streams, DatasetConfig, SynthCifar};
use mls_train::mls::quantizer::QuantConfig;
use mls_train::nn::train::native_model;
use mls_train::serve::{serve_stream, serve_tcp, ServeOptions, ServedModel};
use mls_train::util::frame;
use mls_train::util::json::Json;
use mls_train::util::simd::{self, Level};

/// The paper's default quantized config — the one the server exists for.
const CFG: &str = "e2m4_gnc_eg8mg1_sr";

fn images(n: usize) -> Vec<f32> {
    let ds = SynthCifar::new(DatasetConfig { noise: 1.0, seed: 5, ..Default::default() });
    ds.batch(n, streams::TEST, 0).0
}

fn req_frame(id: u64, image: &[f32]) -> Vec<u8> {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert(
        "image".to_string(),
        Json::Arr(image.iter().map(|&v| Json::Num(v as f64)).collect()),
    );
    let mut buf = Vec::new();
    frame::write_frame(&mut buf, Json::Obj(m).to_string_compact().as_bytes()).unwrap();
    buf
}

fn shutdown_frame() -> Vec<u8> {
    let mut buf = Vec::new();
    frame::write_frame(&mut buf, br#"{"cmd": "shutdown"}"#).unwrap();
    buf
}

/// Parse every response frame out of a finished writer buffer.
fn read_responses(buf: &[u8]) -> Vec<Json> {
    let mut r = buf;
    let mut out = Vec::new();
    while let Some(p) = frame::read_frame(&mut r, 1 << 22).unwrap() {
        out.push(Json::parse(std::str::from_utf8(&p).unwrap()).unwrap());
    }
    out
}

fn get_u64(j: &Json, key: &str) -> u64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or_else(|| panic!("no {key} in {j:?}")) as u64
}

fn assert_bits_eq(got: &[f32], want: &[f32], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: logit {i}: {a} vs {b}");
    }
}

/// Contract 1: the cached served forward reproduces the eval oracle
/// exactly — bits and audit — at every thread count and SIMD level.
#[test]
fn served_forward_is_bit_identical_to_the_eval_oracle() {
    let imgs = images(4);
    let prev = simd::active();
    for threads in [1usize, 2, 8] {
        for level in Level::supported() {
            simd::set_level(level);
            let mut served = ServedModel::fresh("cnn_t", CFG, 9, threads).unwrap();
            let mut logits = Vec::new();
            // first call quantizes + packs the weights; SECOND call is
            // the cached steady state under test
            served.infer_batch(&imgs, 4, &mut logits);
            served.infer_batch(&imgs, 4, &mut logits);
            let (oracle, oracle_audit) = served.model().eval_logits(&imgs, 4);
            let tag = format!("threads={threads} simd={level:?}");
            assert_bits_eq(&logits, &oracle, &tag);
            assert_eq!(served.last_audit(), &oracle_audit, "{tag}: audit counters");
        }
    }
    simd::set_level(prev);
}

/// Contract 1, cache axis: repeated serves and the requantize baseline
/// (`set_weight_cache(false)`) all produce the same bits — nearest
/// rounding is deterministic, the cache only saves work.
#[test]
fn weight_cache_toggle_never_changes_the_bits() {
    let imgs = images(2);
    let mut served = ServedModel::fresh("cnn_t", CFG, 3, 2).unwrap();
    let (mut cached, mut repeat, mut uncached) = (Vec::new(), Vec::new(), Vec::new());
    served.infer_batch(&imgs, 2, &mut cached);
    served.infer_batch(&imgs, 2, &mut repeat);
    assert_bits_eq(&repeat, &cached, "second cached serve");
    served.set_weight_cache(false);
    served.infer_batch(&imgs, 2, &mut uncached);
    assert_bits_eq(&uncached, &cached, "requantize-every-call baseline");
    let audit_uncached = served.last_audit().clone();
    served.set_weight_cache(true);
    served.infer_batch(&imgs, 2, &mut repeat);
    assert_bits_eq(&repeat, &cached, "re-frozen cache");
    assert_eq!(served.last_audit(), &audit_uncached, "audit counters ignore the cache");
}

/// Contract 1, checkpoint axis: a model served from a coordinator step
/// checkpoint is bit-identical to one rebuilt from the run's final state.
#[test]
fn checkpoint_and_final_state_serve_identical_logits() {
    let dir = std::env::temp_dir().join("mls_serve_test").join("ckpt_parity");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let mut config = TrainConfig::default();
    config.model = "cnn_t".to_string();
    config.cfg_name = CFG.to_string();
    config.seed = 11;
    config.steps = 2;
    config.batch = 2;
    config.checkpoint_every = 1;
    config.out_dir = Some(dir.to_string_lossy().into_owned());
    let result = train_native(&config).unwrap();

    let ckpt_path = dir.join(format!("cnn_t_{CFG}_s11.ckpt.bin"));
    let mut from_ckpt = ServedModel::from_checkpoint(&ckpt_path, 2).unwrap();

    let mut model = native_model("cnn_t", QuantConfig::parse_name(CFG).unwrap(), 11).unwrap();
    model.load_state(&result.final_state).unwrap();
    let mut from_state = ServedModel::from_model(model, 2);

    let imgs = images(3);
    let (mut a, mut b) = (Vec::new(), Vec::new());
    from_ckpt.infer_batch(&imgs, 3, &mut a);
    from_state.infer_batch(&imgs, 3, &mut b);
    assert_bits_eq(&a, &b, "checkpoint vs final_state");
    assert_eq!(from_ckpt.last_audit(), from_state.last_audit(), "audit counters");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Contract 2, unbatched: `batch_max = 1` serves each request alone, in
/// FIFO order, each response bit-identical to the single-image oracle.
#[test]
fn serve_stream_answers_in_fifo_order_with_exact_logits() {
    let mut served = ServedModel::fresh("cnn_t", CFG, 7, 2).unwrap();
    let elems = served.input_elems();
    let classes = served.classes();
    let imgs = images(3);

    let mut input = Vec::new();
    for (i, id) in [5u64, 6, 7].iter().enumerate() {
        input.extend_from_slice(&req_frame(*id, &imgs[i * elems..(i + 1) * elems]));
    }
    input.extend_from_slice(&shutdown_frame());

    let opts = ServeOptions { batch_max: 1, batch_wait: Duration::ZERO, ..Default::default() };
    let mut out = Vec::new();
    let stats = serve_stream(&mut served, Cursor::new(input), &mut out, &opts).unwrap();
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.batches, 3, "batch_max=1 must never coalesce");

    let resps = read_responses(&out);
    assert_eq!(resps.len(), 3);
    for (i, (resp, id)) in resps.iter().zip([5u64, 6, 7]).enumerate() {
        let row = &imgs[i * elems..(i + 1) * elems];
        let (oracle, _) = served.model().eval_logits(row, 1);
        assert_eq!(get_u64(resp, "id"), id, "FIFO response order");
        assert_eq!(get_u64(resp, "batch"), 1);
        assert_eq!(get_u64(resp, "argmax") as usize, argmax(&oracle), "served class");
        let logits = resp.get("logits").unwrap().f32s().unwrap();
        assert_eq!(logits.len(), classes);
        assert_bits_eq(&logits, &oracle, "logits through JSON");
    }
}

/// Contract 2, coalesced: whatever grouping the batcher lands on, each
/// response names its group size and the group's logits match the
/// oracle run on exactly that coalesced batch (BN uses batch statistics,
/// so the group composition is part of the answer — this is the demux
/// contract).
#[test]
fn coalesced_batches_demux_back_to_the_right_requests() {
    let mut served = ServedModel::fresh("cnn_t", CFG, 7, 2).unwrap();
    let elems = served.input_elems();
    let classes = served.classes();
    let imgs = images(4);

    let mut input = Vec::new();
    for (i, id) in [1u64, 2, 3, 4].iter().enumerate() {
        input.extend_from_slice(&req_frame(*id, &imgs[i * elems..(i + 1) * elems]));
    }
    input.extend_from_slice(&shutdown_frame());

    // a generous window: the whole pre-buffered stream normally lands in
    // one batch, but the contract below holds for ANY grouping
    let opts =
        ServeOptions { batch_max: 8, batch_wait: Duration::from_millis(500), ..Default::default() };
    let mut out = Vec::new();
    let stats = serve_stream(&mut served, Cursor::new(input), &mut out, &opts).unwrap();
    assert_eq!(stats.requests, 4);

    let resps = read_responses(&out);
    assert_eq!(resps.len(), 4);
    let mut i = 0;
    while i < resps.len() {
        let n = get_u64(&resps[i], "batch") as usize;
        assert!(n >= 1 && i + n <= resps.len(), "batch {n} at response {i}");
        let group = &imgs[i * elems..(i + n) * elems];
        let (oracle, _) = served.model().eval_logits(group, n);
        for k in 0..n {
            let resp = &resps[i + k];
            assert_eq!(get_u64(resp, "id"), (i + k) as u64 + 1, "FIFO across the batch");
            assert_eq!(get_u64(resp, "batch") as usize, n, "every rider reports its group");
            let logits = resp.get("logits").unwrap().f32s().unwrap();
            let row = &oracle[k * classes..(k + 1) * classes];
            assert_bits_eq(&logits, row, &format!("demuxed row {k} of batch at {i}"));
        }
        i += n;
    }
}

/// Contract 3a: JSON-level garbage inside a well-formed frame gets an
/// error response (id echoed when recoverable) and the stream keeps
/// serving.
#[test]
fn malformed_json_gets_an_error_and_the_stream_continues() {
    let mut served = ServedModel::fresh("cnn_t", CFG, 7, 1).unwrap();
    let elems = served.input_elems();
    let imgs = images(2);

    let mut input = Vec::new();
    input.extend_from_slice(&req_frame(1, &imgs[..elems]));
    frame::write_frame(&mut input, b"{this is not json").unwrap();
    frame::write_frame(&mut input, br#"{"id": 9, "image": [1.0]}"#).unwrap(); // wrong length
    input.extend_from_slice(&req_frame(2, &imgs[elems..2 * elems]));
    input.extend_from_slice(&shutdown_frame());

    let opts = ServeOptions { batch_max: 8, batch_wait: Duration::ZERO, ..Default::default() };
    let mut out = Vec::new();
    let stats = serve_stream(&mut served, Cursor::new(input), &mut out, &opts).unwrap();
    assert_eq!(stats.requests, 2, "both good requests around the garbage were served");

    let resps = read_responses(&out);
    assert_eq!(resps.len(), 4, "two answers + two errors, in stream order");
    assert_eq!(get_u64(&resps[0], "id"), 1);
    assert!(resps[0].get("error").is_none());
    assert!(matches!(resps[1].get("id"), Some(Json::Null)), "unparseable: no id to echo");
    assert!(resps[1].get("error").and_then(|e| e.as_str()).unwrap().contains("JSON"));
    assert_eq!(get_u64(&resps[2], "id"), 9, "length mismatch echoes the id");
    assert!(resps[2].get("error").and_then(|e| e.as_str()).unwrap().contains("elements"));
    assert_eq!(get_u64(&resps[3], "id"), 2, "stream continued after both");
    assert!(resps[3].get("error").is_none());
}

/// Contract 3b: a frame whose length prefix points past the actual bytes
/// is a framing error — one error response, then the stream is dropped
/// (the byte position is unknowable), after serving what came before.
#[test]
fn truncated_frame_reports_a_frame_error_and_drops_the_stream() {
    let mut served = ServedModel::fresh("cnn_t", CFG, 7, 1).unwrap();
    let elems = served.input_elems();
    let imgs = images(1);

    let mut input = Vec::new();
    input.extend_from_slice(&req_frame(8, &imgs[..elems]));
    input.extend_from_slice(&100u32.to_le_bytes()); // promises 100 bytes...
    input.extend_from_slice(b"only ten b"); // ...delivers 10, then EOF

    let opts = ServeOptions { batch_max: 8, batch_wait: Duration::ZERO, ..Default::default() };
    let mut out = Vec::new();
    let stats = serve_stream(&mut served, Cursor::new(input), &mut out, &opts).unwrap();
    assert_eq!(stats.requests, 1);

    let resps = read_responses(&out);
    assert_eq!(resps.len(), 2);
    assert_eq!(get_u64(&resps[0], "id"), 8);
    assert!(resps[0].get("error").is_none());
    assert!(matches!(resps[1].get("id"), Some(Json::Null)));
    assert!(resps[1].get("error").and_then(|e| e.as_str()).unwrap().contains("frame error"));
}

/// Contract 2 over TCP: two concurrent connections coalesce into one
/// model, responses demux back to the connection that asked, and
/// `{"cmd":"shutdown"}` from either stops the server cleanly.
#[test]
fn tcp_serves_concurrent_connections_and_shuts_down() {
    let mut served = ServedModel::fresh("cnn_t", CFG, 7, 2).unwrap();
    let elems = served.input_elems();
    let imgs = images(2);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let (a_done_tx, a_done_rx) = mpsc::channel::<()>();
    let img_a = imgs[..elems].to_vec();
    let client_a = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all_frames(&req_frame(10, &img_a));
        let resp = s.read_one_response();
        a_done_tx.send(()).unwrap();
        resp
    });
    let img_b = imgs[elems..2 * elems].to_vec();
    let client_b = std::thread::spawn(move || {
        // strictly after A has its answer: shutdown must not race A's
        // request into a closed queue
        a_done_rx.recv().unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all_frames(&req_frame(20, &img_b));
        let resp = s.read_one_response();
        s.write_all_frames(&shutdown_frame());
        resp
    });

    let opts = ServeOptions::default();
    let stats = serve_tcp(&mut served, listener, &opts).unwrap();
    assert_eq!(stats.requests, 2);

    let resp_a = client_a.join().unwrap();
    let resp_b = client_b.join().unwrap();
    assert_eq!(get_u64(&resp_a, "id"), 10, "connection A got A's answer");
    assert_eq!(get_u64(&resp_b, "id"), 20, "connection B got B's answer");
    for resp in [&resp_a, &resp_b] {
        let logits = resp.get("logits").unwrap().f32s().unwrap();
        assert_eq!(logits.len(), served.classes());
        assert_eq!(get_u64(resp, "argmax") as usize, argmax(&logits));
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

/// Tiny client-side helpers for the TCP test.
trait ClientExt {
    fn write_all_frames(&mut self, bytes: &[u8]);
    fn read_one_response(&mut self) -> Json;
}

impl ClientExt for TcpStream {
    fn write_all_frames(&mut self, bytes: &[u8]) {
        use std::io::Write;
        self.write_all(bytes).unwrap();
        self.flush().unwrap();
    }

    fn read_one_response(&mut self) -> Json {
        let payload = frame::read_frame(self, 1 << 22).unwrap().expect("a response frame");
        Json::parse(std::str::from_utf8(&payload).unwrap()).unwrap()
    }
}
