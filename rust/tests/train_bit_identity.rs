//! The PR 5 module-graph redesign must be provably behavior-preserving:
//! the chain models (`cnn_t`, `cnn_s`) must produce **bit-identical**
//! per-step losses, gradients, audit counters and parameter updates
//! before vs after the rewrite.
//!
//! This test pins that by carrying a verbatim copy of the PRE-refactor
//! single-chain trainer (`mod chain` below — the PR 4 `nn/train.rs`
//! enum-of-layers implementation, trimmed to what the chain models use:
//! builder, forward, backward, plain SGD) and replaying fixed-seed steps
//! on both implementations: same init, same batches, same step seeds.
//! Initial states, per-step losses, accuracies, full gradient vectors,
//! all per-pass audit counters and post-update states are compared
//! bit-for-bit, for the fp32 AND the quantized `<2,4>` stochastic-
//! rounding config.

use mls_train::data::{streams, DatasetConfig, SynthCifar};
use mls_train::mls::quantizer::QuantConfig;
use mls_train::nn::train::native_model;

/// Verbatim copy of the PR 4 chain trainer (the pre-refactor
/// implementation this PR replaced). Kept test-only: its sole purpose is
/// to prove the module-graph executor reproduces it bit-exactly.
mod chain {
    use mls_train::arith::conv::{
        conv2d_f32_dgrad, conv2d_f32_threaded, conv2d_f32_wgrad, ConvOutput,
    };
    use mls_train::arith::spec::ConvSpec;
    use mls_train::mls::quantizer::{quantize, QuantConfig, Rounding};
    use mls_train::mls::MlsTensor;
    use mls_train::util::rng::Pcg32;

    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct Pass {
        pub convs: u64,
        pub mul_ops: u64,
        pub int_add_ops: u64,
        pub float_add_ops: u64,
        pub group_scale_ops: u64,
        pub peak_acc_bits: u32,
    }

    impl Pass {
        fn absorb(&mut self, out: &ConvOutput) {
            self.convs += 1;
            self.mul_ops += out.mul_ops;
            self.int_add_ops += out.int_add_ops;
            self.float_add_ops += out.float_add_ops;
            self.group_scale_ops += out.group_scale_ops;
            self.peak_acc_bits = self.peak_acc_bits.max(out.peak_acc_bits);
        }
    }

    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct Audit {
        pub forward: Pass,
        pub wgrad: Pass,
        pub dgrad: Pass,
    }

    pub struct ConvLayer {
        pub w: Vec<f32>,
        pub co: usize,
        pub ci: usize,
        pub k: usize,
        pub stride: usize,
        pub pad: usize,
        pub quantized: bool,
    }

    impl ConvLayer {
        fn spec(&self, h: usize, w: usize) -> ConvSpec {
            ConvSpec::new(self.stride, self.pad, self.k, self.k, h, w)
        }
    }

    pub struct BnLayer {
        pub c: usize,
        pub gamma: Vec<f32>,
        pub beta: Vec<f32>,
        pub eps: f32,
    }

    pub struct FcLayer {
        pub din: usize,
        pub dout: usize,
        pub w: Vec<f32>,
        pub b: Vec<f32>,
    }

    pub enum NativeLayer {
        Conv(ConvLayer),
        BatchNorm(BnLayer),
        Relu,
        GlobalAvgPool,
        Fc(FcLayer),
    }

    impl NativeLayer {
        fn param_len(&self) -> usize {
            match self {
                NativeLayer::Conv(l) => l.w.len(),
                NativeLayer::BatchNorm(l) => 2 * l.c,
                NativeLayer::Fc(l) => l.w.len() + l.b.len(),
                _ => 0,
            }
        }
    }

    enum Cache {
        Conv { x: Vec<f32>, h: usize, w: usize, qw: Option<MlsTensor>, qa: Option<MlsTensor> },
        Bn { xhat: Vec<f32>, inv_std: Vec<f32>, h: usize, w: usize },
        Relu { pos: Vec<bool> },
        Gap { c: usize, h: usize, w: usize },
        Fc { x: Vec<f32> },
    }

    pub struct ChainModel {
        pub input: (usize, usize, usize),
        pub classes: usize,
        pub qcfg: QuantConfig,
        pub layers: Vec<NativeLayer>,
        pub threads: usize,
    }

    fn quantize_dyn(
        x: &[f32],
        shape: &[usize],
        cfg: &QuantConfig,
        rng: Option<&mut Pcg32>,
    ) -> MlsTensor {
        match (cfg.rounding, rng) {
            (Rounding::Stochastic, Some(rng)) => {
                let offsets = rng.rounding_offsets(x.len());
                quantize(x, shape, cfg, &offsets)
            }
            (Rounding::Stochastic, None) => {
                let nearest = QuantConfig { rounding: Rounding::Nearest, ..*cfg };
                quantize(x, shape, &nearest, &[])
            }
            (Rounding::Nearest, _) => quantize(x, shape, cfg, &[]),
        }
    }

    fn softmax_ce(logits: &[f32], labels: &[i32], classes: usize) -> (f32, f32, Vec<f32>) {
        let n = labels.len();
        assert_eq!(logits.len(), n * classes, "logit/label shape mismatch");
        let mut dlogits = vec![0.0f32; n * classes];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for (nb, &label) in labels.iter().enumerate() {
            let label = label as usize;
            assert!(label < classes, "label {label} out of range");
            let row = &logits[nb * classes..(nb + 1) * classes];
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f64;
            for &v in row {
                sum += ((v - maxv) as f64).exp();
            }
            let mut best = 0usize;
            for (k, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = k;
                }
                let p = ((v - maxv) as f64).exp() / sum;
                dlogits[nb * classes + k] =
                    ((p - if k == label { 1.0 } else { 0.0 }) / n as f64) as f32;
            }
            let p_label = ((row[label] - maxv) as f64).exp() / sum;
            loss -= p_label.max(1e-30).ln();
            if best == label {
                correct += 1;
            }
        }
        ((loss / n as f64) as f32, correct as f32 / n as f32, dlogits)
    }

    impl ChainModel {
        pub fn state_len(&self) -> usize {
            self.layers.iter().map(|l| l.param_len()).sum()
        }

        fn param_offsets(&self) -> Vec<usize> {
            let mut offs = Vec::with_capacity(self.layers.len());
            let mut cursor = 0;
            for l in &self.layers {
                offs.push(cursor);
                cursor += l.param_len();
            }
            offs
        }

        pub fn state(&self) -> Vec<f32> {
            let mut out = Vec::with_capacity(self.state_len());
            for l in &self.layers {
                match l {
                    NativeLayer::Conv(c) => out.extend_from_slice(&c.w),
                    NativeLayer::BatchNorm(b) => {
                        out.extend_from_slice(&b.gamma);
                        out.extend_from_slice(&b.beta);
                    }
                    NativeLayer::Fc(f) => {
                        out.extend_from_slice(&f.w);
                        out.extend_from_slice(&f.b);
                    }
                    _ => {}
                }
            }
            out
        }

        fn forward_inner(
            &self,
            images: &[f32],
            n: usize,
            mut rng: Option<&mut Pcg32>,
            mut caches: Option<&mut Vec<Cache>>,
            audit: &mut Audit,
        ) -> Vec<f32> {
            let (c0, h0, w0) = self.input;
            assert_eq!(images.len(), n * c0 * h0 * w0, "image batch shape mismatch");
            let mut x = images.to_vec();
            let (mut c, mut h, mut w) = (c0, h0, w0);
            for layer in &self.layers {
                match layer {
                    NativeLayer::Conv(l) => {
                        assert_eq!(c, l.ci, "conv input channel mismatch");
                        let spec = l.spec(h, w);
                        let (ho, wo) = (spec.out_h(), spec.out_w());
                        let (z, qw, qa) = if l.quantized && self.qcfg.enabled {
                            let qw = quantize_dyn(
                                &l.w,
                                &[l.co, l.ci, l.k, l.k],
                                &self.qcfg,
                                rng.as_deref_mut(),
                            );
                            let qa =
                                quantize_dyn(&x, &[n, c, h, w], &self.qcfg, rng.as_deref_mut());
                            let out = spec.forward(&qw, &qa, self.threads);
                            audit.forward.absorb(&out);
                            (out.z, Some(qw), Some(qa))
                        } else {
                            let (z, _) = conv2d_f32_threaded(
                                &l.w,
                                [l.co, l.ci, l.k, l.k],
                                &x,
                                [n, c, h, w],
                                l.stride,
                                l.pad,
                                self.threads,
                            );
                            (z, None, None)
                        };
                        if let Some(caches) = caches.as_deref_mut() {
                            let xf =
                                if qa.is_some() { Vec::new() } else { std::mem::take(&mut x) };
                            caches.push(Cache::Conv { x: xf, h, w, qw, qa });
                        }
                        x = z;
                        (c, h, w) = (l.co, ho, wo);
                    }
                    NativeLayer::BatchNorm(l) => {
                        assert_eq!(c, l.c, "BN channel mismatch");
                        let m = (n * h * w) as f64;
                        let plane = h * w;
                        let mut xhat = vec![0.0f32; x.len()];
                        let mut inv_std = vec![0.0f32; c];
                        for ch in 0..c {
                            let mut sum = 0.0f64;
                            let mut sq = 0.0f64;
                            for nb in 0..n {
                                let base = (nb * c + ch) * plane;
                                for &v in &x[base..base + plane] {
                                    sum += v as f64;
                                    sq += v as f64 * v as f64;
                                }
                            }
                            let mean = sum / m;
                            let var = (sq / m - mean * mean).max(0.0);
                            let inv = 1.0 / (var + l.eps as f64).sqrt();
                            inv_std[ch] = inv as f32;
                            let (g, b) = (l.gamma[ch], l.beta[ch]);
                            for nb in 0..n {
                                let base = (nb * c + ch) * plane;
                                for i in base..base + plane {
                                    let xh = ((x[i] as f64 - mean) * inv) as f32;
                                    xhat[i] = xh;
                                    x[i] = g * xh + b;
                                }
                            }
                        }
                        if let Some(caches) = caches.as_deref_mut() {
                            caches.push(Cache::Bn { xhat, inv_std, h, w });
                        }
                    }
                    NativeLayer::Relu => {
                        let mut pos = Vec::new();
                        if caches.is_some() {
                            pos = x.iter().map(|&v| v > 0.0).collect();
                        }
                        for v in x.iter_mut() {
                            if *v < 0.0 {
                                *v = 0.0;
                            }
                        }
                        if let Some(caches) = caches.as_deref_mut() {
                            caches.push(Cache::Relu { pos });
                        }
                    }
                    NativeLayer::GlobalAvgPool => {
                        let plane = h * w;
                        let mut y = vec![0.0f32; n * c];
                        for nb in 0..n {
                            for ch in 0..c {
                                let base = (nb * c + ch) * plane;
                                let mut sum = 0.0f64;
                                for &v in &x[base..base + plane] {
                                    sum += v as f64;
                                }
                                y[nb * c + ch] = (sum / plane as f64) as f32;
                            }
                        }
                        if let Some(caches) = caches.as_deref_mut() {
                            caches.push(Cache::Gap { c, h, w });
                        }
                        x = y;
                        (h, w) = (1, 1);
                    }
                    NativeLayer::Fc(l) => {
                        let din = c * h * w;
                        assert_eq!(din, l.din, "FC input dim mismatch");
                        let mut y = vec![0.0f32; n * l.dout];
                        for nb in 0..n {
                            let xin = &x[nb * din..(nb + 1) * din];
                            for o in 0..l.dout {
                                let wrow = &l.w[o * din..(o + 1) * din];
                                let mut acc = l.b[o] as f64;
                                for d in 0..din {
                                    acc += wrow[d] as f64 * xin[d] as f64;
                                }
                                y[nb * l.dout + o] = acc as f32;
                            }
                        }
                        if let Some(caches) = caches.as_deref_mut() {
                            caches.push(Cache::Fc { x: std::mem::take(&mut x) });
                        }
                        x = y;
                        (c, h, w) = (l.dout, 1, 1);
                    }
                }
            }
            assert_eq!(c * h * w, self.classes, "head output does not match the class count");
            x
        }

        pub fn loss_and_grads(
            &self,
            images: &[f32],
            labels: &[i32],
            seed: i64,
        ) -> (f32, f32, Vec<f32>, Audit) {
            let n = labels.len();
            let mut rng = Pcg32::new(seed as u64, 0x51e9_a1b2);
            let mut audit = Audit::default();
            let mut caches: Vec<Cache> = Vec::with_capacity(self.layers.len());
            let logits =
                self.forward_inner(images, n, Some(&mut rng), Some(&mut caches), &mut audit);
            let (loss, acc, dlogits) = softmax_ce(&logits, labels, self.classes);

            let mut grads = vec![0.0f32; self.state_len()];
            let offs = self.param_offsets();
            let mut g = dlogits;
            for (li, layer) in self.layers.iter().enumerate().rev() {
                let cache = caches.pop().expect("one cache per layer");
                match (layer, cache) {
                    (NativeLayer::Fc(l), Cache::Fc { x }) => {
                        let gw = &mut grads[offs[li]..offs[li] + l.w.len() + l.b.len()];
                        for nb in 0..n {
                            let xin = &x[nb * l.din..(nb + 1) * l.din];
                            let grow = &g[nb * l.dout..(nb + 1) * l.dout];
                            for o in 0..l.dout {
                                let go = grow[o];
                                for d in 0..l.din {
                                    gw[o * l.din + d] += go * xin[d];
                                }
                                gw[l.w.len() + o] += go;
                            }
                        }
                        let mut dx = vec![0.0f32; x.len()];
                        for nb in 0..n {
                            let grow = &g[nb * l.dout..(nb + 1) * l.dout];
                            let drow = &mut dx[nb * l.din..(nb + 1) * l.din];
                            for o in 0..l.dout {
                                let go = grow[o];
                                let wrow = &l.w[o * l.din..(o + 1) * l.din];
                                for d in 0..l.din {
                                    drow[d] += go * wrow[d];
                                }
                            }
                        }
                        g = dx;
                    }
                    (NativeLayer::GlobalAvgPool, Cache::Gap { c, h, w }) => {
                        let plane = h * w;
                        let mut dx = vec![0.0f32; n * c * plane];
                        for nb in 0..n {
                            for ch in 0..c {
                                let gv = g[nb * c + ch] / plane as f32;
                                let base = (nb * c + ch) * plane;
                                for slot in &mut dx[base..base + plane] {
                                    *slot = gv;
                                }
                            }
                        }
                        g = dx;
                    }
                    (NativeLayer::Relu, Cache::Relu { pos }) => {
                        for (gv, &p) in g.iter_mut().zip(&pos) {
                            if !p {
                                *gv = 0.0;
                            }
                        }
                    }
                    (NativeLayer::BatchNorm(l), Cache::Bn { xhat, inv_std, h, w }) => {
                        let plane = h * w;
                        let m = (n * plane) as f64;
                        let gg = &mut grads[offs[li]..offs[li] + 2 * l.c];
                        for ch in 0..l.c {
                            let mut sum_dy = 0.0f64;
                            let mut sum_dy_xhat = 0.0f64;
                            for nb in 0..n {
                                let base = (nb * l.c + ch) * plane;
                                for i in base..base + plane {
                                    sum_dy += g[i] as f64;
                                    sum_dy_xhat += g[i] as f64 * xhat[i] as f64;
                                }
                            }
                            gg[ch] += sum_dy_xhat as f32; // dgamma
                            gg[l.c + ch] += sum_dy as f32; // dbeta
                            let scale = l.gamma[ch] as f64 * inv_std[ch] as f64;
                            let mean_dy = sum_dy / m;
                            let mean_dy_xhat = sum_dy_xhat / m;
                            for nb in 0..n {
                                let base = (nb * l.c + ch) * plane;
                                for i in base..base + plane {
                                    g[i] = (scale
                                        * (g[i] as f64
                                            - mean_dy
                                            - xhat[i] as f64 * mean_dy_xhat))
                                        as f32;
                                }
                            }
                        }
                    }
                    (NativeLayer::Conv(l), Cache::Conv { x, h, w, qw, qa }) => {
                        let spec = l.spec(h, w);
                        let (ho, wo) = (spec.out_h(), spec.out_w());
                        let eshape = [n, l.co, ho, wo];
                        let need_dx = li > 0;
                        let gw = &mut grads[offs[li]..offs[li] + l.w.len()];
                        if let (Some(qw), Some(qa)) = (qw, qa) {
                            let qe = quantize_dyn(&g, &eshape, &self.qcfg, Some(&mut rng));
                            let wg = spec.weight_grad(&qe, &qa, self.threads);
                            audit.wgrad.absorb(&wg);
                            gw.copy_from_slice(&wg.z);
                            if need_dx {
                                let dg = spec.input_grad(&qe, &qw, self.threads);
                                audit.dgrad.absorb(&dg);
                                g = dg.z;
                            } else {
                                g = Vec::new();
                            }
                        } else {
                            let (wg, _) = conv2d_f32_wgrad(
                                &g,
                                eshape,
                                &x,
                                [n, l.ci, h, w],
                                l.stride,
                                l.pad,
                                l.k,
                                l.k,
                                self.threads,
                            );
                            gw.copy_from_slice(&wg);
                            if need_dx {
                                let (dg, _) = conv2d_f32_dgrad(
                                    &g,
                                    eshape,
                                    &l.w,
                                    [l.co, l.ci, l.k, l.k],
                                    l.stride,
                                    l.pad,
                                    h,
                                    w,
                                    self.threads,
                                );
                                g = dg;
                            } else {
                                g = Vec::new();
                            }
                        }
                    }
                    _ => unreachable!("cache kind does not match layer kind"),
                }
            }
            (loss, acc, grads, audit)
        }

        /// The historical step: loss_and_grads + the inlined plain-SGD
        /// update `p -= lr * g`.
        pub fn train_step(&mut self, images: &[f32], labels: &[i32], lr: f32, seed: i64) -> f32 {
            let (loss, _, grads, _) = self.loss_and_grads(images, labels, seed);
            let offs = self.param_offsets();
            for (li, layer) in self.layers.iter_mut().enumerate() {
                let len = layer.param_len();
                let gs = &grads[offs[li]..offs[li] + len];
                let mut cursor = 0;
                let mut update = |p: &mut [f32]| {
                    for (pv, gv) in p.iter_mut().zip(&gs[cursor..cursor + p.len()]) {
                        *pv -= lr * gv;
                    }
                    cursor += p.len();
                };
                match layer {
                    NativeLayer::Conv(c) => update(&mut c.w),
                    NativeLayer::BatchNorm(b) => {
                        update(&mut b.gamma);
                        update(&mut b.beta);
                    }
                    NativeLayer::Fc(f) => {
                        update(&mut f.w);
                        update(&mut f.b);
                    }
                    _ => {}
                }
            }
            loss
        }
    }

    /// The historical chain builder (verbatim init: same RNG stream, same
    /// He sigmas, same draw order).
    struct Builder {
        layers: Vec<NativeLayer>,
        rng: Pcg32,
        c: usize,
        h: usize,
        w: usize,
    }

    impl Builder {
        fn new(input: (usize, usize, usize), seed: u64) -> Self {
            Builder {
                layers: Vec::new(),
                rng: Pcg32::new(seed, 0x6e61_7469),
                c: input.0,
                h: input.1,
                w: input.2,
            }
        }

        fn conv(
            &mut self,
            co: usize,
            k: usize,
            stride: usize,
            pad: usize,
            quantized: bool,
        ) -> &mut Self {
            let ci = self.c;
            let sigma = (2.0 / (ci * k * k) as f32).sqrt();
            let w = self.rng.normal_vec(co * ci * k * k, sigma);
            self.layers
                .push(NativeLayer::Conv(ConvLayer { w, co, ci, k, stride, pad, quantized }));
            self.c = co;
            self.h = (self.h + 2 * pad - k) / stride + 1;
            self.w = (self.w + 2 * pad - k) / stride + 1;
            self
        }

        fn bn(&mut self) -> &mut Self {
            self.layers.push(NativeLayer::BatchNorm(BnLayer {
                c: self.c,
                gamma: vec![1.0; self.c],
                beta: vec![0.0; self.c],
                eps: 1e-5,
            }));
            self
        }

        fn relu(&mut self) -> &mut Self {
            self.layers.push(NativeLayer::Relu);
            self
        }

        fn gap(&mut self) -> &mut Self {
            self.layers.push(NativeLayer::GlobalAvgPool);
            (self.h, self.w) = (1, 1);
            self
        }

        fn fc(&mut self, dout: usize) -> &mut Self {
            let din = self.c * self.h * self.w;
            let sigma = (2.0 / din as f32).sqrt();
            let w = self.rng.normal_vec(dout * din, sigma);
            self.layers.push(NativeLayer::Fc(FcLayer { din, dout, w, b: vec![0.0; dout] }));
            self.c = dout;
            self
        }
    }

    pub fn build(name: &str, qcfg: QuantConfig, seed: u64) -> ChainModel {
        let input = (3usize, 16usize, 16usize);
        let classes = 10usize;
        let mut b = Builder::new(input, seed.wrapping_add(0x9e37_79b9));
        match name {
            "cnn_t" => {
                b.conv(8, 3, 1, 1, false).bn().relu();
                b.conv(16, 3, 2, 1, true).bn().relu();
                b.conv(16, 1, 1, 0, true).bn().relu();
                b.conv(16, 3, 1, 1, true).bn().relu();
                b.gap().fc(classes);
            }
            "cnn_s" => {
                b.conv(16, 3, 1, 1, false).bn().relu();
                b.conv(32, 3, 2, 1, true).bn().relu();
                b.conv(32, 3, 1, 1, true).bn().relu();
                b.conv(64, 3, 2, 1, true).bn().relu();
                b.conv(64, 3, 1, 1, true).bn().relu();
                b.gap().fc(classes);
            }
            other => panic!("chain reference has no model {other:?}"),
        }
        ChainModel { input, classes, qcfg, layers: b.layers, threads: 2 }
    }
}

fn assert_bits_eq(a: &[f32], b: &[f32], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}[{i}]: {x:?} vs {y:?}");
    }
}

fn check_model(name: &str, cfg_name: &str, steps: u64, batch: usize) {
    let qcfg = QuantConfig::parse_name(cfg_name).unwrap();
    let seed = 9u64;
    let mut legacy = chain::build(name, qcfg, seed);
    let mut modern = native_model(name, qcfg, seed).unwrap();
    modern.set_threads(2);
    assert_bits_eq(&legacy.state(), &modern.state(), &format!("{name}/{cfg_name}: init state"));

    let ds = SynthCifar::new(DatasetConfig {
        noise: 1.0,
        label_noise: 0.0,
        seed: 5,
        ..Default::default()
    });
    for step in 0..steps {
        let (images, labels) = ds.batch(batch, streams::TRAIN, step);
        let sseed = 31 + step as i64;
        let tag = format!("{name}/{cfg_name} step {step}");

        // the full pass without update: loss, acc, gradients, audit
        let (l_old, a_old, g_old, audit_old) = legacy.loss_and_grads(&images, &labels, sseed);
        let (l_new, a_new, g_new, audit_new) = modern.loss_and_grads(&images, &labels, sseed);
        assert_eq!(l_old.to_bits(), l_new.to_bits(), "{tag}: loss");
        assert_eq!(a_old.to_bits(), a_new.to_bits(), "{tag}: acc");
        assert_bits_eq(&g_old, &g_new, &format!("{tag}: grads"));
        for (pass, old, new) in [
            ("forward", audit_old.forward, audit_new.forward),
            ("wgrad", audit_old.wgrad, audit_new.wgrad),
            ("dgrad", audit_old.dgrad, audit_new.dgrad),
        ] {
            assert_eq!(old.convs, new.convs, "{tag}: {pass} convs");
            assert_eq!(old.mul_ops, new.mul_ops, "{tag}: {pass} mul_ops");
            assert_eq!(old.int_add_ops, new.int_add_ops, "{tag}: {pass} int_add_ops");
            assert_eq!(old.float_add_ops, new.float_add_ops, "{tag}: {pass} float_add_ops");
            assert_eq!(old.group_scale_ops, new.group_scale_ops, "{tag}: {pass} group_scale_ops");
            assert_eq!(old.peak_acc_bits, new.peak_acc_bits, "{tag}: {pass} peak_acc_bits");
        }

        // the update: the historical inlined SGD vs the Optimizer trait
        let loss_old = legacy.train_step(&images, &labels, 0.05, sseed);
        let out = modern.train_step(&images, &labels, 0.05, sseed);
        assert_eq!(loss_old.to_bits(), out.loss.to_bits(), "{tag}: step loss");
        assert_bits_eq(&legacy.state(), &modern.state(), &format!("{tag}: post-update state"));
    }
}

#[test]
fn cnn_t_quantized_is_bit_identical_to_chain_trainer() {
    check_model("cnn_t", "e2m4_gnc_eg8mg1_sr", 3, 4);
}

#[test]
fn cnn_t_fp32_is_bit_identical_to_chain_trainer() {
    check_model("cnn_t", "fp32", 2, 4);
}

#[test]
fn cnn_t_e2m1_is_bit_identical_to_chain_trainer() {
    // the aggressive <2,1> format exercises different rounding paths
    check_model("cnn_t", "e2m1_gnc_eg8mg1_sr", 2, 4);
}

#[test]
fn cnn_s_quantized_is_bit_identical_to_chain_trainer() {
    check_model("cnn_s", "e2m4_gnc_eg8mg1_sr", 2, 4);
}
