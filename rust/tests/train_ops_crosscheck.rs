//! Analytic-vs-executed audit cross-check: the per-step op counts the
//! energy tables are built from (`nn::ops::count_training_ops`) against
//! the audit counters the Alg. 1 kernels ACTUALLY report when one native
//! training step runs on `cnn_t` — the analytic model and the kernels
//! must agree or the energy tables are fiction.
//!
//! What must match, and how:
//!
//! * **conv MACs** — the kernels count only in-bounds window taps, the
//!   analytic model full `K^2` windows. The test derives the in-bounds
//!   tap count per layer from geometry alone and pins the executed
//!   `mul_ops` of every pass to it EXACTLY; the full-window analytic
//!   count must then sit within the geometric clipping fraction of the
//!   executed one (and EQUAL it on the unpadded 1x1 layer).
//! * **pass symmetry** — Alg. 1's premise that fwd/wgrad/dgrad execute
//!   the same MAC count must hold in the executed counters exactly.
//! * **group scales / tree adds** — the analytic model uses the paper's
//!   Table VI convention `MACs / K^2` for every pass; the executed
//!   forward counters must equal that share exactly, while the backward
//!   passes reduce along different axes (wgrad trees over the batch with
//!   `Ho*Wo`-deep groups, dgrad over `Co` on the input grid) whose
//!   closed forms the test pins instead — documenting exactly where the
//!   paper convention is an approximation of the executed datapath.

use mls_train::data::{streams, DatasetConfig, SynthCifar};
use mls_train::mls::quantizer::QuantConfig;
use mls_train::nn::ops::count_training_ops;
use mls_train::nn::train::native_model;
use mls_train::nn::zoo::{native_network, Layer};

/// The quantized conv layers of `cnn_t`:
/// (ci, co, k, stride, pad, hin, win, ho, wo). The first (fp32) conv is
/// excluded — it runs the f32 path and is not audited, exactly as the
/// analytic model counts it separately as `conv_macs_unquantized`.
const QCONVS: &[(usize, usize, usize, usize, usize, usize, usize, usize, usize)] = &[
    (8, 16, 3, 2, 1, 16, 16, 8, 8),
    (16, 16, 1, 1, 0, 8, 8, 8, 8),
    (16, 16, 3, 1, 1, 8, 8, 8, 8),
];

/// In-bounds window taps of one conv layer, from geometry alone:
/// `#{(oy, ox, i, j) : 0 <= oy*s + i - p < hin, 0 <= ox*s + j - p < win}`
/// (separable into rows x cols; this mirrors the kernels' analytic
/// counter derivation without touching any kernel code).
fn inbounds_taps(
    k: usize,
    stride: usize,
    pad: usize,
    hin: usize,
    win: usize,
    ho: usize,
    wo: usize,
) -> u64 {
    let axis = |len: usize, out: usize| -> u64 {
        let mut c = 0u64;
        for o in 0..out {
            for t in 0..k {
                let pos = (o * stride + t) as isize - pad as isize;
                if pos >= 0 && (pos as usize) < len {
                    c += 1;
                }
            }
        }
        c
    };
    axis(hin, ho) * axis(win, wo)
}

/// The quantized conv shapes of the zoo twin of a native model, as
/// `(ci, co, k, stride, pad, hin, win, ho, wo)` tuples — the native graph
/// is LOWERED from this twin (`zoo::native_network` ->
/// `nn::graph::lower`), so these are by construction the shapes the
/// native model executes ("same" padding: `pad = (k - 1) / 2`).
fn quantized_convs(model: &str) -> Vec<(usize, usize, usize, usize, usize, usize, usize, usize, usize)> {
    native_network(model)
        .unwrap()
        .layers
        .iter()
        .filter_map(|l| match l {
            Layer::Conv { cin, cout, k, stride, h, w, hin, win, quantized: true, .. } => {
                Some((*cin, *cout, *k, *stride, (*k - 1) / 2, *hin, *win, *h, *w))
            }
            _ => None,
        })
        .collect()
}

#[test]
fn executed_audit_counters_match_analytic_model() {
    let batch = 4usize;
    let b = batch as u64;

    // the zoo twin's quantized conv shapes must be the pinned QCONVS set
    // (the lowering executes exactly these)
    assert_eq!(quantized_convs("cnn_t"), QCONVS.to_vec());

    // one native Alg. 1 step (nearest rounding: determinism is free)
    let mut cfg = QuantConfig::default();
    cfg.rounding = mls_train::mls::Rounding::Nearest;
    let mut model = native_model("cnn_t", cfg, 0).expect("cnn_t builds");
    let ds = SynthCifar::new(DatasetConfig::default());
    let (images, labels) = ds.batch(batch, streams::TRAIN, 0);
    let out = model.train_step(&images, &labels, 0.01, 1);
    assert!(out.loss.is_finite());
    let audit = out.audit;

    // ---- conv MACs: executed == geometry, exactly, for every pass ----
    let mut expect_macs = 0u64;
    let mut full_window_macs = 0u64;
    for &(ci, co, k, stride, pad, hin, win, ho, wo) in QCONVS {
        let taps = inbounds_taps(k, stride, pad, hin, win, ho, wo);
        expect_macs += b * (ci * co) as u64 * taps;
        full_window_macs += b * (ci * co * k * k * ho * wo) as u64;
    }
    assert_eq!(audit.forward.mul_ops, expect_macs, "executed fwd MACs != geometric tap count");
    assert_eq!(audit.wgrad.mul_ops, expect_macs, "executed wgrad MACs != geometric tap count");
    assert_eq!(audit.dgrad.mul_ops, expect_macs, "executed dgrad MACs != geometric tap count");
    assert_eq!(audit.forward.int_add_ops, expect_macs);

    // the unpadded 1x1 layer contributes with NO clipping: its full-window
    // and in-bounds counts coincide (sanity of the clipping story)
    let (_ci, _co, k, stride, pad, hin, win, ho, wo) = QCONVS[1];
    assert_eq!(
        inbounds_taps(k, stride, pad, hin, win, ho, wo),
        (k * k * ho * wo) as u64,
        "the 1x1 pad-0 layer must be clip-free"
    );

    // full-window analytic count vs executed: equal up to the border
    // clipping of the padded 3x3 layers (a few percent at these sizes)
    assert!(audit.forward.mul_ops <= full_window_macs);
    assert!(
        audit.forward.mul_ops as f64 >= 0.84 * full_window_macs as f64,
        "clipping fraction implausible: executed {} vs full-window {}",
        audit.forward.mul_ops,
        full_window_macs
    );

    // ---- against count_training_ops (per-sample, 3 passes/layer) ----
    let net = native_network("cnn_t").unwrap();
    let t = count_training_ops(&net, batch);
    let analytic_fwd_macs: f64 = QCONVS
        .iter()
        .map(|&(ci, co, k, _, _, _, _, ho, wo)| (ci * co * k * k * ho * wo) as f64)
        .sum();
    assert_eq!(
        t.conv_macs_quantized, 3.0 * analytic_fwd_macs,
        "analytic model must count 3 equal passes per quantized conv"
    );
    assert_eq!(t.conv_macs_quantized as u64 * b, 3 * full_window_macs);
    // the model-derived analytic count (bench_train_step's fp32
    // denominator) = the fp32 stem's 2 passes + 3 passes per quantized
    // conv, all full-window
    let stem_macs = (3 * 8 * 3 * 3 * 16 * 16) as u64;
    assert_eq!(model.conv_macs_per_sample() * b, 2 * stem_macs * b + 3 * full_window_macs);

    // ---- group scales / tree adds ----
    // forward: executed == the analytic MACs/K^2 convention, exactly
    // (group-scale applications are per (pixel, group) and never clipped)
    let expect_fwd_gscale: u64 =
        QCONVS.iter().map(|&(ci, co, _, _, _, _, _, ho, wo)| b * (co * ho * wo * ci) as u64).sum();
    let expect_fwd_tree: u64 = QCONVS
        .iter()
        .map(|&(ci, co, _, _, _, _, _, ho, wo)| b * (co * ho * wo) as u64 * (ci as u64 - 1))
        .sum();
    assert_eq!(audit.forward.group_scale_ops, expect_fwd_gscale);
    assert_eq!(audit.forward.float_add_ops, expect_fwd_tree);
    let analytic_fwd_gscale: f64 = QCONVS
        .iter()
        .map(|&(ci, co, _, _, _, _, _, ho, wo)| (ci * co * ho * wo) as f64)
        .sum();
    assert_eq!(
        audit.forward.group_scale_ops as f64,
        analytic_fwd_gscale * b as f64,
        "executed forward group scales must equal the analytic MACs/K^2 share"
    );
    // ... and the analytic total is exactly 3x its forward share (the
    // Table VI convention applies MACs/K^2 to the backward passes too)
    assert_eq!(t.group_scale_ops, 3.0 * analytic_fwd_gscale);
    assert_eq!(t.tree_adds, t.group_scale_ops);

    // backward: the EXECUTED datapath reduces along different axes; pin
    // the closed forms so the divergence from the paper convention is a
    // recorded, tested fact rather than silent drift.
    // wgrad: pixels = Ci*Co*K^2 (the dW grid), groups tree over the batch
    let expect_wgrad_gscale: u64 =
        QCONVS.iter().map(|&(ci, co, k, _, _, _, _, _, _)| (ci * co * k * k) as u64 * b).sum();
    let expect_wgrad_tree: u64 =
        QCONVS.iter().map(|&(ci, co, k, _, _, _, _, _, _)| (ci * co * k * k) as u64 * (b - 1)).sum();
    assert_eq!(audit.wgrad.group_scale_ops, expect_wgrad_gscale);
    assert_eq!(audit.wgrad.float_add_ops, expect_wgrad_tree);
    // dgrad: pixels = N*Ci*Hin*Win (the dA grid), groups tree over Co
    let expect_dgrad_gscale: u64 =
        QCONVS.iter().map(|&(ci, co, _, _, _, hin, win, _, _)| b * (ci * hin * win * co) as u64).sum();
    let expect_dgrad_tree: u64 = QCONVS
        .iter()
        .map(|&(ci, co, _, _, _, hin, win, _, _)| b * (ci * hin * win) as u64 * (co as u64 - 1))
        .sum();
    assert_eq!(audit.dgrad.group_scale_ops, expect_dgrad_gscale);
    assert_eq!(audit.dgrad.float_add_ops, expect_dgrad_tree);

    // ---- dq element counts are the exact tensor sizes ----
    let expect_dq_act: f64 =
        QCONVS.iter().map(|&(ci, _, _, _, _, hin, win, _, _)| (ci * hin * win) as f64).sum();
    assert_eq!(t.dq_act_elements, expect_dq_act, "dq_act must use exact input dims");
    let expect_dq_err: f64 =
        QCONVS.iter().map(|&(_, co, _, _, _, _, _, ho, wo)| (co * ho * wo) as f64).sum();
    assert_eq!(t.dq_err_elements, expect_dq_err);
}

#[test]
fn resnet_executed_macs_match_geometry() {
    // the residual model's executed counters obey the same geometric
    // in-bounds tap law as the chain — including the 1x1 projection
    // shortcuts, which are clip-free (pad 0) — and the three passes stay
    // exactly equal per Alg. 1.
    let batch = 2usize;
    let b = batch as u64;
    let qconvs = quantized_convs("resnet_t");
    assert_eq!(qconvs.len(), 8, "stem excluded; 2 + 3 + 3 quantized convs");

    let mut expect_macs = 0u64;
    for &(ci, co, k, stride, pad, hin, win, ho, wo) in &qconvs {
        expect_macs += b * (ci * co) as u64 * inbounds_taps(k, stride, pad, hin, win, ho, wo);
    }

    let mut cfg = QuantConfig::default();
    cfg.rounding = mls_train::mls::Rounding::Nearest;
    let mut model = native_model("resnet_t", cfg, 0).expect("resnet_t builds");
    let ds = SynthCifar::new(DatasetConfig::default());
    let (images, labels) = ds.batch(batch, streams::TRAIN, 0);
    let out = model.train_step(&images, &labels, 0.01, 1);
    assert!(out.loss.is_finite());
    let audit = out.audit;

    assert_eq!(audit.forward.mul_ops, expect_macs, "executed fwd MACs != geometric tap count");
    assert_eq!(audit.wgrad.mul_ops, expect_macs, "executed wgrad MACs != geometric tap count");
    assert_eq!(audit.dgrad.mul_ops, expect_macs, "executed dgrad MACs != geometric tap count");

    // the analytic model counts the same conv set full-window, 3 passes
    let net = native_network("resnet_t").unwrap();
    let t = count_training_ops(&net, batch);
    let full_window: f64 = qconvs
        .iter()
        .map(|&(ci, co, k, _, _, _, _, ho, wo)| (ci * co * k * k * ho * wo) as f64)
        .sum();
    assert_eq!(t.conv_macs_quantized, 3.0 * full_window);
    assert!(audit.forward.mul_ops as f64 <= full_window * b as f64);
    assert!(
        audit.forward.mul_ops as f64 >= 0.84 * full_window * b as f64,
        "clipping fraction implausible"
    );
    // the twin counts the residual joins the executed Add nodes implement
    let ewadds: f64 = net
        .layers
        .iter()
        .map(|l| match l {
            Layer::EwAdd { c, h, w } => (c * h * w) as f64,
            _ => 0.0,
        })
        .sum();
    assert_eq!(t.ewadd_elements, ewadds);
    assert!(t.ewadd_elements > 0.0);
}
