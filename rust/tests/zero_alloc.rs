//! The zero-alloc steady-state contract of the step arena
//! ([`mls_train::nn::arena`]), pinned with a counting global allocator:
//!
//! 1. After the one-step warm-up, `train_step_quiet` performs ZERO heap
//!    allocation — not "little", zero — for whole 3-step replays of
//!    `cnn_t` and `resnet_t`. Pinned at `threads = 1`: the worker pool's
//!    dispatch machinery (one `Arc` job per multi-chunk fan-out, lazily
//!    spawned threads) allocates on purpose, which is why the strict
//!    claim is single-threaded while the arena's own strict mode (pool
//!    misses panic) holds at every thread count.
//! 2. The arena path is bit-identical to the historical allocating path
//!    — loss, accuracy, every per-layer audit counter, and the
//!    post-update parameter state — across {1, 2, 8} threads and every
//!    SIMD dispatch level this CPU supports.
//!
//! One `#[test]` on purpose: the allocation counters are process-global,
//! so no concurrent test may run in this binary.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use mls_train::data::{streams, DatasetConfig, SynthCifar};
use mls_train::mls::quantizer::QuantConfig;
use mls_train::nn::train::{native_model, state_checksum};
use mls_train::util::simd::{self, Level};

/// [`System`] plus allocation counters. Deallocation is passed through
/// uncounted: the contract is "no heap growth", and frees of warm-up
/// buffers are not evidence against it.
struct Counting;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

/// The paper's default quantized training config: `<2,4>` element
/// format, (n, c) grouping, stochastic rounding — the config whose step
/// loop the arena was built for.
fn qcfg() -> QuantConfig {
    QuantConfig::parse_name("e2m4_gnc_eg8mg1_sr").unwrap()
}

fn dataset() -> SynthCifar {
    SynthCifar::new(DatasetConfig { noise: 1.0, label_noise: 0.0, seed: 5, ..Default::default() })
}

/// Warm one step, then replay two more and assert the allocator counters
/// did not move at all.
fn assert_zero_alloc_steps(name: &str) {
    let mut m = native_model(name, qcfg(), 9).unwrap();
    m.set_threads(1);
    m.enable_step_arena();
    let ds = dataset();
    let batches: Vec<_> = (0..3).map(|step| ds.batch(4, streams::TRAIN, step)).collect();

    // step 1: every pool and conv slot grows to steady-state capacity
    let (images, labels) = &batches[0];
    m.train_step_quiet(images, labels, 0.05, 31);

    let (allocs0, bytes0) = (ALLOCS.load(Ordering::Relaxed), BYTES.load(Ordering::Relaxed));
    for (step, (images, labels)) in batches.iter().enumerate().skip(1) {
        m.train_step_quiet(images, labels, 0.05, 31 + step as i64);
    }
    let allocs = ALLOCS.load(Ordering::Relaxed) - allocs0;
    let bytes = BYTES.load(Ordering::Relaxed) - bytes0;
    assert_eq!(
        (allocs, bytes),
        (0, 0),
        "{name}: warm arena steps hit the heap ({allocs} allocations, {bytes} bytes)"
    );
}

/// Fresh allocating model vs fresh arena model, same seeds and batches:
/// loss, accuracy, the full per-layer audit stream and the post-update
/// parameter state must agree bit for bit.
fn assert_arena_matches_heap(name: &str, threads: usize) {
    let mut heap = native_model(name, qcfg(), 9).unwrap();
    let mut arena = native_model(name, qcfg(), 9).unwrap();
    heap.set_threads(threads);
    arena.set_threads(threads);
    arena.enable_step_arena();
    let ds = dataset();
    for step in 0..2u64 {
        let (images, labels) = ds.batch(2, streams::TRAIN, step);
        let sseed = 31 + step as i64;
        let out = heap.train_step(&images, &labels, 0.05, sseed);
        let (loss, acc) = arena.train_step_quiet(&images, &labels, 0.05, sseed);
        let tag = format!("{name} threads={threads} simd={:?} step {step}", simd::active());
        assert_eq!(out.loss.to_bits(), loss.to_bits(), "{tag}: loss");
        assert_eq!(out.acc.to_bits(), acc.to_bits(), "{tag}: acc");
        assert_eq!(&out.audit, arena.last_audit().unwrap(), "{tag}: audit stream");
        assert_eq!(
            state_checksum(&heap.state()),
            state_checksum(&arena.state()),
            "{tag}: post-update state"
        );
    }
}

#[test]
fn arena_steps_allocate_nothing_and_match_the_heap_path() {
    // the strict-zero phase runs FIRST: nothing may have dispatched to
    // the worker pool yet, so the single-threaded warm loop is provably
    // the only allocation source being measured
    for name in ["cnn_t", "resnet_t"] {
        assert_zero_alloc_steps(name);
    }

    let prev = simd::active();
    for name in ["cnn_t", "resnet_t"] {
        for threads in [1usize, 2, 8] {
            for level in Level::supported() {
                simd::set_level(level);
                assert_arena_matches_heap(name, threads);
            }
        }
    }
    simd::set_level(prev);
}
