//! End-to-end tests of the declarative lab runner: a plan file expands
//! deterministically, `lab::run_plan` executes every trial into its own
//! directory (trial_input.json + audit stream + trial_output.json),
//! re-runs are crash-resumable — only trials whose existing output fails
//! validation re-execute, and a re-executed trial reproduces its output
//! bit-for-bit (fixed seeds) outside the wall-clock `timing` object — and
//! the analysis step emits the ranked JSONL + markdown tables.

use std::path::PathBuf;

use mls_train::coordinator::lab::{self, Plan, TrialStatus};
use mls_train::util::json::Json;

/// A fresh scratch dir per test (tests run in parallel).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mls_lab_test_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The 2×2 test plan: cnn_t × {fp32, e2m4} × seeds {0, 1}, tiny steps.
fn plan_2x2() -> Plan {
    let v = Json::parse(
        r#"{
            "name": "resume2x2",
            "base": {"steps": 3, "batch": 4, "eval_every": 2, "eval_batches": 1,
                     "noise": 1.0, "label_noise": 0.0},
            "grid": {"cfg": ["fp32", "e2m4_gnc_eg8mg1_sr"], "model": ["cnn_t"]},
            "seeds": [0, 1]
        }"#,
    )
    .unwrap();
    Plan::from_json(&v).unwrap()
}

fn statuses_of(report: &lab::LabReport) -> Vec<(&str, TrialStatus)> {
    report.statuses.iter().map(|(id, s)| (id.as_str(), *s)).collect()
}

/// Parse a trial_output.json and drop the wall-clock `timing` object —
/// everything left must be a pure function of the resolved config.
fn parsed_minus_timing(path: &std::path::Path) -> Json {
    let mut v = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    if let Json::Obj(m) = &mut v {
        assert!(m.remove("timing").is_some(), "{}: no timing object", path.display());
    }
    v
}

#[test]
fn committed_example_plans_expand() {
    // integration tests run with cwd = rust/, the crate manifest dir
    let smoke = Plan::load(std::path::Path::new("../examples/plan_smoke.json")).unwrap();
    let trials = smoke.trials().unwrap();
    assert_eq!(trials.len(), 4, "smoke: cnn_t x 2 cfgs x 2 seeds");
    assert!(trials.iter().all(|t| t.config.steps == 6 && t.config.batch == 8));

    let table2 = Plan::load(std::path::Path::new("../examples/plan_table2.json")).unwrap();
    let trials = table2.trials().unwrap();
    assert_eq!(trials.len(), 12, "table2: 2 models x 3 cfgs x 2 optimizers");
    let ids: Vec<&str> = trials.iter().map(|t| t.id.as_str()).collect();
    assert!(ids.contains(&"t000__cnn_t__fp32__s0"), "{ids:?}");
    // every model/cfg/optimizer combination appears exactly once
    let mut combos: Vec<(String, String, String)> = trials
        .iter()
        .map(|t| (t.config.model.clone(), t.config.cfg_name.clone(), t.config.optimizer.clone()))
        .collect();
    combos.sort();
    combos.dedup();
    assert_eq!(combos.len(), 12);
}

#[test]
fn crash_resume_reruns_only_the_corrupted_trial_bit_identically() {
    let out = scratch("crash_resume");
    let plan = plan_2x2();

    // fresh run: all four trials execute
    let r1 = lab::run_plan(&plan, &out, false).unwrap();
    assert_eq!(r1.ran(), 4);
    assert_eq!(r1.skipped(), 0);
    let ids: Vec<&str> = r1.statuses.iter().map(|(id, _)| id.as_str()).collect();
    assert_eq!(
        ids,
        vec![
            "t000__cnn_t__fp32__s0",
            "t001__cnn_t__fp32__s1",
            "t002__cnn_t__e2m4_gnc_eg8mg1_sr__s0",
            "t003__cnn_t__e2m4_gnc_eg8mg1_sr__s1",
        ]
    );

    let run_dir = out.join("resume2x2");
    let victim = "t002__cnn_t__e2m4_gnc_eg8mg1_sr__s0";
    let victim_out = run_dir.join(victim).join("trial_output.json");

    // per-trial artifacts exist: input, output, and (quantized only) the
    // streamed audit
    for id in &ids {
        let dir = run_dir.join(id);
        assert!(dir.join("trial_input.json").is_file(), "{id}: no trial_input.json");
        assert!(dir.join("trial_output.json").is_file(), "{id}: no trial_output.json");
        let audit = dir.join(format!(
            "cnn_t_{}_s{}.audit.jsonl",
            if id.contains("fp32") { "fp32" } else { "e2m4_gnc_eg8mg1_sr" },
            id.rsplit("__s").next().unwrap()
        ));
        assert_eq!(
            audit.is_file(),
            !id.contains("fp32"),
            "{id}: audit stream presence (fp32 collects none)"
        );
        if audit.is_file() {
            let text = std::fs::read_to_string(&audit).unwrap();
            assert_eq!(text.lines().count(), 3, "one audit record per step");
            for line in text.lines() {
                Json::parse(line).unwrap();
            }
        }
    }

    let pristine = parsed_minus_timing(&victim_out);

    // crash simulation: truncate the victim's output mid-bytes
    let bytes = std::fs::read(&victim_out).unwrap();
    std::fs::write(&victim_out, &bytes[..bytes.len() / 2]).unwrap();

    // resume: ONLY the corrupted trial re-executes
    let r2 = lab::run_plan(&plan, &out, false).unwrap();
    let expect: Vec<(&str, TrialStatus)> = ids
        .iter()
        .map(|&id| (id, if id == victim { TrialStatus::Ran } else { TrialStatus::Skipped }))
        .collect();
    assert_eq!(statuses_of(&r2), expect);
    assert_eq!(r2.ran(), 1);
    assert_eq!(r2.skipped(), 3);

    // fixed seeds: the re-run output is bit-identical outside `timing`
    assert_eq!(
        parsed_minus_timing(&victim_out).to_string_pretty(),
        pristine.to_string_pretty(),
        "re-executed trial must reproduce its output bit-for-bit"
    );

    // third invocation: everything validates, nothing runs
    let r3 = lab::run_plan(&plan, &out, false).unwrap();
    assert_eq!(r3.ran(), 0);
    assert_eq!(r3.skipped(), 4);

    // a stale config (edited plan) also invalidates: same name, new steps
    let mut edited = plan.clone();
    edited.base.iter_mut().find(|(k, _)| k == "steps").unwrap().1 = "4".to_string();
    let r4 = lab::run_plan(&edited, &out, false).unwrap();
    assert_eq!(r4.ran(), 4, "config echo mismatch must re-run every trial");
}

#[test]
fn trial_outputs_have_the_documented_shape() {
    let out = scratch("output_shape");
    let plan = plan_2x2();
    lab::run_plan(&plan, &out, false).unwrap();
    let run_dir = out.join("resume2x2");

    let v = Json::parse(
        &std::fs::read_to_string(
            run_dir.join("t002__cnn_t__e2m4_gnc_eg8mg1_sr__s0").join("trial_output.json"),
        )
        .unwrap(),
    )
    .unwrap();
    assert_eq!(v.req("plan").unwrap().as_str(), Some("resume2x2"));
    assert_eq!(v.req("seed").unwrap().as_usize(), Some(0));
    let cfg = v.req("config").unwrap().as_obj().unwrap();
    assert_eq!(cfg.get("model").unwrap().as_str(), Some("cnn_t"));
    assert_eq!(cfg.get("steps").unwrap().as_str(), Some("3"));
    let r = v.req("result").unwrap();
    assert_eq!(r.req("status").unwrap().as_str(), Some("ok"));
    assert_eq!(r.req("steps_run").unwrap().as_usize(), Some(3));
    assert_eq!(r.req("loss_curve").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(r.req("acc_curve").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(r.req("eval").unwrap().as_arr().unwrap().len(), 1, "eval_every=2 over 3 steps");
    assert_eq!(r.req("audit_steps").unwrap().as_usize(), Some(3));
    let totals = r.req("audit_totals").unwrap();
    assert!(totals.req("forward").unwrap().req("convs").unwrap().as_usize().unwrap() > 0);
    let checksum = r.req("state_checksum").unwrap().as_str().unwrap();
    assert_eq!(checksum.len(), 16, "fnv64 hex: {checksum:?}");
    v.req("timing").unwrap().req("mean_step_ms").unwrap().as_f64().unwrap();

    // fp32 trial: no audit totals, audit_steps 0
    let v = Json::parse(
        &std::fs::read_to_string(run_dir.join("t000__cnn_t__fp32__s0").join("trial_output.json"))
            .unwrap(),
    )
    .unwrap();
    let r = v.req("result").unwrap();
    assert_eq!(r.req("audit_steps").unwrap().as_usize(), Some(0));
    assert!(r.get("audit_totals").is_none());

    // the run dir carries a provenance copy of the normalized plan
    let prov = Json::parse(&std::fs::read_to_string(run_dir.join("plan.json")).unwrap()).unwrap();
    assert_eq!(Plan::from_json(&prov).unwrap(), plan);
}

#[test]
fn analysis_ranks_trials_and_builds_tables() {
    let out = scratch("analysis");
    let plan = plan_2x2();
    let report = lab::run_plan(&plan, &out, false).unwrap();
    let analysis = report.analysis_dir;

    let ranked = std::fs::read_to_string(analysis.join("ranked.jsonl")).unwrap();
    let rows: Vec<Json> = ranked.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(rows.len(), 4, "one ranked record per trial");
    let mut last_acc = f64::INFINITY;
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.req("rank").unwrap().as_usize(), Some(i + 1));
        assert_eq!(row.req("status").unwrap().as_str(), Some("ok"));
        let acc = row.req("test_acc").unwrap().as_f64().unwrap();
        assert!(acc <= last_acc, "ranking must be by descending accuracy");
        last_acc = acc;
        let bits = row.req("bits").unwrap().as_usize().unwrap();
        let cfg = row.req("cfg").unwrap().as_str().unwrap();
        assert_eq!(bits, if cfg == "fp32" { 32 } else { 7 }, "{cfg}: element bits");
    }

    let tables = std::fs::read_to_string(analysis.join("tables.md")).unwrap();
    for needle in [
        "## Ranked trials",
        "## Best format per model",
        "## Accuracy-vs-bitwidth frontier",
        "**best**",
        "| cnn_t |",
        "e2m4_gnc_eg8mg1_sr",
        "fp32",
    ] {
        assert!(tables.contains(needle), "tables.md missing {needle:?}:\n{tables}");
    }

    // the standalone analyze entry point rebuilds the same files
    std::fs::remove_dir_all(&analysis).unwrap();
    let rebuilt = lab::analyze(&report.run_dir).unwrap();
    assert_eq!(std::fs::read_to_string(rebuilt.join("ranked.jsonl")).unwrap(), ranked);
}

#[test]
fn run_plan_file_reads_a_plan_from_disk() {
    let out = scratch("plan_file");
    let plan_path = out.join("p.json");
    std::fs::write(
        &plan_path,
        r#"{"name": "fileplan",
            "base": {"steps": 2, "batch": 4, "eval_every": 0, "eval_batches": 1,
                     "noise": 1.0, "label_noise": 0.0},
            "grid": {"model": ["cnn_t"], "cfg": ["fp32"]}}"#,
    )
    .unwrap();
    let report = lab::run_plan_file(&plan_path, &out, false).unwrap();
    assert_eq!(report.ran(), 1);
    assert!(report.summary().contains("ran 1, skipped 0"), "{}", report.summary());
    assert!(out.join("fileplan").join("t000__cnn_t__fp32__s0").join("trial_output.json").is_file());
    // --force re-executes validated trials
    let forced = lab::run_plan_file(&plan_path, &out, true).unwrap();
    assert_eq!(forced.ran(), 1);
    assert_eq!(forced.skipped(), 0);
}
