//! Integration tests over the real PJRT runtime + artifacts.
//!
//! These require `make artifacts` to have run. They compile the real
//! lowered train/eval/probe HLO and verify end-to-end behaviour: losses
//! decrease, shapes match the manifest, eval is deterministic, and the
//! quantized path actually perturbs training (vs fp32).
//!
//! The PJRT client is not `Send` (Rc internals in the xla crate) and XLA
//! compilation costs seconds per artifact, so all engine-backed checks run
//! sequentially inside ONE #[test] sharing one engine.

use mls_train::coordinator::{trainer, TrainConfig};
use mls_train::data::{streams, SynthCifar};
use mls_train::runtime::Engine;

fn quick_config(model: &str, cfg_name: &str, steps: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.backend = mls_train::coordinator::Backend::Pjrt; // this suite exercises the PJRT engine
    c.model = model.to_string();
    c.cfg_name = cfg_name.to_string();
    c.steps = steps;
    c.eval_every = 0;
    c.eval_batches = 2;
    c.out_dir = None;
    c.data.noise = 0.8;
    c.lr.base = 0.05;
    c.lr.milestones = vec![];
    c
}

#[test]
fn end_to_end_runtime_suite() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping end_to_end_runtime_suite: no artifacts (run `make artifacts` first)");
        return;
    }
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping end_to_end_runtime_suite: built without the `pjrt` feature");
        return;
    }
    let mut e = Engine::from_dir(dir).expect("run `make artifacts` before cargo test");

    // --- manifest and init consistency -----------------------------------
    assert!(!e.manifest.artifacts.is_empty());
    for (name, meta) in e.manifest.models.clone() {
        let init = e.manifest.load_init(&name).unwrap();
        assert_eq!(init.len(), meta.state_dim);
        assert!(init.iter().all(|v| v.is_finite()));
        // momentum half starts at zero
        assert!(init[meta.n_var..].iter().all(|&v| v == 0.0), "{name} momentum");
        // specs tile the var region
        let total: usize = meta.specs.iter().map(|s| s.size()).sum();
        assert_eq!(total, meta.n_var, "{name} spec tiling");
    }

    // --- input validation --------------------------------------------------
    let err = e.execute("cnn_s", "train_step", "fp32", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("inputs"));
    let err = e.manifest.find("cnn_s", "train_step", "nope").unwrap_err();
    assert!(format!("{err:#}").contains("fp32"));

    // --- fp32 training reduces loss ----------------------------------------
    let c = quick_config("cnn_s", "fp32", 25);
    let rf = trainer::train(&mut e, &c).unwrap();
    assert!(!rf.diverged);
    let first = rf.metrics.steps[0].loss;
    let last = rf.metrics.final_loss(5);
    assert!(last < first as f64 * 0.8, "fp32 loss {first} -> {last}");

    // --- quantized training reduces loss and differs from fp32 -------------
    let cq = quick_config("cnn_s", "e2m4_gnc_eg8mg1_sr", 25);
    let rq = trainer::train(&mut e, &cq).unwrap();
    assert!(!rq.diverged);
    assert!(
        rq.metrics.final_loss(5) < rq.metrics.steps[0].loss as f64 * 0.9,
        "quantized loss {} -> {}",
        rq.metrics.steps[0].loss,
        rq.metrics.final_loss(5)
    );
    let diff = rq
        .final_state
        .iter()
        .zip(&rf.final_state)
        .filter(|(a, b)| a != b)
        .count();
    assert!(diff > rq.final_state.len() / 10, "only {diff} differing state elements");

    // --- eval determinism ---------------------------------------------------
    let model = "cnn_s";
    let state = e.manifest.load_init(model).unwrap();
    let ds = SynthCifar::new(Default::default());
    let batch = e.manifest.model(model).unwrap().batch;
    let (images, labels) = ds.batch(batch, streams::VAL, 0);
    let a = e.eval_step(model, &state, &images, &labels).unwrap();
    let b = e.eval_step(model, &state, &images, &labels).unwrap();
    assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    assert_eq!(a.acc.to_bits(), b.acc.to_bits());

    // --- seed controls stochastic rounding, bit-reproducibly ----------------
    let cfg = "e2m4_gnc_eg8mg1_sr";
    let (images, labels) = ds.batch(batch, streams::TRAIN, 0);
    let init = e.manifest.load_init(model).unwrap();
    let mut s1 = init.clone();
    e.train_step(model, cfg, &mut s1, &images, &labels, 1, 0.01).unwrap();
    let mut s2 = init.clone();
    e.train_step(model, cfg, &mut s2, &images, &labels, 2, 0.01).unwrap();
    assert_ne!(s1, s2, "stochastic rounding seed must matter");
    let mut s3 = init.clone();
    e.train_step(model, cfg, &mut s3, &images, &labels, 1, 0.01).unwrap();
    assert_eq!(s1, s3, "same seed must reproduce bit-exactly");

    // --- probe outputs match manifest shapes --------------------------------
    let model = "resnet_t";
    if e.manifest.find(model, "probe_step", cfg).is_ok() {
        let meta = e.manifest.model(model).unwrap().clone();
        let state = e.manifest.load_init(model).unwrap();
        let (images, labels) = ds.batch(meta.batch, streams::TEST, 0);
        let outs = e.probe_step(model, cfg, &state, &images, &labels, 3).unwrap();
        let k = meta.probe_names.len();
        assert_eq!(outs.len(), 3 * k);
        for (i, name) in meta.probe_names.iter().enumerate() {
            let a_len: usize = meta.probe_a_shapes[name].iter().product();
            let e_len: usize = meta.probe_e_shapes[name].iter().product();
            assert_eq!(outs[i].len(), a_len, "A.{name}");
            assert_eq!(outs[k + i].len(), e_len, "E.{name}");
            assert!(outs[k + i].iter().all(|v| v.is_finite()), "E.{name} finite");
        }
    } else {
        eprintln!("probe artifact missing; probe checks skipped");
    }
}
