//! Property tests over the MLS quantizer and arithmetic simulator
//! (mini-proptest harness in util::prop; reproduce failures with
//! `PROP_SEED=<seed> cargo test --test proptests`).

use mls_train::arith::conv::{conv2d_f32, lowbit_conv};
use mls_train::arith::bitwidth;
use mls_train::mls::format::{self, EmFormat};
use mls_train::mls::quantizer::{fake_quant, quantize, QuantConfig, Rounding};
use mls_train::mls::{Grouping, MlsTensor};
use mls_train::util::prop::{check, grouped_tensor, shape4};
use mls_train::util::rng::Pcg32;

fn random_cfg(rng: &mut Pcg32) -> QuantConfig {
    let groupings = [Grouping::None, Grouping::First, Grouping::Second, Grouping::Both];
    QuantConfig {
        element: EmFormat::new(rng.below(4), 1 + rng.below(5)),
        group: EmFormat::new(if rng.uniform() < 0.5 { 4 } else { 8 }, rng.below(2)),
        grouping: groupings[rng.below(4) as usize],
        rounding: if rng.uniform() < 0.5 { Rounding::Stochastic } else { Rounding::Nearest },
        enabled: true,
    }
}

fn quantize_random(rng: &mut Pcg32) -> (Vec<f32>, Vec<usize>, QuantConfig, MlsTensor) {
    let shape = shape4(rng, 6);
    let cfg = random_cfg(rng);
    let x = grouped_tensor(rng, shape);
    let r = rng.rounding_offsets(x.len());
    let t = quantize(&x, &shape, &cfg, &r);
    (x, shape.to_vec(), cfg, t)
}

#[test]
fn prop_codes_in_range() {
    check("codes_in_range", |rng| {
        let (_, _, cfg, t) = quantize_random(rng);
        let max_code = (1u32 << cfg.element.e) - 1;
        let max_man = (1u32 << cfg.element.m) - 1;
        assert!(t.exp_code.iter().all(|&c| (c as u32) <= max_code));
        assert!(t.man.iter().all(|&m| m <= max_man));
        let max_gcode = (1u32 << cfg.group.e) - 1;
        assert!(t.sg_exp.iter().all(|&c| (c as u32) <= max_gcode.max(126)));
        assert!(t.sg_man.iter().all(|&m| m <= (1u32 << cfg.group.m) - 1));
    });
}

#[test]
fn prop_error_bound_nearest() {
    check("error_bound", |rng| {
        let shape = shape4(rng, 6);
        let mut cfg = random_cfg(rng);
        cfg.rounding = Rounding::Nearest;
        let x = grouped_tensor(rng, shape);
        let t = quantize(&x, &shape, &cfg, &[]);
        let q = t.dequantize();
        for (idx, (&xi, &qi)) in x.iter().zip(&q).enumerate() {
            let g = cfg.grouping.group_of(&shape, idx);
            // one full ulp at the coarsest level of this group: nearest
            // rounding gives half an ulp except at the top of the range,
            // where mantissa saturation (Alg. 2 line 13 clip) can cost a
            // full step for E=0 fixed point.
            let bound = t.s_t * t.group_scale(g) * 0.5f32.powi(cfg.element.m as i32);
            assert!(
                (qi - xi).abs() <= bound * 1.0001 + 1e-9,
                "idx {idx}: x={xi} q={qi} bound={bound} cfg={}",
                cfg.name()
            );
        }
    });
}

#[test]
fn prop_stochastic_brackets_value() {
    // stochastic result never moves past one grid step from the input
    check("stochastic_brackets", |rng| {
        let shape = shape4(rng, 5);
        let mut cfg = random_cfg(rng);
        cfg.rounding = Rounding::Stochastic;
        let x = grouped_tensor(rng, shape);
        let r = rng.rounding_offsets(x.len());
        let q = fake_quant(&x, &shape, &cfg, &r);
        let t = quantize(&x, &shape, &cfg, &r);
        for (idx, (&xi, &qi)) in x.iter().zip(&q).enumerate() {
            let g = cfg.grouping.group_of(&shape, idx);
            let step = t.s_t * t.group_scale(g) * 0.5f32.powi(cfg.element.m as i32);
            assert!(
                (qi - xi).abs() <= step * 1.0001 + 1e-9,
                "idx {idx}: x={xi} q={qi} step={step}"
            );
        }
    });
}

#[test]
fn prop_group_scale_dominance() {
    // |x| / (S_t * S_g) <= 1 for every element (ceil rounding guarantees it)
    check("dominance", |rng| {
        let (x, shape, cfg, t) = quantize_random(rng);
        if t.s_t == 0.0 {
            return;
        }
        for (idx, &xi) in x.iter().enumerate() {
            let g = cfg.grouping.group_of(&shape, idx);
            let xf = xi.abs() / (t.group_scale(g) * t.s_t);
            assert!(xf <= 1.0 + 1e-6, "idx {idx}: xf={xf}");
        }
    });
}

#[test]
fn prop_dequantize_fixed_point() {
    // decoding stored fields reproduces dequantize() exactly
    check("decode_consistency", |rng| {
        let (_, _, _, t) = quantize_random(rng);
        let q = t.dequantize();
        for idx in 0..t.len() {
            assert_eq!(q[idx].to_bits(), t.value(idx).to_bits());
        }
    });
}

#[test]
fn prop_integer_conv_matches_float_conv() {
    check("int_conv", |rng| {
        let mut cfg = QuantConfig {
            element: EmFormat::new(rng.below(3), 1 + rng.below(4)),
            ..QuantConfig::default()
        };
        cfg.rounding = Rounding::Nearest;
        let ci = 1 + rng.below(4) as usize;
        let co = 1 + rng.below(4) as usize;
        let n = 1 + rng.below(2) as usize;
        let hw = 3 + rng.below(4) as usize;
        let wshape = [co, ci, 3, 3];
        let ashape = [n, ci, hw, hw];
        let w = grouped_tensor(rng, wshape);
        let a = grouped_tensor(rng, ashape);
        let tw = quantize(&w, &wshape, &cfg, &[]);
        let ta = quantize(&a, &ashape, &cfg, &[]);
        let out = lowbit_conv(&tw, &ta, 1, 1);
        let (zf, _) = conv2d_f32(&tw.dequantize(), wshape, &ta.dequantize(), ashape, 1, 1);
        let scale = zf.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-9);
        for (i, (x, y)) in out.z.iter().zip(&zf).enumerate() {
            assert!((x - y).abs() / scale < 2e-5, "i={i} {x} vs {y} cfg={}", cfg.name());
        }
        // and the accumulator never exceeded the analysis
        assert!(out.peak_acc_bits <= bitwidth::required_acc_bits(cfg.element, 9));
    });
}

#[test]
fn prop_storage_smaller_than_f32() {
    check("storage", |rng| {
        let (_, _, cfg, t) = quantize_random(rng);
        if t.len() < 16 {
            return; // constant overhead dominates tiny tensors
        }
        if cfg.element_bits() < 16 && t.group_count() * 4 <= t.len() {
            assert!(t.compression_ratio() > 1.0, "{}", cfg.name());
        }
    });
}

#[test]
fn prop_exp2i_exact() {
    check("exp2i", |rng| {
        let k = rng.below(253) as i32 - 126;
        let v = format::exp2i(k);
        assert_eq!(v, 2.0f64.powi(k) as f32);
    });
}
