//! Cross-layer golden tests: the Rust MLS implementation must reproduce the
//! Python/jnp reference (ref.py) BIT-EXACTLY on the golden vectors emitted
//! by `python/tests/test_golden.py` into `artifacts/golden/`.

use std::path::PathBuf;

use mls_train::arith::intra::{intra_group_mac, Element};
use mls_train::mls::quantizer::{quantize, QuantConfig};
use mls_train::util::json::Json;
use mls_train::util::stats;

fn golden_dir() -> PathBuf {
    // artifacts/ lives at the repo root (one level above the rust package),
    // where python/tests/test_golden.py writes it; the golden set is also
    // checked in so this test always runs.
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("artifacts")
        .join("golden")
}

fn load(name: &str) -> Option<Json> {
    let path = golden_dir().join(name);
    let text = std::fs::read_to_string(&path).ok()?;
    Some(Json::parse(&text).expect("golden json parses"))
}

fn require_goldens() -> Vec<String> {
    let index = load("index.json").unwrap_or_else(|| {
        panic!(
            "golden vectors missing at {:?} — run `make test-python` (or \
             `cd python && pytest tests/test_golden.py`) first",
            golden_dir()
        )
    });
    index
        .as_arr()
        .expect("index is an array")
        .iter()
        .map(|v| v.as_str().unwrap().to_string())
        .collect()
}

#[test]
fn quantizer_bit_exact_against_python() {
    let names = require_goldens();
    assert!(names.len() >= 10, "expected a full golden set, got {names:?}");
    for name in names {
        let doc = load(&name).unwrap();
        let cfg = QuantConfig::from_json(doc.req("cfg").unwrap()).unwrap();
        let shape = doc.req("shape").unwrap().usizes().unwrap();
        let x = doc.req("x").unwrap().f32s().unwrap();
        let r = doc.req("r").unwrap().f32s().unwrap();

        let t = quantize(&x, &shape, &cfg, &r);

        // tensor scale
        let st_expect = doc.req("s_t").unwrap().as_f32().unwrap();
        assert_eq!(t.s_t.to_bits(), st_expect.to_bits(), "{name}: s_t");

        // group-scale codes
        let sg_exp = doc.req("sg_exp_code").unwrap().i32s().unwrap();
        let sg_man = doc.req("sg_man").unwrap().i32s().unwrap();
        assert_eq!(t.sg_exp.len(), sg_exp.len(), "{name}: group count");
        for g in 0..sg_exp.len() {
            assert_eq!(t.sg_exp[g] as i32, sg_exp[g], "{name}: sg_exp[{g}]");
            assert_eq!(t.sg_man[g] as i32, sg_man[g], "{name}: sg_man[{g}]");
        }
        // group-scale values
        let sg_vals = doc.req("s_g").unwrap().f32s().unwrap();
        for g in 0..sg_vals.len() {
            assert_eq!(t.group_scale(g).to_bits(), sg_vals[g].to_bits(), "{name}: s_g[{g}]");
        }

        // element fields
        let exp_codes = doc.req("x_exp_code").unwrap().i32s().unwrap();
        let mans = doc.req("x_man").unwrap().i32s().unwrap();
        let signs = doc.req("sign").unwrap().i32s().unwrap();
        for i in 0..x.len() {
            assert_eq!(t.exp_code[i] as i32, exp_codes[i], "{name}: exp_code[{i}] (x={})", x[i]);
            assert_eq!(t.man[i] as i32, mans[i], "{name}: man[{i}] (x={})", x[i]);
            assert_eq!(t.sign[i] as i32, signs[i], "{name}: sign[{i}]");
        }

        // dequantized values — full bit equality
        let q_expect = doc.req("q").unwrap().f32s().unwrap();
        let q = t.dequantize();
        for i in 0..q.len() {
            assert_eq!(
                q[i].to_bits(),
                q_expect[i].to_bits(),
                "{name}: q[{i}] rust {} vs python {} (x={})",
                q[i],
                q_expect[i],
                x[i]
            );
        }

        // ARE (nearest) — scalar, compared at f32 precision
        let are_expect = doc.req("are_nearest").unwrap().as_f64().unwrap();
        let mut ncfg = cfg;
        ncfg.rounding = mls_train::mls::Rounding::Nearest;
        let qn = mls_train::mls::quantizer::fake_quant(&x, &shape, &ncfg, &[]);
        // python computes mean|q-x|/mean|x| in f32; allow f32 round-off
        let are = stats::average_relative_error(&x, &qn);
        assert!(
            (are - are_expect).abs() < 1e-5 * (1.0 + are_expect.abs()),
            "{name}: ARE {are} vs {are_expect}"
        );
    }
}

#[test]
fn intra_group_mac_matches_python() {
    let doc = match load("mac_e2m4.json") {
        Some(d) => d,
        None => panic!("mac golden missing — run pytest tests/test_golden.py"),
    };
    let cfg = QuantConfig::from_json(doc.req("cfg").unwrap()).unwrap();
    let g = doc.req("g").unwrap().as_usize().unwrap();
    let l = doc.req("l").unwrap().as_usize().unwrap();
    let w = doc.req("w").unwrap().f32s().unwrap();
    let a = doc.req("a").unwrap().f32s().unwrap();
    let p_expect = doc.req("p").unwrap().i32s().unwrap();
    let scale_expect = doc.req("scale_log2").unwrap().as_i64().unwrap() as i32;

    // quantize with grouping=first, nearest (as the python golden does)
    let mut qcfg = cfg;
    qcfg.grouping = mls_train::mls::Grouping::First;
    qcfg.rounding = mls_train::mls::Rounding::Nearest;
    let shape = [g, l];
    let tw = quantize(&w, &shape, &qcfg, &[]);
    let ta = quantize(&a, &shape, &qcfg, &[]);

    // cross-check dequantized values against the python fields
    let wq_expect = doc.req("w_q").unwrap().f32s().unwrap();
    let wq = tw.dequantize();
    for i in 0..wq.len() {
        assert_eq!(wq[i].to_bits(), wq_expect[i].to_bits(), "w_q[{i}]");
    }

    for gi in 0..g {
        let mk = |t: &mls_train::mls::MlsTensor, i: usize| Element {
            sign: t.sign[i],
            exp_code: t.exp_code[i],
            man: t.man[i],
        };
        let we: Vec<Element> = (gi * l..(gi + 1) * l).map(|i| mk(&tw, i)).collect();
        let ae: Vec<Element> = (gi * l..(gi + 1) * l).map(|i| mk(&ta, i)).collect();
        let ps = intra_group_mac(&we, &ae, qcfg.element);
        assert_eq!(ps.p, p_expect[gi] as i64, "P[{gi}]");
        assert_eq!(ps.scale_log2, scale_expect);
    }
}
