//! In-Rust port of the Python bit-model geometry fuzz that validated the
//! planar (PR 2) and packed-GEMM (PR 3) kernels offline: a seeded sweep
//! of ~200 random conv geometries — shapes (ragged channel/pixel blocks
//! included), strides {1, 2}, pads {0, 1, 2}, element formats {e2m4,
//! e2m1, int4}, both rounding modes, worker counts {1, 2, 8} — asserting
//! the packed-GEMM, planar, and legacy kernels are BIT-identical on
//! output values and all five hardware-audit counters. A second sweep
//! (`convspec_backward_passes_fuzz`) drives the Alg. 1 weight-gradient /
//! input-gradient passes of the pass-generic `ConvSpec` engine over the
//! same geometry space: gradient shapes, cross-thread bit-identity,
//! equal executed MAC counts across passes, and agreement with an f32
//! reference backward conv. The authoring container has no Rust
//! toolchain, so this is the fuzz CI actually runs; a failing case
//! prints its full geometry for reproduction.
//!
//! Both sweeps additionally force every SIMD dispatch level the host
//! supports (scalar `off` plus any of SSE4.1 / AVX2 / NEON) through
//! `util::simd::set_level` and pin each one bit-identical to the legacy
//! kernel — values and all five audit counters. Forcing a level is a
//! benign global: every level is bit-identical by construction, so
//! concurrent tests observing a forced level still pass.

use mls_train::arith::conv::{
    conv2d_f32_dgrad, conv2d_f32_wgrad, lowbit_conv_legacy_threaded, lowbit_conv_planar_threaded,
    lowbit_conv_threaded, ConvOutput,
};
use mls_train::arith::spec::ConvSpec;
use mls_train::mls::quantizer::{quantize, QuantConfig, Rounding};
use mls_train::util::prop::grouped_tensor;
use mls_train::util::rng::Pcg32;
use mls_train::util::simd;

fn assert_convs_identical(a: &ConvOutput, b: &ConvOutput, tag: &str) {
    assert_eq!(a.shape, b.shape, "{tag}: shape");
    assert_eq!(a.z.len(), b.z.len(), "{tag}: z length");
    for (i, (x, y)) in a.z.iter().zip(&b.z).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: z[{i}] {x} vs {y}");
    }
    assert_eq!(a.peak_acc_bits, b.peak_acc_bits, "{tag}: peak_acc_bits");
    assert_eq!(a.mul_ops, b.mul_ops, "{tag}: mul_ops");
    assert_eq!(a.int_add_ops, b.int_add_ops, "{tag}: int_add_ops");
    assert_eq!(a.float_add_ops, b.float_add_ops, "{tag}: float_add_ops");
    assert_eq!(a.group_scale_ops, b.group_scale_ops, "{tag}: group_scale_ops");
}

#[test]
fn packed_planar_legacy_bit_identical_on_random_geometries() {
    let mut rng = Pcg32::seeded(0xF0_2253);
    let formats = [(2u32, 4u32), (2, 1), (0, 4)];
    let thread_choices = [1usize, 2, 8];
    let mut cases = 0u64;
    let mut attempts = 0u64;
    while cases < 200 {
        attempts += 1;
        assert!(attempts < 4000, "geometry sampler rejected too many draws");
        let co_n = 1 + rng.below(5) as usize;
        let ci_n = 1 + rng.below(4) as usize;
        let kh = 1 + rng.below(3) as usize;
        let kw = 1 + rng.below(3) as usize;
        let n_n = 1 + rng.below(2) as usize;
        let stride = 1 + rng.below(2) as usize;
        let pad = rng.below(3) as usize;
        let h = 1 + rng.below(8) as usize;
        let wi = 1 + rng.below(8) as usize;
        if h + 2 * pad < kh || wi + 2 * pad < kw {
            continue; // no output pixels — geometry invalid
        }
        let (e, m) = formats[rng.below(3) as usize];
        let stochastic = rng.below(2) == 1;
        let mut cfg = QuantConfig::new(e, m);
        cfg.rounding = if stochastic { Rounding::Stochastic } else { Rounding::Nearest };
        let wshape = [co_n, ci_n, kh, kw];
        let ashape = [n_n, ci_n, h, wi];
        let w = grouped_tensor(&mut rng, wshape);
        let a = grouped_tensor(&mut rng, ashape);
        let (rw, ra) = if stochastic {
            (rng.rounding_offsets(w.len()), rng.rounding_offsets(a.len()))
        } else {
            (Vec::new(), Vec::new())
        };
        let tw = quantize(&w, &wshape, &cfg, &rw);
        let ta = quantize(&a, &ashape, &cfg, &ra);
        let threads = thread_choices[(cases % 3) as usize];
        let tag = format!(
            "case {cases}: w{wshape:?} a{ashape:?} s{stride} p{pad} <{e},{m}> \
             {} @ {threads} threads",
            cfg.rounding.name()
        );
        let legacy = lowbit_conv_legacy_threaded(&tw, &ta, stride, pad, 1);
        let packed = lowbit_conv_threaded(&tw, &ta, stride, pad, threads);
        let planar = lowbit_conv_planar_threaded(&tw, &ta, stride, pad, threads);
        assert_convs_identical(&legacy, &packed, &format!("{tag} [packed]"));
        assert_convs_identical(&legacy, &planar, &format!("{tag} [planar]"));
        // every SIMD dispatch level the host supports must reproduce the
        // legacy kernel bit-for-bit
        for lvl in simd::Level::supported() {
            let prev = simd::set_level(lvl);
            let forced = lowbit_conv_threaded(&tw, &ta, stride, pad, threads);
            simd::set_level(prev);
            assert_convs_identical(&legacy, &forced, &format!("{tag} [simd {}]", lvl.name()));
        }
        cases += 1;
    }
}

/// The Alg. 1 backward passes on the same seeded geometry sweep: wgrad /
/// dgrad through the pass-generic `ConvSpec` engine must (a) produce the
/// gradient shapes, (b) be bit-identical (values AND all five audit
/// counters) across worker counts {1, 2, 8}, (c) execute exactly the
/// forward pass's in-bounds MAC count, and (d) match the f32 reference
/// backward convs of the dequantized operands to float-path tolerance.
#[test]
fn convspec_backward_passes_fuzz() {
    let mut rng = Pcg32::seeded(0xBAC_4A5D);
    let formats = [(2u32, 4u32), (2, 1), (0, 4)];
    let mut cases = 0u64;
    let mut attempts = 0u64;
    while cases < 80 {
        attempts += 1;
        assert!(attempts < 2000, "geometry sampler rejected too many draws");
        let co_n = 1 + rng.below(5) as usize;
        let ci_n = 1 + rng.below(4) as usize;
        let kh = 1 + rng.below(3) as usize;
        let kw = 1 + rng.below(3) as usize;
        let n_n = 1 + rng.below(2) as usize;
        let stride = 1 + rng.below(2) as usize;
        let pad = rng.below(3) as usize;
        let h = 1 + rng.below(8) as usize;
        let wi = 1 + rng.below(8) as usize;
        if h + 2 * pad < kh || wi + 2 * pad < kw {
            continue; // no output pixels — geometry invalid
        }
        let (e, m) = formats[rng.below(3) as usize];
        let stochastic = rng.below(2) == 1;
        let mut cfg = QuantConfig::new(e, m);
        cfg.rounding = if stochastic { Rounding::Stochastic } else { Rounding::Nearest };
        let spec = ConvSpec::new(stride, pad, kh, kw, h, wi);
        let (ho, wo) = (spec.out_h(), spec.out_w());
        let wshape = [co_n, ci_n, kh, kw];
        let ashape = [n_n, ci_n, h, wi];
        let eshape = [n_n, co_n, ho, wo];
        let w = grouped_tensor(&mut rng, wshape);
        let a = grouped_tensor(&mut rng, ashape);
        let ef = grouped_tensor(&mut rng, eshape);
        let (rw, ra, re) = if stochastic {
            (
                rng.rounding_offsets(w.len()),
                rng.rounding_offsets(a.len()),
                rng.rounding_offsets(ef.len()),
            )
        } else {
            (Vec::new(), Vec::new(), Vec::new())
        };
        let tw = quantize(&w, &wshape, &cfg, &rw);
        let ta = quantize(&a, &ashape, &cfg, &ra);
        let te = quantize(&ef, &eshape, &cfg, &re);
        let tag = format!(
            "case {cases}: w{wshape:?} a{ashape:?} s{stride} p{pad} <{e},{m}> {}",
            cfg.rounding.name()
        );

        let fwd = spec.forward(&tw, &ta, 1);
        let wg = spec.weight_grad(&te, &ta, 1);
        let dg = spec.input_grad(&te, &tw, 1);
        assert_eq!(wg.shape, wshape, "{tag}: dW shape");
        assert_eq!(dg.shape, ashape, "{tag}: dA shape");
        // Alg. 1: every pass executes the same number of low-bit MACs
        assert_eq!(fwd.mul_ops, wg.mul_ops, "{tag}: fwd vs wgrad mul_ops");
        assert_eq!(fwd.mul_ops, dg.mul_ops, "{tag}: fwd vs dgrad mul_ops");
        assert_eq!(fwd.int_add_ops, wg.int_add_ops, "{tag}: wgrad int_add_ops");
        assert_eq!(fwd.int_add_ops, dg.int_add_ops, "{tag}: dgrad int_add_ops");

        // bit-identity across worker counts
        for threads in [2usize, 8] {
            let wgt = spec.weight_grad(&te, &ta, threads);
            assert_convs_identical(&wg, &wgt, &format!("{tag} [wgrad t{threads}]"));
            let dgt = spec.input_grad(&te, &tw, threads);
            assert_convs_identical(&dg, &dgt, &format!("{tag} [dgrad t{threads}]"));
        }

        // bit-identity across SIMD dispatch levels, for all three passes
        for lvl in simd::Level::supported() {
            let prev = simd::set_level(lvl);
            let fwd_l = spec.forward(&tw, &ta, 1);
            let wg_l = spec.weight_grad(&te, &ta, 1);
            let dg_l = spec.input_grad(&te, &tw, 1);
            simd::set_level(prev);
            let ltag = format!("{tag} [simd {}]", lvl.name());
            assert_convs_identical(&fwd, &fwd_l, &format!("{ltag} fwd"));
            assert_convs_identical(&wg, &wg_l, &format!("{ltag} wgrad"));
            assert_convs_identical(&dg, &dg_l, &format!("{ltag} dgrad"));
        }

        // the f32 reference backward convs of the dequantized operands
        let (wg_ref, _) = conv2d_f32_wgrad(
            &te.dequantize(),
            eshape,
            &ta.dequantize(),
            ashape,
            stride,
            pad,
            kh,
            kw,
            1,
        );
        let wscale = wg_ref.iter().fold(0.0f32, |mx, v| mx.max(v.abs())).max(1e-6);
        for (i, (x, y)) in wg.z.iter().zip(&wg_ref).enumerate() {
            assert!((x - y).abs() / wscale < 2e-4, "{tag}: dW[{i}] {x} vs {y}");
        }
        let (dg_ref, _) = conv2d_f32_dgrad(
            &te.dequantize(),
            eshape,
            &tw.dequantize(),
            wshape,
            stride,
            pad,
            h,
            wi,
            1,
        );
        let dscale = dg_ref.iter().fold(0.0f32, |mx, v| mx.max(v.abs())).max(1e-6);
        for (i, (x, y)) in dg.z.iter().zip(&dg_ref).enumerate() {
            assert!((x - y).abs() / dscale < 2e-4, "{tag}: dA[{i}] {x} vs {y}");
        }
        cases += 1;
    }
}
