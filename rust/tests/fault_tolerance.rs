//! End-to-end tests of the crash-safe, self-healing training loop
//! (PR 8): step checkpoints with **bit-identical** resume (a run killed
//! by an injected crash at step k and resumed must match an
//! uninterrupted run bit for bit — metrics, evals, final state, audit
//! roll-up, test metrics), corrupt-checkpoint detection with fallback
//! to the rotated previous checkpoint, every `on_divergence` policy
//! (abort / rollback / halve_lr) driven by deterministic injected
//! faults, and lab trials that resume at step (not trial) granularity.

use std::path::{Path, PathBuf};

use mls_train::coordinator::lab::{self, Plan};
use mls_train::coordinator::{trainer, TrainConfig};
use mls_train::util::json::Json;

/// A fresh scratch dir per test case (tests run in parallel).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mls_fault_test_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Tiny quantized config: every per-step random source is a pure
/// function of (config, step), so checkpoint resume can be bit-exact.
fn cfg(model: &str, optimizer: &str, steps: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = model.to_string();
    c.cfg_name = "e2m4_gnc_eg8mg1_sr".to_string();
    c.steps = steps;
    c.batch = if model == "resnet_t" { 2 } else { 4 };
    c.eval_every = 2;
    c.eval_batches = 1;
    c.lr.base = 0.05;
    c.lr.milestones = vec![];
    c.optimizer = optimizer.to_string();
    c.data.noise = 1.0;
    c.data.label_noise = 0.0;
    c.out_dir = None;
    c
}

/// The full bit-identity contract between two runs of the same
/// trajectory: everything except wall-clock `step_ms`.
fn assert_bit_identical(a: &trainer::TrainResult, b: &trainer::TrainResult, tag: &str) {
    assert_eq!(a.metrics.steps.len(), b.metrics.steps.len(), "{tag}: step row count");
    for (x, y) in a.metrics.steps.iter().zip(&b.metrics.steps) {
        assert_eq!(x.step, y.step, "{tag}: step index");
        assert_eq!(x.lr.to_bits(), y.lr.to_bits(), "{tag}: lr at step {}", x.step);
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{tag}: loss at step {}", x.step);
        assert_eq!(x.acc.to_bits(), y.acc.to_bits(), "{tag}: acc at step {}", x.step);
    }
    assert_eq!(a.metrics.evals.len(), b.metrics.evals.len(), "{tag}: eval row count");
    for (x, y) in a.metrics.evals.iter().zip(&b.metrics.evals) {
        assert_eq!(x.step, y.step, "{tag}: eval step");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{tag}: eval loss at step {}", x.step);
        assert_eq!(x.acc.to_bits(), y.acc.to_bits(), "{tag}: eval acc at step {}", x.step);
    }
    assert_eq!(a.final_state.len(), b.final_state.len(), "{tag}: state length");
    let diff = a
        .final_state
        .iter()
        .zip(&b.final_state)
        .filter(|(x, y)| x.to_bits() != y.to_bits())
        .count();
    assert_eq!(diff, 0, "{tag}: {diff} parameter(s) differ bitwise");
    assert_eq!(a.audit_totals, b.audit_totals, "{tag}: audit roll-up");
    assert_eq!(a.audit_steps, b.audit_steps, "{tag}: audit step count");
    assert_eq!(a.diverged, b.diverged, "{tag}: diverged flag");
    assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "{tag}: test loss");
    assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits(), "{tag}: test acc");
}

fn audit_lines(dir: &Path, tag: &str) -> Vec<Json> {
    let text = std::fs::read_to_string(dir.join(format!("{tag}.audit.jsonl"))).unwrap();
    text.lines().map(|l| Json::parse(l).unwrap()).collect()
}

// ---------------------------------------------------------------------------
// Tentpole: interrupted-at-arbitrary-step resume is bit-identical, for
// both optimizers on both graph models, crashing at the first step, a
// middle step, and the last step before the end.
// ---------------------------------------------------------------------------

#[test]
fn crash_and_resume_is_bit_identical_across_models_optimizers_and_steps() {
    const STEPS: u64 = 6;
    for model in ["cnn_t", "resnet_t"] {
        for optimizer in ["sgd", "momentum"] {
            // one uninterrupted baseline per (model, optimizer), reused
            // for every crash step
            let base_dir = scratch(&format!("base_{model}_{optimizer}"));
            let mut base = cfg(model, optimizer, STEPS);
            base.checkpoint_every = 1;
            base.out_dir = Some(base_dir.to_string_lossy().into_owned());
            let clean = trainer::train_native(&base).unwrap();
            assert!(!clean.diverged);
            assert_eq!(clean.resumed_from, None);
            assert_eq!(clean.steps_executed, STEPS);

            for crash_at in [1, STEPS / 2, STEPS - 1] {
                let tag = format!("{model}/{optimizer} crash@{crash_at}");
                let dir = scratch(&format!("resume_{model}_{optimizer}_{crash_at}"));
                let mut c = cfg(model, optimizer, STEPS);
                c.checkpoint_every = 1;
                c.out_dir = Some(dir.to_string_lossy().into_owned());
                c.fault = Some(format!("crash_after_ckpt@step{crash_at}"));

                let err = trainer::train_native(&c).expect_err("the injected crash must kill");
                assert!(
                    format!("{err:#}").contains("MLS_FAULT crash injected"),
                    "{tag}: unexpected error {err:#}"
                );
                let ckpt = dir.join(format!("{}_e2m4_gnc_eg8mg1_sr_s{}.ckpt.bin", model, 0));
                assert!(ckpt.is_file(), "{tag}: crash left no checkpoint");

                // resume: same config, same injected fault (one-shot and
                // behind the resume point — it must not re-fire)
                let resumed = trainer::train_native(&c).unwrap();
                assert_eq!(resumed.resumed_from, Some(crash_at + 1), "{tag}");
                assert_eq!(resumed.steps_executed, STEPS - (crash_at + 1), "{tag}");
                assert_bit_identical(&clean, &resumed, &tag);
            }
        }
    }
}

#[test]
fn corrupt_checkpoint_is_detected_and_falls_back_to_previous_good() {
    let dir = scratch("corrupt_fallback");
    let mut c = cfg("cnn_t", "momentum", 6);
    c.checkpoint_every = 2; // checkpoints with next_step 2, 4, 6
    c.out_dir = Some(dir.to_string_lossy().into_owned());

    let clean_dir = scratch("corrupt_fallback_clean");
    let mut clean_cfg = c.clone();
    clean_cfg.out_dir = Some(clean_dir.to_string_lossy().into_owned());
    let clean = trainer::train_native(&clean_cfg).unwrap();

    // the run completes but its LATEST checkpoint (next_step 6) is
    // corrupted in place right after the save
    c.fault = Some("corrupt_ckpt@step5".to_string());
    trainer::train_native(&c).unwrap();
    let tag = "cnn_t_e2m4_gnc_eg8mg1_sr_s0";
    assert!(dir.join(format!("{tag}.ckpt.bin")).is_file());
    assert!(dir.join(format!("{tag}.ckpt.prev.bin")).is_file(), "rotation must keep prev");

    // a re-run must reject the corrupt latest (checksum), fall back to
    // the rotated previous checkpoint (next_step 4), and still land
    // bit-identical
    let resumed = trainer::train_native(&c).unwrap();
    assert_eq!(
        resumed.resumed_from,
        Some(4),
        "corrupt latest must fall back to the previous checkpoint"
    );
    assert_eq!(resumed.steps_executed, 2);
    assert_bit_identical(&clean, &resumed, "corrupt fallback");

    // the manifest sidecar documents the (re-written) latest checkpoint
    let manifest =
        Json::parse(&std::fs::read_to_string(dir.join(format!("{tag}.ckpt.json"))).unwrap())
            .unwrap();
    assert_eq!(manifest.req("format").unwrap().as_str(), Some("MLSCKPT1"));
    assert_eq!(manifest.req("next_step").unwrap().as_usize(), Some(6));
    assert_eq!(manifest.req("optimizer").unwrap().as_str(), Some("momentum"));
    let checksum = manifest.req("checksum_fnv1a").unwrap().as_str().unwrap();
    assert_eq!(checksum.len(), 16, "fnv64 hex: {checksum:?}");
}

// ---------------------------------------------------------------------------
// Health policies: a NaN gradient at a deterministic step exercises
// abort, rollback, and halve_lr.
// ---------------------------------------------------------------------------

#[test]
fn nan_gradient_with_abort_policy_stops_and_records_the_verdict() {
    let dir = scratch("nan_abort");
    let mut c = cfg("cnn_t", "sgd", 5);
    c.out_dir = Some(dir.to_string_lossy().into_owned());
    c.fault = Some("nan_grad@step2".to_string());
    assert_eq!(c.on_divergence, "abort", "abort must be the default policy");

    let r = trainer::train_native(&c).unwrap();
    assert!(r.diverged, "a health abort is a diverged run");
    assert_eq!(r.metrics.steps.len(), 3, "steps 0..=2 recorded, then the abort");
    assert_eq!(r.rollbacks, 0);
    assert_eq!(r.steps_executed, 3);
    assert!(r.test_loss.is_nan(), "no test eval after an abort");

    // the audit stream carries the health record explaining the stop:
    // 3 train_step records then 1 health record
    let lines = audit_lines(&dir, "cnn_t_e2m4_gnc_eg8mg1_sr_s0");
    assert_eq!(lines.len(), 4, "3 train_step + 1 health");
    let health = lines.last().unwrap();
    assert_eq!(health.req("audit").unwrap().as_str(), Some("health"));
    assert_eq!(health.req("verdict").unwrap().as_str(), Some("nan_grad"));
    assert_eq!(health.req("action").unwrap().as_str(), Some("abort"));
    assert_eq!(health.req("step").unwrap().as_usize(), Some(2));
    assert!(health.req("grad_nonfinite").unwrap().as_usize().unwrap() > 0);
}

#[test]
fn nan_gradient_with_rollback_policy_recovers_bit_identically() {
    // no checkpoints at all: the anchor is the run start, so the
    // rollback replays from step 0 — and must still converge to the
    // exact same trajectory as a run that never faulted
    let clean = trainer::train_native(&{
        let mut c = cfg("cnn_t", "momentum", 5);
        c.on_divergence = "rollback".to_string();
        c
    })
    .unwrap();

    let mut c = cfg("cnn_t", "momentum", 5);
    c.on_divergence = "rollback".to_string();
    c.fault = Some("nan_grad@step2".to_string());
    let r = trainer::train_native(&c).unwrap();
    assert!(!r.diverged, "rollback must recover");
    assert_eq!(r.rollbacks, 1);
    // steps 0..=2 executed, fault fires, replay of 0..5: 3 + 5
    assert_eq!(r.steps_executed, 8);
    assert_bit_identical(&clean, &r, "rollback from run start");
}

#[test]
fn nan_gradient_rollback_restores_the_last_checkpoint_not_step_zero() {
    let dir = scratch("nan_rollback_ckpt");
    let mut c = cfg("cnn_t", "sgd", 6);
    c.on_divergence = "rollback".to_string();
    c.checkpoint_every = 2; // anchor at next_step 2 when the fault fires
    c.out_dir = Some(dir.to_string_lossy().into_owned());

    let clean_dir = scratch("nan_rollback_ckpt_clean");
    let mut clean_cfg = c.clone();
    clean_cfg.out_dir = Some(clean_dir.to_string_lossy().into_owned());
    let clean = trainer::train_native(&clean_cfg).unwrap();

    c.fault = Some("nan_grad@step3".to_string());
    let r = trainer::train_native(&c).unwrap();
    assert!(!r.diverged);
    assert_eq!(r.rollbacks, 1);
    // 0..=3 executed (4), rollback to 2, replay 2..6 (4)
    assert_eq!(r.steps_executed, 8);
    assert_bit_identical(&clean, &r, "rollback to checkpoint");

    // the audit stream was truncated back to the anchor before the
    // replay: train_step records stay strictly monotonic (the invariant
    // `validate_bench.py --monotonic-steps` enforces in CI), and the
    // rollback health record names its target
    let lines = audit_lines(&dir, "cnn_t_e2m4_gnc_eg8mg1_sr_s0");
    let mut last_step = None;
    for l in &lines {
        if l.req("audit").unwrap().as_str() == Some("train_step") {
            let s = l.req("step").unwrap().as_usize().unwrap();
            assert!(!last_step.is_some_and(|p| s <= p), "non-monotonic step {s} in {lines:?}");
            last_step = Some(s);
        }
    }
    assert_eq!(last_step, Some(5), "the replayed stream covers every step");
    let health: Vec<&Json> =
        lines.iter().filter(|l| l.req("audit").unwrap().as_str() == Some("health")).collect();
    assert_eq!(health.len(), 1);
    assert_eq!(health[0].req("action").unwrap().as_str(), Some("rollback"));
    assert_eq!(health[0].req("rollback_to").unwrap().as_usize(), Some(2));
}

#[test]
fn nan_gradient_with_halve_lr_policy_compounds_into_the_replay() {
    let dir = scratch("nan_halve_lr");
    let mut c = cfg("cnn_t", "sgd", 5);
    c.on_divergence = "halve_lr".to_string();
    c.checkpoint_every = 1;
    c.out_dir = Some(dir.to_string_lossy().into_owned());
    c.fault = Some("nan_grad@step2".to_string());

    let r = trainer::train_native(&c).unwrap();
    assert!(!r.diverged, "halve_lr must recover");
    assert_eq!(r.rollbacks, 1);
    let base = c.lr.base;
    assert_eq!(
        r.metrics.steps[1].lr.to_bits(),
        base.to_bits(),
        "pre-fault steps keep the configured lr"
    );
    for row in &r.metrics.steps[2..] {
        assert_eq!(
            row.lr.to_bits(),
            (base * 0.5).to_bits(),
            "step {}: replay and every later step run at half lr",
            row.step
        );
    }

    // the halved lr changes the trajectory — this is recovery, not replay
    let clean = trainer::train_native(&{
        let mut c2 = cfg("cnn_t", "sgd", 5);
        c2.on_divergence = "halve_lr".to_string();
        c2
    })
    .unwrap();
    assert_ne!(
        clean.final_state, r.final_state,
        "halve_lr must actually perturb the trajectory"
    );
}

#[test]
fn scale_overflow_verdict_triggers_and_rollback_recovers() {
    let clean = trainer::train_native(&{
        let mut c = cfg("cnn_t", "sgd", 4);
        c.on_divergence = "rollback".to_string();
        c
    })
    .unwrap();

    let mut c = cfg("cnn_t", "sgd", 4);
    c.on_divergence = "rollback".to_string();
    c.fault = Some("scale_overflow@step1".to_string());
    let r = trainer::train_native(&c).unwrap();
    assert!(!r.diverged);
    assert_eq!(r.rollbacks, 1);
    assert_bit_identical(&clean, &r, "scale_overflow rollback");

    // under abort, the same fault is terminal with its own verdict name
    let mut ca = cfg("cnn_t", "sgd", 4);
    ca.on_divergence = "abort".to_string();
    let dir = scratch("scale_abort");
    ca.out_dir = Some(dir.to_string_lossy().into_owned());
    ca.fault = Some("scale_overflow@step1".to_string());
    let ra = trainer::train_native(&ca).unwrap();
    assert!(ra.diverged);
    let lines = audit_lines(&dir, "cnn_t_e2m4_gnc_eg8mg1_sr_s0");
    let health = lines.last().unwrap();
    assert_eq!(health.req("verdict").unwrap().as_str(), Some("scale_overflow"));
}

// ---------------------------------------------------------------------------
// Lab integration: a trial killed mid-run resumes at STEP granularity.
// ---------------------------------------------------------------------------

fn fault_plan() -> Plan {
    let v = Json::parse(
        r#"{
            "name": "faultlab",
            "base": {"steps": 6, "batch": 4, "eval_every": 2, "eval_batches": 1,
                     "checkpoint_every": 1, "noise": 1.0, "label_noise": 0.0},
            "grid": {"cfg": ["e2m4_gnc_eg8mg1_sr"], "model": ["cnn_t"]}
        }"#,
    )
    .unwrap();
    Plan::from_json(&v).unwrap()
}

/// Parse a trial_output.json and drop the wall-clock `timing` object —
/// everything left must be a pure function of the resolved config.
fn parsed_minus_timing(path: &Path) -> Json {
    let mut v = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    if let Json::Obj(m) = &mut v {
        assert!(m.remove("timing").is_some(), "{}: no timing object", path.display());
    }
    v
}

#[test]
fn lab_trial_crash_resumes_at_step_granularity() {
    let plan = fault_plan();
    let trial_id = "t000__cnn_t__e2m4_gnc_eg8mg1_sr__s0";

    // uninterrupted baseline in its own run root
    let clean_out = scratch("lab_clean");
    let r = lab::run_plan(&plan, &clean_out, false).unwrap();
    assert_eq!(r.ran(), 1);
    let clean_output = clean_out.join("faultlab").join(trial_id).join("trial_output.json");
    let clean = parsed_minus_timing(&clean_output);

    // crash the trial mid-run: the plan invocation fails, leaving the
    // checkpoint but no trial_output.json
    let out = scratch("lab_crash");
    let err = lab::run_plan_opts(&plan, &out, false, Some("crash_after_ckpt@step3"))
        .expect_err("the injected crash must fail the plan run");
    assert!(format!("{err:#}").contains("MLS_FAULT crash injected"), "{err:#}");
    let trial_dir = out.join("faultlab").join(trial_id);
    let tag = "cnn_t_e2m4_gnc_eg8mg1_sr_s0";
    assert!(trial_dir.join(format!("{tag}.ckpt.bin")).is_file());
    assert!(!trial_dir.join("trial_output.json").exists());

    // resume WITHOUT the fault: the trial re-runs, picks up the
    // checkpoint, and executes only the remaining steps
    let r2 = lab::run_plan(&plan, &out, false).unwrap();
    assert_eq!(r2.ran(), 1);
    let output_path = trial_dir.join("trial_output.json");
    let v = Json::parse(&std::fs::read_to_string(&output_path).unwrap()).unwrap();
    let timing = v.req("timing").unwrap();
    assert_eq!(timing.req("resumed").unwrap().as_usize(), Some(4), "resumed at step 4");
    assert_eq!(timing.req("steps_executed").unwrap().as_usize(), Some(2), "only steps 4..6 ran");

    // ...and the output is bit-identical to the uninterrupted baseline
    assert_eq!(
        parsed_minus_timing(&output_path).to_string_pretty(),
        clean.to_string_pretty(),
        "resumed trial must reproduce the clean output bit-for-bit"
    );

    // the resumed audit stream has no duplicate / out-of-order steps
    let lines = audit_lines(&trial_dir, tag);
    let steps: Vec<usize> = lines
        .iter()
        .filter(|l| l.req("audit").unwrap().as_str() == Some("train_step"))
        .map(|l| l.req("step").unwrap().as_usize().unwrap())
        .collect();
    assert_eq!(steps, vec![0, 1, 2, 3, 4, 5], "strictly monotonic, no duplicates");

    // a third invocation skips the (now valid) trial entirely
    let r3 = lab::run_plan(&plan, &out, false).unwrap();
    assert_eq!(r3.ran(), 0);
    assert_eq!(r3.skipped(), 1);

    // --force starts over: checkpoints are deleted first, so the forced
    // run executes every step instead of resuming
    let r4 = lab::run_plan(&plan, &out, true).unwrap();
    assert_eq!(r4.ran(), 1);
    let v = Json::parse(&std::fs::read_to_string(&output_path).unwrap()).unwrap();
    let timing = v.req("timing").unwrap();
    assert!(timing.get("resumed").is_none(), "forced run must not resume");
    assert_eq!(timing.req("steps_executed").unwrap().as_usize(), Some(6));
}
