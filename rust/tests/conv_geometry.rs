//! Conv geometry edge cases the original suite skipped: stride 2, pad 0
//! and pad 2, non-square inputs and kernels (`kh != kw`, `h != w`), and
//! 1x1 kernels — asserting the packed-GEMM default kernel AND the planar
//! kernel are bit-identical to the legacy reference (output values AND
//! all five hardware-audit counters) across `QuantConfig`s {e2m1, e2m4,
//! int4} and worker counts {1, 2, 8}, and that the counters match an
//! independent clipped-window count of the geometry.

use mls_train::arith::conv::{
    conv2d_f32, lowbit_conv_legacy_threaded, lowbit_conv_planar_threaded, lowbit_conv_threaded,
    ConvOutput,
};
use mls_train::mls::quantizer::{quantize, QuantConfig, Rounding};
use mls_train::mls::MlsTensor;
use mls_train::util::prop::grouped_tensor;
use mls_train::util::rng::Pcg32;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// (wshape [Co,Ci,Kh,Kw], ashape [N,Ci,H,W], stride, pad)
const GEOMETRIES: [([usize; 4], [usize; 4], usize, usize); 11] = [
    // square baseline at the pads the old suite skipped
    ([4, 3, 3, 3], [2, 3, 6, 6], 1, 0),
    ([4, 3, 3, 3], [2, 3, 6, 6], 1, 2),
    // stride 2 with pad 0 / 1 / 2
    ([4, 3, 3, 3], [2, 3, 6, 6], 2, 0),
    ([4, 3, 3, 3], [2, 3, 7, 7], 2, 1),
    ([4, 3, 3, 3], [2, 3, 7, 7], 2, 2),
    // non-square kernels and inputs (kh != kw, h != w)
    ([3, 2, 3, 2], [2, 2, 7, 5], 1, 1),
    ([3, 2, 2, 3], [1, 2, 5, 8], 2, 1),
    // 1x1 kernels: pad 0 (all interior) and pad 1 (all-halo border ring)
    ([4, 3, 1, 1], [2, 3, 5, 5], 1, 0),
    ([4, 3, 1, 1], [2, 3, 5, 5], 1, 1),
    ([4, 3, 1, 1], [2, 3, 6, 4], 2, 0),
    // kernel covers the whole input; pad larger than the kernel overhang
    ([2, 3, 3, 3], [1, 3, 3, 3], 1, 2),
];

fn assert_convs_identical(a: &ConvOutput, b: &ConvOutput, tag: &str) {
    assert_eq!(a.shape, b.shape, "{tag}: shape");
    assert_eq!(a.z.len(), b.z.len(), "{tag}: z length");
    for (i, (x, y)) in a.z.iter().zip(&b.z).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: z[{i}] {x} vs {y}");
    }
    assert_eq!(a.peak_acc_bits, b.peak_acc_bits, "{tag}: peak_acc_bits");
    assert_eq!(a.mul_ops, b.mul_ops, "{tag}: mul_ops");
    assert_eq!(a.int_add_ops, b.int_add_ops, "{tag}: int_add_ops");
    assert_eq!(a.float_add_ops, b.float_add_ops, "{tag}: float_add_ops");
    assert_eq!(a.group_scale_ops, b.group_scale_ops, "{tag}: group_scale_ops");
}

fn quant_cfgs() -> [QuantConfig; 3] {
    let mk = |e, m| QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(e, m) };
    [mk(2, 4), mk(2, 1), mk(0, 4)]
}

fn quantize_pair(
    cfg: &QuantConfig,
    wshape: [usize; 4],
    ashape: [usize; 4],
    seed: u64,
) -> (MlsTensor, MlsTensor) {
    let mut rng = Pcg32::seeded(seed);
    let w = grouped_tensor(&mut rng, wshape);
    let a = grouped_tensor(&mut rng, ashape);
    (quantize(&w, &wshape, cfg, &[]), quantize(&a, &ashape, cfg, &[]))
}

/// The number of in-bounds window taps summed over every output pixel —
/// an independent reference for `mul_ops` / `int_add_ops` on clipped
/// geometries (the counters count clipped windows, not kh*kw*pixels).
fn clipped_window_taps(
    wshape: [usize; 4],
    ashape: [usize; 4],
    stride: usize,
    pad: usize,
) -> u64 {
    let [co_n, ci_n, kh, kw] = wshape;
    let [n_n, _, h, wi] = ashape;
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wi + 2 * pad - kw) / stride + 1;
    let mut taps = 0u64;
    for oy in 0..ho {
        for ox in 0..wo {
            for i in 0..kh {
                for j in 0..kw {
                    let iy = (oy * stride + i) as isize - pad as isize;
                    let ix = (ox * stride + j) as isize - pad as isize;
                    if iy >= 0 && ix >= 0 && iy < h as isize && ix < wi as isize {
                        taps += 1;
                    }
                }
            }
        }
    }
    taps * (n_n * co_n * ci_n) as u64
}

#[test]
fn packed_and_planar_match_legacy_across_geometries_and_formats() {
    for (gi, &(wshape, ashape, stride, pad)) in GEOMETRIES.iter().enumerate() {
        for cfg in quant_cfgs() {
            let (tw, ta) = quantize_pair(&cfg, wshape, ashape, 200 + gi as u64);
            let legacy = lowbit_conv_legacy_threaded(&tw, &ta, stride, pad, 1);
            for threads in THREAD_COUNTS {
                let packed = lowbit_conv_threaded(&tw, &ta, stride, pad, threads);
                let tag = format!(
                    "{} geom#{gi} w{wshape:?} a{ashape:?} s{stride} p{pad} @ {threads} threads",
                    cfg.name()
                );
                assert_convs_identical(&legacy, &packed, &format!("{tag} (packed)"));
                let planar = lowbit_conv_planar_threaded(&tw, &ta, stride, pad, threads);
                assert_convs_identical(&legacy, &planar, &format!("{tag} (planar)"));
                // the legacy kernel is itself thread-count independent
                let legacy_t = lowbit_conv_legacy_threaded(&tw, &ta, stride, pad, threads);
                assert_convs_identical(&legacy, &legacy_t, &format!("{tag} (legacy)"));
            }
        }
    }
}

#[test]
fn counters_match_independent_clipped_window_count() {
    let cfg = quant_cfgs()[0];
    for (gi, &(wshape, ashape, stride, pad)) in GEOMETRIES.iter().enumerate() {
        let (tw, ta) = quantize_pair(&cfg, wshape, ashape, 300 + gi as u64);
        let out = lowbit_conv_threaded(&tw, &ta, stride, pad, 2);
        let taps = clipped_window_taps(wshape, ashape, stride, pad);
        let [n_n, co_n, ho, wo] = out.shape;
        let ci_n = wshape[1];
        let pixels = (n_n * co_n * ho * wo) as u64;
        let tag = format!("geom#{gi} s{stride} p{pad}");
        assert_eq!(out.mul_ops, taps, "{tag}: mul_ops");
        assert_eq!(out.int_add_ops, taps, "{tag}: int_add_ops");
        assert_eq!(out.group_scale_ops, pixels * ci_n as u64, "{tag}: group_scale_ops");
        assert_eq!(out.float_add_ops, pixels * (ci_n as u64 - 1), "{tag}: float_add_ops");
    }
}

#[test]
fn planar_tracks_float_path_across_geometries() {
    let cfg = quant_cfgs()[0]; // e2m4 nearest
    for (gi, &(wshape, ashape, stride, pad)) in GEOMETRIES.iter().enumerate() {
        let (tw, ta) = quantize_pair(&cfg, wshape, ashape, 400 + gi as u64);
        let out = lowbit_conv_threaded(&tw, &ta, stride, pad, 2);
        let (zf, zshape) =
            conv2d_f32(&tw.dequantize(), wshape, &ta.dequantize(), ashape, stride, pad);
        assert_eq!(out.shape, zshape, "geom#{gi}");
        let scale = zf.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-9);
        for (i, (a, b)) in out.z.iter().zip(&zf).enumerate() {
            assert!(
                (a - b).abs() / scale < 1e-5,
                "geom#{gi} idx {i}: int {a} vs float {b}"
            );
        }
    }
}

#[test]
fn all_zero_operands_pin_peak_acc_bits_to_one() {
    // an all-zero tensor quantizes to s_t = 0 with every element sign 0;
    // the conv runs every window but no accumulator ever leaves zero, so
    // the audit reports the documented 1-bit floor (sign bit only) — on
    // both kernels, at every thread count
    let cfg = quant_cfgs()[0];
    let wshape = [2usize, 3, 3, 3];
    let ashape = [1usize, 3, 5, 5];
    let zeros_w = vec![0.0f32; wshape.iter().product()];
    let zeros_a = vec![0.0f32; ashape.iter().product()];
    let tw = quantize(&zeros_w, &wshape, &cfg, &[]);
    let ta = quantize(&zeros_a, &ashape, &cfg, &[]);
    let legacy = lowbit_conv_legacy_threaded(&tw, &ta, 1, 1, 1);
    assert_eq!(legacy.peak_acc_bits, 1);
    assert!(legacy.z.iter().all(|&v| v == 0.0));
    for threads in THREAD_COUNTS {
        let packed = lowbit_conv_threaded(&tw, &ta, 1, 1, threads);
        assert_convs_identical(&legacy, &packed, &format!("all-zero packed @ {threads} threads"));
        let planar = lowbit_conv_planar_threaded(&tw, &ta, 1, 1, threads);
        assert_convs_identical(&legacy, &planar, &format!("all-zero planar @ {threads} threads"));
    }
    // the windows still ran: op counters are geometry-driven, not
    // value-driven
    assert_eq!(legacy.mul_ops, clipped_window_taps(wshape, ashape, 1, 1));
}
