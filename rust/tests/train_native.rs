//! End-to-end tests of the NATIVE training backend: `coordinator::train`
//! with `backend=native` must complete multi-step Alg. 1 low-bit training
//! runs on synthetic CIFAR with finite, decreasing loss — no PJRT, no
//! artifacts, no Python — and stay deterministic in the seed.

use mls_train::coordinator::{trainer, Backend, TrainConfig};

fn native_config(cfg_name: &str, steps: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    assert_eq!(c.backend, Backend::Native, "native must be the default backend");
    c.model = "cnn_t".to_string();
    c.cfg_name = cfg_name.to_string();
    c.steps = steps;
    c.batch = 16;
    c.eval_every = 0;
    c.eval_batches = 2;
    c.lr.base = 0.05;
    c.lr.milestones = vec![];
    c.data.noise = 1.0;
    c.data.label_noise = 0.0;
    c.out_dir = None;
    c
}

fn assert_loss_decreases(r: &trainer::TrainResult, tag: &str) {
    assert!(!r.diverged, "{tag}: diverged");
    for row in &r.metrics.steps {
        assert!(row.loss.is_finite(), "{tag}: loss {} at step {}", row.loss, row.step);
    }
    let first: f64 = r.metrics.steps[..3].iter().map(|s| s.loss as f64).sum::<f64>() / 3.0;
    let last = r.metrics.final_loss(3);
    assert!(last < first, "{tag}: loss did not decrease ({first:.4} -> {last:.4})");
}

#[test]
fn native_fp32_training_reduces_loss() {
    let c = native_config("fp32", 18);
    let r = trainer::train_native(&c).unwrap();
    assert_loss_decreases(&r, "fp32");
    assert!(r.test_acc >= 0.0 && r.test_acc <= 1.0);
    assert_eq!(r.metrics.steps.len(), 18);
}

#[test]
fn native_quantized_training_reduces_loss_and_differs_from_fp32() {
    let cq = native_config("e2m4_gnc_eg8mg1_sr", 15);
    let rq = trainer::train_native(&cq).unwrap();
    assert_loss_decreases(&rq, "e2m4");

    let cf = native_config("fp32", 15);
    let rf = trainer::train_native(&cf).unwrap();
    assert_eq!(rq.final_state.len(), rf.final_state.len());
    let diff = rq.final_state.iter().zip(&rf.final_state).filter(|(a, b)| a != b).count();
    assert!(
        diff > rq.final_state.len() / 10,
        "quantized training must actually perturb the trajectory ({diff} differing params)"
    );
}

#[test]
fn native_runs_are_deterministic_in_the_seed() {
    let c = native_config("e2m4_gnc_eg8mg1_sr", 4);
    let r1 = trainer::train_native(&c).unwrap();
    let r2 = trainer::train_native(&c).unwrap();
    for (a, b) in r1.metrics.steps.iter().zip(&r2.metrics.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {} loss", a.step);
    }
    assert_eq!(r1.final_state, r2.final_state);

    let mut c3 = c.clone();
    c3.seed = 1;
    let r3 = trainer::train_native(&c3).unwrap();
    assert_ne!(r1.final_state, r3.final_state, "the run seed must matter");
}

#[test]
fn native_train_dispatches_through_coordinator_train() {
    // `train()` with the default (native) backend must ignore the engine
    // entirely — an empty manifest-only stub engine works
    let manifest = mls_train::runtime::Manifest {
        dir: std::path::PathBuf::from("."),
        batch: 16,
        img_shape: vec![3, 16, 16],
        num_classes: 10,
        models: Default::default(),
        artifacts: Vec::new(),
    };
    let mut engine = mls_train::runtime::Engine::new(manifest).unwrap();
    let c = native_config("e2m1_gnc_eg8mg1_sr", 3);
    let r = trainer::train(&mut engine, &c).unwrap();
    assert_eq!(r.metrics.steps.len(), 3);
    assert!(r.metrics.steps.iter().all(|s| s.loss.is_finite()));
}

#[test]
fn unsupported_native_model_errors_clearly() {
    let mut c = native_config("fp32", 1);
    c.model = "resnet_t".to_string();
    let err = trainer::train_native(&c).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("native"), "{msg}");
    assert!(msg.contains("pjrt"), "{msg}");
}
