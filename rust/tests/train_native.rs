//! End-to-end tests of the NATIVE training backend: `coordinator::train`
//! with `backend=native` must complete multi-step Alg. 1 low-bit training
//! runs on synthetic CIFAR with finite, decreasing loss — no PJRT, no
//! artifacts, no Python — stay deterministic in the seed, and (since
//! PR 5) cover the residual module-graph model `resnet_t`: gradient
//! checks through the skip-add fan-in (identity AND 1x1-projection
//! shortcuts), full-step bit-identity across {1, 2, 8} worker threads,
//! the per-layer audit stream, the pluggable optimizer, and the up-front
//! config validation errors.

use mls_train::coordinator::{trainer, Backend, TrainConfig};
use mls_train::data::{streams, DatasetConfig, SynthCifar};
use mls_train::mls::quantizer::QuantConfig;
use mls_train::nn::train::{native_model, Op};
use mls_train::util::json::Json;

fn native_config(cfg_name: &str, steps: u64) -> TrainConfig {
    let mut c = TrainConfig::default();
    assert_eq!(c.backend, Backend::Native, "native must be the default backend");
    c.model = "cnn_t".to_string();
    c.cfg_name = cfg_name.to_string();
    c.steps = steps;
    c.batch = 16;
    c.eval_every = 0;
    c.eval_batches = 2;
    c.lr.base = 0.05;
    c.lr.milestones = vec![];
    c.data.noise = 1.0;
    c.data.label_noise = 0.0;
    c.out_dir = None;
    c
}

fn assert_loss_decreases(r: &trainer::TrainResult, tag: &str) {
    assert!(!r.diverged, "{tag}: diverged");
    for row in &r.metrics.steps {
        assert!(row.loss.is_finite(), "{tag}: loss {} at step {}", row.loss, row.step);
    }
    let first: f64 = r.metrics.steps[..3].iter().map(|s| s.loss as f64).sum::<f64>() / 3.0;
    let last = r.metrics.final_loss(3);
    assert!(last < first, "{tag}: loss did not decrease ({first:.4} -> {last:.4})");
}

fn batch(n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let ds = SynthCifar::new(DatasetConfig {
        noise: 1.0,
        label_noise: 0.0,
        seed,
        ..Default::default()
    });
    ds.batch(n, streams::TRAIN, 0)
}

#[test]
fn native_fp32_training_reduces_loss() {
    let c = native_config("fp32", 18);
    let r = trainer::train_native(&c).unwrap();
    assert_loss_decreases(&r, "fp32");
    assert!(r.test_acc >= 0.0 && r.test_acc <= 1.0);
    assert_eq!(r.metrics.steps.len(), 18);
}

#[test]
fn native_quantized_training_reduces_loss_and_differs_from_fp32() {
    let cq = native_config("e2m4_gnc_eg8mg1_sr", 15);
    let rq = trainer::train_native(&cq).unwrap();
    assert_loss_decreases(&rq, "e2m4");

    let cf = native_config("fp32", 15);
    let rf = trainer::train_native(&cf).unwrap();
    assert_eq!(rq.final_state.len(), rf.final_state.len());
    let diff = rq.final_state.iter().zip(&rf.final_state).filter(|(a, b)| a != b).count();
    assert!(
        diff > rq.final_state.len() / 10,
        "quantized training must actually perturb the trajectory ({diff} differing params)"
    );
}

#[test]
fn native_runs_are_deterministic_in_the_seed() {
    let c = native_config("e2m4_gnc_eg8mg1_sr", 4);
    let r1 = trainer::train_native(&c).unwrap();
    let r2 = trainer::train_native(&c).unwrap();
    for (a, b) in r1.metrics.steps.iter().zip(&r2.metrics.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {} loss", a.step);
    }
    assert_eq!(r1.final_state, r2.final_state);

    let mut c3 = c.clone();
    c3.seed = 1;
    let r3 = trainer::train_native(&c3).unwrap();
    assert_ne!(r1.final_state, r3.final_state, "the run seed must matter");
}

#[test]
fn native_train_dispatches_through_coordinator_train() {
    // `train()` with the default (native) backend must ignore the engine
    // entirely — an empty manifest-only stub engine works
    let manifest = mls_train::runtime::Manifest {
        dir: std::path::PathBuf::from("."),
        batch: 16,
        img_shape: vec![3, 16, 16],
        num_classes: 10,
        models: Default::default(),
        artifacts: Vec::new(),
    };
    let mut engine = mls_train::runtime::Engine::new(manifest).unwrap();
    let c = native_config("e2m1_gnc_eg8mg1_sr", 3);
    let r = trainer::train(&mut engine, &c).unwrap();
    assert_eq!(r.metrics.steps.len(), 3);
    assert!(r.metrics.steps.iter().all(|s| s.loss.is_finite()));
}

// ---------------------------------------------------------------------------
// resnet_t: the residual module-graph model
// ---------------------------------------------------------------------------

#[test]
fn native_resnet_t_quantized_training_reduces_loss() {
    let mut c = native_config("e2m4_gnc_eg8mg1_sr", 12);
    c.model = "resnet_t".to_string();
    let r = trainer::train_native(&c).unwrap();
    assert_loss_decreases(&r, "resnet_t e2m4");
    assert_eq!(r.metrics.steps.len(), 12);
}

#[test]
fn resnet_t_step_is_bit_identical_across_thread_counts() {
    let (images, labels) = batch(8, 21);
    let run = |threads: usize| {
        let mut m = native_model("resnet_t", QuantConfig::default(), 3).unwrap();
        m.set_threads(threads);
        let out = m.train_step(&images, &labels, 0.05, 11);
        (out.loss.to_bits(), out.audit, m.state())
    };
    let (l1, a1, s1) = run(1);
    for t in [2usize, 8] {
        let (lt, at, st) = run(t);
        assert_eq!(l1, lt, "t{t}: loss");
        assert_eq!(a1, at, "t{t}: audit (per-layer stream + totals)");
        assert_eq!(s1.len(), st.len());
        for (i, (a, b)) in s1.iter().zip(&st).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "t{t}: state[{i}]");
        }
    }
}

#[test]
fn resnet_gradient_check_through_residual_joins() {
    // fp32 config: the whole step is differentiable, so analytic grads
    // must match central finite differences THROUGH the skip-add fan-in —
    // for the identity-shortcut block (block 1) and both 1x1-projection
    // shortcuts (blocks 2, 3).
    let mut model = native_model("resnet_t", QuantConfig::fp32(), 5).unwrap();
    model.set_threads(1);
    let (images, labels) = batch(2, 13);
    let (loss, _, grads, _) = model.loss_and_grads(&images, &labels, 3);
    assert!(loss.is_finite());
    let state = model.state();

    // probe every conv (stem, block convs, projection shortcuts), one BN
    // and the FC head
    let offs = model.graph.param_offsets();
    let mut idxs: Vec<usize> = Vec::new();
    let mut probed_projection = false;
    for (ni, node) in model.graph.nodes.iter().enumerate() {
        let len = node.param_len();
        if len == 0 {
            continue;
        }
        let probes: &[usize] = match &node.op {
            Op::Conv(_) => {
                if node.name.ends_with('s') {
                    probed_projection = true;
                }
                &[0, 1, 2]
            }
            _ => &[0],
        };
        for &p in probes {
            idxs.push(offs[ni] + (p * len.max(3) / 3).min(len - 1));
        }
    }
    idxs.sort_unstable();
    idxs.dedup();
    assert!(probed_projection, "the probe set must cover a projection shortcut");

    let eps = 3e-3f64;
    for &i in &idxs {
        let mut sp = state.clone();
        sp[i] = (state[i] as f64 + eps) as f32;
        model.load_state(&sp).unwrap();
        let (lp, _, _, _) = model.loss_and_grads(&images, &labels, 3);
        sp[i] = (state[i] as f64 - eps) as f32;
        model.load_state(&sp).unwrap();
        let (lm, _, _, _) = model.loss_and_grads(&images, &labels, 3);
        let fd = (lp as f64 - lm as f64) / (2.0 * eps);
        let an = grads[i] as f64;
        let tol = (an.abs().max(fd.abs()).max(1e-2)) * 0.08;
        assert!(
            (fd - an).abs() <= tol,
            "param {i}: analytic {an:.6e} vs finite-diff {fd:.6e} (tol {tol:.2e})"
        );
    }
    model.load_state(&state).unwrap();
}

#[test]
fn per_layer_audit_stream_rolls_up_to_totals() {
    let mut m = native_model("resnet_t", QuantConfig::default(), 2).unwrap();
    let (images, labels) = batch(4, 17);
    let out = m.train_step(&images, &labels, 0.05, 7);
    let a = &out.audit;

    // 8 quantized convs: 2 (block 1) + 3 (block 2, incl projection) + 3
    // (block 3, incl projection); the fp32 stem is not audited
    assert_eq!(a.layers.len(), 8, "one record per quantized conv node");
    assert!(a.layers.iter().any(|l| l.name.ends_with('s')), "projection shortcuts audited");
    assert_eq!(a.forward.convs, 8);
    assert_eq!(a.wgrad.convs, 8);
    assert_eq!(a.dgrad.convs, 8, "every quantized conv computes an input gradient");

    // the stream sums EXACTLY to the step totals (max for peak bits)
    macro_rules! check_pass {
        ($pass:ident) => {
            assert_eq!(a.$pass.mul_ops, a.layers.iter().map(|l| l.$pass.mul_ops).sum::<u64>());
            assert_eq!(
                a.$pass.int_add_ops,
                a.layers.iter().map(|l| l.$pass.int_add_ops).sum::<u64>()
            );
            assert_eq!(
                a.$pass.float_add_ops,
                a.layers.iter().map(|l| l.$pass.float_add_ops).sum::<u64>()
            );
            assert_eq!(
                a.$pass.group_scale_ops,
                a.layers.iter().map(|l| l.$pass.group_scale_ops).sum::<u64>()
            );
            assert_eq!(
                a.$pass.peak_acc_bits,
                a.layers.iter().map(|l| l.$pass.peak_acc_bits).max().unwrap()
            );
        };
    }
    check_pass!(forward);
    check_pass!(wgrad);
    check_pass!(dgrad);

    // Alg. 1 pass symmetry holds per layer, not just in aggregate
    for l in &a.layers {
        assert!(l.forward.mul_ops > 0, "{}", l.name);
        assert_eq!(l.forward.mul_ops, l.wgrad.mul_ops, "{}: fwd vs wgrad", l.name);
        assert_eq!(l.forward.mul_ops, l.dgrad.mul_ops, "{}: fwd vs dgrad", l.name);
    }
}

#[test]
fn audit_stream_written_to_out_dir() {
    let dir = std::env::temp_dir().join("mls_audit_stream_test");
    let _ = std::fs::remove_dir_all(&dir);
    let mut c = native_config("e2m4_gnc_eg8mg1_sr", 2);
    c.batch = 4;
    c.out_dir = Some(dir.to_string_lossy().into_owned());
    trainer::train_native(&c).unwrap();
    let tag = format!("{}_{}_s{}", c.model, c.cfg_name, c.seed);
    let text = std::fs::read_to_string(dir.join(format!("{tag}.audit.jsonl"))).unwrap();
    let lines: Vec<&str> = text.lines().filter(|l| !l.is_empty()).collect();
    assert_eq!(lines.len(), 2, "one record per step");
    for (i, line) in lines.iter().enumerate() {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("line {i}: {e}"));
        assert_eq!(v.get("audit").and_then(Json::as_str), Some("train_step"));
        assert_eq!(v.get("model").and_then(Json::as_str), Some("cnn_t"));
        assert_eq!(v.get("step").and_then(Json::as_f64), Some(i as f64));
        let layers = v.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 3, "cnn_t has 3 quantized convs");
        // totals equal the sum of the per-layer stream in the JSON too
        let sum: f64 = layers
            .iter()
            .map(|l| l.get("forward").unwrap().get("mul_ops").unwrap().as_f64().unwrap())
            .sum();
        let total = v
            .get("totals")
            .unwrap()
            .get("forward")
            .unwrap()
            .get("mul_ops")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(sum, total);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// optimizer plumbing
// ---------------------------------------------------------------------------

#[test]
fn momentum_optimizer_trains_and_differs_from_sgd() {
    let mut cm = native_config("fp32", 10);
    cm.optimizer = "momentum".to_string();
    let rm = trainer::train_native(&cm).unwrap();
    assert_loss_decreases(&rm, "momentum");

    let cs = native_config("fp32", 10);
    let rs = trainer::train_native(&cs).unwrap();
    assert_eq!(rm.final_state.len(), rs.final_state.len());
    assert_ne!(rm.final_state, rs.final_state, "momentum must change the trajectory");
}

// ---------------------------------------------------------------------------
// up-front config validation
// ---------------------------------------------------------------------------

#[test]
fn unsupported_native_model_errors_clearly() {
    let mut c = native_config("fp32", 1);
    c.model = "resnet20".to_string(); // a zoo network, but not native-trainable
    let err = trainer::train_native(&c).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("native"), "{msg}");
    assert!(msg.contains("pjrt"), "{msg}");
    for name in ["cnn_t", "cnn_s", "resnet_t"] {
        assert!(msg.contains(name), "must list {name}: {msg}");
    }
}

#[test]
fn unsupported_grouping_errors_up_front() {
    let mut c = native_config("e2m4_gf_eg8mg1_sr", 1);
    let err = trainer::train_native(&c).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("grouping"), "{msg}");
    assert!(msg.contains("pjrt"), "{msg}");
}

#[test]
fn unknown_optimizer_errors_up_front() {
    let mut c = native_config("fp32", 1);
    c.optimizer = "adam".to_string(); // bypasses the set() guard on purpose
    let err = trainer::train_native(&c).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("sgd") && msg.contains("momentum"), "{msg}");
}

#[test]
fn validate_native_config_accepts_all_native_models() {
    for model in ["cnn_t", "cnn_s", "resnet_t"] {
        let mut c = native_config("e2m4_gnc_eg8mg1_sr", 1);
        c.model = model.to_string();
        trainer::validate_native_config(&c)
            .unwrap_or_else(|e| panic!("{model} must validate: {e:#}"));
    }
}
