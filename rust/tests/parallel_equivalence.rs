//! Serial-vs-parallel equivalence: the tiled/sharded hot kernels must be
//! BIT-IDENTICAL across worker counts — outputs, tensor/group scales and
//! the hardware-audit op counters alike. This is what lets the parallel
//! execution layer serve the paper's bit-accurate simulator: threading is
//! purely a scheduling choice, never a numerics choice.

use mls_train::arith::conv::{
    lowbit_conv, lowbit_conv_legacy_threaded, lowbit_conv_planar_threaded, lowbit_conv_threaded,
    ConvOutput,
};
use mls_train::mls::quantizer::{quantize, quantize_threaded, QuantConfig, Rounding};
use mls_train::mls::{Grouping, MlsTensor};
use mls_train::util::prop::grouped_tensor;
use mls_train::util::rng::Pcg32;
use mls_train::util::simd;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn assert_tensors_identical(a: &MlsTensor, b: &MlsTensor, tag: &str) {
    assert_eq!(a.shape, b.shape, "{tag}: shape");
    assert_eq!(a.s_t.to_bits(), b.s_t.to_bits(), "{tag}: s_t");
    assert_eq!(a.sign, b.sign, "{tag}: sign plane");
    assert_eq!(a.exp_code, b.exp_code, "{tag}: exponent plane");
    assert_eq!(a.man, b.man, "{tag}: mantissa plane");
    assert_eq!(a.sg_exp, b.sg_exp, "{tag}: group scale exponents");
    assert_eq!(a.sg_man, b.sg_man, "{tag}: group scale mantissas");
}

fn assert_convs_identical(a: &ConvOutput, b: &ConvOutput, tag: &str) {
    assert_eq!(a.shape, b.shape, "{tag}: shape");
    assert_eq!(a.z.len(), b.z.len(), "{tag}: z length");
    for (i, (x, y)) in a.z.iter().zip(&b.z).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: z[{i}] {x} vs {y}");
    }
    assert_eq!(a.peak_acc_bits, b.peak_acc_bits, "{tag}: peak_acc_bits");
    assert_eq!(a.mul_ops, b.mul_ops, "{tag}: mul_ops");
    assert_eq!(a.int_add_ops, b.int_add_ops, "{tag}: int_add_ops");
    assert_eq!(a.float_add_ops, b.float_add_ops, "{tag}: float_add_ops");
    assert_eq!(a.group_scale_ops, b.group_scale_ops, "{tag}: group_scale_ops");
}

#[test]
fn quantize_identical_across_thread_counts() {
    let mut rng = Pcg32::seeded(101);
    let shape = [8usize, 12, 5, 5];
    let x = grouped_tensor(&mut rng, shape);
    let r = rng.rounding_offsets(x.len());

    let configs = [
        QuantConfig::default(), // <2,4> nc stochastic
        QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 1) },
        QuantConfig { grouping: Grouping::Second, ..QuantConfig::default() },
        QuantConfig { grouping: Grouping::First, ..QuantConfig::new(0, 4) },
        QuantConfig { grouping: Grouping::None, ..QuantConfig::default() },
    ];
    for cfg in configs {
        let offsets: &[f32] = if cfg.rounding == Rounding::Stochastic { &r } else { &[] };
        let serial = quantize_threaded(&x, &shape, &cfg, offsets, 1);
        for threads in THREAD_COUNTS {
            let par = quantize_threaded(&x, &shape, &cfg, offsets, threads);
            let tag = format!("{} @ {threads} threads", cfg.name());
            assert_tensors_identical(&serial, &par, &tag);
            // dequantization must agree bit-for-bit too
            let qs = serial.dequantize_threaded(1);
            let qp = par.dequantize_threaded(threads);
            for (i, (a, b)) in qs.iter().zip(&qp).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: q[{i}]");
            }
        }
    }
}

#[test]
fn lowbit_conv_identical_across_thread_counts() {
    let mut rng = Pcg32::seeded(102);
    let wshape = [6usize, 5, 3, 3];
    let ashape = [4usize, 5, 7, 7];
    let w = grouped_tensor(&mut rng, wshape);
    let a = grouped_tensor(&mut rng, ashape);

    for (e, m) in [(2u32, 4u32), (2, 1), (0, 4)] {
        let mut cfg = QuantConfig::new(e, m);
        cfg.rounding = Rounding::Nearest;
        let tw = quantize(&w, &wshape, &cfg, &[]);
        let ta = quantize(&a, &ashape, &cfg, &[]);
        let serial = lowbit_conv_threaded(&tw, &ta, 1, 1, 1);
        for threads in THREAD_COUNTS {
            let par = lowbit_conv_threaded(&tw, &ta, 1, 1, threads);
            assert_convs_identical(&serial, &par, &format!("<{e},{m}> @ {threads} threads"));
        }
        // stride-2, pad-0 geometry as well (clipped windows change counters)
        let s2 = lowbit_conv_threaded(&tw, &ta, 2, 0, 1);
        for threads in THREAD_COUNTS {
            let p2 = lowbit_conv_threaded(&tw, &ta, 2, 0, threads);
            assert_convs_identical(&s2, &p2, &format!("<{e},{m}> s2 @ {threads} threads"));
        }
    }
}

#[test]
fn packed_and_planar_kernels_match_legacy_across_thread_counts() {
    // the packed-GEMM and planar kernels are pure implementation changes:
    // for every format, geometry and worker count they must reproduce the
    // legacy per-pixel kernel bit-for-bit — values and audit counters
    // alike
    let mut rng = Pcg32::seeded(104);
    let wshape = [6usize, 5, 3, 3];
    let ashape = [4usize, 5, 7, 7];
    let w = grouped_tensor(&mut rng, wshape);
    let a = grouped_tensor(&mut rng, ashape);

    for (e, m) in [(2u32, 4u32), (2, 1), (0, 4)] {
        let mut cfg = QuantConfig::new(e, m);
        cfg.rounding = Rounding::Nearest;
        let tw = quantize(&w, &wshape, &cfg, &[]);
        let ta = quantize(&a, &ashape, &cfg, &[]);
        for (stride, pad) in [(1usize, 1usize), (2, 0), (2, 2)] {
            let legacy = lowbit_conv_legacy_threaded(&tw, &ta, stride, pad, 1);
            for threads in THREAD_COUNTS {
                let packed = lowbit_conv_threaded(&tw, &ta, stride, pad, threads);
                let tag = format!("<{e},{m}> s{stride} p{pad} packed @ {threads} threads");
                assert_convs_identical(&legacy, &packed, &tag);
                let planar = lowbit_conv_planar_threaded(&tw, &ta, stride, pad, threads);
                let tag = format!("<{e},{m}> s{stride} p{pad} planar @ {threads} threads");
                assert_convs_identical(&legacy, &planar, &tag);
            }
        }
    }
}

#[test]
fn simd_levels_identical_to_forced_scalar() {
    // the runtime SIMD dispatch is a pure implementation choice, exactly
    // like threading: for every supported ISA level, quantization (all
    // grouping modes, both rounding modes) and the packed conv must
    // reproduce the forced-scalar results bit-for-bit at every worker
    // count — planes, scales, output values and audit counters alike
    let mut rng = Pcg32::seeded(106);
    let shape = [8usize, 12, 5, 5];
    let x = grouped_tensor(&mut rng, shape);
    let r = rng.rounding_offsets(x.len());

    let configs = [
        QuantConfig::default(), // <2,4> nc stochastic
        QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 1) },
        QuantConfig { grouping: Grouping::Second, ..QuantConfig::default() },
        QuantConfig { grouping: Grouping::First, ..QuantConfig::new(0, 4) },
        QuantConfig { grouping: Grouping::None, ..QuantConfig::default() },
    ];
    for cfg in configs {
        let offsets: &[f32] = if cfg.rounding == Rounding::Stochastic { &r } else { &[] };
        let prev = simd::set_level(simd::Level::Off);
        let scalar = quantize_threaded(&x, &shape, &cfg, offsets, 1);
        simd::set_level(prev);
        for lvl in simd::Level::supported() {
            let prev = simd::set_level(lvl);
            for threads in THREAD_COUNTS {
                let forced = quantize_threaded(&x, &shape, &cfg, offsets, threads);
                let tag = format!("{} [simd {}] @ {threads} threads", cfg.name(), lvl.name());
                assert_tensors_identical(&scalar, &forced, &tag);
            }
            simd::set_level(prev);
        }
    }

    let wshape = [6usize, 5, 3, 3];
    let mut ncfg = QuantConfig::new(2, 4);
    ncfg.rounding = Rounding::Nearest;
    let tw = quantize(&grouped_tensor(&mut rng, wshape), &wshape, &ncfg, &[]);
    let ta = quantize(&x, &shape, &ncfg, &[]);
    let prev = simd::set_level(simd::Level::Off);
    let scalar = lowbit_conv_threaded(&tw, &ta, 1, 1, 1);
    simd::set_level(prev);
    for lvl in simd::Level::supported() {
        let prev = simd::set_level(lvl);
        for threads in THREAD_COUNTS {
            let forced = lowbit_conv_threaded(&tw, &ta, 1, 1, threads);
            let tag = format!("conv [simd {}] @ {threads} threads", lvl.name());
            assert_convs_identical(&scalar, &forced, &tag);
        }
        simd::set_level(prev);
    }
}

#[test]
fn serial_fallback_threshold_is_pure_scheduling() {
    // ambient quantize()/dequantize() drop to one thread below
    // SERIAL_FALLBACK_ELEMS; sharding is bit-identical at every thread
    // count, so the fallback must be invisible in the results — on both
    // sides of the threshold
    use mls_train::mls::quantizer::SERIAL_FALLBACK_ELEMS;
    let mut rng = Pcg32::seeded(105);
    let small = [4usize, 6, 5, 5]; // 600 elements: far below the threshold
    let large = [8usize, 16, 12, 12]; // 18432: above it
    assert!(small.iter().product::<usize>() < SERIAL_FALLBACK_ELEMS);
    assert!(large.iter().product::<usize>() >= SERIAL_FALLBACK_ELEMS);
    for shape in [small, large] {
        let x = grouped_tensor(&mut rng, shape);
        let r = rng.rounding_offsets(x.len());
        let cfg = QuantConfig::default();
        let ambient = quantize(&x, &shape, &cfg, &r);
        for threads in THREAD_COUNTS {
            let explicit = quantize_threaded(&x, &shape, &cfg, &r, threads);
            let tag = format!("{shape:?} fallback vs {threads} threads");
            assert_tensors_identical(&ambient, &explicit, &tag);
            let qa = ambient.dequantize();
            let qe = explicit.dequantize_threaded(threads);
            for (i, (a, b)) in qa.iter().zip(&qe).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: q[{i}]");
            }
        }
    }
}

#[test]
fn default_entry_points_match_explicit_serial() {
    // the MLS_THREADS-driven defaults must be a pure scheduling choice:
    // whatever the ambient thread count, results equal the serial path
    let mut rng = Pcg32::seeded(103);
    let shape = [4usize, 6, 4, 4];
    let x = grouped_tensor(&mut rng, shape);
    let r = rng.rounding_offsets(x.len());
    let cfg = QuantConfig::default();

    let t_default = quantize(&x, &shape, &cfg, &r);
    let t_serial = quantize_threaded(&x, &shape, &cfg, &r, 1);
    assert_tensors_identical(&t_serial, &t_default, "default quantize");

    let wshape = [3usize, 6, 3, 3];
    let mut ncfg = QuantConfig::new(2, 4);
    ncfg.rounding = Rounding::Nearest;
    let tw = quantize(&grouped_tensor(&mut rng, wshape), &wshape, &ncfg, &[]);
    let ta = quantize(&x, &shape, &ncfg, &[]);
    let c_default = lowbit_conv(&tw, &ta, 1, 1);
    let c_serial = lowbit_conv_threaded(&tw, &ta, 1, 1, 1);
    assert_convs_identical(&c_serial, &c_default, "default conv");
}
