//! Bench: the bit-accurate integer-path convolution (Eq. 6-8 simulator)
//! vs the plain f32 convolution — the Table V / VI hot path in software.
//!
//! Measures the cache-blocked packed-GEMM kernel (the `lowbit_conv`
//! default) against the planar kernel (its direct baseline) and the
//! legacy per-pixel kernel (all three bit-identical by construction) and
//! writes the machine-readable perf trajectory to `BENCH_conv.json` at
//! the repo root (schema: `schemas/bench_conv.schema.json`, validated in
//! CI). `--smoke` / `MLS_BENCH_SMOKE=1` switches to the fast CI
//! anti-bit-rot mode; `MLS_BENCH_ENFORCE=1` turns the serial speedup
//! ratios into hard gates (exit 1 on regression): packed >= planar,
//! planar >= legacy, and (when a vector ISA is active) the SIMD
//! microkernel >= the forced-scalar kernel, all at 1 thread.

use std::time::Duration;

use mls_train::arith::conv::{
    conv2d_f32_threaded, lowbit_conv, lowbit_conv_legacy_threaded, lowbit_conv_planar_threaded,
    lowbit_conv_threaded,
};
use mls_train::arith::spec::ConvSpec;
use mls_train::mls::quantizer::{quantize, QuantConfig, Rounding};
use mls_train::util::bench::{bench, black_box, budget, enforce_mode, smoke_mode, BenchReport};
use mls_train::util::json::Json;
use mls_train::util::parallel;
use mls_train::util::rng::Pcg32;
use mls_train::util::simd::{self, Level};

fn main() {
    let mut rng = Pcg32::seeded(2);
    let wshape = [16usize, 16, 3, 3];
    let ashape = [4usize, 16, 12, 12];
    let w = mls_train::util::prop::grouped_tensor(&mut rng, wshape);
    let a = mls_train::util::prop::grouped_tensor(&mut rng, ashape);
    let macs: u64 = (16 * 16 * 9 * 12 * 12 * 4) as u64;
    let threads = parallel::num_threads();
    let b = budget(Duration::from_secs(3));

    println!(
        "# bench_conv_arith — {macs} MACs per conv, {threads} worker threads{}",
        if smoke_mode() { " [smoke]" } else { "" }
    );

    let mut report = BenchReport::new("BENCH_conv.json", "bench_conv_arith");
    report.set("threads", Json::Num(threads as f64));
    report.set("macs_per_conv", Json::Num(macs as f64));
    let simd_level = simd::active();
    report.set("simd", Json::Str(simd_level.name().to_string()));
    println!("# simd dispatch: {}", simd::describe());
    report.set(
        "shapes",
        Json::Str(format!("w[Co,Ci,Kh,Kw]={wshape:?} a[N,Ci,H,W]={ashape:?} stride=1 pad=1")),
    );

    let mut cfg = QuantConfig::new(2, 4);
    cfg.rounding = Rounding::Nearest;
    let tw = quantize(&w, &wshape, &cfg, &[]);
    let ta = quantize(&a, &ashape, &cfg, &[]);

    let legacy_serial = bench("lowbit_conv/legacy_e2m4_serial", b, || {
        black_box(lowbit_conv_legacy_threaded(&tw, &ta, 1, 1, 1));
    });
    println!(
        "  -> {:.1} MMAC/s (legacy per-pixel decode kernel)",
        legacy_serial.throughput_items(macs) / 1e6
    );
    report.add_result(&legacy_serial, macs, "mac");

    let planar_serial = bench("lowbit_conv/planar_e2m4_serial", b, || {
        black_box(lowbit_conv_planar_threaded(&tw, &ta, 1, 1, 1));
    });
    let planar_vs_legacy = legacy_serial.median.as_secs_f64() / planar_serial.median.as_secs_f64();
    println!(
        "  -> {:.1} MMAC/s ({planar_vs_legacy:.2}x vs legacy at 1 thread, bit-identical)",
        planar_serial.throughput_items(macs) / 1e6
    );
    report.add_result(&planar_serial, macs, "mac");
    report.add_ratio("planar_vs_legacy_serial", planar_vs_legacy);

    let packed_serial = bench("lowbit_conv/packed_e2m4_serial", b, || {
        black_box(lowbit_conv_threaded(&tw, &ta, 1, 1, 1));
    });
    let packed_vs_planar = planar_serial.median.as_secs_f64() / packed_serial.median.as_secs_f64();
    let packed_vs_legacy = legacy_serial.median.as_secs_f64() / packed_serial.median.as_secs_f64();
    println!(
        "  -> {:.1} MMAC/s ({packed_vs_planar:.2}x vs planar, {packed_vs_legacy:.2}x vs legacy \
         at 1 thread, bit-identical)",
        packed_serial.throughput_items(macs) / 1e6
    );
    report.add_result(&packed_serial, macs, "mac");
    report.add_ratio("packed_vs_planar_serial", packed_vs_planar);
    report.add_ratio("packed_vs_legacy_serial", packed_vs_legacy);

    let packed_par = bench(&format!("lowbit_conv/packed_e2m4_t{threads}"), b, || {
        black_box(lowbit_conv(&tw, &ta, 1, 1));
    });
    let threaded_vs_serial = packed_serial.median.as_secs_f64() / packed_par.median.as_secs_f64();
    println!(
        "  -> {:.1} MMAC/s ({threaded_vs_serial:.2}x vs serial, bit-identical)",
        packed_par.throughput_items(macs) / 1e6
    );
    report.add_result(&packed_par, macs, "mac");
    report.add_ratio("packed_threaded_vs_serial", threaded_vs_serial);

    // Alg. 1 backward passes on the SAME ConvSpec engine. Both execute
    // exactly the forward in-bounds MAC count (the tap sets are bijective
    // re-indexings), so the MMAC/s figures are directly comparable.
    let spec = ConvSpec::of_forward(&tw, &ta, 1, 1);
    let eshape = [ashape[0], wshape[0], spec.out_h(), spec.out_w()];
    let ef = mls_train::util::prop::grouped_tensor(&mut rng, eshape);
    let te = quantize(&ef, &eshape, &cfg, &[]);

    let wgrad_serial = bench("lowbit_conv/wgrad_e2m4_serial", b, || {
        black_box(spec.weight_grad(&te, &ta, 1));
    });
    let wgrad_vs_packed = packed_serial.median.as_secs_f64() / wgrad_serial.median.as_secs_f64();
    println!(
        "  -> {:.1} MMAC/s ({wgrad_vs_packed:.2}x the packed forward at 1 thread)",
        wgrad_serial.throughput_items(macs) / 1e6
    );
    report.add_result(&wgrad_serial, macs, "mac");
    report.add_ratio("wgrad_vs_packed_serial", wgrad_vs_packed);

    let dgrad_serial = bench("lowbit_conv/dgrad_e2m4_serial", b, || {
        black_box(spec.input_grad(&te, &tw, 1));
    });
    let dgrad_vs_packed = packed_serial.median.as_secs_f64() / dgrad_serial.median.as_secs_f64();
    println!(
        "  -> {:.1} MMAC/s ({dgrad_vs_packed:.2}x the packed forward at 1 thread)",
        dgrad_serial.throughput_items(macs) / 1e6
    );
    report.add_result(&dgrad_serial, macs, "mac");
    report.add_ratio("dgrad_vs_packed_serial", dgrad_vs_packed);

    // SIMD microkernel vs the forced-scalar reference on the SAME packed
    // engine, serial, for all three Alg. 1 passes — the ratio isolates the
    // Eq. 7 vector MAC (pack/epilogue/scheduling are shared). On a scalar
    // host (simd = "off") these ratios read ~1.0 by construction.
    let prev = simd::set_level(Level::Off);
    let scalar_fwd = bench("lowbit_conv/packed_e2m4_scalar_serial", b, || {
        black_box(lowbit_conv_threaded(&tw, &ta, 1, 1, 1));
    });
    let scalar_wgrad = bench("lowbit_conv/wgrad_e2m4_scalar_serial", b, || {
        black_box(spec.weight_grad(&te, &ta, 1));
    });
    let scalar_dgrad = bench("lowbit_conv/dgrad_e2m4_scalar_serial", b, || {
        black_box(spec.input_grad(&te, &tw, 1));
    });
    simd::set_level(prev);
    let simd_vs_scalar = scalar_fwd.median.as_secs_f64() / packed_serial.median.as_secs_f64();
    let simd_wgrad_vs_scalar =
        scalar_wgrad.median.as_secs_f64() / wgrad_serial.median.as_secs_f64();
    let simd_dgrad_vs_scalar =
        scalar_dgrad.median.as_secs_f64() / dgrad_serial.median.as_secs_f64();
    println!(
        "  -> {:.1} MMAC/s scalar fwd ({} is {simd_vs_scalar:.2}x scalar; wgrad \
         {simd_wgrad_vs_scalar:.2}x, dgrad {simd_dgrad_vs_scalar:.2}x, bit-identical)",
        scalar_fwd.throughput_items(macs) / 1e6,
        simd_level.name()
    );
    report.add_result(&scalar_fwd, macs, "mac");
    report.add_result(&scalar_wgrad, macs, "mac");
    report.add_result(&scalar_dgrad, macs, "mac");
    report.add_ratio("simd_vs_scalar_serial", simd_vs_scalar);
    report.add_ratio("simd_wgrad_vs_scalar_serial", simd_wgrad_vs_scalar);
    report.add_ratio("simd_dgrad_vs_scalar_serial", simd_dgrad_vs_scalar);

    let wq = tw.dequantize();
    let aq = ta.dequantize();
    let float_serial = bench("conv2d_f32/float_path_serial", b, || {
        black_box(conv2d_f32_threaded(&wq, wshape, &aq, ashape, 1, 1, 1));
    });
    println!("  -> {:.1} MMAC/s", float_serial.throughput_items(macs) / 1e6);
    report.add_result(&float_serial, macs, "mac");

    let float_par = bench(&format!("conv2d_f32/float_path_t{threads}"), b, || {
        black_box(conv2d_f32_threaded(&wq, wshape, &aq, ashape, 1, 1, threads));
    });
    println!(
        "  -> {:.1} MMAC/s ({:.2}x vs serial, bit-identical)",
        float_par.throughput_items(macs) / 1e6,
        float_serial.median.as_secs_f64() / float_par.median.as_secs_f64()
    );
    report.add_result(&float_par, macs, "mac");
    report.add_ratio(
        "float_threaded_vs_serial",
        float_serial.median.as_secs_f64() / float_par.median.as_secs_f64(),
    );

    let mut cfg1 = QuantConfig::new(2, 1);
    cfg1.rounding = Rounding::Nearest;
    let tw1 = quantize(&w, &wshape, &cfg1, &[]);
    let ta1 = quantize(&a, &ashape, &cfg1, &[]);
    let e2m1 = bench(&format!("lowbit_conv/packed_e2m1_t{threads}"), b, || {
        black_box(lowbit_conv(&tw1, &ta1, 1, 1));
    });
    println!("  -> {:.1} MMAC/s", e2m1.throughput_items(macs) / 1e6);
    report.add_result(&e2m1, macs, "mac");

    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_conv.json: {e}");
            std::process::exit(1);
        }
    }

    // CI perf guard: the packed-GEMM kernel must not lose to the planar
    // kernel, nor planar to legacy, at 1 thread. Full runs gate at the
    // acceptance floor of 1.0; smoke runs (~50 ms budgets, noisy shared
    // runners) get a small margin so scheduling jitter cannot fail a push
    // without a real regression — an actual regression reads well below
    // this.
    let floor = if smoke_mode() { 0.9 } else { 1.0 };
    if enforce_mode() && planar_vs_legacy < floor {
        eprintln!(
            "PERF REGRESSION: planar kernel is {planar_vs_legacy:.3}x the legacy kernel at 1 \
             thread (< {floor})"
        );
        std::process::exit(1);
    }
    if enforce_mode() && packed_vs_planar < floor {
        eprintln!(
            "PERF REGRESSION: packed-GEMM kernel is {packed_vs_planar:.3}x the planar kernel at \
             1 thread (< {floor})"
        );
        std::process::exit(1);
    }
    // The vectorized microkernel must not lose to the scalar reference it
    // replaces (acceptance floor 1.0; only meaningful when a vector ISA
    // is actually active — on a scalar host both sides run the same code).
    if enforce_mode() && simd_level != Level::Off && simd_vs_scalar < 1.0 {
        eprintln!(
            "PERF REGRESSION: {} microkernel is {simd_vs_scalar:.3}x the forced-scalar kernel \
             at 1 thread (< 1.0)",
            simd_level.name()
        );
        std::process::exit(1);
    }
}
