//! Bench: the bit-accurate integer-path convolution (Eq. 6-8 simulator)
//! vs the plain f32 convolution — the Table V / VI hot path in software.

use std::time::Duration;

use mls_train::arith::conv::{conv2d_f32, lowbit_conv};
use mls_train::mls::quantizer::{quantize, QuantConfig, Rounding};
use mls_train::util::bench::{bench, black_box};
use mls_train::util::rng::Pcg32;

fn main() {
    let mut rng = Pcg32::seeded(2);
    let wshape = [16usize, 16, 3, 3];
    let ashape = [4usize, 16, 12, 12];
    let w = mls_train::util::prop::grouped_tensor(&mut rng, wshape);
    let a = mls_train::util::prop::grouped_tensor(&mut rng, ashape);
    let macs: u64 = (16 * 16 * 9 * 12 * 12 * 4) as u64;

    println!("# bench_conv_arith — {macs} MACs per conv");

    let mut cfg = QuantConfig::new(2, 4);
    cfg.rounding = Rounding::Nearest;
    let tw = quantize(&w, &wshape, &cfg, &[]);
    let ta = quantize(&a, &ashape, &cfg, &[]);

    let res = bench("lowbit_conv/int_path_e2m4", Duration::from_secs(3), || {
        black_box(lowbit_conv(&tw, &ta, 1, 1));
    });
    println!("  -> {:.1} MMAC/s", res.throughput_items(macs) / 1e6);

    let wq = tw.dequantize();
    let aq = ta.dequantize();
    let res = bench("conv2d_f32/float_path", Duration::from_secs(3), || {
        black_box(conv2d_f32(&wq, wshape, &aq, ashape, 1, 1));
    });
    println!("  -> {:.1} MMAC/s", res.throughput_items(macs) / 1e6);

    let mut cfg1 = QuantConfig::new(2, 1);
    cfg1.rounding = Rounding::Nearest;
    let tw1 = quantize(&w, &wshape, &cfg1, &[]);
    let ta1 = quantize(&a, &ashape, &cfg1, &[]);
    let res = bench("lowbit_conv/int_path_e2m1", Duration::from_secs(3), || {
        black_box(lowbit_conv(&tw1, &ta1, 1, 1));
    });
    println!("  -> {:.1} MMAC/s", res.throughput_items(macs) / 1e6);
}
