//! Bench: the bit-accurate integer-path convolution (Eq. 6-8 simulator)
//! vs the plain f32 convolution — the Table V / VI hot path in software.
//!
//! Reports the serial baseline next to the tiled parallel path so the
//! speedup (and its bit-identity) is visible in every run; `--smoke` /
//! `MLS_BENCH_SMOKE=1` switches to the fast CI anti-bit-rot mode.

use std::time::Duration;

use mls_train::arith::conv::{conv2d_f32, lowbit_conv, lowbit_conv_threaded};
use mls_train::mls::quantizer::{quantize, QuantConfig, Rounding};
use mls_train::util::bench::{bench, black_box, budget, smoke_mode};
use mls_train::util::parallel;
use mls_train::util::rng::Pcg32;

fn main() {
    let mut rng = Pcg32::seeded(2);
    let wshape = [16usize, 16, 3, 3];
    let ashape = [4usize, 16, 12, 12];
    let w = mls_train::util::prop::grouped_tensor(&mut rng, wshape);
    let a = mls_train::util::prop::grouped_tensor(&mut rng, ashape);
    let macs: u64 = (16 * 16 * 9 * 12 * 12 * 4) as u64;
    let threads = parallel::num_threads();
    let b = budget(Duration::from_secs(3));

    println!(
        "# bench_conv_arith — {macs} MACs per conv, {threads} worker threads{}",
        if smoke_mode() { " [smoke]" } else { "" }
    );

    let mut cfg = QuantConfig::new(2, 4);
    cfg.rounding = Rounding::Nearest;
    let tw = quantize(&w, &wshape, &cfg, &[]);
    let ta = quantize(&a, &ashape, &cfg, &[]);

    let serial = bench("lowbit_conv/int_path_e2m4_serial", b, || {
        black_box(lowbit_conv_threaded(&tw, &ta, 1, 1, 1));
    });
    println!("  -> {:.1} MMAC/s", serial.throughput_items(macs) / 1e6);

    let par = bench(&format!("lowbit_conv/int_path_e2m4_t{threads}"), b, || {
        black_box(lowbit_conv(&tw, &ta, 1, 1));
    });
    println!(
        "  -> {:.1} MMAC/s ({:.2}x vs serial, bit-identical)",
        par.throughput_items(macs) / 1e6,
        serial.median.as_secs_f64() / par.median.as_secs_f64()
    );

    let wq = tw.dequantize();
    let aq = ta.dequantize();
    let res = bench("conv2d_f32/float_path", b, || {
        black_box(conv2d_f32(&wq, wshape, &aq, ashape, 1, 1));
    });
    println!("  -> {:.1} MMAC/s", res.throughput_items(macs) / 1e6);

    let mut cfg1 = QuantConfig::new(2, 1);
    cfg1.rounding = Rounding::Nearest;
    let tw1 = quantize(&w, &wshape, &cfg1, &[]);
    let ta1 = quantize(&a, &ashape, &cfg1, &[]);
    let res = bench(&format!("lowbit_conv/int_path_e2m1_t{threads}"), b, || {
        black_box(lowbit_conv(&tw1, &ta1, 1, 1));
    });
    println!("  -> {:.1} MMAC/s", res.throughput_items(macs) / 1e6);
}
