//! Bench: PJRT train-step dispatch — the end-to-end driver hot loop
//! (compile once, then measure steady-state step latency for the fp32 and
//! the Pallas-quantized MLS artifacts).
//!
//! Requires `make artifacts`. Skips gracefully when artifacts are missing
//! so `cargo bench` stays green on a fresh checkout.

use std::time::Duration;

use mls_train::data::{streams, SynthCifar};
use mls_train::runtime::Engine;
use mls_train::util::bench::{bench, black_box, budget};

fn main() {
    println!("# bench_runtime — PJRT step latency");
    if !cfg!(feature = "pjrt") {
        println!("skipped: built without the `pjrt` feature (stub engine)");
        return;
    }
    let mut engine = match Engine::from_dir("artifacts") {
        Ok(e) => e,
        Err(e) => {
            println!("skipped: {e:#}");
            return;
        }
    };
    let model = "resnet_t";
    let meta = match engine.manifest.model(model) {
        Ok(m) => m.clone(),
        Err(e) => {
            println!("skipped: {e:#}");
            return;
        }
    };
    let ds = SynthCifar::new(Default::default());
    let (images, labels) = ds.batch(meta.batch, streams::TRAIN, 0);
    let init = engine.manifest.load_init(model).unwrap();

    for cfg in ["fp32", "e2m4_gnc_eg8mg1_sr", "e2m1_gnc_eg8mg1_sr"] {
        if engine.manifest.find(model, "train_step", cfg).is_err() {
            println!("skipping {cfg}: artifact missing");
            continue;
        }
        // compile outside the measured region
        let mut state = init.clone();
        engine.train_step(model, cfg, &mut state, &images, &labels, 0, 0.05).unwrap();
        let mut step = 0;
        let res = bench(&format!("train_step/{model}/{cfg}"), budget(Duration::from_secs(5)), || {
            step += 1;
            black_box(
                engine
                    .train_step(model, cfg, &mut state, &images, &labels, step, 0.05)
                    .unwrap(),
            );
        });
        println!(
            "  -> {:.1} images/s (batch {})",
            meta.batch as f64 / res.median.as_secs_f64(),
            meta.batch
        );
    }

    // eval-step latency
    let state = init.clone();
    if engine.manifest.find(model, "eval_step", "fp32").is_ok() {
        engine.eval_step(model, &state, &images, &labels).unwrap();
        bench(&format!("eval_step/{model}"), budget(Duration::from_secs(3)), || {
            black_box(engine.eval_step(model, &state, &images, &labels).unwrap());
        });
    }
}
