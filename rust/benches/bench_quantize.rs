//! Bench: MLS dynamic quantization throughput (the DQ overhead row of
//! Table VI — 4 muls + 2 adds per element on the paper's hardware; here we
//! measure the software simulator's elements/s on the L3 hot path).
//!
//! Reports the serial baseline next to the group-sharded parallel path
//! (plus the tiny-tensor serial-fallback comparison) and writes the
//! machine-readable trajectory to `BENCH_quantize.json` at the repo root
//! (schema: `schemas/bench_quantize.schema.json`, validated in CI);
//! `--smoke` / `MLS_BENCH_SMOKE=1` switches to the fast CI mode.

use std::time::Duration;

use mls_train::mls::quantizer::{fake_quant, quantize, quantize_threaded, QuantConfig, Rounding};
use mls_train::mls::Grouping;
use mls_train::util::bench::{bench, black_box, budget, smoke_mode, BenchReport};
use mls_train::util::json::Json;
use mls_train::util::parallel;
use mls_train::util::rng::Pcg32;
use mls_train::util::simd::{self, Level};

fn main() {
    let mut rng = Pcg32::seeded(1);
    let shape = [32usize, 64, 16, 16]; // a typical activation tensor
    let n: usize = shape.iter().product();
    let x = mls_train::util::prop::grouped_tensor(&mut rng, shape);
    let r = rng.rounding_offsets(n);
    let threads = parallel::num_threads();
    let b = budget(Duration::from_secs(2));

    println!(
        "# bench_quantize — {n} elements ({}x{}x{}x{}), {threads} worker threads{}",
        shape[0],
        shape[1],
        shape[2],
        shape[3],
        if smoke_mode() { " [smoke]" } else { "" }
    );

    let mut report = BenchReport::new("BENCH_quantize.json", "bench_quantize");
    report.set("threads", Json::Num(threads as f64));
    report.set("elements", Json::Num(n as f64));
    report.set("shape", Json::Str(format!("{shape:?}")));
    let simd_level = simd::active();
    report.set("simd", Json::Str(simd_level.name().to_string()));
    println!("# simd dispatch: {}", simd::describe());

    // serial vs parallel on the headline config
    let cfg = QuantConfig::default();
    let serial = bench("quantize/e2m4_nc_stochastic_serial", b, || {
        black_box(quantize_threaded(&x, &shape, &cfg, &r, 1));
    });
    println!("  -> {:.1} Melem/s", serial.throughput_items(n as u64) / 1e6);
    report.add_result(&serial, n as u64, "elem");
    let par = bench(&format!("quantize/e2m4_nc_stochastic_t{threads}"), b, || {
        black_box(quantize(&x, &shape, &cfg, &r));
    });
    let threaded_vs_serial = serial.median.as_secs_f64() / par.median.as_secs_f64();
    println!(
        "  -> {:.1} Melem/s ({threaded_vs_serial:.2}x vs serial, bit-identical)",
        par.throughput_items(n as u64) / 1e6
    );
    report.add_result(&par, n as u64, "elem");
    report.add_ratio("threaded_vs_serial", threaded_vs_serial);

    // SIMD element pass vs the forced-scalar reference, serial — isolates
    // the vectorized |max| reduce + quantize lane (bit-identical by
    // construction; ~1.0 on a scalar host where simd = "off")
    let prev = simd::set_level(Level::Off);
    let scalar_serial = bench("quantize/e2m4_nc_stochastic_scalar_serial", b, || {
        black_box(quantize_threaded(&x, &shape, &cfg, &r, 1));
    });
    simd::set_level(prev);
    let simd_vs_scalar = scalar_serial.median.as_secs_f64() / serial.median.as_secs_f64();
    println!(
        "  -> {:.1} Melem/s scalar ({} is {simd_vs_scalar:.2}x scalar, bit-identical)",
        scalar_serial.throughput_items(n as u64) / 1e6,
        simd_level.name()
    );
    report.add_result(&scalar_serial, n as u64, "elem");
    report.add_ratio("quantize_simd_vs_scalar", simd_vs_scalar);

    for (name, cfg) in [
        ("e2m4_nc_nearest", QuantConfig { rounding: Rounding::Nearest, ..Default::default() }),
        ("e2m1_nc_stochastic", QuantConfig::new(2, 1)),
        ("e2m4_none", QuantConfig { grouping: Grouping::None, ..Default::default() }),
        ("e2m4_second", QuantConfig { grouping: Grouping::Second, ..Default::default() }),
        ("int4_nc", QuantConfig::new(0, 4)),
    ] {
        let res = bench(&format!("quantize/{name}"), b, || {
            black_box(quantize(&x, &shape, &cfg, &r));
        });
        println!("  -> {:.1} Melem/s", res.throughput_items(n as u64) / 1e6);
        report.add_result(&res, n as u64, "elem");
    }

    let cfg = QuantConfig::default();
    let res = bench("fake_quant/e2m4_nc", b, || {
        black_box(fake_quant(&x, &shape, &cfg, &r));
    });
    println!("  -> {:.1} Melem/s", res.throughput_items(n as u64) / 1e6);
    report.add_result(&res, n as u64, "elem");

    // tiny-tensor dispatch overhead: the ambient entry point stays serial
    // below SERIAL_FALLBACK_ELEMS, so quantize() on a small tensor should
    // beat forcing it across the pool
    let small_shape = [4usize, 16, 8, 8];
    let small_n: usize = small_shape.iter().product();
    let xs = &x[..small_n];
    let rs = &r[..small_n];
    let small_fallback = bench("quantize/small_e2m4_fallback", b, || {
        black_box(quantize(xs, &small_shape, &cfg, rs));
    });
    println!("  -> {:.1} Melem/s", small_fallback.throughput_items(small_n as u64) / 1e6);
    report.add_result(&small_fallback, small_n as u64, "elem");
    let small_pool = bench(&format!("quantize/small_e2m4_forced_t{threads}"), b, || {
        black_box(quantize_threaded(xs, &small_shape, &cfg, rs, threads));
    });
    let small_ratio = small_pool.median.as_secs_f64() / small_fallback.median.as_secs_f64();
    println!(
        "  -> {:.1} Melem/s (fallback is {small_ratio:.2}x the forced pool dispatch, \
         bit-identical)",
        small_pool.throughput_items(small_n as u64) / 1e6
    );
    report.add_result(&small_pool, small_n as u64, "elem");
    report.add_ratio("small_fallback_vs_forced_pool", small_ratio);

    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_quantize.json: {e}");
            std::process::exit(1);
        }
    }
}
