//! Bench: synthcifar batch generation — it sits on the training hot loop
//! ahead of every PJRT step, so it must stay far below the step time.

use std::time::Duration;

use mls_train::data::{streams, DatasetConfig, SynthCifar};
use mls_train::util::bench::{bench, black_box, budget};

fn main() {
    let ds = SynthCifar::new(DatasetConfig::default());
    println!("# bench_data — synthcifar generation");
    for batch in [32usize, 128] {
        let res = bench(&format!("batch/{batch}"), budget(Duration::from_secs(2)), || {
            black_box(ds.batch(batch, streams::TRAIN, 7));
        });
        let imgs_per_s = res.throughput_items(batch as u64);
        println!("  -> {:.0} images/s", imgs_per_s);
    }
}
