//! Bench: whole-network energy accounting (the Table VI generator) — must
//! stay trivially cheap since the ablation harness calls it in loops.

use std::time::Duration;

use mls_train::hw::counter::training_energy;
use mls_train::hw::units::{Arithmetic, EnergyModel};
use mls_train::mls::format::EmFormat;
use mls_train::nn::zoo::network;
use mls_train::util::bench::{bench, black_box, budget};

fn main() {
    let em = EnergyModel::fitted();
    let b = budget(Duration::from_secs(1));
    println!("# bench_energy — Table VI pipeline per network");
    for name in ["resnet18", "resnet34", "vgg16", "googlenet"] {
        let net = network(name).unwrap();
        bench(&format!("training_energy/{name}"), b, || {
            black_box(training_energy(&net, 64, Arithmetic::Mls(EmFormat::new(2, 4)), &em));
        });
    }
    bench("network_build/googlenet", b, || {
        black_box(network("googlenet").unwrap());
    });
}
