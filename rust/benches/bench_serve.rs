//! Bench: the served inference path — deterministic batched forward on
//! the quantize-once weight/panel cache ([`mls_train::serve`]). Measures
//! the two structural claims of the serving design: coalescing wins
//! (`batched_vs_single_throughput`: req/s of a batch-8 forward vs eight
//! batch-1 forwards) and quantize-once wins
//! (`cached_vs_requantize_latency`: a batch-1 forward with the weight
//! cache on vs re-quantizing + re-packing every call), plus served
//! request latency percentiles (p50/p99 of warm batch-1 forwards) and
//! req/s rows at offered batch sizes {1, 2, 8}. Steady-state heap
//! traffic per request is measured by a counting allocator and reported
//! (not gated: the worker pool's per-call overhead is thread-count
//! dependent). Writes `BENCH_serve.json`
//! (schema: `schemas/bench_serve.schema.json`, validated in CI); under
//! `MLS_BENCH_ENFORCE=1` both ratios gate the build.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use mls_train::data::{streams, DatasetConfig, SynthCifar};
use mls_train::serve::ServedModel;
use mls_train::util::bench::{bench, black_box, budget, enforce_mode, smoke_mode, BenchReport};
use mls_train::util::json::Json;
use mls_train::util::{parallel, stats};

/// [`System`] plus a byte counter (see `bench_train_step.rs`): measure,
/// don't claim, the steady-state allocation pressure of a served request.
struct Counting;

static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

const MODEL: &str = "cnn_t";
const CFG: &str = "e2m4_gnc_eg8mg1_sr";

fn main() {
    let threads = parallel::num_threads();
    let b = budget(Duration::from_secs(2));
    let batch_sizes = [1usize, 2, 8];

    let ds = SynthCifar::new(DatasetConfig::default());
    let (images, _) = ds.batch(*batch_sizes.iter().max().unwrap(), streams::TEST, 0);

    let mut served = ServedModel::fresh(MODEL, CFG, 0, threads).expect("cnn_t builds");
    let elems = served.input_elems();
    let mut logits = Vec::new();

    // warm every offered batch size: first touch quantizes + packs the
    // weights (once ever) and grows the arena size classes (once per
    // batch size); everything after is the steady state being measured
    for &n in &batch_sizes {
        served.infer_batch(&images[..n * elems], n, &mut logits);
    }
    let fwd_macs = served.last_audit().forward.mul_ops; // batch-8 probe
    println!(
        "# bench_serve — {MODEL} {CFG}, {fwd_macs} low-bit forward MACs per batch-8 request \
         wave, {threads} worker threads{}",
        if smoke_mode() { " [smoke]" } else { "" }
    );

    let mut report = BenchReport::new("BENCH_serve.json", "bench_serve");
    report.set("threads", Json::Num(threads as f64));
    report.set("model", Json::Str(MODEL.to_string()));
    report.set("cfg", Json::Str(CFG.to_string()));

    // steady-state allocation pressure of a warm batch-1 request
    let warm_reqs = 8u64;
    let bytes0 = BYTES.load(Ordering::Relaxed);
    for _ in 0..warm_reqs {
        served.infer_batch(&images[..elems], 1, &mut logits);
        black_box(logits.len());
    }
    let bytes_per_request = (BYTES.load(Ordering::Relaxed) - bytes0) as f64 / warm_reqs as f64;
    report.set("bytes_allocated_per_request", Json::Num(bytes_per_request));

    // offered-load rows: req/s at each coalesced batch size
    let mut medians = [0.0f64; 3];
    for (i, &n) in batch_sizes.iter().enumerate() {
        let r = bench(&format!("serve/{MODEL}_b{n}_t{threads}"), b, || {
            served.infer_batch(&images[..n * elems], n, &mut logits);
            black_box(logits.len());
        });
        println!(
            "  -> {:.1} req/s at batch {n} ({:.1} low-bit forward MMAC/s)",
            r.throughput_items(n as u64),
            r.throughput_items(fwd_macs * n as u64 / 8) / 1e6
        );
        medians[i] = r.median.as_secs_f64();
        report.add_result(&r, n as u64, "req");
    }
    let (t1, t8) = (medians[0], medians[2]);

    // served latency percentiles: per-request wall time of warm batch-1
    // forwards (the queue-empty service floor; bench() only reports
    // p10/p90, the serving SLO wants p50/p99)
    let lat_iters = if smoke_mode() { 60 } else { 2000 };
    let mut lat_s = Vec::with_capacity(lat_iters);
    for _ in 0..lat_iters {
        let t0 = Instant::now();
        served.infer_batch(&images[..elems], 1, &mut logits);
        black_box(logits.len());
        lat_s.push(t0.elapsed().as_secs_f64());
    }
    let p50_us = stats::quantile(&lat_s, 0.5) * 1e6;
    let p99_us = stats::quantile(&lat_s, 0.99) * 1e6;
    println!("  -> served batch-1 latency: p50 {p50_us:.1}us  p99 {p99_us:.1}us");
    report.set("p50_us", Json::Num(p50_us));
    report.set("p99_us", Json::Num(p99_us));

    // the quantize-once claim: same forward with the weight cache off
    // (every call re-quantizes weights and re-packs panels — what a
    // server without a persistent cache would pay per request)
    served.set_weight_cache(false);
    served.infer_batch(&images[..elems], 1, &mut logits); // warm the toggle
    let requant = bench(&format!("serve/{MODEL}_b1_requantize_t{threads}"), b, || {
        served.infer_batch(&images[..elems], 1, &mut logits);
        black_box(logits.len());
    });
    served.set_weight_cache(true);
    report.add_result(&requant, 1, "req");

    let batched_vs_single = (8.0 / t8) / (1.0 / t1);
    let cached_vs_requantize = requant.median.as_secs_f64() / t1;
    println!(
        "  -> batched_vs_single_throughput {batched_vs_single:.2}x, \
         cached_vs_requantize_latency {cached_vs_requantize:.2}x"
    );
    report.add_ratio("batched_vs_single_throughput", batched_vs_single);
    report.add_ratio("cached_vs_requantize_latency", cached_vs_requantize);

    // smoke iterations are few and noisy; the 0.9 floor avoids flaking
    // without a real regression — an actual regression reads well below
    let floor = if smoke_mode() { 0.9 } else { 1.0 };
    if enforce_mode() && batched_vs_single < floor {
        eprintln!(
            "PERF REGRESSION: batch-8 serving is {batched_vs_single:.3}x the throughput of \
             batch-1 serving (< {floor})"
        );
        std::process::exit(1);
    }
    if enforce_mode() && cached_vs_requantize < floor {
        eprintln!(
            "PERF REGRESSION: the quantize-once cache saves {cached_vs_requantize:.3}x vs \
             re-quantizing per request (< {floor})"
        );
        std::process::exit(1);
    }

    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_serve.json: {e}");
            std::process::exit(1);
        }
    }
}
