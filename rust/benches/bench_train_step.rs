//! Bench: one full native Alg. 1 training step — dynamic quantization of
//! W/A/E, quantized forward + weight-gradient + input-gradient convs on
//! the pass-generic packed-GEMM engine, BN/ReLU/FC/softmax/SGD in f32 —
//! on the `cnn_t` chain model and the `resnet_t` residual module-graph
//! model over synthetic-CIFAR batches. Reports steps/s and the low-bit
//! MMAC/s of the executed conv work (from each step's own audit
//! counters), serial vs pool-threaded, plus the step-arena path: measured
//! heap bytes per warm arena step (`bytes_allocated_per_step`, must be 0)
//! and the `arena_vs_alloc_step` speedup of the zero-alloc step over the
//! allocating step at 1 thread. Writes the trajectory to
//! `BENCH_train.json` (schema: `schemas/bench_train.schema.json`) and one
//! per-layer audit stream record of the resnet_t probe step to
//! `AUDIT_step.json` (schema: `schemas/audit_step.schema.json`, validated
//! in CI).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use mls_train::data::{streams, DatasetConfig, SynthCifar};
use mls_train::mls::quantizer::QuantConfig;
use mls_train::nn::train::native_model;
use mls_train::util::bench::{
    bench, black_box, budget, enforce_mode, repo_root, smoke_mode, BenchReport,
};
use mls_train::util::json::Json;
use mls_train::util::parallel;

/// [`System`] plus a byte counter, so this bench can MEASURE (not just
/// claim) the heap traffic of a warm arena step. Frees are uncounted:
/// the reported number is allocation pressure, not net growth.
struct Counting;

static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

fn main() {
    let threads = parallel::num_threads();
    let batch = 16usize;
    let b = budget(Duration::from_secs(2));

    let ds = SynthCifar::new(DatasetConfig::default());
    let (images, labels) = ds.batch(batch, streams::TRAIN, 0);

    // the executed low-bit conv MACs per step, from the audit counters of
    // a probe step (lr = 0 keeps the parameters fixed across timed
    // iterations, so every iteration does identical work)
    let mut model = native_model("cnn_t", QuantConfig::default(), 0).expect("cnn_t builds");
    let probe = model.train_step(&images, &labels, 0.0, 1);
    let audit = probe.audit;
    let macs = audit.forward.mul_ops + audit.wgrad.mul_ops + audit.dgrad.mul_ops;

    println!(
        "# bench_train_step — cnn_t, batch {batch}, {macs} executed low-bit MACs per step \
         (fwd+wgrad+dgrad), {threads} worker threads{}",
        if smoke_mode() { " [smoke]" } else { "" }
    );

    let mut report = BenchReport::new("BENCH_train.json", "bench_train_step");
    report.set("threads", Json::Num(threads as f64));
    report.set("batch", Json::Num(batch as f64));
    report.set("model", Json::Str("cnn_t".to_string()));
    report.set("macs_per_step", Json::Num(macs as f64));

    model.set_threads(1);
    let serial = bench("train_step/cnn_t_e2m4_b16_serial", b, || {
        black_box(model.train_step(&images, &labels, 0.0, 2));
    });
    println!(
        "  -> {:.2} steps/s, {:.1} low-bit MMAC/s (serial)",
        1.0 / serial.median.as_secs_f64(),
        serial.throughput_items(macs) / 1e6
    );
    report.add_result(&serial, macs, "mac");

    // the zero-alloc arena step, same model/batch/seed at 1 thread: first
    // measure the actual heap bytes of warm steps with the counting
    // allocator (the steady-state contract is exactly 0), then time it
    // against the allocating serial step above
    let mut arena = native_model("cnn_t", QuantConfig::default(), 0).expect("cnn_t builds");
    arena.set_threads(1);
    arena.enable_step_arena();
    arena.train_step_quiet(&images, &labels, 0.0, 2); // warm-up step
    let warm_steps = 4u64;
    let bytes0 = BYTES.load(Ordering::Relaxed);
    for _ in 0..warm_steps {
        black_box(arena.train_step_quiet(&images, &labels, 0.0, 2));
    }
    let bytes_per_step = (BYTES.load(Ordering::Relaxed) - bytes0) as f64 / warm_steps as f64;
    report.set("bytes_allocated_per_step", Json::Num(bytes_per_step));

    let arena_r = bench("train_step/cnn_t_e2m4_b16_arena_serial", b, || {
        black_box(arena.train_step_quiet(&images, &labels, 0.0, 2));
    });
    let arena_vs_alloc = serial.median.as_secs_f64() / arena_r.median.as_secs_f64();
    println!(
        "  -> {:.2} steps/s, {bytes_per_step:.0} bytes allocated per warm step \
         ({arena_vs_alloc:.2}x vs allocating serial step, bit-identical)",
        1.0 / arena_r.median.as_secs_f64(),
    );
    report.add_result(&arena_r, macs, "mac");
    report.add_ratio("arena_vs_alloc_step", arena_vs_alloc);

    // deterministic gate: a warm arena step may not touch the heap at all
    if enforce_mode() && bytes_per_step != 0.0 {
        eprintln!("ALLOC REGRESSION: warm arena step allocates {bytes_per_step:.0} bytes (!= 0)");
        std::process::exit(1);
    }
    // smoke iterations are few and noisy; the 0.9 floor avoids flaking
    // without a real regression — an actual regression reads well below
    let floor = if smoke_mode() { 0.9 } else { 1.0 };
    if enforce_mode() && arena_vs_alloc < floor {
        eprintln!(
            "PERF REGRESSION: arena step is {arena_vs_alloc:.3}x the allocating step at 1 \
             thread (< {floor})"
        );
        std::process::exit(1);
    }

    model.set_threads(threads);
    let par = bench(&format!("train_step/cnn_t_e2m4_b16_t{threads}"), b, || {
        black_box(model.train_step(&images, &labels, 0.0, 2));
    });
    let threaded_vs_serial = serial.median.as_secs_f64() / par.median.as_secs_f64();
    println!(
        "  -> {:.2} steps/s, {:.1} low-bit MMAC/s ({threaded_vs_serial:.2}x vs serial, bit-identical)",
        1.0 / par.median.as_secs_f64(),
        par.throughput_items(macs) / 1e6
    );
    report.add_result(&par, macs, "mac");
    report.add_ratio("train_threaded_vs_serial", threaded_vs_serial);

    // fp32 reference step (f32 convs end to end) — the software-simulator
    // cost baseline the quantized step is compared against. Its MMAC/s is
    // reported against the model-derived analytic f32 conv MAC count
    // (full windows; fwd + wgrad per layer, + dgrad for non-first
    // layers) — NOT the quantized probe's low-bit count, which this step
    // never executes.
    let mut fp32 = native_model("cnn_t", QuantConfig::fp32(), 0).expect("cnn_t builds");
    let f32_macs = batch as u64 * fp32.conv_macs_per_sample();
    fp32.set_threads(threads);
    let fp = bench(&format!("train_step/cnn_t_fp32_b16_t{threads}"), b, || {
        black_box(fp32.train_step(&images, &labels, 0.0, 2));
    });
    println!(
        "  -> {:.2} steps/s, {:.1} f32 MMAC/s (fp32 reference step)",
        1.0 / fp.median.as_secs_f64(),
        fp.throughput_items(f32_macs) / 1e6
    );
    report.add_result(&fp, f32_macs, "mac");
    report.add_ratio(
        "quantized_vs_fp32_step",
        fp.median.as_secs_f64() / par.median.as_secs_f64(),
    );

    // the residual module-graph model: a full quantized resnet_t step
    // (skip-add joins, 1x1 projection shortcuts — 8 quantized convs x 3
    // passes), plus one per-layer audit stream record for CI validation
    let rbatch = 8usize;
    let (rimages, rlabels) = ds.batch(rbatch, streams::TRAIN, 1);
    let qd = QuantConfig::default();
    let mut resnet = native_model("resnet_t", qd, 0).expect("resnet_t builds");
    let rprobe = resnet.train_step(&rimages, &rlabels, 0.0, 1);
    let raudit = rprobe.audit;
    let rmacs = raudit.forward.mul_ops + raudit.wgrad.mul_ops + raudit.dgrad.mul_ops;
    report.set("resnet_t_macs_per_step", Json::Num(rmacs as f64));

    let audit_path = repo_root().join("AUDIT_step.json");
    let audit_json = raudit.to_json("resnet_t", &qd.name(), rbatch, 0);
    if let Err(e) = std::fs::write(&audit_path, audit_json.to_string_pretty() + "\n") {
        eprintln!("failed to write AUDIT_step.json: {e}");
        std::process::exit(1);
    }
    println!(
        "wrote {} ({} per-layer records, {} executed low-bit MACs per resnet_t step)",
        audit_path.display(),
        raudit.layers.len(),
        rmacs
    );

    resnet.set_threads(threads);
    let rpar = bench(&format!("train_step/resnet_t_e2m4_b8_t{threads}"), b, || {
        black_box(resnet.train_step(&rimages, &rlabels, 0.0, 2));
    });
    println!(
        "  -> {:.2} steps/s, {:.1} low-bit MMAC/s (resnet_t, residual graph)",
        1.0 / rpar.median.as_secs_f64(),
        rpar.throughput_items(rmacs) / 1e6
    );
    report.add_result(&rpar, rmacs, "mac");
    // per-SAMPLE cost ratio: the two rows run different batch sizes
    // (resnet_t b8 vs cnn_t b16), so normalize before dividing
    report.add_ratio(
        "resnet_t_vs_cnn_t_step",
        (rpar.median.as_secs_f64() / rbatch as f64) / (par.median.as_secs_f64() / batch as f64),
    );

    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_train.json: {e}");
            std::process::exit(1);
        }
    }
}
