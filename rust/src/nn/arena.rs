//! Step-owned memory for the zero-alloc steady-state training step.
//!
//! [`StepArena`] owns every buffer the train-step loop needs: an
//! exact-size-class pool for the transient `f32`/`bool` tensors, the
//! graph value/gradient slot tables, and one [`ConvSlots`] per node
//! holding the persistent quantized operands (decoded element planes,
//! group scales, packed weight panels) of the low-bit convolutions.
//!
//! The lifecycle is warm-up-on-first-step: step 1 runs with an empty
//! pool and allocates each buffer once (a take that misses falls back
//! to the heap); every buffer is recycled by the end of the step, so
//! step 2 onward replays the identical take/recycle sequence entirely
//! from the pool. After [`StepArena::end_step`] flips the pool into
//! strict mode, a pool miss is a bug — the step shape changed — and
//! panics with the dispatch label of the offending section instead of
//! silently re-allocating.
//!
//! [`StepMem`] is how the executor sees all this: `Heap` preserves the
//! historical allocate-and-drop behavior bit-for-bit (it is the
//! bit-identity anchor), `Arena` routes the same requests through the
//! pool. Both variants hand out zero-filled buffers, so the executor
//! code is identical under either.

use crate::arith::pack::PackedWeights;
use crate::arith::planes::DecodedPlanes;
use crate::mls::quantizer::FusedQuant;
use crate::mls::EmFormat;
use crate::nn::graph::{Feat, Graph, Op};
use crate::util::parallel;

/// Exact-size-class free lists for one element type. `classes` is kept
/// sorted by buffer length so take/recycle are a binary search plus a
/// push/pop — no allocation once every class seen in the warm-up step
/// has been registered.
struct SizeClasses<T> {
    classes: Vec<(usize, Vec<Vec<T>>)>,
}

impl<T: Copy> SizeClasses<T> {
    fn new() -> Self {
        SizeClasses {
            classes: Vec::new(),
        }
    }

    /// Pop a pooled buffer of exactly `len` elements, reset to `zero`.
    /// A miss allocates fresh — unless `strict`, where it panics: after
    /// warm-up every take must hit the pool.
    fn take(&mut self, len: usize, zero: T, strict: bool, kind: &str) -> Vec<T> {
        if len == 0 {
            return Vec::new();
        }
        if let Ok(i) = self.classes.binary_search_by_key(&len, |c| c.0) {
            if let Some(mut v) = self.classes[i].1.pop() {
                v.fill(zero);
                return v;
            }
        }
        if strict {
            strict_miss(kind, len);
        }
        vec![zero; len]
    }

    /// Return a buffer to its size class (registered on first sight).
    fn recycle(&mut self, v: Vec<T>) {
        if v.is_empty() {
            return;
        }
        match self.classes.binary_search_by_key(&v.len(), |c| c.0) {
            Ok(i) => self.classes[i].1.push(v),
            Err(i) => self.classes.insert(i, (v.len(), vec![v])),
        }
    }
}

#[cold]
#[inline(never)]
fn strict_miss(kind: &str, len: usize) -> ! {
    let site = parallel::current_label().unwrap_or_else(|| "unlabeled step section".to_string());
    panic!(
        "step arena: no pooled {kind} buffer of {len} elements in strict (warm) mode at `{site}`; \
         after the warm-up step the step shape must stay fixed (same batch size, model, \
         quantization config, and thread count)"
    );
}

/// The transient-buffer pool of a [`StepArena`].
struct BufPool {
    f32s: SizeClasses<f32>,
    bools: SizeClasses<bool>,
    /// set after the warm-up step: a pool miss becomes a panic
    strict: bool,
}

impl BufPool {
    fn new() -> Self {
        BufPool {
            f32s: SizeClasses::new(),
            bools: SizeClasses::new(),
            strict: false,
        }
    }

    fn take_f32(&mut self, len: usize) -> Vec<f32> {
        self.f32s.take(len, 0.0, self.strict, "f32")
    }

    fn recycle_f32(&mut self, v: Vec<f32>) {
        self.f32s.recycle(v);
    }

    fn take_bool(&mut self, len: usize) -> Vec<bool> {
        self.bools.take(len, false, self.strict, "bool")
    }

    fn recycle_bool(&mut self, v: Vec<bool>) {
        self.bools.recycle(v);
    }
}

/// One conv's quantize-once weight cache: the quantized weight planes
/// plus their packed forward panels, the pair every forward execution
/// of the conv consumes. The trainer refreshes it once per step (the
/// parameter update invalidates the *contents*, never the capacity);
/// the inference server freezes it once per model
/// ([`StepArena::freeze_weights`]) and replays it for every request,
/// so the steady-state serve path never touches the quantizer.
pub(crate) struct WeightPanels {
    /// quantized weights (decoded planes + group scales)
    pub(crate) qw: FusedQuant,
    /// packed stationary panels of the forward pass
    pub(crate) pw: PackedWeights,
    /// contents are valid for the current parameters (set after the
    /// first quantize+pack; only consulted when the arena is frozen)
    pub(crate) ready: bool,
}

impl Default for WeightPanels {
    fn default() -> Self {
        WeightPanels {
            qw: FusedQuant::new(),
            pw: PackedWeights::default(),
            ready: false,
        }
    }
}

/// Persistent per-node quantized-conv storage: the step-`i` quantized
/// operands of one low-bit convolution, plus the transposed plane /
/// group-scale relayouts and packed panels its backward passes need.
/// Everything is grow-only `Vec` scratch inside, so after the warm-up
/// step refilling these allocates nothing.
pub(crate) struct ConvSlots {
    /// quantized weights + packed forward panels (refilled once per
    /// step when training, frozen across requests when serving; dgrad
    /// relayouts read the same `wp.qw` planes)
    pub(crate) wp: WeightPanels,
    /// quantized activations
    pub(crate) qa: FusedQuant,
    /// quantized output error
    pub(crate) qe: FusedQuant,
    /// `qw` relayout for dgrad: transpose01 + kernel flip of the planes
    pub(crate) wt_planes: DecodedPlanes,
    pub(crate) wt_sg_exp: Vec<u8>,
    pub(crate) wt_sg_man: Vec<u32>,
    /// `qe` relayout for wgrad (the stationary operand)
    pub(crate) et_planes: DecodedPlanes,
    pub(crate) et_sg_exp: Vec<u8>,
    pub(crate) et_sg_man: Vec<u32>,
    /// `qa` relayout for wgrad (the gathered operand)
    pub(crate) at_planes: DecodedPlanes,
    pub(crate) at_sg_exp: Vec<u8>,
    pub(crate) at_sg_man: Vec<u32>,
    /// packed stationary panels of the backward passes
    pub(crate) pw_wgrad: PackedWeights,
    pub(crate) pw_dgrad: PackedWeights,
    /// pre-built dispatch labels so the warm loop never formats
    pub(crate) label_fwd: String,
    pub(crate) label_wgrad: String,
    pub(crate) label_dgrad: String,
}

fn empty_planes() -> DecodedPlanes {
    DecodedPlanes {
        signed_frac: Vec::new(),
        shift: Vec::new(),
        scaled_frac: Vec::new(),
        fmt: EmFormat::new(0, 0),
    }
}

impl Default for ConvSlots {
    fn default() -> Self {
        ConvSlots {
            wp: WeightPanels::default(),
            qa: FusedQuant::new(),
            qe: FusedQuant::new(),
            wt_planes: empty_planes(),
            wt_sg_exp: Vec::new(),
            wt_sg_man: Vec::new(),
            et_planes: empty_planes(),
            et_sg_exp: Vec::new(),
            et_sg_man: Vec::new(),
            at_planes: empty_planes(),
            at_sg_exp: Vec::new(),
            at_sg_man: Vec::new(),
            pw_wgrad: PackedWeights::default(),
            pw_dgrad: PackedWeights::default(),
            label_fwd: String::new(),
            label_wgrad: String::new(),
            label_dgrad: String::new(),
        }
    }
}

/// forward / wgrad / dgrad pass indices for [`StepArena::conv_label`].
pub(crate) const PASS_FORWARD: usize = 0;
pub(crate) const PASS_WGRAD: usize = 1;
pub(crate) const PASS_DGRAD: usize = 2;

/// Every buffer one training step needs, owned across steps.
pub struct StepArena {
    pool: BufPool,
    /// one slot per graph node (non-conv nodes keep an empty default)
    pub(crate) convs: Vec<ConvSlots>,
    /// graph value slots + remaining-use counts (executor forward)
    pub(crate) vals: Vec<Option<Feat>>,
    pub(crate) uses: Vec<usize>,
    /// gradient slots (executor backward)
    pub(crate) gslots: Vec<Option<Vec<f32>>>,
    /// stochastic-rounding offset scratch, shared by every quantize
    pub(crate) offsets: Vec<f32>,
    /// forward-only serving mode: the per-conv [`WeightPanels`] are
    /// quantized+packed on first use and then replayed verbatim
    pub(crate) weights_frozen: bool,
}

impl StepArena {
    /// Size the per-node storage (and pre-format the dispatch labels)
    /// from the lowered graph. Transient buffers are warm-up-sized: the
    /// first step through the executor allocates them, later steps
    /// replay the same take/recycle sequence from the pool.
    pub fn for_graph(g: &Graph) -> StepArena {
        let convs = g
            .nodes
            .iter()
            .map(|node| {
                let mut cs = ConvSlots::default();
                if matches!(node.op, Op::Conv(_)) {
                    cs.label_fwd = format!("{}:forward", node.name);
                    cs.label_wgrad = format!("{}:wgrad", node.name);
                    cs.label_dgrad = format!("{}:dgrad", node.name);
                }
                cs
            })
            .collect();
        StepArena {
            pool: BufPool::new(),
            convs,
            vals: Vec::new(),
            uses: Vec::new(),
            gslots: Vec::new(),
            offsets: Vec::new(),
            weights_frozen: false,
        }
    }

    /// Mark warm-up done: from here on a pool miss panics instead of
    /// allocating. Idempotent; call at the end of every step.
    pub fn end_step(&mut self) {
        self.pool.strict = true;
    }

    /// Switch the arena into quantize-once serving mode: every conv's
    /// [`WeightPanels`] is filled on its first forward and then reused
    /// verbatim by all later forwards. Only valid for eval-style
    /// forwards (no RNG — the deterministic rounding path consumes no
    /// offsets, so skipping the weight quantize is bit-neutral) while
    /// the parameters stay fixed; the executor keeps requantizing when
    /// an RNG is present, so a frozen arena fed into a training step
    /// degrades safely instead of reusing stale stochastic planes.
    /// The pool is deliberately left non-strict: serving coalesces
    /// variable batch sizes, and each new size class simply warms up
    /// on first sight.
    pub fn freeze_weights(&mut self) {
        self.weights_frozen = true;
    }

    /// The pre-formatted dispatch label of conv node `node`, pass
    /// [`PASS_FORWARD`]/[`PASS_WGRAD`]/[`PASS_DGRAD`].
    pub(crate) fn conv_label(&self, node: usize, pass: usize) -> &str {
        let cs = &self.convs[node];
        match pass {
            PASS_FORWARD => &cs.label_fwd,
            PASS_WGRAD => &cs.label_wgrad,
            _ => &cs.label_dgrad,
        }
    }
}

/// How the executor obtains and releases step-transient buffers.
///
/// `Heap` reproduces the historical behavior exactly: takes are fresh
/// zeroed allocations, recycles are drops. `Arena` serves the same
/// requests from a [`StepArena`]. Values are identical either way —
/// only the allocation behavior differs.
pub enum StepMem<'a> {
    Heap,
    Arena(&'a mut StepArena),
}

impl StepMem<'_> {
    pub(crate) fn is_arena(&self) -> bool {
        matches!(self, StepMem::Arena(_))
    }

    /// Whether the backing arena is in quantize-once serving mode
    /// (see [`StepArena::freeze_weights`]). Heap mode never is.
    pub(crate) fn weights_frozen(&self) -> bool {
        match self {
            StepMem::Heap => false,
            StepMem::Arena(a) => a.weights_frozen,
        }
    }

    /// A zero-filled `f32` buffer of exactly `len` elements.
    pub(crate) fn take_f32(&mut self, len: usize) -> Vec<f32> {
        match self {
            StepMem::Heap => vec![0.0; len],
            StepMem::Arena(a) => a.pool.take_f32(len),
        }
    }

    pub(crate) fn recycle_f32(&mut self, v: Vec<f32>) {
        match self {
            StepMem::Heap => drop(v),
            StepMem::Arena(a) => a.pool.recycle_f32(v),
        }
    }

    /// A `false`-filled `bool` buffer of exactly `len` elements.
    pub(crate) fn take_bool(&mut self, len: usize) -> Vec<bool> {
        match self {
            StepMem::Heap => vec![false; len],
            StepMem::Arena(a) => a.pool.take_bool(len),
        }
    }

    pub(crate) fn recycle_bool(&mut self, v: Vec<bool>) {
        match self {
            StepMem::Heap => drop(v),
            StepMem::Arena(a) => a.pool.recycle_bool(v),
        }
    }

    /// The forward value-slot tables: `n_vals` empty slots plus zeroed
    /// use counts. Arena mode reuses the persistent tables.
    pub(crate) fn take_graph_slots(&mut self, n_vals: usize) -> (Vec<Option<Feat>>, Vec<usize>) {
        match self {
            StepMem::Heap => (vec![None; n_vals], vec![0; n_vals]),
            StepMem::Arena(a) => {
                let mut vals = std::mem::take(&mut a.vals);
                vals.clear();
                vals.resize_with(n_vals, || None);
                let mut uses = std::mem::take(&mut a.uses);
                uses.clear();
                uses.resize(n_vals, 0);
                (vals, uses)
            }
        }
    }

    /// Return the value-slot tables, sweeping any residual features
    /// (e.g. values an eval-style walk never consumed) into the pool.
    pub(crate) fn put_graph_slots(&mut self, mut vals: Vec<Option<Feat>>, uses: Vec<usize>) {
        match self {
            StepMem::Heap => {}
            StepMem::Arena(a) => {
                for slot in vals.iter_mut() {
                    if let Some(f) = slot.take() {
                        a.pool.recycle_f32(f.data);
                    }
                }
                a.vals = vals;
                a.uses = uses;
            }
        }
    }

    /// The backward gradient-slot table: `n_vals` empty slots.
    pub(crate) fn take_grad_slots(&mut self, n_vals: usize) -> Vec<Option<Vec<f32>>> {
        match self {
            StepMem::Heap => vec![None; n_vals],
            StepMem::Arena(a) => {
                let mut g = std::mem::take(&mut a.gslots);
                g.clear();
                g.resize_with(n_vals, || None);
                g
            }
        }
    }

    /// Return the gradient-slot table, recycling residual gradients
    /// (the input slot's gradient is never consumed).
    pub(crate) fn put_grad_slots(&mut self, mut gslots: Vec<Option<Vec<f32>>>) {
        match self {
            StepMem::Heap => {}
            StepMem::Arena(a) => {
                for slot in gslots.iter_mut() {
                    if let Some(v) = slot.take() {
                        a.pool.recycle_f32(v);
                    }
                }
                a.gslots = gslots;
            }
        }
    }

    /// Detach node `i`'s conv storage for the duration of one pass
    /// (the executor needs it and the pool borrowed simultaneously).
    /// Arena-only: the heap path keeps per-step quantized tensors.
    pub(crate) fn take_conv_slots(&mut self, i: usize) -> ConvSlots {
        match self {
            StepMem::Heap => unreachable!("conv slots are arena-only"),
            StepMem::Arena(a) => std::mem::take(&mut a.convs[i]),
        }
    }

    pub(crate) fn put_conv_slots(&mut self, i: usize, cs: ConvSlots) {
        match self {
            StepMem::Heap => unreachable!("conv slots are arena-only"),
            StepMem::Arena(a) => a.convs[i] = cs,
        }
    }

    /// The shared stochastic-rounding offset scratch.
    pub(crate) fn take_offsets(&mut self) -> Vec<f32> {
        match self {
            StepMem::Heap => Vec::new(),
            StepMem::Arena(a) => std::mem::take(&mut a.offsets),
        }
    }

    pub(crate) fn put_offsets(&mut self, off: Vec<f32>) {
        match self {
            StepMem::Heap => {}
            StepMem::Arena(a) => a.offsets = off,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycle_then_take_reuses_the_buffer_zeroed() {
        let mut p = BufPool::new();
        let mut v = p.take_f32(16);
        v.iter_mut().for_each(|x| *x = 3.5);
        let ptr = v.as_ptr();
        p.recycle_f32(v);
        let w = p.take_f32(16);
        assert_eq!(w.as_ptr(), ptr, "same-size take must reuse the pooled buffer");
        assert!(w.iter().all(|&x| x == 0.0), "pooled buffers are handed out zeroed");
    }

    #[test]
    fn non_strict_miss_allocates_fresh() {
        let mut p = BufPool::new();
        let v = p.take_f32(8);
        p.recycle_f32(v);
        let w = p.take_f32(24); // unseen size class
        assert_eq!(w.len(), 24);
        assert!(w.iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "strict (warm) mode")]
    fn strict_miss_panics() {
        let mut p = BufPool::new();
        p.strict = true;
        let _ = p.take_f32(8);
    }

    #[test]
    #[should_panic(expected = "conv3:wgrad")]
    fn strict_miss_names_the_dispatch_label() {
        let mut p = BufPool::new();
        p.strict = true;
        parallel::with_label("conv3:wgrad", || {
            let _ = p.take_f32(8);
        });
    }

    #[test]
    fn bool_pool_round_trips() {
        let mut p = BufPool::new();
        let mut v = p.take_bool(5);
        v[3] = true;
        let ptr = v.as_ptr();
        p.recycle_bool(v);
        let w = p.take_bool(5);
        assert_eq!(w.as_ptr(), ptr);
        assert!(w.iter().all(|&x| !x));
    }

    #[test]
    fn zero_len_takes_are_free() {
        let mut p = BufPool::new();
        p.strict = true; // a zero-length take never consults the pool
        assert!(p.take_f32(0).is_empty());
        p.recycle_f32(Vec::new());
    }

    #[test]
    fn size_classes_stay_sorted_and_exact() {
        let mut p = BufPool::new();
        for len in [32usize, 8, 16, 8] {
            let v = p.take_f32(len);
            p.recycle_f32(v);
        }
        assert!(p.f32s.classes.windows(2).all(|w| w[0].0 < w[1].0));
        // an exact-size take drains only its own class
        let _ = p.take_f32(16);
        let sizes: Vec<usize> = p.f32s.classes.iter().map(|c| c.0).collect();
        assert_eq!(sizes, vec![8, 16, 32]);
        assert!(p.f32s.classes[1].1.is_empty());
    }
}
