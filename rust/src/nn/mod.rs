//! Model-shape zoo and analytic op counting.
//!
//! Holds the exact layer geometry of every CNN the paper evaluates
//! (ResNet-18/34 and VGG-16 / GoogleNet on ImageNet, ResNet-20 on
//! CIFAR-10) plus the scaled trainable models of this reproduction. The
//! counts drive Table I, Table III (GOPs) and the Table VI energy rows —
//! they are analytic in layer shapes, so these tables reproduce exactly.

pub mod ops;
pub mod zoo;

pub use ops::{count_training_ops, TrainingOps};
pub use zoo::{network, Layer, Network, NETWORKS};
