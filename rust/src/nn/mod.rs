//! Model-shape zoo, analytic op counting, and the native Alg. 1 trainer.
//!
//! [`zoo`] holds the exact layer geometry of every CNN the paper
//! evaluates (ResNet-18/34 and VGG-16 / GoogleNet on ImageNet, ResNet-20
//! on CIFAR-10) plus the scaled trainable models of this reproduction;
//! [`ops`] turns a zoo network into analytic per-step op counts. The
//! counts drive Table I, Table III (GOPs) and the Table VI energy rows —
//! they are analytic in layer shapes, so these tables reproduce exactly.
//! [`graph`] is the composable module-graph IR the native trainer
//! executes (nodes over explicit values, residual `Add` joins, a
//! trainer-owned activation [`graph::Tape`], per-layer audit stream);
//! every native model lowers its zoo twin ([`zoo::native_network`]) into
//! such a graph. [`optim`] provides the pluggable parameter-update rules
//! (plain SGD, momentum SGD), each serializable for step checkpoints.
//! [`health`] is the per-step numeric guard (NaN/Inf, scale saturation,
//! loss-divergence windows) behind the trainer's `on_divergence`
//! recovery policies. [`train`] ties them together as the native
//! low-bit training step: per-layer Alg. 1 forward/backward on real MLS
//! tensors through the pass-generic conv engine, whose executed audit
//! counters cross-check the analytic model.

pub mod arena;
pub mod graph;
pub mod health;
pub mod ops;
pub mod optim;
pub mod train;
pub mod zoo;

pub use arena::{StepArena, StepMem};
pub use graph::{Graph, LayerAudit, PassCounters, StepAudit, Tape};
pub use health::{DivergencePolicy, GradStats, HealthMonitor, HealthRecord};
pub use ops::{count_training_ops, TrainingOps};
pub use optim::{parse_optimizer, Optimizer};
pub use train::{native_model, NativeModel, NativeStepOutput};
pub use zoo::{network, Layer, Network, NETWORKS};
