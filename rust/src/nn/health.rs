//! Per-step numeric health guard for the native trainer.
//!
//! Low-bit training dies in characteristic ways (DoReFa-Net; Ortiz et
//! al.): a stochastic-rounded gradient goes NaN/Inf, tensor magnitudes
//! blow past what the Alg. 2 tensor-max normalization can represent, or
//! the loss diverges smoothly over a window of steps. The
//! [`HealthMonitor`] inspects each step's loss and gradient statistics
//! BEFORE the optimizer update, and the trainer reacts per the
//! `on_divergence` policy ([`DivergencePolicy`]): abort the run, roll
//! back to the last good checkpoint, or roll back AND halve the
//! learning rate. Every verdict is emitted as a machine-readable
//! [`HealthRecord`] line into the run's `<tag>.audit.jsonl` stream
//! (`{"audit": "health", ...}`, discriminated from the per-layer
//! `"train_step"` records by the `audit` tag —
//! `schemas/audit_step.schema.json` covers both).
//!
//! Healthy steps emit nothing, so fault-free runs keep byte-identical
//! audit streams to the pre-health trainer.

use crate::util::json::Json;

/// Recovery policies `TrainConfig.on_divergence` accepts.
pub const POLICIES: &[&str] = &["abort", "rollback", "halve_lr"];

/// Ceiling on rollback recoveries per run: a fault the rollback cannot
/// clear (e.g. deterministic divergence that replays identically) must
/// terminate instead of looping forever.
pub const MAX_ROLLBACKS: u64 = 8;

/// Gradient magnitude above which the group-scale pipeline is considered
/// saturated. MLS group scales are ratios `S_r / S_t ∈ [0, 1]`
/// normalized by the f32 tensor max (Alg. 2), so the failure mode is not
/// a stored scale code overflowing but the tensor max itself nearing the
/// f32 exponent ceiling, where `x / S_t` and the downstream shift-add
/// arithmetic lose exactness. 2^120 leaves 7 doublings of headroom below
/// f32::MAX.
pub fn scale_sat_limit() -> f32 {
    crate::mls::format::exp2i(120)
}

/// What the trainer does when the monitor returns a verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DivergencePolicy {
    /// stop the run, mark it diverged (the pre-PR-8 behavior)
    Abort,
    /// restore the last good checkpoint and replay from there
    Rollback,
    /// rollback + halve the learning-rate scale for the rest of the run
    HalveLr,
}

impl DivergencePolicy {
    /// Every supported policy; [`Self::parse`] scans this list so the
    /// parseable set cannot drift from the `name()` outputs (and
    /// [`POLICIES`] is pinned against it in the tests below).
    pub const ALL: [DivergencePolicy; 3] =
        [DivergencePolicy::Abort, DivergencePolicy::Rollback, DivergencePolicy::HalveLr];

    pub fn name(&self) -> &'static str {
        match self {
            DivergencePolicy::Abort => "abort",
            DivergencePolicy::Rollback => "rollback",
            DivergencePolicy::HalveLr => "halve_lr",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<DivergencePolicy> {
        Self::ALL.into_iter().find(|p| p.name() == s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown on_divergence policy {s:?} (have {:?})",
                Self::ALL.map(|p| p.name())
            )
        })
    }
}

/// Cheap whole-gradient statistics, computed once per step.
#[derive(Clone, Copy, Debug, Default)]
pub struct GradStats {
    /// number of NaN/Inf entries
    pub nonfinite: u64,
    /// max |g| over the finite entries (0 for an all-nonfinite gradient)
    pub max_abs: f32,
}

/// Scan a flat gradient vector (layout: `Graph::state`).
pub fn grad_stats(grads: &[f32]) -> GradStats {
    let mut s = GradStats::default();
    for &g in grads {
        if g.is_finite() {
            s.max_abs = s.max_abs.max(g.abs());
        } else {
            s.nonfinite += 1;
        }
    }
    s
}

/// What went wrong on a step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// the training loss itself is NaN/Inf
    NonFiniteLoss,
    /// NaN/Inf entries in the gradient
    NanGrad,
    /// finite but saturated gradient magnitude (see [`scale_sat_limit`])
    ScaleOverflow,
    /// loss exceeded `divergence_factor` x best-so-far for
    /// `divergence_window` consecutive steps
    LossDivergence,
}

impl Verdict {
    pub fn name(&self) -> &'static str {
        match self {
            Verdict::NonFiniteLoss => "non_finite_loss",
            Verdict::NanGrad => "nan_grad",
            Verdict::ScaleOverflow => "scale_overflow",
            Verdict::LossDivergence => "loss_divergence",
        }
    }
}

/// The per-run monitor. Its whole mutable state is `(best_loss, streak)`
/// — both ride inside the checkpoint, so a resumed run reaches every
/// verdict on the same step as an uninterrupted one.
#[derive(Clone, Copy, Debug)]
pub struct HealthMonitor {
    /// consecutive blow-up steps before [`Verdict::LossDivergence`]
    /// (0 disables the window check)
    window: u64,
    /// a step counts as a blow-up when `loss > factor * best_loss`
    factor: f32,
    best_loss: f32,
    streak: u64,
}

impl HealthMonitor {
    pub fn new(window: u64, factor: f32) -> HealthMonitor {
        HealthMonitor { window, factor, best_loss: f32::INFINITY, streak: 0 }
    }

    /// `(best_loss, streak)` for checkpointing.
    pub fn state(&self) -> (f32, u64) {
        (self.best_loss, self.streak)
    }

    /// Restore a checkpointed `(best_loss, streak)`.
    pub fn restore(&mut self, best_loss: f32, streak: u64) {
        self.best_loss = best_loss;
        self.streak = streak;
    }

    /// Judge one step (pre-update). Returns the first verdict that
    /// applies, in severity order; `None` means healthy.
    pub fn check(&mut self, loss: f32, grads: &GradStats) -> Option<Verdict> {
        if !loss.is_finite() {
            return Some(Verdict::NonFiniteLoss);
        }
        if grads.nonfinite > 0 {
            return Some(Verdict::NanGrad);
        }
        if grads.max_abs > scale_sat_limit() {
            return Some(Verdict::ScaleOverflow);
        }
        if self.window > 0 {
            // best_loss starts at +inf, so the first finite loss can
            // never count as a blow-up
            if loss > self.factor * self.best_loss {
                self.streak += 1;
                if self.streak >= self.window {
                    return Some(Verdict::LossDivergence);
                }
            } else {
                self.streak = 0;
            }
        }
        self.best_loss = self.best_loss.min(loss);
        None
    }
}

/// One machine-readable health event in the audit stream.
#[derive(Clone, Debug)]
pub struct HealthRecord {
    pub step: u64,
    pub verdict: Verdict,
    /// the policy action taken: "abort" | "rollback" | "halve_lr"
    pub action: &'static str,
    pub loss: f32,
    pub grad_nonfinite: u64,
    pub grad_max_abs: f32,
    /// blow-up streak length at the verdict (window check only)
    pub streak: u64,
    /// step the run rolled back to (rollback/halve_lr actions)
    pub rollback_to: Option<u64>,
    /// learning-rate scale in effect AFTER the action
    pub lr_scale: f32,
}

fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

impl HealthRecord {
    /// The `{"audit": "health", ...}` stream line
    /// (`schemas/audit_step.schema.json`, health branch). Non-finite
    /// numbers render as `null` — JSON has no NaN/Inf.
    pub fn to_json(&self, model: &str, cfg: &str) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("audit".to_string(), Json::Str("health".to_string()));
        m.insert("model".to_string(), Json::Str(model.to_string()));
        m.insert("cfg".to_string(), Json::Str(cfg.to_string()));
        m.insert("step".to_string(), Json::Num(self.step as f64));
        m.insert("verdict".to_string(), Json::Str(self.verdict.name().to_string()));
        m.insert("action".to_string(), Json::Str(self.action.to_string()));
        m.insert("loss".to_string(), num_or_null(self.loss as f64));
        m.insert("grad_nonfinite".to_string(), Json::Num(self.grad_nonfinite as f64));
        m.insert("grad_max_abs".to_string(), num_or_null(self.grad_max_abs as f64));
        m.insert("streak".to_string(), Json::Num(self.streak as f64));
        m.insert(
            "rollback_to".to_string(),
            match self.rollback_to {
                Some(s) => Json::Num(s as f64),
                None => Json::Null,
            },
        );
        m.insert("lr_scale".to_string(), Json::Num(self.lr_scale as f64));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_registry_round_trips_and_matches_listing() {
        for p in DivergencePolicy::ALL {
            assert_eq!(DivergencePolicy::parse(p.name()).unwrap(), p);
        }
        let names: Vec<&str> = DivergencePolicy::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(names, POLICIES, "POLICIES listing must match the enum");
        let msg = format!("{:#}", DivergencePolicy::parse("explode").unwrap_err());
        for p in POLICIES {
            assert!(msg.contains(p), "{msg}");
        }
    }

    #[test]
    fn grad_stats_counts_and_maxes() {
        let s = grad_stats(&[0.5, -2.0, f32::NAN, f32::INFINITY, 1.0]);
        assert_eq!(s.nonfinite, 2);
        assert_eq!(s.max_abs, 2.0);
        let z = grad_stats(&[]);
        assert_eq!((z.nonfinite, z.max_abs), (0, 0.0));
    }

    #[test]
    fn verdict_priority_and_thresholds() {
        let mut m = HealthMonitor::new(0, 10.0);
        let clean = GradStats { nonfinite: 0, max_abs: 1.0 };
        assert_eq!(m.check(1.0, &clean), None);
        assert_eq!(m.check(f32::NAN, &clean), Some(Verdict::NonFiniteLoss));
        assert_eq!(
            m.check(f32::NAN, &GradStats { nonfinite: 3, max_abs: 0.0 }),
            Some(Verdict::NonFiniteLoss),
            "loss verdict outranks grad verdict"
        );
        assert_eq!(
            m.check(1.0, &GradStats { nonfinite: 3, max_abs: 0.0 }),
            Some(Verdict::NanGrad)
        );
        let sat = GradStats { nonfinite: 0, max_abs: f32::MAX };
        assert_eq!(m.check(1.0, &sat), Some(Verdict::ScaleOverflow));
        let near = GradStats { nonfinite: 0, max_abs: scale_sat_limit() };
        assert_eq!(m.check(1.0, &near), None, "limit itself is not over");
    }

    #[test]
    fn divergence_window_fires_on_consecutive_blowups_only() {
        let clean = GradStats::default();
        let mut m = HealthMonitor::new(3, 10.0);
        assert_eq!(m.check(100.0, &clean), None, "first loss sets the baseline");
        assert_eq!(m.check(2.0, &clean), None); // best -> 2.0
        assert_eq!(m.check(25.0, &clean), None); // blow-up 1
        assert_eq!(m.check(30.0, &clean), None); // blow-up 2
        assert_eq!(m.check(3.0, &clean), None, "recovery resets the streak");
        assert_eq!(m.check(25.0, &clean), None);
        assert_eq!(m.check(26.0, &clean), None);
        assert_eq!(m.check(27.0, &clean), Some(Verdict::LossDivergence), "3rd consecutive");
        // window 0 disables the check entirely
        let mut off = HealthMonitor::new(0, 10.0);
        off.check(1.0, &clean);
        for _ in 0..20 {
            assert_eq!(off.check(1e9, &clean), None);
        }
    }

    #[test]
    fn monitor_state_round_trips() {
        let clean = GradStats::default();
        let mut a = HealthMonitor::new(3, 10.0);
        a.check(2.0, &clean);
        a.check(25.0, &clean);
        let (best, streak) = a.state();
        assert_eq!((best, streak), (2.0, 1));
        let mut b = HealthMonitor::new(3, 10.0);
        b.restore(best, streak);
        // both reach the verdict on the same subsequent sequence
        assert_eq!(a.check(26.0, &clean), b.check(26.0, &clean));
        assert_eq!(a.check(27.0, &clean), b.check(27.0, &clean));
        assert_eq!(a.check(27.0, &clean), Some(Verdict::LossDivergence));
    }

    #[test]
    fn health_record_renders_nonfinite_as_null() {
        let rec = HealthRecord {
            step: 4,
            verdict: Verdict::NanGrad,
            action: "rollback",
            loss: f32::NAN,
            grad_nonfinite: 3,
            grad_max_abs: 1.5,
            streak: 0,
            rollback_to: Some(2),
            lr_scale: 1.0,
        };
        let s = rec.to_json("cnn_t", "fp32").to_string_compact();
        assert!(s.contains("\"audit\":\"health\""), "{s}");
        assert!(s.contains("\"verdict\":\"nan_grad\""), "{s}");
        assert!(s.contains("\"loss\":null"), "{s}");
        assert!(s.contains("\"rollback_to\":2"), "{s}");
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("step").and_then(|v| v.as_f64()), Some(4.0));
    }
}
