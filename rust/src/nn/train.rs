//! Native Alg. 1 low-bit training step — the paper's training loop run
//! entirely on the in-crate MLS substrates, with **zero external
//! dependencies** (no PJRT, no artifacts).
//!
//! One step per Alg. 1, per conv layer:
//!
//! ```text
//!   forward    qW = Q(W)  (once per step)      Z  = Conv  (qW, Q(A))
//!   backward   qE = Q(E)  (once per layer)     dW = Conv  (qE, qA)
//!                                              dA = Conv^T(qE, qW)
//! ```
//!
//! All three convs execute on the pass-generic packed-GEMM engine
//! ([`crate::arith::spec::ConvSpec`]) over real [`MlsTensor`]s, so the
//! executed hardware-audit counters of every pass are collected per step
//! ([`StepAudit`]) and can be cross-checked against the analytic
//! [`super::ops::count_training_ops`] model (see
//! `rust/tests/train_ops_crosscheck.rs`). Dynamic quantization points
//! follow the paper: W once per step, A once per forward, E once per
//! backward, each through [`crate::mls::quantizer::quantize`] with fresh
//! stochastic-rounding offsets from the step seed (evaluation uses
//! deterministic nearest rounding). Gradients pass through the quantizers
//! by the straight-through estimator, and through ReLU as the usual mask;
//! BN (batch statistics, full backward), global average pooling, the FC
//! classifier, softmax cross-entropy and the SGD update all run in f32,
//! matching the framework split of the paper (Sec. VI-E).
//!
//! The first conv layer stays unquantized (paper convention); its
//! forward/backward run the f32 reference convs, and — also per Alg. 1 —
//! the first layer never computes an input gradient.

use anyhow::{bail, Result};

use crate::arith::conv::{conv2d_f32_dgrad, conv2d_f32_threaded, conv2d_f32_wgrad, ConvOutput};
use crate::arith::spec::ConvSpec;
use crate::mls::quantizer::{quantize, QuantConfig, Rounding};
use crate::mls::{Grouping, MlsTensor};
use crate::util::parallel;
use crate::util::rng::Pcg32;

/// Executed hardware-audit counters of one conv-pass kind, summed over
/// the quantized conv layers of one training step.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PassCounters {
    /// quantized convs executed
    pub convs: u64,
    pub mul_ops: u64,
    pub int_add_ops: u64,
    pub float_add_ops: u64,
    pub group_scale_ops: u64,
    /// max over layers of the per-conv peak accumulator bits
    pub peak_acc_bits: u32,
}

impl PassCounters {
    fn absorb(&mut self, out: &ConvOutput) {
        self.convs += 1;
        self.mul_ops += out.mul_ops;
        self.int_add_ops += out.int_add_ops;
        self.float_add_ops += out.float_add_ops;
        self.group_scale_ops += out.group_scale_ops;
        self.peak_acc_bits = self.peak_acc_bits.max(out.peak_acc_bits);
    }
}

/// Per-step executed audit over the quantized convs, split by Alg. 1
/// pass. The unquantized first layer runs f32 and is not audited (it is
/// counted separately by the analytic model too).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepAudit {
    pub forward: PassCounters,
    pub wgrad: PassCounters,
    pub dgrad: PassCounters,
}

/// Result of one native training step.
#[derive(Clone, Copy, Debug)]
pub struct NativeStepOutput {
    pub loss: f32,
    pub acc: f32,
    pub audit: StepAudit,
}

/// One conv layer's parameters (no bias — BN follows every conv).
pub struct ConvLayer {
    pub w: Vec<f32>,
    pub co: usize,
    pub ci: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// false for the first layer (paper convention: stem stays fp32)
    pub quantized: bool,
}

impl ConvLayer {
    fn spec(&self, h: usize, w: usize) -> ConvSpec {
        ConvSpec::new(self.stride, self.pad, self.k, self.k, h, w)
    }
}

/// Batch-statistics BatchNorm with a learned per-channel affine.
pub struct BnLayer {
    pub c: usize,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub eps: f32,
}

/// Fully-connected classifier head, `w` in `[dout, din]` row-major.
pub struct FcLayer {
    pub din: usize,
    pub dout: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

pub enum NativeLayer {
    Conv(ConvLayer),
    BatchNorm(BnLayer),
    Relu,
    GlobalAvgPool,
    Fc(FcLayer),
}

impl NativeLayer {
    fn param_len(&self) -> usize {
        match self {
            NativeLayer::Conv(l) => l.w.len(),
            NativeLayer::BatchNorm(l) => 2 * l.c,
            NativeLayer::Fc(l) => l.w.len() + l.b.len(),
            _ => 0,
        }
    }
}

/// Per-layer forward caches one backward pass consumes.
enum Cache {
    Conv { x: Vec<f32>, h: usize, w: usize, qw: Option<MlsTensor>, qa: Option<MlsTensor> },
    Bn { xhat: Vec<f32>, inv_std: Vec<f32>, h: usize, w: usize },
    Relu { pos: Vec<bool> },
    Gap { c: usize, h: usize, w: usize },
    Fc { x: Vec<f32> },
}

/// A sequential Conv -> BN -> ReLU -> ... -> GAP -> FC network trainable
/// natively under Alg. 1.
pub struct NativeModel {
    pub name: String,
    /// (C, H, W) of one input sample
    pub input: (usize, usize, usize),
    pub classes: usize,
    /// conv operand quantization (element/group formats, grouping,
    /// rounding); `enabled = false` trains fully in f32
    pub qcfg: QuantConfig,
    pub layers: Vec<NativeLayer>,
    threads: usize,
}

/// Quantize under `cfg`, drawing stochastic-rounding offsets from `rng`
/// when the config asks for them; with no RNG (evaluation) stochastic
/// configs fall back to deterministic nearest rounding.
fn quantize_dyn(
    x: &[f32],
    shape: &[usize],
    cfg: &QuantConfig,
    rng: Option<&mut Pcg32>,
) -> MlsTensor {
    match (cfg.rounding, rng) {
        (Rounding::Stochastic, Some(rng)) => {
            let offsets = rng.rounding_offsets(x.len());
            quantize(x, shape, cfg, &offsets)
        }
        (Rounding::Stochastic, None) => {
            let nearest = QuantConfig { rounding: Rounding::Nearest, ..*cfg };
            quantize(x, shape, &nearest, &[])
        }
        (Rounding::Nearest, _) => quantize(x, shape, cfg, &[]),
    }
}

fn softmax_ce(logits: &[f32], labels: &[i32], classes: usize) -> (f32, f32, Vec<f32>) {
    let n = labels.len();
    assert_eq!(logits.len(), n * classes, "logit/label shape mismatch");
    let mut dlogits = vec![0.0f32; n * classes];
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for (nb, &label) in labels.iter().enumerate() {
        let label = label as usize;
        assert!(label < classes, "label {label} out of range");
        let row = &logits[nb * classes..(nb + 1) * classes];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f64;
        for &v in row {
            sum += ((v - maxv) as f64).exp();
        }
        let mut best = 0usize;
        for (k, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = k;
            }
            let p = ((v - maxv) as f64).exp() / sum;
            dlogits[nb * classes + k] =
                ((p - if k == label { 1.0 } else { 0.0 }) / n as f64) as f32;
        }
        let p_label = ((row[label] - maxv) as f64).exp() / sum;
        loss -= p_label.max(1e-30).ln();
        if best == label {
            correct += 1;
        }
    }
    ((loss / n as f64) as f32, correct as f32 / n as f32, dlogits)
}

impl NativeModel {
    /// Flattened parameter count (the checkpoint/state length).
    pub fn state_len(&self) -> usize {
        self.layers.iter().map(|l| l.param_len()).sum()
    }

    /// Per-layer offsets into the flat state/gradient vector.
    fn param_offsets(&self) -> Vec<usize> {
        let mut offs = Vec::with_capacity(self.layers.len());
        let mut cursor = 0;
        for l in &self.layers {
            offs.push(cursor);
            cursor += l.param_len();
        }
        offs
    }

    /// Flatten all parameters (layer order; conv `w`, BN `gamma` then
    /// `beta`, FC `w` then `b`).
    pub fn state(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.state_len());
        for l in &self.layers {
            match l {
                NativeLayer::Conv(c) => out.extend_from_slice(&c.w),
                NativeLayer::BatchNorm(b) => {
                    out.extend_from_slice(&b.gamma);
                    out.extend_from_slice(&b.beta);
                }
                NativeLayer::Fc(f) => {
                    out.extend_from_slice(&f.w);
                    out.extend_from_slice(&f.b);
                }
                _ => {}
            }
        }
        out
    }

    /// Load a flat state vector written by [`Self::state`].
    pub fn load_state(&mut self, state: &[f32]) -> Result<()> {
        anyhow::ensure!(
            state.len() == self.state_len(),
            "state length {} != model parameter count {}",
            state.len(),
            self.state_len()
        );
        let mut cursor = 0;
        let mut take = |dst: &mut [f32]| {
            dst.copy_from_slice(&state[cursor..cursor + dst.len()]);
            cursor += dst.len();
        };
        for l in &mut self.layers {
            match l {
                NativeLayer::Conv(c) => take(&mut c.w),
                NativeLayer::BatchNorm(b) => {
                    take(&mut b.gamma);
                    take(&mut b.beta);
                }
                NativeLayer::Fc(f) => {
                    take(&mut f.w);
                    take(&mut f.b);
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Override the conv worker count (defaults to the ambient
    /// [`parallel::num_threads`]; results are bit-identical either way).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Full-window conv MACs of one Alg. 1 step, per sample: forward +
    /// weight-gradient for every conv, plus the input gradient for every
    /// conv after the first — independent of quantization, derived from
    /// the model's actual layer geometry. The analytic throughput
    /// denominator for f32 steps (`bench_train_step`); the quantized
    /// steps report their executed in-bounds counts from the audit
    /// instead.
    pub fn conv_macs_per_sample(&self) -> u64 {
        let (_, mut h, mut w) = self.input;
        let mut macs = 0u64;
        let mut first = true;
        for layer in &self.layers {
            match layer {
                NativeLayer::Conv(l) => {
                    let spec = l.spec(h, w);
                    let (ho, wo) = (spec.out_h(), spec.out_w());
                    let passes: u64 = if first { 2 } else { 3 };
                    macs += (l.ci * l.co * l.k * l.k * ho * wo) as u64 * passes;
                    first = false;
                    (h, w) = (ho, wo);
                }
                NativeLayer::GlobalAvgPool => (h, w) = (1, 1),
                _ => {}
            }
        }
        macs
    }

    /// Forward through all layers. With `rng` the quantizers draw
    /// stochastic-rounding offsets (training); without it they round to
    /// nearest (evaluation). With `caches` every layer records what its
    /// backward needs. Returns the logits `[N, classes]`.
    fn forward_inner(
        &self,
        images: &[f32],
        n: usize,
        mut rng: Option<&mut Pcg32>,
        mut caches: Option<&mut Vec<Cache>>,
        audit: &mut StepAudit,
    ) -> Vec<f32> {
        let (c0, h0, w0) = self.input;
        assert_eq!(images.len(), n * c0 * h0 * w0, "image batch shape mismatch");
        let mut x = images.to_vec();
        let (mut c, mut h, mut w) = (c0, h0, w0);
        for layer in &self.layers {
            match layer {
                NativeLayer::Conv(l) => {
                    assert_eq!(c, l.ci, "conv input channel mismatch");
                    let spec = l.spec(h, w);
                    let (ho, wo) = (spec.out_h(), spec.out_w());
                    let (z, qw, qa) = if l.quantized && self.qcfg.enabled {
                        let qw = quantize_dyn(
                            &l.w,
                            &[l.co, l.ci, l.k, l.k],
                            &self.qcfg,
                            rng.as_deref_mut(),
                        );
                        let qa = quantize_dyn(&x, &[n, c, h, w], &self.qcfg, rng.as_deref_mut());
                        let out = spec.forward(&qw, &qa, self.threads);
                        audit.forward.absorb(&out);
                        (out.z, Some(qw), Some(qa))
                    } else {
                        let (z, _) = conv2d_f32_threaded(
                            &l.w,
                            [l.co, l.ci, l.k, l.k],
                            &x,
                            [n, c, h, w],
                            l.stride,
                            l.pad,
                            self.threads,
                        );
                        (z, None, None)
                    };
                    if let Some(caches) = caches.as_deref_mut() {
                        // the quantized backward only ever reads qW/qA —
                        // keep the f32 activations alive only for the f32
                        // backward path
                        let xf = if qa.is_some() { Vec::new() } else { std::mem::take(&mut x) };
                        caches.push(Cache::Conv { x: xf, h, w, qw, qa });
                    }
                    x = z;
                    (c, h, w) = (l.co, ho, wo);
                }
                NativeLayer::BatchNorm(l) => {
                    assert_eq!(c, l.c, "BN channel mismatch");
                    let m = (n * h * w) as f64;
                    let plane = h * w;
                    let mut xhat = vec![0.0f32; x.len()];
                    let mut inv_std = vec![0.0f32; c];
                    for ch in 0..c {
                        let mut sum = 0.0f64;
                        let mut sq = 0.0f64;
                        for nb in 0..n {
                            let base = (nb * c + ch) * plane;
                            for &v in &x[base..base + plane] {
                                sum += v as f64;
                                sq += v as f64 * v as f64;
                            }
                        }
                        let mean = sum / m;
                        let var = (sq / m - mean * mean).max(0.0);
                        let inv = 1.0 / (var + l.eps as f64).sqrt();
                        inv_std[ch] = inv as f32;
                        let (g, b) = (l.gamma[ch], l.beta[ch]);
                        for nb in 0..n {
                            let base = (nb * c + ch) * plane;
                            for i in base..base + plane {
                                let xh = ((x[i] as f64 - mean) * inv) as f32;
                                xhat[i] = xh;
                                x[i] = g * xh + b;
                            }
                        }
                    }
                    if let Some(caches) = caches.as_deref_mut() {
                        caches.push(Cache::Bn { xhat, inv_std, h, w });
                    }
                }
                NativeLayer::Relu => {
                    let mut pos = Vec::new();
                    if caches.is_some() {
                        pos = x.iter().map(|&v| v > 0.0).collect();
                    }
                    for v in x.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                    if let Some(caches) = caches.as_deref_mut() {
                        caches.push(Cache::Relu { pos });
                    }
                }
                NativeLayer::GlobalAvgPool => {
                    let plane = h * w;
                    let mut y = vec![0.0f32; n * c];
                    for nb in 0..n {
                        for ch in 0..c {
                            let base = (nb * c + ch) * plane;
                            let mut sum = 0.0f64;
                            for &v in &x[base..base + plane] {
                                sum += v as f64;
                            }
                            y[nb * c + ch] = (sum / plane as f64) as f32;
                        }
                    }
                    if let Some(caches) = caches.as_deref_mut() {
                        caches.push(Cache::Gap { c, h, w });
                    }
                    x = y;
                    (h, w) = (1, 1);
                }
                NativeLayer::Fc(l) => {
                    let din = c * h * w;
                    assert_eq!(din, l.din, "FC input dim mismatch");
                    let mut y = vec![0.0f32; n * l.dout];
                    for nb in 0..n {
                        let xin = &x[nb * din..(nb + 1) * din];
                        for o in 0..l.dout {
                            let wrow = &l.w[o * din..(o + 1) * din];
                            let mut acc = l.b[o] as f64;
                            for d in 0..din {
                                acc += wrow[d] as f64 * xin[d] as f64;
                            }
                            y[nb * l.dout + o] = acc as f32;
                        }
                    }
                    if let Some(caches) = caches.as_deref_mut() {
                        caches.push(Cache::Fc { x: std::mem::take(&mut x) });
                    }
                    x = y;
                    (c, h, w) = (l.dout, 1, 1);
                }
            }
        }
        assert_eq!(c * h * w, self.classes, "head output does not match the class count");
        x
    }

    /// One full Alg. 1 pass WITHOUT the parameter update: forward,
    /// softmax cross-entropy, backward. Returns `(loss, acc, grads,
    /// audit)` with `grads` laid out exactly like [`Self::state`] — this
    /// is what the finite-difference gradient check exercises.
    pub fn loss_and_grads(
        &self,
        images: &[f32],
        labels: &[i32],
        seed: i64,
    ) -> (f32, f32, Vec<f32>, StepAudit) {
        let n = labels.len();
        let mut rng = Pcg32::new(seed as u64, 0x51e9_a1b2);
        let mut audit = StepAudit::default();
        let mut caches: Vec<Cache> = Vec::with_capacity(self.layers.len());
        let logits = self.forward_inner(images, n, Some(&mut rng), Some(&mut caches), &mut audit);
        let (loss, acc, dlogits) = softmax_ce(&logits, labels, self.classes);

        let mut grads = vec![0.0f32; self.state_len()];
        let offs = self.param_offsets();
        let mut g = dlogits;
        for (li, layer) in self.layers.iter().enumerate().rev() {
            let cache = caches.pop().expect("one cache per layer");
            match (layer, cache) {
                (NativeLayer::Fc(l), Cache::Fc { x }) => {
                    let gw = &mut grads[offs[li]..offs[li] + l.w.len() + l.b.len()];
                    for nb in 0..n {
                        let xin = &x[nb * l.din..(nb + 1) * l.din];
                        let grow = &g[nb * l.dout..(nb + 1) * l.dout];
                        for o in 0..l.dout {
                            let go = grow[o];
                            for d in 0..l.din {
                                gw[o * l.din + d] += go * xin[d];
                            }
                            gw[l.w.len() + o] += go;
                        }
                    }
                    let mut dx = vec![0.0f32; x.len()];
                    for nb in 0..n {
                        let grow = &g[nb * l.dout..(nb + 1) * l.dout];
                        let drow = &mut dx[nb * l.din..(nb + 1) * l.din];
                        for o in 0..l.dout {
                            let go = grow[o];
                            let wrow = &l.w[o * l.din..(o + 1) * l.din];
                            for d in 0..l.din {
                                drow[d] += go * wrow[d];
                            }
                        }
                    }
                    g = dx;
                }
                (NativeLayer::GlobalAvgPool, Cache::Gap { c, h, w }) => {
                    let plane = h * w;
                    let mut dx = vec![0.0f32; n * c * plane];
                    for nb in 0..n {
                        for ch in 0..c {
                            let gv = g[nb * c + ch] / plane as f32;
                            let base = (nb * c + ch) * plane;
                            for slot in &mut dx[base..base + plane] {
                                *slot = gv;
                            }
                        }
                    }
                    g = dx;
                }
                (NativeLayer::Relu, Cache::Relu { pos }) => {
                    for (gv, &p) in g.iter_mut().zip(&pos) {
                        if !p {
                            *gv = 0.0;
                        }
                    }
                }
                (NativeLayer::BatchNorm(l), Cache::Bn { xhat, inv_std, h, w }) => {
                    let plane = h * w;
                    let m = (n * plane) as f64;
                    let gg = &mut grads[offs[li]..offs[li] + 2 * l.c];
                    for ch in 0..l.c {
                        let mut sum_dy = 0.0f64;
                        let mut sum_dy_xhat = 0.0f64;
                        for nb in 0..n {
                            let base = (nb * l.c + ch) * plane;
                            for i in base..base + plane {
                                sum_dy += g[i] as f64;
                                sum_dy_xhat += g[i] as f64 * xhat[i] as f64;
                            }
                        }
                        gg[ch] += sum_dy_xhat as f32; // dgamma
                        gg[l.c + ch] += sum_dy as f32; // dbeta
                        let scale = l.gamma[ch] as f64 * inv_std[ch] as f64;
                        let mean_dy = sum_dy / m;
                        let mean_dy_xhat = sum_dy_xhat / m;
                        for nb in 0..n {
                            let base = (nb * l.c + ch) * plane;
                            for i in base..base + plane {
                                g[i] = (scale
                                    * (g[i] as f64 - mean_dy - xhat[i] as f64 * mean_dy_xhat))
                                    as f32;
                            }
                        }
                    }
                }
                (NativeLayer::Conv(l), Cache::Conv { x, h, w, qw, qa }) => {
                    let spec = l.spec(h, w);
                    let (ho, wo) = (spec.out_h(), spec.out_w());
                    let eshape = [n, l.co, ho, wo];
                    let need_dx = li > 0;
                    let gw = &mut grads[offs[li]..offs[li] + l.w.len()];
                    if let (Some(qw), Some(qa)) = (qw, qa) {
                        // Alg. 1: quantize E once, reuse for both passes
                        let qe = quantize_dyn(&g, &eshape, &self.qcfg, Some(&mut rng));
                        let wg = spec.weight_grad(&qe, &qa, self.threads);
                        audit.wgrad.absorb(&wg);
                        gw.copy_from_slice(&wg.z);
                        if need_dx {
                            let dg = spec.input_grad(&qe, &qw, self.threads);
                            audit.dgrad.absorb(&dg);
                            g = dg.z;
                        } else {
                            g = Vec::new();
                        }
                    } else {
                        let (wg, _) = conv2d_f32_wgrad(
                            &g,
                            eshape,
                            &x,
                            [n, l.ci, h, w],
                            l.stride,
                            l.pad,
                            l.k,
                            l.k,
                            self.threads,
                        );
                        gw.copy_from_slice(&wg);
                        if need_dx {
                            let (dg, _) = conv2d_f32_dgrad(
                                &g,
                                eshape,
                                &l.w,
                                [l.co, l.ci, l.k, l.k],
                                l.stride,
                                l.pad,
                                h,
                                w,
                                self.threads,
                            );
                            g = dg;
                        } else {
                            g = Vec::new();
                        }
                    }
                }
                _ => unreachable!("cache kind does not match layer kind"),
            }
        }
        (loss, acc, grads, audit)
    }

    /// One Alg. 1 training step: [`Self::loss_and_grads`] followed by the
    /// plain-SGD update `p -= lr * g`.
    pub fn train_step(
        &mut self,
        images: &[f32],
        labels: &[i32],
        lr: f32,
        seed: i64,
    ) -> NativeStepOutput {
        let (loss, acc, grads, audit) = self.loss_and_grads(images, labels, seed);
        let offs = self.param_offsets();
        for (li, layer) in self.layers.iter_mut().enumerate() {
            let len = layer.param_len();
            let gs = &grads[offs[li]..offs[li] + len];
            let mut cursor = 0;
            let mut update = |p: &mut [f32]| {
                for (pv, gv) in p.iter_mut().zip(&gs[cursor..cursor + p.len()]) {
                    *pv -= lr * gv;
                }
                cursor += p.len();
            };
            match layer {
                NativeLayer::Conv(c) => update(&mut c.w),
                NativeLayer::BatchNorm(b) => {
                    update(&mut b.gamma);
                    update(&mut b.beta);
                }
                NativeLayer::Fc(f) => {
                    update(&mut f.w);
                    update(&mut f.b);
                }
                _ => {}
            }
        }
        NativeStepOutput { loss, acc, audit }
    }

    /// Evaluate one batch: forward with deterministic nearest rounding,
    /// no caches, no parameter changes. Returns `(loss, acc)`.
    pub fn eval_batch(&self, images: &[f32], labels: &[i32]) -> (f32, f32) {
        let mut audit = StepAudit::default();
        let logits = self.forward_inner(images, labels.len(), None, None, &mut audit);
        let (loss, acc, _) = softmax_ce(&logits, labels, self.classes);
        (loss, acc)
    }
}

/// Builder for the sequential native models.
struct NativeBuilder {
    layers: Vec<NativeLayer>,
    rng: Pcg32,
    c: usize,
    h: usize,
    w: usize,
}

impl NativeBuilder {
    fn new(input: (usize, usize, usize), seed: u64) -> Self {
        NativeBuilder {
            layers: Vec::new(),
            rng: Pcg32::new(seed, 0x6e61_7469),
            c: input.0,
            h: input.1,
            w: input.2,
        }
    }

    fn conv(&mut self, co: usize, k: usize, stride: usize, pad: usize, quantized: bool) -> &mut Self {
        let ci = self.c;
        // He initialization
        let sigma = (2.0 / (ci * k * k) as f32).sqrt();
        let w = self.rng.normal_vec(co * ci * k * k, sigma);
        self.layers.push(NativeLayer::Conv(ConvLayer { w, co, ci, k, stride, pad, quantized }));
        self.c = co;
        self.h = (self.h + 2 * pad - k) / stride + 1;
        self.w = (self.w + 2 * pad - k) / stride + 1;
        self
    }

    fn bn(&mut self) -> &mut Self {
        self.layers.push(NativeLayer::BatchNorm(BnLayer {
            c: self.c,
            gamma: vec![1.0; self.c],
            beta: vec![0.0; self.c],
            eps: 1e-5,
        }));
        self
    }

    fn relu(&mut self) -> &mut Self {
        self.layers.push(NativeLayer::Relu);
        self
    }

    fn gap(&mut self) -> &mut Self {
        self.layers.push(NativeLayer::GlobalAvgPool);
        (self.h, self.w) = (1, 1);
        self
    }

    fn fc(&mut self, dout: usize) -> &mut Self {
        let din = self.c * self.h * self.w;
        let sigma = (2.0 / din as f32).sqrt();
        let w = self.rng.normal_vec(dout * din, sigma);
        self.layers.push(NativeLayer::Fc(FcLayer { din, dout, w, b: vec![0.0; dout] }));
        self.c = dout;
        self
    }
}

/// Names the native backend can train.
pub const NATIVE_MODELS: &[&str] = &["cnn_t", "cnn_s"];

/// Build a named native model: `cnn_t` (tiny 4-conv smoke/test net) or
/// `cnn_s` (the scaled VGG-style model mirroring the artifact zoo's
/// `cnn_s` layer shapes). The first conv of each stays unquantized; all
/// later convs run the full Alg. 1 quantized forward/backward under
/// `qcfg`. Initialization is deterministic in `seed`.
pub fn native_model(name: &str, qcfg: QuantConfig, seed: u64) -> Result<NativeModel> {
    // the integer conv engine requires the paper's (n, c) grouping; catch
    // other grouping ablations up front with a clean error instead of a
    // mid-step kernel assert
    anyhow::ensure!(
        !qcfg.enabled || qcfg.grouping == Grouping::Both,
        "the native backend requires nc grouping (grouping=both) for quantized configs, \
         got {:?} — run grouping ablations on the pjrt backend",
        qcfg.grouping
    );
    let input = (3usize, 16usize, 16usize);
    let classes = 10usize;
    let mut b = NativeBuilder::new(input, seed.wrapping_add(0x9e37_79b9));
    match name {
        "cnn_t" => {
            b.conv(8, 3, 1, 1, false).bn().relu();
            b.conv(16, 3, 2, 1, true).bn().relu();
            b.conv(16, 1, 1, 0, true).bn().relu();
            b.conv(16, 3, 1, 1, true).bn().relu();
            b.gap().fc(classes);
        }
        "cnn_s" => {
            b.conv(16, 3, 1, 1, false).bn().relu();
            b.conv(32, 3, 2, 1, true).bn().relu();
            b.conv(32, 3, 1, 1, true).bn().relu();
            b.conv(64, 3, 2, 1, true).bn().relu();
            b.conv(64, 3, 1, 1, true).bn().relu();
            b.gap().fc(classes);
        }
        other => bail!(
            "model {other:?} is not supported by the native backend (have {NATIVE_MODELS:?}; \
             use backend=pjrt for the artifact models)"
        ),
    }
    Ok(NativeModel {
        name: name.to_string(),
        input,
        classes,
        qcfg,
        layers: b.layers,
        threads: parallel::num_threads(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{streams, DatasetConfig, SynthCifar};

    fn batch(n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let ds = SynthCifar::new(DatasetConfig { noise: 1.0, label_noise: 0.0, seed, ..Default::default() });
        ds.batch(n, streams::TRAIN, 0)
    }

    #[test]
    fn gradient_check_fp32_against_finite_differences() {
        // fp32 config: the whole step is differentiable, so analytic
        // grads must match central finite differences on the loss
        let mut model = native_model("cnn_t", QuantConfig::fp32(), 7).unwrap();
        model.set_threads(1);
        let (images, labels) = batch(2, 11);
        let (loss, _, grads, _) = model.loss_and_grads(&images, &labels, 3);
        assert!(loss.is_finite());
        let state = model.state();
        assert_eq!(grads.len(), state.len());

        // sample parameters across every layer kind
        let mut idxs: Vec<usize> = Vec::new();
        let offs = model.param_offsets();
        for (li, layer) in model.layers.iter().enumerate() {
            let len = layer.param_len();
            if len == 0 {
                continue;
            }
            for probe in [0, len / 3, len / 2, len - 1] {
                idxs.push(offs[li] + probe);
            }
        }
        idxs.dedup();

        let eps = 3e-3f64;
        for &i in &idxs {
            let mut sp = state.clone();
            sp[i] = (state[i] as f64 + eps) as f32;
            model.load_state(&sp).unwrap();
            let (lp, _, _, _) = model.loss_and_grads(&images, &labels, 3);
            sp[i] = (state[i] as f64 - eps) as f32;
            model.load_state(&sp).unwrap();
            let (lm, _, _, _) = model.loss_and_grads(&images, &labels, 3);
            let fd = (lp as f64 - lm as f64) / (2.0 * eps);
            let an = grads[i] as f64;
            let tol = (an.abs().max(fd.abs()).max(1e-2)) * 0.08;
            assert!(
                (fd - an).abs() <= tol,
                "param {i}: analytic {an:.6e} vs finite-diff {fd:.6e} (tol {tol:.2e})"
            );
        }
        model.load_state(&state).unwrap();
    }

    #[test]
    fn quantized_step_runs_and_audit_passes_agree() {
        let mut model = native_model("cnn_t", QuantConfig::default(), 1).unwrap();
        let (images, labels) = batch(4, 5);
        let before = model.state();
        let out = model.train_step(&images, &labels, 0.05, 9);
        assert!(out.loss.is_finite(), "loss {}", out.loss);
        assert!((0.0..=1.0).contains(&out.acc));
        assert_ne!(model.state(), before, "SGD must move the parameters");

        // every quantized conv ran all three passes (none is the first
        // layer), and Alg. 1 executes the same MAC count in each pass
        let a = out.audit;
        assert_eq!(a.forward.convs, 3);
        assert_eq!(a.wgrad.convs, 3);
        assert_eq!(a.dgrad.convs, 3);
        assert!(a.forward.mul_ops > 0);
        assert_eq!(a.forward.mul_ops, a.wgrad.mul_ops);
        assert_eq!(a.forward.mul_ops, a.dgrad.mul_ops);
        assert_eq!(a.forward.int_add_ops, a.wgrad.int_add_ops);
        assert!(a.forward.peak_acc_bits >= 1);
    }

    #[test]
    fn steps_are_deterministic_in_the_seed() {
        let (images, labels) = batch(3, 2);
        let run = |seed: i64| {
            let mut m = native_model("cnn_t", QuantConfig::default(), 4).unwrap();
            let out = m.train_step(&images, &labels, 0.05, seed);
            (out.loss.to_bits(), m.state())
        };
        let (l1, s1) = run(17);
        let (l2, s2) = run(17);
        assert_eq!(l1, l2, "same seed must reproduce the loss bit-exactly");
        assert_eq!(s1, s2, "same seed must reproduce the update bit-exactly");
        let (_, s3) = run(18);
        assert_ne!(s1, s3, "the stochastic-rounding seed must matter");
    }

    #[test]
    fn state_round_trips() {
        let mut model = native_model("cnn_s", QuantConfig::default(), 3).unwrap();
        let s = model.state();
        assert_eq!(s.len(), model.state_len());
        let mut perturbed = s.clone();
        for (i, v) in perturbed.iter_mut().enumerate() {
            *v += (i % 7) as f32 * 0.01;
        }
        model.load_state(&perturbed).unwrap();
        assert_eq!(model.state(), perturbed);
        assert!(model.load_state(&s[..s.len() - 1]).is_err());
    }

    #[test]
    fn fp32_training_reduces_loss_quickly() {
        let mut model = native_model("cnn_t", QuantConfig::fp32(), 0).unwrap();
        let ds = SynthCifar::new(DatasetConfig { noise: 1.0, label_noise: 0.0, ..Default::default() });
        let mut losses = Vec::new();
        for step in 0..15u64 {
            let (images, labels) = ds.batch(16, streams::TRAIN, step);
            let out = model.train_step(&images, &labels, 0.05, step as i64);
            assert!(out.loss.is_finite(), "step {step}: loss {}", out.loss);
            losses.push(out.loss);
        }
        let first: f32 = losses[..3].iter().sum::<f32>() / 3.0;
        let last: f32 = losses[losses.len() - 3..].iter().sum::<f32>() / 3.0;
        assert!(last < first, "loss did not decrease: {first:.4} -> {last:.4} ({losses:?})");
    }
}
