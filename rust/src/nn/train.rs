//! Native Alg. 1 low-bit training step — the paper's training loop run
//! entirely on the in-crate MLS substrates, with **zero external
//! dependencies** (no PJRT, no artifacts).
//!
//! One step per Alg. 1, per conv layer:
//!
//! ```text
//!   forward    qW = Q(W)  (once per step)      Z  = Conv  (qW, Q(A))
//!   backward   qE = Q(E)  (once per layer)     dW = Conv  (qE, qA)
//!                                              dA = Conv^T(qE, qW)
//! ```
//!
//! Since PR 5 the model is a composable **module graph**
//! ([`crate::nn::graph`]) rather than a hardcoded chain: nodes in
//! topological order over explicit values, an `Add` join with gradient
//! fan-in for residual blocks, a [`Tape`] activation cache owned by the
//! trainer, and a pluggable [`Optimizer`] (plain SGD — bit-identical to
//! the historical inlined update — or momentum SGD). Every native model
//! (`cnn_t`, `cnn_s`, `resnet_t`) constructs its graph by **lowering its
//! analytic zoo twin** ([`crate::nn::zoo::native_network`] ->
//! [`crate::nn::graph::lower`]), so the analytic op model and the
//! executed graph share one geometry source.
//!
//! All three convs execute on the pass-generic packed-GEMM engine
//! ([`crate::arith::spec::ConvSpec`]) over real [`MlsTensor`]s; the
//! executed hardware-audit counters are collected as a per-layer stream
//! ([`StepAudit::layers`], one [`PassCounters`] record per quantized conv
//! node per pass) whose roll-up totals cross-check against the analytic
//! [`super::ops::count_training_ops`] model
//! (`rust/tests/train_ops_crosscheck.rs`). Dynamic quantization points
//! follow the paper: W once per step, A once per forward, E once per
//! backward, with fresh stochastic-rounding offsets from the step seed
//! (evaluation uses deterministic nearest rounding). Gradients pass the
//! quantizers by the straight-through estimator and ReLU as the usual
//! mask; BN (batch statistics, full backward), global average pooling,
//! the FC classifier, softmax cross-entropy and the optimizer run in f32,
//! matching the framework split of the paper (Sec. VI-E).
//!
//! The conv reading the graph input (the stem) stays unquantized (paper
//! convention); its forward/backward run the f32 reference convs, and —
//! also per Alg. 1 — it never computes an input gradient.
//!
//! [`MlsTensor`]: crate::mls::MlsTensor

use anyhow::Result;

use crate::mls::quantizer::QuantConfig;
use crate::mls::Grouping;
use crate::nn::arena::{StepArena, StepMem};
use crate::nn::graph::{lower, Executor, Graph, Tape};
use crate::nn::optim::{Optimizer, Sgd};
use crate::nn::zoo;
use crate::util::parallel;
use crate::util::rng::Pcg32;

pub use crate::nn::graph::{
    BnLayer, ConvLayer, FcLayer, LayerAudit, Node, Op, PassCounters, StepAudit,
};
pub use crate::nn::zoo::NATIVE_MODELS;

/// Result of one native training step.
#[derive(Clone, Debug)]
pub struct NativeStepOutput {
    pub loss: f32,
    pub acc: f32,
    pub audit: StepAudit,
}

fn softmax_ce(logits: &[f32], labels: &[i32], classes: usize) -> (f32, f32, Vec<f32>) {
    let mut dlogits = vec![0.0f32; labels.len() * classes];
    let (loss, acc) = softmax_ce_into(logits, labels, classes, &mut dlogits);
    (loss, acc, dlogits)
}

/// [`softmax_ce`] into a caller-owned gradient buffer (every element is
/// overwritten), so the warm step loop reuses one `dlogits` allocation.
fn softmax_ce_into(
    logits: &[f32],
    labels: &[i32],
    classes: usize,
    dlogits: &mut [f32],
) -> (f32, f32) {
    let n = labels.len();
    assert_eq!(logits.len(), n * classes, "logit/label shape mismatch");
    assert_eq!(dlogits.len(), n * classes, "dlogits buffer length mismatch");
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for (nb, &label) in labels.iter().enumerate() {
        let label = label as usize;
        assert!(label < classes, "label {label} out of range");
        let row = &logits[nb * classes..(nb + 1) * classes];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f64;
        for &v in row {
            sum += ((v - maxv) as f64).exp();
        }
        let mut best = 0usize;
        for (k, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = k;
            }
            let p = ((v - maxv) as f64).exp() / sum;
            dlogits[nb * classes + k] =
                ((p - if k == label { 1.0 } else { 0.0 }) / n as f64) as f32;
        }
        let p_label = ((row[label] - maxv) as f64).exp() / sum;
        loss -= p_label.max(1e-30).ln();
        if best == label {
            correct += 1;
        }
    }
    ((loss / n as f64) as f32, correct as f32 / n as f32)
}

/// The persistent step memory of [`NativeModel::train_step_quiet`]: the
/// arena plus every trainer-level buffer the step loop needs, so a warm
/// step allocates nothing at all.
struct StepScratch {
    arena: StepArena,
    tape: Tape,
    audit: StepAudit,
    grads: Vec<f32>,
    state: Vec<f32>,
}

/// A module-graph network trainable natively under Alg. 1.
/// `state`/`load_state`/`train_step`/`eval_batch` are the stable outer
/// API; internally forward/backward run on the [`Executor`] over
/// [`Self::graph`], and the parameter update on the pluggable
/// [`Optimizer`] (plain SGD by default).
pub struct NativeModel {
    pub name: String,
    /// (C, H, W) of one input sample
    pub input: (usize, usize, usize),
    pub classes: usize,
    /// conv operand quantization (element/group formats, grouping,
    /// rounding); `enabled = false` trains fully in f32
    pub qcfg: QuantConfig,
    /// the executable module graph (nodes own the parameters)
    pub graph: Graph,
    optimizer: Box<dyn Optimizer>,
    threads: usize,
    /// persistent step memory, present once [`Self::enable_step_arena`]
    /// has run; `train_step` routes through the zero-alloc path when set
    scratch: Option<StepScratch>,
}

impl NativeModel {
    /// Flattened parameter count (the checkpoint/state length).
    pub fn state_len(&self) -> usize {
        self.graph.state_len()
    }

    /// Flatten all parameters (node order; conv `w`, BN `gamma` then
    /// `beta`, FC `w` then `b`).
    pub fn state(&self) -> Vec<f32> {
        self.graph.state()
    }

    /// Load a flat state vector written by [`Self::state`].
    pub fn load_state(&mut self, state: &[f32]) -> Result<()> {
        self.graph.load_state(state)
    }

    /// Override the conv worker count (defaults to the ambient
    /// [`parallel::num_threads`]; results are bit-identical either way).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Swap the parameter-update rule (plain [`Sgd`] by default). The
    /// optimizer owns its state (e.g. momentum velocity), which persists
    /// across steps.
    pub fn set_optimizer(&mut self, optimizer: Box<dyn Optimizer>) {
        self.optimizer = optimizer;
    }

    /// Name of the active optimizer.
    pub fn optimizer_name(&self) -> &'static str {
        self.optimizer.name()
    }

    /// Full-window conv MACs of one Alg. 1 step, per sample (see
    /// [`Graph::conv_macs_per_sample`]).
    pub fn conv_macs_per_sample(&self) -> u64 {
        self.graph.conv_macs_per_sample()
    }

    fn executor(&self) -> Executor<'_> {
        Executor { graph: &self.graph, qcfg: &self.qcfg, threads: self.threads }
    }

    /// One full Alg. 1 pass WITHOUT the parameter update: forward,
    /// softmax cross-entropy, backward. Returns `(loss, acc, grads,
    /// audit)` with `grads` laid out exactly like [`Self::state`] — this
    /// is what the finite-difference gradient checks exercise. The audit
    /// carries the per-layer stream plus its roll-up totals.
    pub fn loss_and_grads(
        &self,
        images: &[f32],
        labels: &[i32],
        seed: i64,
    ) -> (f32, f32, Vec<f32>, StepAudit) {
        let n = labels.len();
        let mut rng = Pcg32::new(seed as u64, 0x51e9_a1b2);
        let mut audit = StepAudit::default();
        let mut tape = Tape::default();
        let ex = self.executor();
        let logits = ex.forward(images, n, Some(&mut rng), Some(&mut tape), &mut audit);
        let (loss, acc, dlogits) = softmax_ce(&logits, labels, self.classes);
        let mut grads = vec![0.0f32; self.graph.state_len()];
        ex.backward(tape, dlogits, n, &mut rng, &mut grads, &mut audit);
        audit.roll_up();
        (loss, acc, grads, audit)
    }

    /// The optimizer-update half of a training step: apply `grads` to
    /// the graph parameters through the active optimizer. Split out so
    /// the fault-tolerant trainer can inspect the gradients (health
    /// guard, fault injection) BETWEEN backward and update; calling
    /// [`Self::loss_and_grads`] then this is bit-identical to
    /// [`Self::train_step`].
    pub fn apply_update(&mut self, grads: &[f32], lr: f32) {
        let mut state = self.graph.state();
        self.optimizer.step(&mut state, grads, lr);
        self.graph.load_state(&state).expect("state length is stable");
    }

    /// Flatten the optimizer's internal slots (see [`Optimizer::state`]).
    pub fn optimizer_state(&self) -> Vec<f32> {
        self.optimizer.state()
    }

    /// Restore optimizer slots written by [`Self::optimizer_state`].
    pub fn load_optimizer_state(&mut self, state: &[f32]) -> Result<()> {
        self.optimizer.load_state(state)
    }

    /// One Alg. 1 training step: [`Self::loss_and_grads`] followed by the
    /// optimizer update over the flat state vector.
    pub fn train_step(
        &mut self,
        images: &[f32],
        labels: &[i32],
        lr: f32,
        seed: i64,
    ) -> NativeStepOutput {
        if self.scratch.is_some() {
            let (loss, acc) = self.train_step_quiet(images, labels, lr, seed);
            let audit = self.scratch.as_ref().unwrap().audit.clone();
            return NativeStepOutput { loss, acc, audit };
        }
        let (loss, acc, grads, audit) = self.loss_and_grads(images, labels, seed);
        self.apply_update(&grads, lr);
        NativeStepOutput { loss, acc, audit }
    }

    /// Attach the persistent step arena. The first step after this call
    /// warms every pool/slot up to its steady-state capacity; every later
    /// step runs the arena in strict mode and performs zero heap
    /// allocation (proved by `rust/tests/zero_alloc.rs`). Values and
    /// audit counters are bit-identical to the allocating path.
    /// Idempotent; once enabled, [`Self::train_step`] routes through
    /// [`Self::train_step_quiet`].
    pub fn enable_step_arena(&mut self) {
        if self.scratch.is_none() {
            self.scratch = Some(StepScratch {
                arena: StepArena::for_graph(&self.graph),
                tape: Tape::default(),
                audit: StepAudit::default(),
                grads: vec![0.0f32; self.graph.state_len()],
                state: Vec::with_capacity(self.graph.state_len()),
            });
        }
    }

    /// Whether [`Self::enable_step_arena`] has attached the arena.
    pub fn step_arena_enabled(&self) -> bool {
        self.scratch.is_some()
    }

    /// The audit of the last arena-path step (None before the first
    /// [`Self::train_step_quiet`], or when the arena is not enabled).
    pub fn last_audit(&self) -> Option<&StepAudit> {
        self.scratch.as_ref().map(|s| &s.audit)
    }

    /// [`Self::train_step`] on the arena path, returning only `(loss,
    /// acc)` so the warm loop never clones the audit stream (read it via
    /// [`Self::last_audit`]). Enables the arena on first use; after the
    /// warm-up step this performs zero heap allocation end to end —
    /// executor buffers, quantized planes, weight panels, gradient and
    /// optimizer-state staging all live in the persistent [`StepScratch`].
    pub fn train_step_quiet(
        &mut self,
        images: &[f32],
        labels: &[i32],
        lr: f32,
        seed: i64,
    ) -> (f32, f32) {
        let (loss, acc) = self.forward_backward_quiet(images, labels, seed);
        self.finish_step_quiet(lr);
        (loss, acc)
    }

    /// The forward+backward half of [`Self::train_step_quiet`]: one full
    /// Alg. 1 pass on the zero-alloc arena path, leaving the gradients in
    /// the persistent scratch ([`Self::step_grads`]) and the parameters
    /// untouched. The coordinator's step loop runs this, inspects and
    /// possibly mutates the gradients (health guard, fault injection),
    /// then commits with [`Self::finish_step_quiet`] or abandons the step
    /// with [`Self::discard_step_quiet`] — the committed sequence is the
    /// literal body of [`Self::train_step_quiet`], so it is bit-identical
    /// to the fused call, which in turn is bit-identical to
    /// [`Self::loss_and_grads`] + [`Self::apply_update`]
    /// (`rust/tests/zero_alloc.rs`).
    pub fn forward_backward_quiet(
        &mut self,
        images: &[f32],
        labels: &[i32],
        seed: i64,
    ) -> (f32, f32) {
        self.enable_step_arena();
        let n = labels.len();
        let mut rng = Pcg32::new(seed as u64, 0x51e9_a1b2);
        let NativeModel { graph, qcfg, scratch, threads, classes, .. } = self;
        let s = scratch.as_mut().expect("enable_step_arena ran above");
        let ex = Executor { graph: &*graph, qcfg, threads: *threads };
        let mut mem = StepMem::Arena(&mut s.arena);
        let logits = ex.forward_mem(images, n, Some(&mut rng), Some(&mut s.tape), &mut s.audit, &mut mem);
        let mut dlogits = mem.take_f32(n * *classes);
        let (loss, acc) = softmax_ce_into(&logits, labels, *classes, &mut dlogits);
        mem.recycle_f32(logits);
        s.grads.fill(0.0);
        ex.backward_mem(&mut s.tape, dlogits, n, &mut rng, &mut s.grads, &mut s.audit, &mut mem);
        s.audit.roll_up();
        (loss, acc)
    }

    /// The gradients left behind by the last
    /// [`Self::forward_backward_quiet`], laid out like [`Self::state`].
    pub fn step_grads(&self) -> &[f32] {
        &self.scratch.as_ref().expect("forward_backward_quiet has not run").grads
    }

    /// Mutable access to [`Self::step_grads`] (fault injection mutates
    /// the gradients in place between backward and update).
    pub fn step_grads_mut(&mut self) -> &mut Vec<f32> {
        &mut self.scratch.as_mut().expect("forward_backward_quiet has not run").grads
    }

    /// Commit the step started by [`Self::forward_backward_quiet`]: apply
    /// the scratch gradients through the optimizer and seal the arena
    /// warm-up. Same operation sequence as the tail of the fused
    /// [`Self::train_step_quiet`].
    pub fn finish_step_quiet(&mut self, lr: f32) {
        let NativeModel { graph, optimizer, scratch, .. } = self;
        let s = scratch.as_mut().expect("forward_backward_quiet has not run");
        graph.state_into(&mut s.state);
        optimizer.step(&mut s.state, &s.grads, lr);
        graph.load_state(&s.state).expect("state length is stable");
        s.arena.end_step();
    }

    /// Abandon the step started by [`Self::forward_backward_quiet`]
    /// without touching the parameters (divergence rollback discards the
    /// poisoned step before restoring the last good checkpoint). Still
    /// seals the arena warm-up: the executor buffers were all recycled by
    /// the backward pass, so the next step replays from the pool whether
    /// or not this one committed.
    pub fn discard_step_quiet(&mut self) {
        if let Some(s) = self.scratch.as_mut() {
            s.arena.end_step();
        }
    }

    /// Evaluate one batch: forward with deterministic nearest rounding,
    /// no tape, no parameter changes. Returns `(loss, acc)`.
    pub fn eval_batch(&self, images: &[f32], labels: &[i32]) -> (f32, f32) {
        let mut audit = StepAudit::default();
        let logits = self.executor().forward(images, labels.len(), None, None, &mut audit);
        let (loss, acc, _) = softmax_ce(&logits, labels, self.classes);
        (loss, acc)
    }

    /// The raw logits + audit of an [`Self::eval_batch`]-style forward
    /// (deterministic nearest rounding, no tape, heap memory). This is
    /// the bit-identity oracle the inference server is pinned against:
    /// a served forward over the same batch must reproduce these logits
    /// and all five audit counters exactly (`rust/tests/serve.rs`).
    pub fn eval_logits(&self, images: &[f32], n: usize) -> (Vec<f32>, StepAudit) {
        let mut audit = StepAudit::default();
        let logits = self.executor().forward(images, n, None, None, &mut audit);
        audit.roll_up();
        (logits, audit)
    }
}

/// Build a named native model: `cnn_t` (tiny 4-conv smoke/test net),
/// `cnn_s` (the scaled VGG-style zoo model) or `resnet_t` (the scaled
/// residual zoo model, Table II's native grid). The graph is lowered from
/// the model's analytic zoo twin ([`zoo::native_network`]); the stem conv
/// stays unquantized; all later convs — including residual 1x1 projection
/// shortcuts — run the full Alg. 1 quantized forward/backward under
/// `qcfg`. Initialization is deterministic in `seed`.
pub fn native_model(name: &str, qcfg: QuantConfig, seed: u64) -> Result<NativeModel> {
    // the integer conv engine requires the paper's (n, c) grouping; catch
    // other grouping ablations up front with a clean error instead of a
    // mid-step kernel assert
    anyhow::ensure!(
        !qcfg.enabled || qcfg.grouping == Grouping::Both,
        "the native backend requires nc grouping (grouping=both) for quantized configs, \
         got {:?} — run grouping ablations on the pjrt backend",
        qcfg.grouping
    );
    let net = zoo::native_network(name)?;
    let graph = lower(&net, seed.wrapping_add(0x9e37_79b9))?;
    Ok(NativeModel {
        name: name.to_string(),
        input: net.input,
        classes: graph.classes,
        qcfg,
        graph,
        optimizer: Box::new(Sgd::default()),
        threads: parallel::num_threads(),
        scratch: None,
    })
}

/// Incremental FNV-1a-64 hasher — the one checksum primitive shared by
/// [`state_checksum`] and the step-checkpoint codec
/// ([`crate::coordinator::checkpoint`]), so the fingerprint the lab
/// records and the integrity trailer the resume path verifies cannot
/// drift apart.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a::default()
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// FNV-1a-64 over a byte slice.
pub fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.finish()
}

/// FNV-1a checksum over the exact bit pattern of a flat parameter state
/// (little-endian `to_bits` bytes). Two runs with identical configs and
/// seeds end in the same checksum — the lab runner records it in
/// `trial_output.json` as the bit-identity fingerprint that the
/// crash-resume test compares across re-runs.
pub fn state_checksum(state: &[f32]) -> u64 {
    let mut h = Fnv1a::new();
    for v in state {
        h.update(&v.to_bits().to_le_bytes());
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{streams, DatasetConfig, SynthCifar};

    fn batch(n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let ds = SynthCifar::new(DatasetConfig { noise: 1.0, label_noise: 0.0, seed, ..Default::default() });
        ds.batch(n, streams::TRAIN, 0)
    }

    #[test]
    fn state_checksum_is_bit_sensitive() {
        let a = [0.5f32, -1.25, 3.0];
        let mut b = a;
        assert_eq!(state_checksum(&a), state_checksum(&b));
        b[1] = f32::from_bits(b[1].to_bits() ^ 1); // one ULP flip
        assert_ne!(state_checksum(&a), state_checksum(&b));
        assert_ne!(state_checksum(&[0.0]), state_checksum(&[-0.0]), "sign bit counts");
        assert_ne!(state_checksum(&[]), state_checksum(&[0.0]));
        // the incremental hasher IS state_checksum over the same bytes
        let bytes: Vec<u8> = a.iter().flat_map(|v| v.to_bits().to_le_bytes()).collect();
        assert_eq!(fnv1a_bytes(&bytes), state_checksum(&a));
        let mut inc = Fnv1a::new();
        inc.update(&bytes[..5]);
        inc.update(&bytes[5..]);
        assert_eq!(inc.finish(), fnv1a_bytes(&bytes), "chunking must not change the hash");
    }

    #[test]
    fn apply_update_split_matches_train_step_bitwise() {
        let (images, labels) = batch(3, 6);
        let run_fused = || {
            let mut m = native_model("cnn_t", QuantConfig::default(), 9).unwrap();
            let out = m.train_step(&images, &labels, 0.05, 21);
            (out.loss.to_bits(), m.state())
        };
        let run_split = || {
            let mut m = native_model("cnn_t", QuantConfig::default(), 9).unwrap();
            let (loss, _, grads, _) = m.loss_and_grads(&images, &labels, 21);
            m.apply_update(&grads, 0.05);
            (loss.to_bits(), m.state())
        };
        assert_eq!(run_fused(), run_split(), "the split step must be bit-identical");
    }

    #[test]
    fn split_quiet_step_matches_fused_quiet_step_bitwise() {
        // the coordinator's health-guarded loop (forward_backward_quiet ->
        // inspect step_grads -> finish_step_quiet) must be bit-identical
        // to the fused arena step, which zero_alloc.rs pins against the
        // allocating loss_and_grads path
        let (images, labels) = batch(3, 6);
        let run_fused = |steps: usize| {
            let mut m = native_model("cnn_t", QuantConfig::default(), 9).unwrap();
            m.enable_step_arena();
            let mut out = (0, Vec::new());
            for s in 0..steps {
                let (loss, _) = m.train_step_quiet(&images, &labels, 0.05, 21 + s as i64);
                out = (loss.to_bits(), m.state());
            }
            (out.0, out.1, m.last_audit().unwrap().clone())
        };
        let run_split = |steps: usize| {
            let mut m = native_model("cnn_t", QuantConfig::default(), 9).unwrap();
            m.enable_step_arena();
            let mut out = (0, Vec::new());
            for s in 0..steps {
                let (loss, _) = m.forward_backward_quiet(&images, &labels, 21 + s as i64);
                assert_eq!(m.step_grads().len(), m.state_len());
                m.finish_step_quiet(0.05);
                out = (loss.to_bits(), m.state());
            }
            (out.0, out.1, m.last_audit().unwrap().clone())
        };
        // two steps so the second runs on a warm (strict) arena
        assert_eq!(run_fused(2), run_split(2), "split quiet step must be bit-identical");
    }

    #[test]
    fn discard_step_quiet_leaves_parameters_untouched() {
        let (images, labels) = batch(3, 6);
        let mut m = native_model("cnn_t", QuantConfig::default(), 9).unwrap();
        m.enable_step_arena();
        let before = m.state();
        let (loss, _) = m.forward_backward_quiet(&images, &labels, 21);
        assert!(loss.is_finite());
        m.discard_step_quiet();
        assert_eq!(m.state(), before, "a discarded step must not move the parameters");
        // the next committed step still runs cleanly on the sealed arena
        let (loss2, _) = m.train_step_quiet(&images, &labels, 0.05, 22);
        assert!(loss2.is_finite());
        assert_ne!(m.state(), before);
    }

    #[test]
    fn gradient_check_fp32_against_finite_differences() {
        // fp32 config: the whole step is differentiable, so analytic
        // grads must match central finite differences on the loss
        let mut model = native_model("cnn_t", QuantConfig::fp32(), 7).unwrap();
        model.set_threads(1);
        let (images, labels) = batch(2, 11);
        let (loss, _, grads, _) = model.loss_and_grads(&images, &labels, 3);
        assert!(loss.is_finite());
        let state = model.state();
        assert_eq!(grads.len(), state.len());

        // sample parameters across every node kind
        let mut idxs: Vec<usize> = Vec::new();
        let offs = model.graph.param_offsets();
        for (ni, node) in model.graph.nodes.iter().enumerate() {
            let len = node.param_len();
            if len == 0 {
                continue;
            }
            for probe in [0, len / 3, len / 2, len - 1] {
                idxs.push(offs[ni] + probe);
            }
        }
        idxs.dedup();

        let eps = 3e-3f64;
        for &i in &idxs {
            let mut sp = state.clone();
            sp[i] = (state[i] as f64 + eps) as f32;
            model.load_state(&sp).unwrap();
            let (lp, _, _, _) = model.loss_and_grads(&images, &labels, 3);
            sp[i] = (state[i] as f64 - eps) as f32;
            model.load_state(&sp).unwrap();
            let (lm, _, _, _) = model.loss_and_grads(&images, &labels, 3);
            let fd = (lp as f64 - lm as f64) / (2.0 * eps);
            let an = grads[i] as f64;
            let tol = (an.abs().max(fd.abs()).max(1e-2)) * 0.08;
            assert!(
                (fd - an).abs() <= tol,
                "param {i}: analytic {an:.6e} vs finite-diff {fd:.6e} (tol {tol:.2e})"
            );
        }
        model.load_state(&state).unwrap();
    }

    #[test]
    fn quantized_step_runs_and_audit_passes_agree() {
        let mut model = native_model("cnn_t", QuantConfig::default(), 1).unwrap();
        let (images, labels) = batch(4, 5);
        let before = model.state();
        let out = model.train_step(&images, &labels, 0.05, 9);
        assert!(out.loss.is_finite(), "loss {}", out.loss);
        assert!((0.0..=1.0).contains(&out.acc));
        assert_ne!(model.state(), before, "SGD must move the parameters");

        // every quantized conv ran all three passes (none reads the graph
        // input), and Alg. 1 executes the same MAC count in each pass
        let a = &out.audit;
        assert_eq!(a.forward.convs, 3);
        assert_eq!(a.wgrad.convs, 3);
        assert_eq!(a.dgrad.convs, 3);
        assert!(a.forward.mul_ops > 0);
        assert_eq!(a.forward.mul_ops, a.wgrad.mul_ops);
        assert_eq!(a.forward.mul_ops, a.dgrad.mul_ops);
        assert_eq!(a.forward.int_add_ops, a.wgrad.int_add_ops);
        assert!(a.forward.peak_acc_bits >= 1);

        // the audit is a per-layer stream whose roll-up IS the totals
        assert_eq!(a.layers.len(), 3, "one record per quantized conv");
        assert_eq!(a.forward.mul_ops, a.layers.iter().map(|l| l.forward.mul_ops).sum::<u64>());
        assert_eq!(a.wgrad.mul_ops, a.layers.iter().map(|l| l.wgrad.mul_ops).sum::<u64>());
        assert_eq!(a.dgrad.mul_ops, a.layers.iter().map(|l| l.dgrad.mul_ops).sum::<u64>());
        for l in &a.layers {
            assert_eq!(l.forward.convs, 1);
            assert_eq!(l.forward.mul_ops, l.wgrad.mul_ops, "{}: pass symmetry", l.name);
            assert_eq!(l.forward.mul_ops, l.dgrad.mul_ops, "{}: pass symmetry", l.name);
        }
    }

    #[test]
    fn steps_are_deterministic_in_the_seed() {
        let (images, labels) = batch(3, 2);
        let run = |seed: i64| {
            let mut m = native_model("cnn_t", QuantConfig::default(), 4).unwrap();
            let out = m.train_step(&images, &labels, 0.05, seed);
            (out.loss.to_bits(), m.state())
        };
        let (l1, s1) = run(17);
        let (l2, s2) = run(17);
        assert_eq!(l1, l2, "same seed must reproduce the loss bit-exactly");
        assert_eq!(s1, s2, "same seed must reproduce the update bit-exactly");
        let (_, s3) = run(18);
        assert_ne!(s1, s3, "the stochastic-rounding seed must matter");
    }

    #[test]
    fn state_round_trips() {
        let mut model = native_model("cnn_s", QuantConfig::default(), 3).unwrap();
        let s = model.state();
        assert_eq!(s.len(), model.state_len());
        let mut perturbed = s.clone();
        for (i, v) in perturbed.iter_mut().enumerate() {
            *v += (i % 7) as f32 * 0.01;
        }
        model.load_state(&perturbed).unwrap();
        assert_eq!(model.state(), perturbed);
        assert!(model.load_state(&s[..s.len() - 1]).is_err());
    }

    #[test]
    fn fp32_training_reduces_loss_quickly() {
        let mut model = native_model("cnn_t", QuantConfig::fp32(), 0).unwrap();
        let ds = SynthCifar::new(DatasetConfig { noise: 1.0, label_noise: 0.0, ..Default::default() });
        let mut losses = Vec::new();
        for step in 0..15u64 {
            let (images, labels) = ds.batch(16, streams::TRAIN, step);
            let out = model.train_step(&images, &labels, 0.05, step as i64);
            assert!(out.loss.is_finite(), "step {step}: loss {}", out.loss);
            losses.push(out.loss);
        }
        let first: f32 = losses[..3].iter().sum::<f32>() / 3.0;
        let last: f32 = losses[losses.len() - 3..].iter().sum::<f32>() / 3.0;
        assert!(last < first, "loss did not decrease: {first:.4} -> {last:.4} ({losses:?})");
    }

    #[test]
    fn resnet_t_builds_and_steps() {
        let mut model = native_model("resnet_t", QuantConfig::default(), 1).unwrap();
        assert_eq!(model.optimizer_name(), "sgd");
        let (images, labels) = batch(2, 8);
        let out = model.train_step(&images, &labels, 0.05, 5);
        assert!(out.loss.is_finite());
        // 8 quantized convs (stem excluded), all running all three passes
        assert_eq!(out.audit.layers.len(), 8);
        assert_eq!(out.audit.forward.convs, 8);
        assert_eq!(out.audit.dgrad.convs, 8);
    }
}
