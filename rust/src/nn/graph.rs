//! Composable module graph for the native Alg. 1 trainer.
//!
//! PR 4's native trainer was a hardcoded single chain (an enum of layers
//! walked forward and backward by one monolithic function), which cannot
//! express a skip connection. This module replaces it with a small,
//! explicit node-graph IR:
//!
//! * [`Graph`] — nodes in topological order over *values*: value `0` is
//!   the graph input, the output of node `i` is value `i + 1`. Every node
//!   names its input value(s); [`Op::Add`] (the residual join) takes two
//!   and fans the gradient back into both.
//! * [`Tape`] — the activation cache of one forward pass, **owned by the
//!   trainer/executor**, not by the layers: one [`NodeCache`] entry per
//!   node, consumed exactly once by the backward pass.
//! * [`Executor`] — the forward/backward contracts. Forward moves each
//!   value buffer into its single consumer (cloning only at residual
//!   fan-out), so chain models execute the byte-identical sequence of
//!   f32 operations the PR 4 trainer did; backward walks the nodes in
//!   reverse, accumulating gradient contributions per value (`move` for
//!   the first contribution, element-wise `+=` for later ones).
//! * [`lower`] — the shared lowering from the analytic model zoo
//!   ([`crate::nn::zoo`]): `cnn_t`, `cnn_s` and `resnet_t` all construct
//!   their executable graphs from their zoo twins through this one
//!   function, so the analytic op model and the executed graph share a
//!   single geometry source. Residual basic blocks lower to
//!   `Conv -> BN -> ReLU -> Conv -> BN` plus an identity or 1x1-projection
//!   shortcut joined by [`Op::Add`] and a trailing ReLU, with every
//!   quantized conv running the full Alg. 1 forward/wgrad/dgrad triple
//!   exactly like chain convs.
//!
//! Quantization points, straight-through gradients, the fp32 stem
//! convention (the conv reading the graph input stays unquantized and
//! skips its input gradient) and the per-conv audit counters all carry
//! over from the chain trainer unchanged — the chain models are
//! **bit-identical** before vs after the redesign, pinned by
//! `rust/tests/train_bit_identity.rs`, which replays fixed-seed steps
//! against a verbatim copy of the historical implementation.
//!
//! The executed audit is now a per-layer stream: one [`PassCounters`]
//! record per quantized conv node per Alg. 1 pass ([`LayerAudit`]),
//! rolled up into the step totals of [`StepAudit`] (sum over counters,
//! max over peak bits) — the totals are exactly what the chain trainer
//! reported.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::arith::conv::{conv2d_f32_dgrad_into, conv2d_f32_into, conv2d_f32_wgrad_into, ConvOutput};
use crate::arith::spec::{self, ConvSpec, OperandView};
use crate::arith::{pack, planes};
use crate::mls::quantizer::{quantize, quantize_into_planes, QuantConfig, Rounding};
use crate::mls::MlsTensor;
use crate::nn::arena::{StepMem, PASS_DGRAD, PASS_FORWARD, PASS_WGRAD};
use crate::nn::zoo::{Layer, Network};
use crate::util::json::Json;
use crate::util::parallel::with_label;
use crate::util::rng::Pcg32;

/// Index of a value: `0` is the graph input, the output of node `i` is
/// value `i + 1`.
pub type ValueId = usize;

/// The graph-input value id.
pub const INPUT: ValueId = 0;

// ---------------------------------------------------------------------------
// Audit stream
// ---------------------------------------------------------------------------

/// Executed hardware-audit counters of one conv-pass kind (one quantized
/// conv in a [`LayerAudit`] record, or the roll-up over all of them in
/// [`StepAudit`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PassCounters {
    /// quantized convs executed
    pub convs: u64,
    pub mul_ops: u64,
    pub int_add_ops: u64,
    pub float_add_ops: u64,
    pub group_scale_ops: u64,
    /// max over layers of the per-conv peak accumulator bits
    pub peak_acc_bits: u32,
}

impl PassCounters {
    pub(crate) fn absorb(&mut self, out: &ConvOutput) {
        self.convs += 1;
        self.mul_ops += out.mul_ops;
        self.int_add_ops += out.int_add_ops;
        self.float_add_ops += out.float_add_ops;
        self.group_scale_ops += out.group_scale_ops;
        self.peak_acc_bits = self.peak_acc_bits.max(out.peak_acc_bits);
    }

    pub(crate) fn absorb_engine(&mut self, a: &spec::EngineAudit) {
        self.convs += 1;
        self.mul_ops += a.mul_ops;
        self.int_add_ops += a.int_add_ops;
        self.float_add_ops += a.float_add_ops;
        self.group_scale_ops += a.group_scale_ops;
        self.peak_acc_bits = self.peak_acc_bits.max(a.peak_acc_bits);
    }

    pub(crate) fn merge(&mut self, other: &PassCounters) {
        self.convs += other.convs;
        self.mul_ops += other.mul_ops;
        self.int_add_ops += other.int_add_ops;
        self.float_add_ops += other.float_add_ops;
        self.group_scale_ops += other.group_scale_ops;
        self.peak_acc_bits = self.peak_acc_bits.max(other.peak_acc_bits);
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("convs".to_string(), Json::Num(self.convs as f64));
        m.insert("mul_ops".to_string(), Json::Num(self.mul_ops as f64));
        m.insert("int_add_ops".to_string(), Json::Num(self.int_add_ops as f64));
        m.insert("float_add_ops".to_string(), Json::Num(self.float_add_ops as f64));
        m.insert("group_scale_ops".to_string(), Json::Num(self.group_scale_ops as f64));
        m.insert("peak_acc_bits".to_string(), Json::Num(self.peak_acc_bits as f64));
        Json::Obj(m)
    }
}

/// Per-node audit record: the executed counters of ONE quantized conv
/// node, one [`PassCounters`] per Alg. 1 pass.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerAudit {
    /// node index in [`Graph::nodes`]
    pub node: usize,
    /// node name (the zoo conv name, e.g. `conv3` or `conv5s`)
    pub name: String,
    pub forward: PassCounters,
    pub wgrad: PassCounters,
    pub dgrad: PassCounters,
}

impl LayerAudit {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("node".to_string(), Json::Num(self.node as f64));
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("forward".to_string(), self.forward.to_json());
        m.insert("wgrad".to_string(), self.wgrad.to_json());
        m.insert("dgrad".to_string(), self.dgrad.to_json());
        Json::Obj(m)
    }
}

/// Per-step executed audit over the quantized convs: a per-layer stream
/// (`layers`, one record per quantized conv node in forward execution
/// order) plus the roll-up totals per Alg. 1 pass. The totals are exactly
/// the sum of the stream (max for `peak_acc_bits`); the unquantized stem
/// runs f32 and is not audited, as before.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepAudit {
    pub forward: PassCounters,
    pub wgrad: PassCounters,
    pub dgrad: PassCounters,
    /// one record per quantized conv node, forward execution order
    pub layers: Vec<LayerAudit>,
}

impl StepAudit {
    /// Recompute the per-pass totals from the per-layer stream.
    pub(crate) fn roll_up(&mut self) {
        let mut forward = PassCounters::default();
        let mut wgrad = PassCounters::default();
        let mut dgrad = PassCounters::default();
        for l in &self.layers {
            forward.merge(&l.forward);
            wgrad.merge(&l.wgrad);
            dgrad.merge(&l.dgrad);
        }
        self.forward = forward;
        self.wgrad = wgrad;
        self.dgrad = dgrad;
    }

    /// Accumulate another step's per-pass totals into `self` (sum over
    /// counters, max over `peak_acc_bits`; the per-layer stream is not
    /// accumulated). The lab runner uses this to roll a whole run's audit
    /// stream up into the `audit_totals` of `trial_output.json`.
    pub fn merge_totals(&mut self, other: &StepAudit) {
        self.forward.merge(&other.forward);
        self.wgrad.merge(&other.wgrad);
        self.dgrad.merge(&other.dgrad);
    }

    /// Per-pass totals as a JSON object (the `totals` sub-object of
    /// [`Self::to_json`], reused by the lab runner's `trial_output.json`).
    pub fn totals_json(&self) -> Json {
        let mut totals = BTreeMap::new();
        totals.insert("forward".to_string(), self.forward.to_json());
        totals.insert("wgrad".to_string(), self.wgrad.to_json());
        totals.insert("dgrad".to_string(), self.dgrad.to_json());
        Json::Obj(totals)
    }

    /// One audit-stream record (`schemas/audit_step.schema.json`): the
    /// per-layer records plus the roll-up totals, tagged with the run
    /// context. `coordinator::train_native` writes one such record per
    /// step to `<tag>.audit.jsonl`; `bench_train_step` writes one to
    /// `AUDIT_step.json` for CI schema validation.
    pub fn to_json(&self, model: &str, cfg: &str, batch: usize, step: u64) -> Json {
        let mut m = BTreeMap::new();
        m.insert("audit".to_string(), Json::Str("train_step".to_string()));
        m.insert("model".to_string(), Json::Str(model.to_string()));
        m.insert("cfg".to_string(), Json::Str(cfg.to_string()));
        m.insert("batch".to_string(), Json::Num(batch as f64));
        m.insert("step".to_string(), Json::Num(step as f64));
        m.insert("totals".to_string(), self.totals_json());
        m.insert(
            "layers".to_string(),
            Json::Arr(self.layers.iter().map(LayerAudit::to_json).collect()),
        );
        Json::Obj(m)
    }
}

// ---------------------------------------------------------------------------
// Node ops
// ---------------------------------------------------------------------------

/// One conv layer's parameters (no bias — BN follows every conv).
pub struct ConvLayer {
    pub w: Vec<f32>,
    pub co: usize,
    pub ci: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// exact input spatial dims (fixed at lowering time)
    pub hin: usize,
    pub win: usize,
    /// false for the stem (paper convention: the first conv stays fp32)
    pub quantized: bool,
}

impl ConvLayer {
    pub fn spec(&self) -> ConvSpec {
        ConvSpec::new(self.stride, self.pad, self.k, self.k, self.hin, self.win)
    }
}

/// Batch-statistics BatchNorm with a learned per-channel affine.
pub struct BnLayer {
    pub c: usize,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub eps: f32,
}

/// Fully-connected classifier head, `w` in `[dout, din]` row-major.
pub struct FcLayer {
    pub din: usize,
    pub dout: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

/// The operation a node applies to its input value(s).
pub enum Op {
    Conv(ConvLayer),
    BatchNorm(BnLayer),
    Relu,
    GlobalAvgPool,
    Fc(FcLayer),
    /// element-wise residual join: two inputs, gradient fans into both
    Add,
}

/// One graph node: an op applied to named input values. `inputs` holds
/// one value id, or two for [`Op::Add`].
pub struct Node {
    pub name: String,
    pub op: Op,
    pub inputs: Vec<ValueId>,
}

impl Node {
    pub fn param_len(&self) -> usize {
        match &self.op {
            Op::Conv(l) => l.w.len(),
            Op::BatchNorm(l) => 2 * l.c,
            Op::Fc(l) => l.w.len() + l.b.len(),
            _ => 0,
        }
    }
}

/// The executable module graph: nodes in topological order over values,
/// plus the input/output contract.
pub struct Graph {
    pub nodes: Vec<Node>,
    /// (C, H, W) of one input sample
    pub input: (usize, usize, usize),
    pub classes: usize,
}

impl Graph {
    /// Flattened parameter count (the checkpoint/state length).
    pub fn state_len(&self) -> usize {
        self.nodes.iter().map(|n| n.param_len()).sum()
    }

    /// Per-node offsets into the flat state/gradient vector.
    pub fn param_offsets(&self) -> Vec<usize> {
        let mut offs = Vec::with_capacity(self.nodes.len());
        let mut cursor = 0;
        for n in &self.nodes {
            offs.push(cursor);
            cursor += n.param_len();
        }
        offs
    }

    /// Flatten all parameters (node order; conv `w`, BN `gamma` then
    /// `beta`, FC `w` then `b`).
    pub fn state(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.state_len());
        for n in &self.nodes {
            match &n.op {
                Op::Conv(c) => out.extend_from_slice(&c.w),
                Op::BatchNorm(b) => {
                    out.extend_from_slice(&b.gamma);
                    out.extend_from_slice(&b.beta);
                }
                Op::Fc(f) => {
                    out.extend_from_slice(&f.w);
                    out.extend_from_slice(&f.b);
                }
                _ => {}
            }
        }
        out
    }

    /// [`Self::state`] into a caller-owned buffer (cleared first), so the
    /// warm train-step loop reuses one state vector across steps.
    pub fn state_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.state_len());
        for n in &self.nodes {
            match &n.op {
                Op::Conv(c) => out.extend_from_slice(&c.w),
                Op::BatchNorm(b) => {
                    out.extend_from_slice(&b.gamma);
                    out.extend_from_slice(&b.beta);
                }
                Op::Fc(f) => {
                    out.extend_from_slice(&f.w);
                    out.extend_from_slice(&f.b);
                }
                _ => {}
            }
        }
    }

    /// Load a flat state vector written by [`Self::state`].
    pub fn load_state(&mut self, state: &[f32]) -> Result<()> {
        ensure!(
            state.len() == self.state_len(),
            "state length {} != graph parameter count {}",
            state.len(),
            self.state_len()
        );
        let mut cursor = 0;
        let mut take = |dst: &mut [f32]| {
            dst.copy_from_slice(&state[cursor..cursor + dst.len()]);
            cursor += dst.len();
        };
        for n in &mut self.nodes {
            match &mut n.op {
                Op::Conv(c) => take(&mut c.w),
                Op::BatchNorm(b) => {
                    take(&mut b.gamma);
                    take(&mut b.beta);
                }
                Op::Fc(f) => {
                    take(&mut f.w);
                    take(&mut f.b);
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Full-window conv MACs of one Alg. 1 step, per sample: forward +
    /// weight-gradient for every conv, plus the input gradient for every
    /// conv that does not read the graph input — independent of
    /// quantization, derived from the graph's actual layer geometry. The
    /// analytic throughput denominator for f32 steps (`bench_train_step`);
    /// quantized steps report their executed in-bounds counts from the
    /// audit instead.
    pub fn conv_macs_per_sample(&self) -> u64 {
        let mut macs = 0u64;
        for node in &self.nodes {
            if let Op::Conv(l) = &node.op {
                let spec = l.spec();
                let (ho, wo) = (spec.out_h(), spec.out_w());
                let passes: u64 = if node.inputs[0] == INPUT { 2 } else { 3 };
                macs += (l.ci * l.co * l.k * l.k * ho * wo) as u64 * passes;
            }
        }
        macs
    }
}

// ---------------------------------------------------------------------------
// Tape (activation cache) and the executor
// ---------------------------------------------------------------------------

/// What one node's backward needs from its forward execution.
enum NodeCache {
    None,
    Conv {
        /// f32 input activations — kept ONLY for the f32 (stem) backward;
        /// the quantized backward reads qW/qA and never the f32 input
        x: Vec<f32>,
        qw: Option<MlsTensor>,
        qa: Option<MlsTensor>,
        /// index into [`StepAudit::layers`] for quantized convs
        audit_slot: Option<usize>,
    },
    Bn { xhat: Vec<f32>, inv_std: Vec<f32>, h: usize, w: usize },
    Relu { pos: Vec<bool> },
    Gap { c: usize, h: usize, w: usize },
    Fc { x: Vec<f32> },
}

/// Activation cache of one forward pass, owned by the trainer (not by the
/// layers): one entry per node, consumed by [`Executor::backward`].
#[derive(Default)]
pub struct Tape {
    caches: Vec<NodeCache>,
}

/// One feature-map value flowing through the graph.
#[derive(Clone)]
pub(crate) struct Feat {
    pub(crate) data: Vec<f32>,
    pub(crate) c: usize,
    pub(crate) h: usize,
    pub(crate) w: usize,
}

/// Quantize under `cfg`, drawing stochastic-rounding offsets from `rng`
/// when the config asks for them; with no RNG (evaluation) stochastic
/// configs fall back to deterministic nearest rounding.
fn quantize_dyn(x: &[f32], shape: &[usize], cfg: &QuantConfig, rng: Option<&mut Pcg32>) -> MlsTensor {
    match (cfg.rounding, rng) {
        (Rounding::Stochastic, Some(rng)) => {
            let offsets = rng.rounding_offsets(x.len());
            quantize(x, shape, cfg, &offsets)
        }
        (Rounding::Stochastic, None) => {
            let nearest = QuantConfig { rounding: Rounding::Nearest, ..*cfg };
            quantize(x, shape, &nearest, &[])
        }
        (Rounding::Nearest, _) => quantize(x, shape, cfg, &[]),
    }
}

/// [`quantize_dyn`]'s rounding-offset rule without the quantize: draw the
/// offsets into `out` (training) or fall back to nearest rounding (no
/// RNG), returning the effective config. The arena forward/backward pair
/// this with [`quantize_into_planes`], consuming the RNG stream in the
/// exact order the heap path's [`quantize_dyn`] calls do.
fn offsets_dyn(
    cfg: &QuantConfig,
    rng: Option<&mut Pcg32>,
    n: usize,
    out: &mut Vec<f32>,
) -> QuantConfig {
    match (cfg.rounding, rng) {
        (Rounding::Stochastic, Some(rng)) => {
            rng.rounding_offsets_into(out, n);
            *cfg
        }
        (Rounding::Stochastic, None) => {
            out.clear();
            QuantConfig { rounding: Rounding::Nearest, ..*cfg }
        }
        (Rounding::Nearest, _) => {
            out.clear();
            *cfg
        }
    }
}

/// Consume one input value: moved into its last consumer, copied for
/// earlier consumers at a residual fan-out. Chains therefore move every
/// buffer, exactly like the historical trainer; the fan-out copy goes
/// through `mem` so the warm arena step reuses a pooled buffer.
fn take_val(
    mem: &mut StepMem,
    vals: &mut [Option<Feat>],
    uses: &mut [usize],
    vid: ValueId,
    who: &str,
) -> Feat {
    assert!(uses[vid] > 0, "{who}: value {vid} over-consumed");
    uses[vid] -= 1;
    let slot = &mut vals[vid];
    if uses[vid] == 0 {
        slot.take().unwrap_or_else(|| panic!("{who}: value {vid} missing"))
    } else {
        let f = slot.as_ref().unwrap_or_else(|| panic!("{who}: value {vid} missing"));
        let mut data = mem.take_f32(f.data.len());
        data.copy_from_slice(&f.data);
        Feat { data, c: f.c, h: f.h, w: f.w }
    }
}

/// Accumulate a gradient contribution into a value's gradient slot: the
/// first contribution moves, later ones add element-wise (residual
/// fan-in) and the spent buffer returns to `mem`.
fn accumulate(mem: &mut StepMem, slot: &mut Option<Vec<f32>>, dx: Vec<f32>) {
    match slot {
        None => *slot = Some(dx),
        Some(acc) => {
            assert_eq!(acc.len(), dx.len(), "gradient fan-in length mismatch");
            for (a, d) in acc.iter_mut().zip(&dx) {
                *a += *d;
            }
            mem.recycle_f32(dx);
        }
    }
}

/// Claim the audit record for the next quantized conv of this forward
/// pass: appended on first sight (warm-up / fresh audits), reset in place
/// when the audit stream is persistent across steps (arena mode).
fn layer_slot(audit: &mut StepAudit, cursor: &mut usize, node: usize, name: &str) -> usize {
    let i = *cursor;
    *cursor += 1;
    if i == audit.layers.len() {
        audit.layers.push(LayerAudit { node, name: name.to_string(), ..Default::default() });
    } else {
        let la = &mut audit.layers[i];
        debug_assert_eq!(la.node, node, "audit stream shape changed across steps");
        la.forward = PassCounters::default();
        la.wgrad = PassCounters::default();
        la.dgrad = PassCounters::default();
    }
    i
}

/// Run `f` under the conv node's dispatch label: arena mode borrows the
/// pre-formatted label (no allocation in the warm loop), heap mode
/// formats it like the historical code.
fn with_conv_label<R>(mem: &StepMem, i: usize, pass: usize, name: &str, f: impl FnOnce() -> R) -> R {
    match mem {
        StepMem::Arena(a) => with_label(a.conv_label(i, pass), f),
        StepMem::Heap => {
            let pass_name = match pass {
                PASS_FORWARD => "forward",
                PASS_WGRAD => "wgrad",
                _ => "dgrad",
            };
            with_label(&format!("{name}:{pass_name}"), f)
        }
    }
}

/// The forward/backward contracts over a [`Graph`]: borrows the graph and
/// the run configuration, owns no state — the [`Tape`] and audit stream
/// are passed through explicitly, so the trainer owns every cache.
pub struct Executor<'a> {
    pub graph: &'a Graph,
    pub qcfg: &'a QuantConfig,
    pub threads: usize,
}

impl Executor<'_> {
    /// Forward through the graph. With `rng` the quantizers draw
    /// stochastic-rounding offsets (training); without it they round to
    /// nearest (evaluation). With `tape` every node records what its
    /// backward needs. Quantized convs append one [`LayerAudit`] record to
    /// `audit.layers` (forward counters filled; backward fills the rest).
    /// Returns the logits `[N, classes]`.
    pub fn forward(
        &self,
        images: &[f32],
        n: usize,
        rng: Option<&mut Pcg32>,
        tape: Option<&mut Tape>,
        audit: &mut StepAudit,
    ) -> Vec<f32> {
        self.forward_mem(images, n, rng, tape, audit, &mut StepMem::Heap)
    }

    /// [`Self::forward`] with explicit step memory: `StepMem::Heap`
    /// reproduces the historical allocate-per-step behavior bit-for-bit;
    /// `StepMem::Arena` serves every buffer from the step arena and
    /// quantizes convs straight into their persistent plane slots
    /// (identical values — pinned by `rust/tests/zero_alloc.rs`).
    pub(crate) fn forward_mem(
        &self,
        images: &[f32],
        n: usize,
        mut rng: Option<&mut Pcg32>,
        mut tape: Option<&mut Tape>,
        audit: &mut StepAudit,
        mem: &mut StepMem,
    ) -> Vec<f32> {
        let g = self.graph;
        let (c0, h0, w0) = g.input;
        assert_eq!(images.len(), n * c0 * h0 * w0, "image batch shape mismatch");
        let n_vals = g.nodes.len() + 1;
        let (mut vals, mut uses) = mem.take_graph_slots(n_vals);
        for node in &g.nodes {
            for &vid in &node.inputs {
                uses[vid] += 1;
            }
        }
        let mut inp = mem.take_f32(images.len());
        inp.copy_from_slice(images);
        vals[INPUT] = Some(Feat { data: inp, c: c0, h: h0, w: w0 });
        if let Some(tape) = tape.as_deref_mut() {
            tape.caches.clear();
        }
        let mut audit_cursor = 0usize;

        for (i, node) in g.nodes.iter().enumerate() {
            let out = match &node.op {
                Op::Conv(l) => {
                    let x = take_val(mem, &mut vals, &mut uses, node.inputs[0], &node.name);
                    assert_eq!(x.c, l.ci, "{}: conv input channel mismatch", node.name);
                    assert_eq!(
                        (x.h, x.w),
                        (l.hin, l.win),
                        "{}: conv input spatial mismatch",
                        node.name
                    );
                    let spec = l.spec();
                    let (ho, wo) = (spec.out_h(), spec.out_w());
                    let (z, qw, qa, audit_slot) = if l.quantized && self.qcfg.enabled {
                        let slot = layer_slot(audit, &mut audit_cursor, i, &node.name);
                        if mem.is_arena() {
                            let z = self.arena_conv_forward(
                                mem,
                                i,
                                l,
                                &spec,
                                &x,
                                n,
                                rng.as_deref_mut(),
                                audit,
                                slot,
                            );
                            (z, None, None, Some(slot))
                        } else {
                            let qw = quantize_dyn(
                                &l.w,
                                &[l.co, l.ci, l.k, l.k],
                                self.qcfg,
                                rng.as_deref_mut(),
                            );
                            let qa = quantize_dyn(
                                &x.data,
                                &[n, x.c, x.h, x.w],
                                self.qcfg,
                                rng.as_deref_mut(),
                            );
                            // label the dispatch so a kernel panic names
                            // this layer and pass (util::parallel rethrow)
                            let out = with_label(&format!("{}:forward", node.name), || {
                                spec.forward(&qw, &qa, self.threads)
                            });
                            audit.layers[slot].forward.absorb(&out);
                            (out.z, Some(qw), Some(qa), Some(slot))
                        }
                    } else {
                        let mut z = mem.take_f32(n * l.co * ho * wo);
                        with_conv_label(mem, i, PASS_FORWARD, &node.name, || {
                            conv2d_f32_into(
                                &l.w,
                                [l.co, l.ci, l.k, l.k],
                                &x.data,
                                [n, x.c, x.h, x.w],
                                l.stride,
                                l.pad,
                                self.threads,
                                &mut z,
                            );
                        });
                        (z, None, None, None)
                    };
                    // the quantized backward only ever reads the quantized
                    // operands — keep the f32 activations alive only for
                    // the f32 backward path
                    let xf = if audit_slot.is_none() && tape.is_some() {
                        x.data
                    } else {
                        mem.recycle_f32(x.data);
                        Vec::new()
                    };
                    if let Some(tape) = tape.as_deref_mut() {
                        tape.caches.push(NodeCache::Conv { x: xf, qw, qa, audit_slot });
                    }
                    Feat { data: z, c: l.co, h: ho, w: wo }
                }
                Op::BatchNorm(l) => {
                    let mut x = take_val(mem, &mut vals, &mut uses, node.inputs[0], &node.name);
                    assert_eq!(x.c, l.c, "{}: BN channel mismatch", node.name);
                    let (h, w) = (x.h, x.w);
                    let m = (n * h * w) as f64;
                    let plane = h * w;
                    let mut xhat = mem.take_f32(x.data.len());
                    let mut inv_std = mem.take_f32(l.c);
                    for ch in 0..l.c {
                        let mut sum = 0.0f64;
                        let mut sq = 0.0f64;
                        for nb in 0..n {
                            let base = (nb * l.c + ch) * plane;
                            for &v in &x.data[base..base + plane] {
                                sum += v as f64;
                                sq += v as f64 * v as f64;
                            }
                        }
                        let mean = sum / m;
                        let var = (sq / m - mean * mean).max(0.0);
                        let inv = 1.0 / (var + l.eps as f64).sqrt();
                        inv_std[ch] = inv as f32;
                        let (gam, bet) = (l.gamma[ch], l.beta[ch]);
                        for nb in 0..n {
                            let base = (nb * l.c + ch) * plane;
                            for idx in base..base + plane {
                                let xh = ((x.data[idx] as f64 - mean) * inv) as f32;
                                xhat[idx] = xh;
                                x.data[idx] = gam * xh + bet;
                            }
                        }
                    }
                    if let Some(tape) = tape.as_deref_mut() {
                        tape.caches.push(NodeCache::Bn { xhat, inv_std, h, w });
                    } else {
                        mem.recycle_f32(xhat);
                        mem.recycle_f32(inv_std);
                    }
                    x
                }
                Op::Relu => {
                    let mut x = take_val(mem, &mut vals, &mut uses, node.inputs[0], &node.name);
                    let mut pos = Vec::new();
                    if tape.is_some() {
                        pos = mem.take_bool(x.data.len());
                        for (p, &v) in pos.iter_mut().zip(x.data.iter()) {
                            *p = v > 0.0;
                        }
                    }
                    for v in x.data.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                    if let Some(tape) = tape.as_deref_mut() {
                        tape.caches.push(NodeCache::Relu { pos });
                    }
                    x
                }
                Op::GlobalAvgPool => {
                    let x = take_val(mem, &mut vals, &mut uses, node.inputs[0], &node.name);
                    let plane = x.h * x.w;
                    let mut y = mem.take_f32(n * x.c);
                    for nb in 0..n {
                        for ch in 0..x.c {
                            let base = (nb * x.c + ch) * plane;
                            let mut sum = 0.0f64;
                            for &v in &x.data[base..base + plane] {
                                sum += v as f64;
                            }
                            y[nb * x.c + ch] = (sum / plane as f64) as f32;
                        }
                    }
                    if let Some(tape) = tape.as_deref_mut() {
                        tape.caches.push(NodeCache::Gap { c: x.c, h: x.h, w: x.w });
                    }
                    mem.recycle_f32(x.data);
                    Feat { data: y, c: x.c, h: 1, w: 1 }
                }
                Op::Fc(l) => {
                    let x = take_val(mem, &mut vals, &mut uses, node.inputs[0], &node.name);
                    let din = x.c * x.h * x.w;
                    assert_eq!(din, l.din, "{}: FC input dim mismatch", node.name);
                    let mut y = mem.take_f32(n * l.dout);
                    for nb in 0..n {
                        let xin = &x.data[nb * din..(nb + 1) * din];
                        for o in 0..l.dout {
                            let wrow = &l.w[o * din..(o + 1) * din];
                            let mut acc = l.b[o] as f64;
                            for d in 0..din {
                                acc += wrow[d] as f64 * xin[d] as f64;
                            }
                            y[nb * l.dout + o] = acc as f32;
                        }
                    }
                    if let Some(tape) = tape.as_deref_mut() {
                        tape.caches.push(NodeCache::Fc { x: x.data });
                    } else {
                        mem.recycle_f32(x.data);
                    }
                    Feat { data: y, c: l.dout, h: 1, w: 1 }
                }
                Op::Add => {
                    let mut a = take_val(mem, &mut vals, &mut uses, node.inputs[0], &node.name);
                    let b = take_val(mem, &mut vals, &mut uses, node.inputs[1], &node.name);
                    assert_eq!(
                        (a.c, a.h, a.w),
                        (b.c, b.h, b.w),
                        "{}: residual operand shapes differ",
                        node.name
                    );
                    for (av, bv) in a.data.iter_mut().zip(&b.data) {
                        *av += *bv;
                    }
                    mem.recycle_f32(b.data);
                    if let Some(tape) = tape.as_deref_mut() {
                        tape.caches.push(NodeCache::None);
                    }
                    a
                }
            };
            vals[i + 1] = Some(out);
        }

        let out = vals[n_vals - 1].take().expect("graph output value");
        mem.put_graph_slots(vals, uses);
        assert_eq!(
            out.c * out.h * out.w,
            g.classes,
            "head output does not match the class count"
        );
        out.data
    }

    /// Backward through the graph: consumes the forward [`Tape`], seeds
    /// the final value's gradient with `dlogits`, walks the nodes in
    /// reverse accumulating per-value gradients (residual joins fan in by
    /// element-wise addition), and writes parameter gradients into `grads`
    /// (laid out like [`Graph::state`]). Quantized convs quantize E once
    /// and reuse it for both backward passes (Alg. 1); the conv reading
    /// the graph input skips its input gradient.
    pub fn backward(
        &self,
        mut tape: Tape,
        dlogits: Vec<f32>,
        n: usize,
        rng: &mut Pcg32,
        grads: &mut [f32],
        audit: &mut StepAudit,
    ) {
        self.backward_mem(&mut tape, dlogits, n, rng, grads, audit, &mut StepMem::Heap);
    }

    /// [`Self::backward`] with explicit step memory (see
    /// [`Self::forward_mem`]). The tape is drained in place, so arena
    /// steps reuse its cache-entry capacity across steps.
    pub(crate) fn backward_mem(
        &self,
        tape: &mut Tape,
        dlogits: Vec<f32>,
        n: usize,
        rng: &mut Pcg32,
        grads: &mut [f32],
        audit: &mut StepAudit,
        mem: &mut StepMem,
    ) {
        let g = self.graph;
        assert_eq!(grads.len(), g.state_len(), "gradient buffer length mismatch");
        assert_eq!(tape.caches.len(), g.nodes.len(), "one cache entry per node");
        let n_vals = g.nodes.len() + 1;
        let mut gslots = mem.take_grad_slots(n_vals);
        gslots[n_vals - 1] = Some(dlogits);
        // reverse-cursor parameter offsets: walking the nodes in reverse
        // while subtracting each `param_len` reproduces `param_offsets()`
        // without materializing the offset table
        let mut off_i = g.state_len();

        for (i, node) in g.nodes.iter().enumerate().rev() {
            off_i -= node.param_len();
            let gout = gslots[i + 1]
                .take()
                .unwrap_or_else(|| panic!("{}: missing output gradient", node.name));
            let cache = std::mem::replace(&mut tape.caches[i], NodeCache::None);
            match (&node.op, cache) {
                (Op::Fc(l), NodeCache::Fc { x }) => {
                    let gw = &mut grads[off_i..off_i + l.w.len() + l.b.len()];
                    for nb in 0..n {
                        let xin = &x[nb * l.din..(nb + 1) * l.din];
                        let grow = &gout[nb * l.dout..(nb + 1) * l.dout];
                        for o in 0..l.dout {
                            let go = grow[o];
                            for d in 0..l.din {
                                gw[o * l.din + d] += go * xin[d];
                            }
                            gw[l.w.len() + o] += go;
                        }
                    }
                    let mut dx = mem.take_f32(x.len());
                    for nb in 0..n {
                        let grow = &gout[nb * l.dout..(nb + 1) * l.dout];
                        let drow = &mut dx[nb * l.din..(nb + 1) * l.din];
                        for o in 0..l.dout {
                            let go = grow[o];
                            let wrow = &l.w[o * l.din..(o + 1) * l.din];
                            for d in 0..l.din {
                                drow[d] += go * wrow[d];
                            }
                        }
                    }
                    accumulate(mem, &mut gslots[node.inputs[0]], dx);
                    mem.recycle_f32(gout);
                    mem.recycle_f32(x);
                }
                (Op::GlobalAvgPool, NodeCache::Gap { c, h, w }) => {
                    let plane = h * w;
                    let mut dx = mem.take_f32(n * c * plane);
                    for nb in 0..n {
                        for ch in 0..c {
                            let gv = gout[nb * c + ch] / plane as f32;
                            let base = (nb * c + ch) * plane;
                            for slot in &mut dx[base..base + plane] {
                                *slot = gv;
                            }
                        }
                    }
                    accumulate(mem, &mut gslots[node.inputs[0]], dx);
                    mem.recycle_f32(gout);
                }
                (Op::Relu, NodeCache::Relu { pos }) => {
                    let mut gv = gout;
                    for (gvv, &p) in gv.iter_mut().zip(&pos) {
                        if !p {
                            *gvv = 0.0;
                        }
                    }
                    accumulate(mem, &mut gslots[node.inputs[0]], gv);
                    mem.recycle_bool(pos);
                }
                (Op::BatchNorm(l), NodeCache::Bn { xhat, inv_std, h, w }) => {
                    let mut gv = gout;
                    let plane = h * w;
                    let m = (n * plane) as f64;
                    let gg = &mut grads[off_i..off_i + 2 * l.c];
                    for ch in 0..l.c {
                        let mut sum_dy = 0.0f64;
                        let mut sum_dy_xhat = 0.0f64;
                        for nb in 0..n {
                            let base = (nb * l.c + ch) * plane;
                            for idx in base..base + plane {
                                sum_dy += gv[idx] as f64;
                                sum_dy_xhat += gv[idx] as f64 * xhat[idx] as f64;
                            }
                        }
                        gg[ch] += sum_dy_xhat as f32; // dgamma
                        gg[l.c + ch] += sum_dy as f32; // dbeta
                        let scale = l.gamma[ch] as f64 * inv_std[ch] as f64;
                        let mean_dy = sum_dy / m;
                        let mean_dy_xhat = sum_dy_xhat / m;
                        for nb in 0..n {
                            let base = (nb * l.c + ch) * plane;
                            for idx in base..base + plane {
                                gv[idx] = (scale
                                    * (gv[idx] as f64 - mean_dy - xhat[idx] as f64 * mean_dy_xhat))
                                    as f32;
                            }
                        }
                    }
                    accumulate(mem, &mut gslots[node.inputs[0]], gv);
                    mem.recycle_f32(xhat);
                    mem.recycle_f32(inv_std);
                }
                (Op::Conv(l), NodeCache::Conv { x, qw, qa, audit_slot }) => {
                    let spec = l.spec();
                    let (ho, wo) = (spec.out_h(), spec.out_w());
                    let eshape = [n, l.co, ho, wo];
                    let need_dx = node.inputs[0] != INPUT;
                    if l.quantized && self.qcfg.enabled {
                        let slot = audit_slot.expect("quantized conv has an audit slot");
                        if let (Some(qw), Some(qa)) = (qw, qa) {
                            // Alg. 1: quantize E once, reuse for both passes
                            let qe = quantize_dyn(&gout, &eshape, self.qcfg, Some(&mut *rng));
                            mem.recycle_f32(gout);
                            let gw = &mut grads[off_i..off_i + l.w.len()];
                            let wg = with_label(&format!("{}:wgrad", node.name), || {
                                spec.weight_grad(&qe, &qa, self.threads)
                            });
                            audit.layers[slot].wgrad.absorb(&wg);
                            gw.copy_from_slice(&wg.z);
                            if need_dx {
                                let dg = with_label(&format!("{}:dgrad", node.name), || {
                                    spec.input_grad(&qe, &qw, self.threads)
                                });
                                audit.layers[slot].dgrad.absorb(&dg);
                                accumulate(mem, &mut gslots[node.inputs[0]], dg.z);
                            }
                        } else {
                            let gw = &mut grads[off_i..off_i + l.w.len()];
                            let dx_slot =
                                if need_dx { Some(&mut gslots[node.inputs[0]]) } else { None };
                            self.arena_conv_backward(
                                mem, i, l, &spec, gout, n, rng, gw, audit, slot, dx_slot,
                            );
                        }
                    } else {
                        let gw = &mut grads[off_i..off_i + l.w.len()];
                        with_conv_label(mem, i, PASS_WGRAD, &node.name, || {
                            conv2d_f32_wgrad_into(
                                &gout,
                                eshape,
                                &x,
                                [n, l.ci, l.hin, l.win],
                                l.stride,
                                l.pad,
                                l.k,
                                l.k,
                                self.threads,
                                gw,
                            );
                        });
                        if need_dx {
                            let mut dx = mem.take_f32(n * l.ci * l.hin * l.win);
                            with_conv_label(mem, i, PASS_DGRAD, &node.name, || {
                                conv2d_f32_dgrad_into(
                                    &gout,
                                    eshape,
                                    &l.w,
                                    [l.co, l.ci, l.k, l.k],
                                    l.stride,
                                    l.pad,
                                    l.hin,
                                    l.win,
                                    self.threads,
                                    &mut dx,
                                );
                            });
                            accumulate(mem, &mut gslots[node.inputs[0]], dx);
                        }
                        mem.recycle_f32(gout);
                        mem.recycle_f32(x);
                    }
                }
                (Op::Add, NodeCache::None) => {
                    let mut dup = mem.take_f32(gout.len());
                    dup.copy_from_slice(&gout);
                    accumulate(mem, &mut gslots[node.inputs[0]], gout);
                    accumulate(mem, &mut gslots[node.inputs[1]], dup);
                }
                _ => unreachable!("cache kind does not match node kind"),
            }
        }
        mem.put_grad_slots(gslots);
    }

    /// Forward one quantized conv from the step arena: W and A quantize
    /// straight into the node's persistent plane slots (RNG stream order
    /// identical to the heap path's two [`quantize_dyn`] calls), the
    /// weight panel packs once into its persistent buffer, and the
    /// engine runs into a pooled output. Values are bit-identical to
    /// `spec.forward(&qw, &qa, ..)` on freshly quantized tensors.
    ///
    /// When the arena is weight-frozen (serving) and the conv's
    /// [`WeightPanels`](crate::nn::arena::WeightPanels) are ready, the
    /// weight quantize+pack is skipped entirely and the cached planes
    /// and panels are replayed. That skip is bit-neutral only for
    /// RNG-free (evaluation) forwards — with no RNG the weight
    /// [`offsets_dyn`] draws nothing and nearest rounding makes the
    /// cached planes identical to a requantize — so the cache is
    /// bypassed whenever an RNG is present.
    #[allow(clippy::too_many_arguments)]
    fn arena_conv_forward(
        &self,
        mem: &mut StepMem,
        i: usize,
        l: &ConvLayer,
        spec: &ConvSpec,
        x: &Feat,
        n: usize,
        mut rng: Option<&mut Pcg32>,
        audit: &mut StepAudit,
        slot: usize,
    ) -> Vec<f32> {
        // only a deterministic (RNG-free) quantize may populate or reuse
        // the frozen cache: stochastic weight planes differ per draw
        let deterministic = rng.is_none();
        let mut cs = mem.take_conv_slots(i);
        let refresh_w = !(mem.weights_frozen() && deterministic && cs.wp.ready);
        let mut off = mem.take_offsets();
        if refresh_w {
            let wcfg = offsets_dyn(self.qcfg, rng.as_deref_mut(), l.w.len(), &mut off);
            quantize_into_planes(&l.w, &[l.co, l.ci, l.k, l.k], &wcfg, &off, &mut cs.wp.qw);
        }
        let acfg = offsets_dyn(self.qcfg, rng.as_deref_mut(), x.data.len(), &mut off);
        quantize_into_planes(&x.data, &[n, x.c, x.h, x.w], &acfg, &off, &mut cs.qa);
        if refresh_w {
            pack::pack_weights_into(
                &cs.wp.qw.planes,
                l.co,
                l.ci * l.k * l.k,
                self.threads,
                &mut cs.wp.pw,
            );
            cs.wp.ready = deterministic;
        }
        let (ho, wo) = (spec.out_h(), spec.out_w());
        let mut z = mem.take_f32(n * l.co * ho * wo);
        let au = with_label(&cs.label_fwd, || {
            spec.forward_view(
                OperandView::of_fused(&cs.wp.qw),
                &cs.wp.qw.planes,
                OperandView::of_fused(&cs.qa),
                &cs.qa.planes,
                n,
                l.co,
                l.ci,
                self.threads,
                &cs.wp.pw,
                &mut z,
            )
        });
        audit.layers[slot].forward.absorb_engine(&au);
        mem.put_offsets(off);
        mem.put_conv_slots(i, cs);
        z
    }

    /// Backward one quantized conv from the step arena: E quantizes into
    /// the node's persistent slots (same RNG draw as the heap path's
    /// [`quantize_dyn`]), then both Alg. 1 passes reuse the forward's
    /// quantized W/A — the transposed operand layouts the engine needs
    /// are produced by relaying out the decoded planes and group scales
    /// directly (pinned against the `MlsTensor` transposes by
    /// `plane_transposes_match_tensor_relayouts`), never rebuilding an
    /// element-wise tensor.
    #[allow(clippy::too_many_arguments)]
    fn arena_conv_backward(
        &self,
        mem: &mut StepMem,
        i: usize,
        l: &ConvLayer,
        spec: &ConvSpec,
        gout: Vec<f32>,
        n: usize,
        rng: &mut Pcg32,
        gw: &mut [f32],
        audit: &mut StepAudit,
        slot: usize,
        dx_slot: Option<&mut Option<Vec<f32>>>,
    ) {
        let (ho, wo) = (spec.out_h(), spec.out_w());
        let mut cs = mem.take_conv_slots(i);
        let mut off = mem.take_offsets();
        let ecfg = offsets_dyn(self.qcfg, Some(rng), gout.len(), &mut off);
        quantize_into_planes(&gout, &[n, l.co, ho, wo], &ecfg, &off, &mut cs.qe);
        mem.recycle_f32(gout);

        // wgrad: stationary E^T [Co, N, Ho, Wo], gathered A^T [Ci, N, H, W]
        planes::transpose01_planes(&cs.qe.planes, n, l.co, ho * wo, false, &mut cs.et_planes);
        planes::transpose01_groups(
            &cs.qe.sg_exp,
            &cs.qe.sg_man,
            n,
            l.co,
            &mut cs.et_sg_exp,
            &mut cs.et_sg_man,
        );
        planes::transpose01_planes(
            &cs.qa.planes,
            n,
            l.ci,
            l.hin * l.win,
            false,
            &mut cs.at_planes,
        );
        planes::transpose01_groups(
            &cs.qa.sg_exp,
            &cs.qa.sg_man,
            n,
            l.ci,
            &mut cs.at_sg_exp,
            &mut cs.at_sg_man,
        );
        pack::pack_weights_into(&cs.et_planes, l.co, n * ho * wo, self.threads, &mut cs.pw_wgrad);
        let mut zt = mem.take_f32(l.ci * l.co * l.k * l.k);
        let au = with_label(&cs.label_wgrad, || {
            spec::run_engine_view(
                OperandView {
                    s_t: cs.qe.s_t,
                    sg_exp: &cs.et_sg_exp,
                    sg_man: &cs.et_sg_man,
                    fmt: cs.qe.planes.fmt,
                },
                &cs.et_planes,
                OperandView {
                    s_t: cs.qa.s_t,
                    sg_exp: &cs.at_sg_exp,
                    sg_man: &cs.at_sg_man,
                    fmt: cs.qa.planes.fmt,
                },
                &cs.at_planes,
                l.ci,
                l.co,
                spec.wgrad_dims(n),
                self.threads,
                &cs.pw_wgrad,
                &mut zt,
            )
        });
        audit.layers[slot].wgrad.absorb_engine(&au);
        // the engine emits [Ci, Co, Kh, Kw]; parameters are [Co, Ci, Kh, Kw]
        spec::transpose01_copy(&zt, l.ci, l.co, l.k * l.k, gw);
        mem.recycle_f32(zt);

        if let Some(dx_slot) = dx_slot {
            // dgrad: stationary kernel-flipped W^T [Ci, Co, Kh, Kw], gathered E
            planes::transpose01_planes(
                &cs.wp.qw.planes,
                l.co,
                l.ci,
                l.k * l.k,
                true,
                &mut cs.wt_planes,
            );
            planes::transpose01_groups(
                &cs.wp.qw.sg_exp,
                &cs.wp.qw.sg_man,
                l.co,
                l.ci,
                &mut cs.wt_sg_exp,
                &mut cs.wt_sg_man,
            );
            pack::pack_weights_into(
                &cs.wt_planes,
                l.ci,
                l.co * l.k * l.k,
                self.threads,
                &mut cs.pw_dgrad,
            );
            let mut dx = mem.take_f32(n * l.ci * l.hin * l.win);
            let au = with_label(&cs.label_dgrad, || {
                spec::run_engine_view(
                    OperandView {
                        s_t: cs.wp.qw.s_t,
                        sg_exp: &cs.wt_sg_exp,
                        sg_man: &cs.wt_sg_man,
                        fmt: cs.wp.qw.planes.fmt,
                    },
                    &cs.wt_planes,
                    OperandView::of_fused(&cs.qe),
                    &cs.qe.planes,
                    n,
                    l.ci,
                    spec.dgrad_dims(l.co),
                    self.threads,
                    &cs.pw_dgrad,
                    &mut dx,
                )
            });
            audit.layers[slot].dgrad.absorb_engine(&au);
            accumulate(mem, dx_slot, dx);
        }
        mem.put_offsets(off);
        mem.put_conv_slots(i, cs);
    }
}

// ---------------------------------------------------------------------------
// Lowering from the analytic zoo
// ---------------------------------------------------------------------------

/// A residual basic block recognized in a zoo layer list: two main-branch
/// `Conv, BN` pairs, an optional `Conv(*s), BN` projection shortcut, and
/// the `EwAdd` join.
struct BlockPlan {
    conv1: usize,
    bn1: usize,
    conv2: usize,
    bn2: usize,
    shortcut: Option<(usize, usize)>,
    ewadd: usize,
}

/// Graph-under-construction: nodes plus the shape of every value.
struct Lowerer {
    nodes: Vec<Node>,
    /// shape (c, h, w) per value id; `shapes[0]` is the graph input
    shapes: Vec<(usize, usize, usize)>,
    rng: Pcg32,
    bn_n: usize,
    relu_n: usize,
    add_n: usize,
}

impl Lowerer {
    fn push(&mut self, name: String, op: Op, inputs: Vec<ValueId>, shape: (usize, usize, usize)) -> ValueId {
        self.nodes.push(Node { name, op, inputs });
        self.shapes.push(shape);
        self.nodes.len() // the value id of the new node's output
    }

    /// Lower one zoo conv (He-initialized, "same"-padded odd kernel).
    fn conv(&mut self, zl: &Layer, from: ValueId) -> Result<ValueId> {
        let Layer::Conv { name, cin, cout, k, stride, h, w, hin, win, quantized } = zl else {
            bail!("lowering expected a conv layer");
        };
        let (fc, fh, fw) = self.shapes[from];
        ensure!(
            *cin == fc && *hin == fh && *win == fw,
            "conv {name}: zoo input {cin}x{hin}x{win} != lowered input {fc}x{fh}x{fw}"
        );
        ensure!(*k % 2 == 1, "conv {name}: only odd kernels lower to 'same' padding");
        let pad = (*k - 1) / 2;
        let ho = (fh + 2 * pad - *k) / *stride + 1;
        let wo = (fw + 2 * pad - *k) / *stride + 1;
        ensure!(
            ho == *h && wo == *w,
            "conv {name}: lowered output {ho}x{wo} != zoo output {h}x{w}"
        );
        // He initialization (same draw order and sigma as the historical
        // chain builder, so chain-model init is bit-identical)
        let sigma = (2.0 / (cin * k * k) as f32).sqrt();
        let wts = self.rng.normal_vec(cout * cin * k * k, sigma);
        Ok(self.push(
            name.clone(),
            Op::Conv(ConvLayer {
                w: wts,
                co: *cout,
                ci: *cin,
                k: *k,
                stride: *stride,
                pad,
                hin: fh,
                win: fw,
                quantized: *quantized,
            }),
            vec![from],
            (*cout, ho, wo),
        ))
    }

    fn bn(&mut self, zl: &Layer, from: ValueId) -> Result<ValueId> {
        let Layer::BatchNorm { c, .. } = zl else {
            bail!("lowering expected a BN layer");
        };
        let (fc, fh, fw) = self.shapes[from];
        ensure!(*c == fc, "bn: zoo channels {c} != lowered input channels {fc}");
        self.bn_n += 1;
        Ok(self.push(
            format!("bn{}", self.bn_n),
            Op::BatchNorm(BnLayer {
                c: fc,
                gamma: vec![1.0; fc],
                beta: vec![0.0; fc],
                eps: 1e-5,
            }),
            vec![from],
            (fc, fh, fw),
        ))
    }

    fn relu(&mut self, from: ValueId) -> ValueId {
        let shape = self.shapes[from];
        self.relu_n += 1;
        self.push(format!("relu{}", self.relu_n), Op::Relu, vec![from], shape)
    }
}

/// Recognize the residual basic blocks in a zoo layer list. A block ends
/// at `EwAdd`; the zoo emits `Conv, BN, Conv, BN [, Conv("..s"), BN]`
/// before it. The `s` name suffix is the zoo's projection-shortcut
/// marker (`zoo::B::basic_block` is the only emitter and documents the
/// contract); a misclassification cannot slip through silently — the
/// lowering's channel/shape `ensure!`s reject any block whose branches
/// do not line up.
fn plan_blocks(layers: &[Layer]) -> Result<Vec<BlockPlan>> {
    let is_bn = |i: usize| matches!(layers.get(i), Some(Layer::BatchNorm { .. }));
    let conv_name = |i: usize| match layers.get(i) {
        Some(Layer::Conv { name, .. }) => Some(name.as_str()),
        _ => None,
    };
    let mut plans = Vec::new();
    for (j, layer) in layers.iter().enumerate() {
        if !matches!(layer, Layer::EwAdd { .. }) {
            continue;
        }
        let plan = if j >= 6
            && conv_name(j - 2).is_some_and(|nm| nm.ends_with('s'))
            && is_bn(j - 1)
        {
            ensure!(
                conv_name(j - 6).is_some() && is_bn(j - 5) && conv_name(j - 4).is_some() && is_bn(j - 3),
                "residual join at zoo layer {j}: projection block must be Conv,BN,Conv,BN,Conv,BN"
            );
            BlockPlan {
                conv1: j - 6,
                bn1: j - 5,
                conv2: j - 4,
                bn2: j - 3,
                shortcut: Some((j - 2, j - 1)),
                ewadd: j,
            }
        } else {
            ensure!(
                j >= 4 && conv_name(j - 4).is_some() && is_bn(j - 3) && conv_name(j - 2).is_some() && is_bn(j - 1),
                "residual join at zoo layer {j}: identity block must be Conv,BN,Conv,BN"
            );
            BlockPlan {
                conv1: j - 4,
                bn1: j - 3,
                conv2: j - 2,
                bn2: j - 1,
                shortcut: None,
                ewadd: j,
            }
        };
        plans.push(plan);
    }
    Ok(plans)
}

/// Lower an analytic zoo [`Network`] into an executable [`Graph`]: the
/// ONE construction path shared by every native model (`cnn_t`, `cnn_s`,
/// `resnet_t` — see [`crate::nn::zoo::native_network`]).
///
/// * chain `Conv, BN` pairs lower to `Conv -> BN -> ReLU`,
/// * residual basic blocks (recognized by their `EwAdd` join) lower to
///   `Conv -> BN -> ReLU -> Conv -> BN` plus an identity or
///   1x1-projection shortcut, joined by [`Op::Add`] and a trailing ReLU,
/// * the classifier lowers to `GlobalAvgPool -> Fc` (the pool is skipped
///   when the feature map is already 1x1).
///
/// Initialization is deterministic in `seed` (He-init convs, unit BN,
/// zero FC bias), drawing in zoo declaration order — chain models
/// reproduce the historical chain-builder state bit-exactly.
pub fn lower(net: &Network, seed: u64) -> Result<Graph> {
    let layers = &net.layers;
    let plans = plan_blocks(layers)?;
    let block_at: BTreeMap<usize, usize> =
        plans.iter().enumerate().map(|(bi, p)| (p.conv1, bi)).collect();

    let mut lo = Lowerer {
        nodes: Vec::new(),
        shapes: vec![net.input],
        rng: Pcg32::new(seed, 0x6e61_7469),
        bn_n: 0,
        relu_n: 0,
        add_n: 0,
    };
    let mut cur: ValueId = INPUT;
    let mut classes = None;

    let mut i = 0usize;
    while i < layers.len() {
        if let Some(&bi) = block_at.get(&i) {
            let plan = &plans[bi];
            let block_in = cur;
            // main branch
            let v = lo.conv(&layers[plan.conv1], block_in)?;
            let v = lo.bn(&layers[plan.bn1], v)?;
            let v = lo.relu(v);
            let v = lo.conv(&layers[plan.conv2], v)?;
            let main_tail = lo.bn(&layers[plan.bn2], v)?;
            // shortcut branch
            let skip_tail = match plan.shortcut {
                Some((cs, bs)) => {
                    let s = lo.conv(&layers[cs], block_in)?;
                    lo.bn(&layers[bs], s)?
                }
                None => block_in,
            };
            ensure!(
                lo.shapes[main_tail] == lo.shapes[skip_tail],
                "residual join at zoo layer {}: branch shapes {:?} vs {:?}",
                plan.ewadd,
                lo.shapes[main_tail],
                lo.shapes[skip_tail]
            );
            lo.add_n += 1;
            let joined = lo.push(
                format!("add{}", lo.add_n),
                Op::Add,
                vec![main_tail, skip_tail],
                lo.shapes[main_tail],
            );
            cur = lo.relu(joined);
            i = plan.ewadd + 1;
        } else {
            match &layers[i] {
                zl @ Layer::Conv { .. } => {
                    cur = lo.conv(zl, cur)?;
                    i += 1;
                    if matches!(layers.get(i), Some(Layer::BatchNorm { .. })) {
                        cur = lo.bn(&layers[i], cur)?;
                        i += 1;
                    }
                    cur = lo.relu(cur);
                }
                Layer::Fc { din, dout } => {
                    let (fc, fh, fw) = lo.shapes[cur];
                    if fh * fw > 1 {
                        cur = lo.push(
                            "gap".to_string(),
                            Op::GlobalAvgPool,
                            vec![cur],
                            (fc, 1, 1),
                        );
                    }
                    let dflat = lo.shapes[cur].0;
                    ensure!(
                        dflat == *din,
                        "fc: zoo input dim {din} != lowered input dim {dflat}"
                    );
                    let sigma = (2.0 / dflat as f32).sqrt();
                    let wts = lo.rng.normal_vec(dout * dflat, sigma);
                    cur = lo.push(
                        "fc".to_string(),
                        Op::Fc(FcLayer {
                            din: dflat,
                            dout: *dout,
                            w: wts,
                            b: vec![0.0; *dout],
                        }),
                        vec![cur],
                        (*dout, 1, 1),
                    );
                    classes = Some(*dout);
                    i += 1;
                }
                Layer::BatchNorm { .. } => {
                    bail!("cannot lower: BatchNorm at zoo layer {i} without a preceding conv")
                }
                Layer::EwAdd { .. } => {
                    bail!("cannot lower: unrecognized residual topology at zoo layer {i}")
                }
            }
        }
    }

    let classes = classes
        .ok_or_else(|| anyhow::anyhow!("cannot lower: network has no classifier head"))?;
    ensure!(
        matches!(lo.nodes.last().map(|n| &n.op), Some(Op::Fc(_))),
        "cannot lower: the classifier head must be the final layer"
    );
    Ok(Graph { nodes: lo.nodes, input: net.input, classes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    #[test]
    fn chain_lowering_matches_historical_node_sequence() {
        // cnn_t must lower to the exact node sequence the PR 4 chain
        // trainer executed: (Conv, BN, ReLU) x4, GAP, FC
        let net = zoo::native_network("cnn_t").unwrap();
        let g = lower(&net, 1).unwrap();
        let kinds: Vec<&str> = g
            .nodes
            .iter()
            .map(|n| match &n.op {
                Op::Conv(_) => "conv",
                Op::BatchNorm(_) => "bn",
                Op::Relu => "relu",
                Op::GlobalAvgPool => "gap",
                Op::Fc(_) => "fc",
                Op::Add => "add",
            })
            .collect();
        assert_eq!(
            kinds,
            vec![
                "conv", "bn", "relu", "conv", "bn", "relu", "conv", "bn", "relu", "conv", "bn",
                "relu", "gap", "fc"
            ]
        );
        // a pure chain: every node consumes the previous value
        for (i, node) in g.nodes.iter().enumerate() {
            assert_eq!(node.inputs, vec![i], "node {i} must consume value {i}");
        }
        assert_eq!(g.classes, 10);
    }

    #[test]
    fn resnet_lowering_builds_residual_joins() {
        let net = zoo::native_network("resnet_t").unwrap();
        let g = lower(&net, 2).unwrap();
        let adds: Vec<&Node> =
            g.nodes.iter().filter(|n| matches!(n.op, Op::Add)).collect();
        assert_eq!(adds.len(), 3, "resnet_t has three residual joins");
        for a in &adds {
            assert_eq!(a.inputs.len(), 2, "{}: joins take two inputs", a.name);
        }
        // block 1 is an identity block: its Add reads a ReLU output (the
        // block input value) directly; blocks 2 and 3 project through a
        // quantized 1x1 conv + BN
        let convs: Vec<&Node> =
            g.nodes.iter().filter(|n| matches!(n.op, Op::Conv(_))).collect();
        assert_eq!(convs.len(), 9, "stem + 2 + 3 + 3 convs");
        let proj: Vec<&Node> =
            convs.iter().filter(|n| n.name.ends_with('s')).copied().collect();
        assert_eq!(proj.len(), 2, "two projection shortcuts");
        for p in &proj {
            let Op::Conv(l) = &p.op else { unreachable!() };
            assert_eq!(l.k, 1, "{}: projection shortcut is 1x1", p.name);
            assert_eq!(l.stride, 2, "{}: projection shortcut strides", p.name);
            assert!(l.quantized, "{}: shortcuts run Alg. 1 like any conv", p.name);
        }
        // exactly one conv reads the graph input (the fp32 stem)
        let stems: Vec<&Node> =
            convs.iter().filter(|n| n.inputs[0] == INPUT).copied().collect();
        assert_eq!(stems.len(), 1);
        let Op::Conv(stem) = &stems[0].op else { unreachable!() };
        assert!(!stem.quantized, "the stem stays fp32");
        assert_eq!(g.classes, 10);
        // state round-trips through the flat vector
        let mut g = g;
        let s = g.state();
        assert_eq!(s.len(), g.state_len());
        g.load_state(&s).unwrap();
        assert_eq!(g.state(), s);
        assert!(g.load_state(&s[..s.len() - 1]).is_err());
    }

    #[test]
    fn lowering_is_deterministic_in_the_seed() {
        let net = zoo::native_network("resnet_t").unwrap();
        let a = lower(&net, 7).unwrap().state();
        let b = lower(&net, 7).unwrap().state();
        let c = lower(&net, 8).unwrap().state();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn audit_stream_rolls_up() {
        let mut audit = StepAudit::default();
        for (i, mul) in [(0usize, 10u64), (1, 20)] {
            let mut la = LayerAudit { node: i, name: format!("conv{i}"), ..Default::default() };
            la.forward.convs = 1;
            la.forward.mul_ops = mul;
            la.forward.peak_acc_bits = 4 + i as u32;
            audit.layers.push(la);
        }
        audit.roll_up();
        assert_eq!(audit.forward.convs, 2);
        assert_eq!(audit.forward.mul_ops, 30);
        assert_eq!(audit.forward.peak_acc_bits, 5);
        assert_eq!(audit.wgrad, PassCounters::default());
        let j = audit.to_json("m", "cfg", 4, 2);
        assert_eq!(j.get("audit").and_then(Json::as_str), Some("train_step"));
        assert_eq!(j.get("batch").and_then(Json::as_f64), Some(4.0));
        assert_eq!(j.get("layers").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        let totals = j.get("totals").unwrap();
        assert_eq!(
            totals.get("forward").unwrap().get("mul_ops").and_then(Json::as_f64),
            Some(30.0)
        );
        // the record prints as a single JSON line (the .audit.jsonl format)
        let line = j.to_string_compact();
        assert!(!line.contains('\n'));
        assert_eq!(Json::parse(&line).unwrap(), j);
    }
}
