//! Analytic training-op accounting (paper Table I / Table VI inputs).
//!
//! Counts are PER SAMPLE (the paper's "divided by batch size" convention);
//! weight-indexed work that happens once per STEP (weight dynamic
//! quantization, SGD update) is amortized over the batch.
//!
//! Backward convolutions follow Alg. 1: every conv layer runs a weight-
//! gradient conv (Conv(qE, qA), same MAC count as forward) and, except for
//! the first layer, an input-gradient conv (Conv^T(qE, qW), same MAC
//! count). BN costs 9 muls + 10 adds per element over forward + backward
//! (paper Sec. VI-E); dynamic quantization costs 4 muls + 2 adds per
//! quantized element; the MLS element-wise addition needs one extra mul
//! for the tensor-scale alignment (Table VI "EW-Add / FloatMul" row).
//!
//! The native trainer's executable graphs are LOWERED from the same zoo
//! `Network`s this module counts ([`crate::nn::zoo::native_network`] ->
//! [`crate::nn::graph::lower`]), so the analytic counts and the executed
//! per-layer audit stream share one geometry source by construction.
//!
//! Two conventions to be aware of when comparing against the EXECUTED
//! audit counters of the native Alg. 1 kernels (pinned by
//! `rust/tests/train_ops_crosscheck.rs`):
//!
//! * conv MAC counts here are full-window (`cin*cout*k^2*h*w`); the
//!   kernels count only in-bounds taps, so executed MACs run a few
//!   percent lower on padded 3x3 layers and match exactly on unpadded /
//!   1x1 layers. Within each step the three executed passes are equal to
//!   one another, exactly as this model assumes.
//! * `tree_adds`/`group_scale_ops` use the paper's Table VI convention
//!   `MACs / K^2` for ALL passes. The executed backward passes reduce
//!   along different axes (wgrad trees over the batch with `Ho*Wo`-deep
//!   groups, dgrad trees over `Co` on the `hin x win` grid), so their
//!   true tree/scale counts differ from the forward-shaped approximation;
//!   the cross-check test records both. The energy tables keep the
//!   paper's convention so Table VI reproduces as published.

use super::zoo::{Layer, Network};

/// Raw op amounts for one training step, per sample.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TrainingOps {
    /// conv MACs executed on the (potentially) low-bit unit, fwd + bwd,
    /// split by whether the layer is quantized in the MLS framework
    pub conv_macs_quantized: f64,
    pub conv_macs_unquantized: f64,
    /// inter-group adder-tree additions (one tree output per
    /// (sample, co, ci-reduction, pixel)): sum over convs of macs / K^2
    pub tree_adds: f64,
    /// group-scale unit applications (MLS only; == tree inputs)
    pub group_scale_ops: f64,
    /// BN elements processed (x9 mul, x10 add)
    pub bn_elements: f64,
    /// FC MACs, fwd + bwd (x3 of inference)
    pub fc_macs: f64,
    /// residual element-wise additions
    pub ewadd_elements: f64,
    /// parameters updated by SGD (amortized per sample)
    pub sgd_params: f64,
    /// dynamically quantized elements: weights (amortized), activations,
    /// errors (MLS only)
    pub dq_weight_elements: f64,
    pub dq_act_elements: f64,
    pub dq_err_elements: f64,
}

impl TrainingOps {
    pub fn total_conv_macs(&self) -> f64 {
        self.conv_macs_quantized + self.conv_macs_unquantized
    }

    pub fn dq_elements(&self) -> f64 {
        self.dq_weight_elements + self.dq_act_elements + self.dq_err_elements
    }
}

/// Count the training ops of `net` with weight work amortized over `batch`.
pub fn count_training_ops(net: &Network, batch: usize) -> TrainingOps {
    let b = batch.max(1) as f64;
    let mut t = TrainingOps::default();
    let mut first_conv = true;

    for layer in &net.layers {
        match layer {
            Layer::Conv { cin, cout, k, h, w, hin, win, quantized, .. } => {
                let macs = (cin * cout * k * k * h * w) as f64;
                // fwd + grad-W (+ grad-A unless this is the first conv)
                let n_convs = if first_conv { 2.0 } else { 3.0 };
                let total = macs * n_convs;
                if *quantized {
                    t.conv_macs_quantized += total;
                    t.tree_adds += total / (*k * *k) as f64;
                    t.group_scale_ops += total / (*k * *k) as f64;
                    // DQ: W once per step; A once per fwd; E once per bwd.
                    // A uses the EXACT input spatial dims — the historical
                    // `h * w * stride^2` approximation over-counted
                    // whenever "same"-padded striding ceils an odd input.
                    t.dq_weight_elements += (cin * cout * k * k) as f64 / b;
                    t.dq_act_elements += (cin * hin * win) as f64;
                    t.dq_err_elements += (cout * h * w) as f64;
                } else {
                    t.conv_macs_unquantized += total;
                }
                first_conv = false;
            }
            Layer::BatchNorm { c, h, w } => {
                t.bn_elements += (c * h * w) as f64;
            }
            Layer::Fc { din, dout } => {
                t.fc_macs += (din * dout) as f64 * 3.0;
                t.sgd_params += (din * dout + dout) as f64 / b;
            }
            Layer::EwAdd { c, h, w } => {
                t.ewadd_elements += (c * h * w) as f64;
            }
        }
    }
    // conv + BN parameters in the SGD update
    let conv_bn_params: u64 = net
        .layers
        .iter()
        .map(|l| match l {
            Layer::Conv { cin, cout, k, .. } => (cin * cout * k * k) as u64,
            Layer::BatchNorm { c, .. } => 2 * *c as u64,
            _ => 0,
        })
        .sum();
    t.sgd_params += conv_bn_params as f64 / b;
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo::network;

    #[test]
    fn resnet18_table1_shape() {
        // Table I: Conv F = 1.88E+09, Conv B = 4.22E+09 per sample. Our
        // analytic F must match within 6%, and B must be ~2.2x F.
        let net = network("resnet18").unwrap();
        let fwd = net.inference_macs() as f64
            - net
                .layers
                .iter()
                .map(|l| if let Layer::Fc { din, dout } = l { (din * dout) as f64 } else { 0.0 })
                .sum::<f64>();
        assert!((fwd / 1.88e9 - 1.0).abs() < 0.06, "fwd {fwd:.3e}");
        let t = count_training_ops(&net, 64);
        let bwd = t.total_conv_macs() - fwd;
        let ratio = bwd / fwd;
        assert!((1.7..2.4).contains(&ratio), "B/F ratio {ratio}");
    }

    #[test]
    fn googlenet_table1_shape() {
        let net = network("googlenet").unwrap();
        let t = count_training_ops(&net, 64);
        // Table I: F 1.58e9, B 3.05e9 -> total ~4.6e9
        let total = t.total_conv_macs();
        assert!((3.9e9..5.3e9).contains(&total), "total {total:.3e}");
    }

    #[test]
    fn tree_adds_are_macs_over_k2() {
        let net = network("resnet20").unwrap();
        let t = count_training_ops(&net, 1);
        // every quantized conv is 3x3 or 1x1; tree adds must be between
        // macs/9 and macs
        assert!(t.tree_adds >= t.conv_macs_quantized / 9.0);
        assert!(t.tree_adds <= t.conv_macs_quantized);
        assert_eq!(t.tree_adds, t.group_scale_ops);
    }

    #[test]
    fn batch_amortizes_weight_work() {
        let net = network("resnet20").unwrap();
        let t1 = count_training_ops(&net, 1);
        let t64 = count_training_ops(&net, 64);
        assert!((t1.dq_weight_elements / t64.dq_weight_elements - 64.0).abs() < 1e-9);
        assert!((t1.sgd_params / t64.sgd_params - 64.0).abs() < 1e-9);
        // activation-side work is batch independent (already per sample)
        assert_eq!(t1.dq_act_elements, t64.dq_act_elements);
        assert_eq!(t1.bn_elements, t64.bn_elements);
    }

    #[test]
    fn dq_act_uses_exact_input_dims() {
        // a stride-2 "same" conv over an ODD 15x15 input: output 8x8, so
        // the old `h * w * stride^2` approximation would claim 3*8*8*4 =
        // 768 quantized activation elements; the exact input is 3*15*15 =
        // 675
        let net = Network {
            name: "odd15",
            input: (3, 15, 15),
            layers: vec![Layer::Conv {
                name: "c1".to_string(),
                cin: 3,
                cout: 4,
                k: 3,
                stride: 2,
                h: 8,
                w: 8,
                hin: 15,
                win: 15,
                quantized: true,
            }],
        };
        let t = count_training_ops(&net, 1);
        assert_eq!(t.dq_act_elements, 675.0);
        assert_ne!(t.dq_act_elements, 768.0);
        // even-dim zoo layers are unaffected (input == output * stride):
        // resnet20's quantized convs all divide evenly, so the exact and
        // approximate counts coincide there
        let r20 = network("resnet20").unwrap();
        let exact: f64 = r20
            .layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv { cin, hin, win, quantized: true, .. } => {
                    Some((cin * hin * win) as f64)
                }
                _ => None,
            })
            .sum();
        assert_eq!(count_training_ops(&r20, 1).dq_act_elements, exact);
    }

    #[test]
    fn first_layer_unquantized_everywhere() {
        for name in ["resnet18", "resnet34", "resnet20", "vgg16", "googlenet"] {
            let net = network(name).unwrap();
            let t = count_training_ops(&net, 64);
            assert!(t.conv_macs_unquantized > 0.0, "{name}");
            assert!(t.conv_macs_quantized > 10.0 * t.conv_macs_unquantized, "{name}");
        }
    }
}
