//! Layer geometry of the paper's evaluated networks.
//!
//! Shapes follow the original architectures:
//! * ResNet-18/34 — He et al. 2016, ImageNet variant (conv7/2 stem, four
//!   stages of basic blocks, 224x224 input),
//! * ResNet-20 — the CIFAR variant (3x3 stem, three stages of three basic
//!   blocks at 16/32/64 channels, 32x32 input),
//! * VGG-16 — Simonyan & Zisserman 2014 configuration D,
//! * GoogleNet — Szegedy et al. 2015 (Inception v1), main branch only
//!   (auxiliary classifiers are inference-off and the paper's Table I
//!   numbers match the main branch),
//! plus the scaled `resnet_t` / `cnn_s` models that the trainable
//! artifacts implement (DESIGN.md substitution table).

/// One accounted layer. `h`/`w` are OUTPUT sizes; convs additionally
/// carry their exact INPUT sizes `hin`/`win` (the dynamic-quantization
/// element counts need them — `h * stride` over-counts whenever padded
/// striding ceils an odd input).
#[derive(Clone, Debug, PartialEq)]
pub enum Layer {
    Conv {
        name: String,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        h: usize,
        w: usize,
        /// exact input spatial dims
        hin: usize,
        win: usize,
        /// quantized in the low-bit framework (first conv stays fp32)
        quantized: bool,
    },
    BatchNorm { c: usize, h: usize, w: usize },
    Fc { din: usize, dout: usize },
    /// element-wise residual addition over c x h x w
    EwAdd { c: usize, h: usize, w: usize },
}

#[derive(Clone, Debug)]
pub struct Network {
    pub name: &'static str,
    pub input: (usize, usize, usize), // (C, H, W)
    pub layers: Vec<Layer>,
}

impl Network {
    /// Forward multiply-accumulate count of all convs + FCs (the "GOPs"
    /// convention of the paper's Table III counts one MAC as one op).
    pub fn inference_macs(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Conv { cin, cout, k, h, w, .. } => {
                    (cin * cout * k * k * h * w) as u64
                }
                Layer::Fc { din, dout } => (din * dout) as u64,
                _ => 0,
            })
            .sum()
    }

    pub fn conv_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| matches!(l, Layer::Conv { .. }))
    }

    pub fn param_count(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Conv { cin, cout, k, .. } => (cin * cout * k * k) as u64,
                Layer::Fc { din, dout } => (din * dout + dout) as u64,
                Layer::BatchNorm { c, .. } => 2 * *c as u64,
                _ => 0,
            })
            .sum()
    }
}

/// Names of all predefined networks.
pub const NETWORKS: &[&str] = &[
    "resnet18", "resnet34", "resnet20", "vgg16", "googlenet", "resnet_t", "cnn_s",
];

/// Look up a predefined network by name.
pub fn network(name: &str) -> anyhow::Result<Network> {
    Ok(match name {
        "resnet18" => resnet_imagenet(&[2, 2, 2, 2], "resnet18"),
        "resnet34" => resnet_imagenet(&[3, 4, 6, 3], "resnet34"),
        "resnet20" => resnet_cifar(3, "resnet20"),
        "vgg16" => vgg16(),
        "googlenet" => googlenet(),
        "resnet_t" => resnet_t(),
        "cnn_s" => cnn_s(),
        _ => anyhow::bail!("unknown network {name:?} (have {NETWORKS:?})"),
    })
}

/// Models the NATIVE backend can train (each is the lowering of its
/// analytic twin below; see [`crate::nn::graph::lower`]).
pub const NATIVE_MODELS: &[&str] = &["cnn_t", "cnn_s", "resnet_t"];

/// The analytic twin of a native-trainable model. Every native model
/// constructs its executable graph by lowering the `Network` returned
/// here (`crate::nn::train::native_model`), so the analytic op counts
/// ([`super::ops::count_training_ops`]) and the executed per-layer audit
/// stream share a single geometry source. `cnn_t` is the tiny smoke/test
/// twin (not a paper network, hence not in [`NETWORKS`]); `cnn_s` and
/// `resnet_t` are the scaled trainable zoo models.
pub fn native_network(name: &str) -> anyhow::Result<Network> {
    Ok(match name {
        "cnn_t" => cnn_t(),
        "cnn_s" => cnn_s(),
        "resnet_t" => resnet_t(),
        other => anyhow::bail!(
            "model {other:?} is not supported by the native backend (native models: \
             {NATIVE_MODELS:?}; use backend=pjrt for the artifact models)"
        ),
    })
}

struct B {
    layers: Vec<Layer>,
    c: usize,
    h: usize,
    w: usize,
    n: usize,
}

impl B {
    fn new(c: usize, h: usize, w: usize) -> Self {
        B { layers: Vec::new(), c, h, w, n: 0 }
    }

    fn conv(&mut self, cout: usize, k: usize, stride: usize, quantized: bool) -> &mut Self {
        let (hin, win) = (self.h, self.w);
        // "same" padding geometry: out = ceil(in / stride)
        self.h = self.h.div_ceil(stride);
        self.w = self.w.div_ceil(stride);
        self.n += 1;
        self.layers.push(Layer::Conv {
            name: format!("conv{}", self.n),
            cin: self.c,
            cout,
            k,
            stride,
            h: self.h,
            w: self.w,
            hin,
            win,
            quantized,
        });
        self.c = cout;
        self
    }

    fn bn(&mut self) -> &mut Self {
        self.layers.push(Layer::BatchNorm { c: self.c, h: self.h, w: self.w });
        self
    }

    fn pool(&mut self, stride: usize) -> &mut Self {
        self.h = self.h.div_ceil(stride);
        self.w = self.w.div_ceil(stride);
        self
    }

    fn ew_add(&mut self) -> &mut Self {
        self.layers.push(Layer::EwAdd { c: self.c, h: self.h, w: self.w });
        self
    }

    fn fc(&mut self, dout: usize) -> &mut Self {
        self.layers.push(Layer::Fc { din: self.c, dout });
        self.c = dout;
        self
    }

    fn basic_block(&mut self, cout: usize, stride: usize) -> &mut Self {
        let cin = self.c;
        let (hin, win) = (self.h, self.w);
        self.conv(cout, 3, stride, true).bn();
        self.conv(cout, 3, 1, true).bn();
        if stride != 1 || cin != cout {
            // projection shortcut (1x1) on the pre-block feature map: its
            // output geometry equals the block output. The `s` name
            // suffix is load-bearing: `nn::graph::plan_blocks` recognizes
            // projection shortcuts by it when lowering a zoo network to
            // an executable graph, so main-branch convs must never be
            // named `conv{n}s`.
            self.layers.push(Layer::Conv {
                name: format!("conv{}s", self.n),
                cin,
                cout,
                k: 1,
                stride,
                h: self.h,
                w: self.w,
                hin,
                win,
                quantized: true,
            });
            self.layers.push(Layer::BatchNorm { c: cout, h: self.h, w: self.w });
        }
        self.ew_add()
    }
}

fn resnet_imagenet(blocks: &[usize; 4], name: &'static str) -> Network {
    let mut b = B::new(3, 224, 224);
    b.conv(64, 7, 2, false).bn().pool(2); // stem conv is unquantized
    let widths = [64usize, 128, 256, 512];
    for (stage, (&n_blocks, &width)) in blocks.iter().zip(&widths).enumerate() {
        for blk in 0..n_blocks {
            let stride = if stage > 0 && blk == 0 { 2 } else { 1 };
            b.basic_block(width, stride);
        }
    }
    b.c = 512; // GAP output features
    b.fc(1000);
    Network { name, input: (3, 224, 224), layers: b.layers }
}

fn resnet_cifar(n_per_stage: usize, name: &'static str) -> Network {
    let mut b = B::new(3, 32, 32);
    b.conv(16, 3, 1, false).bn();
    for (stage, &width) in [16usize, 32, 64].iter().enumerate() {
        for blk in 0..n_per_stage {
            let stride = if stage > 0 && blk == 0 { 2 } else { 1 };
            b.basic_block(width, stride);
        }
    }
    b.c = 64;
    b.fc(10);
    Network { name, input: (3, 32, 32), layers: b.layers }
}

fn vgg16() -> Network {
    let mut b = B::new(3, 224, 224);
    let cfg: &[&[usize]] = &[&[64, 64], &[128, 128], &[256, 256, 256], &[512, 512, 512], &[512, 512, 512]];
    let mut first = true;
    for group in cfg {
        for &width in *group {
            b.conv(width, 3, 1, !first).bn();
            first = false;
        }
        b.pool(2);
    }
    b.c = 512 * 7 * 7;
    b.fc(4096).fc(4096).fc(1000);
    Network { name: "vgg16", input: (3, 224, 224), layers: b.layers }
}

fn googlenet() -> Network {
    let mut b = B::new(3, 224, 224);
    b.conv(64, 7, 2, false).bn().pool(2); // 56x56
    b.conv(64, 1, 1, true).bn();
    b.conv(192, 3, 1, true).bn();
    b.pool(2); // 28x28

    // (c1x1, c3r, c3, c5r, c5, pool_proj)
    let inceptions: &[(usize, usize, usize, usize, usize, usize, bool)] = &[
        (64, 96, 128, 16, 32, 32, false),    // 3a @28
        (128, 128, 192, 32, 96, 64, true),   // 3b, then pool -> 14
        (192, 96, 208, 16, 48, 64, false),   // 4a @14
        (160, 112, 224, 24, 64, 64, false),  // 4b
        (128, 128, 256, 24, 64, 64, false),  // 4c
        (112, 144, 288, 32, 64, 64, false),  // 4d
        (256, 160, 320, 32, 128, 128, true), // 4e, then pool -> 7
        (256, 160, 320, 32, 128, 128, false),// 5a @7
        (384, 192, 384, 48, 128, 128, false),// 5b
    ];
    for &(c1, c3r, c3, c5r, c5, pp, pool_after) in inceptions {
        let cin = b.c;
        let (h, w) = (b.h, b.w);
        let mut branch = |cin: usize, cout: usize, k: usize| {
            b.layers.push(Layer::Conv {
                name: format!("conv{}", b.n),
                cin,
                cout,
                k,
                stride: 1,
                h,
                w,
                hin: h,
                win: w,
                quantized: true,
            });
            b.n += 1;
            b.layers.push(Layer::BatchNorm { c: cout, h, w });
        };
        branch(cin, c1, 1);
        branch(cin, c3r, 1);
        branch(c3r, c3, 3);
        branch(cin, c5r, 1);
        branch(c5r, c5, 5);
        branch(cin, pp, 1); // pool projection
        b.c = c1 + c3 + c5 + pp;
        if pool_after {
            b.pool(2);
        }
    }
    b.c = 1024;
    b.fc(1000);
    Network { name: "googlenet", input: (3, 224, 224), layers: b.layers }
}

/// The scaled trainable residual model (mirrors python model.resnet_t).
fn resnet_t() -> Network {
    let mut b = B::new(3, 16, 16);
    b.conv(16, 3, 1, false).bn();
    b.basic_block(16, 1);
    b.basic_block(32, 2);
    b.basic_block(64, 2);
    b.c = 64;
    b.fc(10);
    Network { name: "resnet_t", input: (3, 16, 16), layers: b.layers }
}

/// The scaled trainable VGG-style model (mirrors python model.cnn_s).
fn cnn_s() -> Network {
    let mut b = B::new(3, 16, 16);
    b.conv(16, 3, 1, false).bn();
    b.conv(32, 3, 2, true).bn();
    b.conv(32, 3, 1, true).bn();
    b.conv(64, 3, 2, true).bn();
    b.conv(64, 3, 1, true).bn();
    b.c = 64;
    b.fc(10);
    Network { name: "cnn_s", input: (3, 16, 16), layers: b.layers }
}

/// The tiny 4-conv smoke/test model of the native trainer (fp32 3x3
/// stem, then a strided 3x3, a 1x1 and a 3x3 quantized conv). Not a
/// paper network — it exists so tests and benches have a cheap twin that
/// still exercises stride 2, 1x1 kernels and pad 0.
fn cnn_t() -> Network {
    let mut b = B::new(3, 16, 16);
    b.conv(8, 3, 1, false).bn();
    b.conv(16, 3, 2, true).bn();
    b.conv(16, 1, 1, true).bn();
    b.conv(16, 3, 1, true).bn();
    b.c = 16;
    b.fc(10);
    Network { name: "cnn_t", input: (3, 16, 16), layers: b.layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inference_gops_match_table3() {
        // paper Table III: 1.88 / 3.59 / 15.25 / 1.58 GOPs (MACs). Our
        // analytic counts must land within 6% of the published numbers.
        for (name, gops) in [("resnet18", 1.88), ("resnet34", 3.59), ("vgg16", 15.25),
                             ("googlenet", 1.58)] {
            let n = network(name).unwrap();
            let got = n.inference_macs() as f64 / 1e9;
            let rel = (got - gops).abs() / gops;
            assert!(rel < 0.06, "{name}: got {got:.3} GOPs vs paper {gops}");
        }
    }

    #[test]
    fn param_counts_plausible() {
        let r18 = network("resnet18").unwrap();
        let p = r18.param_count() as f64 / 1e6;
        assert!((10.0..13.0).contains(&p), "resnet18 params {p}M");
        let r34 = network("resnet34").unwrap();
        let p34 = r34.param_count() as f64 / 1e6;
        assert!((20.0..23.0).contains(&p34), "resnet34 params {p34}M");
    }

    #[test]
    fn resnet20_structure() {
        let n = network("resnet20").unwrap();
        // 1 stem + 3 stages x 3 blocks x 2 convs + 2 projection shortcuts
        let convs = n.conv_layers().count();
        assert_eq!(convs, 1 + 18 + 2);
        // first conv unquantized, everything else quantized
        let unq = n
            .conv_layers()
            .filter(|l| matches!(l, Layer::Conv { quantized: false, .. }))
            .count();
        assert_eq!(unq, 1);
    }

    #[test]
    fn googlenet_output_channels() {
        let n = network("googlenet").unwrap();
        // final inception output must be 1024 (feeding the classifier)
        let last_fc = n.layers.iter().rev().find(|l| matches!(l, Layer::Fc { .. }));
        match last_fc {
            Some(Layer::Fc { din, dout }) => {
                assert_eq!(*din, 1024);
                assert_eq!(*dout, 1000);
            }
            _ => panic!("no fc"),
        }
    }

    #[test]
    fn unknown_network_errors() {
        assert!(network("nope").is_err());
    }

    #[test]
    fn native_twins_build() {
        for name in NATIVE_MODELS {
            let n = native_network(name).unwrap();
            assert!(!n.layers.is_empty(), "{name}");
            // the stem is the only unquantized conv everywhere
            let unq = n
                .conv_layers()
                .filter(|l| matches!(l, Layer::Conv { quantized: false, .. }))
                .count();
            assert_eq!(unq, 1, "{name}");
        }
        // cnn_t: 4 convs, 16x16 input, 10 classes
        let t = native_network("cnn_t").unwrap();
        assert_eq!(t.conv_layers().count(), 4);
        assert_eq!(t.input, (3, 16, 16));
        // resnet_t twin has its three residual joins
        let r = native_network("resnet_t").unwrap();
        let joins = r.layers.iter().filter(|l| matches!(l, Layer::EwAdd { .. })).count();
        assert_eq!(joins, 3);
        // unknown names error listing the supported set
        let err = native_network("resnet20").unwrap_err();
        let msg = format!("{err:#}");
        for name in NATIVE_MODELS {
            assert!(msg.contains(name), "{msg}");
        }
        assert!(msg.contains("pjrt"), "{msg}");
    }

    #[test]
    fn all_networks_build() {
        for name in NETWORKS {
            let n = network(name).unwrap();
            assert!(!n.layers.is_empty(), "{name}");
            assert!(n.inference_macs() > 0, "{name}");
        }
    }
}
