//! Pluggable parameter-update rules for the native Alg. 1 trainer.
//!
//! The PR 4 trainer inlined `p -= lr * g` into its step function; the
//! module-graph redesign replaces that with the [`Optimizer`] trait over
//! the flat parameter vector (layout: [`crate::nn::graph::Graph::state`]).
//!
//! * [`Sgd`] — plain SGD. With `weight_decay == 0` the update is the
//!   literal expression `p -= lr * g` the historical trainer executed, so
//!   chain-model training stays **bit-identical** (pinned by
//!   `rust/tests/train_bit_identity.rs`).
//! * [`MomentumSgd`] — heavy-ball momentum,
//!   `v <- mu * v + (g + wd * p); p <- p - lr * v`, the paper's training
//!   recipe (Sec. VI: momentum 0.9). The velocity buffer persists across
//!   steps inside the optimizer, sized lazily to the parameter count.
//!
//! Both support optional L2 weight decay folded into the gradient
//! (`g + wd * p`), skipped entirely when `wd == 0` so the zero-decay
//! path adds no float ops.

use anyhow::Result;

/// One parameter-update rule over the flat state vector.
pub trait Optimizer {
    fn name(&self) -> &'static str;
    /// Apply one update in place. `params` and `grads` share the layout
    /// of [`crate::nn::graph::Graph::state`].
    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32);

    /// Flatten the optimizer's internal slots (momentum velocity, ...)
    /// for checkpointing. Stateless rules return the default empty vec.
    fn state(&self) -> Vec<f32> {
        Vec::new()
    }

    /// Restore slots written by [`Self::state`]. The default (stateless)
    /// implementation accepts only an empty vector, so a checkpoint
    /// written under a stateful rule cannot silently load into a
    /// stateless one.
    fn load_state(&mut self, state: &[f32]) -> Result<()> {
        anyhow::ensure!(
            state.is_empty(),
            "optimizer {:?} is stateless but the checkpoint carries {} slot values",
            self.name(),
            state.len()
        );
        Ok(())
    }
}

/// Plain SGD: `p -= lr * g` (bit-identical to the historical inlined
/// update when `weight_decay == 0`), or `p -= lr * (g + wd * p)`.
#[derive(Clone, Debug, Default)]
pub struct Sgd {
    pub weight_decay: f32,
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), grads.len(), "param/grad length mismatch");
        if self.weight_decay != 0.0 {
            for (p, g) in params.iter_mut().zip(grads) {
                *p -= lr * (*g + self.weight_decay * *p);
            }
        } else {
            for (p, g) in params.iter_mut().zip(grads) {
                *p -= lr * *g;
            }
        }
    }
}

/// Momentum SGD (heavy ball): `v <- mu * v + (g + wd * p)`,
/// `p <- p - lr * v`. The velocity persists across steps.
#[derive(Clone, Debug)]
pub struct MomentumSgd {
    pub momentum: f32,
    pub weight_decay: f32,
    v: Vec<f32>,
}

impl MomentumSgd {
    pub fn new(momentum: f32, weight_decay: f32) -> Self {
        MomentumSgd { momentum, weight_decay, v: Vec::new() }
    }
}

impl Optimizer for MomentumSgd {
    fn name(&self) -> &'static str {
        "momentum"
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), grads.len(), "param/grad length mismatch");
        if self.v.len() != params.len() {
            self.v = vec![0.0; params.len()];
        }
        for ((p, g), v) in params.iter_mut().zip(grads).zip(self.v.iter_mut()) {
            let ge = if self.weight_decay != 0.0 { *g + self.weight_decay * *p } else { *g };
            *v = self.momentum * *v + ge;
            *p -= lr * *v;
        }
    }

    fn state(&self) -> Vec<f32> {
        self.v.clone()
    }

    fn load_state(&mut self, state: &[f32]) -> Result<()> {
        // an empty slot vector is the pre-first-step state (v lazily
        // sized on the first update), so it always loads
        self.v = state.to_vec();
        Ok(())
    }
}

/// Optimizer names `TrainConfig.optimizer` accepts.
pub const OPTIMIZERS: &[&str] = &["sgd", "momentum"];

/// Build an optimizer from its config name (`optimizer=sgd|momentum`,
/// `momentum=0.9`, `weight_decay=0.0`).
pub fn parse_optimizer(name: &str, momentum: f32, weight_decay: f32) -> Result<Box<dyn Optimizer>> {
    Ok(match name {
        "sgd" => Box::new(Sgd { weight_decay }),
        "momentum" => Box::new(MomentumSgd::new(momentum, weight_decay)),
        other => anyhow::bail!("unknown optimizer {other:?} (have {OPTIMIZERS:?})"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_is_bit_exact_p_minus_lr_g() {
        // the plain-SGD path must execute the literal historical update
        let params0: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let grads: Vec<f32> = (0..64).map(|i| (i as f32 * 0.11).cos()).collect();
        let lr = 0.05f32;
        let mut params = params0.clone();
        Sgd::default().step(&mut params, &grads, lr);
        for (i, ((p0, g), p)) in params0.iter().zip(&grads).zip(&params).enumerate() {
            let mut want = *p0;
            want -= lr * *g;
            assert_eq!(p.to_bits(), want.to_bits(), "param {i}");
        }
    }

    #[test]
    fn momentum_matches_closed_form_on_scalar_quadratic() {
        // loss = a/2 * x^2, grad = a*x. With v_{t+1} = mu v_t + a x_t and
        // x_{t+1} = x_t - lr v_{t+1}, the state obeys the 2-term linear
        // recurrence x_{t+1} = (1 + mu - lr a) x_t - mu x_{t-1}, so
        // x_t = c1 l1^t + c2 l2^t with l1/l2 the roots of
        // l^2 - (1 + mu - lr a) l + mu = 0. Parameters chosen so the
        // discriminant is positive (real, distinct roots).
        let (a, lr, mu) = (1.0f64, 0.2f64, 0.04f64);
        let tr = 1.0 + mu - lr * a;
        let disc = tr * tr - 4.0 * mu;
        assert!(disc > 0.0, "test parameters must give real roots");
        let l1 = (tr + disc.sqrt()) / 2.0;
        let l2 = (tr - disc.sqrt()) / 2.0;
        let x0 = 1.0f64;
        let x1 = x0 - lr * a * x0; // first step has v_0 = 0
        let c2 = (x1 - l1 * x0) / (l2 - l1);
        let c1 = x0 - c2;
        let closed_form = |t: u32| c1 * l1.powi(t as i32) + c2 * l2.powi(t as i32);

        let mut opt = MomentumSgd::new(mu as f32, 0.0);
        let mut x = [x0 as f32];
        for t in 1..=30u32 {
            let g = [a as f32 * x[0]];
            opt.step(&mut x, &g, lr as f32);
            let want = closed_form(t);
            let got = x[0] as f64;
            assert!(
                (got - want).abs() <= want.abs().max(1e-6) * 1e-4,
                "step {t}: optimizer {got:.9e} vs closed form {want:.9e}"
            );
        }
        // momentum genuinely differs from plain SGD on the same problem
        let mut sx = [x0 as f32];
        let mut sgd = Sgd::default();
        for _ in 0..30 {
            let g = [a as f32 * sx[0]];
            sgd.step(&mut sx, &g, lr as f32);
        }
        assert_ne!(x[0].to_bits(), sx[0].to_bits());
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        // zero gradient: only the decay term acts
        let mut p = [2.0f32];
        let g = [0.0f32];
        let mut opt = Sgd { weight_decay: 0.1 };
        opt.step(&mut p, &g, 0.5);
        assert!((p[0] - (2.0 - 0.5 * 0.1 * 2.0)).abs() < 1e-6);
        let mut pm = [2.0f32];
        let mut mopt = MomentumSgd::new(0.9, 0.1);
        mopt.step(&mut pm, &g, 0.5);
        assert!((pm[0] - (2.0 - 0.5 * 0.1 * 2.0)).abs() < 1e-6);
    }

    #[test]
    fn velocity_persists_across_steps() {
        // two steps with the same gradient: the second update is larger
        // by the momentum carry
        let mut p = [0.0f32];
        let g = [1.0f32];
        let mut opt = MomentumSgd::new(0.9, 0.0);
        opt.step(&mut p, &g, 0.1);
        let d1 = -p[0];
        let before = p[0];
        opt.step(&mut p, &g, 0.1);
        let d2 = before - p[0];
        assert!((d1 - 0.1).abs() < 1e-6);
        assert!((d2 - 0.19).abs() < 1e-6, "second step must carry 0.9 * v");
    }

    #[test]
    fn optimizer_state_round_trips_bit_identically() {
        // momentum: checkpoint after step 1, restore into a fresh
        // optimizer, and step 2 must land bit-identically
        let grads: Vec<f32> = (0..32).map(|i| (i as f32 * 0.23).sin()).collect();
        let p0: Vec<f32> = (0..32).map(|i| (i as f32 * 0.71).cos()).collect();
        let mut a = MomentumSgd::new(0.9, 0.0005);
        assert!(a.state().is_empty(), "pre-first-step velocity is empty");
        let mut pa = p0.clone();
        a.step(&mut pa, &grads, 0.1);
        let snapshot = a.state();
        assert_eq!(snapshot.len(), 32);

        let mut b = MomentumSgd::new(0.9, 0.0005);
        b.load_state(&snapshot).unwrap();
        let mut pb = pa.clone();
        a.step(&mut pa, &grads, 0.1);
        b.step(&mut pb, &grads, 0.1);
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }

        // stateless SGD: empty state loads, non-empty is rejected
        let mut s = Sgd::default();
        assert!(s.state().is_empty());
        s.load_state(&[]).unwrap();
        assert!(s.load_state(&[1.0]).is_err(), "slots into stateless rule must fail");
    }

    #[test]
    fn parse_names() {
        assert_eq!(parse_optimizer("sgd", 0.9, 0.0).unwrap().name(), "sgd");
        assert_eq!(parse_optimizer("momentum", 0.9, 0.0).unwrap().name(), "momentum");
        let err = parse_optimizer("adam", 0.9, 0.0).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("sgd") && msg.contains("momentum"), "{msg}");
    }
}
