//! Crash-durable file writes.
//!
//! [`write_atomic`] is the write primitive for every artifact a resume
//! path trusts (checkpoints, manifests, `trial_output.json`, truncated
//! audit streams): write to a sibling temp file, `fsync` the FILE, rename
//! over the destination, then `fsync` the parent DIRECTORY. A plain
//! write+rename survives a process crash but not a power-loss-shaped one
//! — without the file fsync the rename can land while the data blocks
//! are still dirty (an empty-but-renamed output that resume would
//! trust), and without the directory fsync the rename itself can vanish.
//! Readers therefore see either the complete old content or the complete
//! new content, never a prefix.

use std::path::Path;

use anyhow::{Context, Result};

/// Atomically and durably replace `path` with `bytes`.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("write_atomic: {path:?} has no file name"))?;
    let tmp = path.with_file_name(format!("{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("write_atomic: create {tmp:?}"))?;
        use std::io::Write;
        f.write_all(bytes).with_context(|| format!("write_atomic: write {tmp:?}"))?;
        f.sync_all().with_context(|| format!("write_atomic: fsync {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("write_atomic: rename {tmp:?} -> {path:?}"))?;
    sync_parent_dir(path)
}

/// Fsync the directory holding `path`, making a completed rename of
/// `path` durable. Directory handles are not openable for sync on every
/// platform; non-unix targets fall back to a no-op (the rename is still
/// atomic there, just not power-loss durable).
pub fn sync_parent_dir(path: &Path) -> Result<()> {
    #[cfg(unix)]
    {
        let parent = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
            _ => std::path::PathBuf::from("."),
        };
        let dir = std::fs::File::open(&parent)
            .with_context(|| format!("write_atomic: open dir {parent:?}"))?;
        dir.sync_all().with_context(|| format!("write_atomic: fsync dir {parent:?}"))?;
    }
    #[cfg(not(unix))]
    let _ = path;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("mls_fsio_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn writes_and_replaces() {
        let dir = scratch("replace");
        let path = dir.join("out.json");
        write_atomic(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        write_atomic(&path, b"second, longer content").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer content");
        // no temp file left behind
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["out.json".to_string()], "{names:?}");
    }

    #[test]
    fn missing_parent_fails_cleanly() {
        let dir = scratch("noparent");
        let path = dir.join("missing_subdir").join("out.json");
        assert!(write_atomic(&path, b"x").is_err());
    }
}
