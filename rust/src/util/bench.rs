//! Micro-bench harness for the `cargo bench` targets (criterion is not
//! available offline). Warmup + timed runs, median/p10/p90 reporting, and a
//! black-box sink to defeat dead-code elimination.

use std::time::{Duration, Instant};

use super::stats;

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

/// True when the benches should run a fast smoke pass (CI anti-bit-rot
/// mode): enabled by a `--smoke` CLI flag or `MLS_BENCH_SMOKE=1`.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("MLS_BENCH_SMOKE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
}

/// The measurement budget benches should use: `full` normally, a ~50 ms
/// slice in smoke mode (still >= 10 samples, enough to prove the kernel
/// runs and reports).
pub fn budget(full: Duration) -> Duration {
    if smoke_mode() {
        Duration::from_millis(50)
    } else {
        full
    }
}

pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters: usize,
}

impl BenchResult {
    pub fn throughput_items(&self, items: u64) -> f64 {
        items as f64 / self.median.as_secs_f64()
    }
}

/// Time `f` adaptively: warm up, then run until ~`budget` elapsed (at least
/// `min_iters` samples), reporting the per-iteration distribution.
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // warmup
    let warm_start = Instant::now();
    let mut warm_iters = 0u32;
    while warm_start.elapsed() < budget / 10 && warm_iters < 10_000 {
        f();
        warm_iters += 1;
    }

    let mut samples = Vec::new();
    let start = Instant::now();
    let min_iters = 10;
    while samples.len() < min_iters || (start.elapsed() < budget && samples.len() < 10_000) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let result = BenchResult {
        name: name.to_string(),
        median: Duration::from_secs_f64(stats::median(&samples)),
        p10: Duration::from_secs_f64(stats::quantile(&samples, 0.1)),
        p90: Duration::from_secs_f64(stats::quantile(&samples, 0.9)),
        iters: samples.len(),
    };
    println!(
        "bench {:<44} median {:>12?}  p10 {:>12?}  p90 {:>12?}  ({} iters)",
        result.name, result.median, result.p10, result.p90, result.iters
    );
    result
}

/// Convenience wrapper with the default 2 s budget.
pub fn run(name: &str, f: impl FnMut()) -> BenchResult {
    bench(name, Duration::from_secs(2), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", Duration::from_millis(50), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 10);
        assert!(r.median.as_nanos() > 0);
    }
}
