//! Micro-bench harness for the `cargo bench` targets (criterion is not
//! available offline). Warmup + timed runs, median/p10/p90 reporting, a
//! black-box sink to defeat dead-code elimination, and [`BenchReport`] —
//! the machine-readable `BENCH_*.json` emitter CI archives so the perf
//! trajectory of the hot kernels is measured on every push.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

/// True when the benches should run a fast smoke pass (CI anti-bit-rot
/// mode): enabled by a `--smoke` CLI flag or `MLS_BENCH_SMOKE=1`.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("MLS_BENCH_SMOKE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
}

/// The measurement budget benches should use: `full` normally, a ~50 ms
/// slice in smoke mode (still >= 10 samples, enough to prove the kernel
/// runs and reports).
pub fn budget(full: Duration) -> Duration {
    if smoke_mode() {
        Duration::from_millis(50)
    } else {
        full
    }
}

pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters: usize,
}

impl BenchResult {
    pub fn throughput_items(&self, items: u64) -> f64 {
        items as f64 / self.median.as_secs_f64()
    }
}

/// Time `f` adaptively: warm up, then run until ~`budget` elapsed (at least
/// `min_iters` samples), reporting the per-iteration distribution.
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // warmup
    let warm_start = Instant::now();
    let mut warm_iters = 0u32;
    while warm_start.elapsed() < budget / 10 && warm_iters < 10_000 {
        f();
        warm_iters += 1;
    }

    let mut samples = Vec::new();
    let start = Instant::now();
    let min_iters = 10;
    while samples.len() < min_iters || (start.elapsed() < budget && samples.len() < 10_000) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let result = BenchResult {
        name: name.to_string(),
        median: Duration::from_secs_f64(stats::median(&samples)),
        p10: Duration::from_secs_f64(stats::quantile(&samples, 0.1)),
        p90: Duration::from_secs_f64(stats::quantile(&samples, 0.9)),
        iters: samples.len(),
    };
    println!(
        "bench {:<44} median {:>12?}  p10 {:>12?}  p90 {:>12?}  ({} iters)",
        result.name, result.median, result.p10, result.p90, result.iters
    );
    result
}

/// Convenience wrapper with the default 2 s budget.
pub fn run(name: &str, f: impl FnMut()) -> BenchResult {
    bench(name, Duration::from_secs(2), f)
}

/// True when regressions should fail the process (CI perf guard):
/// enabled by `MLS_BENCH_ENFORCE=1`. With the guard off, benches only
/// report; with it on, `bench_conv_arith` exits nonzero if the planar
/// kernel is slower than the legacy kernel at 1 thread.
pub fn enforce_mode() -> bool {
    std::env::var("MLS_BENCH_ENFORCE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// The repository root (one level above the rust package), where the
/// `BENCH_*.json` perf-trajectory files live.
pub fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")
}

/// Machine-readable bench report: accumulates per-kernel results and
/// derived ratios, then writes one `BENCH_<name>.json` file at the repo
/// root. CI's bench-smoke step archives these as workflow artifacts, so
/// every push carries its measured MMAC/s / Melem/s trajectory.
pub struct BenchReport {
    file: String,
    meta: BTreeMap<String, Json>,
    results: BTreeMap<String, Json>,
    ratios: BTreeMap<String, Json>,
}

impl BenchReport {
    /// Start a report that will be written to `<repo root>/<file>`.
    pub fn new(file: &str, bench_name: &str) -> Self {
        let mut meta = BTreeMap::new();
        meta.insert("bench".to_string(), Json::Str(bench_name.to_string()));
        meta.insert("smoke".to_string(), Json::Bool(smoke_mode()));
        BenchReport {
            file: file.to_string(),
            meta,
            results: BTreeMap::new(),
            ratios: BTreeMap::new(),
        }
    }

    /// Attach a top-level metadata value (thread count, problem size, ...).
    pub fn set(&mut self, key: &str, value: Json) {
        self.meta.insert(key.to_string(), value);
    }

    /// Record one measured kernel: timing distribution plus throughput in
    /// `items / second` scaled to millions (`MMAC/s`, `Melem/s`, ...).
    pub fn add_result(&mut self, r: &BenchResult, items: u64, unit: &str) {
        let mut entry = BTreeMap::new();
        entry.insert("median_s".to_string(), Json::Num(r.median.as_secs_f64()));
        entry.insert("p10_s".to_string(), Json::Num(r.p10.as_secs_f64()));
        entry.insert("p90_s".to_string(), Json::Num(r.p90.as_secs_f64()));
        entry.insert("iters".to_string(), Json::Num(r.iters as f64));
        entry.insert(
            format!("m{unit}_per_s"),
            Json::Num(r.throughput_items(items) / 1e6),
        );
        self.results.insert(r.name.clone(), Json::Obj(entry));
    }

    /// Record a derived speedup ratio (e.g. planar vs legacy at 1 thread).
    pub fn add_ratio(&mut self, key: &str, ratio: f64) {
        self.ratios.insert(key.to_string(), Json::Num(ratio));
    }

    /// Write the report to `<repo root>/<file>` and return the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        self.write_to(&repo_root())
    }

    /// Write the report into `dir` (unit tests use a temp dir).
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let mut obj = self.meta.clone();
        obj.insert("results".to_string(), Json::Obj(self.results.clone()));
        obj.insert("ratios".to_string(), Json::Obj(self.ratios.clone()));
        let path = dir.join(&self.file);
        std::fs::write(&path, Json::Obj(obj).to_string_pretty() + "\n")?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", Duration::from_millis(50), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 10);
        assert!(r.median.as_nanos() > 0);
    }

    #[test]
    fn report_round_trips_through_json() {
        let r = bench("report-probe", Duration::from_millis(20), || {
            black_box((0..1000).sum::<u64>());
        });
        let mut report = BenchReport::new("BENCH_test_report.json", "bench_unit_test");
        report.set("threads", Json::Num(1.0));
        report.add_result(&r, 1000, "elem");
        report.add_ratio("probe_vs_itself", 1.0);
        let dir = std::env::temp_dir();
        let path = report.write_to(&dir).expect("write report");
        let text = std::fs::read_to_string(&path).expect("read report");
        let parsed = Json::parse(&text).expect("parse report");
        assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("bench_unit_test"));
        let results = parsed.get("results").expect("results");
        let probe = results.get("report-probe").expect("probe entry");
        assert!(probe.get("median_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(probe.get("melem_per_s").and_then(Json::as_f64).unwrap() > 0.0);
        let ratios = parsed.get("ratios").expect("ratios");
        assert_eq!(ratios.get("probe_vs_itself").and_then(Json::as_f64), Some(1.0));
        let _ = std::fs::remove_file(&path);
    }
}
