//! Minimal strict JSON parser + printer.
//!
//! Parses the artifact manifest, golden vectors and experiment configs.
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bool, null); numbers are held as f64, which round-trips every
//! f32 the Python side emits exactly (an f32 is exactly representable as
//! f64, and CPython's repr is shortest-round-trip).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<f32> {
        self.as_f64().map(|v| v as f32)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Render a scalar as the string the typed config registry parses:
    /// strings pass through, numbers/bools print in their compact JSON
    /// form (shortest round-trip for numbers, so `0.05` stays `"0.05"`).
    /// Arrays, objects and null are not scalars — None.
    pub fn coerce_string(&self) -> Option<String> {
        match self {
            Json::Str(s) => Some(s.clone()),
            Json::Num(_) | Json::Bool(_) => Some(self.to_string_compact()),
            _ => None,
        }
    }

    /// Required-field helpers that produce readable errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn f32s(&self) -> anyhow::Result<Vec<f32>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array of numbers"))?;
        arr.iter()
            .map(|v| v.as_f32().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }

    pub fn i32s(&self) -> anyhow::Result<Vec<i32>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array of ints"))?;
        arr.iter()
            .map(|v| {
                v.as_i64()
                    .map(|x| x as i32)
                    .ok_or_else(|| anyhow::anyhow!("expected int"))
            })
            .collect()
    }

    pub fn usizes(&self) -> anyhow::Result<Vec<usize>> {
        Ok(self.i32s()?.into_iter().map(|v| v as usize).collect())
    }

    // ---- printing ---------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line form (no newlines or indentation) — one record per
    /// line in the `.audit.jsonl` per-layer audit stream.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent, false);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..indent + 1 {
                            out.push_str("  ");
                        }
                    }
                    Json::Str(k.clone()).write(out, indent, false);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push_str("  ");
                    }
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u"))?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| self.err("utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn f32_roundtrip() {
        // exact f32 values round-trip through the f64 number representation
        for v in [0.1f32, 1e-30, 3.4e38, -2.5, 0.0, 1.0 / 3.0] {
            let s = format!("{}", v as f64);
            let parsed = Json::parse(&s).unwrap().as_f32().unwrap();
            assert_eq!(parsed.to_bits(), v.to_bits(), "{v}");
        }
    }

    #[test]
    fn print_parse_roundtrip() {
        let v = Json::parse(r#"{"x": [1.5, true, "s"], "y": {"z": []}}"#).unwrap();
        let printed = v.to_string_pretty();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn compact_is_single_line() {
        let v = Json::parse(r#"{"x": [1.5, true, "s"], "y": {"z": []}}"#).unwrap();
        let line = v.to_string_compact();
        assert!(!line.contains('\n'), "{line:?}");
        assert_eq!(Json::parse(&line).unwrap(), v);
    }

    #[test]
    fn coerce_string_scalars_only() {
        assert_eq!(Json::Str("x".into()).coerce_string(), Some("x".into()));
        assert_eq!(Json::Num(12.0).coerce_string(), Some("12".into()));
        assert_eq!(Json::Num(0.05).coerce_string(), Some("0.05".into()));
        assert_eq!(Json::Bool(true).coerce_string(), Some("true".into()));
        assert_eq!(Json::Null.coerce_string(), None);
        assert_eq!(Json::Arr(vec![]).coerce_string(), None);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }
}
