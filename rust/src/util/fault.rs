//! Deterministic fault injection for the crash-safety test harness.
//!
//! A fault spec names ONE site and ONE step:
//!
//! ```text
//!   MLS_FAULT=<site>@step<k>[:seed]
//! ```
//!
//! sites ([`SITES`]):
//!
//! * `nan_grad`        — poison a few gradient entries with NaN right
//!   after the backward pass of step `k` (before the health check and
//!   the optimizer update), the classic low-bit divergence signature;
//! * `scale_overflow`  — poison gradient entries with `1e38` at step
//!   `k`, driving the magnitude past the group-scale saturation limit
//!   ([`crate::nn::health::SCALE_SAT_LIMIT`]);
//! * `crash_before_ckpt` — abort the run at the end of step `k`,
//!   BEFORE the step's checkpoint would be written (the checkpoint
//!   interval that covers step `k` is lost);
//! * `crash_after_ckpt`  — abort the run at the end of step `k`, AFTER
//!   any checkpoint write for that step (resume restarts at `k + 1`);
//! * `corrupt_ckpt`    — flip one byte inside the checkpoint written at
//!   step `k` after it lands on disk (latent corruption: the run
//!   continues, the damage surfaces at the next resume's checksum
//!   verification).
//!
//! Every site fires **once** per armed run ([`FaultArm`]): a rollback
//! recovery that replays step `k` sees clean gradients the second time,
//! which is exactly what makes the `on_divergence=rollback` policy
//! testable deterministically. The optional `:seed` varies which
//! gradient entries are poisoned (default seed 0); the choice is a pure
//! function of `(seed, step)`, never of wall clock or thread timing.
//!
//! Faults reach the trainer either through `TrainConfig::fault`
//! (in-process tests set it directly — no global state, safe under the
//! parallel test harness) or the `MLS_FAULT` environment variable
//! ([`FaultSpec::from_env`], for CLI / CI use).

use anyhow::{anyhow, ensure, Result};

use crate::util::rng::Pcg32;

/// One injectable fault site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    NanGrad,
    ScaleOverflow,
    CrashBeforeCkpt,
    CrashAfterCkpt,
    CorruptCkpt,
}

impl FaultSite {
    /// Every supported site; [`Self::parse`] scans this list so the
    /// parseable set cannot drift from the `name()` outputs.
    pub const ALL: [FaultSite; 5] = [
        FaultSite::NanGrad,
        FaultSite::ScaleOverflow,
        FaultSite::CrashBeforeCkpt,
        FaultSite::CrashAfterCkpt,
        FaultSite::CorruptCkpt,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FaultSite::NanGrad => "nan_grad",
            FaultSite::ScaleOverflow => "scale_overflow",
            FaultSite::CrashBeforeCkpt => "crash_before_ckpt",
            FaultSite::CrashAfterCkpt => "crash_after_ckpt",
            FaultSite::CorruptCkpt => "corrupt_ckpt",
        }
    }

    pub fn parse(s: &str) -> Result<FaultSite> {
        Self::ALL.into_iter().find(|f| f.name() == s).ok_or_else(|| {
            anyhow!("unknown fault site {s:?} (have {:?})", Self::ALL.map(|f| f.name()))
        })
    }
}

/// The site names `MLS_FAULT` accepts (doc/help listings).
pub const SITES: [&str; 5] = [
    "nan_grad",
    "scale_overflow",
    "crash_before_ckpt",
    "crash_after_ckpt",
    "corrupt_ckpt",
];

/// A parsed `<site>@step<k>[:seed]` spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub site: FaultSite,
    pub step: u64,
    /// varies which gradient entries a poison site hits (default 0)
    pub seed: u64,
}

impl std::fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@step{}", self.site.name(), self.step)?;
        if self.seed != 0 {
            write!(f, ":{}", self.seed)?;
        }
        Ok(())
    }
}

impl FaultSpec {
    /// Parse `<site>@step<k>[:seed]` (the `MLS_FAULT` grammar).
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let (site, rest) = s
            .split_once('@')
            .ok_or_else(|| anyhow!("fault spec {s:?} must be <site>@step<k>[:seed]"))?;
        let site = FaultSite::parse(site)?;
        let rest = rest
            .strip_prefix("step")
            .ok_or_else(|| anyhow!("fault spec {s:?}: expected step<k> after '@'"))?;
        let (step, seed) = match rest.split_once(':') {
            Some((k, seed)) => (k, Some(seed)),
            None => (rest, None),
        };
        ensure!(!step.is_empty(), "fault spec {s:?}: empty step index");
        let step: u64 =
            step.parse().map_err(|e| anyhow!("fault spec {s:?}: bad step index: {e}"))?;
        let seed: u64 = match seed {
            Some(v) => v.parse().map_err(|e| anyhow!("fault spec {s:?}: bad seed: {e}"))?,
            None => 0,
        };
        Ok(FaultSpec { site, step, seed })
    }

    /// The ambient `MLS_FAULT` spec, if set (a malformed value is a hard
    /// error — a typo must not silently run fault-free).
    pub fn from_env() -> Result<Option<FaultSpec>> {
        match std::env::var("MLS_FAULT") {
            Ok(v) if !v.trim().is_empty() => Ok(Some(Self::parse(v.trim())?)),
            _ => Ok(None),
        }
    }
}

/// How many gradient entries a poison site overwrites.
const POISON_ENTRIES: usize = 3;

/// An armed (one-shot) fault for one training run. Every query marks the
/// fault as fired when it matches, so a deterministic rollback replay of
/// the same step proceeds clean.
#[derive(Debug)]
pub struct FaultArm {
    spec: Option<FaultSpec>,
    fired: bool,
}

impl FaultArm {
    pub fn new(spec: Option<FaultSpec>) -> FaultArm {
        FaultArm { spec, fired: false }
    }

    pub fn spec(&self) -> Option<&FaultSpec> {
        self.spec.as_ref()
    }

    fn take(&mut self, site: FaultSite, step: u64) -> Option<FaultSpec> {
        match self.spec {
            Some(s) if !self.fired && s.site == site && s.step == step => {
                self.fired = true;
                Some(s)
            }
            _ => None,
        }
    }

    /// Apply a gradient-poison site (`nan_grad` / `scale_overflow`) for
    /// `step`, returning the site that fired. The poisoned indices are a
    /// pure function of `(spec.seed, step)`.
    pub fn poison_grads(&mut self, step: u64, grads: &mut [f32]) -> Option<FaultSite> {
        for site in [FaultSite::NanGrad, FaultSite::ScaleOverflow] {
            if let Some(spec) = self.take(site, step) {
                let value = match site {
                    FaultSite::NanGrad => f32::NAN,
                    _ => 1.0e38,
                };
                let mut rng = Pcg32::new(spec.seed ^ 0xfa_17_fa_17, step);
                for _ in 0..POISON_ENTRIES.min(grads.len()) {
                    let idx = rng.next_u32() as usize % grads.len();
                    grads[idx] = value;
                }
                return Some(site);
            }
        }
        None
    }

    /// Fire a crash site at `step`: returns the error the trainer
    /// propagates (the process-level analogue of a SIGKILL mid-run).
    pub fn crash_point(&mut self, site: FaultSite, step: u64) -> Result<()> {
        debug_assert!(matches!(site, FaultSite::CrashBeforeCkpt | FaultSite::CrashAfterCkpt));
        if let Some(spec) = self.take(site, step) {
            anyhow::bail!("MLS_FAULT crash injected: {spec}");
        }
        Ok(())
    }

    /// Whether the `corrupt_ckpt` site fires for the checkpoint written
    /// at `step`.
    pub fn corrupt_due(&mut self, step: u64) -> bool {
        self.take(FaultSite::CorruptCkpt, step).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_site() {
        for name in SITES {
            let spec = FaultSpec::parse(&format!("{name}@step7")).unwrap();
            assert_eq!(spec.site.name(), name);
            assert_eq!(spec.step, 7);
            assert_eq!(spec.seed, 0);
        }
        let spec = FaultSpec::parse("nan_grad@step3:42").unwrap();
        assert_eq!(spec, FaultSpec { site: FaultSite::NanGrad, step: 3, seed: 42 });
        assert_eq!(spec.to_string(), "nan_grad@step3:42");
        assert_eq!(FaultSpec::parse(&spec.to_string()).unwrap(), spec);
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in [
            "nan_grad",          // no step
            "nan_grad@3",        // missing 'step'
            "nan_grad@step",     // empty index
            "nan_grad@stepx",    // non-numeric index
            "nan_grad@step3:",   // empty seed
            "bad_site@step3",    // unknown site
            "@step3",            // empty site
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
        let msg = format!("{:#}", FaultSpec::parse("bogus@step1").unwrap_err());
        for name in SITES {
            assert!(msg.contains(name), "site listing must contain {name:?}: {msg}");
        }
    }

    #[test]
    fn poison_is_one_shot_and_deterministic() {
        let spec = FaultSpec::parse("nan_grad@step2:5").unwrap();
        let poison = |grads: &mut [f32]| {
            let mut arm = FaultArm::new(Some(spec));
            assert!(arm.poison_grads(1, grads).is_none(), "wrong step must not fire");
            arm.poison_grads(2, grads)
        };
        let mut a = vec![1.0f32; 64];
        let mut b = vec![1.0f32; 64];
        assert_eq!(poison(&mut a), Some(FaultSite::NanGrad));
        assert_eq!(poison(&mut b), Some(FaultSite::NanGrad));
        let hits: Vec<usize> = a.iter().enumerate().filter(|(_, v)| v.is_nan()).map(|(i, _)| i).collect();
        assert!(!hits.is_empty() && hits.len() <= POISON_ENTRIES);
        for i in &hits {
            assert!(b[*i].is_nan(), "same (seed, step) must poison the same entries");
        }
        // one-shot: a second query on the same arm stays clean
        let mut arm = FaultArm::new(Some(spec));
        let mut g = vec![1.0f32; 8];
        assert!(arm.poison_grads(2, &mut g).is_some());
        let mut g2 = vec![1.0f32; 8];
        assert!(arm.poison_grads(2, &mut g2).is_none(), "fired faults must not re-fire");
        assert!(g2.iter().all(|v| *v == 1.0));
    }

    #[test]
    fn crash_sites_error_once() {
        let spec = FaultSpec::parse("crash_after_ckpt@step3").unwrap();
        let mut arm = FaultArm::new(Some(spec));
        arm.crash_point(FaultSite::CrashAfterCkpt, 2).unwrap();
        arm.crash_point(FaultSite::CrashBeforeCkpt, 3).unwrap(); // wrong site
        let err = arm.crash_point(FaultSite::CrashAfterCkpt, 3).unwrap_err();
        assert!(format!("{err:#}").contains("MLS_FAULT crash injected"));
        arm.crash_point(FaultSite::CrashAfterCkpt, 3).unwrap(); // one-shot
    }

    #[test]
    fn unarmed_is_inert() {
        let mut arm = FaultArm::new(None);
        let mut g = vec![1.0f32; 4];
        assert!(arm.poison_grads(0, &mut g).is_none());
        assert!(!arm.corrupt_due(0));
        arm.crash_point(FaultSite::CrashAfterCkpt, 0).unwrap();
    }
}
