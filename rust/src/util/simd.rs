//! Runtime SIMD capability detection + the `MLS_SIMD` dispatch override.
//!
//! The Eq. 7 microkernel ([`crate::arith::simd`]) and the quantizer inner
//! loops ([`crate::mls::quantizer`]) ship explicit-intrinsics paths
//! (SSE4.1 / AVX2 on `x86_64`, NEON on `aarch64`) next to the scalar
//! reference kernels. Which path runs is decided HERE, once per process:
//!
//! * detection runs lazily via `is_x86_feature_detected!` /
//!   `is_aarch64_feature_detected!` and is cached in a [`OnceLock`]
//!   (detection order: `avx2 > sse41 > neon > off`),
//! * `MLS_SIMD={auto,off,sse41,avx2,neon}` overrides detection for
//!   testing and benching (`off` is the scalar escape hatch; requesting
//!   an ISA this CPU lacks falls back to scalar with a warning),
//! * [`set_level`] is an in-process override on top of both — the
//!   identity tests and the `simd_vs_scalar` benches use it to force
//!   each supported path inside one process.
//!
//! Every path is BIT-IDENTICAL by construction — values and all five
//! hardware-audit counters — so the level is purely a speed choice,
//! never a numerics choice (pinned by `rust/tests/conv_fuzz.rs` and
//! `rust/tests/parallel_equivalence.rs` across every [`supported`]
//! level).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// One SIMD dispatch level. `Off` (the scalar reference kernels) exists
/// on every architecture; the vector levels exist only where their ISA
/// does, and [`Level::is_supported`] reports `false` elsewhere.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Level {
    /// Scalar reference kernels — the bit-identity anchor.
    Off,
    /// 128-bit `core::arch::x86_64` path (SSE4.1).
    Sse41,
    /// 256-bit `core::arch::x86_64` path (AVX2).
    Avx2,
    /// 128-bit `core::arch::aarch64` path (NEON).
    Neon,
}

const UNSET: u8 = u8::MAX;

impl Level {
    /// Every dispatch level, scalar first. [`Level::parse`] scans this
    /// list, so parseable names cannot drift from `name()` outputs.
    pub const ALL: [Level; 4] = [Level::Off, Level::Sse41, Level::Avx2, Level::Neon];

    pub fn name(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Sse41 => "sse41",
            Level::Avx2 => "avx2",
            Level::Neon => "neon",
        }
    }

    /// Parse an `MLS_SIMD` value. `"auto"` means "use runtime
    /// detection" and returns `None`; anything else must name a level.
    pub fn parse(s: &str) -> anyhow::Result<Option<Level>> {
        if s == "auto" {
            return Ok(None);
        }
        Self::ALL
            .into_iter()
            .find(|l| l.name() == s)
            .map(Some)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown MLS_SIMD value {s:?} (have \"auto\" or {:?})",
                    Self::ALL.map(|l| l.name())
                )
            })
    }

    /// Whether this CPU can execute the level's kernels.
    pub fn is_supported(self) -> bool {
        match self {
            Level::Off => true,
            Level::Sse41 => detect_sse41(),
            Level::Avx2 => detect_avx2(),
            Level::Neon => detect_neon(),
        }
    }

    /// Every level this CPU supports, scalar first — the identity tests
    /// force each of these in turn via [`set_level`].
    pub fn supported() -> Vec<Level> {
        Self::ALL.into_iter().filter(|l| l.is_supported()).collect()
    }

    fn from_u8(v: u8) -> Level {
        Self::ALL[v as usize]
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_sse41() -> bool {
    std::arch::is_x86_feature_detected!("sse4.1")
}
#[cfg(not(target_arch = "x86_64"))]
fn detect_sse41() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn detect_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}
#[cfg(not(target_arch = "x86_64"))]
fn detect_avx2() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn detect_neon() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}
#[cfg(not(target_arch = "aarch64"))]
fn detect_neon() -> bool {
    false
}

/// Widest supported level: `avx2 > sse41 > neon > off`.
pub fn detect() -> Level {
    if detect_avx2() {
        Level::Avx2
    } else if detect_sse41() {
        Level::Sse41
    } else if detect_neon() {
        Level::Neon
    } else {
        Level::Off
    }
}

/// In-process override set by [`set_level`]; `UNSET` defers to the
/// cached env/detection default.
static OVERRIDE: AtomicU8 = AtomicU8::new(UNSET);
/// The process default: `MLS_SIMD` if set (and supported), else
/// [`detect`]. Read once — env changes after first use are ignored.
static DEFAULT: OnceLock<Level> = OnceLock::new();

fn default_level() -> Level {
    *DEFAULT.get_or_init(|| match std::env::var("MLS_SIMD") {
        Err(_) => detect(),
        Ok(s) => match Level::parse(&s) {
            Ok(None) => detect(),
            Ok(Some(l)) if l.is_supported() => l,
            Ok(Some(l)) => {
                eprintln!(
                    "[mls] MLS_SIMD={} is not supported on this CPU; using the scalar kernels",
                    l.name()
                );
                Level::Off
            }
            Err(e) => {
                eprintln!("[mls] {e:#}; using runtime detection");
                detect()
            }
        },
    })
}

/// The dispatch level the kernels run at right now: the [`set_level`]
/// override if one is active, else the `MLS_SIMD`/detection default.
pub fn active() -> Level {
    match OVERRIDE.load(Ordering::Relaxed) {
        UNSET => default_level(),
        v => Level::from_u8(v),
    }
}

/// Force the dispatch level for this process, returning the previously
/// active level so callers can restore it. Used by the identity tests
/// and the `simd_vs_scalar` benches to pin each path in one process;
/// safe to call at any time because every level is bit-identical.
pub fn set_level(level: Level) -> Level {
    let prev = active();
    OVERRIDE.store(
        Level::ALL.iter().position(|l| *l == level).unwrap() as u8,
        Ordering::Relaxed,
    );
    prev
}

/// Human-readable dispatch line for `bench-info` and the trainer log.
pub fn describe() -> String {
    let source = if OVERRIDE.load(Ordering::Relaxed) != UNSET {
        "forced via set_level"
    } else if std::env::var_os("MLS_SIMD").is_some() {
        "MLS_SIMD override"
    } else {
        "runtime-detected"
    };
    format!(
        "{} ({source}; detection order avx2 > sse41 > neon > off, scalar fallback always available)",
        active().name()
    )
}

/// Log the selected dispatch path once per process (trainer startup —
/// audit reproducibility: which microkernel produced a run's numbers).
pub fn log_once() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| eprintln!("[mls] simd dispatch: {}", describe()));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_the_registry() {
        for l in Level::ALL {
            assert_eq!(Level::parse(l.name()).unwrap(), Some(l), "{}", l.name());
        }
        assert_eq!(Level::parse("auto").unwrap(), None);
        let err = format!("{:#}", Level::parse("bogus").unwrap_err());
        for l in Level::ALL {
            assert!(err.contains(l.name()), "{err}");
        }
    }

    #[test]
    fn supported_always_includes_scalar_and_the_detected_level() {
        let sup = Level::supported();
        assert_eq!(sup[0], Level::Off);
        assert!(detect().is_supported());
        assert!(sup.contains(&detect()));
        // vector levels never co-exist across architectures
        assert!(!(sup.contains(&Level::Neon) && sup.contains(&Level::Sse41)));
    }

    #[test]
    fn set_level_overrides_and_restores() {
        let before = active();
        let prev = set_level(Level::Off);
        assert_eq!(prev, before);
        assert_eq!(active(), Level::Off);
        assert!(describe().starts_with("off"));
        set_level(before);
        assert_eq!(active(), before);
    }
}
