//! In-tree utility substrate.
//!
//! The build environment only mirrors the `xla` crate's dependency closure,
//! so the usual ecosystem crates (serde_json, rand, proptest, criterion,
//! clap) are replaced with small, focused implementations:
//!
//! * [`rng`] — PCG32 deterministic random numbers (data generation,
//!   stochastic rounding offsets, property tests),
//! * [`json`] — a strict JSON parser/printer (artifact manifests, golden
//!   vectors, metrics),
//! * [`prop`] — a mini property-testing harness (randomized invariants
//!   with seed reporting on failure),
//! * [`bench`] — a measured-section micro-bench harness used by the
//!   `cargo bench` targets (median-of-runs with warmup, plus the CI smoke
//!   mode),
//! * [`stats`] — summary statistics shared by metrics and benches,
//! * [`parallel`] — deterministic fork/join on a persistent worker pool
//!   for the hot kernels (rayon is not available offline), with an
//!   `MLS_THREADS` override,
//! * [`simd`] — one-time runtime SIMD capability detection + the
//!   `MLS_SIMD` dispatch override for the vectorized kernels,
//! * [`fsio`] — crash-durable atomic file replacement (fsync file +
//!   parent directory around the rename),
//! * [`fault`] — the deterministic `MLS_FAULT=<site>@step<k>[:seed]`
//!   fault-injection harness the crash-safety tests drive,
//! * [`frame`] — length-prefixed message framing for the serve protocol
//!   (stdin/jsonl and TCP share it).

pub mod bench;
pub mod fault;
pub mod frame;
pub mod fsio;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod stats;
