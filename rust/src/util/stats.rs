//! Summary statistics shared by metrics, benches and experiment reports.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-quantile via sorted interpolation (p in [0, 1]).
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = p.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (idx - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Average relative error — the Fig. 7 metric: mean|q - x| / mean|x|.
pub fn average_relative_error(x: &[f32], q: &[f32]) -> f64 {
    assert_eq!(x.len(), q.len());
    let num: f64 = x.iter().zip(q).map(|(a, b)| (a - b).abs() as f64).sum();
    let den: f64 = x.iter().map(|a| a.abs() as f64).sum();
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.1180339887).abs() < 1e-9);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
    }

    #[test]
    fn are_metric() {
        let x = [1.0f32, -2.0, 4.0];
        let q = [1.0f32, -2.0, 3.0];
        let are = average_relative_error(&x, &q);
        assert!((are - 1.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn are_zero_input() {
        assert_eq!(average_relative_error(&[0.0; 3], &[0.0; 3]), 0.0);
    }
}
