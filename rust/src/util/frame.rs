//! Length-prefixed framing for the serve protocol: every message on a
//! stream (stdin/stdout or one TCP connection) is a 4-byte little-endian
//! length followed by that many payload bytes. The payload is one JSON
//! object ([`crate::serve::server`] defines the request/response shapes);
//! the framing layer itself is payload-agnostic.
//!
//! Error discipline (pinned in the tests below and `tests/serve.rs`):
//!
//! * EOF exactly at a frame boundary is a clean end-of-stream
//!   (`Ok(None)`), the normal way a client hangs up;
//! * EOF mid-prefix or mid-payload is a truncated frame
//!   ([`std::io::ErrorKind::UnexpectedEof`]);
//! * a length above `max_len` is rejected BEFORE allocating
//!   ([`std::io::ErrorKind::InvalidData`]) — a corrupt or hostile prefix
//!   must not drive a huge allocation.

use std::io::{self, Read, Write};

/// Write one frame: 4-byte LE length prefix, then the payload.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    let len = u32::try_from(payload.len())
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "frame payload exceeds u32"))?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one frame. `Ok(None)` on clean EOF (stream closed between
/// frames); errors on truncation mid-frame or a length above `max_len`.
pub fn read_frame(r: &mut impl Read, max_len: usize) -> io::Result<Option<Vec<u8>>> {
    let mut prefix = [0u8; 4];
    // hand-rolled first read so EOF-at-boundary and EOF-mid-prefix are
    // distinguishable (read_exact collapses both into UnexpectedEof)
    let mut got = 0;
    while got < prefix.len() {
        match r.read(&mut prefix[got..])? {
            0 if got == 0 => return Ok(None),
            0 => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("truncated frame: EOF after {got} of 4 length-prefix bytes"),
                ))
            }
            n => got += n,
        }
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len > max_len {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds the {max_len}-byte limit"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(|e| {
        io::Error::new(
            io::ErrorKind::UnexpectedEof,
            format!("truncated frame: wanted {len} payload bytes: {e}"),
        )
    })?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn round_trips_frames_in_order() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[0u8, 255, 7]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r, 1024).unwrap().as_deref(), Some(&b"hello"[..]));
        assert_eq!(read_frame(&mut r, 1024).unwrap().as_deref(), Some(&b""[..]));
        assert_eq!(read_frame(&mut r, 1024).unwrap().as_deref(), Some(&[0u8, 255, 7][..]));
        assert!(read_frame(&mut r, 1024).unwrap().is_none(), "clean EOF at boundary");
        assert!(read_frame(&mut r, 1024).unwrap().is_none(), "EOF is sticky");
    }

    #[test]
    fn truncation_is_an_unexpected_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"payload").unwrap();
        for cut in [1, 3, 4, 6, buf.len() - 1] {
            let mut r = Cursor::new(buf[..cut].to_vec());
            let err = read_frame(&mut r, 1024).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof, "cut at {cut}: {err}");
        }
    }

    #[test]
    fn oversized_length_is_rejected_without_allocating() {
        let mut buf = (u32::MAX).to_le_bytes().to_vec();
        buf.extend_from_slice(b"x");
        let err = read_frame(&mut Cursor::new(buf), 1 << 20).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("exceeds"), "{err}");
    }
}
