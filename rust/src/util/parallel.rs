//! Deterministic data-parallel execution for the MLS hot kernels.
//!
//! The build environment only guarantees the Rust toolchain (no rayon), so
//! this is a small scoped-thread fork/join layer with the two shapes the
//! kernels need:
//!
//! * [`map_ranges`] — split `0..n` into at most `threads` contiguous
//!   ranges, run one worker per range, return the per-range results in
//!   range order,
//! * [`map_collect`] — order-preserving parallel map over `0..n`.
//!
//! Work is assigned statically (contiguous chunks), so for a fixed input
//! the set of per-item computations is independent of the thread count and
//! results are **bit-identical** for every `threads` value — the property
//! `rust/tests/parallel_equivalence.rs` pins down for the conv/quantize
//! kernels.
//!
//! The default worker count is `available_parallelism()`, overridable with
//! the `MLS_THREADS` environment variable (e.g. `MLS_THREADS=1` forces the
//! serial path).

use std::sync::OnceLock;

/// Worker count: `MLS_THREADS` if set to a positive integer, else the
/// machine's available parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("MLS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Split `0..n` into at most `threads` contiguous ranges and run
/// `f(lo, hi)` on each, one worker per range. Results come back in range
/// order. With `threads <= 1` (or a single range) everything runs on the
/// calling thread.
pub fn map_ranges<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return vec![f(0, n)];
    }
    let chunk = n.div_ceil(threads);
    let ranges: Vec<(usize, usize)> = (0..threads)
        .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
        .filter(|&(lo, hi)| lo < hi)
        .collect();
    let mut out = Vec::with_capacity(ranges.len());
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|(lo, hi)| s.spawn(move || f(lo, hi)))
            .collect();
        for h in handles {
            match h.join() {
                Ok(v) => out.push(v),
                // rethrow with the original payload so kernel assertions
                // read the same as on the serial path
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    out
}

/// Order-preserving parallel map over `0..n`.
pub fn map_collect<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let parts = map_ranges(threads, n, |lo, hi| (lo..hi).map(&f).collect::<Vec<T>>());
    let mut out = Vec::with_capacity(n);
    for p in parts {
        out.extend(p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn map_collect_preserves_order() {
        for threads in [1usize, 2, 3, 8, 64] {
            let got = map_collect(threads, 100, |i| i * i);
            let want: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn map_ranges_tiles_exactly() {
        for threads in [1usize, 2, 5, 7, 16] {
            for n in [0usize, 1, 2, 9, 100] {
                let ranges = map_ranges(threads, n, |lo, hi| (lo, hi));
                // ranges are contiguous, ordered, non-empty and cover 0..n
                let mut cursor = 0;
                for (lo, hi) in &ranges {
                    assert_eq!(*lo, cursor);
                    assert!(lo < hi);
                    cursor = *hi;
                }
                assert_eq!(cursor, n, "threads={threads} n={n}");
                assert!(ranges.len() <= threads.max(1));
            }
        }
    }

    #[test]
    fn map_ranges_empty_input() {
        let out: Vec<(usize, usize)> = map_ranges(4, 0, |lo, hi| (lo, hi));
        assert!(out.is_empty());
    }
}
