//! Deterministic data-parallel execution for the MLS hot kernels, on a
//! **persistent worker pool**.
//!
//! The build environment only guarantees the Rust toolchain (no rayon), so
//! this is a small fork/join layer with the two shapes the kernels need:
//!
//! * [`map_ranges`] — split `0..n` into at most `threads` contiguous
//!   ranges, run one task per range, return the per-range results in
//!   range order,
//! * [`DisjointWriter`] — direct parallel writes into disjoint spans of
//!   one preallocated output buffer.
//!
//! Work is assigned statically (contiguous chunks derived from the
//! *requested* `threads`, never from the pool size), so for a fixed input
//! the set of per-chunk computations is independent of both the thread
//! count and which worker executes which chunk — results are
//! **bit-identical** for every `threads` value, the property
//! `rust/tests/parallel_equivalence.rs` pins down for the conv/quantize
//! kernels.
//!
//! ## The pool
//!
//! Earlier revisions spawned scoped threads per call, which made every
//! small conv/quantize pay thread-spawn latency (tens of microseconds per
//! worker — comparable to the whole kernel for small tensors). Now a pool
//! of workers is lazily spawned on the first parallel dispatch and reused
//! for the life of the process:
//!
//! * jobs are published to a shared queue; each job exposes its chunks
//!   through an **atomic cursor** (`fetch_add` work claiming), so chunk
//!   scheduling is dynamic while chunk *boundaries* stay static;
//! * the submitting thread participates in its own job (claiming chunks
//!   like any worker), then blocks until every chunk completed — which is
//!   also what makes borrowing stack data from the caller sound;
//! * nested dispatch is allowed: an inner job's submitter drains it
//!   itself even when all pool workers are busy, so progress is always
//!   guaranteed;
//! * worker panics are caught per chunk, the job is drained to
//!   completion, and the first panic payload is rethrown on the
//!   submitting thread, prefixed with the submitter's [`with_label`]
//!   scope (e.g. `conv1:forward`) and the failing chunk's index and
//!   range — a kernel assertion deep in a parallel conv names the layer
//!   and pass that tripped it.
//!
//! The default worker count for the *chunking* is
//! `available_parallelism()`, overridable with the `MLS_THREADS`
//! environment variable (e.g. `MLS_THREADS=1` forces the serial path; a
//! value above the core count oversubscribes). The pool itself is sized
//! once, at first dispatch, to `max(MLS_THREADS, available_parallelism)
//! - 1` threads (the submitter is the extra executor); `MLS_THREADS`
//! keeps its per-call meaning afterwards — it decides how many chunks a
//! dispatch is split into, the pool only caps how many run concurrently.

use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Reusable label scopes of one thread: a stack of owned `String`
/// buffers that are cleared and refilled instead of reallocated, so
/// entering a [`with_label`] scope in the steady-state step loop costs
/// no heap traffic once every nesting depth has been visited once.
#[derive(Default)]
struct LabelStack {
    bufs: Vec<String>,
    depth: usize,
}

thread_local! {
    /// The submitting thread's current panic-label scopes (see
    /// [`with_label`]).
    static LABEL: RefCell<LabelStack> = RefCell::new(LabelStack::default());
}

/// Run `f` with a panic label attached to the calling thread: any panic
/// rethrown by a [`map_ranges`] or [`for_ranges`] dispatch submitted
/// inside `f` is prefixed with `label` and the failing chunk's range, so
/// an assertion deep in a parallel kernel names the call site (the
/// trainer labels every conv as `<layer>:<pass>`). Scopes nest — the
/// previous label is restored on exit, panicking or not. Scope buffers
/// are pooled per thread and per depth, so re-entering a scope
/// allocates nothing after its first use.
pub fn with_label<R>(label: &str, f: impl FnOnce() -> R) -> R {
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            LABEL.with(|l| l.borrow_mut().depth -= 1);
        }
    }
    LABEL.with(|l| {
        let mut stack = l.borrow_mut();
        let depth = stack.depth;
        if depth == stack.bufs.len() {
            stack.bufs.push(String::with_capacity(label.len()));
        }
        stack.bufs[depth].clear();
        stack.bufs[depth].push_str(label);
        stack.depth = depth + 1;
    });
    let _restore = Restore;
    f()
}

/// The innermost active [`with_label`] scope of the calling thread, if
/// any. Allocates the returned clone — callers keep it off hot paths
/// (it runs on panic rethrow and on arena-miss diagnostics only).
pub(crate) fn current_label() -> Option<String> {
    LABEL.with(|l| {
        let stack = l.borrow();
        stack.depth.checked_sub(1).map(|top| stack.bufs[top].clone())
    })
}

/// Prefix a string panic payload with the dispatch context; opaque
/// (non-string) payloads pass through unchanged.
fn relabel_payload(
    payload: Box<dyn std::any::Any + Send>,
    label: Option<&str>,
    idx: usize,
    lo: usize,
    hi: usize,
) -> Box<dyn std::any::Any + Send> {
    let msg = if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        return payload;
    };
    match label {
        Some(l) => Box::new(format!("{l}: chunk {idx} [{lo}..{hi}): {msg}")),
        None => Box::new(format!("chunk {idx} [{lo}..{hi}): {msg}")),
    }
}

/// Worker count: `MLS_THREADS` if set to a positive integer, else the
/// machine's available parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("MLS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// One published unit of parallel work: `total` chunks claimed through an
/// atomic cursor, executed via a type-erased callback into caller stack
/// data.
///
/// # Safety contract
///
/// `data` points at a live `F` on the submitting thread's stack and
/// `call` is the matching monomorphized trampoline. The pointer is only
/// dereferenced between a successful chunk claim (`next.fetch_add < total`)
/// and that chunk's `done` increment, and the submitter blocks until
/// `done == total` before the closure can go out of scope — so every
/// dereference happens while the closure is provably alive.
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    next: AtomicUsize,
    done: AtomicUsize,
    total: usize,
    /// first panicked chunk: (chunk index, payload)
    panic: Mutex<Option<(usize, Box<dyn std::any::Any + Send>)>>,
}

// Publication of `data` happens through the pool mutex (push under lock),
// and the lifetime argument is covered by the contract above.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

unsafe fn call_chunk<F: Fn(usize) + Sync>(data: *const (), idx: usize) {
    let f = unsafe { &*(data as *const F) };
    f(idx);
}

struct Pool {
    /// jobs with unclaimed chunks (submitters remove their job when done)
    jobs: Mutex<Vec<Arc<Job>>>,
    /// workers wait here for new jobs
    work_cv: Condvar,
    /// submitters wait here for their job's completion
    done_cv: Condvar,
    workers: usize,
}

impl Pool {
    /// Claim-and-run chunks of `job` until its cursor is exhausted.
    fn run_chunks(&self, job: &Job) {
        loop {
            let idx = job.next.fetch_add(1, Ordering::Relaxed);
            if idx >= job.total {
                return;
            }
            let result = catch_unwind(AssertUnwindSafe(|| {
                // SAFETY: idx < total and done has not been incremented
                // for this chunk yet, so the submitter is still blocked
                // and the closure behind `data` is alive (see Job docs).
                unsafe { (job.call)(job.data, idx) }
            }));
            if let Err(payload) = result {
                let mut slot = job.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some((idx, payload));
                }
            }
            // Release pairs with the submitter's Acquire load: everything
            // this chunk wrote (result slots, output tiles) is visible
            // once the submitter observes done == total.
            let finished = job.done.fetch_add(1, Ordering::Release) + 1;
            if finished == job.total {
                let _guard = self.jobs.lock().unwrap();
                self.done_cv.notify_all();
            }
        }
    }

    fn worker_loop(&self) {
        loop {
            let job = {
                let mut guard = self.jobs.lock().unwrap();
                loop {
                    let open = guard
                        .iter()
                        .find(|j| j.next.load(Ordering::Relaxed) < j.total)
                        .cloned();
                    match open {
                        Some(j) => break j,
                        None => guard = self.work_cv.wait(guard).unwrap(),
                    }
                }
            };
            self.run_chunks(&job);
        }
    }
}

/// The process-wide pool, spawned on first use. Worker threads are
/// detached and live for the rest of the process (they park on the
/// condvar when idle); the one `Pool` allocation is intentionally leaked
/// so the workers can borrow it `'static`.
fn pool() -> &'static Pool {
    static POOL: OnceLock<&'static Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let workers = num_threads().max(hw).saturating_sub(1);
        let pool: &'static Pool = Box::leak(Box::new(Pool {
            jobs: Mutex::new(Vec::new()),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            workers,
        }));
        for i in 0..pool.workers {
            std::thread::Builder::new()
                .name(format!("mls-worker-{i}"))
                .spawn(move || pool.worker_loop())
                .expect("spawn mls worker thread");
        }
        pool
    })
}

/// Run `f(0), f(1), ..., f(chunks - 1)` to completion, using the pool for
/// concurrency; the calling thread participates. Returns the first
/// panicked chunk (index + payload) after the job drains — the caller
/// decides how to rethrow (see [`map_ranges`], which adds the chunk
/// range and submitter label). The single-chunk fast path runs inline
/// and lets a panic unwind naturally.
fn dispatch<F: Fn(usize) + Sync>(
    chunks: usize,
    f: F,
) -> Option<(usize, Box<dyn std::any::Any + Send>)> {
    if chunks == 0 {
        return None;
    }
    if chunks == 1 {
        f(0);
        return None;
    }
    let pool = pool();
    if pool.workers == 0 {
        // serial fallback: same caught-panic shape as the pool path
        for idx in 0..chunks {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(idx))) {
                return Some((idx, payload));
            }
        }
        return None;
    }
    let job = Arc::new(Job {
        data: &f as *const F as *const (),
        call: call_chunk::<F>,
        next: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        total: chunks,
        panic: Mutex::new(None),
    });
    {
        let mut guard = pool.jobs.lock().unwrap();
        guard.push(Arc::clone(&job));
        // wake only as many workers as there are chunks left after the
        // submitter takes its share — notify_all would stampede the whole
        // pool through the mutex for a 2-chunk job. Under-waking is safe:
        // busy workers re-scan the job list before sleeping, and the
        // submitter drains its own job regardless.
        for _ in 0..(chunks - 1).min(pool.workers) {
            pool.work_cv.notify_one();
        }
    }
    // the submitter is an executor too — this also guarantees progress
    // when every pool worker is busy (e.g. nested dispatch)
    pool.run_chunks(&job);
    {
        let mut guard = pool.jobs.lock().unwrap();
        while job.done.load(Ordering::Acquire) < job.total {
            guard = pool.done_cv.wait(guard).unwrap();
        }
        guard.retain(|j| !Arc::ptr_eq(j, &job));
    }
    job.panic.lock().unwrap().take()
}

/// Split `0..n` into at most `threads` contiguous ranges and run
/// `f(lo, hi)` on each. Results come back in range order. With
/// `threads <= 1` (or a single range) everything runs on the calling
/// thread; otherwise the ranges execute on the persistent pool.
pub fn map_ranges<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return vec![f(0, n)];
    }
    let chunk = n.div_ceil(threads);
    let ranges: Vec<(usize, usize)> = (0..threads)
        .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
        .filter(|&(lo, hi)| lo < hi)
        .collect();
    let slots: Vec<Mutex<Option<T>>> = ranges.iter().map(|_| Mutex::new(None)).collect();
    if let Some((idx, payload)) = dispatch(ranges.len(), |i| {
        let (lo, hi) = ranges[i];
        let value = f(lo, hi);
        *slots[i].lock().unwrap() = Some(value);
    }) {
        // rethrow on the submitting thread, naming the failing chunk and
        // the caller's with_label scope (e.g. `conv1:forward`); the label
        // is read here, not before the dispatch, so the non-panicking hot
        // path never clones it
        let (lo, hi) = ranges[idx];
        let label = current_label();
        resume_unwind(relabel_payload(payload, label.as_deref(), idx, lo, hi));
    }
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("every range chunk completed"))
        .collect()
}

/// [`map_ranges`] without result collection: split `0..n` into at most
/// `threads` contiguous ranges and run `f(lo, hi)` on each, returning
/// nothing. The chunk boundaries are exactly [`map_ranges`]' (derived
/// from the requested `threads`, never from the pool size), so the two
/// shapes are interchangeable for kernels that write through a
/// [`DisjointWriter`] and merge their statistics through atomics.
///
/// Unlike [`map_ranges`] this path performs **zero heap allocation** on
/// the submitting thread for single-chunk dispatches (`threads <= 1` or
/// `n` small enough to collapse to one range) — there is no slot vector
/// and no range vector — which is what the steady-state training step
/// relies on at 1 thread. Multi-chunk dispatches allocate only the one
/// `Arc<Job>` publication inside [`dispatch`].
pub fn for_ranges<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    let chunk = n.div_ceil(threads);
    // number of non-empty ranges (the filtered count map_ranges builds)
    let chunks = n.div_ceil(chunk);
    if let Some((idx, payload)) = dispatch(chunks, |i| {
        let lo = i * chunk;
        let hi = ((i + 1) * chunk).min(n);
        f(lo, hi);
    }) {
        let (lo, hi) = (idx * chunk, ((idx + 1) * chunk).min(n));
        let label = current_label();
        resume_unwind(relabel_payload(payload, label.as_deref(), idx, lo, hi));
    }
}

/// Shared-output writer for parallel kernels whose work units fill
/// provably **disjoint** spans of one preallocated buffer — the
/// direct-write replacement for collect-then-concatenate merging (each
/// conv tile lands at its row offsets instead of being copied once more).
///
/// The wrapper borrows the buffer for `'a`, so the buffer cannot be
/// dropped, moved, or reborrowed while writers exist; disjointness of the
/// spans is the caller's contract (see [`DisjointWriter::span`]).
pub struct DisjointWriter<'a, T> {
    base: *mut T,
    len: usize,
    _buf: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: the writer only hands out spans under the caller contract that
// concurrent spans never overlap, so sending/sharing it across the pool
// is sound for Send element types.
unsafe impl<T: Send> Send for DisjointWriter<'_, T> {}
unsafe impl<T: Send> Sync for DisjointWriter<'_, T> {}

impl<'a, T> DisjointWriter<'a, T> {
    pub fn new(buf: &'a mut [T]) -> Self {
        DisjointWriter { base: buf.as_mut_ptr(), len: buf.len(), _buf: std::marker::PhantomData }
    }

    /// Exclusive view of `offset..offset + n`.
    ///
    /// # Safety
    ///
    /// The caller must guarantee that no two live spans overlap — i.e.
    /// each buffer element is handed to at most one work unit at a time.
    /// The bounds themselves are checked (panic on overflow past the
    /// buffer), only aliasing is the caller's obligation.
    #[allow(clippy::mut_from_ref)] // deliberate: &self is the shared handle, disjointness is the contract
    pub unsafe fn span(&self, offset: usize, n: usize) -> &mut [T] {
        // checked_add: a wrapped `offset + n` in release mode would slip
        // past the bound and defeat the very check this assert provides
        let end = offset.checked_add(n).expect("span end overflows usize");
        assert!(end <= self.len, "span {offset}+{n} out of bounds ({})", self.len);
        unsafe { std::slice::from_raw_parts_mut(self.base.add(offset), n) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn map_ranges_results_come_back_in_range_order() {
        for threads in [1usize, 2, 3, 8, 64] {
            let parts = map_ranges(threads, 100, |lo, hi| (lo..hi).map(|i| i * i).collect::<Vec<_>>());
            let got: Vec<usize> = parts.into_iter().flatten().collect();
            let want: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn map_ranges_tiles_exactly() {
        for threads in [1usize, 2, 5, 7, 16] {
            for n in [0usize, 1, 2, 9, 100] {
                let ranges = map_ranges(threads, n, |lo, hi| (lo, hi));
                // ranges are contiguous, ordered, non-empty and cover 0..n
                let mut cursor = 0;
                for (lo, hi) in &ranges {
                    assert_eq!(*lo, cursor);
                    assert!(lo < hi);
                    cursor = *hi;
                }
                assert_eq!(cursor, n, "threads={threads} n={n}");
                assert!(ranges.len() <= threads.max(1));
            }
        }
    }

    #[test]
    fn map_ranges_empty_input() {
        let out: Vec<(usize, usize)> = map_ranges(4, 0, |lo, hi| (lo, hi));
        assert!(out.is_empty());
    }

    #[test]
    fn pool_survives_many_sequential_dispatches() {
        // pre-pool this was one thread spawn per range per call; now the
        // same workers serve every call — 500 back-to-back jobs must all
        // come back complete and ordered
        for round in 0..500u64 {
            let got = map_ranges(4, 64, |lo, hi| (lo..hi).map(|i| i as u64 + round).sum::<u64>());
            let want: u64 = (0..64).map(|i| i + round).sum();
            assert_eq!(got.iter().sum::<u64>(), want, "round {round}");
        }
    }

    #[test]
    fn panic_in_chunk_propagates_to_submitter() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            map_ranges(4, 16, |lo, _hi| {
                assert!(lo != 8, "chunk boom {lo}");
                lo
            })
        }));
        let payload = result.expect_err("the panicking chunk must rethrow here");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| payload.downcast_ref::<&str>().unwrap_or(&"?").to_string());
        assert!(msg.contains("chunk boom"), "unexpected payload {msg:?}");
        // the rethrown payload names the failing chunk and its range
        assert!(msg.contains("chunk 2 [8..12)"), "missing chunk context: {msg:?}");
        // the pool must still be serviceable after a panicked job
        let got = map_ranges(4, 10, |lo, hi| (lo..hi).map(|i| i * 3).sum::<usize>());
        assert_eq!(got.iter().sum::<usize>(), (0..10).map(|i| i * 3).sum::<usize>());
    }

    fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
        payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| payload.downcast_ref::<&str>().unwrap_or(&"?").to_string())
    }

    #[test]
    fn with_label_prefixes_rethrown_panics_and_restores() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            with_label("conv1:forward", || {
                map_ranges(4, 16, |lo, _hi| {
                    assert!(lo != 4, "tile boom {lo}");
                    lo
                })
            })
        }));
        let msg = panic_message(result.expect_err("must rethrow"));
        assert!(
            msg.contains("conv1:forward: chunk 1 [4..8): tile boom 4"),
            "unexpected payload {msg:?}"
        );
        // the label scope ended (by unwinding, even): a fresh dispatch
        // panics without it
        let result = catch_unwind(AssertUnwindSafe(|| {
            map_ranges(4, 16, |lo, _hi| {
                assert!(lo != 4, "tile boom {lo}");
                lo
            })
        }));
        let msg = panic_message(result.expect_err("must rethrow"));
        assert!(!msg.contains("conv1:forward"), "stale label leaked: {msg:?}");
        assert!(msg.contains("chunk 1 [4..8)"), "{msg:?}");
    }

    #[test]
    fn with_label_scopes_nest() {
        let outer = with_label("outer", || {
            let inner = catch_unwind(AssertUnwindSafe(|| {
                with_label("inner", || {
                    map_ranges(2, 4, |lo, _hi| {
                        assert!(lo != 2, "nested boom");
                        lo
                    })
                })
            }));
            let msg = panic_message(inner.expect_err("must rethrow"));
            assert!(msg.contains("inner: chunk 1 [2..4)"), "{msg:?}");
            // back in the outer scope after the inner one unwound
            catch_unwind(AssertUnwindSafe(|| {
                map_ranges(2, 4, |lo, _hi| {
                    assert!(lo != 2, "outer boom");
                    lo
                })
            }))
        });
        let msg = panic_message(outer.expect_err("must rethrow"));
        assert!(msg.contains("outer: chunk 1 [2..4)"), "{msg:?}");
    }

    #[test]
    fn for_ranges_matches_map_ranges_chunking() {
        for threads in [1usize, 2, 5, 7, 16] {
            for n in [0usize, 1, 2, 9, 100] {
                let want = map_ranges(threads, n, |lo, hi| (lo, hi));
                let got = Mutex::new(Vec::new());
                for_ranges(threads, n, |lo, hi| got.lock().unwrap().push((lo, hi)));
                let mut got = got.into_inner().unwrap();
                got.sort_unstable();
                assert_eq!(got, want, "threads={threads} n={n}");
            }
        }
    }

    #[test]
    fn for_ranges_panic_carries_label_and_range() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            with_label("conv2:wgrad", || {
                for_ranges(4, 16, |lo, _hi| {
                    assert!(lo != 8, "span boom {lo}");
                })
            })
        }));
        let msg = panic_message(result.expect_err("must rethrow"));
        assert!(
            msg.contains("conv2:wgrad: chunk 2 [8..12): span boom 8"),
            "unexpected payload {msg:?}"
        );
    }

    #[test]
    fn disjoint_writer_fills_every_slot() {
        let mut buf = vec![0u32; 97];
        let writer = DisjointWriter::new(&mut buf);
        map_ranges(8, 97, |lo, hi| {
            // SAFETY: map_ranges hands out non-overlapping [lo, hi) ranges
            let span = unsafe { writer.span(lo, hi - lo) };
            for (off, slot) in span.iter_mut().enumerate() {
                *slot = (lo + off) as u32 * 2;
            }
        });
        drop(writer);
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as u32 * 2);
        }
    }

    #[test]
    fn nested_dispatch_makes_progress() {
        // inner jobs are drained by their own submitters even when every
        // pool worker is stuck on outer chunks
        let got = map_ranges(8, 8, |lo, hi| {
            let inner = map_ranges(4, 32, |a, b| (a..b).sum::<usize>());
            inner.iter().sum::<usize>() + (lo..hi).len()
        });
        let inner_sum: usize = (0..32).sum();
        assert_eq!(got.iter().sum::<usize>(), 8 * inner_sum + 8);
    }
}
