//! PCG32 — small, fast, statistically solid deterministic RNG.
//!
//! Used for synthetic data generation, rounding offsets on the Rust
//! quantization path, and the property-test harness. The stream is fully
//! determined by `(state, inc)` so every experiment is reproducible from
//! the seed recorded in its config.

/// PCG32 (XSH-RR variant, O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire's method, bias-free for our n).
    pub fn below(&mut self, n: u32) -> u32 {
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Standard normal (Box–Muller; one value per call, cached pair dropped
    /// for simplicity — throughput is not a concern at our sizes).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill a vec with N(0, sigma).
    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * sigma).collect()
    }

    /// Fill a vec with U[-1/2, 1/2) rounding offsets (Alg. 2's R tensor).
    pub fn rounding_offsets(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.uniform() - 0.5).collect()
    }

    /// [`Self::rounding_offsets`] into a caller-owned buffer: same draws
    /// in the same order, but reusing `out`'s capacity, so the warm step
    /// loop pays no allocation for its offset tensors.
    pub fn rounding_offsets_into(&mut self, out: &mut Vec<f32>, n: usize) {
        out.clear();
        out.extend((0..n).map(|_| self.uniform() - 0.5));
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg32::seeded(8);
        let mean: f32 = (0..100_000).map(|_| r.uniform()).sum::<f32>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let xs: Vec<f32> = (0..100_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg32::seeded(10);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn rounding_offsets_into_matches_allocating_draws() {
        let mut a = Pcg32::seeded(12);
        let mut b = Pcg32::seeded(12);
        let mut buf = Vec::new();
        for n in [0usize, 1, 7, 64, 3] {
            let want = a.rounding_offsets(n);
            b.rounding_offsets_into(&mut buf, n);
            assert_eq!(buf.len(), n);
            assert!(want.iter().zip(&buf).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
        // and the streams stay in lockstep afterwards
        assert_eq!(a.next_u32(), b.next_u32());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
