//! Mini property-testing harness.
//!
//! `proptest` is not available offline, so invariants are checked with a
//! simple randomized runner: N generated cases per property, deterministic
//! seeding, and the failing seed printed so a counterexample reproduces
//! with `PROP_SEED=<n> cargo test`.

use super::rng::Pcg32;

/// Number of cases per property (override with PROP_CASES).
pub fn cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Run `f` against `cases()` seeded RNGs; panic with the seed on failure.
pub fn check(name: &str, mut f: impl FnMut(&mut Pcg32)) {
    if let Ok(s) = std::env::var("PROP_SEED") {
        let seed: u64 = s.parse().expect("PROP_SEED must be an integer");
        let mut rng = Pcg32::seeded(seed);
        f(&mut rng);
        return;
    }
    for case in 0..cases() {
        let seed = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case + 1);
        let mut rng = Pcg32::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property {name:?} failed at case {case} (PROP_SEED={seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Random tensor shape with up to `max_dim` per axis (always 4-D).
pub fn shape4(rng: &mut Pcg32, max_dim: usize) -> [usize; 4] {
    [
        1 + rng.below(max_dim as u32) as usize,
        1 + rng.below(max_dim as u32) as usize,
        1 + rng.below(max_dim as u32) as usize,
        1 + rng.below(max_dim as u32) as usize,
    ]
}

/// Random tensor with per-(dim0,dim1) magnitude variation, the shape of
/// data the MLS group scaling exists for.
pub fn grouped_tensor(rng: &mut Pcg32, shape: [usize; 4]) -> Vec<f32> {
    let [d0, d1, d2, d3] = shape;
    let mut out = Vec::with_capacity(d0 * d1 * d2 * d3);
    for _ in 0..d0 {
        for _ in 0..d1 {
            let scale = (rng.normal() * 2.0).exp();
            for _ in 0..d2 * d3 {
                out.push(rng.normal() * scale);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("count", |_| n += 1);
        assert_eq!(n, cases());
    }

    #[test]
    fn shapes_in_range() {
        check("shape4", |rng| {
            let s = shape4(rng, 6);
            assert!(s.iter().all(|&d| (1..=6).contains(&d)));
        });
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        check("fails", |rng| {
            assert!(rng.uniform() < 0.5, "expected failure");
        });
    }
}
