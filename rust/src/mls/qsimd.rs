//! Vectorized quantizer inner loops (Alg. 2): the per-group |max|
//! reduce and the per-element quantize pass, with per-ISA paths selected
//! by [`crate::util::simd`] and pinned bit-identical to the scalar
//! reference in [`super::quantizer`].
//!
//! ## Why the vector element pass is exact
//!
//! The scalar path per element is: `xf = |v| / (S_g * S_t)`, then
//! [`format::quantize_element`] — a subnormal/normal branch, each doing
//! one f32 multiply, the rounding add, `floor`, an f32 clamp and a
//! saturating `as u32` cast. The vector lane computes BOTH branch
//! candidates branch-free and selects by the ordered compare
//! `xf < 2^emin` (all-subnormal when `E == 0`), with two deliberate
//! representation changes that are proven value-identical (exhaustively
//! modeled against the scalar semantics over every reachable edge case —
//! NaN from `0/0` under a zero group scale, denormals, overflowing
//! candidates — before this file was written):
//!
//! * the float clamp + saturating cast becomes `cvttps` (out-of-range
//!   and NaN produce `i32::MIN`) followed by an **integer** clamp to
//!   `[0, 2^M - 1]` — identical because the scalar clamp bounds are
//!   exactly representable and NaN claps to 0 on both paths;
//! * `2^-exp_cl` is built per lane by bit assembly
//!   (`(127 - exp_cl) << 23`) instead of a table — exact for
//!   `-126 <= -exp_cl <= 127`, guaranteed by the eligibility gate below.
//!
//! Eligibility: `E <= 7` and `M - emin <= 127` (every registry format
//! qualifies; exotic formats take the scalar path). Stochastic rounding
//! offsets are already precomputed per element by the caller, so the
//! vector pass consumes the same RNG sequence by construction. The
//! group |max| reduce is exact for any input — including NaN, which both
//! paths ignore — because vector lanes use "keep the accumulator unless
//! strictly greater" select semantics matching `f32::max`, all lanes are
//! non-negative, and max is order-independent on non-negative floats.
//!
//! `NEON` note: aarch64 gets the vector |max| reduce; its element pass
//! currently falls back to scalar (no aarch64 hardware in CI to pin it).

use super::format::{self, EmFormat};
use crate::util::simd::Level;

/// `max |x|` over one contiguous group chunk at the given dispatch
/// level; bit-identical to the serial `fold(0.0, |m, v| m.max(v.abs()))`
/// for every input.
pub(super) fn abs_max(level: Level, chunk: &[f32]) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: dispatch invariant — util::simd only yields levels the
        // running CPU supports
        Level::Avx2 if chunk.len() >= 8 => unsafe { abs_max_avx2(chunk) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above; the 128-bit path only uses baseline SSE2 ops
        Level::Sse41 if chunk.len() >= 4 => unsafe { abs_max_sse(chunk) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above (NEON verified by runtime detection)
        Level::Neon if chunk.len() >= 4 => unsafe { abs_max_neon(chunk) },
        _ => abs_max_scalar(chunk),
    }
}

pub(super) fn abs_max_scalar(chunk: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &v in chunk {
        m = m.max(v.abs());
    }
    m
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn abs_max_avx2(chunk: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
    let mut acc = _mm256_setzero_ps();
    let n8 = chunk.len() / 8 * 8;
    let mut i = 0;
    while i < n8 {
        let v = _mm256_and_ps(_mm256_loadu_ps(chunk.as_ptr().add(i)), absmask);
        // operand order matters: maxps returns the SECOND operand when
        // the compare is unordered, so a NaN lane in `v` keeps `acc` —
        // exactly f32::max's NaN-ignoring semantics
        acc = _mm256_max_ps(v, acc);
        i += 8;
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut m = 0.0f32;
    for &l in &lanes {
        m = m.max(l);
    }
    for &v in &chunk[n8..] {
        m = m.max(v.abs());
    }
    m
}

#[cfg(target_arch = "x86_64")]
unsafe fn abs_max_sse(chunk: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    let absmask = _mm_castsi128_ps(_mm_set1_epi32(0x7FFF_FFFF));
    let mut acc = _mm_setzero_ps();
    let n4 = chunk.len() / 4 * 4;
    let mut i = 0;
    while i < n4 {
        let v = _mm_and_ps(_mm_loadu_ps(chunk.as_ptr().add(i)), absmask);
        // v first: NaN lanes keep acc (see the AVX2 note)
        acc = _mm_max_ps(v, acc);
        i += 4;
    }
    let mut lanes = [0.0f32; 4];
    _mm_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut m = 0.0f32;
    for &l in &lanes {
        m = m.max(l);
    }
    for &v in &chunk[n4..] {
        m = m.max(v.abs());
    }
    m
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn abs_max_neon(chunk: &[f32]) -> f32 {
    use core::arch::aarch64::*;
    let mut acc = vdupq_n_f32(0.0);
    let n4 = chunk.len() / 4 * 4;
    let mut i = 0;
    while i < n4 {
        let v = vabsq_f32(vld1q_f32(chunk.as_ptr().add(i)));
        // compare-and-select instead of vmaxq (which would propagate
        // NaN): a NaN lane compares false and keeps acc, like f32::max
        acc = vbslq_f32(vcgtq_f32(v, acc), v, acc);
        i += 4;
    }
    let mut lanes = [0.0f32; 4];
    vst1q_f32(lanes.as_mut_ptr(), acc);
    let mut m = 0.0f32;
    for &l in &lanes {
        m = m.max(l);
    }
    for &v in &chunk[n4..] {
        m = m.max(v.abs());
    }
    m
}

/// Scalar per-element quantize — the exact op sequence of the historical
/// closure in [`super::quantizer::quantize_threaded`], now the single
/// source of truth for the scalar path, vector tails and fallbacks.
#[inline]
pub(super) fn quantize_one_scalar(v: f32, sg: f32, s_t_safe: f32, fmt: EmFormat, r: f32) -> (i8, u8, u32) {
    let s = if v > 0.0 {
        1
    } else if v < 0.0 {
        -1
    } else {
        0
    };
    // identical op order to ref.py: abs(x) / (s_g * s_t)
    let xf = v.abs() / (sg * s_t_safe);
    let (c, mm) = format::quantize_element(xf, fmt, r);
    (s, c, mm)
}

/// Whether the vector element pass may run for this format at this
/// level (see the module doc for why these bounds make it exact;
/// `m <= 23` additionally keeps the integer clamp bound in i32 range).
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
fn elem_eligible(fmt: EmFormat, level: Level) -> bool {
    matches!(level, Level::Avx2 | Level::Sse41)
        && fmt.e <= 7
        && fmt.m <= 23
        && (fmt.m as i32 - fmt.emin()) <= 127
}

/// Per-format constants hoisted out of the element loop.
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
struct ElemConsts {
    /// `2^(M - emin)`: subnormal-candidate scale
    sub_scale: f32,
    /// `2^emin`: the subnormal/normal threshold
    min_normal: f32,
    /// `2^M` as f32
    two_m: f32,
    /// `2^M - 1`: integer mantissa clamp bound
    two_m_m1: i32,
    emin: i32,
    /// `E == 0`: every lane takes the subnormal path
    all_sub: bool,
}

impl ElemConsts {
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    fn of(fmt: EmFormat) -> Self {
        let emin = fmt.emin();
        ElemConsts {
            sub_scale: format::exp2i(fmt.m as i32 - emin),
            min_normal: format::exp2i(emin),
            two_m: (1u32 << fmt.m) as f32,
            two_m_m1: (1i32 << fmt.m) - 1,
            emin,
            all_sub: fmt.e == 0,
        }
    }
}

/// Quantize one contiguous run of elements sharing the group scale
/// `sg`, appending `(sign, exp_code, man)` to the output planes.
/// `offsets` (stochastic rounding, same length as `x`) or `None`
/// (nearest). Bit-identical to calling [`quantize_one_scalar`] per
/// element in order, at every dispatch level.
#[allow(clippy::too_many_arguments)]
#[cfg_attr(not(target_arch = "x86_64"), allow(unused_variables, unused_mut))]
pub(super) fn quantize_run(
    level: Level,
    x: &[f32],
    offsets: Option<&[f32]>,
    sg: f32,
    s_t_safe: f32,
    fmt: EmFormat,
    sv: &mut Vec<i8>,
    cv: &mut Vec<u8>,
    mv: &mut Vec<u32>,
) {
    if let Some(o) = offsets {
        debug_assert_eq!(o.len(), x.len());
    }
    let mut i = 0usize;
    #[cfg(target_arch = "x86_64")]
    if elem_eligible(fmt, level) {
        // same two ops (mul then div) per lane as the scalar path, with
        // the product hoisted: sg * s_t_safe is bit-identical per run
        let den = sg * s_t_safe;
        let pre = ElemConsts::of(fmt);
        match level {
            Level::Avx2 => {
                while i + 8 <= x.len() {
                    // SAFETY: 8 lanes readable at i (loop bound), AVX2
                    // supported per the dispatch invariant
                    unsafe {
                        quantize8_avx2(
                            x.as_ptr().add(i),
                            offsets.map(|o| o.as_ptr().add(i)),
                            den,
                            &pre,
                            sv,
                            cv,
                            mv,
                        )
                    };
                    i += 8;
                }
            }
            Level::Sse41 => {
                while i + 4 <= x.len() {
                    // SAFETY: 4 lanes readable at i, SSE4.1 supported
                    unsafe {
                        quantize4_sse41(
                            x.as_ptr().add(i),
                            offsets.map(|o| o.as_ptr().add(i)),
                            den,
                            &pre,
                            sv,
                            cv,
                            mv,
                        )
                    };
                    i += 4;
                }
            }
            _ => {}
        }
    }
    // scalar tail (and the whole run for ineligible formats/levels)
    for (k, &v) in x.iter().enumerate().skip(i) {
        let r = offsets.map_or(0.0, |o| o[k]);
        let (s, c, m) = quantize_one_scalar(v, sg, s_t_safe, fmt, r);
        sv.push(s);
        cv.push(c);
        mv.push(m);
    }
}

/// One AVX2 vector of 8 elements through the branch-free quantize lane.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn quantize8_avx2(
    x: *const f32,
    r: Option<*const f32>,
    den: f32,
    pre: &ElemConsts,
    sv: &mut Vec<i8>,
    cv: &mut Vec<u8>,
    mv: &mut Vec<u32>,
) {
    use core::arch::x86_64::*;
    let v = _mm256_loadu_ps(x);
    let av = _mm256_and_ps(v, _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF)));
    let xf = _mm256_div_ps(av, _mm256_set1_ps(den));
    let rv = match r {
        Some(p) => _mm256_loadu_ps(p),
        None => _mm256_setzero_ps(),
    };
    let half = _mm256_set1_ps(0.5);
    let izero = _mm256_setzero_si256();
    let man_hi = _mm256_set1_epi32(pre.two_m_m1);
    // subnormal candidate: floor(xf * 2^(M-emin) + r + 0.5), same f32
    // op order as the scalar branch, then cvtt + integer clamp
    let t_sub = _mm256_floor_ps(_mm256_add_ps(
        _mm256_add_ps(_mm256_mul_ps(xf, _mm256_set1_ps(pre.sub_scale)), rv),
        half,
    ));
    let man_sub = _mm256_min_epi32(_mm256_max_epi32(_mm256_cvttps_epi32(t_sub), izero), man_hi);
    // normal candidate: exponent by bit extraction, clamp to [emin, -1],
    // 2^-exp_cl assembled per lane, then the scalar branch's op order
    let ebits = _mm256_sub_epi32(
        _mm256_and_si256(_mm256_srli_epi32::<23>(_mm256_castps_si256(xf)), _mm256_set1_epi32(0xFF)),
        _mm256_set1_epi32(127),
    );
    let exp_cl = _mm256_min_epi32(
        _mm256_max_epi32(ebits, _mm256_set1_epi32(pre.emin)),
        _mm256_set1_epi32(-1),
    );
    let pow = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_sub_epi32(
        _mm256_set1_epi32(127),
        exp_cl,
    )));
    let y = _mm256_mul_ps(xf, pow);
    let t_n = _mm256_floor_ps(_mm256_add_ps(
        _mm256_add_ps(
            _mm256_mul_ps(_mm256_sub_ps(y, _mm256_set1_ps(1.0)), _mm256_set1_ps(pre.two_m)),
            rv,
        ),
        half,
    ));
    let man_n = _mm256_min_epi32(_mm256_max_epi32(_mm256_cvttps_epi32(t_n), izero), man_hi);
    let code_n = _mm256_sub_epi32(izero, exp_cl);
    // select: ordered xf < 2^emin (NaN lanes -> normal path, where both
    // candidates yield man 0 / code 1 exactly like the scalar cast)
    let sub_mask = if pre.all_sub {
        _mm256_set1_epi32(-1)
    } else {
        _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(xf, _mm256_set1_ps(pre.min_normal)))
    };
    let man = _mm256_blendv_epi8(man_n, man_sub, sub_mask);
    let code = _mm256_andnot_si256(sub_mask, code_n);
    // sign: ordered compares, so NaN (and zero) lanes give 0
    let fzero = _mm256_setzero_ps();
    let pos = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_GT_OQ>(v, fzero));
    let neg = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(v, fzero));
    let sign = _mm256_or_si256(
        _mm256_and_si256(pos, _mm256_set1_epi32(1)),
        _mm256_and_si256(neg, _mm256_set1_epi32(-1)),
    );
    let mut sb = [0i32; 8];
    let mut cb = [0i32; 8];
    let mut mb = [0i32; 8];
    _mm256_storeu_si256(sb.as_mut_ptr() as *mut __m256i, sign);
    _mm256_storeu_si256(cb.as_mut_ptr() as *mut __m256i, code);
    _mm256_storeu_si256(mb.as_mut_ptr() as *mut __m256i, man);
    for l in 0..8 {
        sv.push(sb[l] as i8);
        cv.push(cb[l] as u8);
        mv.push(mb[l] as u32);
    }
}

/// One SSE4.1 vector of 4 elements — same lane recipe at half width.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn quantize4_sse41(
    x: *const f32,
    r: Option<*const f32>,
    den: f32,
    pre: &ElemConsts,
    sv: &mut Vec<i8>,
    cv: &mut Vec<u8>,
    mv: &mut Vec<u32>,
) {
    use core::arch::x86_64::*;
    let v = _mm_loadu_ps(x);
    let av = _mm_and_ps(v, _mm_castsi128_ps(_mm_set1_epi32(0x7FFF_FFFF)));
    let xf = _mm_div_ps(av, _mm_set1_ps(den));
    let rv = match r {
        Some(p) => _mm_loadu_ps(p),
        None => _mm_setzero_ps(),
    };
    let half = _mm_set1_ps(0.5);
    let izero = _mm_setzero_si128();
    let man_hi = _mm_set1_epi32(pre.two_m_m1);
    let t_sub =
        _mm_floor_ps(_mm_add_ps(_mm_add_ps(_mm_mul_ps(xf, _mm_set1_ps(pre.sub_scale)), rv), half));
    let man_sub = _mm_min_epi32(_mm_max_epi32(_mm_cvttps_epi32(t_sub), izero), man_hi);
    let ebits = _mm_sub_epi32(
        _mm_and_si128(_mm_srli_epi32::<23>(_mm_castps_si128(xf)), _mm_set1_epi32(0xFF)),
        _mm_set1_epi32(127),
    );
    let exp_cl = _mm_min_epi32(_mm_max_epi32(ebits, _mm_set1_epi32(pre.emin)), _mm_set1_epi32(-1));
    let pow = _mm_castsi128_ps(_mm_slli_epi32::<23>(_mm_sub_epi32(_mm_set1_epi32(127), exp_cl)));
    let y = _mm_mul_ps(xf, pow);
    let t_n = _mm_floor_ps(_mm_add_ps(
        _mm_add_ps(_mm_mul_ps(_mm_sub_ps(y, _mm_set1_ps(1.0)), _mm_set1_ps(pre.two_m)), rv),
        half,
    ));
    let man_n = _mm_min_epi32(_mm_max_epi32(_mm_cvttps_epi32(t_n), izero), man_hi);
    let code_n = _mm_sub_epi32(izero, exp_cl);
    let sub_mask = if pre.all_sub {
        _mm_set1_epi32(-1)
    } else {
        _mm_castps_si128(_mm_cmplt_ps(xf, _mm_set1_ps(pre.min_normal)))
    };
    let man = _mm_blendv_epi8(man_n, man_sub, sub_mask);
    let code = _mm_andnot_si128(sub_mask, code_n);
    let fzero = _mm_setzero_ps();
    let pos = _mm_castps_si128(_mm_cmpgt_ps(v, fzero));
    let neg = _mm_castps_si128(_mm_cmplt_ps(v, fzero));
    let sign = _mm_or_si128(_mm_and_si128(pos, _mm_set1_epi32(1)), _mm_and_si128(neg, _mm_set1_epi32(-1)));
    let mut sb = [0i32; 4];
    let mut cb = [0i32; 4];
    let mut mb = [0i32; 4];
    _mm_storeu_si128(sb.as_mut_ptr() as *mut __m128i, sign);
    _mm_storeu_si128(cb.as_mut_ptr() as *mut __m128i, code);
    _mm_storeu_si128(mb.as_mut_ptr() as *mut __m128i, man);
    for l in 0..4 {
        sv.push(sb[l] as i8);
        cv.push(cb[l] as u8);
        mv.push(mb[l] as u32);
    }
}

/// Whether the vector dequantize pass may run for this format at this
/// level: `e <= 6` keeps every exponent code `<= 63` so `2^-code` can be
/// assembled per lane as a normal f32 (`(127 - code) << 23`) and the
/// subnormal-branch product `(man/2^M) * 2^emin >= 2^-86` stays normal;
/// `m <= 23` keeps the mantissa exact under `cvtepi32_ps`.
#[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
fn deq_eligible(fmt: EmFormat, level: Level) -> bool {
    matches!(level, Level::Avx2 | Level::Sse41) && fmt.e <= 6 && fmt.m <= 23
}

/// Dequantize one contiguous run of elements sharing the hoisted scale
/// `sg` (`= S_t * S_g`), appending to `out`. Bit-identical to the scalar
/// per-element expression `sign as f32 * sg * fmt.decode(code, man)` at
/// every dispatch level (the vector lane reproduces each scalar f32 op
/// in the same order: `man/2^M`, the normal/subnormal decode branch as a
/// branch-free select, then the two scale multiplies left to right).
pub(super) fn dequantize_run(
    level: Level,
    sign: &[i8],
    exp_code: &[u8],
    man: &[u32],
    sg: f32,
    fmt: EmFormat,
    out: &mut Vec<f32>,
) {
    let n = sign.len();
    debug_assert_eq!(exp_code.len(), n);
    debug_assert_eq!(man.len(), n);
    let start = out.len();
    out.resize(start + n, 0.0);
    let dst = &mut out[start..];
    let mut i = 0usize;
    #[cfg(target_arch = "x86_64")]
    if deq_eligible(fmt, level) {
        let two_m = (1u32 << fmt.m) as f32;
        let emin_pow = format::exp2i(fmt.emin());
        match level {
            Level::Avx2 => {
                while i + 8 <= n {
                    // SAFETY: 8 lanes readable/writable at i (loop
                    // bound), AVX2 supported per the dispatch invariant
                    unsafe {
                        dequantize8_avx2(
                            sign.as_ptr().add(i),
                            exp_code.as_ptr().add(i),
                            man.as_ptr().add(i),
                            sg,
                            two_m,
                            emin_pow,
                            dst.as_mut_ptr().add(i),
                        )
                    };
                    i += 8;
                }
            }
            Level::Sse41 => {
                while i + 4 <= n {
                    // SAFETY: 4 lanes readable/writable at i, SSE4.1
                    // supported
                    unsafe {
                        dequantize4_sse41(
                            sign.as_ptr().add(i),
                            exp_code.as_ptr().add(i),
                            man.as_ptr().add(i),
                            sg,
                            two_m,
                            emin_pow,
                            dst.as_mut_ptr().add(i),
                        )
                    };
                    i += 4;
                }
            }
            _ => {}
        }
    }
    // scalar tail (and the whole run for ineligible formats/levels) —
    // the exact op order of MlsTensor::dequantize_threaded
    for k in i..n {
        let xbar = fmt.decode(exp_code[k], man[k]);
        dst[k] = sign[k] as f32 * sg * xbar;
    }
}

/// One AVX2 vector of 8 elements through the branch-free decode lane.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dequantize8_avx2(
    sign: *const i8,
    code: *const u8,
    man: *const u32,
    sg: f32,
    two_m: f32,
    emin_pow: f32,
    out: *mut f32,
) {
    use core::arch::x86_64::*;
    let sign_i = _mm256_cvtepi8_epi32(_mm_loadl_epi64(sign as *const __m128i));
    let code_i = _mm256_cvtepu8_epi32(_mm_loadl_epi64(code as *const __m128i));
    // exact: man <= 2^M - 1 <= 2^23 - 1 fits f32's mantissa
    let man_f = _mm256_cvtepi32_ps(_mm256_loadu_si256(man as *const __m256i));
    let frac = _mm256_div_ps(man_f, _mm256_set1_ps(two_m));
    // normal candidate: (1 + man/2^M) * 2^-code, 2^-code assembled per
    // lane (code <= 63 by the eligibility gate, so always normal)
    let pow = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_sub_epi32(
        _mm256_set1_epi32(127),
        code_i,
    )));
    let normal = _mm256_mul_ps(_mm256_add_ps(_mm256_set1_ps(1.0), frac), pow);
    // subnormal candidate: (man/2^M) * 2^emin
    let sub = _mm256_mul_ps(frac, _mm256_set1_ps(emin_pow));
    let is_sub = _mm256_castsi256_ps(_mm256_cmpeq_epi32(code_i, _mm256_setzero_si256()));
    let xbar = _mm256_blendv_ps(normal, sub, is_sub);
    // (sign * sg) * xbar — the scalar left-to-right multiply order
    let sign_f = _mm256_cvtepi32_ps(sign_i);
    let res = _mm256_mul_ps(_mm256_mul_ps(sign_f, _mm256_set1_ps(sg)), xbar);
    _mm256_storeu_ps(out, res);
}

/// One SSE4.1 vector of 4 elements — same lane recipe at half width.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
unsafe fn dequantize4_sse41(
    sign: *const i8,
    code: *const u8,
    man: *const u32,
    sg: f32,
    two_m: f32,
    emin_pow: f32,
    out: *mut f32,
) {
    use core::arch::x86_64::*;
    let sign_i = _mm_cvtepi8_epi32(_mm_cvtsi32_si128((sign as *const i32).read_unaligned()));
    let code_i = _mm_cvtepu8_epi32(_mm_cvtsi32_si128((code as *const i32).read_unaligned()));
    let man_f = _mm_cvtepi32_ps(_mm_loadu_si128(man as *const __m128i));
    let frac = _mm_div_ps(man_f, _mm_set1_ps(two_m));
    let pow =
        _mm_castsi128_ps(_mm_slli_epi32::<23>(_mm_sub_epi32(_mm_set1_epi32(127), code_i)));
    let normal = _mm_mul_ps(_mm_add_ps(_mm_set1_ps(1.0), frac), pow);
    let sub = _mm_mul_ps(frac, _mm_set1_ps(emin_pow));
    let is_sub = _mm_castsi128_ps(_mm_cmpeq_epi32(code_i, _mm_setzero_si128()));
    let xbar = _mm_blendv_ps(normal, sub, is_sub);
    let sign_f = _mm_cvtepi32_ps(sign_i);
    let res = _mm_mul_ps(_mm_mul_ps(sign_f, _mm_set1_ps(sg)), xbar);
    _mm_storeu_ps(out, res);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::simd::Level;

    #[test]
    fn abs_max_matches_scalar_on_every_level() {
        let mut rng = Pcg32::seeded(0xA85);
        for n in [0usize, 1, 3, 4, 7, 8, 9, 31, 32, 100, 257] {
            let mut v = rng.normal_vec(n, 2.0);
            if n > 4 {
                v[n / 2] = 0.0;
                v[n - 1] = -v[n - 1].abs();
            }
            let want = abs_max_scalar(&v);
            for level in Level::supported() {
                assert_eq!(
                    abs_max(level, &v).to_bits(),
                    want.to_bits(),
                    "n={n} level {}",
                    level.name()
                );
            }
        }
    }

    #[test]
    fn abs_max_ignores_nan_like_scalar_fold() {
        let mut v = vec![1.0f32, -3.5, f32::NAN, 2.0, -0.5, f32::NAN, 0.25, 1.75, 0.5];
        for level in Level::supported() {
            assert_eq!(abs_max(level, &v), 3.5, "level {}", level.name());
        }
        // NaN in a tail position too
        v.push(f32::NAN);
        for level in Level::supported() {
            assert_eq!(abs_max(level, &v), 3.5, "tail, level {}", level.name());
        }
    }

    /// Run-level pin: the vector quantize path equals the scalar path
    /// element for element — values, edge cases (exact powers, tiny
    /// denormal inputs, zeros, negatives) and the stochastic offset
    /// sequence — for a spread of formats incl. the all-subnormal E=0.
    #[test]
    fn quantize_run_matches_scalar_on_every_level() {
        let mut rng = Pcg32::seeded(0x9A11);
        let formats =
            [(0u32, 4u32), (0, 2), (1, 1), (2, 1), (2, 4), (3, 4), (3, 0), (5, 2), (7, 0)];
        for (e, m) in formats {
            let fmt = EmFormat::new(e, m);
            for n in [1usize, 4, 7, 8, 9, 64, 129] {
                let mut x = rng.normal_vec(n, 1.0);
                if n >= 8 {
                    x[0] = 0.0;
                    x[1] = 1.0;
                    x[2] = -1.0;
                    x[3] = format::exp2i(fmt.emin());
                    x[4] = format::exp2i(fmt.emin()) * 0.5;
                    x[5] = f32::from_bits(1); // smallest denormal input
                }
                let offsets = rng.rounding_offsets(n);
                for (sg, s_t) in [(1.0f32, 1.0f32), (0.5, 2.5), (0.015625, 100.0)] {
                    for use_offsets in [false, true] {
                        let o = use_offsets.then_some(&offsets[..]);
                        let mut want = (Vec::new(), Vec::new(), Vec::new());
                        for (k, &v) in x.iter().enumerate() {
                            let r = o.map_or(0.0, |o| o[k]);
                            let (s, c, mm) = quantize_one_scalar(v, sg, s_t, fmt, r);
                            want.0.push(s);
                            want.1.push(c);
                            want.2.push(mm);
                        }
                        for level in Level::supported() {
                            let mut got = (Vec::new(), Vec::new(), Vec::new());
                            quantize_run(
                                level, &x, o, sg, s_t, fmt, &mut got.0, &mut got.1, &mut got.2,
                            );
                            assert_eq!(
                                got,
                                want,
                                "e{e}m{m} n={n} sg={sg} sr={use_offsets} level {}",
                                level.name()
                            );
                        }
                    }
                }
            }
        }
    }

    /// Run-level pin for the decode direction: the vector dequantize
    /// path equals the scalar per-element expression bit for bit —
    /// including the e=7 format that fails the eligibility gate and must
    /// fall back to scalar — for every supported level.
    #[test]
    fn dequantize_run_matches_scalar_on_every_level() {
        let mut rng = Pcg32::seeded(0xDE09);
        let formats =
            [(0u32, 4u32), (0, 2), (1, 1), (2, 1), (2, 4), (3, 4), (3, 0), (5, 2), (6, 3), (7, 0)];
        for (e, m) in formats {
            let fmt = EmFormat::new(e, m);
            let code_hi = (1u32 << e) as u32; // codes in [0, 2^e - 1]
            let man_hi = 1u32 << m;
            for n in [1usize, 3, 4, 7, 8, 9, 64, 129] {
                let sign: Vec<i8> =
                    (0..n).map(|_| [(-1i8), 0, 1][rng.below(3) as usize]).collect();
                let code: Vec<u8> = (0..n).map(|_| rng.below(code_hi) as u8).collect();
                let man: Vec<u32> = (0..n).map(|_| rng.below(man_hi)).collect();
                for sg in [1.0f32, 0.37, 2.5e-3] {
                    let want: Vec<f32> = (0..n)
                        .map(|k| sign[k] as f32 * sg * fmt.decode(code[k], man[k]))
                        .collect();
                    for level in Level::supported() {
                        let mut got = vec![99.0f32]; // nonempty: append semantics
                        dequantize_run(level, &sign, &code, &man, sg, fmt, &mut got);
                        assert_eq!(got.len(), n + 1, "e{e}m{m} n={n}");
                        assert_eq!(got[0], 99.0);
                        for (k, (a, b)) in got[1..].iter().zip(&want).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "e{e}m{m} n={n} sg={sg} k={k} level {}",
                                level.name()
                            );
                        }
                    }
                }
            }
        }
    }
}
