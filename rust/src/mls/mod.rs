//! Bit-accurate MLS (multi-level scaling) tensor format — the Rust mirror
//! of the canonical numerics in `python/compile/kernels/ref.py`.
//!
//! The three scaling levels (paper Sec. IV):
//!
//! 1. **tensor-wise** `S_t` — an ordinary f32 (the tensor's max magnitude),
//! 2. **group-wise** `S_g` — a hardware-friendly `<E_g, M_g<=1>` value
//!    (power of two, or a two-term shift-add),
//! 3. **element-wise** `<E_x, M_x>` — sign + exponent code + mantissa with
//!    IEEE-754-style gradual underflow.
//!
//! Every function here is validated bit-exactly against Python golden
//! vectors (`rust/tests/golden.rs`) and by property tests
//! (`rust/tests/proptests.rs`).

pub mod error;
pub mod format;
pub mod grouping;
mod qsimd;
pub mod quantizer;
pub mod tensor;

pub use format::EmFormat;
pub use grouping::Grouping;
pub use quantizer::{QuantConfig, Rounding};
pub use tensor::MlsTensor;
