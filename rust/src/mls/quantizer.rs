//! Dynamic quantization to the MLS format (paper Alg. 2) — bit-accurate.
//!
//! The pipeline mirrors ref.mls_quantize_fields operation-for-operation so
//! its output matches the Python/XLA float simulation bit-exactly:
//!
//!   S_s = sign(X);  S_r = GroupMax|X|;  S_t = max(S_r)
//!   S_g = ceil-quantized <E_g, M_g>(S_r / S_t)
//!   X_f = |X| / (S_g * S_t)          (f32 mul then f32 div, same order)
//!   Xbar = <E_x, M_x>(X_f) with stochastic rounding + gradual underflow
//!
//! The group |max| reduce and the contiguous element pass run through
//! the vectorized kernels in [`super::qsimd`] (SSE4.1/AVX2, runtime
//! dispatch via [`crate::util::simd`]), pinned bit-identical to the
//! scalar path — including the stochastic-rounding offset sequence,
//! which is drawn per element by the caller and merely consumed here.

use super::format::{self, EmFormat};
use super::grouping::Grouping;
use super::qsimd;
use super::tensor::MlsTensor;
use crate::util::json::Json;
use crate::util::parallel;

/// Rounding mode (Alg. 2 line 13).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Rounding {
    /// SRound(x, r) = floor(x + r + 1/2), r ~ U[-1/2, 1/2)
    Stochastic,
    /// floor(x + 1/2)
    Nearest,
}

impl Rounding {
    /// Every supported rounding mode. [`Self::parse`] and
    /// [`Self::parse_short`] scan this list, so parseable names cannot
    /// drift from `name()`/`short_name()` outputs (same registry
    /// discipline as [`Grouping::ALL`] and
    /// [`crate::coordinator::Backend::ALL`]).
    pub const ALL: [Rounding; 2] = [Rounding::Stochastic, Rounding::Nearest];

    pub fn parse(s: &str) -> anyhow::Result<Rounding> {
        Self::ALL.into_iter().find(|r| r.name() == s).ok_or_else(|| {
            anyhow::anyhow!("unknown rounding {s:?} (have {:?})", Self::ALL.map(|r| r.name()))
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Rounding::Stochastic => "stochastic",
            Rounding::Nearest => "nearest",
        }
    }

    /// Short token used inside [`QuantConfig`] names (`"sr"`/`"nr"`).
    pub fn short_name(&self) -> &'static str {
        match self {
            Rounding::Stochastic => "sr",
            Rounding::Nearest => "nr",
        }
    }

    /// Inverse of [`Self::short_name`], scanning [`Self::ALL`].
    pub fn parse_short(s: &str) -> anyhow::Result<Rounding> {
        Self::ALL.into_iter().find(|r| r.short_name() == s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown rounding token {s:?} (have {:?})",
                Self::ALL.map(|r| r.short_name())
            )
        })
    }
}

/// Full quantizer configuration; field-compatible with the Python
/// `QuantConfig` (and its JSON form in the artifact manifest).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    pub element: EmFormat,
    pub group: EmFormat,
    pub grouping: Grouping,
    pub rounding: Rounding,
    pub enabled: bool,
}

impl Default for QuantConfig {
    /// The paper's ImageNet headline config: `<2,4>` elements, `<8,1>`
    /// group scales, n x c grouping, stochastic rounding.
    fn default() -> Self {
        QuantConfig {
            element: EmFormat::new(2, 4),
            group: EmFormat::new(8, 1),
            grouping: Grouping::Both,
            rounding: Rounding::Stochastic,
            enabled: true,
        }
    }
}

impl QuantConfig {
    pub fn new(e_x: u32, m_x: u32) -> Self {
        QuantConfig { element: EmFormat::new(e_x, m_x), ..Default::default() }
    }

    pub fn fp32() -> Self {
        QuantConfig { enabled: false, ..Default::default() }
    }

    /// Parse the JSON object produced by Python `QuantConfig.to_dict()`.
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(QuantConfig {
            element: EmFormat::new(
                v.req("e_x")?.as_i64().unwrap_or(2) as u32,
                v.req("m_x")?.as_i64().unwrap_or(4) as u32,
            ),
            group: EmFormat::new(
                v.req("e_g")?.as_i64().unwrap_or(8) as u32,
                v.req("m_g")?.as_i64().unwrap_or(1) as u32,
            ),
            grouping: Grouping::parse(v.req("grouping")?.as_str().unwrap_or("both"))?,
            rounding: Rounding::parse(v.req("rounding")?.as_str().unwrap_or("stochastic"))?,
            enabled: v.req("enabled")?.as_bool().unwrap_or(true),
        })
    }

    /// Stable short name matching Python `QuantConfig.name()`. The
    /// grouping/rounding tokens come from the same
    /// [`Grouping::short_name`] / [`Rounding::short_name`] registries
    /// that [`Self::parse_name`] scans, so `parse_name(name())` is a
    /// round trip by construction for every supported config.
    pub fn name(&self) -> String {
        if !self.enabled {
            return "fp32".to_string();
        }
        format!(
            "e{}m{}_{}_eg{}mg{}_{}",
            self.element.e,
            self.element.m,
            self.grouping.short_name(),
            self.group.e,
            self.group.m,
            self.rounding.short_name()
        )
    }

    /// Parse a [`Self::name`]-formatted config string (the inverse of
    /// `name()`, e.g. `"e2m4_gnc_eg8mg1_sr"` or `"fp32"`). This is how
    /// the native training backend maps a `cfg_name` from
    /// [`crate::coordinator::TrainConfig`] onto a quantizer config with
    /// no artifact manifest involved.
    pub fn parse_name(s: &str) -> anyhow::Result<QuantConfig> {
        if s == "fp32" {
            return Ok(QuantConfig::fp32());
        }
        // element fields read "e{E}m{M}", group fields "eg{E}mg{M}"
        let parse_em = |part: &str, prefix: &str, sep: &str| -> anyhow::Result<EmFormat> {
            let rest = part
                .strip_prefix(prefix)
                .ok_or_else(|| anyhow::anyhow!("config {s:?}: {part:?} must start with {prefix:?}"))?;
            let (e, m) = rest
                .split_once(sep)
                .ok_or_else(|| anyhow::anyhow!("config {s:?}: {part:?} has no mantissa field"))?;
            Ok(EmFormat::new(
                e.parse().map_err(|_| anyhow::anyhow!("config {s:?}: bad E in {part:?}"))?,
                m.parse().map_err(|_| anyhow::anyhow!("config {s:?}: bad M in {part:?}"))?,
            ))
        };
        let parts: Vec<&str> = s.split('_').collect();
        anyhow::ensure!(
            parts.len() == 4,
            "config {s:?}: expected eEmM_<grouping>_egEmgM_<rounding> or \"fp32\""
        );
        let grouping = Grouping::parse_short(parts[1]).map_err(|e| e.context(format!("config {s:?}")))?;
        let rounding = Rounding::parse_short(parts[3]).map_err(|e| e.context(format!("config {s:?}")))?;
        Ok(QuantConfig {
            element: parse_em(parts[0], "e", "m")?,
            group: parse_em(parts[2], "eg", "mg")?,
            grouping,
            rounding,
            enabled: true,
        })
    }

    /// Stored bits per element (sign + exponent code + mantissa).
    pub fn element_bits(&self) -> u32 {
        1 + self.element.bits()
    }

    /// Smallest power-of-two integer accumulator for intra-group sums
    /// (Sec. V-C: product bits + 4 bits of K*K=9 accumulation headroom;
    /// matches the paper's Table II column: 8 for <1,1>, 16 for <2,1>,
    /// 32 for <2,4>).
    pub fn accumulator_bits(&self) -> u32 {
        let need = self.element.product_bits() + 4;
        for w in [8u32, 16, 32, 64] {
            if need <= w {
                return w;
            }
        }
        64
    }
}

/// Below this element count the ambient-thread entry points
/// ([`quantize`], [`crate::mls::MlsTensor::dequantize`]) stay serial:
/// even with the persistent pool a dispatch costs queue/wake/join
/// synchronization that a tiny tensor cannot amortize. Sharding is
/// bit-identical at every thread count, so the threshold is a pure
/// scheduling choice (pinned by `rust/tests/parallel_equivalence.rs`);
/// the explicit `*_threaded` entry points are not second-guessed.
pub const SERIAL_FALLBACK_ELEMS: usize = 16 * 1024;

/// Quantize a tensor to the full MLS decomposition.
///
/// `rounding_offsets` must have one U[-1/2, 1/2) value per element when the
/// config says stochastic (pass `&[]` for nearest — it is ignored).
///
/// The group-maxima and element passes are sharded over scaling groups on
/// the [`crate::util::parallel`] pool (`MLS_THREADS` workers, serial below
/// [`SERIAL_FALLBACK_ELEMS`] elements); see [`quantize_threaded`] for the
/// bit-identity guarantee.
pub fn quantize(x: &[f32], shape: &[usize], cfg: &QuantConfig, rounding_offsets: &[f32]) -> MlsTensor {
    let n: usize = shape.iter().product::<usize>().max(1);
    let threads = if n < SERIAL_FALLBACK_ELEMS { 1 } else { parallel::num_threads() };
    quantize_threaded(x, shape, cfg, rounding_offsets, threads)
}

/// [`quantize`] with an explicit worker count.
///
/// Groups (and, for the strided `Second` grouping, elements) are
/// independent given the tensor scale, and that scale is reduced in the
/// same group order regardless of sharding, so the output is bit-identical
/// for every `threads` value (pinned by
/// `rust/tests/parallel_equivalence.rs`).
pub fn quantize_threaded(
    x: &[f32],
    shape: &[usize],
    cfg: &QuantConfig,
    rounding_offsets: &[f32],
    threads: usize,
) -> MlsTensor {
    let n: usize = shape.iter().product::<usize>().max(1);
    assert_eq!(x.len(), n, "shape/element mismatch");
    let stochastic = cfg.rounding == Rounding::Stochastic;
    if stochastic {
        assert_eq!(rounding_offsets.len(), n, "need one rounding offset per element");
    }

    let n_groups = cfg.grouping.group_count(shape);
    // SIMD dispatch level read once per call: every shard of this call
    // runs the same kernels (all levels are bit-identical anyway)
    let level = crate::util::simd::active();

    // Per-element group ids cost a division each; all groupings except
    // Second are CONTIGUOUS runs of group_len elements in row-major
    // order, so the hot loops below walk chunk-wise (perf pass log in
    // EXPERIMENTS.md section Perf: ~2.3x on the <2,4> nc path).
    let group_len = cfg.grouping.group_len(shape);
    let contiguous = !matches!(cfg.grouping, Grouping::Second);

    // group maxima S_r and tensor max S_t (Alg. 2 lines 1-3)
    let s_r: Vec<f32> = if contiguous {
        // one max per contiguous group chunk, sharded over group ranges
        parallel::map_ranges(threads, n_groups, |lo, hi| {
            let mut part = Vec::with_capacity(hi - lo);
            for g in lo..hi {
                part.push(qsimd::abs_max(level, &x[g * group_len..(g + 1) * group_len]));
            }
            part
        })
        .concat()
    } else {
        let mut s_r = vec![0.0f32; n_groups];
        for (idx, &v) in x.iter().enumerate() {
            let g = cfg.grouping.group_of(shape, idx);
            let a = v.abs();
            if a > s_r[g] {
                s_r[g] = a;
            }
        }
        s_r
    };
    let s_t = s_r.iter().cloned().fold(0.0f32, f32::max);
    let s_t_safe = if s_t > 0.0 { s_t } else { 1.0 };

    // group scales (lines 4-8) — O(n_groups), kept serial
    let mut sg_exp = vec![0u8; n_groups];
    let mut sg_man = vec![0u32; n_groups];
    let mut sg_val = vec![0.0f32; n_groups];
    for g in 0..n_groups {
        let sgf = s_r[g] / s_t_safe;
        let (c, m) = format::quantize_group_scale(sgf, cfg.group);
        sg_exp[g] = c;
        sg_man[g] = m;
        sg_val[g] = format::group_scale_value(c, m, cfg.group);
    }

    // elements (lines 9-16) — per element, independent given its group
    // scale. Contiguous groupings walk single-scale runs through the
    // (possibly vectorized) qsimd::quantize_run; the strided Second
    // grouping stays scalar per element.
    let fmt = cfg.element;
    let run_offsets = |lo: usize, hi: usize| -> Option<&[f32]> {
        stochastic.then(|| &rounding_offsets[lo..hi])
    };
    let parts: Vec<(Vec<i8>, Vec<u8>, Vec<u32>)> = if contiguous && n_groups >= threads {
        // shard over group ranges so each worker walks whole chunks
        parallel::map_ranges(threads, n_groups, |lo, hi| {
            let len = (hi - lo) * group_len;
            let mut sv = Vec::with_capacity(len);
            let mut cv = Vec::with_capacity(len);
            let mut mv = Vec::with_capacity(len);
            for g in lo..hi {
                let (base, end) = (g * group_len, (g + 1) * group_len);
                qsimd::quantize_run(
                    level,
                    &x[base..end],
                    run_offsets(base, end),
                    sg_val[g],
                    s_t_safe,
                    fmt,
                    &mut sv,
                    &mut cv,
                    &mut mv,
                );
            }
            (sv, cv, mv)
        })
    } else if contiguous {
        // fewer groups than workers (e.g. Grouping::None has exactly one):
        // shard over flat element ranges, split at group boundaries; the
        // group of element idx is idx / group_len for every contiguous
        // grouping
        parallel::map_ranges(threads, n, |lo, hi| {
            let mut sv = Vec::with_capacity(hi - lo);
            let mut cv = Vec::with_capacity(hi - lo);
            let mut mv = Vec::with_capacity(hi - lo);
            let mut idx = lo;
            while idx < hi {
                let g = idx / group_len;
                let end = ((g + 1) * group_len).min(hi);
                qsimd::quantize_run(
                    level,
                    &x[idx..end],
                    run_offsets(idx, end),
                    sg_val[g],
                    s_t_safe,
                    fmt,
                    &mut sv,
                    &mut cv,
                    &mut mv,
                );
                idx = end;
            }
            (sv, cv, mv)
        })
    } else {
        // strided groups: shard over flat element ranges instead
        parallel::map_ranges(threads, n, |lo, hi| {
            let mut sv = Vec::with_capacity(hi - lo);
            let mut cv = Vec::with_capacity(hi - lo);
            let mut mv = Vec::with_capacity(hi - lo);
            for (idx, &v) in x[lo..hi].iter().enumerate().map(|(o, v)| (lo + o, v)) {
                let g = cfg.grouping.group_of(shape, idx);
                let r = if stochastic { rounding_offsets[idx] } else { 0.0 };
                let (s, c, m) = qsimd::quantize_one_scalar(v, sg_val[g], s_t_safe, fmt, r);
                sv.push(s);
                cv.push(c);
                mv.push(m);
            }
            (sv, cv, mv)
        })
    };
    let mut sign = Vec::with_capacity(n);
    let mut exp_code = Vec::with_capacity(n);
    let mut man = Vec::with_capacity(n);
    for (sv, cv, mv) in parts {
        sign.extend(sv);
        exp_code.extend(cv);
        man.extend(mv);
    }

    MlsTensor {
        shape: shape.to_vec(),
        cfg: *cfg,
        s_t: if s_t > 0.0 { s_t } else { 0.0 },
        sign,
        exp_code,
        man,
        sg_exp,
        sg_man,
    }
}

/// Fake-quantize: quantize + dequantize in one pass (the value the training
/// simulation sees). Bit-exact vs ref.mls_fake_quant.
pub fn fake_quant(x: &[f32], shape: &[usize], cfg: &QuantConfig, rounding_offsets: &[f32]) -> Vec<f32> {
    if !cfg.enabled {
        return x.to_vec();
    }
    let t = quantize(x, shape, cfg, rounding_offsets);
    t.dequantize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        rng.normal_vec(n, 1.0)
    }

    #[test]
    fn config_names_match_python() {
        assert_eq!(QuantConfig::default().name(), "e2m4_gnc_eg8mg1_sr");
        assert_eq!(QuantConfig::fp32().name(), "fp32");
        let mut c = QuantConfig::new(0, 2);
        c.grouping = Grouping::First;
        assert_eq!(c.name(), "e0m2_gf_eg8mg1_sr");
    }

    #[test]
    fn parse_name_round_trips() {
        let mut configs = vec![QuantConfig::default(), QuantConfig::fp32(), QuantConfig::new(2, 1)];
        let mut c = QuantConfig::new(0, 2);
        c.grouping = Grouping::First;
        c.rounding = Rounding::Nearest;
        configs.push(c);
        let mut c = QuantConfig::new(1, 1);
        c.grouping = Grouping::Second;
        configs.push(c);
        let mut c = QuantConfig::new(2, 4);
        c.grouping = Grouping::None;
        configs.push(c);
        for cfg in configs {
            let parsed = QuantConfig::parse_name(&cfg.name()).unwrap();
            assert_eq!(parsed, cfg, "round trip of {}", cfg.name());
        }
        assert!(QuantConfig::parse_name("nope").is_err());
        assert!(QuantConfig::parse_name("e2m4_gx_eg8mg1_sr").is_err());
        assert!(QuantConfig::parse_name("e2m4_gnc_eg8mg1_xx").is_err());
    }

    #[test]
    fn every_supported_name_round_trips_through_the_registry() {
        // property test over the full generator grid: parse_name is the
        // exact inverse of name() for every grouping x rounding (from
        // their ALL registries) x a spread of element/group formats, so
        // validate_native_config error listings can never name a config
        // that does not parse (or vice versa)
        let mut count = 0usize;
        for grouping in Grouping::ALL {
            for rounding in Rounding::ALL {
                for e_x in 0..=3u32 {
                    for m_x in 0..=4u32 {
                        for (e_g, m_g) in [(8u32, 1u32), (8, 0), (4, 2)] {
                            let cfg = QuantConfig {
                                element: EmFormat::new(e_x, m_x),
                                group: EmFormat::new(e_g, m_g),
                                grouping,
                                rounding,
                                enabled: true,
                            };
                            let name = cfg.name();
                            let parsed = QuantConfig::parse_name(&name)
                                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
                            assert_eq!(parsed, cfg, "{name}");
                            assert_eq!(parsed.name(), name, "{name}: second trip");
                            count += 1;
                        }
                    }
                }
            }
        }
        assert_eq!(count, 4 * 2 * 4 * 5 * 3, "grid fully enumerated");
        let fp = QuantConfig::parse_name("fp32").unwrap();
        assert_eq!(fp, QuantConfig::fp32());
        assert_eq!(fp.name(), "fp32");
        // unknown tokens list every valid short name
        let err = format!("{:#}", QuantConfig::parse_name("e2m4_gx_eg8mg1_sr").unwrap_err());
        for g in Grouping::ALL {
            assert!(err.contains(g.short_name()), "{err}");
        }
        let err = format!("{:#}", QuantConfig::parse_name("e2m4_gnc_eg8mg1_xx").unwrap_err());
        for r in Rounding::ALL {
            assert!(err.contains(r.short_name()), "{err}");
        }
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"e_x": 2, "m_x": 1, "e_g": 8, "m_g": 0, "grouping": "second",
                "rounding": "nearest", "enabled": true}"#,
        )
        .unwrap();
        let c = QuantConfig::from_json(&j).unwrap();
        assert_eq!(c.element, EmFormat::new(2, 1));
        assert_eq!(c.group, EmFormat::new(8, 0));
        assert_eq!(c.grouping, Grouping::Second);
        assert_eq!(c.rounding, Rounding::Nearest);
    }

    #[test]
    fn accumulator_widths_match_paper() {
        let c24 = QuantConfig::new(2, 4);
        let c21 = QuantConfig::new(2, 1);
        assert_eq!(c24.accumulator_bits(), 32); // paper Table II: ACCUM 32
        assert_eq!(c21.accumulator_bits(), 16); // paper Table II: ACCUM 16
    }

    #[test]
    fn error_bound_nearest() {
        let shape = [4usize, 8, 3, 3];
        let x = sample(shape.iter().product(), 1);
        let mut cfg = QuantConfig::default();
        cfg.rounding = Rounding::Nearest;
        let t = quantize(&x, &shape, &cfg, &[]);
        let q = t.dequantize();
        // |q - x| <= S_t * S_g * (half max ulp) per group
        for (idx, (&xi, &qi)) in x.iter().zip(&q).enumerate() {
            let g = cfg.grouping.group_of(&shape, idx);
            let sg = format::group_scale_value(t.sg_exp[g], t.sg_man[g], cfg.group);
            let bound = t.s_t * sg * 0.5 * 0.5f32.powi(cfg.element.m as i32);
            assert!((qi - xi).abs() <= bound + 1e-7, "idx {idx}: {xi} -> {qi}");
        }
    }

    #[test]
    fn zero_tensor() {
        let shape = [2usize, 3, 2, 2];
        let x = vec![0.0f32; 24];
        let q = fake_quant(&x, &shape, &QuantConfig::default(), &vec![0.1; 24]);
        assert!(q.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn disabled_is_identity() {
        let x = sample(24, 2);
        let q = fake_quant(&x, &[2, 3, 2, 2], &QuantConfig::fp32(), &[]);
        assert_eq!(x, q);
    }

    #[test]
    fn sign_symmetry() {
        let shape = [3usize, 4, 2, 2];
        let x = sample(48, 3);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        let mut cfg = QuantConfig::default();
        cfg.rounding = Rounding::Nearest;
        let q1 = fake_quant(&x, &shape, &cfg, &[]);
        let q2 = fake_quant(&neg, &shape, &cfg, &[]);
        for (a, b) in q1.iter().zip(&q2) {
            assert_eq!(*a, -*b);
        }
    }
}
