//! Dynamic quantization to the MLS format (paper Alg. 2) — bit-accurate.
//!
//! The pipeline mirrors ref.mls_quantize_fields operation-for-operation so
//! its output matches the Python/XLA float simulation bit-exactly:
//!
//!   S_s = sign(X);  S_r = GroupMax|X|;  S_t = max(S_r)
//!   S_g = ceil-quantized <E_g, M_g>(S_r / S_t)
//!   X_f = |X| / (S_g * S_t)          (f32 mul then f32 div, same order)
//!   Xbar = <E_x, M_x>(X_f) with stochastic rounding + gradual underflow
//!
//! The group |max| reduce and the contiguous element pass run through
//! the vectorized kernels in [`super::qsimd`] (SSE4.1/AVX2, runtime
//! dispatch via [`crate::util::simd`]), pinned bit-identical to the
//! scalar path — including the stochastic-rounding offset sequence,
//! which is drawn per element by the caller and merely consumed here.

use super::format::{self, EmFormat};
use super::grouping::Grouping;
use super::qsimd;
use super::tensor::MlsTensor;
use crate::util::json::Json;
use crate::util::parallel;

/// Rounding mode (Alg. 2 line 13).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Rounding {
    /// SRound(x, r) = floor(x + r + 1/2), r ~ U[-1/2, 1/2)
    Stochastic,
    /// floor(x + 1/2)
    Nearest,
}

impl Rounding {
    /// Every supported rounding mode. [`Self::parse`] and
    /// [`Self::parse_short`] scan this list, so parseable names cannot
    /// drift from `name()`/`short_name()` outputs (same registry
    /// discipline as [`Grouping::ALL`] and
    /// [`crate::coordinator::Backend::ALL`]).
    pub const ALL: [Rounding; 2] = [Rounding::Stochastic, Rounding::Nearest];

    pub fn parse(s: &str) -> anyhow::Result<Rounding> {
        Self::ALL.into_iter().find(|r| r.name() == s).ok_or_else(|| {
            anyhow::anyhow!("unknown rounding {s:?} (have {:?})", Self::ALL.map(|r| r.name()))
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Rounding::Stochastic => "stochastic",
            Rounding::Nearest => "nearest",
        }
    }

    /// Short token used inside [`QuantConfig`] names (`"sr"`/`"nr"`).
    pub fn short_name(&self) -> &'static str {
        match self {
            Rounding::Stochastic => "sr",
            Rounding::Nearest => "nr",
        }
    }

    /// Inverse of [`Self::short_name`], scanning [`Self::ALL`].
    pub fn parse_short(s: &str) -> anyhow::Result<Rounding> {
        Self::ALL.into_iter().find(|r| r.short_name() == s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown rounding token {s:?} (have {:?})",
                Self::ALL.map(|r| r.short_name())
            )
        })
    }
}

/// Full quantizer configuration; field-compatible with the Python
/// `QuantConfig` (and its JSON form in the artifact manifest).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    pub element: EmFormat,
    pub group: EmFormat,
    pub grouping: Grouping,
    pub rounding: Rounding,
    pub enabled: bool,
}

impl Default for QuantConfig {
    /// The paper's ImageNet headline config: `<2,4>` elements, `<8,1>`
    /// group scales, n x c grouping, stochastic rounding.
    fn default() -> Self {
        QuantConfig {
            element: EmFormat::new(2, 4),
            group: EmFormat::new(8, 1),
            grouping: Grouping::Both,
            rounding: Rounding::Stochastic,
            enabled: true,
        }
    }
}

impl QuantConfig {
    pub fn new(e_x: u32, m_x: u32) -> Self {
        QuantConfig { element: EmFormat::new(e_x, m_x), ..Default::default() }
    }

    pub fn fp32() -> Self {
        QuantConfig { enabled: false, ..Default::default() }
    }

    /// Parse the JSON object produced by Python `QuantConfig.to_dict()`.
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        Ok(QuantConfig {
            element: EmFormat::new(
                v.req("e_x")?.as_i64().unwrap_or(2) as u32,
                v.req("m_x")?.as_i64().unwrap_or(4) as u32,
            ),
            group: EmFormat::new(
                v.req("e_g")?.as_i64().unwrap_or(8) as u32,
                v.req("m_g")?.as_i64().unwrap_or(1) as u32,
            ),
            grouping: Grouping::parse(v.req("grouping")?.as_str().unwrap_or("both"))?,
            rounding: Rounding::parse(v.req("rounding")?.as_str().unwrap_or("stochastic"))?,
            enabled: v.req("enabled")?.as_bool().unwrap_or(true),
        })
    }

    /// Stable short name matching Python `QuantConfig.name()`. The
    /// grouping/rounding tokens come from the same
    /// [`Grouping::short_name`] / [`Rounding::short_name`] registries
    /// that [`Self::parse_name`] scans, so `parse_name(name())` is a
    /// round trip by construction for every supported config.
    pub fn name(&self) -> String {
        if !self.enabled {
            return "fp32".to_string();
        }
        format!(
            "e{}m{}_{}_eg{}mg{}_{}",
            self.element.e,
            self.element.m,
            self.grouping.short_name(),
            self.group.e,
            self.group.m,
            self.rounding.short_name()
        )
    }

    /// Parse a [`Self::name`]-formatted config string (the inverse of
    /// `name()`, e.g. `"e2m4_gnc_eg8mg1_sr"` or `"fp32"`). This is how
    /// the native training backend maps a `cfg_name` from
    /// [`crate::coordinator::TrainConfig`] onto a quantizer config with
    /// no artifact manifest involved.
    pub fn parse_name(s: &str) -> anyhow::Result<QuantConfig> {
        if s == "fp32" {
            return Ok(QuantConfig::fp32());
        }
        // element fields read "e{E}m{M}", group fields "eg{E}mg{M}"
        let parse_em = |part: &str, prefix: &str, sep: &str| -> anyhow::Result<EmFormat> {
            let rest = part
                .strip_prefix(prefix)
                .ok_or_else(|| anyhow::anyhow!("config {s:?}: {part:?} must start with {prefix:?}"))?;
            let (e, m) = rest
                .split_once(sep)
                .ok_or_else(|| anyhow::anyhow!("config {s:?}: {part:?} has no mantissa field"))?;
            Ok(EmFormat::new(
                e.parse().map_err(|_| anyhow::anyhow!("config {s:?}: bad E in {part:?}"))?,
                m.parse().map_err(|_| anyhow::anyhow!("config {s:?}: bad M in {part:?}"))?,
            ))
        };
        let parts: Vec<&str> = s.split('_').collect();
        anyhow::ensure!(
            parts.len() == 4,
            "config {s:?}: expected eEmM_<grouping>_egEmgM_<rounding> or \"fp32\""
        );
        let grouping = Grouping::parse_short(parts[1]).map_err(|e| e.context(format!("config {s:?}")))?;
        let rounding = Rounding::parse_short(parts[3]).map_err(|e| e.context(format!("config {s:?}")))?;
        Ok(QuantConfig {
            element: parse_em(parts[0], "e", "m")?,
            group: parse_em(parts[2], "eg", "mg")?,
            grouping,
            rounding,
            enabled: true,
        })
    }

    /// Stored bits per element (sign + exponent code + mantissa).
    pub fn element_bits(&self) -> u32 {
        1 + self.element.bits()
    }

    /// Smallest power-of-two integer accumulator for intra-group sums
    /// (Sec. V-C: product bits + 4 bits of K*K=9 accumulation headroom;
    /// matches the paper's Table II column: 8 for <1,1>, 16 for <2,1>,
    /// 32 for <2,4>).
    pub fn accumulator_bits(&self) -> u32 {
        let need = self.element.product_bits() + 4;
        for w in [8u32, 16, 32, 64] {
            if need <= w {
                return w;
            }
        }
        64
    }
}

/// Below this element count the ambient-thread entry points
/// ([`quantize`], [`crate::mls::MlsTensor::dequantize`]) stay serial:
/// even with the persistent pool a dispatch costs queue/wake/join
/// synchronization that a tiny tensor cannot amortize. Sharding is
/// bit-identical at every thread count, so the threshold is a pure
/// scheduling choice (pinned by `rust/tests/parallel_equivalence.rs`);
/// the explicit `*_threaded` entry points are not second-guessed.
pub const SERIAL_FALLBACK_ELEMS: usize = 16 * 1024;

/// Quantize a tensor to the full MLS decomposition.
///
/// `rounding_offsets` must have one U[-1/2, 1/2) value per element when the
/// config says stochastic (pass `&[]` for nearest — it is ignored).
///
/// The group-maxima and element passes are sharded over scaling groups on
/// the [`crate::util::parallel`] pool (`MLS_THREADS` workers, serial below
/// [`SERIAL_FALLBACK_ELEMS`] elements); see [`quantize_threaded`] for the
/// bit-identity guarantee.
pub fn quantize(x: &[f32], shape: &[usize], cfg: &QuantConfig, rounding_offsets: &[f32]) -> MlsTensor {
    let n: usize = shape.iter().product::<usize>().max(1);
    let threads = if n < SERIAL_FALLBACK_ELEMS { 1 } else { parallel::num_threads() };
    quantize_threaded(x, shape, cfg, rounding_offsets, threads)
}

/// [`quantize`] with an explicit worker count.
///
/// Groups (and, for the strided `Second` grouping, elements) are
/// independent given the tensor scale, and that scale is reduced in the
/// same group order regardless of sharding, so the output is bit-identical
/// for every `threads` value (pinned by
/// `rust/tests/parallel_equivalence.rs`).
pub fn quantize_threaded(
    x: &[f32],
    shape: &[usize],
    cfg: &QuantConfig,
    rounding_offsets: &[f32],
    threads: usize,
) -> MlsTensor {
    let n: usize = shape.iter().product::<usize>().max(1);
    assert_eq!(x.len(), n, "shape/element mismatch");
    let stochastic = cfg.rounding == Rounding::Stochastic;
    if stochastic {
        assert_eq!(rounding_offsets.len(), n, "need one rounding offset per element");
    }

    let n_groups = cfg.grouping.group_count(shape);
    // SIMD dispatch level read once per call: every shard of this call
    // runs the same kernels (all levels are bit-identical anyway)
    let level = crate::util::simd::active();

    // Per-element group ids cost a division each; all groupings except
    // Second are CONTIGUOUS runs of group_len elements in row-major
    // order, so the hot loops below walk chunk-wise (perf pass log in
    // EXPERIMENTS.md section Perf: ~2.3x on the <2,4> nc path).
    let group_len = cfg.grouping.group_len(shape);
    let contiguous = !matches!(cfg.grouping, Grouping::Second);

    // group maxima S_r and tensor max S_t (Alg. 2 lines 1-3)
    let s_r: Vec<f32> = if contiguous {
        // one max per contiguous group chunk, sharded over group ranges
        parallel::map_ranges(threads, n_groups, |lo, hi| {
            let mut part = Vec::with_capacity(hi - lo);
            for g in lo..hi {
                part.push(qsimd::abs_max(level, &x[g * group_len..(g + 1) * group_len]));
            }
            part
        })
        .concat()
    } else {
        // strided (Second) groups still form contiguous runs of
        // `inner = d2*d3` elements, each owned by one group, so the
        // vector |max| reduce applies run-wise; folding run maxima into
        // s_r in element order reproduces the per-element fold exactly
        // (max over non-negative floats is order-independent and both
        // paths ignore NaN)
        let inner: usize = shape.iter().skip(2).product::<usize>().max(1);
        let mut s_r = vec![0.0f32; n_groups];
        let mut idx = 0usize;
        while idx < n {
            let end = (idx + inner).min(n);
            let g = cfg.grouping.group_of(shape, idx);
            let a = qsimd::abs_max(level, &x[idx..end]);
            if a > s_r[g] {
                s_r[g] = a;
            }
            idx = end;
        }
        s_r
    };
    let s_t = s_r.iter().cloned().fold(0.0f32, f32::max);
    let s_t_safe = if s_t > 0.0 { s_t } else { 1.0 };

    // group scales (lines 4-8) — O(n_groups), kept serial
    let mut sg_exp = vec![0u8; n_groups];
    let mut sg_man = vec![0u32; n_groups];
    let mut sg_val = vec![0.0f32; n_groups];
    for g in 0..n_groups {
        let sgf = s_r[g] / s_t_safe;
        let (c, m) = format::quantize_group_scale(sgf, cfg.group);
        sg_exp[g] = c;
        sg_man[g] = m;
        sg_val[g] = format::group_scale_value(c, m, cfg.group);
    }

    // elements (lines 9-16) — per element, independent given its group
    // scale. Every grouping walks single-scale runs through the
    // (possibly vectorized) qsimd::quantize_run: contiguous groupings
    // chunk whole groups, the strided Second grouping chunks the
    // contiguous inner blocks each group owns.
    let fmt = cfg.element;
    let run_offsets = |lo: usize, hi: usize| -> Option<&[f32]> {
        stochastic.then(|| &rounding_offsets[lo..hi])
    };
    let parts: Vec<(Vec<i8>, Vec<u8>, Vec<u32>)> = if contiguous && n_groups >= threads {
        // shard over group ranges so each worker walks whole chunks
        parallel::map_ranges(threads, n_groups, |lo, hi| {
            let len = (hi - lo) * group_len;
            let mut sv = Vec::with_capacity(len);
            let mut cv = Vec::with_capacity(len);
            let mut mv = Vec::with_capacity(len);
            for g in lo..hi {
                let (base, end) = (g * group_len, (g + 1) * group_len);
                qsimd::quantize_run(
                    level,
                    &x[base..end],
                    run_offsets(base, end),
                    sg_val[g],
                    s_t_safe,
                    fmt,
                    &mut sv,
                    &mut cv,
                    &mut mv,
                );
            }
            (sv, cv, mv)
        })
    } else if contiguous {
        // fewer groups than workers (e.g. Grouping::None has exactly one):
        // shard over flat element ranges, split at group boundaries; the
        // group of element idx is idx / group_len for every contiguous
        // grouping
        parallel::map_ranges(threads, n, |lo, hi| {
            let mut sv = Vec::with_capacity(hi - lo);
            let mut cv = Vec::with_capacity(hi - lo);
            let mut mv = Vec::with_capacity(hi - lo);
            let mut idx = lo;
            while idx < hi {
                let g = idx / group_len;
                let end = ((g + 1) * group_len).min(hi);
                qsimd::quantize_run(
                    level,
                    &x[idx..end],
                    run_offsets(idx, end),
                    sg_val[g],
                    s_t_safe,
                    fmt,
                    &mut sv,
                    &mut cv,
                    &mut mv,
                );
                idx = end;
            }
            (sv, cv, mv)
        })
    } else {
        // strided (Second) groups: shard over flat element ranges, split
        // at the inner-block run boundaries so each run shares one group
        // scale and flows through the vector quantize kernel
        let inner: usize = shape.iter().skip(2).product::<usize>().max(1);
        parallel::map_ranges(threads, n, |lo, hi| {
            let mut sv = Vec::with_capacity(hi - lo);
            let mut cv = Vec::with_capacity(hi - lo);
            let mut mv = Vec::with_capacity(hi - lo);
            let mut idx = lo;
            while idx < hi {
                let end = ((idx / inner + 1) * inner).min(hi);
                let g = cfg.grouping.group_of(shape, idx);
                qsimd::quantize_run(
                    level,
                    &x[idx..end],
                    run_offsets(idx, end),
                    sg_val[g],
                    s_t_safe,
                    fmt,
                    &mut sv,
                    &mut cv,
                    &mut mv,
                );
                idx = end;
            }
            (sv, cv, mv)
        })
    };
    let mut sign = Vec::with_capacity(n);
    let mut exp_code = Vec::with_capacity(n);
    let mut man = Vec::with_capacity(n);
    for (sv, cv, mv) in parts {
        sign.extend(sv);
        exp_code.extend(cv);
        man.extend(mv);
    }

    MlsTensor {
        shape: shape.to_vec(),
        cfg: *cfg,
        s_t: if s_t > 0.0 { s_t } else { 0.0 },
        sign,
        exp_code,
        man,
        sg_exp,
        sg_man,
    }
}

/// Fake-quantize: quantize + dequantize in one pass (the value the training
/// simulation sees). Bit-exact vs ref.mls_fake_quant.
pub fn fake_quant(x: &[f32], shape: &[usize], cfg: &QuantConfig, rounding_offsets: &[f32]) -> Vec<f32> {
    if !cfg.enabled {
        return x.to_vec();
    }
    let t = quantize(x, shape, cfg, rounding_offsets);
    t.dequantize()
}

/// Caller-owned output + scratch of the fused [`quantize_into_planes`]
/// pass: a quantized tensor's conv-ready decoded element planes and
/// stored group scales, produced WITHOUT the intermediate [`MlsTensor`]
/// field arrays ever materializing. Every buffer is grow-only and reused
/// across calls, so a warm trainer step pays no allocation here.
pub struct FusedQuant {
    /// decoded element planes (what the conv engine consumes)
    pub planes: crate::arith::planes::DecodedPlanes,
    /// per-group scale exponent codes (the group-scale epilogue inputs)
    pub sg_exp: Vec<u8>,
    /// per-group scale mantissas
    pub sg_man: Vec<u32>,
    /// tensor-wise scale (0 for the all-zero tensor, like [`MlsTensor::s_t`])
    pub s_t: f32,
    // group-pass and run-length field scratch, reused across calls
    sg_val: Vec<f32>,
    s_r: Vec<f32>,
    sv: Vec<i8>,
    cv: Vec<u8>,
    mv: Vec<u32>,
}

impl Default for FusedQuant {
    fn default() -> Self {
        FusedQuant {
            planes: crate::arith::planes::DecodedPlanes {
                signed_frac: Vec::new(),
                shift: Vec::new(),
                scaled_frac: Vec::new(),
                fmt: EmFormat::new(0, 0),
            },
            sg_exp: Vec::new(),
            sg_man: Vec::new(),
            sg_val: Vec::new(),
            s_r: Vec::new(),
            sv: Vec::new(),
            cv: Vec::new(),
            mv: Vec::new(),
            s_t: 0.0,
        }
    }
}

impl FusedQuant {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Fused quantize-into-planes: quantize `x` exactly like
/// [`quantize_threaded`] would (same kernels, same group order, same
/// rounding-offset consumption) but decode every element straight into
/// [`crate::arith::planes::DecodedPlanes`] form, so the `MlsTensor`
/// sign/exponent-code/mantissa arrays never exist. The per-element
/// decode replicates [`crate::arith::planes::DecodedPlanes::of_threaded`]
/// operation-for-operation, so
/// `(out.planes, out.sg_exp, out.sg_man, out.s_t)` is bit-identical to
/// `(t.decoded_planes(), t.sg_exp, t.sg_man, t.s_t)` for
/// `t = quantize(x, ..)` — pinned by `quantize_into_planes_matches_unfused`.
///
/// Requires a contiguous grouping (`None`/`First`/`Both` — the trainer
/// always quantizes `Both`). Serial by design: the conv the planes feed
/// dominates the step, and a serial pass keeps the warm-step loop free
/// of pool-dispatch allocations; the output is element-wise, so it is
/// identical to the threaded unfused path regardless.
pub fn quantize_into_planes(
    x: &[f32],
    shape: &[usize],
    cfg: &QuantConfig,
    rounding_offsets: &[f32],
    out: &mut FusedQuant,
) {
    let n: usize = shape.iter().product::<usize>().max(1);
    assert_eq!(x.len(), n, "shape/element mismatch");
    let stochastic = cfg.rounding == Rounding::Stochastic;
    if stochastic {
        assert_eq!(rounding_offsets.len(), n, "need one rounding offset per element");
    }
    assert!(
        !matches!(cfg.grouping, Grouping::Second),
        "fused quantize requires contiguous scaling groups"
    );
    let fmt = cfg.element;
    let emin = fmt.emin();
    // same hard width guard as DecodedPlanes::of_threaded: the combined
    // (M+1) + (2^E - 2) shifted-fraction width must fit i32
    let smax: u32 = if fmt.e == 0 { 0 } else { (1u32 << fmt.e) - 2 };
    assert!(
        fmt.m + 1 + smax <= 31,
        "element format <{},{}> too wide for the conv planes: (M+1) + (2^E - 2) = {} must be <= 31 bits",
        fmt.e,
        fmt.m,
        fmt.m + 1 + smax
    );
    let n_groups = cfg.grouping.group_count(shape);
    let group_len = cfg.grouping.group_len(shape);
    let level = crate::util::simd::active();

    // group maxima S_r and tensor max S_t — same kernel, same group order
    // as the unfused path
    out.s_r.clear();
    for g in 0..n_groups {
        out.s_r.push(qsimd::abs_max(level, &x[g * group_len..(g + 1) * group_len]));
    }
    let s_t = out.s_r.iter().cloned().fold(0.0f32, f32::max);
    let s_t_safe = if s_t > 0.0 { s_t } else { 1.0 };
    out.s_t = if s_t > 0.0 { s_t } else { 0.0 };

    // group scales
    out.sg_exp.clear();
    out.sg_man.clear();
    out.sg_val.clear();
    for g in 0..n_groups {
        let sgf = out.s_r[g] / s_t_safe;
        let (c, m) = format::quantize_group_scale(sgf, cfg.group);
        out.sg_exp.push(c);
        out.sg_man.push(m);
        out.sg_val.push(format::group_scale_value(c, m, cfg.group));
    }

    // elements: quantize each group run into the run-length field
    // scratch, then decode straight into the planes
    out.planes.fmt = fmt;
    out.planes.signed_frac.clear();
    out.planes.shift.clear();
    out.planes.scaled_frac.clear();
    out.planes.signed_frac.reserve(n);
    out.planes.shift.reserve(n);
    out.planes.scaled_frac.reserve(n);
    for g in 0..n_groups {
        let (base, end) = (g * group_len, (g + 1) * group_len);
        out.sv.clear();
        out.cv.clear();
        out.mv.clear();
        qsimd::quantize_run(
            level,
            &x[base..end],
            stochastic.then(|| &rounding_offsets[base..end]),
            out.sg_val[g],
            s_t_safe,
            fmt,
            &mut out.sv,
            &mut out.cv,
            &mut out.mv,
        );
        for k in 0..group_len {
            let (s, c, m) = (out.sv[k], out.cv[k], out.mv[k]);
            // the exact Element::frac_int / exp_val decode of planes.rs
            let frac = (if c >= 1 { m + (1u32 << fmt.m) } else { m }) as i32;
            let f = s as i32 * frac;
            let sh = (if c >= 1 { -(c as i32) - emin } else { 0 }) as u32;
            debug_assert!(sh <= smax, "shift {sh} out of [0, {smax}]");
            out.planes.signed_frac.push(f);
            out.planes.shift.push(sh as u8);
            out.planes.scaled_frac.push(f << sh);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        rng.normal_vec(n, 1.0)
    }

    #[test]
    fn config_names_match_python() {
        assert_eq!(QuantConfig::default().name(), "e2m4_gnc_eg8mg1_sr");
        assert_eq!(QuantConfig::fp32().name(), "fp32");
        let mut c = QuantConfig::new(0, 2);
        c.grouping = Grouping::First;
        assert_eq!(c.name(), "e0m2_gf_eg8mg1_sr");
    }

    #[test]
    fn parse_name_round_trips() {
        let mut configs = vec![QuantConfig::default(), QuantConfig::fp32(), QuantConfig::new(2, 1)];
        let mut c = QuantConfig::new(0, 2);
        c.grouping = Grouping::First;
        c.rounding = Rounding::Nearest;
        configs.push(c);
        let mut c = QuantConfig::new(1, 1);
        c.grouping = Grouping::Second;
        configs.push(c);
        let mut c = QuantConfig::new(2, 4);
        c.grouping = Grouping::None;
        configs.push(c);
        for cfg in configs {
            let parsed = QuantConfig::parse_name(&cfg.name()).unwrap();
            assert_eq!(parsed, cfg, "round trip of {}", cfg.name());
        }
        assert!(QuantConfig::parse_name("nope").is_err());
        assert!(QuantConfig::parse_name("e2m4_gx_eg8mg1_sr").is_err());
        assert!(QuantConfig::parse_name("e2m4_gnc_eg8mg1_xx").is_err());
    }

    #[test]
    fn every_supported_name_round_trips_through_the_registry() {
        // property test over the full generator grid: parse_name is the
        // exact inverse of name() for every grouping x rounding (from
        // their ALL registries) x a spread of element/group formats, so
        // validate_native_config error listings can never name a config
        // that does not parse (or vice versa)
        let mut count = 0usize;
        for grouping in Grouping::ALL {
            for rounding in Rounding::ALL {
                for e_x in 0..=3u32 {
                    for m_x in 0..=4u32 {
                        for (e_g, m_g) in [(8u32, 1u32), (8, 0), (4, 2)] {
                            let cfg = QuantConfig {
                                element: EmFormat::new(e_x, m_x),
                                group: EmFormat::new(e_g, m_g),
                                grouping,
                                rounding,
                                enabled: true,
                            };
                            let name = cfg.name();
                            let parsed = QuantConfig::parse_name(&name)
                                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
                            assert_eq!(parsed, cfg, "{name}");
                            assert_eq!(parsed.name(), name, "{name}: second trip");
                            count += 1;
                        }
                    }
                }
            }
        }
        assert_eq!(count, 4 * 2 * 4 * 5 * 3, "grid fully enumerated");
        let fp = QuantConfig::parse_name("fp32").unwrap();
        assert_eq!(fp, QuantConfig::fp32());
        assert_eq!(fp.name(), "fp32");
        // unknown tokens list every valid short name
        let err = format!("{:#}", QuantConfig::parse_name("e2m4_gx_eg8mg1_sr").unwrap_err());
        for g in Grouping::ALL {
            assert!(err.contains(g.short_name()), "{err}");
        }
        let err = format!("{:#}", QuantConfig::parse_name("e2m4_gnc_eg8mg1_xx").unwrap_err());
        for r in Rounding::ALL {
            assert!(err.contains(r.short_name()), "{err}");
        }
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"e_x": 2, "m_x": 1, "e_g": 8, "m_g": 0, "grouping": "second",
                "rounding": "nearest", "enabled": true}"#,
        )
        .unwrap();
        let c = QuantConfig::from_json(&j).unwrap();
        assert_eq!(c.element, EmFormat::new(2, 1));
        assert_eq!(c.group, EmFormat::new(8, 0));
        assert_eq!(c.grouping, Grouping::Second);
        assert_eq!(c.rounding, Rounding::Nearest);
    }

    #[test]
    fn accumulator_widths_match_paper() {
        let c24 = QuantConfig::new(2, 4);
        let c21 = QuantConfig::new(2, 1);
        assert_eq!(c24.accumulator_bits(), 32); // paper Table II: ACCUM 32
        assert_eq!(c21.accumulator_bits(), 16); // paper Table II: ACCUM 16
    }

    #[test]
    fn error_bound_nearest() {
        let shape = [4usize, 8, 3, 3];
        let x = sample(shape.iter().product(), 1);
        let mut cfg = QuantConfig::default();
        cfg.rounding = Rounding::Nearest;
        let t = quantize(&x, &shape, &cfg, &[]);
        let q = t.dequantize();
        // |q - x| <= S_t * S_g * (half max ulp) per group
        for (idx, (&xi, &qi)) in x.iter().zip(&q).enumerate() {
            let g = cfg.grouping.group_of(&shape, idx);
            let sg = format::group_scale_value(t.sg_exp[g], t.sg_man[g], cfg.group);
            let bound = t.s_t * sg * 0.5 * 0.5f32.powi(cfg.element.m as i32);
            assert!((qi - xi).abs() <= bound + 1e-7, "idx {idx}: {xi} -> {qi}");
        }
    }

    /// The run-wise (vectorized) `Grouping::Second` path equals the
    /// historical per-element scalar loop — maxima fold, stored group
    /// scales, and every element field — for both rounding modes and
    /// every thread count, at whatever dispatch level is active (CI runs
    /// the suite under both `MLS_SIMD=auto` and `MLS_SIMD=off`).
    #[test]
    fn second_grouping_matches_per_element_reference() {
        let shape = [3usize, 5, 4, 3];
        let n: usize = shape.iter().product();
        let mut rng = Pcg32::seeded(0x5EC);
        let x = rng.normal_vec(n, 1.0);
        let offsets = rng.rounding_offsets(n);
        for rounding in Rounding::ALL {
            let mut cfg = QuantConfig::new(2, 4);
            cfg.grouping = Grouping::Second;
            cfg.rounding = rounding;
            let off: &[f32] = if rounding == Rounding::Stochastic { &offsets } else { &[] };
            // scalar reference: the historical per-element fold + element loop
            let n_groups = cfg.grouping.group_count(&shape);
            let mut s_r = vec![0.0f32; n_groups];
            for (idx, &v) in x.iter().enumerate() {
                let g = cfg.grouping.group_of(&shape, idx);
                let a = v.abs();
                if a > s_r[g] {
                    s_r[g] = a;
                }
            }
            let s_t = s_r.iter().cloned().fold(0.0f32, f32::max);
            let s_t_safe = if s_t > 0.0 { s_t } else { 1.0 };
            let mut sg_exp = vec![0u8; n_groups];
            let mut sg_man = vec![0u32; n_groups];
            let mut sg_val = vec![0.0f32; n_groups];
            for g in 0..n_groups {
                let (c, m) = format::quantize_group_scale(s_r[g] / s_t_safe, cfg.group);
                sg_exp[g] = c;
                sg_man[g] = m;
                sg_val[g] = format::group_scale_value(c, m, cfg.group);
            }
            for threads in [1usize, 2, 8] {
                let t = quantize_threaded(&x, &shape, &cfg, off, threads);
                let tag = format!("{} t{threads}", rounding.name());
                assert_eq!(t.s_t.to_bits(), s_t.to_bits(), "{tag}: s_t");
                assert_eq!(t.sg_exp, sg_exp, "{tag}: sg_exp");
                assert_eq!(t.sg_man, sg_man, "{tag}: sg_man");
                for idx in 0..n {
                    let g = cfg.grouping.group_of(&shape, idx);
                    let r = if rounding == Rounding::Stochastic { offsets[idx] } else { 0.0 };
                    let (s, c, m) =
                        qsimd::quantize_one_scalar(x[idx], sg_val[g], s_t_safe, cfg.element, r);
                    assert_eq!(
                        (t.sign[idx], t.exp_code[idx], t.man[idx]),
                        (s, c, m),
                        "{tag}: idx {idx}"
                    );
                }
            }
        }
    }

    /// The fused quantize-into-planes pass is bit-identical to quantize
    /// followed by a separate plane decode — planes, group scales, and
    /// tensor scale — for every contiguous grouping, format, and
    /// rounding mode, with the output buffers reused across every
    /// combination.
    #[test]
    fn quantize_into_planes_matches_unfused() {
        let shape = [4usize, 3, 3, 3];
        let n: usize = shape.iter().product();
        let mut rng = Pcg32::seeded(0xF0D);
        let x = rng.normal_vec(n, 1.0);
        let offsets = rng.rounding_offsets(n);
        let mut fused = FusedQuant::new();
        for grouping in [Grouping::Both, Grouping::First, Grouping::None] {
            for (e, m) in [(2u32, 4u32), (2, 1), (0, 2)] {
                for rounding in Rounding::ALL {
                    let cfg = QuantConfig {
                        element: EmFormat::new(e, m),
                        grouping,
                        rounding,
                        ..QuantConfig::default()
                    };
                    let off: &[f32] =
                        if rounding == Rounding::Stochastic { &offsets } else { &[] };
                    let t = quantize(&x, &shape, &cfg, off);
                    let planes = t.decoded_planes();
                    quantize_into_planes(&x, &shape, &cfg, off, &mut fused);
                    let tag = format!("{} e{e}m{m} {}", grouping.name(), rounding.name());
                    assert_eq!(fused.s_t.to_bits(), t.s_t.to_bits(), "{tag}: s_t");
                    assert_eq!(fused.sg_exp, t.sg_exp, "{tag}: sg_exp");
                    assert_eq!(fused.sg_man, t.sg_man, "{tag}: sg_man");
                    assert_eq!(fused.planes.fmt, cfg.element, "{tag}: fmt");
                    assert_eq!(fused.planes.signed_frac, planes.signed_frac, "{tag}: frac");
                    assert_eq!(fused.planes.shift, planes.shift, "{tag}: shift");
                    assert_eq!(fused.planes.scaled_frac, planes.scaled_frac, "{tag}: scaled");
                }
            }
        }
        // the all-zero tensor pins s_t = 0 exactly like the unfused path
        let z = vec![0.0f32; n];
        quantize_into_planes(&z, &shape, &QuantConfig::default(), &offsets, &mut fused);
        assert_eq!(fused.s_t, 0.0);
        assert!(fused.planes.signed_frac.iter().all(|&f| f == 0));
    }

    #[test]
    fn zero_tensor() {
        let shape = [2usize, 3, 2, 2];
        let x = vec![0.0f32; 24];
        let q = fake_quant(&x, &shape, &QuantConfig::default(), &vec![0.1; 24]);
        assert!(q.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn disabled_is_identity() {
        let x = sample(24, 2);
        let q = fake_quant(&x, &[2, 3, 2, 2], &QuantConfig::fp32(), &[]);
        assert_eq!(x, q);
    }

    #[test]
    fn sign_symmetry() {
        let shape = [3usize, 4, 2, 2];
        let x = sample(48, 3);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        let mut cfg = QuantConfig::default();
        cfg.rounding = Rounding::Nearest;
        let q1 = fake_quant(&x, &shape, &cfg, &[]);
        let q2 = fake_quant(&neg, &shape, &cfg, &[]);
        for (a, b) in q1.iter().zip(&q2) {
            assert_eq!(*a, -*b);
        }
    }
}
