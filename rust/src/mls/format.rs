//! The `<E, M>` customized floating-point format (paper Eq. 3 + Sec. V-C).
//!
//! Storage convention (identical to ref.py — see its module docstring):
//!
//! * exponent **code** `c in [0, 2^E - 1]`
//!   * `c >= 1` (normal):     `value = (1 + man/2^M) * 2^(-c)`
//!   * `c == 0` (subnormal):  `value = (man/2^M) * 2^(emin)`,
//!     `emin = 1 - 2^E` (gradual underflow at the minimum normal level)
//! * mantissa `man in [0, 2^M - 1]`; rounding saturates within the level
//!   (Alg. 2 line 13) — no carry, mirroring the hardware clip datapath.
//! * `NearestRound(x) = floor(x + 0.5)`; stochastic rounding adds
//!   `r ~ U[-1/2, 1/2)` before the same floor.
//!
//! All arithmetic is plain IEEE f32, with every multiplication by a power
//! of two exact, so the sequence of operations reproduces the XLA/jnp
//! lowering bit-for-bit.

/// An `<E, M>` element or group-scale format descriptor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct EmFormat {
    /// exponent bits (0..=8)
    pub e: u32,
    /// mantissa bits (0..=23)
    pub m: u32,
}

impl EmFormat {
    pub const fn new(e: u32, m: u32) -> Self {
        EmFormat { e, m }
    }

    /// Minimum normal exponent: `1 - 2^E`.
    pub fn emin(&self) -> i32 {
        1 - (1i64 << self.e) as i32
    }

    /// Number of stored bits per value (excluding the separate sign plane).
    pub fn bits(&self) -> u32 {
        self.e + self.m
    }

    /// Largest representable value: `(2 - 2^-M) * 2^-1`.
    pub fn max_value(&self) -> f32 {
        (2.0 - 0.5f32.powi(self.m as i32)) * 0.5
    }

    /// Bit-width of an element x element product (Sec. V-C):
    /// `2M + 2^{E+1} - 2`.
    pub fn product_bits(&self) -> u32 {
        2 * self.m + (1u32 << (self.e + 1)) - 2
    }

    /// Decode stored fields to the represented value.
    pub fn decode(&self, exp_code: u8, man: u32) -> f32 {
        let two_m = (1u32 << self.m) as f32;
        if exp_code >= 1 {
            (1.0 + man as f32 / two_m) * exp2i(-(exp_code as i32))
        } else {
            man as f32 / two_m * exp2i(self.emin())
        }
    }
}

/// Exact `2^k` for the exponent ranges we use. f32 underflows below -149;
/// the MLS pipeline clamps pins at -126 (see `quantize_group_scale`), so
/// the remaining uses stay in range.
#[inline]
pub fn exp2i(k: i32) -> f32 {
    if k > 127 {
        f32::INFINITY // matches np.float32(2.0**k) overflow behaviour
    } else if k >= -126 {
        f32::from_bits(((k + 127) as u32) << 23)
    } else if k >= -149 {
        // subnormal f32 powers of two
        f32::from_bits(1u32 << (k + 149))
    } else {
        0.0
    }
}

/// Unbiased exponent of |x| = f * 2^e, f in [1, 2) — straight from the
/// IEEE-754 bit pattern (zero/denormals map to -127, below any MLS emin).
#[inline]
pub fn f32_exponent(x: f32) -> i32 {
    ((x.to_bits() >> 23) & 0xFF) as i32 - 127
}

/// Fraction in [1, 2) of |x| (meaningless for zero/denormal inputs).
#[inline]
pub fn f32_fraction(x: f32) -> f32 {
    f32::from_bits((x.to_bits() & 0x007F_FFFF) | 0x3F80_0000)
}

/// Quantize one non-negative, group-normalized value `xf <= 1` to `<E, M>`;
/// returns the stored fields. `r` is the rounding offset (0 for nearest).
/// Mirrors ref.element_codes exactly.
#[inline]
pub fn quantize_element(xf: f32, fmt: EmFormat, r: f32) -> (u8, u32) {
    let emin = fmt.emin();
    let two_m = (1u64 << fmt.m) as f32;

    // E == 0 has no normal levels: pure fixed point (paper's "single
    // number" rows). Otherwise IEEE-style gradual underflow below 2^emin.
    if fmt.e == 0 || xf < exp2i(emin) {
        // gradual underflow: integer mantissa at level emin, implicit 0
        let man_s = (xf * exp2f_pow(fmt.m as i32 - emin) + r + 0.5).floor();
        let man = man_s.clamp(0.0, two_m - 1.0) as u32;
        (0, man)
    } else {
        let exp = f32_exponent(xf);
        let exp_cl = exp.clamp(emin, -1);
        let y = xf * exp2i(-exp_cl); // exact
        let man_n = ((y - 1.0) * two_m + r + 0.5).floor();
        let man = man_n.clamp(0.0, two_m - 1.0) as u32;
        ((-exp_cl) as u8, man)
    }
}

/// `2^k` for the subnormal rescale factor `2^(M - emin)`. For E >= 6 this
/// exceeds the f32 range and becomes +inf, which is exactly what
/// `np.float32(2.0 ** k)` yields on the Python side, so the (already
/// saturating) downstream clamp behaves identically.
#[inline]
fn exp2f_pow(k: i32) -> f32 {
    exp2i(k)
}

/// Dequantized value of quantize_element (ref.quantize_element).
#[inline]
pub fn quantize_element_value(xf: f32, fmt: EmFormat, r: f32) -> f32 {
    let (code, man) = quantize_element(xf, fmt, r);
    fmt.decode(code, man)
}

/// Quantize a group scale `sgf = S_r / S_t in [0, 1]` to `<E_g, M_g>` with
/// ceil rounding + carry (Alg. 2 lines 4-8). Returns (exp_code, man) where
/// the value is `(1 + man/2^Mg) * 2^(-exp_code)`; all-zero groups pin to
/// the clamped minimum (DESIGN.md: max(emin, -126) so f32 never flushes).
#[inline]
pub fn quantize_group_scale(sgf: f32, fmt: EmFormat) -> (u8, u32) {
    let egmin = fmt.emin();
    let egpin = egmin.max(-126);
    let two_mg = (1u32 << fmt.m) as f32;

    if sgf <= exp2i(egpin) {
        return ((-egpin) as u8, 0);
    }
    let exp = f32_exponent(sgf);
    let mut exp_cl = exp.clamp(egmin, 0);
    let y = sgf * exp2i(-exp_cl); // exact
    let mut man = ((y - 1.0) * two_mg).ceil();
    if man >= two_mg {
        man = 0.0;
        exp_cl = (exp_cl + 1).clamp(egmin, 0);
    }
    let man = man.clamp(0.0, two_mg - 1.0) as u32;
    ((-exp_cl) as u8, man)
}

/// Group-scale value from its stored fields.
#[inline]
pub fn group_scale_value(exp_code: u8, man: u32, fmt: EmFormat) -> f32 {
    let two_mg = (1u32 << fmt.m) as f32;
    (1.0 + man as f32 / two_mg) * exp2i(-(exp_code as i32))
}

#[cfg(test)]
mod tests {
    use super::*;

    const E2M4: EmFormat = EmFormat::new(2, 4);
    const E2M1: EmFormat = EmFormat::new(2, 1);

    #[test]
    fn exp2i_matches_powi() {
        for k in -126..=127 {
            assert_eq!(exp2i(k), 2.0f32.powi(k), "k={k}");
        }
        assert_eq!(exp2i(-149), f32::from_bits(1));
        assert_eq!(exp2i(-200), 0.0);
    }

    #[test]
    fn f32_fields() {
        assert_eq!(f32_exponent(1.0), 0);
        assert_eq!(f32_exponent(0.5), -1);
        assert_eq!(f32_exponent(3.0), 1);
        assert_eq!(f32_exponent(0.0), -127);
        assert_eq!(f32_fraction(3.0), 1.5);
        assert_eq!(f32_fraction(0.75), 1.5);
    }

    #[test]
    fn formats() {
        assert_eq!(E2M4.emin(), -3);
        assert_eq!(E2M4.product_bits(), 14); // the paper's "14" for <2,4>
        assert_eq!(E2M1.product_bits(), 8);
        assert_eq!(EmFormat::new(5, 2).product_bits(), 2 * 2 + 64 - 2);
        assert_eq!(E2M4.max_value(), (2.0 - 1.0 / 16.0) / 2.0);
    }

    #[test]
    fn exact_values_roundtrip() {
        for code in 1..=3u8 {
            for man in 0..16u32 {
                let v = E2M4.decode(code, man);
                let (c2, m2) = quantize_element(v, E2M4, 0.0);
                assert_eq!((c2, m2), (code, man), "v={v}");
            }
        }
    }

    #[test]
    fn subnormals_roundtrip() {
        for man in 0..16u32 {
            let v = E2M4.decode(0, man);
            let (c2, m2) = quantize_element(v, E2M4, 0.0);
            assert_eq!((c2, m2), (0, man), "v={v}");
        }
    }

    #[test]
    fn saturates_at_one() {
        let (code, man) = quantize_element(1.0, E2M4, 0.0);
        assert_eq!((code, man), (1, 15));
        assert_eq!(E2M4.decode(code, man), E2M4.max_value());
    }

    #[test]
    fn zero_is_zero() {
        let (code, man) = quantize_element(0.0, E2M4, 0.0);
        assert_eq!(E2M4.decode(code, man), 0.0);
    }

    #[test]
    fn group_scale_dominates() {
        let fmt = EmFormat::new(8, 1);
        for i in 0..1000 {
            let s = i as f32 / 1000.0;
            let (c, m) = quantize_group_scale(s, fmt);
            let v = group_scale_value(c, m, fmt);
            assert!(v >= s - 1e-7, "s={s} v={v}");
        }
    }

    #[test]
    fn group_scale_carry() {
        // 0.76 -> frac 1.52 @ exp -1 -> ceil(0.52*2)=2 -> carry -> 1.0 @ exp 0
        let (c, m) = quantize_group_scale(0.76, EmFormat::new(8, 1));
        assert_eq!((c, m), (0, 0));
    }

    #[test]
    fn group_scale_zero_pins() {
        let (c, m) = quantize_group_scale(0.0, EmFormat::new(8, 1));
        assert_eq!(c, 126);
        assert_eq!(m, 0);
        assert_eq!(group_scale_value(c, m, EmFormat::new(8, 1)), exp2i(-126));
    }

    #[test]
    fn group_scale_m0_power_of_two() {
        let fmt = EmFormat::new(8, 0);
        for s in [0.3f32, 0.5, 0.6, 0.9] {
            let (c, m) = quantize_group_scale(s, fmt);
            assert_eq!(m, 0);
            let v = group_scale_value(c, m, fmt);
            assert!(v >= s && v / 2.0 < s, "s={s} v={v}");
        }
    }
}
