//! Grouping dimensions for the group-wise scale (paper Sec. IV-B).
//!
//! A 4-D tensor `[d0, d1, d2, d3]` can be grouped by its first dimension
//! ("n" for activations/errors, "co" for weights), its second ("c"/"ci"),
//! both (the paper's best-performing `n x c`), or not at all. 2-D tensors
//! are treated as `[d0, d1, 1, 1]`.

/// Which leading dims form a group (mirrors qconfig.GROUPINGS).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Grouping {
    /// one group for the whole tensor (#group = 1)
    None,
    /// grouped by dim 0 (paper: "n" rows of Table IV)
    First,
    /// grouped by dim 1 (paper: "c")
    Second,
    /// grouped by dim 0 x dim 1 (paper: "nc")
    Both,
}

impl Grouping {
    /// Every supported grouping, in Table IV order. [`Self::parse`] and
    /// [`Self::parse_short`] scan this list, so the set of parseable
    /// names is BY CONSTRUCTION the set of `name()`/`short_name()`
    /// outputs — the listings in error messages cannot drift from what
    /// round-trips (pinned by the registry round-trip tests).
    pub const ALL: [Grouping; 4] =
        [Grouping::None, Grouping::First, Grouping::Second, Grouping::Both];

    pub fn parse(s: &str) -> anyhow::Result<Grouping> {
        Self::ALL.into_iter().find(|g| g.name() == s).ok_or_else(|| {
            anyhow::anyhow!("unknown grouping {s:?} (have {:?})", Self::ALL.map(|g| g.name()))
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Grouping::None => "none",
            Grouping::First => "first",
            Grouping::Second => "second",
            Grouping::Both => "both",
        }
    }

    /// Short token used inside [`crate::mls::QuantConfig`] names
    /// (`"g1"`/`"gf"`/`"gs"`/`"gnc"`, e.g. the `gnc` in
    /// `e2m4_gnc_eg8mg1_sr`).
    pub fn short_name(&self) -> &'static str {
        match self {
            Grouping::None => "g1",
            Grouping::First => "gf",
            Grouping::Second => "gs",
            Grouping::Both => "gnc",
        }
    }

    /// Inverse of [`Self::short_name`], scanning [`Self::ALL`].
    pub fn parse_short(s: &str) -> anyhow::Result<Grouping> {
        Self::ALL.into_iter().find(|g| g.short_name() == s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown grouping token {s:?} (have {:?})",
                Self::ALL.map(|g| g.short_name())
            )
        })
    }

    /// Number of groups for a shape.
    pub fn group_count(&self, shape: &[usize]) -> usize {
        let (d0, d1) = dims01(shape);
        match self {
            Grouping::None => 1,
            Grouping::First => d0,
            Grouping::Second => d1,
            Grouping::Both => d0 * d1,
        }
    }

    /// Group id of the element at flat index `idx` (row-major).
    #[inline]
    pub fn group_of(&self, shape: &[usize], idx: usize) -> usize {
        let (_d0, d1) = dims01(shape);
        let inner: usize = shape.iter().skip(2).product::<usize>().max(1);
        match self {
            Grouping::None => 0,
            Grouping::First => idx / (d1 * inner),
            Grouping::Second => (idx / inner) % d1,
            Grouping::Both => idx / inner,
        }
    }

    /// Per-group element count (groups are uniform).
    pub fn group_len(&self, shape: &[usize]) -> usize {
        let total: usize = shape.iter().product::<usize>().max(1);
        total / self.group_count(shape)
    }
}

fn dims01(shape: &[usize]) -> (usize, usize) {
    let d0 = shape.first().copied().unwrap_or(1);
    let d1 = shape.get(1).copied().unwrap_or(1);
    (d0, d1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts() {
        let s = [4usize, 6, 3, 3];
        assert_eq!(Grouping::None.group_count(&s), 1);
        assert_eq!(Grouping::First.group_count(&s), 4);
        assert_eq!(Grouping::Second.group_count(&s), 6);
        assert_eq!(Grouping::Both.group_count(&s), 24);
    }

    #[test]
    fn group_of_matches_layout() {
        let s = [2usize, 3, 2, 2];
        let total: usize = s.iter().product();
        for idx in 0..total {
            let i0 = idx / (3 * 4);
            let i1 = (idx / 4) % 3;
            assert_eq!(Grouping::First.group_of(&s, idx), i0);
            assert_eq!(Grouping::Second.group_of(&s, idx), i1);
            assert_eq!(Grouping::Both.group_of(&s, idx), i0 * 3 + i1);
            assert_eq!(Grouping::None.group_of(&s, idx), 0);
        }
    }

    #[test]
    fn group_len_times_count_is_total() {
        for g in [Grouping::None, Grouping::First, Grouping::Second, Grouping::Both] {
            let s = [4usize, 6, 5, 5];
            assert_eq!(g.group_len(&s) * g.group_count(&s), 600);
        }
    }

    #[test]
    fn two_d_shapes() {
        let s = [3usize, 8];
        assert_eq!(Grouping::Both.group_count(&s), 24);
        assert_eq!(Grouping::First.group_of(&s, 9), 1);
        assert_eq!(Grouping::Second.group_of(&s, 9), 1);
    }

    #[test]
    fn parse_roundtrip() {
        for name in ["none", "first", "second", "both"] {
            assert_eq!(Grouping::parse(name).unwrap().name(), name);
        }
        assert!(Grouping::parse("bogus").is_err());
    }

    #[test]
    fn registry_round_trips_every_name_form() {
        // both name forms round-trip for EVERY variant, and the error
        // listings contain every valid name — the property the config
        // redesign relies on (parse scans ALL, so drift is impossible;
        // this pins ALL being complete)
        assert_eq!(Grouping::ALL.len(), 4);
        for g in Grouping::ALL {
            assert_eq!(Grouping::parse(g.name()).unwrap(), g);
            assert_eq!(Grouping::parse_short(g.short_name()).unwrap(), g);
        }
        let long = format!("{:#}", Grouping::parse("zzz").unwrap_err());
        let short = format!("{:#}", Grouping::parse_short("zzz").unwrap_err());
        for g in Grouping::ALL {
            assert!(long.contains(g.name()), "{long}");
            assert!(short.contains(g.short_name()), "{short}");
        }
    }
}
