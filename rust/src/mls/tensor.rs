//! The quantized MLS tensor container: sign plane + element field planes +
//! group scales + tensor scale, with dequantization and storage accounting.

use super::format;
use super::quantizer::QuantConfig;
use crate::util::parallel;

/// A tensor in the MLS format (paper Fig. 5): `X = S_s * S_t * S_g * Xbar`.
#[derive(Clone, Debug)]
pub struct MlsTensor {
    pub shape: Vec<usize>,
    pub cfg: QuantConfig,
    /// tensor-wise scale (full-precision f32; 0 for the all-zero tensor)
    pub s_t: f32,
    /// per-element sign in {-1, 0, 1}
    pub sign: Vec<i8>,
    /// per-element exponent codes (0 = gradual underflow)
    pub exp_code: Vec<u8>,
    /// per-element mantissas in [0, 2^M - 1]
    pub man: Vec<u32>,
    /// per-group scale exponent codes (value = (1 + man/2^Mg) * 2^-code)
    pub sg_exp: Vec<u8>,
    /// per-group scale mantissas
    pub sg_man: Vec<u32>,
}

impl MlsTensor {
    pub fn len(&self) -> usize {
        self.sign.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sign.is_empty()
    }

    pub fn group_count(&self) -> usize {
        self.sg_exp.len()
    }

    /// Group scale value of group `g`.
    pub fn group_scale(&self, g: usize) -> f32 {
        format::group_scale_value(self.sg_exp[g], self.sg_man[g], self.cfg.group)
    }

    /// Element value (dequantized, including all scales).
    pub fn value(&self, idx: usize) -> f32 {
        let g = self.cfg.grouping.group_of(&self.shape, idx);
        let xbar = self.cfg.element.decode(self.exp_code[idx], self.man[idx]);
        if self.s_t == 0.0 {
            return 0.0;
        }
        // same op order as ref: ((sign * s_t) * s_g) * xbar
        ((self.sign[idx] as f32 * self.s_t) * self.group_scale(g)) * xbar
    }

    /// Dequantize the whole tensor (ref.mls_quantize_fields "q").
    ///
    /// Sharded over scaling groups on the [`crate::util::parallel`] pool;
    /// bit-identical for every worker count (elements are independent).
    /// Tensors below [`super::quantizer::SERIAL_FALLBACK_ELEMS`] elements
    /// run serial — pool dispatch overhead would dominate them.
    pub fn dequantize(&self) -> Vec<f32> {
        let threads = if self.len() < super::quantizer::SERIAL_FALLBACK_ELEMS {
            1
        } else {
            parallel::num_threads()
        };
        self.dequantize_threaded(threads)
    }

    /// [`Self::dequantize`] with an explicit worker count.
    ///
    /// Every grouping walks single-scale element runs through the
    /// vectorized [`super::qsimd::dequantize_run`] kernel (bit-identical
    /// to the scalar per-element decode at every dispatch level): the
    /// contiguous groupings chunk whole groups; the strided `Second`
    /// grouping still forms contiguous runs of `inner = d2*d3` elements
    /// per group, so it runs the same kernel run-wise.
    pub fn dequantize_threaded(&self, threads: usize) -> Vec<f32> {
        let n = self.len();
        let mut sg_cache: Vec<f32> = (0..self.group_count()).map(|g| self.group_scale(g)).collect();
        if self.s_t == 0.0 {
            return vec![0.0; n];
        }
        for s in sg_cache.iter_mut() {
            *s = self.s_t * *s; // hoist s_t * s_g per group
        }
        let fmt = self.cfg.element;
        // dispatch level read once per call: every shard runs the same
        // kernels (all levels are bit-identical anyway)
        let level = crate::util::simd::active();
        let contiguous = !matches!(self.cfg.grouping, super::Grouping::Second);
        let parts: Vec<Vec<f32>> = if contiguous && self.group_count() >= threads {
            // contiguous groups: chunk-wise walk avoids per-element divides
            let group_len = self.cfg.grouping.group_len(&self.shape);
            parallel::map_ranges(threads, self.group_count(), |lo, hi| {
                let mut out = Vec::with_capacity((hi - lo) * group_len);
                for g in lo..hi {
                    let base = g * group_len;
                    let end = base + group_len;
                    super::qsimd::dequantize_run(
                        level,
                        &self.sign[base..end],
                        &self.exp_code[base..end],
                        &self.man[base..end],
                        sg_cache[g],
                        fmt,
                        &mut out,
                    );
                }
                out
            })
        } else if contiguous {
            // fewer groups than workers (e.g. Grouping::None): shard over
            // flat element ranges, split at the group boundaries (the
            // group of idx is idx / group_len)
            let group_len = self.cfg.grouping.group_len(&self.shape);
            parallel::map_ranges(threads, n, |lo, hi| {
                let mut out = Vec::with_capacity(hi - lo);
                let mut idx = lo;
                while idx < hi {
                    let g = idx / group_len;
                    let end = ((g + 1) * group_len).min(hi);
                    super::qsimd::dequantize_run(
                        level,
                        &self.sign[idx..end],
                        &self.exp_code[idx..end],
                        &self.man[idx..end],
                        sg_cache[g],
                        fmt,
                        &mut out,
                    );
                    idx = end;
                }
                out
            })
        } else {
            // strided (Second) groups: shard over flat element ranges,
            // split at the inner-block boundaries so each run shares one
            // group scale
            let inner: usize = self.shape.iter().skip(2).product::<usize>().max(1);
            parallel::map_ranges(threads, n, |lo, hi| {
                let mut out = Vec::with_capacity(hi - lo);
                let mut idx = lo;
                while idx < hi {
                    let end = ((idx / inner + 1) * inner).min(hi);
                    let g = self.cfg.grouping.group_of(&self.shape, idx);
                    super::qsimd::dequantize_run(
                        level,
                        &self.sign[idx..end],
                        &self.exp_code[idx..end],
                        &self.man[idx..end],
                        sg_cache[g],
                        fmt,
                        &mut out,
                    );
                    idx = end;
                }
                out
            })
        };
        parts.concat()
    }

    /// Decode the element planes once into the struct-of-arrays form the
    /// planar conv kernel consumes (`signed_frac` / `shift`, see
    /// [`crate::arith::planes::DecodedPlanes`]). Callers convolving the
    /// same tensor repeatedly can pass the result to
    /// [`crate::arith::conv::lowbit_conv_with_planes`] to pay the decode
    /// once across calls.
    pub fn decoded_planes(&self) -> crate::arith::planes::DecodedPlanes {
        crate::arith::planes::DecodedPlanes::of(self)
    }

    /// Swap the two leading axes of a 4-D `(dim0, dim1)`-grouped tensor:
    /// `[d0, d1, d2, d3] -> [d1, d0, d2, d3]`. A **lossless relayout** —
    /// scaling groups are `(dim0, dim1)` pairs, so groups (with their
    /// stored scales) and their element blocks permute without any
    /// re-quantization; `t.transpose01().dequantize()` is the exact
    /// permutation of `t.dequantize()`. The pass-generic conv engine
    /// ([`crate::arith::spec`]) uses this to put Alg. 1 backward operands
    /// into the canonical `[V, G, ., .]` / `[U, G, ., .]` layouts.
    pub fn transpose01(&self) -> MlsTensor {
        self.permute01(false)
    }

    /// [`Self::transpose01`] plus a spatial flip of the two trailing axes
    /// (`new[i1, i0, i2, i3] = old[i0, i1, d2-1-i2, d3-1-i3]`) — the
    /// weight relayout of the transposed (input-gradient) convolution.
    /// The flip permutes elements *within* each scaling group, so it is
    /// lossless for the same reason.
    pub fn transpose01_flip23(&self) -> MlsTensor {
        self.permute01(true)
    }

    fn permute01(&self, flip: bool) -> MlsTensor {
        assert_eq!(self.shape.len(), 4, "transpose01 needs a 4-D tensor");
        assert_eq!(
            self.cfg.grouping,
            super::Grouping::Both,
            "transpose01 is only group-structure-preserving for (dim0, dim1) grouping"
        );
        let [d0, d1, d2, d3] = [self.shape[0], self.shape[1], self.shape[2], self.shape[3]];
        let inner = d2 * d3;
        let n = self.len();
        let mut sign = vec![0i8; n];
        let mut exp_code = vec![0u8; n];
        let mut man = vec![0u32; n];
        let mut sg_exp = vec![0u8; self.group_count()];
        let mut sg_man = vec![0u32; self.group_count()];
        for i0 in 0..d0 {
            for i1 in 0..d1 {
                let g_src = i0 * d1 + i1;
                let g_dst = i1 * d0 + i0;
                sg_exp[g_dst] = self.sg_exp[g_src];
                sg_man[g_dst] = self.sg_man[g_src];
                let src = g_src * inner;
                let dst = g_dst * inner;
                if !flip {
                    sign[dst..dst + inner].copy_from_slice(&self.sign[src..src + inner]);
                    exp_code[dst..dst + inner].copy_from_slice(&self.exp_code[src..src + inner]);
                    man[dst..dst + inner].copy_from_slice(&self.man[src..src + inner]);
                } else {
                    for i2 in 0..d2 {
                        for i3 in 0..d3 {
                            let s = src + (d2 - 1 - i2) * d3 + (d3 - 1 - i3);
                            let d = dst + i2 * d3 + i3;
                            sign[d] = self.sign[s];
                            exp_code[d] = self.exp_code[s];
                            man[d] = self.man[s];
                        }
                    }
                }
            }
        }
        MlsTensor {
            shape: vec![d1, d0, d2, d3],
            cfg: self.cfg,
            s_t: self.s_t,
            sign,
            exp_code,
            man,
            sg_exp,
            sg_man,
        }
    }

    /// Stored size in bits: elements (sign+E+M) + group scales (E_g+M_g) +
    /// one f32 tensor scale. The compression story vs f32 (Table VI memory
    /// argument).
    pub fn storage_bits(&self) -> u64 {
        let elem = self.len() as u64 * (1 + self.cfg.element.bits()) as u64;
        let groups = self.group_count() as u64 * self.cfg.group.bits() as u64;
        elem + groups + 32
    }

    /// Compression ratio vs f32 storage.
    pub fn compression_ratio(&self) -> f64 {
        (self.len() as u64 * 32) as f64 / self.storage_bits() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::super::quantizer::{quantize, QuantConfig, Rounding};
    use crate::util::rng::Pcg32;

    #[test]
    fn dequantize_matches_value() {
        let shape = [3usize, 4, 3, 3];
        let mut rng = Pcg32::seeded(5);
        let x = rng.normal_vec(shape.iter().product(), 1.0);
        let mut cfg = QuantConfig::default();
        cfg.rounding = Rounding::Nearest;
        let t = quantize(&x, &shape, &cfg, &[]);
        let q = t.dequantize();
        for idx in 0..t.len() {
            assert_eq!(q[idx], t.value(idx));
        }
    }

    /// The run-wise (vectorized) dequantize equals the per-element
    /// scalar expression bit for bit, for every grouping — including the
    /// strided `Second` — and every thread count.
    #[test]
    fn dequantize_is_bit_stable_for_every_grouping_and_thread_count() {
        use crate::mls::Grouping;
        let shape = [3usize, 5, 4, 3];
        let mut rng = Pcg32::seeded(0x0DE);
        let x = rng.normal_vec(shape.iter().product(), 1.0);
        for grouping in Grouping::ALL {
            let mut cfg = QuantConfig::new(2, 4);
            cfg.grouping = grouping;
            let t = quantize(&x, &shape, &cfg, &rng.rounding_offsets(x.len()));
            let want: Vec<f32> = (0..t.len())
                .map(|idx| {
                    let g = grouping.group_of(&shape, idx);
                    let xbar = t.cfg.element.decode(t.exp_code[idx], t.man[idx]);
                    t.sign[idx] as f32 * (t.s_t * t.group_scale(g)) * xbar
                })
                .collect();
            for threads in [1usize, 2, 8] {
                let got = t.dequantize_threaded(threads);
                for (idx, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{} t{threads} idx {idx}",
                        grouping.name()
                    );
                }
            }
        }
    }

    #[test]
    fn storage_accounting() {
        let shape = [4usize, 4, 3, 3];
        let mut rng = Pcg32::seeded(6);
        let x = rng.normal_vec(shape.iter().product(), 1.0);
        let cfg = QuantConfig::default(); // <2,4>: 7 bits/elem
        let t = quantize(&x, &shape, &cfg, &rng.rounding_offsets(x.len()));
        let expect = 144 * 7 + 16 * 9 + 32;
        assert_eq!(t.storage_bits(), expect as u64);
        // 32 / (7 + group overhead) ~ 3.9x for this small tensor
        assert!(t.compression_ratio() > 3.5);
    }

    #[test]
    fn transpose01_is_exact_value_permutation() {
        let shape = [3usize, 4, 2, 5];
        let [d0, d1, d2, d3] = shape;
        let mut rng = Pcg32::seeded(8);
        let x = crate::util::prop::grouped_tensor(&mut rng, shape);
        let cfg = QuantConfig::default();
        let t = quantize(&x, &shape, &cfg, &rng.rounding_offsets(x.len()));
        let q = t.dequantize();

        let tt = t.transpose01();
        assert_eq!(tt.shape, vec![d1, d0, d2, d3]);
        assert_eq!(tt.s_t, t.s_t);
        let qt = tt.dequantize();
        let tf = t.transpose01_flip23();
        let qf = tf.dequantize();
        for i0 in 0..d0 {
            for i1 in 0..d1 {
                for i2 in 0..d2 {
                    for i3 in 0..d3 {
                        let src = ((i0 * d1 + i1) * d2 + i2) * d3 + i3;
                        let dst = ((i1 * d0 + i0) * d2 + i2) * d3 + i3;
                        assert_eq!(qt[dst].to_bits(), q[src].to_bits(), "t [{i0},{i1},{i2},{i3}]");
                        let dflip = ((i1 * d0 + i0) * d2 + (d2 - 1 - i2)) * d3 + (d3 - 1 - i3);
                        assert_eq!(
                            qf[dflip].to_bits(),
                            q[src].to_bits(),
                            "tf [{i0},{i1},{i2},{i3}]"
                        );
                    }
                }
            }
        }
        // involution: transposing twice restores the original fields
        let back = tt.transpose01();
        assert_eq!(back.sign, t.sign);
        assert_eq!(back.exp_code, t.exp_code);
        assert_eq!(back.man, t.man);
        assert_eq!(back.sg_exp, t.sg_exp);
    }

    #[test]
    fn exponent_codes_in_range() {
        let shape = [4usize, 4, 2, 2];
        let mut rng = Pcg32::seeded(7);
        let x = rng.normal_vec(shape.iter().product(), 1.0);
        let cfg = QuantConfig::new(2, 4);
        let t = quantize(&x, &shape, &cfg, &rng.rounding_offsets(x.len()));
        // E_x = 2: codes 0..=3
        assert!(t.exp_code.iter().all(|&c| c <= 3));
        assert!(t.man.iter().all(|&m| m < 16));
    }
}
