//! Quantization-error statistics (paper Fig. 6 / Fig. 7).

use super::quantizer::{fake_quant, QuantConfig, Rounding};
use crate::util::stats;

/// ARE of quantizing `x` under `cfg` with nearest rounding (the Fig. 7
/// metric): mean|q - x| / mean|x|.
pub fn average_relative_error(x: &[f32], shape: &[usize], cfg: &QuantConfig) -> f64 {
    let mut c = *cfg;
    c.rounding = Rounding::Nearest;
    let q = fake_quant(x, shape, &c, &[]);
    stats::average_relative_error(x, &q)
}

/// Per-group maxima of |x| (the Fig. 6 curves), sorted descending.
pub fn group_maxima(x: &[f32], shape: &[usize], grouping: super::Grouping) -> Vec<f32> {
    let n_groups = grouping.group_count(shape);
    let mut maxima = vec![0.0f32; n_groups];
    for (idx, &v) in x.iter().enumerate() {
        let g = grouping.group_of(shape, idx);
        maxima[g] = maxima[g].max(v.abs());
    }
    maxima.sort_by(|a, b| b.partial_cmp(a).unwrap());
    maxima
}

/// Fraction of groups whose maximum is below half the overall maximum —
/// the paper's "over half of the groups" observation motivating group-wise
/// scaling (Fig. 6 red line).
pub fn fraction_below_half_max(maxima: &[f32]) -> f64 {
    let overall = maxima.iter().cloned().fold(0.0f32, f32::max);
    if overall == 0.0 {
        return 0.0;
    }
    maxima.iter().filter(|&&m| m < overall / 2.0).count() as f64 / maxima.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mls::{Grouping, QuantConfig};
    use crate::util::prop::grouped_tensor;
    use crate::util::rng::Pcg32;

    #[test]
    fn are_decreases_with_mantissa() {
        let mut rng = Pcg32::seeded(31);
        let shape = [8usize, 8, 4, 4];
        let x = grouped_tensor(&mut rng, shape);
        let mut last = f64::INFINITY;
        for m in [1u32, 2, 3, 4, 6] {
            let are = average_relative_error(&x, &shape, &QuantConfig::new(2, m));
            assert!(are <= last + 1e-9, "m={m}: {are} > {last}");
            last = are;
        }
    }

    #[test]
    fn grouping_helps_on_group_scaled_data() {
        let mut rng = Pcg32::seeded(32);
        let shape = [8usize, 8, 4, 4];
        let x = grouped_tensor(&mut rng, shape);
        let mut c_none = QuantConfig::new(0, 3);
        c_none.grouping = Grouping::None;
        let c_both = QuantConfig { grouping: Grouping::Both, ..QuantConfig::new(0, 3) };
        let are_none = average_relative_error(&x, &shape, &c_none);
        let are_both = average_relative_error(&x, &shape, &c_both);
        assert!(are_both < are_none, "{are_both} !< {are_none}");
    }

    #[test]
    fn group_maxima_sorted_and_sized() {
        let mut rng = Pcg32::seeded(33);
        let shape = [4usize, 6, 3, 3];
        let x = grouped_tensor(&mut rng, shape);
        let m = group_maxima(&x, &shape, Grouping::Both);
        assert_eq!(m.len(), 24);
        assert!(m.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn below_half_max_on_spread_data() {
        let mut rng = Pcg32::seeded(34);
        let shape = [16usize, 16, 3, 3];
        let x = grouped_tensor(&mut rng, shape);
        let m = group_maxima(&x, &shape, Grouping::Both);
        let frac = fraction_below_half_max(&m);
        // exp(2*normal) magnitudes: most groups sit far below the peak
        assert!(frac > 0.5, "frac {frac}");
    }
}
