//! mls-train — CLI for the MLS low-bit training framework.
//!
//! ```text
//! mls-train train        [--set key=value ...]                 one training run
//! mls-train eval         --state FILE [--model M] [--set ...]  evaluate a checkpoint
//! mls-train serve        [--checkpoint F.ckpt.bin] [--set ...]  batched inference server
//! mls-train experiments  --exp <table1|...|ratios> [--set ...] paper tables/figures
//! mls-train lab run      PLAN.json [--out DIR] [--force]       declarative grid runner
//! mls-train lab expand   PLAN.json                             print the trial expansion
//! mls-train lab analyze  RUN_DIR                               rebuild the analysis tables
//! mls-train bench-info   [--artifacts DIR]                     artifacts + bench reports
//! mls-train energy       [--model resnet34] [--batch 64]       Table VI energy breakdown
//! mls-train quantize     --input F [--e 2] [--m 4]             file-level codec demo
//! ```
//!
//! Every subcommand answers `--help`; `train`/`eval`/`experiments`/`lab`
//! embed the typed config key table generated from the registry in
//! `coordinator::config` (`--set key=value`, same keys in plan files).
//! The pre-PR-6 spellings `repro` and `info` still work with a
//! deprecation note.

use anyhow::{anyhow, Result};

use mls_train::coordinator::{config, experiments, lab, trainer, Backend, TrainConfig};
use mls_train::hw::report;
use mls_train::hw::units::EnergyModel;
use mls_train::mls::format::EmFormat;
use mls_train::runtime::Engine;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    cmd: String,
    /// non-flag operands after the subcommand (`lab run PLAN.json`)
    positional: Vec<String>,
    artifacts: String,
    sets: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
    help: bool,
    force: bool,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut positional = Vec::new();
    let mut artifacts = "artifacts".to_string();
    let mut sets = Vec::new();
    let mut flags = std::collections::BTreeMap::new();
    let mut help = false;
    let mut force = false;
    while let Some(a) = it.next() {
        match a.as_str() {
            "--help" | "-h" => help = true,
            "--force" => force = true,
            "--artifacts" => artifacts = it.next().ok_or_else(|| anyhow!("--artifacts needs a value"))?,
            "--set" => sets.push(it.next().ok_or_else(|| anyhow!("--set needs key=value"))?),
            f if f.starts_with("--") => {
                let key = f.trim_start_matches("--").to_string();
                let val = it.next().ok_or_else(|| anyhow!("{f} needs a value"))?;
                flags.insert(key, val);
            }
            other => positional.push(other.to_string()),
        }
    }
    Ok(Args { cmd, positional, artifacts, sets, flags, help, force })
}

fn run() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "experiments" => cmd_experiments(&args),
        "repro" => {
            eprintln!("note: `repro` is deprecated; use `mls-train experiments`");
            cmd_experiments(&args)
        }
        "lab" => cmd_lab(&args),
        "bench-info" => cmd_bench_info(&args),
        "info" => {
            eprintln!("note: `info` is deprecated; use `mls-train bench-info`");
            cmd_bench_info(&args)
        }
        "energy" => cmd_energy(&args),
        "quantize" => cmd_quantize(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}\n{HELP}")),
    }
}

const HELP: &str = "\
mls-train — MLS low-bit CNN training framework (paper reproduction)

commands:
  train        run one training experiment (--set model=cnn_s --set cfg=e2m4_gnc_eg8mg1_sr);
               backend=native (default) is the self-contained Alg. 1 low-bit trainer
  eval         evaluate a saved state (--state runs/...state.bin [--model cnn_s])
  serve        batched low-bit inference server over quantize-once panel caches
               (--checkpoint runs/...ckpt.bin or a fresh init; --set serve_mode=jsonl|tcp,
               serve_batch_max, serve_batch_wait_us, serve_port)
  experiments  regenerate a paper table/figure (--exp table1..table6, fig2, fig6, fig7,
               eq12, ratios)  [formerly `repro`]
  lab          declarative grid runner over plan files:
                 lab run PLAN.json [--out DIR] [--force]   execute (resumable)
                 lab expand PLAN.json                      print the trial expansion
                 lab analyze RUN_DIR                       rebuild ranked.jsonl + tables.md
  bench-info   list artifacts/models and summarize BENCH_*.json reports  [formerly `info`]
  energy       Table VI energy breakdown (--model resnet34 --batch 64)
  quantize     quantize a raw f32 file to MLS and report stats (--input F --e 2 --m 4)

common flags: --artifacts DIR (default: artifacts), --set key=value (repeatable),
--help on any subcommand (train/eval/experiments/lab print the config key table)";

fn print_config_help(cmd: &str, intro: &str) {
    println!("mls-train {cmd} — {intro}\n");
    println!("{}", config::help_table());
}

fn cmd_train(args: &Args) -> Result<()> {
    if args.help {
        print_config_help(
            "train",
            "run one training experiment (--set key=value over these defaults; \
             output files under --set out_dir=..., default runs/)",
        );
        return Ok(());
    }
    let mut config = TrainConfig::default();
    config.out_dir = Some("runs".to_string());
    for kv in &args.sets {
        config.set(kv)?;
    }
    if config.backend == Backend::Native {
        // self-contained: no artifacts, no PJRT
        let result = trainer::train_native(&config)?;
        println!("{}", result.summary());
        println!(
            "native backend ({} optimizer): mean step {:.1} ms; metrics + per-layer audit stream in {}/",
            config.optimizer,
            result.metrics.mean_step_ms(),
            config.out_dir.as_deref().unwrap_or("-")
        );
    } else {
        let mut engine = Engine::from_dir(&args.artifacts)?;
        let result = trainer::train(&mut engine, &config)?;
        println!("{}", result.summary());
        println!(
            "mean step {:.1} ms (device {:.1} ms); metrics in {}/",
            result.metrics.mean_step_ms(),
            engine.mean_exec_time().as_secs_f64() * 1e3,
            config.out_dir.as_deref().unwrap_or("-")
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    if args.help {
        print_config_help(
            "eval",
            "evaluate a saved .state.bin checkpoint on the test stream \
             (--state FILE [--model M], --set for dataset/backend keys)",
        );
        return Ok(());
    }
    let model = args.flags.get("model").cloned().unwrap_or_else(|| "cnn_s".into());
    let state_path = args
        .flags
        .get("state")
        .ok_or_else(|| anyhow!("eval needs --state FILE (a .state.bin checkpoint)"))?;
    let bytes = std::fs::read(state_path)?;
    let state: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let mut config = TrainConfig::default();
    for kv in &args.sets {
        config.set(kv)?;
    }
    let ds = mls_train::data::SynthCifar::new(config.data.clone());
    let (loss, acc) = if config.backend == Backend::Native {
        let qcfg = mls_train::mls::quantizer::QuantConfig::parse_name(&config.cfg_name)?;
        let mut native = mls_train::nn::train::native_model(&model, qcfg, config.seed)?;
        native.load_state(&state)?;
        trainer::evaluate_native(
            &native,
            &ds,
            mls_train::data::streams::TEST,
            config.eval_batches,
            config.batch,
        )
    } else {
        let mut engine = Engine::from_dir(&args.artifacts)?;
        trainer::evaluate(
            &mut engine,
            &model,
            &state,
            &ds,
            mls_train::data::streams::TEST,
            config.eval_batches,
        )?
    };
    println!("{model}: test loss {loss:.4} acc {acc:.3}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.help {
        print_config_help(
            "serve",
            "batched low-bit inference server (--checkpoint FILE.ckpt.bin serves a trained \
             model; otherwise a fresh seeded init of --set model=.../cfg=...); the serve_* \
             keys below control coalescing and transport. Protocol: 4-byte-LE length-prefixed \
             JSON frames, requests {\"id\":N,\"image\":[C*H*W floats]}, shutdown \
             {\"cmd\":\"shutdown\"}",
        );
        return Ok(());
    }
    let mut config = TrainConfig::default();
    for kv in &args.sets {
        config.set(kv)?;
    }
    let threads = mls_train::util::parallel::num_threads();
    let mut served = match args.flags.get("checkpoint") {
        Some(path) => {
            mls_train::serve::ServedModel::from_checkpoint(std::path::Path::new(path), threads)?
        }
        None => {
            mls_train::serve::ServedModel::fresh(&config.model, &config.cfg_name, config.seed, threads)?
        }
    };
    let opts = mls_train::serve::ServeOptions::from_config(&config);
    // status on stderr: stdout is the response channel in jsonl mode
    eprintln!(
        "[serve] model {} ({} input floats -> {} classes), batch_max {}, batch_wait {}us",
        served.name(),
        served.input_elems(),
        served.classes(),
        opts.batch_max,
        config.serve_batch_wait_us,
    );
    let stats = match config.serve_mode.as_str() {
        "jsonl" => {
            let stdin = std::io::stdin();
            let mut stdout = std::io::stdout().lock();
            mls_train::serve::serve_stream(&mut served, stdin, &mut stdout, &opts)?
        }
        "tcp" => {
            let listener = std::net::TcpListener::bind(("127.0.0.1", config.serve_port))?;
            eprintln!("[serve] listening on {}", listener.local_addr()?);
            mls_train::serve::serve_tcp(&mut served, listener, &opts)?
        }
        other => return Err(anyhow!("unknown serve_mode {other:?} (have [\"jsonl\", \"tcp\"])")),
    };
    eprintln!("[serve] {}", stats.summary());
    Ok(())
}

fn cmd_experiments(args: &Args) -> Result<()> {
    if args.help {
        print_config_help(
            "experiments",
            &format!(
                "regenerate a paper table/figure (--exp NAME, --set overrides); \
                 have {:?}",
                experiments::EXPERIMENTS
            ),
        );
        return Ok(());
    }
    let exp = args
        .flags
        .get("exp")
        .ok_or_else(|| anyhow!("experiments needs --exp <name>; have {:?}", experiments::EXPERIMENTS))?;
    let report = experiments::run(exp, &args.artifacts, &args.sets)?;
    println!("{report}");
    Ok(())
}

const LAB_HELP: &str = "\
mls-train lab — declarative grid runner (resumable experiment plans)

  lab run PLAN.json [--out DIR] [--force]
      Expand the plan into trials and execute each in its own directory
      under DIR/<plan-name>/ (DIR default: runs/lab). Trials whose
      existing trial_output.json validates (schemas/trial_output.schema.json
      + exact config echo) are skipped, so a crashed or repeated run only
      executes what is missing; --force re-runs everything. Finishes by
      rebuilding analysis/ranked.jsonl + analysis/tables.md.

  lab expand PLAN.json
      Print the deterministic trial expansion (id and resolved overrides
      per trial) without running anything.

  lab analyze RUN_DIR
      Rebuild the analysis tables from the trial_output.json files under
      an existing run directory.

A plan (schemas/plan.schema.json, example: examples/plan_table2.json):
  { \"name\": \"table2\",               run-directory name
    \"base\": {\"steps\": 40},          fixed overrides (any config key below)
    \"grid\": {\"model\": [...], \"cfg\": [...]},   axes: key -> values
    \"seeds\": [0, 1] }                or \"repeats\": N for seeds 0..N
";

fn cmd_lab(args: &Args) -> Result<()> {
    let sub = args.positional.first().map(String::as_str);
    if args.help || sub.is_none() {
        println!("{LAB_HELP}");
        println!("{}", config::help_table());
        return Ok(());
    }
    let operand = |what: &str| {
        args.positional
            .get(1)
            .ok_or_else(|| anyhow!("lab {} needs {what}\n\n{LAB_HELP}", sub.unwrap_or_default()))
    };
    match sub.unwrap_or_default() {
        "run" => {
            let plan = std::path::PathBuf::from(operand("a PLAN.json path")?);
            let out = args
                .flags
                .get("out")
                .cloned()
                .unwrap_or_else(|| "runs/lab".to_string());
            let report = lab::run_plan_file(&plan, std::path::Path::new(&out), args.force)?;
            println!("{}", report.summary());
            println!("analysis: {}", report.analysis_dir.display());
            Ok(())
        }
        "expand" => {
            let plan = lab::Plan::load(std::path::Path::new(operand("a PLAN.json path")?))?;
            let trials = plan.trials()?;
            println!("plan {}: {} trials", plan.name, trials.len());
            for t in &trials {
                let binds: Vec<String> =
                    t.bindings.iter().map(|(k, v)| format!("{k}={v}")).collect();
                println!("  {}  [{}] seed={}", t.id, binds.join(" "), t.seed);
            }
            Ok(())
        }
        "analyze" => {
            let dir = lab::analyze(std::path::Path::new(operand("a run directory")?))?;
            println!("analysis rebuilt: {}", dir.display());
            Ok(())
        }
        other => Err(anyhow!("unknown lab subcommand {other:?}\n\n{LAB_HELP}")),
    }
}

fn cmd_energy(args: &Args) -> Result<()> {
    let model = args.flags.get("model").cloned().unwrap_or_else(|| "resnet34".into());
    let batch: usize = args.flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(64);
    let em = EnergyModel::fitted();
    println!("{}", report::table6(&model, batch, EmFormat::new(2, 4), &em)?);
    Ok(())
}

fn cmd_bench_info(args: &Args) -> Result<()> {
    let engine = Engine::from_dir(&args.artifacts);
    match engine {
        Ok(e) => {
            println!("artifacts dir: {}", args.artifacts);
            for (name, meta) in &e.manifest.models {
                println!(
                    "model {name}: state_dim {} batch {} img {:?} ({} vars, {} probe layers)",
                    meta.state_dim,
                    meta.batch,
                    meta.img_shape,
                    meta.specs.len(),
                    meta.probe_names.len()
                );
            }
            for a in &e.manifest.artifacts {
                println!("  {} ({} / {})", a.name, a.fn_kind, a.cfg_name);
            }
        }
        Err(e) => println!("no artifacts loaded: {e:#}"),
    }
    println!("\nanalytic networks: {:?}", mls_train::nn::zoo::NETWORKS);
    println!("simd dispatch: {}", mls_train::util::simd::describe());

    // measured bench reports at the repo root (written by `cargo bench`)
    let mut found = false;
    for file in ["BENCH_conv.json", "BENCH_quantize.json", "BENCH_train.json", "BENCH_serve.json"] {
        let Ok(text) = std::fs::read_to_string(file) else { continue };
        let Ok(v) = mls_train::util::json::Json::parse(&text) else {
            println!("bench report {file}: unparseable");
            continue;
        };
        if !found {
            println!("\nbench reports:");
            found = true;
        }
        let results = v.get("results").and_then(|r| r.as_obj().map(|m| m.len())).unwrap_or(0);
        print!("  {file}: {results} results");
        if let Some(simd) = v.get("simd").and_then(|s| s.as_str()) {
            print!("  simd={simd}");
        }
        if let Some(ratios) = v.get("ratios").and_then(|r| r.as_obj()) {
            let pairs: Vec<String> = ratios
                .iter()
                .filter_map(|(k, r)| r.as_f64().map(|x| format!("{k}={x:.2}")))
                .collect();
            if !pairs.is_empty() {
                print!("  [{}]", pairs.join(", "));
            }
        }
        println!();
    }
    if !found {
        println!("\nno BENCH_*.json at the repo root (run `cargo bench` to produce them)");
    }
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    use mls_train::mls::{quantizer, QuantConfig};
    let input = args
        .flags
        .get("input")
        .ok_or_else(|| anyhow!("quantize needs --input FILE (raw little-endian f32)"))?;
    let e: u32 = args.flags.get("e").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let m: u32 = args.flags.get("m").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let bytes = std::fs::read(input)?;
    let x: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let shape = [x.len(), 1, 1, 1];
    let mut cfg = QuantConfig::new(e, m);
    cfg.grouping = mls_train::mls::Grouping::None;
    cfg.rounding = mls_train::mls::Rounding::Nearest;
    let t = quantizer::quantize(&x, &shape, &cfg, &[]);
    let q = t.dequantize();
    let are = mls_train::util::stats::average_relative_error(&x, &q);
    println!(
        "{} values, <{},{}>: storage {:.2} KiB (f32 {:.2} KiB, {:.2}x), ARE {:.5}",
        x.len(),
        e,
        m,
        t.storage_bits() as f64 / 8192.0,
        x.len() as f64 * 4.0 / 1024.0,
        t.compression_ratio(),
        are
    );
    Ok(())
}
