//! mls-train — CLI for the MLS low-bit training framework.
//!
//! ```text
//! mls-train train   [--artifacts DIR] [--set key=value ...]
//! mls-train eval    [--artifacts DIR] --model M --state FILE
//! mls-train repro   --exp <table1|table2|...|fig7|eq12|ratios> [--set ...]
//! mls-train energy  [--model resnet34] [--batch 64]
//! mls-train info    [--artifacts DIR]
//! mls-train quantize --e E --m M < in.f32 > report   (file-level codec demo)
//! ```

use anyhow::{anyhow, Result};

use mls_train::coordinator::{experiments, trainer, Backend, TrainConfig};
use mls_train::hw::report;
use mls_train::hw::units::EnergyModel;
use mls_train::mls::format::EmFormat;
use mls_train::runtime::Engine;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    cmd: String,
    artifacts: String,
    sets: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut artifacts = "artifacts".to_string();
    let mut sets = Vec::new();
    let mut flags = std::collections::BTreeMap::new();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--artifacts" => artifacts = it.next().ok_or_else(|| anyhow!("--artifacts needs a value"))?,
            "--set" => sets.push(it.next().ok_or_else(|| anyhow!("--set needs key=value"))?),
            f if f.starts_with("--") => {
                let key = f.trim_start_matches("--").to_string();
                let val = it.next().ok_or_else(|| anyhow!("{f} needs a value"))?;
                flags.insert(key, val);
            }
            other => return Err(anyhow!("unexpected argument {other:?}")),
        }
    }
    Ok(Args { cmd, artifacts, sets, flags })
}

fn run() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "repro" => cmd_repro(&args),
        "energy" => cmd_energy(&args),
        "info" => cmd_info(&args),
        "quantize" => cmd_quantize(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}\n{HELP}")),
    }
}

const HELP: &str = "\
mls-train — MLS low-bit CNN training framework (paper reproduction)

commands:
  train     run one training experiment (--set model=cnn_s --set cfg=e2m4_gnc_eg8mg1_sr --set steps=300);
            backend=native (default) runs the self-contained Alg. 1 low-bit trainer
            on the module-graph models cnn_t / cnn_s / resnet_t (residual), with
            --set optimizer=sgd|momentum --set momentum=0.9 --set weight_decay=0;
            backend=pjrt the AOT artifacts (needs make artifacts + the pjrt feature)
  eval      evaluate a saved state (--model cnn_s --state runs/...state.bin; --set backend=...)
  repro     regenerate a paper table/figure (--exp table1..table6, fig2, fig6, fig7, eq12, ratios)
  energy    Table VI energy breakdown (--model resnet34 --batch 64)
  info      list artifacts and models
  quantize  quantize a raw f32 file to MLS and report stats (--input F --e 2 --m 4)

common flags: --artifacts DIR (default: artifacts), --set key=value (repeatable)";

fn cmd_train(args: &Args) -> Result<()> {
    let mut config = TrainConfig::default();
    config.out_dir = Some("runs".to_string());
    for kv in &args.sets {
        config.set(kv)?;
    }
    if config.backend == Backend::Native {
        // self-contained: no artifacts, no PJRT
        let result = trainer::train_native(&config)?;
        println!("{}", result.summary());
        println!(
            "native backend ({} optimizer): mean step {:.1} ms; metrics + per-layer audit stream in {}/",
            config.optimizer,
            result.metrics.mean_step_ms(),
            config.out_dir.as_deref().unwrap_or("-")
        );
    } else {
        let mut engine = Engine::from_dir(&args.artifacts)?;
        let result = trainer::train(&mut engine, &config)?;
        println!("{}", result.summary());
        println!(
            "mean step {:.1} ms (device {:.1} ms); metrics in {}/",
            result.metrics.mean_step_ms(),
            engine.mean_exec_time().as_secs_f64() * 1e3,
            config.out_dir.as_deref().unwrap_or("-")
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.flags.get("model").cloned().unwrap_or_else(|| "cnn_s".into());
    let state_path = args
        .flags
        .get("state")
        .ok_or_else(|| anyhow!("eval needs --state FILE (a .state.bin checkpoint)"))?;
    let bytes = std::fs::read(state_path)?;
    let state: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let mut config = TrainConfig::default();
    for kv in &args.sets {
        config.set(kv)?;
    }
    let ds = mls_train::data::SynthCifar::new(config.data.clone());
    let (loss, acc) = if config.backend == Backend::Native {
        let qcfg = mls_train::mls::quantizer::QuantConfig::parse_name(&config.cfg_name)?;
        let mut native = mls_train::nn::train::native_model(&model, qcfg, config.seed)?;
        native.load_state(&state)?;
        trainer::evaluate_native(
            &native,
            &ds,
            mls_train::data::streams::TEST,
            config.eval_batches,
            config.batch,
        )
    } else {
        let mut engine = Engine::from_dir(&args.artifacts)?;
        trainer::evaluate(
            &mut engine,
            &model,
            &state,
            &ds,
            mls_train::data::streams::TEST,
            config.eval_batches,
        )?
    };
    println!("{model}: test loss {loss:.4} acc {acc:.3}");
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let exp = args
        .flags
        .get("exp")
        .ok_or_else(|| anyhow!("repro needs --exp <name>; have {:?}", experiments::EXPERIMENTS))?;
    let report = experiments::run(exp, &args.artifacts, &args.sets)?;
    println!("{report}");
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<()> {
    let model = args.flags.get("model").cloned().unwrap_or_else(|| "resnet34".into());
    let batch: usize = args.flags.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(64);
    let em = EnergyModel::fitted();
    println!("{}", report::table6(&model, batch, EmFormat::new(2, 4), &em)?);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let engine = Engine::from_dir(&args.artifacts);
    match engine {
        Ok(e) => {
            println!("artifacts dir: {}", args.artifacts);
            for (name, meta) in &e.manifest.models {
                println!(
                    "model {name}: state_dim {} batch {} img {:?} ({} vars, {} probe layers)",
                    meta.state_dim,
                    meta.batch,
                    meta.img_shape,
                    meta.specs.len(),
                    meta.probe_names.len()
                );
            }
            for a in &e.manifest.artifacts {
                println!("  {} ({} / {})", a.name, a.fn_kind, a.cfg_name);
            }
        }
        Err(e) => println!("no artifacts loaded: {e:#}"),
    }
    println!("\nanalytic networks: {:?}", mls_train::nn::zoo::NETWORKS);
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    use mls_train::mls::{quantizer, QuantConfig};
    let input = args
        .flags
        .get("input")
        .ok_or_else(|| anyhow!("quantize needs --input FILE (raw little-endian f32)"))?;
    let e: u32 = args.flags.get("e").map(|s| s.parse()).transpose()?.unwrap_or(2);
    let m: u32 = args.flags.get("m").map(|s| s.parse()).transpose()?.unwrap_or(4);
    let bytes = std::fs::read(input)?;
    let x: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let shape = [x.len(), 1, 1, 1];
    let mut cfg = QuantConfig::new(e, m);
    cfg.grouping = mls_train::mls::Grouping::None;
    cfg.rounding = mls_train::mls::Rounding::Nearest;
    let t = quantizer::quantize(&x, &shape, &cfg, &[]);
    let q = t.dequantize();
    let are = mls_train::util::stats::average_relative_error(&x, &q);
    println!(
        "{} values, <{},{}>: storage {:.2} KiB (f32 {:.2} KiB, {:.2}x), ARE {:.5}",
        x.len(),
        e,
        m,
        t.storage_bits() as f64 / 8192.0,
        x.len() as f64 * 4.0 / 1024.0,
        t.compression_ratio(),
        are
    );
    Ok(())
}
