//! # mls-train — MLS low-bit CNN training framework
//!
//! Reproduction of *"Exploring the Potential of Low-bit Training of
//! Convolutional Neural Networks"* (Zhong et al., 2020) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1 (build-time Python)** — the MLS dynamic-quantization Pallas
//!   kernel (`python/compile/kernels/`), bit-exact against a jnp oracle,
//! * **L2 (build-time Python)** — JAX CNNs whose convolutions run the
//!   paper's Alg. 1 quantized forward/backward, AOT-lowered to HLO text,
//! * **L3 (this crate)** — the runtime: PJRT execution of the artifacts,
//!   the training coordinator, and every substrate the paper's evaluation
//!   needs (bit-accurate MLS arithmetic, the hardware energy model, the
//!   model-shape zoo, the synthetic dataset, the experiment harness).
//!
//! Python never runs on the request path: after `make artifacts` the Rust
//! binary is self-contained.
//!
//! See `DESIGN.md` for the architecture and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod arith;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod mls;
pub mod nn;
pub mod runtime;
pub mod serve;
pub mod util;
