//! The quantize-once served model: a trained [`NativeModel`] behind a
//! weight-frozen step arena.
//!
//! Training re-quantizes weights every step because they change every
//! step. At serve time they never change, so the first `infer_batch`
//! quantizes each conv's weights into its persistent
//! [`crate::nn::arena`] plane slots and packs the forward panels once;
//! [`crate::nn::StepArena::freeze_weights`] then lets every later
//! deterministic forward skip straight to the Eq. 7 packed-GEMM engine.
//! Eval-mode quantization draws no RNG (nearest rounding), so skipping
//! it is invisible to the arithmetic: the served output stays
//! bit-identical to the heap-path [`NativeModel::eval_logits`] oracle,
//! values and audit counters both.
//!
//! The arena deliberately never enters strict mode ([`crate::nn::arena`]):
//! coalesced batches vary in size, so the pool must stay allowed to grow
//! a new size class when a new batch size first appears (steady state at
//! a given size is still zero-alloc).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::Checkpoint;
use crate::mls::quantizer::QuantConfig;
use crate::nn::graph::Executor;
use crate::nn::{native_model, NativeModel, StepArena, StepAudit, StepMem};
use crate::util::json::Json;

pub struct ServedModel {
    model: NativeModel,
    arena: StepArena,
    audit: StepAudit,
    threads: usize,
}

impl ServedModel {
    /// Wrap an already-constructed model (fresh init or restored state).
    pub fn from_model(model: NativeModel, threads: usize) -> ServedModel {
        let mut arena = StepArena::for_graph(&model.graph);
        arena.freeze_weights();
        ServedModel { model, arena, audit: StepAudit::default(), threads: threads.max(1) }
    }

    /// A freshly-initialized model (benchmarks and smoke tests: no
    /// checkpoint needed, weights are the seeded init).
    pub fn fresh(model_name: &str, cfg_name: &str, seed: u64, threads: usize) -> Result<ServedModel> {
        let qcfg = QuantConfig::parse_name(cfg_name)?;
        Ok(ServedModel::from_model(native_model(model_name, qcfg, seed)?, threads))
    }

    /// Load a trained model from a step checkpoint written by the
    /// coordinator ([`crate::coordinator::checkpoint`]). The model name,
    /// quant config and init seed come from the checkpoint's own config
    /// echo — serving needs no copy of the training config, and unlike
    /// resume there is no whole-echo equality requirement.
    pub fn from_checkpoint(path: &Path, threads: usize) -> Result<ServedModel> {
        let ckpt = Checkpoint::load_file(path)?;
        let echo = Json::parse(&ckpt.config_echo)
            .map_err(|e| anyhow!("checkpoint config echo is not JSON: {e}"))?;
        let field = |k: &str| {
            echo.get(k)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| anyhow!("checkpoint config echo has no {k:?} field"))
        };
        let model_name = field("model")?;
        let cfg_name = field("cfg")?;
        let seed: u64 = field("seed")?.parse().context("checkpoint config echo seed")?;
        let qcfg = QuantConfig::parse_name(&cfg_name)?;
        let mut model = native_model(&model_name, qcfg, seed)?;
        model
            .load_state(&ckpt.state)
            .with_context(|| format!("checkpoint state for model {model_name:?}"))?;
        Ok(ServedModel::from_model(model, threads))
    }

    /// Deterministic batched forward into `logits_out`
    /// (`[n, classes]`, row-major). First call per batch size warms the
    /// arena and (once ever) quantizes + packs the weights; steady state
    /// reuses everything.
    pub fn infer_batch(&mut self, images: &[f32], n: usize, logits_out: &mut Vec<f32>) {
        let ServedModel { model, arena, audit, threads } = self;
        let ex = Executor { graph: &model.graph, qcfg: &model.qcfg, threads: *threads };
        let mut mem = StepMem::Arena(arena);
        let logits = ex.forward_mem(images, n, None, None, audit, &mut mem);
        audit.roll_up();
        logits_out.clear();
        logits_out.extend_from_slice(&logits);
        mem.recycle_f32(logits);
    }

    /// The audit of the most recent [`Self::infer_batch`] (all five
    /// counters; forward-only, so wgrad/dgrad stay zero).
    pub fn last_audit(&self) -> &StepAudit {
        &self.audit
    }

    /// Toggle the quantize-once weight cache (on by construction). Off,
    /// every forward re-quantizes and re-packs — the `bench_serve`
    /// baseline for the `cached_vs_requantize_latency` ratio; values are
    /// bit-identical either way (nearest rounding is deterministic).
    pub fn set_weight_cache(&mut self, enabled: bool) {
        self.arena.weights_frozen = enabled;
    }

    /// The wrapped model (tests: the `eval_logits` oracle).
    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// Elements per request image (`C*H*W` of the model input).
    pub fn input_elems(&self) -> usize {
        let (c, h, w) = self.model.input;
        c * h * w
    }

    /// Logits per request.
    pub fn classes(&self) -> usize {
        self.model.classes
    }

    pub fn name(&self) -> &str {
        &self.model.name
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}
