//! Batched low-bit inference serving — the forward-only deployment path.
//!
//! The paper's energy argument (Eq. 7 shift-MACs instead of FP multiplies)
//! applies to the forward pass alone, and serving amortizes what training
//! cannot: with fixed weights, dynamic weight quantization is a pure
//! function of the parameters, so the decoded signed-frac/shift planes
//! and the packed forward panels are computed ONCE per model and reused
//! by every request. The pieces:
//!
//! * [`model`] — [`model::ServedModel`]: a [`crate::nn::NativeModel`]
//!   plus a weight-frozen step arena ([`crate::nn::StepArena`]); the
//!   steady-state `infer_batch` quantizes no weights, packs no panels and
//!   allocates (asymptotically) nothing. Bit-identical to
//!   `NativeModel::eval_batch` on the same inputs — values and all audit
//!   counters (pinned by `rust/tests/serve.rs`).
//! * [`batcher`] — [`batcher::Batcher`]: a blocking coalescing queue;
//!   concurrent client streams enqueue, the single model thread dequeues
//!   batches up to `serve_batch_max`, holding an open batch
//!   `serve_batch_wait_us` for stragglers.
//! * [`server`] — the protocol (one JSON object per
//!   [`crate::util::frame`] length-prefixed frame) over two transports:
//!   [`server::serve_stream`] (stdin/stdout, `mls-train serve`) and
//!   [`server::serve_tcp`] ([`std::net::TcpListener`], one framed
//!   connection per client).
//!
//! `benches/bench_serve.rs` measures the two structural claims —
//! `cached_vs_requantize_latency` (quantize-once wins) and
//! `batched_vs_single_throughput` (coalescing wins) — into
//! `BENCH_serve.json`.

pub mod batcher;
pub mod model;
pub mod server;

pub use batcher::{Batcher, Request};
pub use model::ServedModel;
pub use server::{serve_stream, serve_tcp, ServeOptions, ServeStats};
