//! The coalescing request queue between client reader threads and the
//! single model thread.
//!
//! Readers [`Batcher::push`] items as frames arrive; the model thread
//! calls [`Batcher::next_batch`], which blocks for the first item, then
//! holds the batch open up to a deadline (`serve_batch_wait_us`) hoping
//! to coalesce more — the latency/throughput trade the paper's batched
//! forward makes worthwhile (one packed-panel pass over N images costs
//! far less than N passes over one). FIFO order is preserved, which is
//! what makes per-stream response ordering trivial downstream.
//!
//! Generic over the item type so the unit tests below exercise the
//! blocking/coalescing logic without a model; the server instantiates it
//! with its crate-private `Item` (requests + in-order error reports).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One inference request as queued: which connection it came from, the
/// client's request id, the flat image, and when it was enqueued (the
/// served-latency clock starts here).
pub struct Request {
    pub conn: usize,
    pub id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
}

struct Queue<T> {
    pending: VecDeque<T>,
    closed: bool,
}

/// A blocking multi-producer single-consumer coalescing queue.
pub struct Batcher<T> {
    q: Mutex<Queue<T>>,
    cv: Condvar,
}

impl<T> Default for Batcher<T> {
    fn default() -> Self {
        Batcher { q: Mutex::new(Queue { pending: VecDeque::new(), closed: false }), cv: Condvar::new() }
    }
}

impl<T> Batcher<T> {
    pub fn new() -> Batcher<T> {
        Batcher::default()
    }

    /// Enqueue one item (any reader thread).
    pub fn push(&self, item: T) {
        let mut q = self.q.lock().expect("batcher lock");
        q.pending.push_back(item);
        self.cv.notify_all();
    }

    /// Mark the queue closed: producers stop, [`Self::next_batch`] drains
    /// what is pending and then returns `None`.
    pub fn close(&self) {
        self.q.lock().expect("batcher lock").closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.q.lock().expect("batcher lock").closed
    }

    /// Dequeue the next batch, FIFO: blocks until at least one item is
    /// pending (or `None` when closed and drained), then keeps the batch
    /// open up to `wait` for more arrivals, capped at `max` items. A
    /// closed queue dispatches immediately — no point waiting for
    /// stragglers that cannot come.
    pub fn next_batch(&self, max: usize, wait: Duration) -> Option<Vec<T>> {
        let max = max.max(1);
        let mut q = self.q.lock().expect("batcher lock");
        while q.pending.is_empty() {
            if q.closed {
                return None;
            }
            q = self.cv.wait(q).expect("batcher lock");
        }
        let deadline = Instant::now() + wait;
        while q.pending.len() < max && !q.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (back, timeout) = self.cv.wait_timeout(q, deadline - now).expect("batcher lock");
            q = back;
            if timeout.timed_out() {
                break;
            }
        }
        let take = q.pending.len().min(max);
        Some(q.pending.drain(..take).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    const NO_WAIT: Duration = Duration::from_micros(0);

    #[test]
    fn drains_fifo_in_max_sized_batches() {
        let b = Batcher::new();
        for i in 0..5 {
            b.push(i);
        }
        b.close();
        assert_eq!(b.next_batch(2, NO_WAIT), Some(vec![0, 1]));
        assert_eq!(b.next_batch(2, NO_WAIT), Some(vec![2, 3]));
        assert_eq!(b.next_batch(2, NO_WAIT), Some(vec![4]));
        assert_eq!(b.next_batch(2, NO_WAIT), None, "closed + drained");
        assert_eq!(b.next_batch(2, NO_WAIT), None, "None is sticky");
    }

    #[test]
    fn empty_closed_queue_returns_none_without_blocking() {
        let b: Batcher<u32> = Batcher::new();
        b.close();
        assert!(b.is_closed());
        assert_eq!(b.next_batch(8, Duration::from_secs(60)), None);
    }

    #[test]
    fn coalesces_items_that_arrive_within_the_wait_window() {
        let b = Arc::new(Batcher::new());
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                b.push(1);
                std::thread::sleep(Duration::from_millis(5));
                b.push(2);
                b.close();
            })
        };
        // a generous window: both items must land in one batch
        let batch = b.next_batch(8, Duration::from_secs(10)).unwrap();
        producer.join().unwrap();
        assert_eq!(batch, vec![1, 2], "second item must coalesce into the open batch");
        assert_eq!(b.next_batch(8, NO_WAIT), None);
    }

    #[test]
    fn blocks_until_the_first_item_arrives() {
        let b = Arc::new(Batcher::new());
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(5));
                b.push(7);
            })
        };
        // zero coalescing wait still blocks for the FIRST item
        let batch = b.next_batch(4, NO_WAIT).unwrap();
        producer.join().unwrap();
        assert_eq!(batch, vec![7]);
    }

    #[test]
    fn full_batch_dispatches_without_waiting_out_the_window() {
        let b = Batcher::new();
        for i in 0..3 {
            b.push(i);
        }
        let t0 = Instant::now();
        let batch = b.next_batch(3, Duration::from_secs(60)).unwrap();
        assert_eq!(batch, vec![0, 1, 2]);
        assert!(t0.elapsed() < Duration::from_secs(30), "must not sleep out the window");
    }
}
