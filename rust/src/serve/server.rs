//! The serve protocol and its two transports.
//!
//! Every message is one JSON object in one length-prefixed frame
//! ([`crate::util::frame`]: 4-byte LE length + payload). Client -> server:
//!
//! * `{"id": <u53>, "image": [<C*H*W floats>]}` — one inference request;
//! * `{"cmd": "shutdown"}` — stop the server (drains pending requests).
//!
//! Server -> client, in per-stream FIFO order:
//!
//! * `{"id": .., "argmax": .., "batch": <coalesced batch size>,
//!   "latency_us": .., "logits": [..]}` — logits are exact: f32 values
//!   printed as shortest-round-trip f64, so a client parsing them back
//!   recovers the served bits (pinned in `tests/serve.rs`);
//! * `{"id": .. | null, "error": "..."}` — a malformed frame. JSON-level
//!   garbage is recoverable (the frame boundary survives, the stream
//!   continues); a framing-level error is not — after reporting it the
//!   stream is dropped, since the byte position is unknowable.
//!
//! [`serve_stream`] runs one framed stream (CLI: stdin/stdout);
//! [`serve_tcp`] accepts N concurrent connections, all feeding one
//! [`Batcher`] and one model thread, responses demuxed back per
//! connection.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Batcher, Request};
use super::model::ServedModel;
use crate::coordinator::TrainConfig;
use crate::util::frame;
use crate::util::json::Json;

/// Serve-loop knobs (the `serve_*` config registry keys).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// max requests coalesced into one forward batch
    pub batch_max: usize,
    /// how long an open batch waits for stragglers
    pub batch_wait: Duration,
    /// frame-size cap (a corrupt length prefix must not drive an alloc)
    pub max_frame: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            batch_max: 8,
            batch_wait: Duration::from_micros(200),
            max_frame: 1 << 22,
        }
    }
}

impl ServeOptions {
    pub fn from_config(c: &TrainConfig) -> ServeOptions {
        ServeOptions {
            batch_max: c.serve_batch_max.max(1),
            batch_wait: Duration::from_micros(c.serve_batch_wait_us),
            ..ServeOptions::default()
        }
    }
}

/// Per-request service records, aggregated by the dispatch loop
/// (`bench_serve` and the CLI exit summary read these).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub batches: u64,
    /// enqueue -> response-built latency, one entry per served request
    pub latency_us: Vec<u64>,
    /// coalesced batch size each request rode in, parallel to `latency_us`
    pub batch_sizes: Vec<usize>,
}

impl ServeStats {
    /// Latency percentile in microseconds (nearest-rank on the sorted
    /// records); 0 when nothing was served.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        if self.latency_us.is_empty() {
            return 0;
        }
        let mut v = self.latency_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "served {} requests in {} batches (mean batch {:.2}), latency p50 {}us p99 {}us",
            self.requests,
            self.batches,
            self.mean_batch(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0)
        )
    }
}

/// One queued unit of work: an inference request, or a malformed-input
/// report that must be answered in stream order.
pub(crate) enum Item {
    Req(Request),
    Error { conn: usize, id: Option<u64>, error: String },
}

enum Parsed {
    Shutdown,
    Req { id: u64, image: Vec<f32> },
}

/// Parse one request frame. Errors carry the request id when one was
/// recoverable from the payload, so the client can correlate.
fn parse_request(payload: &[u8], expect_elems: usize) -> Result<Parsed, (Option<u64>, String)> {
    let text = std::str::from_utf8(payload)
        .map_err(|e| (None, format!("frame payload is not UTF-8: {e}")))?;
    let j = Json::parse(text).map_err(|e| (None, format!("frame payload is not JSON: {e}")))?;
    if let Some(cmd) = j.get("cmd").and_then(|v| v.as_str()) {
        if cmd == "shutdown" {
            return Ok(Parsed::Shutdown);
        }
        return Err((None, format!("unknown cmd {cmd:?} (have [\"shutdown\"])")));
    }
    let id = j
        .get("id")
        .and_then(|v| v.as_f64())
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .map(|v| v as u64)
        .ok_or_else(|| (None, "request has no non-negative integer \"id\"".to_string()))?;
    let image = j
        .get("image")
        .ok_or_else(|| (Some(id), "request has no \"image\" array".to_string()))?
        .f32s()
        .map_err(|e| (Some(id), format!("bad \"image\": {e}")))?;
    if image.len() != expect_elems {
        return Err((
            Some(id),
            format!("\"image\" has {} elements, model input wants {expect_elems}", image.len()),
        ));
    }
    Ok(Parsed::Req { id, image })
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

fn response_json(id: u64, class: usize, batch: usize, latency_us: u64, logits: &[f32]) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert("argmax".to_string(), Json::Num(class as f64));
    m.insert("batch".to_string(), Json::Num(batch as f64));
    m.insert("latency_us".to_string(), Json::Num(latency_us as f64));
    m.insert(
        "logits".to_string(),
        Json::Arr(logits.iter().map(|&v| Json::Num(v as f64)).collect()),
    );
    Json::Obj(m)
}

fn error_json(id: Option<u64>, error: &str) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("id".to_string(), id.map_or(Json::Null, |v| Json::Num(v as f64)));
    m.insert("error".to_string(), Json::Str(error.to_string()));
    Json::Obj(m)
}

/// Where responses go: the single stream writer, or the per-connection
/// TCP writer map.
trait Sink {
    fn send(&mut self, conn: usize, payload: &[u8]) -> io::Result<()>;
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

struct StreamSink<'a, W: Write> {
    w: &'a mut W,
}

impl<W: Write> Sink for StreamSink<'_, W> {
    fn send(&mut self, _conn: usize, payload: &[u8]) -> io::Result<()> {
        frame::write_frame(self.w, payload)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.w.flush()
    }
}

struct TcpSink<'a> {
    writers: &'a Mutex<HashMap<usize, TcpStream>>,
}

impl Sink for TcpSink<'_> {
    fn send(&mut self, conn: usize, payload: &[u8]) -> io::Result<()> {
        let mut map = self.writers.lock().expect("writer map lock");
        let w = map
            .get_mut(&conn)
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "connection gone"))?;
        frame::write_frame(w, payload)
    }
}

/// One stream's read half: frames -> parsed items -> the batcher.
/// Returns `true` when the stream asked for server shutdown.
fn read_loop(
    mut reader: impl Read,
    conn: usize,
    expect_elems: usize,
    max_frame: usize,
    batcher: &Batcher<Item>,
) -> bool {
    loop {
        match frame::read_frame(&mut reader, max_frame) {
            Ok(None) => return false,
            Err(e) => {
                // the byte position after a framing error is unknowable —
                // report it, then drop the stream rather than serve
                // garbage from a desynchronized frame boundary
                batcher.push(Item::Error { conn, id: None, error: format!("frame error: {e}") });
                return false;
            }
            Ok(Some(payload)) => match parse_request(&payload, expect_elems) {
                Ok(Parsed::Shutdown) => return true,
                Ok(Parsed::Req { id, image }) => batcher.push(Item::Req(Request {
                    conn,
                    id,
                    image,
                    enqueued: Instant::now(),
                })),
                // JSON-level garbage keeps the frame boundary intact:
                // answer with an error and keep serving the stream
                Err((id, error)) => batcher.push(Item::Error { conn, id, error }),
            },
        }
    }
}

/// The single model thread: coalesced batches in, framed responses out,
/// per-stream FIFO order preserved (the batcher is FIFO and responses
/// are emitted in item order).
fn dispatch_loop(
    model: &mut ServedModel,
    batcher: &Batcher<Item>,
    opts: &ServeOptions,
    sink: &mut dyn Sink,
) -> ServeStats {
    let mut stats = ServeStats::default();
    let classes = model.classes();
    let elems = model.input_elems();
    let mut images: Vec<f32> = Vec::new();
    let mut logits: Vec<f32> = Vec::new();
    while let Some(batch) = batcher.next_batch(opts.batch_max, opts.batch_wait) {
        let n = batch.iter().filter(|it| matches!(it, Item::Req(_))).count();
        if n > 0 {
            images.clear();
            for it in &batch {
                if let Item::Req(r) = it {
                    images.extend_from_slice(&r.image);
                }
            }
            debug_assert_eq!(images.len(), n * elems);
            model.infer_batch(&images, n, &mut logits);
            stats.batches += 1;
        }
        let mut k = 0;
        for it in &batch {
            match it {
                Item::Req(r) => {
                    let row = &logits[k * classes..(k + 1) * classes];
                    k += 1;
                    let latency_us = r.enqueued.elapsed().as_micros() as u64;
                    let resp = response_json(r.id, argmax(row), n, latency_us, row);
                    if let Err(e) = sink.send(r.conn, resp.to_string_compact().as_bytes()) {
                        eprintln!("[serve] conn {}: dropping response {}: {e}", r.conn, r.id);
                    }
                    stats.requests += 1;
                    stats.latency_us.push(latency_us);
                    stats.batch_sizes.push(n);
                }
                Item::Error { conn, id, error } => {
                    let payload = error_json(*id, error).to_string_compact();
                    if let Err(e) = sink.send(*conn, payload.as_bytes()) {
                        eprintln!("[serve] conn {conn}: dropping error response: {e}");
                    }
                }
            }
        }
        if let Err(e) = sink.flush() {
            eprintln!("[serve] flush: {e}");
        }
    }
    stats
}

/// Serve one framed stream (the `serve_mode=jsonl` CLI path: stdin in,
/// stdout out). Returns when the stream reaches EOF or sends
/// `{"cmd":"shutdown"}`, after draining every pending request.
pub fn serve_stream<R, W>(
    model: &mut ServedModel,
    reader: R,
    writer: &mut W,
    opts: &ServeOptions,
) -> Result<ServeStats>
where
    R: Read + Send + 'static,
    W: Write,
{
    let batcher = Arc::new(Batcher::<Item>::new());
    let expect_elems = model.input_elems();
    let max_frame = opts.max_frame;
    let reader_thread = {
        let batcher = Arc::clone(&batcher);
        std::thread::spawn(move || {
            let _shutdown = read_loop(reader, 0, expect_elems, max_frame, &batcher);
            // single-stream mode: EOF and shutdown both end the server
            batcher.close();
        })
    };
    let mut sink = StreamSink { w: writer };
    let stats = dispatch_loop(model, &batcher, opts, &mut sink);
    reader_thread.join().map_err(|_| anyhow::anyhow!("serve reader thread panicked"))?;
    Ok(stats)
}

/// Serve N concurrent TCP connections, each carrying the same framing,
/// all coalescing into one model. Runs until some connection sends
/// `{"cmd":"shutdown"}`; pending requests are drained first.
pub fn serve_tcp(
    model: &mut ServedModel,
    listener: TcpListener,
    opts: &ServeOptions,
) -> Result<ServeStats> {
    let addr = listener.local_addr()?;
    let batcher = Arc::new(Batcher::<Item>::new());
    let writers: Arc<Mutex<HashMap<usize, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
    let stop = Arc::new(AtomicBool::new(false));
    let reader_threads: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
        Arc::new(Mutex::new(Vec::new()));
    let expect_elems = model.input_elems();
    let max_frame = opts.max_frame;

    let accept_thread = {
        let batcher = Arc::clone(&batcher);
        let writers = Arc::clone(&writers);
        let stop = Arc::clone(&stop);
        let reader_threads = Arc::clone(&reader_threads);
        std::thread::spawn(move || {
            let mut next_conn = 0usize;
            loop {
                let stream = match listener.accept() {
                    Ok((s, _)) => s,
                    Err(e) => {
                        if !stop.load(Ordering::SeqCst) {
                            eprintln!("[serve] accept failed: {e}");
                        }
                        break;
                    }
                };
                if stop.load(Ordering::SeqCst) {
                    break; // the shutdown self-connection (or a late client)
                }
                let conn = next_conn;
                next_conn += 1;
                let write_half = match stream.try_clone() {
                    Ok(w) => w,
                    Err(e) => {
                        eprintln!("[serve] conn {conn}: clone failed: {e}");
                        continue;
                    }
                };
                writers.lock().expect("writer map lock").insert(conn, write_half);
                let batcher = Arc::clone(&batcher);
                let stop = Arc::clone(&stop);
                reader_threads.lock().expect("reader list lock").push(std::thread::spawn(
                    move || {
                        if read_loop(stream, conn, expect_elems, max_frame, &batcher) {
                            // shutdown: stop accepting, drain, and poke the
                            // accept loop awake with a throwaway connection
                            stop.store(true, Ordering::SeqCst);
                            batcher.close();
                            let _ = TcpStream::connect(addr);
                        }
                    },
                ));
            }
        })
    };

    let mut sink = TcpSink { writers: &writers };
    let stats = dispatch_loop(model, &batcher, opts, &mut sink);

    // teardown: the accept loop is already stopping (stop + self-connect
    // from the shutdown reader); unblock any reader still in read() by
    // closing its socket, then join everything
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    accept_thread.join().map_err(|_| anyhow::anyhow!("serve accept thread panicked"))?;
    for w in writers.lock().expect("writer map lock").values() {
        let _ = w.shutdown(Shutdown::Both);
    }
    let handles: Vec<_> = reader_threads.lock().expect("reader list lock").drain(..).collect();
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("serve reader thread panicked"))?;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_accepts_the_protocol_shapes() {
        let ok = parse_request(br#"{"id": 7, "image": [1.5, -2.0]}"#, 2).unwrap();
        match ok {
            Parsed::Req { id, image } => {
                assert_eq!(id, 7);
                assert_eq!(image, vec![1.5, -2.0]);
            }
            Parsed::Shutdown => panic!("not a shutdown"),
        }
        assert!(matches!(
            parse_request(br#"{"cmd": "shutdown"}"#, 2).unwrap(),
            Parsed::Shutdown
        ));
    }

    #[test]
    fn parse_request_rejects_malformed_payloads_with_context() {
        let (id, e) = parse_request(b"\xff\xfe", 2).unwrap_err();
        assert!(id.is_none() && e.contains("UTF-8"), "{e}");
        let (id, e) = parse_request(b"{not json", 2).unwrap_err();
        assert!(id.is_none() && e.contains("JSON"), "{e}");
        let (id, e) = parse_request(br#"{"image": [1]}"#, 1).unwrap_err();
        assert!(id.is_none() && e.contains("id"), "{e}");
        let (id, e) = parse_request(br#"{"id": -3, "image": [1]}"#, 1).unwrap_err();
        assert!(id.is_none() && e.contains("id"), "negative id: {e}");
        let (id, e) = parse_request(br#"{"id": 4}"#, 1).unwrap_err();
        assert_eq!(id, Some(4));
        assert!(e.contains("image"), "{e}");
        let (id, e) = parse_request(br#"{"id": 4, "image": [1, 2, 3]}"#, 2).unwrap_err();
        assert_eq!(id, Some(4), "length mismatch keeps the id");
        assert!(e.contains("3 elements") && e.contains('2'), "{e}");
        let (id, e) = parse_request(br#"{"cmd": "reboot"}"#, 2).unwrap_err();
        assert!(id.is_none() && e.contains("reboot"), "{e}");
    }

    #[test]
    fn argmax_takes_the_first_maximum() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[5.0, 5.0, 1.0]), 0, "ties break to the lowest index");
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn response_logits_round_trip_bit_exactly_through_json() {
        // f32 -> f64 is exact, and Json prints f64 shortest-round-trip:
        // the client recovers the served bits (the contract tests/serve.rs
        // leans on end to end)
        let logits = [1.0f32, -0.33333334, f32::MIN_POSITIVE, 7.21e-30, -0.0];
        let resp = response_json(9, 0, 4, 123, &logits);
        let back = Json::parse(&resp.to_string_compact()).unwrap();
        let got = back.get("logits").unwrap().f32s().unwrap();
        for (a, b) in logits.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        assert_eq!(back.get("id").and_then(|v| v.as_f64()), Some(9.0));
        assert_eq!(back.get("batch").and_then(|v| v.as_f64()), Some(4.0));
        let err = error_json(None, "boom").to_string_compact();
        assert!(err.contains("null") && err.contains("boom"), "{err}");
    }
}
