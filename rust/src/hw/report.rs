//! Human-readable generators for Table V, Table VI, Fig. 2 and Eq. 12.

use super::counter::{self, training_energy, EnergyBreakdown};
use super::units::{table_v, Arithmetic, EnergyModel};
use crate::mls::format::EmFormat;
use crate::nn::zoo::network;

/// Table V — MAC-unit energy (pJ per op == mW at 1 GHz).
pub fn table5(em: &EnergyModel) -> String {
    let mut out = String::new();
    out.push_str("Table V — power of MAC units (mW @ 1 GHz == pJ/op), TSMC 65nm calibration\n");
    out.push_str(&format!("{:<28} {:>9} {:>10}\n", "Operation", "MUL", "LocalAcc"));
    let rows: &[(&str, f64, f64)] = &[
        ("Full Precision", table_v::FP32_MUL, table_v::FP32_ACC),
        ("8-bit FP [HFP8]", table_v::FP8_MUL, table_v::FP32_ACC),
        ("8-bit INT [FullINT]", table_v::INT8_MUL, table_v::INT_ACC),
        ("Ours <2,4> (FP7)", table_v::MLS_MUL, table_v::INT_ACC),
    ];
    for (name, mul, acc) in rows {
        out.push_str(&format!("{name:<28} {mul:>9.3} {acc:>10.3}\n"));
    }
    out.push_str("-- modeled (scaling-law) extrapolations --\n");
    for fmt in [EmFormat::new(2, 1), EmFormat::new(1, 1), EmFormat::new(2, 3)] {
        let mul = em.mul(Arithmetic::Mls(fmt)).pj;
        let reg = crate::arith::bitwidth::register_bits(fmt, 9);
        let acc = em.local_acc(Arithmetic::Mls(fmt), reg).pj;
        out.push_str(&format!(
            "{:<28} {mul:>9.3} {acc:>10.3}   (i{reg} accumulator)\n",
            format!("Ours <{},{}>", fmt.e, fmt.m)
        ));
    }
    out
}

/// Table VI — detailed training energy for one network under fp32 vs MLS.
pub fn table6(net_name: &str, batch: usize, fmt: EmFormat, em: &EnergyModel) -> anyhow::Result<String> {
    let net = network(net_name)?;
    let full = training_energy(&net, batch, Arithmetic::FullPrecision, em);
    let ours = training_energy(&net, batch, Arithmetic::Mls(fmt), em);
    let mut out = String::new();
    out.push_str(&format!(
        "Table VI — training energy per sample, {} (batch {} amortization)\n",
        net_name, batch
    ));
    out.push_str(&format!("== full precision ==  total {:>10.1} uJ\n", full.total_uj()));
    out.push_str(&render_rows(&full));
    out.push_str(&format!(
        "== ours <{},{}>   ==  total {:>10.1} uJ\n",
        fmt.e, fmt.m, ours.total_uj()
    ));
    out.push_str(&render_rows(&ours));
    out.push_str(&format!(
        "efficiency ratio: {:.2}x (paper: 10.2x for ResNet-34)\n",
        full.total_uj() / ours.total_uj()
    ));
    Ok(out)
}

fn render_rows(bd: &EnergyBreakdown) -> String {
    let mut out = String::new();
    out.push_str(&format!("{:<12} {:<16} {:>12} {:>12}\n", "Op Name", "Op Type", "Amount", "Energy/uJ"));
    for r in &bd.rows {
        out.push_str(&format!(
            "{:<12} {:<16} {:>12.3e} {:>12.2}\n",
            r.op_name, r.op_type, r.amount, r.energy_uj
        ));
    }
    out
}

/// Fig. 2 — normalized 3x3-conv energy (and accuracy drops when the caller
/// supplies measured ones from the Table II runs).
pub fn fig2(
    net_name: &str,
    batch: usize,
    fmt: EmFormat,
    em: &EnergyModel,
    acc_drops: Option<&[(String, f64)]>,
) -> anyhow::Result<String> {
    let net = network(net_name)?;
    let frameworks = [
        Arithmetic::FullPrecision,
        Arithmetic::Fp8,
        Arithmetic::Int8,
        Arithmetic::Mls(fmt),
    ];
    let energies: Vec<(String, f64)> = frameworks
        .iter()
        .map(|&a| {
            (counter::framework_name(a), training_energy(&net, batch, a, em).conv_uj())
        })
        .collect();
    let ours = energies.last().unwrap().1;
    let mut out = String::new();
    out.push_str(&format!(
        "Fig. 2 — conv energy normalized to ours ({}, {})\n",
        net_name,
        counter::framework_name(Arithmetic::Mls(fmt))
    ));
    out.push_str(&format!("{:<12} {:>14} {:>12}\n", "framework", "energy (norm)", "acc drop"));
    for (name, e) in &energies {
        let drop = acc_drops
            .and_then(|d| d.iter().find(|(n, _)| n == name))
            .map(|(_, v)| format!("{v:+.2}%"))
            .unwrap_or_else(|| "-".to_string());
        out.push_str(&format!("{name:<12} {:>14.2} {drop:>12}\n", e / ours));
    }
    out.push_str("(paper Fig. 2: FP32 ~11.5x, FP8 ~2x ours; Int8 slightly below ours\n");
    out.push_str(" with a catastrophic accuracy drop — see Table II runs)\n");
    Ok(out)
}

/// Eq. 12 — the single-conv efficiency ratio.
pub fn eq12(em: &EnergyModel, fmt: EmFormat) -> String {
    format!(
        "Eq. 12 — single 3x3-conv energy-efficiency ratio r = {:.2} (paper: ~11.5)\n",
        counter::eq12_ratio(em, fmt, 3)
    )
}

/// Abstract-band ratios across all paper models.
pub fn ratios(batch: usize, fmt: EmFormat, em: &EnergyModel) -> anyhow::Result<String> {
    let mut out = String::new();
    out.push_str("Whole-training efficiency ratios (paper abstract: 8.3-10.2x vs fp32, 1.9-2.3x vs fp8)\n");
    out.push_str(&format!("{:<12} {:>10} {:>10}\n", "model", "vs fp32", "vs fp8"));
    for name in ["resnet18", "resnet34", "vgg16", "googlenet", "resnet20"] {
        let net = network(name)?;
        let (a, b) = counter::efficiency_ratios(&net, batch, fmt, em);
        out.push_str(&format!("{name:<12} {a:>9.2}x {b:>9.2}x\n"));
    }
    Ok(out)
}

/// Table I — op amounts per sample for the paper's two showcase networks.
pub fn table1(batch: usize) -> anyhow::Result<String> {
    let mut out = String::new();
    out.push_str("Table I — training op counts per sample (divided by batch size)\n");
    out.push_str(&format!(
        "{:<22} {:>14} {:>14}\n",
        "Op", "ResNet18", "GoogleNet"
    ));
    let r = counter::ops(&network("resnet18")?, batch);
    let g = counter::ops(&network("googlenet")?, batch);
    let fwd = |t: &crate::nn::ops::TrainingOps| t.total_conv_macs() / 3.0; // approx fwd share
    let rows: Vec<(&str, f64, f64)> = vec![
        ("Conv-F Mul&Add", fwd(&r), fwd(&g)),
        ("Conv-B Mul&Add", r.total_conv_macs() - fwd(&r), g.total_conv_macs() - fwd(&g)),
        ("BN Mul&Add", 9.5 * r.bn_elements, 9.5 * g.bn_elements),
        ("FC Mul&Add", r.fc_macs, g.fc_macs),
        ("EW-Add", r.ewadd_elements, g.ewadd_elements),
        ("SGD Update", r.sgd_params, g.sgd_params),
        ("DQ elements", r.dq_elements(), g.dq_elements()),
    ];
    for (name, a, b) in rows {
        out.push_str(&format!("{name:<22} {a:>14.3e} {b:>14.3e}\n"));
    }
    out.push_str("(paper Table I: Conv-F 1.88e9 / 1.58e9, Conv-B 4.22e9 / 3.05e9, ...)\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reports_render() {
        let em = EnergyModel::fitted();
        let fmt = EmFormat::new(2, 4);
        assert!(table5(&em).contains("2.311"));
        let t6 = table6("resnet34", 64, fmt, &em).unwrap();
        assert!(t6.contains("efficiency ratio"));
        let f2 = fig2("resnet18", 64, fmt, &em, None).unwrap();
        assert!(f2.contains("fp32"));
        assert!(eq12(&em, fmt).contains("Eq. 12"));
        assert!(ratios(64, fmt, &em).unwrap().contains("googlenet"));
        assert!(table1(64).unwrap().contains("ResNet18"));
    }

    #[test]
    fn fig2_accepts_measured_drops() {
        let em = EnergyModel::fitted();
        let drops = vec![("fp32".to_string(), 0.0), ("mls<2,4>".to_string(), 0.9)];
        let f2 = fig2("resnet18", 64, EmFormat::new(2, 4), &em, Some(&drops)).unwrap();
        assert!(f2.contains("+0.90%"));
    }
}
