//! Whole-network training-energy accounting (paper Table VI).
//!
//! Converts the analytic op amounts of [`crate::nn::ops`] into per-op-type
//! energy rows under a given arithmetic framework:
//!
//! * `FullPrecision` — f32 MUL + f32 ACC everywhere (the GPU baseline),
//! * `Fp8` — 8-bit FP MUL, f32 local accumulation (HFP8 [14], Fig. 1 (a)),
//! * `Int8` — 8-bit INT MUL, integer accumulation and integer tree
//!   (FullINT [12]; cheap but with the Table II accuracy collapse),
//! * `Mls(fmt)` — our unit: low-bit MUL, integer LocalACC sized by the
//!   Sec. V-C analysis, shift-add group scaling, float adder tree, plus
//!   the DQ and EW-add rescale overheads the paper charges itself.

use super::units::{Arithmetic, EnergyModel};
use crate::arith::bitwidth;
use crate::mls::format::EmFormat;
use crate::nn::ops::{count_training_ops, TrainingOps};
use crate::nn::zoo::Network;

/// One Table VI row.
#[derive(Clone, Debug)]
pub struct EnergyRow {
    /// section, e.g. "Conv", "BN", "DQ"
    pub op_name: &'static str,
    /// op type, e.g. "FloatMul", "IntAdd", "FP7Mul"
    pub op_type: String,
    pub amount: f64,
    pub energy_uj: f64,
}

/// Full breakdown for one (network, framework) pair.
#[derive(Clone, Debug)]
pub struct EnergyBreakdown {
    pub network: String,
    pub framework: String,
    pub rows: Vec<EnergyRow>,
}

impl EnergyBreakdown {
    pub fn total_uj(&self) -> f64 {
        self.rows.iter().map(|r| r.energy_uj).sum()
    }

    /// Energy of the conv section only (the Fig. 2 comparison).
    pub fn conv_uj(&self) -> f64 {
        self.rows
            .iter()
            .filter(|r| r.op_name == "Conv" || r.op_name == "DQ")
            .map(|r| r.energy_uj)
            .sum()
    }
}

fn uj(amount: f64, pj_per_op: f64) -> f64 {
    amount * pj_per_op * 1e-6
}

/// Compute the Table VI breakdown. `batch` amortizes weight-side work.
pub fn training_energy(
    net: &Network,
    batch: usize,
    arith: Arithmetic,
    em: &EnergyModel,
) -> EnergyBreakdown {
    let t = count_training_ops(net, batch);
    let mut rows: Vec<EnergyRow> = Vec::new();
    let mut push = |op_name, op_type: String, amount: f64, pj: f64| {
        if amount > 0.0 {
            rows.push(EnergyRow { op_name, op_type, amount, energy_uj: uj(amount, pj) });
        }
    };

    let fmul = em.float_mul().pj;
    let fadd = em.float_add().pj;

    match arith {
        Arithmetic::FullPrecision => {
            let m = t.total_conv_macs();
            push("Conv", "FloatMul".into(), m, fmul);
            push("Conv", "FloatAdd".into(), m, fadd);
        }
        Arithmetic::Fp8 => {
            push("Conv", "FP8Mul".into(), t.conv_macs_quantized, em.mul(arith).pj);
            // float local accumulation (E=5 products do not fit integers)
            push("Conv", "FloatAcc".into(), t.conv_macs_quantized,
                 em.local_acc(arith, 32).pj);
            push("Conv", "FloatMul(first)".into(), t.conv_macs_unquantized, fmul);
            push("Conv", "FloatAdd(first)".into(), t.conv_macs_unquantized, fadd);
            // the FP8 frameworks also rescale/convert per tensor; charge the
            // same DQ overhead as ours for a fair comparison
            push("DQ", "FloatMul".into(), 4.0 * t.dq_elements(), fmul);
            push("DQ", "FloatAdd".into(), 2.0 * t.dq_elements(), fadd);
        }
        Arithmetic::Int8 => {
            push("Conv", "INT8Mul".into(), t.conv_macs_quantized, em.mul(arith).pj);
            push("Conv", "IntAdd".into(), t.conv_macs_quantized, em.local_acc(arith, 32).pj);
            // FullINT keeps the whole datapath integer, including the tree
            push("Conv", "IntTreeAdd".into(), t.tree_adds, em.local_acc(arith, 32).pj);
            push("Conv", "FloatMul(first)".into(), t.conv_macs_unquantized, fmul);
            push("Conv", "FloatAdd(first)".into(), t.conv_macs_unquantized, fadd);
            push("DQ", "FloatMul".into(), 4.0 * t.dq_elements(), fmul);
            push("DQ", "FloatAdd".into(), 2.0 * t.dq_elements(), fadd);
        }
        Arithmetic::Mls(fmt) => {
            let reg = bitwidth::register_bits(fmt, 9);
            let mul_name = format!("FP{}Mul", 1 + fmt.e + fmt.m); // e.g. FP7Mul for <2,4>
            push("Conv", mul_name, t.conv_macs_quantized, em.mul(arith).pj);
            push("Conv", "IntAdd".into(), t.conv_macs_quantized, em.local_acc(arith, reg).pj);
            push("Conv", "GroupScale".into(), t.group_scale_ops, em.group_scale().pj);
            push("Conv", "FloatAdd".into(), t.tree_adds, em.tree_add().pj);
            push("Conv", "FloatMul(first)".into(), t.conv_macs_unquantized, fmul);
            push("Conv", "FloatAdd(first)".into(), t.conv_macs_unquantized, fadd);
            push("DQ", "FloatMul".into(), 4.0 * t.dq_elements(), fmul);
            push("DQ", "FloatAdd".into(), 2.0 * t.dq_elements(), fadd);
            // MLS EW-add needs a tensor-scale alignment multiply
            push("EW-Add", "FloatMul".into(), t.ewadd_elements, fmul);
        }
    }

    // framework-independent fp32 sections (paper Sec. VI-E)
    push("BN", "FloatMul".into(), 9.0 * t.bn_elements, fmul);
    push("BN", "FloatAdd".into(), 10.0 * t.bn_elements, fadd);
    push("FC", "FloatMul".into(), t.fc_macs, fmul);
    push("FC", "FloatAdd".into(), t.fc_macs, fadd);
    push("SGD Update", "FloatMul".into(), 3.0 * t.sgd_params, fmul);
    push("SGD Update", "FloatAdd".into(), 2.0 * t.sgd_params, fadd);
    push("EW-Add", "FloatAdd".into(), t.ewadd_elements, fadd);

    EnergyBreakdown {
        network: net.name.to_string(),
        framework: framework_name(arith),
        rows,
    }
}

pub fn framework_name(arith: Arithmetic) -> String {
    match arith {
        Arithmetic::FullPrecision => "fp32".to_string(),
        Arithmetic::Fp8 => "fp8".to_string(),
        Arithmetic::Int8 => "int8".to_string(),
        Arithmetic::Mls(f) => format!("mls<{},{}>", f.e, f.m),
    }
}

/// Per-3x3-conv energy-efficiency ratio of the MLS unit vs full precision
/// (paper Eq. 12 — evaluates to ~11.5).
pub fn eq12_ratio(em: &EnergyModel, fmt: EmFormat, k: usize) -> f64 {
    let k2 = (k * k) as f64;
    // per tree output: K*K MULs + K*K local accs + 1 tree add (+1 scale)
    let full = em.float_mul().pj * k2 + em.float_add().pj * k2 + em.tree_add().pj;
    let reg = bitwidth::register_bits(fmt, k * k);
    let ours = em.mul(Arithmetic::Mls(fmt)).pj * k2
        + em.local_acc(Arithmetic::Mls(fmt), reg).pj * k2
        + em.group_scale().pj
        + em.tree_add().pj;
    full / ours
}

/// Convenience: the ratios the abstract claims (vs fp32 and vs fp8).
pub fn efficiency_ratios(net: &Network, batch: usize, fmt: EmFormat, em: &EnergyModel) -> (f64, f64) {
    let full = training_energy(net, batch, Arithmetic::FullPrecision, em).total_uj();
    let fp8 = training_energy(net, batch, Arithmetic::Fp8, em).total_uj();
    let ours = training_energy(net, batch, Arithmetic::Mls(fmt), em).total_uj();
    (full / ours, fp8 / ours)
}

/// Re-export for callers that need the raw amounts.
pub fn ops(net: &Network, batch: usize) -> TrainingOps {
    count_training_ops(net, batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo::network;

    fn em() -> EnergyModel {
        EnergyModel::fitted()
    }

    #[test]
    fn eq12_matches_paper() {
        // paper Eq. 12: ~11.5x for a single 3x3 convolution
        let r = eq12_ratio(&em(), EmFormat::new(2, 4), 3);
        assert!((10.5..12.5).contains(&r), "eq12 ratio {r}");
    }

    #[test]
    fn table6_resnet34_ratio_in_paper_band() {
        // paper Sec. VI-E: 32000 / 3130 = 10.2x for ResNet-34; overall
        // claim 8.3 ~ 10.2x. Our reproduction must land in a band around it.
        let net = network("resnet34").unwrap();
        let (vs_fp32, vs_fp8) = efficiency_ratios(&net, 64, EmFormat::new(2, 4), &em());
        assert!((8.0..11.5).contains(&vs_fp32), "vs fp32: {vs_fp32}");
        assert!((1.7..2.6).contains(&vs_fp8), "vs fp8: {vs_fp8}");
    }

    #[test]
    fn all_models_in_abstract_band() {
        // abstract: 8.3-10.2x vs fp32, 1.9-2.3x vs fp8 "for a variety of
        // models" — our op accounting differs in the DQ/tree details, so
        // allow a modelling margin around the published bands (measured
        // values are recorded per model in EXPERIMENTS.md).
        // GoogleNet lands lower than the paper's band because its many
        // 1x1 convolutions leave no intra-group accumulation (tree adds ==
        // MACs when K == 1), which our datapath model charges at the f32
        // adder rate — see EXPERIMENTS.md for the per-model discussion.
        for name in ["resnet18", "resnet34", "vgg16", "googlenet"] {
            let net = network(name).unwrap();
            let (a, b) = efficiency_ratios(&net, 64, EmFormat::new(2, 4), &em());
            assert!((5.0..11.5).contains(&a), "{name} vs fp32 {a}");
            assert!((1.3..2.6).contains(&b), "{name} vs fp8 {b}");
        }
    }

    #[test]
    fn fp32_breakdown_dominated_by_conv() {
        let net = network("resnet34").unwrap();
        let bd = training_energy(&net, 64, Arithmetic::FullPrecision, &em());
        let conv: f64 = bd.rows.iter().filter(|r| r.op_name == "Conv").map(|r| r.energy_uj).sum();
        assert!(conv / bd.total_uj() > 0.95);
    }

    #[test]
    fn int8_cheaper_than_mls_cheaper_than_fp8() {
        // Fig. 2 ordering on conv energy: fp32 >> fp8 > ours > int8
        let net = network("resnet18").unwrap();
        let e = |a| training_energy(&net, 64, a, &em()).conv_uj();
        let fp32 = e(Arithmetic::FullPrecision);
        let fp8 = e(Arithmetic::Fp8);
        let ours = e(Arithmetic::Mls(EmFormat::new(2, 4)));
        let int8 = e(Arithmetic::Int8);
        assert!(fp32 > fp8 && fp8 > ours && ours > int8, "{fp32} {fp8} {ours} {int8}");
    }

    #[test]
    fn mls_low_bit_configs_cheaper() {
        // <2,1> (16-bit accumulator) must beat <2,4> (32-bit accumulator)
        let net = network("resnet20").unwrap();
        let e21 = training_energy(&net, 64, Arithmetic::Mls(EmFormat::new(2, 1)), &em());
        let e24 = training_energy(&net, 64, Arithmetic::Mls(EmFormat::new(2, 4)), &em());
        assert!(e21.total_uj() < e24.total_uj());
    }
}
