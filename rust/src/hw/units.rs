//! Per-operation energy model, calibrated to the paper's Table V
//! (Design Compiler, TSMC 65 nm, 1 GHz — mW at 1 GHz == pJ per op).
//!
//! Calibration points (paper Table V):
//!
//! | arithmetic            | MUL (pJ) | LocalACC (pJ) |
//! |-----------------------|----------|---------------|
//! | full precision (f32)  | 2.311    | 0.512         |
//! | 8-bit FP  (HFP8 <5,2>)| 0.105    | 0.512 (f32)   |
//! | 8-bit INT (FullINT)   | 0.155    | 0.065 (i32)   |
//! | ours (<2,4> + sign)   | 0.124    | 0.065 (i32)   |
//!
//! For formats outside the table a standard scaling law extrapolates:
//! multiplier energy grows ~quadratically with the fraction width (array
//! multiplier area) plus a linear exponent-adder term; integer adder energy
//! grows linearly in width. The law is least-squares fitted to the four
//! published MUL points at model construction (deterministic), so the
//! calibrated formats reproduce Table V within the fit residual and the
//! ablation sweeps interpolate sensibly.

use crate::mls::format::EmFormat;

/// Energy per operation in picojoules.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpEnergy {
    pub pj: f64,
}

/// The arithmetic style of a MAC datapath (drives Table V / VI rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Arithmetic {
    /// f32 multiply + f32 accumulate (the GPU baseline)
    FullPrecision,
    /// 8-bit floating point (HFP8 [14]): fp8 multiply, f32 accumulate
    Fp8,
    /// 8-bit integer (FullINT [12]): int8 multiply, i32 accumulate
    Int8,
    /// the MLS unit: low-bit multiply, i16/i32 accumulate, shift-add scale
    Mls(EmFormat),
}

/// Calibrated + modeled per-op energy table.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    /// multiplier law coefficients: pj = a*f^2 + b*e + c (f = fraction bits
    /// incl. implicit bit, e = exponent bits)
    mul_a: f64,
    mul_b: f64,
    mul_c: f64,
}

/// Published Table V constants (pJ).
pub mod table_v {
    pub const FP32_MUL: f64 = 2.311;
    pub const FP32_ACC: f64 = 0.512;
    pub const FP8_MUL: f64 = 0.105;
    pub const INT8_MUL: f64 = 0.155;
    pub const INT_ACC: f64 = 0.065;
    pub const MLS_MUL: f64 = 0.124;
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self::fitted()
    }
}

impl EnergyModel {
    /// Fit the multiplier law to the four published points:
    /// (f=24, e=8) -> 2.311; (f=3, e=5) -> 0.105; (f=8, e=0) -> 0.155;
    /// (f=5, e=2) -> 0.124.
    pub fn fitted() -> Self {
        let pts: [(f64, f64, f64); 4] = [
            (24.0, 8.0, table_v::FP32_MUL),
            (3.0, 5.0, table_v::FP8_MUL),
            (8.0, 0.0, table_v::INT8_MUL),
            (5.0, 2.0, table_v::MLS_MUL),
        ];
        // RELATIVE least squares for y = a*f^2 + b*e + c: minimize
        // sum((pred - y)/y)^2, i.e. rows scaled by 1/y, so the small
        // low-bit points are fitted as tightly as the big f32 one.
        let mut ata = [[0.0f64; 3]; 3];
        let mut aty = [0.0f64; 3];
        for &(f, e, y) in &pts {
            let row = [f * f / y, e / y, 1.0 / y];
            for i in 0..3 {
                for j in 0..3 {
                    ata[i][j] += row[i] * row[j];
                }
                aty[i] += row[i]; // target is 1 after scaling by 1/y
            }
        }
        let sol = solve3(ata, aty);
        EnergyModel { mul_a: sol[0], mul_b: sol[1], mul_c: sol[2].max(0.0) }
    }

    /// Multiplier energy for the exact calibrated arithmetics (published
    /// values, not the fit) and the law for everything else.
    pub fn mul(&self, arith: Arithmetic) -> OpEnergy {
        let pj = match arith {
            Arithmetic::FullPrecision => table_v::FP32_MUL,
            Arithmetic::Fp8 => table_v::FP8_MUL,
            Arithmetic::Int8 => table_v::INT8_MUL,
            Arithmetic::Mls(fmt) if fmt == EmFormat::new(2, 4) => table_v::MLS_MUL,
            Arithmetic::Mls(fmt) => self.mul_law(fmt.m + 1, fmt.e),
        };
        OpEnergy { pj }
    }

    fn mul_law(&self, frac_bits: u32, exp_bits: u32) -> f64 {
        (self.mul_a * (frac_bits as f64).powi(2) + self.mul_b * exp_bits as f64 + self.mul_c)
            .max(0.01)
    }

    /// Local accumulation energy: float accumulators cost the published
    /// f32 ACC; integer accumulators cost the published i32 ACC scaled
    /// linearly with register width (32-bit == the published point).
    pub fn local_acc(&self, arith: Arithmetic, register_bits: u32) -> OpEnergy {
        let pj = match arith {
            Arithmetic::FullPrecision | Arithmetic::Fp8 => table_v::FP32_ACC,
            Arithmetic::Int8 | Arithmetic::Mls(_) => {
                table_v::INT_ACC * register_bits as f64 / 32.0
            }
        };
        OpEnergy { pj }
    }

    /// Adder-tree (inter-group) addition: always floating point (Fig. 1).
    pub fn tree_add(&self) -> OpEnergy {
        OpEnergy { pj: table_v::FP32_ACC }
    }

    /// Group-wise scale (Eq. 8 shift-add): the paper prices it as one
    /// LocalACC-class integer op ("energy consumption is comparable to a
    /// LocalACC operation", Sec. VI-E).
    pub fn group_scale(&self) -> OpEnergy {
        OpEnergy { pj: table_v::INT_ACC }
    }

    /// Generic f32 ops outside the conv unit (BN, SGD, DQ, EW-add).
    pub fn float_mul(&self) -> OpEnergy {
        OpEnergy { pj: table_v::FP32_MUL }
    }

    pub fn float_add(&self) -> OpEnergy {
        OpEnergy { pj: table_v::FP32_ACC }
    }
}

/// Solve a 3x3 linear system (Gaussian elimination, partial pivoting).
fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> [f64; 3] {
    for col in 0..3 {
        let piv = (col..3)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        for row in col + 1..3 {
            let f = a[row][col] / d;
            for k in col..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0f64; 3];
    for row in (0..3).rev() {
        let mut acc = b[row];
        for k in row + 1..3 {
            acc -= a[row][k] * x[k];
        }
        x[row] = acc / a[row][row];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_points_exact() {
        let m = EnergyModel::fitted();
        assert_eq!(m.mul(Arithmetic::FullPrecision).pj, table_v::FP32_MUL);
        assert_eq!(m.mul(Arithmetic::Fp8).pj, table_v::FP8_MUL);
        assert_eq!(m.mul(Arithmetic::Int8).pj, table_v::INT8_MUL);
        assert_eq!(m.mul(Arithmetic::Mls(EmFormat::new(2, 4))).pj, table_v::MLS_MUL);
    }

    #[test]
    fn law_fits_published_points_closely() {
        // The 3-parameter law cannot reproduce all four published points
        // exactly (the fp8 multiplier is unusually cheap relative to its
        // exponent width); it is only used for NON-calibrated formats, so
        // a 50% relative residual is acceptable — calibrated formats
        // always return the published constants (test above).
        let m = EnergyModel::fitted();
        for (f, e, y) in [(24u32, 8u32, table_v::FP32_MUL), (3, 5, table_v::FP8_MUL),
                          (8, 0, table_v::INT8_MUL), (5, 2, table_v::MLS_MUL)] {
            let got = m.mul_law(f, e);
            assert!((got - y).abs() / y < 0.5, "({f},{e}): {got} vs {y}");
        }
    }

    #[test]
    fn law_monotone_in_width() {
        let m = EnergyModel::fitted();
        assert!(m.mul(Arithmetic::Mls(EmFormat::new(2, 1))).pj
            < m.mul(Arithmetic::Mls(EmFormat::new(2, 6))).pj);
    }

    #[test]
    fn accumulators() {
        let m = EnergyModel::fitted();
        assert_eq!(m.local_acc(Arithmetic::FullPrecision, 32).pj, table_v::FP32_ACC);
        assert_eq!(m.local_acc(Arithmetic::Mls(EmFormat::new(2, 4)), 32).pj, table_v::INT_ACC);
        // 16-bit accumulator (the <2,1> CIFAR config) is half the energy
        assert_eq!(m.local_acc(Arithmetic::Mls(EmFormat::new(2, 1)), 16).pj, table_v::INT_ACC / 2.0);
    }

    #[test]
    fn solve3_known_system() {
        let x = solve3([[2.0, 0.0, 0.0], [0.0, 3.0, 0.0], [1.0, 0.0, 1.0]], [4.0, 9.0, 5.0]);
        assert!((x[0] - 2.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
        assert!((x[2] - 3.0).abs() < 1e-12);
    }
}
