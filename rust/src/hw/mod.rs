//! Hardware energy model (paper Sec. VI-D/E).
//!
//! The paper evaluates its MAC unit with RTL + Design Compiler on TSMC
//! 65 nm at 1 GHz (Table V), then multiplies per-op energies by analytic
//! op counts (Table I) to obtain whole-network training energy (Table VI,
//! Fig. 2, Eq. 12). We reproduce exactly that pipeline:
//!
//! * [`units`] — per-op energies; the four published Table V measurements
//!   are calibration constants, and a fitted area/energy scaling law
//!   extrapolates other bit-widths (for the ablation sweeps),
//! * [`counter`] — op-amount accounting per layer / per network for both
//!   full-precision and MLS training (incl. the DQ overhead, BN 9M+10A,
//!   EW-add rescale — the Table VI rows),
//! * [`report`] — the Table V / Table VI / Fig. 2 / Eq. 12 generators.

pub mod counter;
pub mod report;
pub mod units;
