//! Accumulation bit-width analysis (paper Sec. V-C).
//!
//! For an `<E, M>` element format the product of two values spans
//! `2M + 2^{E+1} - 2` bits; accumulating `L` of them needs
//! `product_bits + ceil(log2(L)) + 1` (sign) bits. The analysis drives the
//! accumulator sizing of the energy model (integer vs floating local
//! accumulation is THE energy win of the paper) and is asserted against
//! the simulator's observed peaks in tests.

use crate::mls::format::EmFormat;

/// One row of the analysis table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitwidthRow {
    pub fmt: EmFormat,
    pub product_bits: u32,
    /// required accumulator bits for a group of `group_len` products
    pub required_acc_bits: u32,
    /// the power-of-two register the hardware would instantiate
    pub register_bits: u32,
    /// whether an integer accumulator suffices (vs FP8's float accum)
    pub integer_accumulation: bool,
}

/// Required accumulator bits for `group_len` accumulated products.
pub fn required_acc_bits(fmt: EmFormat, group_len: usize) -> u32 {
    let log_l = (usize::BITS - group_len.max(1).leading_zeros()) as u32;
    fmt.product_bits() + log_l + 1
}

/// The register width the design instantiates (paper: 16 for <2,1>,
/// 32 for <2,4>; FP-accumulation flagged when even 64 would not pay off).
pub fn register_bits(fmt: EmFormat, group_len: usize) -> u32 {
    let need = required_acc_bits(fmt, group_len);
    for w in [8u32, 16, 32, 64] {
        if need <= w {
            return w;
        }
    }
    64
}

/// Integer accumulation is practical when the product fits a 32-bit
/// register with accumulation headroom — the paper's criterion separating
/// the MLS format (E=2) from FP8 (E=5, 64+-bit dynamic range).
pub fn integer_accumulation_ok(fmt: EmFormat, group_len: usize) -> bool {
    required_acc_bits(fmt, group_len) <= 32
}

/// Build the analysis table for a list of formats at a given group length
/// (K*K = 9 for the 3x3 convolutions the paper evaluates).
pub fn analysis(formats: &[EmFormat], group_len: usize) -> Vec<BitwidthRow> {
    formats
        .iter()
        .map(|&fmt| BitwidthRow {
            fmt,
            product_bits: fmt.product_bits(),
            required_acc_bits: required_acc_bits(fmt, group_len),
            register_bits: register_bits(fmt, group_len),
            integer_accumulation: integer_accumulation_ok(fmt, group_len),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs() {
        // <2,4>: 14-bit products (paper Sec. V-C), 32-bit register
        let f24 = EmFormat::new(2, 4);
        assert_eq!(f24.product_bits(), 14);
        assert_eq!(register_bits(f24, 9), 32);
        assert!(integer_accumulation_ok(f24, 9));

        // <2,1>: 8-bit products, 16-bit register (Table II "ACCUM 16")
        let f21 = EmFormat::new(2, 1);
        assert_eq!(f21.product_bits(), 8);
        assert_eq!(register_bits(f21, 9), 16);

        // FP8 <5,2>: 2*2 + 2^6 - 2 = 66-bit products -> no integer accum
        let fp8 = EmFormat::new(5, 2);
        assert_eq!(fp8.product_bits(), 66);
        assert!(!integer_accumulation_ok(fp8, 9));
    }

    #[test]
    fn register_monotone_in_group_len() {
        let fmt = EmFormat::new(2, 4);
        assert!(register_bits(fmt, 9) <= register_bits(fmt, 1 << 20));
    }

    #[test]
    fn analysis_table_shape() {
        let rows = analysis(&[EmFormat::new(2, 1), EmFormat::new(2, 4), EmFormat::new(5, 2)], 9);
        assert_eq!(rows.len(), 3);
        assert!(rows[0].integer_accumulation);
        assert!(!rows[2].integer_accumulation);
    }
}
