//! Group-wise scale unit (paper Eq. 8).
//!
//! `S_p = S_g^w * S_g^a` where both factors are `<E_g, 1>` values, so the
//! product is an `<E_g+1, 2>` value whose fraction is one of
//! `{1, 1.5, 2.25} = {4, 6, 9} / 4`. The hardware applies it to the integer
//! partial sum `P` with at most two shift-adds:
//!
//! ```text
//! man = 00 :  P                      << (-exp)        (F = 4)
//! man = 01 :  P + (P >> 1)                            (F = 6)
//! man = 11 :  (P << 1) + (P >> 2)                     (F = 9)
//! ```
//!
//! We simulate it exactly as `P * F` (an exact small-integer multiply)
//! carrying the `-2` in the fixed-point exponent, which is the same number
//! the shift-add network produces.

use crate::mls::format::{exp2i, EmFormat};

/// The scale factor of one group pair in `(F, k)` form: `S_p = F/4 * 2^-k`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupScaleFactor {
    /// integer fraction x4: one of {4, 6, 9} for M_g = 1 (or {4} for M_g=0)
    pub f: i64,
    /// exponent code sum (shift amount)
    pub k: u32,
}

impl GroupScaleFactor {
    /// Combine two stored group scales (exp codes + mantissas, M_g <= 1).
    pub fn combine(w_exp: u8, w_man: u32, a_exp: u8, a_man: u32) -> Self {
        debug_assert!(w_man <= 1 && a_man <= 1, "hardware unit supports M_g <= 1");
        // (1 + mw/2)(1 + ma/2) * 4 = 4 + 2(mw + ma) + mw*ma
        let f = 4 + 2 * (w_man + a_man) as i64 + (w_man * a_man) as i64;
        GroupScaleFactor { f, k: w_exp as u32 + a_exp as u32 }
    }

    /// The float value of this scale factor.
    pub fn value(&self) -> f32 {
        self.f as f32 * 0.25 * exp2i(-(self.k as i32))
    }

    /// Apply to an integer partial sum: returns the float contribution
    /// `P * S_p * 2^(p_scale_log2)` exactly as the shift-add + tree input.
    pub fn apply(&self, p: i64, p_scale_log2: i32) -> f32 {
        // P * F is exact in i64 (F <= 9, |P| < 2^40 in any paper config);
        // the power-of-two scale merges the fixed point, the /4 and 2^-k.
        (p * self.f) as f32 * exp2i(p_scale_log2 - 2 - self.k as i32)
    }

    /// Number of adder operations the shift-add network needs (0, 1 or 2
    /// extra adds; used by the energy model — paper counts it as one
    /// LocalACC-class op).
    pub fn shift_add_ops(&self) -> u32 {
        match self.f {
            4 => 0,
            6 => 1,
            9 => 1,
            _ => 2,
        }
    }
}

/// Element-format product fixed-point helper: for partial sums produced by
/// [`crate::arith::intra::intra_group_mac`] with element format `fmt`.
pub fn apply_group_scale(p: i64, fmt: EmFormat, factor: GroupScaleFactor) -> f32 {
    factor.apply(p, 2 * fmt.emin() - 2 * fmt.m as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mls::format::{group_scale_value, quantize_group_scale};

    #[test]
    fn fraction_table() {
        assert_eq!(GroupScaleFactor::combine(0, 0, 0, 0).f, 4); // 1 * 1
        assert_eq!(GroupScaleFactor::combine(0, 1, 0, 0).f, 6); // 1.5 * 1
        assert_eq!(GroupScaleFactor::combine(0, 0, 0, 1).f, 6);
        assert_eq!(GroupScaleFactor::combine(0, 1, 0, 1).f, 9); // 1.5 * 1.5
    }

    #[test]
    fn value_matches_product_of_scales() {
        let fmt = EmFormat::new(8, 1);
        for sw in [0.3f32, 0.55, 0.8, 1.0] {
            for sa in [0.26f32, 0.5, 0.95] {
                let (cw, mw) = quantize_group_scale(sw, fmt);
                let (ca, ma) = quantize_group_scale(sa, fmt);
                let f = GroupScaleFactor::combine(cw, mw, ca, ma);
                let expect = group_scale_value(cw, mw, fmt) * group_scale_value(ca, ma, fmt);
                assert!((f.value() - expect).abs() < 1e-7, "{sw} {sa}");
            }
        }
    }

    #[test]
    fn apply_is_exact_shift_add() {
        let f = GroupScaleFactor { f: 9, k: 3 };
        // P * 9 / 4 / 8 at fixed point 2^-14
        let got = f.apply(1000, -14);
        let expect = 1000.0 * 2.25 / 8.0 * 2.0f32.powi(-14);
        assert_eq!(got, expect);
    }

    #[test]
    fn shift_add_op_counts() {
        assert_eq!(GroupScaleFactor { f: 4, k: 0 }.shift_add_ops(), 0);
        assert_eq!(GroupScaleFactor { f: 6, k: 0 }.shift_add_ops(), 1);
        assert_eq!(GroupScaleFactor { f: 9, k: 0 }.shift_add_ops(), 1);
    }
}
