//! Pass-generic conv engine: ONE geometry description ([`ConvSpec`]) and
//! ONE packed-GEMM driver ([`run_engine`]) execute all three convolutions
//! of the paper's Alg. 1 training step:
//!
//! ```text
//!   forward          Z  = Conv  (qW, qA)   [N, Co, Ho, Wo]
//!   weight gradient  dW = Conv  (qE, qA)   [Co, Ci, Kh, Kw]
//!   input gradient   dA = Conv^T(qE, qW)   [N, Ci, H,  W ]
//! ```
//!
//! All three are the same contraction
//!
//! ```text
//!   Out[u, v, oy, ox] = S_t^x S_t^y * sum_g sum_(i,j)
//!                       X[v, g, i, j] * Y[u, g, pos(oy, ox, i, j)]
//! ```
//!
//! differing only in (a) which operand plays the *stationary* role `X`
//! (packed once into MR-lane panels) vs the *gathered* role `Y` (im2col
//! row panels), and (b) the tap-position map `pos`, which [`SpecDims`]
//! parameterizes with an output `stride`, a tap `dil`ation, an input
//! zero-`ups`ampling factor, and *signed* pads:
//!
//! ```text
//!   iy_logical = oy*stride + i*dil - pad_y      (ix likewise)
//!   physical  <=>  iy_logical >= 0, divisible by ups, quotient < H
//! ```
//!
//! * **forward** — `X = qW`, `Y = qA`, `dil = ups = 1`: the plain strided
//!   conv of [`super::conv`].
//! * **weight gradient** — `X = qE` transposed to `[Co, N, Ho, Wo]`,
//!   `Y = qA` transposed to `[Ci, N, H, W]`, `stride = 1`,
//!   `dil = forward stride`: each dW tap is a stride-dilated dot of the
//!   error field against the activations, reduced over the batch by the
//!   inter-group tree (the scaling groups of E `(n, co)` and A `(n, ci)`
//!   transpose to `(co, n)` / `(ci, n)`, so group structure is preserved
//!   exactly). The engine output `[Ci, Co, Kh, Kw]` is transposed back.
//! * **input gradient** — `X = qW` transposed to `[Ci, Co, Kh, Kw]` and
//!   spatially flipped, `Y = qE` in its native layout, `stride = 1`,
//!   `ups = forward stride`, `pad = K - 1 - pad` (signed: may go negative
//!   when the forward pad reaches the kernel size): the classic transposed
//!   convolution over the zero-upsampled error field. Forward-input pixels
//!   no window ever touched fall out as exact zeros (no output-padding
//!   special case).
//!
//! The operand transpositions are bit-exact MLS relayouts
//! ([`MlsTensor::transpose01`]) — per-group scales travel with their
//! groups — so every pass runs the same microkernel, scratch arenas,
//! group-scale epilogue, adder tree, and audit counters as the forward
//! path, and is bit-identical across thread counts for the same reason
//! the forward kernel is (panels and per-row work are thread-independent,
//! counters merge by sum/max). `rust/tests/conv_fuzz.rs` fuzzes the
//! backward passes against an f32 reference backward conv across worker
//! counts {1, 2, 8}.
//!
//! A faithful Alg. 1 property the engine inherits from the geometry: the
//! executed `mul_ops`/`int_add_ops` of the three passes are **equal** for
//! every layer shape (the in-bounds tap sets are bijective re-indexings
//! of each other), which `spec::tests` and the fuzz pin down.

use super::conv::{lowbit_conv_threaded, ConvDims, ConvOutput};
use super::gemm;
use super::group_scale::GroupScaleFactor;
use super::pack;
use super::planes::DecodedPlanes;
use crate::mls::format::EmFormat;
use crate::mls::quantizer::FusedQuant;
use crate::mls::{Grouping, MlsTensor};
use crate::util::parallel::{self, DisjointWriter};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Which Alg. 1 conv this execution is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvPass {
    /// `Conv(qW, qA)` -> `[N, Co, Ho, Wo]`
    Forward,
    /// `Conv(qE, qA)` -> `[Co, Ci, Kh, Kw]`
    WeightGrad,
    /// `Conv^T(qE, qW)` -> `[N, Ci, H, W]`
    InputGrad,
}

/// The geometry of ONE conv layer, shared by all three Alg. 1 passes:
/// stride, padding, kernel spatial dims, and the forward input spatial
/// dims (which the output shape of the input-gradient pass needs — they
/// are not recoverable from `(Ho, stride)` alone).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvSpec {
    pub stride: usize,
    pub pad: usize,
    pub kh: usize,
    pub kw: usize,
    pub in_h: usize,
    pub in_w: usize,
}

impl ConvSpec {
    pub fn new(stride: usize, pad: usize, kh: usize, kw: usize, in_h: usize, in_w: usize) -> Self {
        assert!(stride >= 1, "stride must be >= 1");
        assert!(kh >= 1 && kw >= 1, "kernel dims must be >= 1");
        assert!(
            in_h + 2 * pad >= kh && in_w + 2 * pad >= kw,
            "kernel {kh}x{kw} does not fit the padded {in_h}x{in_w} input"
        );
        ConvSpec { stride, pad, kh, kw, in_h, in_w }
    }

    /// Derive the layer spec from the forward operand shapes.
    pub fn of_forward(w: &MlsTensor, a: &MlsTensor, stride: usize, pad: usize) -> Self {
        assert_eq!(w.shape.len(), 4, "weights must be [Co, Ci, Kh, Kw]");
        assert_eq!(a.shape.len(), 4, "activations must be [N, Ci, H, W]");
        Self::new(stride, pad, w.shape[2], w.shape[3], a.shape[2], a.shape[3])
    }

    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// `Conv(qW, qA)`: thin wrapper over [`Self::run`].
    pub fn forward(&self, qw: &MlsTensor, qa: &MlsTensor, threads: usize) -> ConvOutput {
        self.run(ConvPass::Forward, qw, qa, threads)
    }

    /// `Conv(qE, qA)` -> `dW [Co, Ci, Kh, Kw]`: thin wrapper over [`Self::run`].
    pub fn weight_grad(&self, qe: &MlsTensor, qa: &MlsTensor, threads: usize) -> ConvOutput {
        self.run(ConvPass::WeightGrad, qe, qa, threads)
    }

    /// `Conv^T(qE, qW)` -> `dA [N, Ci, H, W]`: thin wrapper over [`Self::run`].
    pub fn input_grad(&self, qe: &MlsTensor, qw: &MlsTensor, threads: usize) -> ConvOutput {
        self.run(ConvPass::InputGrad, qe, qw, threads)
    }

    /// Execute one Alg. 1 pass on the packed-GEMM engine. Operand roles
    /// per pass: `Forward (qW, qA)`, `WeightGrad (qE, qA)`,
    /// `InputGrad (qE, qW)`. The result INCLUDES the tensor scales
    /// `S_t^x * S_t^y`, so it is directly comparable with the float
    /// convolution of the dequantized operands, and carries the same five
    /// hardware-audit counters as the forward kernel.
    pub fn run(&self, pass: ConvPass, x: &MlsTensor, y: &MlsTensor, threads: usize) -> ConvOutput {
        let (ho, wo) = (self.out_h(), self.out_w());
        match pass {
            ConvPass::Forward => {
                assert_eq!(x.shape.len(), 4, "weights must be [Co, Ci, Kh, Kw]");
                assert_eq!(y.shape.len(), 4, "activations must be [N, Ci, H, W]");
                assert_eq!(
                    [x.shape[2], x.shape[3]],
                    [self.kh, self.kw],
                    "forward weights do not match the spec kernel dims"
                );
                assert_eq!(
                    [y.shape[2], y.shape[3]],
                    [self.in_h, self.in_w],
                    "forward activations do not match the spec input dims"
                );
                lowbit_conv_threaded(x, y, self.stride, self.pad, threads)
            }
            ConvPass::WeightGrad => {
                let (qe, qa) = (x, y);
                assert_eq!(qe.shape.len(), 4, "error field must be [N, Co, Ho, Wo]");
                assert_eq!(qa.shape.len(), 4, "activations must be [N, Ci, H, W]");
                assert_eq!(qe.cfg.grouping, Grouping::Both);
                assert_eq!(qa.cfg.grouping, Grouping::Both);
                let [n_n, co_n, e_h, e_w] = [qe.shape[0], qe.shape[1], qe.shape[2], qe.shape[3]];
                let [a_n, ci_n, a_h, a_w] = [qa.shape[0], qa.shape[1], qa.shape[2], qa.shape[3]];
                assert_eq!(n_n, a_n, "error/activation batch mismatch");
                assert_eq!([e_h, e_w], [ho, wo], "error field does not match the spec output dims");
                assert_eq!(
                    [a_h, a_w],
                    [self.in_h, self.in_w],
                    "activations do not match the spec input dims"
                );
                // E^T [Co, N, Ho, Wo] is the stationary operand (its taps
                // are the reduction), A^T [Ci, N, H, W] the gathered one;
                // the `(n, *)` scaling groups become `(*, n)` groups, so
                // the engine's group-scale epilogue sees the exact
                // quantization structure of the original tensors.
                let et = qe.transpose01();
                let at = qa.transpose01();
                let ep = DecodedPlanes::of_threaded(&et, threads);
                let ap = DecodedPlanes::of_threaded(&at, threads);
                let d = self.wgrad_dims(n_n);
                let out = run_engine(&et, &ep, &at, &ap, ci_n, co_n, d, threads);
                transpose01_output(out)
            }
            ConvPass::InputGrad => {
                let (qe, qw) = (x, y);
                assert_eq!(qe.shape.len(), 4, "error field must be [N, Co, Ho, Wo]");
                assert_eq!(qw.shape.len(), 4, "weights must be [Co, Ci, Kh, Kw]");
                assert_eq!(qe.cfg.grouping, Grouping::Both);
                assert_eq!(qw.cfg.grouping, Grouping::Both);
                let [n_n, co_n, e_h, e_w] = [qe.shape[0], qe.shape[1], qe.shape[2], qe.shape[3]];
                let [w_co, ci_n, w_kh, w_kw] = [qw.shape[0], qw.shape[1], qw.shape[2], qw.shape[3]];
                assert_eq!(co_n, w_co, "error/weight channel mismatch");
                assert_eq!([e_h, e_w], [ho, wo], "error field does not match the spec output dims");
                assert_eq!(
                    [w_kh, w_kw],
                    [self.kh, self.kw],
                    "weights do not match the spec kernel dims"
                );
                // W transposed to [Ci, Co, Kh, Kw] AND spatially flipped is
                // the stationary operand; E stays in its native layout
                // [N, Co, Ho, Wo] and is gathered through the
                // zero-upsampled view (ups = stride) with the transposed
                // pad K - 1 - p (signed: negative means cropping, which
                // happens when the forward pad reaches the kernel size).
                let wt = qw.transpose01_flip23();
                let wp = DecodedPlanes::of_threaded(&wt, threads);
                let ep = DecodedPlanes::of_threaded(qe, threads);
                let d = self.dgrad_dims(co_n);
                run_engine(&wt, &wp, qe, &ep, n_n, ci_n, d, threads)
            }
        }
    }

    /// Engine geometry of the forward pass (`X = qW [Co, Ci, Kh, Kw]`,
    /// `Y = qA [N, Ci, H, W]`).
    pub(crate) fn forward_dims(&self, ci_n: usize) -> SpecDims {
        SpecDims {
            g_n: ci_n,
            kh: self.kh,
            kw: self.kw,
            h: self.in_h,
            wi: self.in_w,
            ho: self.out_h(),
            wo: self.out_w(),
            stride: self.stride,
            dil: 1,
            ups: 1,
            pad_y: self.pad as isize,
            pad_x: self.pad as isize,
        }
    }

    /// Forward-only engine-view entry: run the Eq. 7 packed-GEMM forward
    /// of this conv over caller-owned quantized operands, pre-packed
    /// stationary panels, and a caller-owned output buffer. This is the
    /// whole per-request arithmetic of the inference server — with the
    /// weight planes and panels cached per model, a served forward calls
    /// exactly this and nothing else — and the same entry the arena
    /// trainer's forward uses, so served results are bit-identical to
    /// training-path forwards by construction (values and all five audit
    /// counters).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn forward_view(
        &self,
        wv: OperandView,
        wp: &DecodedPlanes,
        av: OperandView,
        ap: &DecodedPlanes,
        n: usize,
        co_n: usize,
        ci_n: usize,
        threads: usize,
        panels: &pack::PackedWeights,
        z: &mut [f32],
    ) -> EngineAudit {
        run_engine_view(wv, wp, av, ap, n, co_n, self.forward_dims(ci_n), threads, panels, z)
    }

    /// Engine geometry of the weight-gradient pass (`X = qE^T`,
    /// `Y = qA^T`, batch as the reduction group).
    pub(crate) fn wgrad_dims(&self, n_n: usize) -> SpecDims {
        SpecDims {
            g_n: n_n,
            kh: self.out_h(),
            kw: self.out_w(),
            h: self.in_h,
            wi: self.in_w,
            ho: self.kh,
            wo: self.kw,
            stride: 1,
            dil: self.stride,
            ups: 1,
            pad_y: self.pad as isize,
            pad_x: self.pad as isize,
        }
    }

    /// Engine geometry of the input-gradient pass (`X = qW^T` flipped,
    /// `Y = qE` native, zero-upsampled by the forward stride).
    pub(crate) fn dgrad_dims(&self, co_n: usize) -> SpecDims {
        SpecDims {
            g_n: co_n,
            kh: self.kh,
            kw: self.kw,
            h: self.out_h(),
            wi: self.out_w(),
            ho: self.in_h,
            wo: self.in_w,
            stride: 1,
            dil: 1,
            ups: self.stride,
            pad_y: self.kh as isize - 1 - self.pad as isize,
            pad_x: self.kw as isize - 1 - self.pad as isize,
        }
    }
}

/// Swap the two leading axes of an engine result (`[Ci, Co, Kh, Kw]` ->
/// `[Co, Ci, Kh, Kw]` for the weight-gradient pass). Pure f32 relayout;
/// audit counters are layout-independent and carry through unchanged.
fn transpose01_output(out: ConvOutput) -> ConvOutput {
    let [d0, d1, d2, d3] = out.shape;
    let mut z = vec![0.0f32; out.z.len()];
    transpose01_copy(&out.z, d0, d1, d2 * d3, &mut z);
    ConvOutput {
        z,
        shape: [d1, d0, d2, d3],
        peak_acc_bits: out.peak_acc_bits,
        mul_ops: out.mul_ops,
        int_add_ops: out.int_add_ops,
        float_add_ops: out.float_add_ops,
        group_scale_ops: out.group_scale_ops,
    }
}

/// Swap the two leading axes of a `[d0, d1, inner]` f32 buffer into a
/// caller-owned destination (the arena-mode weight-gradient fixup reuses
/// its destination across steps). `dst` must hold exactly
/// `d0 * d1 * inner` elements; every one is overwritten.
pub(crate) fn transpose01_copy(src: &[f32], d0: usize, d1: usize, inner: usize, dst: &mut [f32]) {
    assert_eq!(src.len(), d0 * d1 * inner, "transpose01_copy: src shape mismatch");
    assert_eq!(dst.len(), src.len(), "transpose01_copy: dst length mismatch");
    for i0 in 0..d0 {
        for i1 in 0..d1 {
            let s = (i0 * d1 + i1) * inner;
            let d = (i1 * d0 + i0) * inner;
            dst[d..d + inner].copy_from_slice(&src[s..s + inner]);
        }
    }
}

/// Geometry of one pass-generic engine execution over operands in the
/// canonical layouts `X [V, G, Kh, Kw]` (stationary) / `Y [U, G, H, W]`
/// (gathered): tap `(i, j)` of output pixel `(oy, ox)` reads the logical
/// input position `oy*stride + i*dil - pad_y` (resp. `ox`/`j`/`pad_x`),
/// which is physical iff it is non-negative, divisible by `ups`, and its
/// quotient lies inside the physical `[H, W]` plane. Exactly one of
/// `stride` and `ups` may exceed 1 (the three Alg. 1 passes never need
/// both).
#[derive(Clone, Copy, Debug)]
pub(crate) struct SpecDims {
    /// reduction groups (inter-group tree width): fwd `Ci`, wgrad `N`,
    /// dgrad `Co`
    pub(crate) g_n: usize,
    /// taps per scaling group (one integer-accumulator segment)
    pub(crate) kh: usize,
    pub(crate) kw: usize,
    /// physical input spatial dims of the gathered operand
    pub(crate) h: usize,
    pub(crate) wi: usize,
    /// output spatial dims
    pub(crate) ho: usize,
    pub(crate) wo: usize,
    pub(crate) stride: usize,
    /// tap dilation (wgrad: the forward stride)
    pub(crate) dil: usize,
    /// input zero-upsampling factor (dgrad: the forward stride)
    pub(crate) ups: usize,
    /// signed pads (dgrad's transposed pad `K - 1 - p` may be negative)
    pub(crate) pad_y: isize,
    pub(crate) pad_x: isize,
}

impl SpecDims {
    /// The forward pass is the identity embedding of [`ConvDims`].
    pub(crate) fn forward(c: ConvDims) -> SpecDims {
        SpecDims {
            g_n: c.ci_n,
            kh: c.kh,
            kw: c.kw,
            h: c.h,
            wi: c.wi,
            ho: c.ho,
            wo: c.wo,
            stride: c.stride,
            dil: 1,
            ups: 1,
            pad_y: c.pad as isize,
            pad_x: c.pad as isize,
        }
    }
}

/// The single packed-GEMM driver all three Alg. 1 passes run through:
/// pack the stationary operand once, then per `(u, oy)` output row build
/// the im2col panel, sweep the MR x NR microkernel with the per-`(v, g)`
/// group-scale epilogue, and write pixels straight into the preallocated
/// `[U, V, Ho, Wo]` buffer. Identical to the historical forward driver —
/// only the index names generalized — so forward results (values AND all
/// five audit counters) are unchanged, and the backward passes inherit
/// panel packing, scratch-arena reuse, factor-table hoisting and
/// bit-identity across thread counts for free.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_engine(
    x: &MlsTensor,
    xp: &DecodedPlanes,
    y: &MlsTensor,
    yp: &DecodedPlanes,
    u_n: usize,
    v_n: usize,
    d: SpecDims,
    threads: usize,
) -> ConvOutput {
    let kdim = d.g_n * d.kh * d.kw;
    assert_eq!(xp.len(), v_n * kdim, "stationary planes do not match [V, G*Kh*Kw]");
    let pw = pack::pack_weights(xp, v_n, kdim, threads);
    let mut z = vec![0.0f32; u_n * v_n * d.ho * d.wo];
    let audit = run_engine_view(
        OperandView::of_tensor(x),
        xp,
        OperandView::of_tensor(y),
        yp,
        u_n,
        v_n,
        d,
        threads,
        &pw,
        &mut z,
    );
    ConvOutput {
        z,
        shape: [u_n, v_n, d.ho, d.wo],
        peak_acc_bits: audit.peak_acc_bits,
        mul_ops: audit.mul_ops,
        int_add_ops: audit.int_add_ops,
        float_add_ops: audit.float_add_ops,
        group_scale_ops: audit.group_scale_ops,
    }
}

/// The scale metadata of one engine operand: the tensor scale plus the
/// per-group scale codes the factor table is built from. Borrows from
/// either an [`MlsTensor`] or a [`FusedQuant`] slot, so the arena path
/// can drive the engine without ever materializing an element tensor.
#[derive(Clone, Copy)]
pub(crate) struct OperandView<'a> {
    pub(crate) s_t: f32,
    pub(crate) sg_exp: &'a [u8],
    pub(crate) sg_man: &'a [u32],
    pub(crate) fmt: EmFormat,
}

impl<'a> OperandView<'a> {
    pub(crate) fn of_tensor(t: &'a MlsTensor) -> Self {
        OperandView { s_t: t.s_t, sg_exp: &t.sg_exp, sg_man: &t.sg_man, fmt: t.cfg.element }
    }

    pub(crate) fn of_fused(q: &'a FusedQuant) -> Self {
        OperandView { s_t: q.s_t, sg_exp: &q.sg_exp, sg_man: &q.sg_man, fmt: q.planes.fmt }
    }
}

/// The five hardware-audit counters of one engine execution, for callers
/// that own the output buffer (see [`run_engine_view`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub(crate) struct EngineAudit {
    pub(crate) peak_acc_bits: u32,
    pub(crate) mul_ops: u64,
    pub(crate) int_add_ops: u64,
    pub(crate) float_add_ops: u64,
    pub(crate) group_scale_ops: u64,
}

/// Allocation-free core of [`run_engine`]: the stationary panels (`pw`)
/// and the `[U, V, Ho, Wo]` output buffer (`z`, fully overwritten) are
/// caller-owned, so the warm training step can reuse both across calls.
/// Per-part peak/tap counters merge through atomics (max and sum are
/// order-independent, so the merged values are bit-identical to the
/// in-order fold the allocating driver used).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_engine_view(
    xv: OperandView,
    xp: &DecodedPlanes,
    yv: OperandView,
    yp: &DecodedPlanes,
    u_n: usize,
    v_n: usize,
    d: SpecDims,
    threads: usize,
    pw: &pack::PackedWeights,
    z: &mut [f32],
) -> EngineAudit {
    debug_assert!(d.ups == 1 || d.stride == 1, "strided AND upsampled is never needed");
    assert_eq!(xv.fmt, yv.fmt, "operand formats must match");
    assert_eq!(xp.fmt, xv.fmt, "stationary planes decoded under a different format");
    assert_eq!(yp.fmt, yv.fmt, "gathered planes decoded under a different format");
    let fmt = xv.fmt;
    let st = xv.s_t * yv.s_t;
    let scale_log2 = 2 * fmt.emin() - 2 * fmt.m as i32;
    let g_n = d.g_n;

    let kdim = g_n * d.kh * d.kw;
    assert_eq!(xp.len(), v_n * kdim, "stationary planes do not match [V, G*Kh*Kw]");
    assert_eq!(yp.len(), u_n * g_n * d.h * d.wi, "gathered planes do not match [U, G, H, W]");
    assert_eq!(pw.co_n, v_n, "packed panels do not match the stationary operand");
    assert_eq!(pw.kdim, kdim, "packed panels do not match the reduction depth");
    // geometry-only half of the analytic tap count, hoisted out of the
    // per-row work (rows_ib * col_taps = a row's in-bounds window taps)
    let col_taps = gemm::col_taps(d);
    // SIMD dispatch level read once per conv so every row of this call
    // runs the same microkernel (all levels are bit-identical anyway)
    let level = crate::util::simd::active();

    let tile_len = d.ho * d.wo;
    assert_eq!(z.len(), u_n * v_n * tile_len, "output buffer does not match [U, V, Ho, Wo]");
    let writer = DisjointWriter::new(z);
    let peak_acc = AtomicI64::new(0);
    let taps_acc = AtomicU64::new(0);
    // work units are (u, oy) output rows: the im2col row panel is packed
    // once and reused by every output channel of that row
    let units = u_n * d.ho;
    parallel::for_ranges(threads, units, |lo, hi| {
        pack::with_scratch(|scratch| {
            let mut peak: i64 = 0;
            let mut taps: u64 = 0;
            let mut last_u = usize::MAX;
            for unit in lo..hi {
                let (u, oy) = (unit / d.ho, unit % d.ho);
                if u != last_u {
                    // hoist the per-(v, g) group-scale factor table — it
                    // depends on the gathered operand's leading index,
                    // never on the pixel
                    scratch.factors.clear();
                    for v in 0..v_n {
                        for g in 0..g_n {
                            let xg = v * g_n + g;
                            let yg = u * g_n + g;
                            scratch.factors.push(GroupScaleFactor::combine(
                                xv.sg_exp[xg],
                                xv.sg_man[xg],
                                yv.sg_exp[yg],
                                yv.sg_man[yg],
                            ));
                        }
                    }
                    last_u = u;
                }
                let (row_peak, rows_ib) = gemm::conv_row_packed(
                    pw, yp, scratch, u, oy, d, scale_log2, st, &writer, level,
                );
                peak = peak.max(row_peak);
                taps += rows_ib as u64 * col_taps;
            }
            peak_acc.fetch_max(peak, Ordering::Relaxed);
            taps_acc.fetch_add(taps, Ordering::Relaxed);
        })
    });
    drop(writer);

    let peak = peak_acc.load(Ordering::Relaxed);
    let taps = taps_acc.load(Ordering::Relaxed);
    let pixels = (u_n * v_n) as u64 * tile_len as u64;
    // same peak-bits semantics as the planar/legacy per-tile merge: any
    // processed (pixel, group) reports at least the 1-bit sign floor
    let peak_acc_bits = if pixels == 0 || g_n == 0 {
        0
    } else {
        64 - peak.unsigned_abs().leading_zeros() + 1
    };
    EngineAudit {
        peak_acc_bits,
        mul_ops: taps * (v_n * g_n) as u64,
        int_add_ops: taps * (v_n * g_n) as u64,
        float_add_ops: pixels * (g_n as u64 - 1),
        group_scale_ops: pixels * g_n as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::conv::{conv2d_f32_dgrad, conv2d_f32_wgrad};
    use crate::mls::quantizer::{quantize, QuantConfig, Rounding};
    use crate::util::rng::Pcg32;

    fn quantized(rng: &mut Pcg32, shape: [usize; 4], cfg: &QuantConfig) -> MlsTensor {
        let x = crate::util::prop::grouped_tensor(rng, shape);
        quantize(&x, &shape, cfg, &[])
    }

    fn assert_close(got: &[f32], want: &[f32], tag: &str) {
        assert_eq!(got.len(), want.len(), "{tag}: len");
        let scale = want.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
        for (i, (a, b)) in got.iter().zip(want).enumerate() {
            assert!((a - b).abs() / scale < 2e-4, "{tag}[{i}]: {a} vs {b} (scale {scale})");
        }
    }

    fn check_pass_triple(stride: usize, pad: usize, kh: usize, kw: usize, h: usize, wi: usize, seed: u64) {
        let mut rng = Pcg32::seeded(seed);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let (co, ci, n) = (4usize, 3usize, 2usize);
        let spec = ConvSpec::new(stride, pad, kh, kw, h, wi);
        let (ho, wo) = (spec.out_h(), spec.out_w());
        let qw = quantized(&mut rng, [co, ci, kh, kw], &cfg);
        let qa = quantized(&mut rng, [n, ci, h, wi], &cfg);
        let qe = quantized(&mut rng, [n, co, ho, wo], &cfg);
        let tag = format!("s{stride} p{pad} k{kh}x{kw} in{h}x{wi}");

        let fwd = spec.forward(&qw, &qa, 1);
        let wg = spec.weight_grad(&qe, &qa, 1);
        let dg = spec.input_grad(&qe, &qw, 1);
        assert_eq!(wg.shape, [co, ci, kh, kw], "{tag}: dW shape");
        assert_eq!(dg.shape, [n, ci, h, wi], "{tag}: dA shape");

        // Alg. 1: all three passes execute the same number of low-bit MACs
        assert_eq!(fwd.mul_ops, wg.mul_ops, "{tag}: fwd vs wgrad mul_ops");
        assert_eq!(fwd.mul_ops, dg.mul_ops, "{tag}: fwd vs dgrad mul_ops");
        assert_eq!(fwd.int_add_ops, wg.int_add_ops, "{tag}: int_add_ops");
        assert_eq!(fwd.int_add_ops, dg.int_add_ops, "{tag}: int_add_ops");

        // against the f32 reference backward convs of the dequantized
        // operands (the integer datapath is exact; only the f32 group
        // scale application and tree adds round)
        let (wg_ref, wg_shape) = conv2d_f32_wgrad(
            &qe.dequantize(),
            [n, co, ho, wo],
            &qa.dequantize(),
            [n, ci, h, wi],
            stride,
            pad,
            kh,
            kw,
            1,
        );
        assert_eq!(wg.shape, wg_shape);
        assert_close(&wg.z, &wg_ref, &format!("{tag}: dW"));
        let (dg_ref, dg_shape) = conv2d_f32_dgrad(
            &qe.dequantize(),
            [n, co, ho, wo],
            &qw.dequantize(),
            [co, ci, kh, kw],
            stride,
            pad,
            h,
            wi,
            1,
        );
        assert_eq!(dg.shape, dg_shape);
        assert_close(&dg.z, &dg_ref, &format!("{tag}: dA"));

        // bit-identity across thread counts, values AND counters
        for threads in [2usize, 8] {
            for (serial, pass, a, b) in [
                (&wg, ConvPass::WeightGrad, &qe, &qa),
                (&dg, ConvPass::InputGrad, &qe, &qw),
            ] {
                let t = spec.run(pass, a, b, threads);
                assert_eq!(t.shape, serial.shape, "{tag} t{threads}");
                for (i, (x, y)) in t.z.iter().zip(&serial.z).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "{tag} t{threads} z[{i}]");
                }
                assert_eq!(t.peak_acc_bits, serial.peak_acc_bits, "{tag} t{threads}");
                assert_eq!(t.mul_ops, serial.mul_ops, "{tag} t{threads}");
                assert_eq!(t.float_add_ops, serial.float_add_ops, "{tag} t{threads}");
                assert_eq!(t.group_scale_ops, serial.group_scale_ops, "{tag} t{threads}");
            }
        }
    }

    #[test]
    fn backward_passes_match_f32_reference_stride1() {
        check_pass_triple(1, 1, 3, 3, 6, 6, 40);
        check_pass_triple(1, 0, 2, 3, 5, 7, 41);
    }

    #[test]
    fn backward_passes_match_f32_reference_stride2() {
        // even and odd inputs: odd + stride 2 exercises the transposed
        // conv's untouched trailing rows (their gradient must be exactly 0)
        check_pass_triple(2, 1, 3, 3, 6, 6, 42);
        check_pass_triple(2, 1, 3, 3, 7, 5, 43);
        check_pass_triple(2, 0, 2, 2, 6, 6, 44);
    }

    #[test]
    fn dgrad_untouched_pixels_are_exact_zero() {
        // h=5, k=2, s=2, p=0: windows cover rows 0..=3, row 4 untouched
        let mut rng = Pcg32::seeded(45);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let spec = ConvSpec::new(2, 0, 2, 2, 5, 5);
        let qw = quantized(&mut rng, [2, 2, 2, 2], &cfg);
        let qe = quantized(&mut rng, [1, 2, spec.out_h(), spec.out_w()], &cfg);
        let dg = spec.input_grad(&qe, &qw, 1);
        assert_eq!(dg.shape, [1, 2, 5, 5]);
        for ci in 0..2 {
            for x in 0..5 {
                assert_eq!(dg.z[(ci * 5 + 4) * 5 + x], 0.0, "row 4 ci{ci} x{x}");
            }
            for y in 0..5 {
                assert_eq!(dg.z[(ci * 5 + y) * 5 + 4], 0.0, "col 4 ci{ci} y{y}");
            }
        }
    }

    #[test]
    fn large_pad_small_kernel_input_grad() {
        // pad >= kernel: the transposed pad K - 1 - p goes negative
        // (cropping); the signed-pad geometry must handle it
        check_pass_triple(1, 2, 1, 1, 4, 4, 46);
        check_pass_triple(1, 2, 2, 2, 4, 4, 47);
    }
}
