//! Inter-group adder tree — the only floating-point accumulation the MLS
//! datapath keeps (paper Fig. 1 (b), Table VI "Conv / FloatAdd" row).
//!
//! Simulated as a balanced pairwise reduction, which is both what the RTL
//! tree does and a numerically stable order (matching the XLA reduction
//! closely enough that conv.rs validates against the float path at 1e-5).

/// Balanced pairwise sum, the adder-tree reduction order.
pub fn tree_sum(xs: &[f32]) -> f32 {
    match xs.len() {
        0 => 0.0,
        1 => xs[0],
        n => {
            let mid = n / 2;
            tree_sum(&xs[..mid]) + tree_sum(&xs[mid..])
        }
    }
}

/// Number of adder ops a tree reduction of n inputs performs.
pub fn tree_add_ops(n: usize) -> u64 {
    n.saturating_sub(1) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn sums_exactly_small() {
        assert_eq!(tree_sum(&[]), 0.0);
        assert_eq!(tree_sum(&[2.5]), 2.5);
        assert_eq!(tree_sum(&[1.0, 2.0, 3.0, 4.0]), 10.0);
    }

    #[test]
    fn close_to_f64_reference() {
        let mut rng = Pcg32::seeded(13);
        let xs = rng.normal_vec(1024, 1.0);
        let exact: f64 = xs.iter().map(|&v| v as f64).sum();
        let got = tree_sum(&xs) as f64;
        assert!((got - exact).abs() < 1e-3, "{got} vs {exact}");
    }

    #[test]
    fn op_count() {
        assert_eq!(tree_add_ops(1), 0);
        assert_eq!(tree_add_ops(64), 63);
    }
}
