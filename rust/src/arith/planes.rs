//! Decode-once operand planes + the planar low-bit conv tile kernel.
//!
//! The legacy kernel ([`super::conv::lowbit_conv_legacy_threaded`]) re-read
//! and re-decoded every MLS element (`Element::of` plus the `frac_int` /
//! `exp_val` branches) for **every output pixel** that touches it,
//! recomputed the `(co, ci)` x `(n, ci)` group-scale product per pixel, and
//! heap-pushed the window operands into per-pixel `Vec`s. This module
//! hoists all of that out of the pixel loop:
//!
//! * [`DecodedPlanes`] precomputes, once per tensor, struct-of-arrays
//!   planes of the two quantities Eq. 7 actually consumes per element:
//!
//!   ```text
//!   signed_frac[i] = s_i * Frac_i          (signed (M+1)-bit fraction)
//!   shift[i]       = exp_i - emin          (product alignment shift)
//!   ```
//!
//!   so the inner MAC becomes the branch-free
//!   `acc += (signed_frac_w * signed_frac_a) << (shift_w + shift_a)`,
//!   which is exactly Eq. 7's
//!   `P = sum_i s_i^w s_i^a Frac_i^w Frac_i^a 2^((exp_i^w - emin) + (exp_i^a - emin))`
//!   accumulated at the fixed point `2^(2*emin - 2M)`.
//!
//! * [`conv_tile_planar`] hoists the [`GroupScaleFactor::combine`] results
//!   into a per-tile table computed once — the factor depends only on the
//!   `(co, ci)` / `(n, ci)` group pair, never on the pixel — and
//!
//! * splits each output plane into an **interior** region whose windows
//!   are fully in bounds (no clipping checks, fixed `kh*kw` trip count)
//!   and a **halo** region that keeps the legacy clipped-window logic,
//!   counting clipped windows exactly as the legacy kernel does.
//!
//! The result is bit-identical to the legacy kernel — output values AND
//! the five hardware-audit counters (`peak_acc_bits`, `mul_ops`,
//! `int_add_ops`, `float_add_ops`, `group_scale_ops`) — for every format,
//! rounding mode, geometry and thread count. `rust/tests/conv_geometry.rs`
//! and `rust/tests/parallel_equivalence.rs` pin this down; the energy
//! model in [`crate::hw`] consumes the counters unchanged.

use super::conv::{ConvDims, TileStats};
use super::group_scale::GroupScaleFactor;
use super::intra::Element;
use super::pack::{self, PackScratch};
use super::tree::tree_sum;
use crate::mls::format::EmFormat;
use crate::mls::MlsTensor;
use crate::util::parallel;

/// Struct-of-arrays decode of an MLS tensor's element planes, built once
/// per tensor so the conv inner loop never touches the stored
/// sign/exponent-code/mantissa fields again.
#[derive(Clone, Debug)]
pub struct DecodedPlanes {
    /// `s_i * Frac_i`: the signed (M+1)-bit integer fraction of Eq. 7
    /// (zero elements store 0, so the branch-free MAC adds nothing).
    pub signed_frac: Vec<i32>,
    /// `exp_i - emin`: the per-element left shift aligning the product at
    /// the fixed point `2^(2*emin - 2M)` (0 for subnormals by definition).
    pub shift: Vec<u8>,
    /// `signed_frac[i] << shift[i]`: the pre-combined Eq. 7 operand used
    /// by the packed/SIMD kernel, turning the shifted MAC into one plain
    /// widening multiply-add (`acc += scaled_w as i64 * scaled_a as i64`).
    /// Exact because `(M+1) + smax <= 31` is asserted at decode time, so
    /// the shifted fraction never leaves i32 and the product never leaves
    /// i64 — the same bound the shift-at-MAC form already required.
    pub scaled_frac: Vec<i32>,
    /// the element format the planes were decoded under — provenance, so
    /// conv entry points can reject planes built from a differently
    /// formatted tensor (the decoded fields are format-dependent).
    pub fmt: EmFormat,
}

impl DecodedPlanes {
    /// Decode `t`'s element planes on the ambient worker count.
    pub fn of(t: &MlsTensor) -> Self {
        Self::of_threaded(t, parallel::num_threads())
    }

    /// Decode `t`'s element planes with an explicit worker count. Purely
    /// element-wise, so the result is identical for every `threads`.
    pub fn of_threaded(t: &MlsTensor, threads: usize) -> Self {
        let fmt = t.cfg.element;
        let emin = fmt.emin();
        // hard assert (not debug): `scaled_frac` left-shifts the signed
        // (M+1)-bit fraction by up to smax = 2^E - 2, so the combined
        // width must fit i32 — otherwise the pre-combined operand (and
        // equally the old shift-at-MAC i64 product) would overflow
        let smax: u32 = if fmt.e == 0 { 0 } else { (1u32 << fmt.e) - 2 };
        assert!(
            fmt.m + 1 + smax <= 31,
            "element format <{},{}> too wide for the conv planes: (M+1) + (2^E - 2) = {} must be <= 31 bits",
            fmt.e,
            fmt.m,
            fmt.m + 1 + smax
        );
        let n = t.len();
        let parts = parallel::map_ranges(threads, n, |lo, hi| {
            let mut frac = Vec::with_capacity(hi - lo);
            let mut shift = Vec::with_capacity(hi - lo);
            let mut scaled = Vec::with_capacity(hi - lo);
            for idx in lo..hi {
                let e = Element::of(t, idx);
                let f = e.sign as i32 * e.frac_int(fmt) as i32;
                let sh = e.exp_val(fmt) - emin;
                debug_assert!((0..=smax as i32).contains(&sh), "shift {sh} out of [0, {smax}]");
                frac.push(f);
                shift.push(sh as u8);
                scaled.push(f << sh as u32);
            }
            (frac, shift, scaled)
        });
        let mut signed_frac = Vec::with_capacity(n);
        let mut shift = Vec::with_capacity(n);
        let mut scaled_frac = Vec::with_capacity(n);
        for (f, s, c) in parts {
            signed_frac.extend(f);
            shift.extend(s);
            scaled_frac.extend(c);
        }
        DecodedPlanes { signed_frac, shift, scaled_frac, fmt }
    }

    pub fn len(&self) -> usize {
        self.signed_frac.len()
    }

    pub fn is_empty(&self) -> bool {
        self.signed_frac.is_empty()
    }
}

/// The `[lo, hi)` span of output coordinates along one axis whose kernel
/// window is fully in bounds: `o` is interior iff `o*stride >= pad` and
/// `o*stride + k - 1 - pad <= in_len - 1`. An empty span (`lo == hi`)
/// means every output pixel on this axis needs the clipped halo path.
pub fn interior_span(
    in_len: usize,
    k: usize,
    stride: usize,
    pad: usize,
    out_len: usize,
) -> (usize, usize) {
    let lo = pad.div_ceil(stride).min(out_len);
    let hi = if in_len + pad >= k {
        ((in_len + pad - k) / stride + 1).min(out_len)
    } else {
        0
    };
    (lo, hi.max(lo))
}

/// Compute one `(n, co)` output tile on the decode-once planes: per-tile
/// group-scale table -> interior/halo pixel loops -> adder tree, with the
/// exact per-tile audit-counter semantics of the legacy kernel. The tile
/// plane is written straight into `z` (the caller's `[Ho, Wo]` span of
/// the shared output buffer).
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_tile_planar(
    wp: &DecodedPlanes,
    ap: &DecodedPlanes,
    w: &MlsTensor,
    a: &MlsTensor,
    n: usize,
    co: usize,
    d: ConvDims,
    fmt: EmFormat,
    st: f32,
    z: &mut [f32],
) -> TileStats {
    let ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad } = d;
    debug_assert_eq!(z.len(), ho * wo);
    let (mut muls, mut iadds, mut fadds, mut gscales) = (0u64, 0u64, 0u64, 0u64);
    // tile-wide max |accumulator|; bits-needed is monotone in this, so one
    // running max reproduces the legacy per-group peak_bits() max exactly
    let mut peak: i64 = 0;

    // per-tile buffers live in the worker's pack arena, so the planar
    // kernel allocates nothing per tile once the pool is warm
    pack::with_scratch(|scratch| {
    let PackScratch { cbuf, factors, .. } = scratch;
    // group-scale factors hoisted out of the pixel loop: one combine per
    // (co, ci)/(n, ci) pair per tile instead of one per output pixel
    factors.clear();
    factors.extend((0..ci_n).map(|ci| {
        let wg = co * ci_n + ci;
        let ag = n * ci_n + ci;
        GroupScaleFactor::combine(w.sg_exp[wg], w.sg_man[wg], a.sg_exp[ag], a.sg_man[ag])
    }));
    let scale_log2 = 2 * fmt.emin() - 2 * fmt.m as i32;

    let (oy_lo, oy_hi) = interior_span(h, kh, stride, pad, ho);
    let (ox_lo, ox_hi) = interior_span(wi, kw, stride, pad, wo);

    if cbuf.len() < ci_n {
        cbuf.resize(ci_n, 0.0);
    }
    let contribs = &mut cbuf[..ci_n];
    for oy in 0..ho {
        let row_interior = oy >= oy_lo && oy < oy_hi;
        for ox in 0..wo {
            if row_interior && ox >= ox_lo && ox < ox_hi {
                // interior: the whole kh x kw window is in bounds
                let iy0 = oy * stride - pad;
                let ix0 = ox * stride - pad;
                for (ci, contrib) in contribs.iter_mut().enumerate() {
                    let wbase = (co * ci_n + ci) * kh * kw;
                    let abase = ((n * ci_n + ci) * h + iy0) * wi + ix0;
                    let mut acc: i64 = 0;
                    for i in 0..kh {
                        let wr = wbase + i * kw;
                        let ar = abase + i * wi;
                        let wfr = &wp.signed_frac[wr..wr + kw];
                        let wsh = &wp.shift[wr..wr + kw];
                        let afr = &ap.signed_frac[ar..ar + kw];
                        let ash = &ap.shift[ar..ar + kw];
                        for j in 0..kw {
                            let prod = wfr[j] as i64 * afr[j] as i64;
                            acc += prod << (wsh[j] as u32 + ash[j] as u32);
                            peak = peak.max(acc.abs());
                        }
                    }
                    muls += (kh * kw) as u64;
                    iadds += (kh * kw) as u64;
                    *contrib = factors[ci].apply(acc, scale_log2);
                }
            } else {
                // halo: legacy clipped-window logic on the decoded planes
                for (ci, contrib) in contribs.iter_mut().enumerate() {
                    let mut acc: i64 = 0;
                    let mut in_bounds = 0u64;
                    for i in 0..kh {
                        for j in 0..kw {
                            let iy = (oy * stride + i) as isize - pad as isize;
                            let ix = (ox * stride + j) as isize - pad as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= wi as isize {
                                continue; // zero padding contributes nothing
                            }
                            let widx = ((co * ci_n + ci) * kh + i) * kw + j;
                            let aidx = ((n * ci_n + ci) * h + iy as usize) * wi + ix as usize;
                            let prod = wp.signed_frac[widx] as i64 * ap.signed_frac[aidx] as i64;
                            acc += prod << (wp.shift[widx] as u32 + ap.shift[aidx] as u32);
                            peak = peak.max(acc.abs());
                            in_bounds += 1;
                        }
                    }
                    muls += in_bounds;
                    iadds += in_bounds;
                    *contrib = factors[ci].apply(acc, scale_log2);
                }
            }
            gscales += ci_n as u64;
            fadds += (ci_n - 1) as u64;
            z[oy * wo + ox] = st * tree_sum(contribs);
        }
    }
    });

    // same formula as PartialSum::peak_bits on the tile-wide max |acc|;
    // a tile that ran at least one (pixel, group) MAC reports >= 1 even
    // when every accumulator stayed zero (the legacy per-group floor)
    let peak_bits = if ho * wo == 0 || ci_n == 0 {
        0
    } else {
        64 - peak.unsigned_abs().leading_zeros() + 1
    };
    TileStats { peak_bits, muls, iadds, fadds, gscales }
}

/// Permute the leading-axes-swapped view of decoded planes into a
/// caller-owned destination: element `(i0, i1, k)` of a `[d0, d1, inner]`
/// source lands at `(i1, i0, k)` — or `(i1, i0, inner - 1 - k)` when
/// `flip` is set (the `transpose01_flip23` relayout of the input-gradient
/// stationary operand). Decode is element-wise, so permuting decoded
/// planes is bit-identical to decoding a permuted tensor; this lets the
/// arena path build the backward operand layouts without materializing
/// transposed `MlsTensor`s.
pub(crate) fn transpose01_planes(
    src: &DecodedPlanes,
    d0: usize,
    d1: usize,
    inner: usize,
    flip: bool,
    dst: &mut DecodedPlanes,
) {
    let n = src.len();
    assert_eq!(n, d0 * d1 * inner, "transpose01_planes: source shape mismatch");
    dst.fmt = src.fmt;
    dst.signed_frac.clear();
    dst.signed_frac.resize(n, 0);
    dst.shift.clear();
    dst.shift.resize(n, 0);
    dst.scaled_frac.clear();
    dst.scaled_frac.resize(n, 0);
    for i0 in 0..d0 {
        for i1 in 0..d1 {
            let s0 = (i0 * d1 + i1) * inner;
            let t0 = (i1 * d0 + i0) * inner;
            if flip {
                for k in 0..inner {
                    let s = s0 + inner - 1 - k;
                    let t = t0 + k;
                    dst.signed_frac[t] = src.signed_frac[s];
                    dst.shift[t] = src.shift[s];
                    dst.scaled_frac[t] = src.scaled_frac[s];
                }
            } else {
                dst.signed_frac[t0..t0 + inner].copy_from_slice(&src.signed_frac[s0..s0 + inner]);
                dst.shift[t0..t0 + inner].copy_from_slice(&src.shift[s0..s0 + inner]);
                dst.scaled_frac[t0..t0 + inner].copy_from_slice(&src.scaled_frac[s0..s0 + inner]);
            }
        }
    }
}

/// The group-scale half of a leading-axes transpose: `Grouping::Both`
/// groups are the `[d0, d1]` leading pairs, so the per-group scale codes
/// permute exactly like the group blocks (scales travel with their
/// groups; `s_t` is layout-independent and untouched).
pub(crate) fn transpose01_groups(
    sg_exp: &[u8],
    sg_man: &[u32],
    d0: usize,
    d1: usize,
    out_exp: &mut Vec<u8>,
    out_man: &mut Vec<u32>,
) {
    let n = d0 * d1;
    assert_eq!(sg_exp.len(), n, "transpose01_groups: sg_exp shape mismatch");
    assert_eq!(sg_man.len(), n, "transpose01_groups: sg_man shape mismatch");
    out_exp.clear();
    out_exp.resize(n, 0);
    out_man.clear();
    out_man.resize(n, 0);
    for i0 in 0..d0 {
        for i1 in 0..d1 {
            out_exp[i1 * d0 + i0] = sg_exp[i0 * d1 + i1];
            out_man[i1 * d0 + i0] = sg_man[i0 * d1 + i1];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mls::quantizer::{quantize, QuantConfig, Rounding};
    use crate::util::rng::Pcg32;

    #[test]
    fn planes_match_element_decode() {
        let shape = [4usize, 3, 3, 3];
        let mut rng = Pcg32::seeded(31);
        let x = crate::util::prop::grouped_tensor(&mut rng, shape);
        for (e, m) in [(2u32, 4u32), (2, 1), (0, 4)] {
            let mut cfg = QuantConfig::new(e, m);
            cfg.rounding = Rounding::Nearest;
            let t = quantize(&x, &shape, &cfg, &[]);
            let fmt = t.cfg.element;
            let p = DecodedPlanes::of_threaded(&t, 1);
            assert_eq!(p.len(), t.len());
            for idx in 0..t.len() {
                let el = Element::of(&t, idx);
                assert_eq!(
                    p.signed_frac[idx] as i64,
                    el.sign as i64 * el.frac_int(fmt),
                    "<{e},{m}> idx {idx}: signed_frac"
                );
                assert_eq!(
                    p.shift[idx] as i32,
                    el.exp_val(fmt) - fmt.emin(),
                    "<{e},{m}> idx {idx}: shift"
                );
                assert_eq!(
                    p.scaled_frac[idx],
                    p.signed_frac[idx] << p.shift[idx] as u32,
                    "<{e},{m}> idx {idx}: scaled_frac"
                );
            }
            // plane build is element-wise: thread count cannot matter
            for threads in [2usize, 8] {
                let pt = DecodedPlanes::of_threaded(&t, threads);
                assert_eq!(pt.signed_frac, p.signed_frac, "t={threads}");
                assert_eq!(pt.shift, p.shift, "t={threads}");
                assert_eq!(pt.scaled_frac, p.scaled_frac, "t={threads}");
            }
        }
    }

    #[test]
    fn plane_transposes_match_tensor_relayouts() {
        let shape = [3usize, 4, 2, 3];
        let mut rng = Pcg32::seeded(33);
        let x = crate::util::prop::grouped_tensor(&mut rng, shape);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let t = quantize(&x, &shape, &cfg, &[]);
        let p = t.decoded_planes();
        let [d0, d1, d2, d3] = shape;
        for flip in [false, true] {
            // reference: relayout the tensor, then decode
            let tt = if flip { t.transpose01_flip23() } else { t.transpose01() };
            let want = tt.decoded_planes();
            let mut got = DecodedPlanes {
                signed_frac: Vec::new(),
                shift: Vec::new(),
                scaled_frac: Vec::new(),
                fmt: t.cfg.element,
            };
            transpose01_planes(&p, d0, d1, d2 * d3, flip, &mut got);
            assert_eq!(got.fmt, want.fmt, "flip {flip}");
            assert_eq!(got.signed_frac, want.signed_frac, "flip {flip}: signed_frac");
            assert_eq!(got.shift, want.shift, "flip {flip}: shift");
            assert_eq!(got.scaled_frac, want.scaled_frac, "flip {flip}: scaled_frac");
            let (mut oe, mut om) = (Vec::new(), Vec::new());
            transpose01_groups(&t.sg_exp, &t.sg_man, d0, d1, &mut oe, &mut om);
            assert_eq!(oe, tt.sg_exp, "flip {flip}: sg_exp");
            assert_eq!(om, tt.sg_man, "flip {flip}: sg_man");
        }
    }

    #[test]
    fn interior_span_matches_bruteforce() {
        for in_len in 1usize..=9 {
            for k in 1usize..=4 {
                for stride in 1usize..=3 {
                    for pad in 0usize..=3 {
                        if in_len + 2 * pad < k {
                            continue; // geometry invalid, no output
                        }
                        let out_len = (in_len + 2 * pad - k) / stride + 1;
                        let (lo, hi) = interior_span(in_len, k, stride, pad, out_len);
                        assert!(lo <= hi && hi <= out_len);
                        for o in 0..out_len {
                            let fully_inside = (0..k).all(|i| {
                                let pos = (o * stride + i) as isize - pad as isize;
                                pos >= 0 && pos < in_len as isize
                            });
                            assert_eq!(
                                lo <= o && o < hi,
                                fully_inside,
                                "in_len={in_len} k={k} stride={stride} pad={pad} o={o}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn interior_span_empty_when_kernel_never_fits() {
        // k=3 input 2, pad 1: every window is clipped
        let out_len = (2 + 2 - 3) + 1;
        let (lo, hi) = interior_span(2, 3, 1, 1, out_len);
        assert_eq!(lo, hi);
    }
}
