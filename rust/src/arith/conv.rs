//! Full low-bit tensor convolution on the integer datapath (Eq. 6), the
//! composition intra-MAC -> group scale -> adder tree, plus the float
//! reference path used to validate it.
//!
//! Layouts follow the paper: weights `[Co, Ci, K, K]` grouped `(co, ci)`,
//! activations `[N, Ci, H, W]` grouped `(n, ci)`; the intra-group MAC runs
//! over the K x K window, the tree reduces over Ci.
//!
//! Two kernels produce the same bits:
//!
//! * the **planar** kernel (default, [`super::planes`]) decodes each
//!   operand tensor once into `signed_frac`/`shift` planes, hoists the
//!   group-scale products to a per-tile table, and splits every output
//!   plane into a checked-free interior and a clipped halo;
//! * the **legacy** kernel ([`lowbit_conv_legacy_threaded`]) re-decodes
//!   operands per pixel through [`Element`]/[`intra_group_mac`] and is
//!   kept as the bit-exactness reference (and the bench baseline).

use super::group_scale::GroupScaleFactor;
use super::intra::{intra_group_mac, Element};
use super::planes::{self, DecodedPlanes};
use super::tree::tree_sum;
use crate::mls::format::EmFormat;
use crate::mls::{Grouping, MlsTensor};
use crate::util::parallel;

/// Outcome of an integer-path convolution, with hardware-audit counters.
pub struct ConvOutput {
    /// [N, Co, Ho, Wo] in row-major order
    pub z: Vec<f32>,
    pub shape: [usize; 4],
    /// peak intra-group accumulator magnitude observed (bit-width audit)
    pub peak_acc_bits: u32,
    /// operation counters for the energy model
    pub mul_ops: u64,
    pub int_add_ops: u64,
    pub float_add_ops: u64,
    pub group_scale_ops: u64,
}

/// Convolution geometry shared by all output tiles.
#[derive(Clone, Copy)]
pub(crate) struct ConvDims {
    pub(crate) ci_n: usize,
    pub(crate) kh: usize,
    pub(crate) kw: usize,
    pub(crate) h: usize,
    pub(crate) wi: usize,
    pub(crate) ho: usize,
    pub(crate) wo: usize,
    pub(crate) stride: usize,
    pub(crate) pad: usize,
}

/// One `(n, co)` output tile: its `[ho, wo]` plane plus the hardware-audit
/// counters it accumulated.
pub(crate) struct ConvTile {
    pub(crate) z: Vec<f32>,
    pub(crate) peak_bits: u32,
    pub(crate) muls: u64,
    pub(crate) iadds: u64,
    pub(crate) fadds: u64,
    pub(crate) gscales: u64,
}

/// Validate operand shapes/configs and derive the conv geometry. Shared by
/// the planar and legacy entry points so both agree on it exactly.
fn conv_geometry(
    w: &MlsTensor,
    a: &MlsTensor,
    stride: usize,
    pad: usize,
) -> (ConvDims, usize, usize) {
    assert_eq!(w.shape.len(), 4, "weights must be [Co, Ci, K, K]");
    assert_eq!(a.shape.len(), 4, "activations must be [N, Ci, H, W]");
    assert_eq!(w.cfg.grouping, Grouping::Both);
    assert_eq!(a.cfg.grouping, Grouping::Both);
    assert_eq!(w.cfg.element, a.cfg.element, "operand formats must match");
    let [co_n, ci_n, kh, kw] = [w.shape[0], w.shape[1], w.shape[2], w.shape[3]];
    let [n_n, a_ci, h, wi] = [a.shape[0], a.shape[1], a.shape[2], a.shape[3]];
    assert_eq!(ci_n, a_ci);
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wi + 2 * pad - kw) / stride + 1;
    (ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad }, n_n, co_n)
}

/// Merge per-tile results in serial tile order: z planes concatenate into
/// the row-major [N, Co, Ho, Wo] layout; counters sum / max exactly.
fn merge_tiles(tiles: Vec<ConvTile>, shape: [usize; 4]) -> ConvOutput {
    let [n_n, co_n, ho, wo] = shape;
    let mut z = Vec::with_capacity(n_n * co_n * ho * wo);
    let mut peak_bits = 0u32;
    let (mut muls, mut iadds, mut fadds, mut gscales) = (0u64, 0u64, 0u64, 0u64);
    for tile in tiles {
        z.extend_from_slice(&tile.z);
        peak_bits = peak_bits.max(tile.peak_bits);
        muls += tile.muls;
        iadds += tile.iadds;
        fadds += tile.fadds;
        gscales += tile.gscales;
    }
    ConvOutput {
        z,
        shape,
        peak_acc_bits: peak_bits,
        mul_ops: muls,
        int_add_ops: iadds,
        float_add_ops: fadds,
        group_scale_ops: gscales,
    }
}

/// `Conv(qW, qA)` on the integer path. `stride`/`pad` as usual; the result
/// INCLUDES the tensor scales `S_t^w * S_t^a` so it is directly comparable
/// with a float convolution of the dequantized tensors.
///
/// Runs the decode-once planar kernel ([`super::planes`]) sharded over
/// `(n, co)` output tiles on the [`crate::util::parallel`] pool
/// (`MLS_THREADS` workers); see [`lowbit_conv_threaded`] for the
/// bit-identical-across-thread-counts guarantee.
pub fn lowbit_conv(w: &MlsTensor, a: &MlsTensor, stride: usize, pad: usize) -> ConvOutput {
    lowbit_conv_threaded(w, a, stride, pad, parallel::num_threads())
}

/// [`lowbit_conv`] with an explicit worker count.
///
/// The operand planes are decoded once (element-wise, thread-count
/// independent), then every `(n, co)` tile is computed independently with
/// the exact serial per-tile operation order, and tile results (values AND
/// counters) are merged in serial tile order — so the output is
/// bit-identical for every `threads` value AND bit-identical to the legacy
/// kernel (both pinned by `rust/tests/parallel_equivalence.rs` and
/// `rust/tests/conv_geometry.rs`).
pub fn lowbit_conv_threaded(
    w: &MlsTensor,
    a: &MlsTensor,
    stride: usize,
    pad: usize,
    threads: usize,
) -> ConvOutput {
    // decode once per tensor, shared read-only by every tile
    let wp = DecodedPlanes::of_threaded(w, threads);
    let ap = DecodedPlanes::of_threaded(a, threads);
    lowbit_conv_with_planes(w, &wp, a, &ap, stride, pad, threads)
}

/// [`lowbit_conv_threaded`] with caller-supplied decoded planes, so a
/// tensor convolved repeatedly (fixed weights across a batch sweep, say)
/// pays its [`MlsTensor::decoded_planes`] decode once across calls. The
/// planes must belong to the corresponding tensors; results are identical
/// to [`lowbit_conv_threaded`] by construction.
pub fn lowbit_conv_with_planes(
    w: &MlsTensor,
    wp: &DecodedPlanes,
    a: &MlsTensor,
    ap: &DecodedPlanes,
    stride: usize,
    pad: usize,
    threads: usize,
) -> ConvOutput {
    let (dims, n_n, co_n) = conv_geometry(w, a, stride, pad);
    assert_eq!(wp.len(), w.len(), "weight planes do not match the weight tensor");
    assert_eq!(ap.len(), a.len(), "activation planes do not match the activation tensor");
    assert_eq!(wp.fmt, w.cfg.element, "weight planes decoded under a different element format");
    assert_eq!(ap.fmt, a.cfg.element, "activation planes decoded under a different element format");
    let fmt = w.cfg.element;
    let st = w.s_t * a.s_t;

    let tiles = parallel::map_collect(threads, n_n * co_n, |t| {
        planes::conv_tile_planar(wp, ap, w, a, t / co_n, t % co_n, dims, fmt, st)
    });
    merge_tiles(tiles, [n_n, co_n, dims.ho, dims.wo])
}

/// The pre-planar reference kernel: re-decodes operands per output pixel
/// through [`Element`] buffers and [`intra_group_mac`], recomputing the
/// group-scale product per pixel. Kept (a) as the independent reference
/// the planar kernel is bit-compared against and (b) as the baseline the
/// `bench_conv_arith` speedup ratio is measured from.
pub fn lowbit_conv_legacy_threaded(
    w: &MlsTensor,
    a: &MlsTensor,
    stride: usize,
    pad: usize,
    threads: usize,
) -> ConvOutput {
    let (dims, n_n, co_n) = conv_geometry(w, a, stride, pad);
    let fmt = w.cfg.element;
    let st = w.s_t * a.s_t;

    let tiles = parallel::map_collect(threads, n_n * co_n, |t| {
        conv_tile_legacy(w, a, t / co_n, t % co_n, dims, fmt, st)
    });
    merge_tiles(tiles, [n_n, co_n, dims.ho, dims.wo])
}

/// Compute one `(n, co)` output tile the legacy way: per-pixel operand
/// gather -> intra-MAC -> per-pixel group scale -> tree.
fn conv_tile_legacy(
    w: &MlsTensor,
    a: &MlsTensor,
    n: usize,
    co: usize,
    d: ConvDims,
    fmt: EmFormat,
    st: f32,
) -> ConvTile {
    let ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad } = d;
    let mut z = vec![0.0f32; ho * wo];
    let mut peak_bits = 0u32;
    let (mut muls, mut iadds, mut fadds, mut gscales) = (0u64, 0u64, 0u64, 0u64);

    let mut contribs = vec![0.0f32; ci_n];
    let mut wbuf: Vec<Element> = Vec::with_capacity(kh * kw);
    let mut abuf: Vec<Element> = Vec::with_capacity(kh * kw);

    for oy in 0..ho {
        for ox in 0..wo {
            for (ci, contrib) in contribs.iter_mut().enumerate() {
                wbuf.clear();
                abuf.clear();
                for i in 0..kh {
                    for j in 0..kw {
                        let iy = (oy * stride + i) as isize - pad as isize;
                        let ix = (ox * stride + j) as isize - pad as isize;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= wi as isize {
                            continue; // zero padding contributes nothing
                        }
                        let widx = ((co * ci_n + ci) * kh + i) * kw + j;
                        let aidx = ((n * ci_n + ci) * h + iy as usize) * wi + ix as usize;
                        wbuf.push(Element::of(w, widx));
                        abuf.push(Element::of(a, aidx));
                    }
                }
                let ps = intra_group_mac(&wbuf, &abuf, fmt);
                peak_bits = peak_bits.max(ps.peak_bits());
                muls += wbuf.len() as u64;
                iadds += wbuf.len() as u64;
                let wg = co * ci_n + ci;
                let ag = n * ci_n + ci;
                let factor = GroupScaleFactor::combine(
                    w.sg_exp[wg],
                    w.sg_man[wg],
                    a.sg_exp[ag],
                    a.sg_man[ag],
                );
                gscales += 1;
                *contrib = factor.apply(ps.p, ps.scale_log2);
            }
            fadds += (ci_n - 1) as u64;
            z[oy * wo + ox] = st * tree_sum(&contribs);
        }
    }

    ConvTile { z, peak_bits, muls, iadds, fadds, gscales }
}

/// Reference: plain f32 convolution (NCHW x OIHW), used for the float path
/// (conv of dequantized tensors) and by the data/nn substrates.
///
/// Sharded over `(n, co)` output tiles with the same interior/halo split
/// as the planar integer kernel; the per-pixel f64 accumulation order
/// (ci -> kh -> kw over in-bounds taps) is unchanged, so results are
/// bit-identical to the historical serial loop for every thread count.
pub fn conv2d_f32(
    w: &[f32],
    wshape: [usize; 4],
    a: &[f32],
    ashape: [usize; 4],
    stride: usize,
    pad: usize,
) -> (Vec<f32>, [usize; 4]) {
    conv2d_f32_threaded(w, wshape, a, ashape, stride, pad, parallel::num_threads())
}

/// [`conv2d_f32`] with an explicit worker count.
pub fn conv2d_f32_threaded(
    w: &[f32],
    wshape: [usize; 4],
    a: &[f32],
    ashape: [usize; 4],
    stride: usize,
    pad: usize,
    threads: usize,
) -> (Vec<f32>, [usize; 4]) {
    let [co_n, ci_n, kh, kw] = wshape;
    let [n_n, a_ci, h, wi] = ashape;
    assert_eq!(ci_n, a_ci);
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wi + 2 * pad - kw) / stride + 1;
    let dims = ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad };

    let tiles = parallel::map_collect(threads, n_n * co_n, |t| {
        conv2d_f32_tile(w, a, t / co_n, t % co_n, dims)
    });
    let mut z = Vec::with_capacity(n_n * co_n * ho * wo);
    for tile in tiles {
        z.extend_from_slice(&tile);
    }
    (z, [n_n, co_n, ho, wo])
}

/// One `(n, co)` plane of the f32 reference conv, interior/halo split.
fn conv2d_f32_tile(w: &[f32], a: &[f32], n: usize, co: usize, d: ConvDims) -> Vec<f32> {
    let ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad } = d;
    let (oy_lo, oy_hi) = planes::interior_span(h, kh, stride, pad, ho);
    let (ox_lo, ox_hi) = planes::interior_span(wi, kw, stride, pad, wo);
    let mut z = vec![0.0f32; ho * wo];
    for oy in 0..ho {
        let row_interior = oy >= oy_lo && oy < oy_hi;
        for ox in 0..wo {
            let mut acc = 0.0f64;
            if row_interior && ox >= ox_lo && ox < ox_hi {
                let iy0 = oy * stride - pad;
                let ix0 = ox * stride - pad;
                for ci in 0..ci_n {
                    let wbase = (co * ci_n + ci) * kh * kw;
                    let abase = ((n * ci_n + ci) * h + iy0) * wi + ix0;
                    for i in 0..kh {
                        let wr = wbase + i * kw;
                        let ar = abase + i * wi;
                        let wrow = &w[wr..wr + kw];
                        let arow = &a[ar..ar + kw];
                        for j in 0..kw {
                            acc += wrow[j] as f64 * arow[j] as f64;
                        }
                    }
                }
            } else {
                for ci in 0..ci_n {
                    for i in 0..kh {
                        for j in 0..kw {
                            let iy = (oy * stride + i) as isize - pad as isize;
                            let ix = (ox * stride + j) as isize - pad as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= wi as isize {
                                continue;
                            }
                            let widx = ((co * ci_n + ci) * kh + i) * kw + j;
                            let aidx = ((n * ci_n + ci) * h + iy as usize) * wi + ix as usize;
                            acc += w[widx] as f64 * a[aidx] as f64;
                        }
                    }
                }
            }
            z[oy * wo + ox] = acc as f32;
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mls::quantizer::{quantize, QuantConfig, Rounding};
    use crate::util::rng::Pcg32;

    fn rand_nchw(rng: &mut Pcg32, shape: [usize; 4]) -> Vec<f32> {
        crate::util::prop::grouped_tensor(rng, shape)
    }

    fn check_cfg(cfg: QuantConfig, seed: u64) {
        let mut rng = Pcg32::seeded(seed);
        let wshape = [4usize, 3, 3, 3];
        let ashape = [2usize, 3, 6, 6];
        let wf = rand_nchw(&mut rng, wshape);
        let af = rand_nchw(&mut rng, ashape);
        let tw = quantize(&wf, &wshape, &cfg, &[]);
        let ta = quantize(&af, &ashape, &cfg, &[]);
        let out = lowbit_conv(&tw, &ta, 1, 1);
        let (zf, zshape) = conv2d_f32(&tw.dequantize(), wshape, &ta.dequantize(), ashape, 1, 1);
        assert_eq!(out.shape, zshape);
        let scale = zf.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-9);
        for (i, (a, b)) in out.z.iter().zip(&zf).enumerate() {
            assert!(
                (a - b).abs() / scale < 1e-5,
                "idx {i}: int {a} vs float {b} (cfg {})",
                cfg.name()
            );
        }
    }

    #[test]
    fn integer_path_matches_float_path_e2m4() {
        let mut cfg = QuantConfig::new(2, 4);
        cfg.rounding = Rounding::Nearest;
        check_cfg(cfg, 20);
    }

    #[test]
    fn integer_path_matches_float_path_e2m1() {
        let mut cfg = QuantConfig::new(2, 1);
        cfg.rounding = Rounding::Nearest;
        check_cfg(cfg, 21);
    }

    #[test]
    fn integer_path_matches_float_path_int4() {
        let mut cfg = QuantConfig::new(0, 4);
        cfg.rounding = Rounding::Nearest;
        check_cfg(cfg, 22);
    }

    #[test]
    fn accumulator_stays_within_analysis() {
        let mut rng = Pcg32::seeded(23);
        let mut cfg = QuantConfig::new(2, 4);
        cfg.rounding = Rounding::Nearest;
        let wshape = [4usize, 4, 3, 3];
        let ashape = [2usize, 4, 5, 5];
        let tw = quantize(&rand_nchw(&mut rng, wshape), &wshape, &cfg, &[]);
        let ta = quantize(&rand_nchw(&mut rng, ashape), &ashape, &cfg, &[]);
        let out = lowbit_conv(&tw, &ta, 1, 1);
        // <2,4>: 14-bit products, 9 accumulations -> must fit the paper's
        // 32-bit integer accumulator with lots of headroom
        assert!(out.peak_acc_bits <= 14 + 4 + 1, "peak {}", out.peak_acc_bits);
        assert!(out.peak_acc_bits <= 32);
    }

    #[test]
    fn op_counters_match_geometry() {
        let mut rng = Pcg32::seeded(24);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let wshape = [2usize, 3, 3, 3];
        let ashape = [1usize, 3, 4, 4];
        let tw = quantize(&rand_nchw(&mut rng, wshape), &wshape, &cfg, &[]);
        let ta = quantize(&rand_nchw(&mut rng, ashape), &ashape, &cfg, &[]);
        let out = lowbit_conv(&tw, &ta, 1, 1);
        // ho=wo=4, n=1, co=2, ci=3: group scale ops = 1*2*16*3
        assert_eq!(out.group_scale_ops, 96);
        assert_eq!(out.float_add_ops, (3 - 1) * 2 * 16);
        // mul ops < full 3x3 window count because padding clips windows
        assert!(out.mul_ops <= 96 * 9);
    }

    #[test]
    fn planar_matches_legacy_kernel() {
        let mut rng = Pcg32::seeded(25);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let wshape = [4usize, 3, 3, 3];
        let ashape = [2usize, 3, 6, 6];
        let tw = quantize(&rand_nchw(&mut rng, wshape), &wshape, &cfg, &[]);
        let ta = quantize(&rand_nchw(&mut rng, ashape), &ashape, &cfg, &[]);
        let new = lowbit_conv_threaded(&tw, &ta, 1, 1, 1);
        let old = lowbit_conv_legacy_threaded(&tw, &ta, 1, 1, 1);
        assert_eq!(new.shape, old.shape);
        for (i, (x, y)) in new.z.iter().zip(&old.z).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "z[{i}]");
        }
        assert_eq!(new.peak_acc_bits, old.peak_acc_bits);
        assert_eq!(new.mul_ops, old.mul_ops);
        assert_eq!(new.int_add_ops, old.int_add_ops);
        assert_eq!(new.float_add_ops, old.float_add_ops);
        assert_eq!(new.group_scale_ops, old.group_scale_ops);
    }

    #[test]
    fn caller_supplied_planes_match_internal_decode() {
        let mut rng = Pcg32::seeded(27);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let wshape = [3usize, 2, 3, 3];
        let ashape = [2usize, 2, 5, 5];
        let tw = quantize(&rand_nchw(&mut rng, wshape), &wshape, &cfg, &[]);
        let ta = quantize(&rand_nchw(&mut rng, ashape), &ashape, &cfg, &[]);
        let wp = tw.decoded_planes();
        let ap = ta.decoded_planes();
        let reused = lowbit_conv_with_planes(&tw, &wp, &ta, &ap, 1, 1, 2);
        let direct = lowbit_conv_threaded(&tw, &ta, 1, 1, 2);
        assert_eq!(reused.shape, direct.shape);
        for (i, (x, y)) in reused.z.iter().zip(&direct.z).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "z[{i}]");
        }
        assert_eq!(reused.peak_acc_bits, direct.peak_acc_bits);
        assert_eq!(reused.mul_ops, direct.mul_ops);
        assert_eq!(reused.int_add_ops, direct.int_add_ops);
        assert_eq!(reused.float_add_ops, direct.float_add_ops);
        assert_eq!(reused.group_scale_ops, direct.group_scale_ops);
    }

    #[test]
    fn conv2d_f32_identity_kernel() {
        // 1x1 identity kernel reproduces the input
        let w = vec![1.0f32];
        let a: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let (z, shape) = conv2d_f32(&w, [1, 1, 1, 1], &a, [1, 1, 4, 4], 1, 0);
        assert_eq!(shape, [1, 1, 4, 4]);
        assert_eq!(z, a);
    }

    #[test]
    fn conv2d_f32_threads_bit_identical() {
        let mut rng = Pcg32::seeded(26);
        let wshape = [3usize, 2, 3, 2];
        let ashape = [2usize, 2, 7, 5];
        let w = rand_nchw(&mut rng, wshape);
        let a = rand_nchw(&mut rng, ashape);
        for (stride, pad) in [(1usize, 0usize), (1, 1), (2, 1), (1, 2)] {
            let (z1, s1) = conv2d_f32_threaded(&w, wshape, &a, ashape, stride, pad, 1);
            for threads in [2usize, 8] {
                let (zt, st) = conv2d_f32_threaded(&w, wshape, &a, ashape, stride, pad, threads);
                assert_eq!(s1, st);
                for (i, (x, y)) in z1.iter().zip(&zt).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "s{stride} p{pad} t{threads} z[{i}]");
                }
            }
        }
    }
}
