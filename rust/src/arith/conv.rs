//! Full low-bit tensor convolution on the integer datapath (Eq. 6), the
//! composition intra-MAC -> group scale -> adder tree, plus the float
//! reference path used to validate it.
//!
//! Layouts follow the paper: weights `[Co, Ci, K, K]` grouped `(co, ci)`,
//! activations `[N, Ci, H, W]` grouped `(n, ci)`; the intra-group MAC runs
//! over the K x K window, the tree reduces over Ci.
//!
//! Three kernels produce the same bits:
//!
//! * the **packed-GEMM** kernel (default, [`super::gemm`] on the panels
//!   of [`super::pack`]) — operands decoded once AND repacked into
//!   cache-blocked panels, the Eq. 7 MAC running as a register-tiled GEMM
//!   whose epilogue applies the hoisted group-scale table and adder tree.
//!   This is the forward instance of the pass-generic [`super::spec`]
//!   engine, which also executes the Alg. 1 weight-gradient and
//!   input-gradient convs ([`super::spec::ConvSpec`]);
//! * the **planar** kernel ([`super::planes`], the bench baseline the
//!   packed speedup ratio is measured from) — decode-once planes walked
//!   in conv order with an interior/halo pixel split;
//! * the **legacy** kernel ([`lowbit_conv_legacy_threaded`]) — re-decodes
//!   operands per pixel through [`Element`]/[`intra_group_mac`], kept as
//!   the independent bit-exactness reference.
//!
//! All kernels write output tiles directly into the preallocated
//! `[N, Co, Ho, Wo]` buffer at their row offsets
//! ([`crate::util::parallel::DisjointWriter`]) — there is no
//! concatenate-tiles merge pass anymore; only the audit counters are
//! merged (sum/max, order-independent).

use super::group_scale::GroupScaleFactor;
use super::intra::{intra_group_mac, Element};
use super::planes::{self, DecodedPlanes};
use super::spec::{self, SpecDims};
use super::tree::tree_sum;
use crate::mls::format::EmFormat;
use crate::mls::{Grouping, MlsTensor};
use crate::util::parallel::{self, DisjointWriter};

/// Outcome of an integer-path convolution, with hardware-audit counters.
pub struct ConvOutput {
    /// [N, Co, Ho, Wo] in row-major order
    pub z: Vec<f32>,
    pub shape: [usize; 4],
    /// peak intra-group accumulator magnitude observed (bit-width audit)
    pub peak_acc_bits: u32,
    /// operation counters for the energy model
    pub mul_ops: u64,
    pub int_add_ops: u64,
    pub float_add_ops: u64,
    pub group_scale_ops: u64,
}

/// Convolution geometry shared by all output tiles.
#[derive(Clone, Copy)]
pub(crate) struct ConvDims {
    pub(crate) ci_n: usize,
    pub(crate) kh: usize,
    pub(crate) kw: usize,
    pub(crate) h: usize,
    pub(crate) wi: usize,
    pub(crate) ho: usize,
    pub(crate) wo: usize,
    pub(crate) stride: usize,
    pub(crate) pad: usize,
}

/// Hardware-audit counters one work unit accumulated (its output pixels
/// land in the shared buffer directly).
#[derive(Clone, Copy, Default)]
pub(crate) struct TileStats {
    pub(crate) peak_bits: u32,
    pub(crate) muls: u64,
    pub(crate) iadds: u64,
    pub(crate) fadds: u64,
    pub(crate) gscales: u64,
}

impl TileStats {
    fn merge(&mut self, other: &TileStats) {
        self.peak_bits = self.peak_bits.max(other.peak_bits);
        self.muls += other.muls;
        self.iadds += other.iadds;
        self.fadds += other.fadds;
        self.gscales += other.gscales;
    }
}

/// Validate operand shapes/configs and derive the conv geometry. Shared by
/// the packed, planar and legacy entry points so all agree on it exactly.
fn conv_geometry(
    w: &MlsTensor,
    a: &MlsTensor,
    stride: usize,
    pad: usize,
) -> (ConvDims, usize, usize) {
    assert_eq!(w.shape.len(), 4, "weights must be [Co, Ci, K, K]");
    assert_eq!(a.shape.len(), 4, "activations must be [N, Ci, H, W]");
    assert_eq!(w.cfg.grouping, Grouping::Both);
    assert_eq!(a.cfg.grouping, Grouping::Both);
    assert_eq!(w.cfg.element, a.cfg.element, "operand formats must match");
    let [co_n, ci_n, kh, kw] = [w.shape[0], w.shape[1], w.shape[2], w.shape[3]];
    let [n_n, a_ci, h, wi] = [a.shape[0], a.shape[1], a.shape[2], a.shape[3]];
    assert_eq!(ci_n, a_ci);
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wi + 2 * pad - kw) / stride + 1;
    (ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad }, n_n, co_n)
}

/// Drive a per-`(n, co)`-tile kernel over the pool, each tile writing its
/// `[Ho, Wo]` plane directly into the output buffer (tiles are contiguous
/// in `[N, Co, Ho, Wo]`), and merge the audit counters.
fn run_tiled<F>(n_n: usize, co_n: usize, d: ConvDims, threads: usize, kernel: F) -> ConvOutput
where
    F: Fn(usize, usize, &mut [f32]) -> TileStats + Sync,
{
    let tile_len = d.ho * d.wo;
    let mut z = vec![0.0f32; n_n * co_n * tile_len];
    let writer = DisjointWriter::new(&mut z);
    let parts = parallel::map_ranges(threads, n_n * co_n, |lo, hi| {
        let mut stats = TileStats::default();
        for t in lo..hi {
            // SAFETY: tile t owns exactly z[t*tile_len .. (t+1)*tile_len]
            // and ranges are disjoint, so no two spans overlap
            let tile = unsafe { writer.span(t * tile_len, tile_len) };
            stats.merge(&kernel(t / co_n, t % co_n, tile));
        }
        stats
    });
    drop(writer);
    let mut stats = TileStats::default();
    for p in &parts {
        stats.merge(p);
    }
    ConvOutput {
        z,
        shape: [n_n, co_n, d.ho, d.wo],
        peak_acc_bits: stats.peak_bits,
        mul_ops: stats.muls,
        int_add_ops: stats.iadds,
        float_add_ops: stats.fadds,
        group_scale_ops: stats.gscales,
    }
}

/// `Conv(qW, qA)` on the integer path. `stride`/`pad` as usual; the result
/// INCLUDES the tensor scales `S_t^w * S_t^a` so it is directly comparable
/// with a float convolution of the dequantized tensors.
///
/// Runs the cache-blocked packed-GEMM kernel ([`super::gemm`]) on the
/// persistent [`crate::util::parallel`] pool (`MLS_THREADS` workers); see
/// [`lowbit_conv_threaded`] for the bit-identical-across-thread-counts
/// guarantee.
pub fn lowbit_conv(w: &MlsTensor, a: &MlsTensor, stride: usize, pad: usize) -> ConvOutput {
    lowbit_conv_threaded(w, a, stride, pad, parallel::num_threads())
}

/// [`lowbit_conv`] with an explicit worker count.
///
/// The operand planes are decoded and packed once (element-wise /
/// layout-only, thread-count independent), every work unit computes its
/// output rows with the exact serial per-(pixel, group) operation order,
/// and the audit counters merge by sum/max — so the output is
/// bit-identical for every `threads` value AND bit-identical to the
/// planar and legacy kernels (pinned by `rust/tests/conv_fuzz.rs`,
/// `rust/tests/conv_geometry.rs`, `rust/tests/parallel_equivalence.rs`).
pub fn lowbit_conv_threaded(
    w: &MlsTensor,
    a: &MlsTensor,
    stride: usize,
    pad: usize,
    threads: usize,
) -> ConvOutput {
    // decode once per tensor, shared read-only by every work unit
    let wp = DecodedPlanes::of_threaded(w, threads);
    let ap = DecodedPlanes::of_threaded(a, threads);
    lowbit_conv_with_planes(w, &wp, a, &ap, stride, pad, threads)
}

/// [`lowbit_conv_threaded`] with caller-supplied decoded planes, so a
/// tensor convolved repeatedly (fixed weights across a batch sweep, say)
/// pays its [`MlsTensor::decoded_planes`] decode once across calls. The
/// planes must belong to the corresponding tensors; results are identical
/// to [`lowbit_conv_threaded`] by construction. (The GEMM weight panels
/// are packed from `wp` per call — an O(|W|) copy.)
pub fn lowbit_conv_with_planes(
    w: &MlsTensor,
    wp: &DecodedPlanes,
    a: &MlsTensor,
    ap: &DecodedPlanes,
    stride: usize,
    pad: usize,
    threads: usize,
) -> ConvOutput {
    let (dims, n_n, co_n) = conv_geometry(w, a, stride, pad);
    assert_eq!(wp.len(), w.len(), "weight planes do not match the weight tensor");
    assert_eq!(ap.len(), a.len(), "activation planes do not match the activation tensor");
    // thin wrapper: the forward pass is the pass-generic engine of
    // [`super::spec`] under the identity geometry (dil = ups = 1) — the
    // same driver executes the Alg. 1 weight-/input-gradient convs
    spec::run_engine(w, wp, a, ap, n_n, co_n, SpecDims::forward(dims), threads)
}

/// The decode-once planar kernel ([`super::planes`]) as an explicit entry
/// point — the baseline `bench_conv_arith` measures the packed-GEMM
/// speedup (`packed_vs_planar_serial`) against. Bit-identical to
/// [`lowbit_conv_threaded`] and [`lowbit_conv_legacy_threaded`].
pub fn lowbit_conv_planar_threaded(
    w: &MlsTensor,
    a: &MlsTensor,
    stride: usize,
    pad: usize,
    threads: usize,
) -> ConvOutput {
    let (dims, n_n, co_n) = conv_geometry(w, a, stride, pad);
    let fmt = w.cfg.element;
    let st = w.s_t * a.s_t;
    let wp = DecodedPlanes::of_threaded(w, threads);
    let ap = DecodedPlanes::of_threaded(a, threads);
    run_tiled(n_n, co_n, dims, threads, |n, co, tile| {
        planes::conv_tile_planar(&wp, &ap, w, a, n, co, dims, fmt, st, tile)
    })
}

/// The pre-planar reference kernel: re-decodes operands per output pixel
/// through [`Element`] buffers and [`intra_group_mac`], recomputing the
/// group-scale product per pixel. Kept as the independent reference the
/// packed and planar kernels are bit-compared against.
pub fn lowbit_conv_legacy_threaded(
    w: &MlsTensor,
    a: &MlsTensor,
    stride: usize,
    pad: usize,
    threads: usize,
) -> ConvOutput {
    let (dims, n_n, co_n) = conv_geometry(w, a, stride, pad);
    let fmt = w.cfg.element;
    let st = w.s_t * a.s_t;
    run_tiled(n_n, co_n, dims, threads, |n, co, tile| {
        conv_tile_legacy(w, a, n, co, dims, fmt, st, tile)
    })
}

/// Compute one `(n, co)` output tile the legacy way: per-pixel operand
/// gather -> intra-MAC -> per-pixel group scale -> tree.
#[allow(clippy::too_many_arguments)]
fn conv_tile_legacy(
    w: &MlsTensor,
    a: &MlsTensor,
    n: usize,
    co: usize,
    d: ConvDims,
    fmt: EmFormat,
    st: f32,
    z: &mut [f32],
) -> TileStats {
    let ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad } = d;
    let mut peak_bits = 0u32;
    let (mut muls, mut iadds, mut fadds, mut gscales) = (0u64, 0u64, 0u64, 0u64);

    let mut contribs = vec![0.0f32; ci_n];
    let mut wbuf: Vec<Element> = Vec::with_capacity(kh * kw);
    let mut abuf: Vec<Element> = Vec::with_capacity(kh * kw);

    for oy in 0..ho {
        for ox in 0..wo {
            for (ci, contrib) in contribs.iter_mut().enumerate() {
                wbuf.clear();
                abuf.clear();
                for i in 0..kh {
                    for j in 0..kw {
                        let iy = (oy * stride + i) as isize - pad as isize;
                        let ix = (ox * stride + j) as isize - pad as isize;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= wi as isize {
                            continue; // zero padding contributes nothing
                        }
                        let widx = ((co * ci_n + ci) * kh + i) * kw + j;
                        let aidx = ((n * ci_n + ci) * h + iy as usize) * wi + ix as usize;
                        wbuf.push(Element::of(w, widx));
                        abuf.push(Element::of(a, aidx));
                    }
                }
                let ps = intra_group_mac(&wbuf, &abuf, fmt);
                peak_bits = peak_bits.max(ps.peak_bits());
                muls += wbuf.len() as u64;
                iadds += wbuf.len() as u64;
                let wg = co * ci_n + ci;
                let ag = n * ci_n + ci;
                let factor = GroupScaleFactor::combine(
                    w.sg_exp[wg],
                    w.sg_man[wg],
                    a.sg_exp[ag],
                    a.sg_man[ag],
                );
                gscales += 1;
                *contrib = factor.apply(ps.p, ps.scale_log2);
            }
            fadds += (ci_n - 1) as u64;
            z[oy * wo + ox] = st * tree_sum(&contribs);
        }
    }

    TileStats { peak_bits, muls, iadds, fadds, gscales }
}

/// Reference: plain f32 convolution (NCHW x OIHW), used for the float path
/// (conv of dequantized tensors) and by the data/nn substrates.
///
/// Sharded over `(n, co)` output tiles with the same interior/halo split
/// as the planar integer kernel; the per-pixel f64 accumulation order
/// (ci -> kh -> kw over in-bounds taps) is unchanged, so results are
/// bit-identical to the historical serial loop for every thread count.
pub fn conv2d_f32(
    w: &[f32],
    wshape: [usize; 4],
    a: &[f32],
    ashape: [usize; 4],
    stride: usize,
    pad: usize,
) -> (Vec<f32>, [usize; 4]) {
    conv2d_f32_threaded(w, wshape, a, ashape, stride, pad, parallel::num_threads())
}

/// [`conv2d_f32`] with an explicit worker count. Tiles write directly
/// into the preallocated `[N, Co, Ho, Wo]` buffer (no concat pass) via
/// the same [`run_tiled`] scaffolding as the integer kernels (the f32
/// path has no audit counters, so its tile stats are all zero).
pub fn conv2d_f32_threaded(
    w: &[f32],
    wshape: [usize; 4],
    a: &[f32],
    ashape: [usize; 4],
    stride: usize,
    pad: usize,
    threads: usize,
) -> (Vec<f32>, [usize; 4]) {
    let [co_n, _, kh, kw] = wshape;
    let [n_n, _, h, wi] = ashape;
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wi + 2 * pad - kw) / stride + 1;
    let mut z = vec![0.0f32; n_n * co_n * ho * wo];
    let shape = conv2d_f32_into(w, wshape, a, ashape, stride, pad, threads, &mut z);
    (z, shape)
}

/// [`conv2d_f32_threaded`] into a caller-owned output buffer (must be
/// exactly `N * Co * Ho * Wo` long; every element is overwritten), so the
/// warm train-step loop pays no per-call allocation. Same tiles, same
/// per-tile element order — bit-identical to the allocating entry point.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32_into(
    w: &[f32],
    wshape: [usize; 4],
    a: &[f32],
    ashape: [usize; 4],
    stride: usize,
    pad: usize,
    threads: usize,
    z: &mut [f32],
) -> [usize; 4] {
    let [co_n, ci_n, kh, kw] = wshape;
    let [n_n, a_ci, h, wi] = ashape;
    assert_eq!(ci_n, a_ci);
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wi + 2 * pad - kw) / stride + 1;
    let dims = ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad };
    let tile_len = ho * wo;
    assert_eq!(z.len(), n_n * co_n * tile_len, "f32 conv output buffer length");

    let writer = DisjointWriter::new(z);
    parallel::for_ranges(threads, n_n * co_n, |lo, hi| {
        for t in lo..hi {
            // SAFETY: tile t owns exactly z[t*tile_len .. (t+1)*tile_len]
            // and ranges are disjoint, so no two spans overlap
            let tile = unsafe { writer.span(t * tile_len, tile_len) };
            conv2d_f32_tile(w, a, t / co_n, t % co_n, dims, tile);
        }
    });
    drop(writer);
    [n_n, co_n, ho, wo]
}

/// One `(n, co)` plane of the f32 reference conv, interior/halo split.
fn conv2d_f32_tile(w: &[f32], a: &[f32], n: usize, co: usize, d: ConvDims, z: &mut [f32]) {
    let ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad } = d;
    let (oy_lo, oy_hi) = planes::interior_span(h, kh, stride, pad, ho);
    let (ox_lo, ox_hi) = planes::interior_span(wi, kw, stride, pad, wo);
    for oy in 0..ho {
        let row_interior = oy >= oy_lo && oy < oy_hi;
        for ox in 0..wo {
            let mut acc = 0.0f64;
            if row_interior && ox >= ox_lo && ox < ox_hi {
                let iy0 = oy * stride - pad;
                let ix0 = ox * stride - pad;
                for ci in 0..ci_n {
                    let wbase = (co * ci_n + ci) * kh * kw;
                    let abase = ((n * ci_n + ci) * h + iy0) * wi + ix0;
                    for i in 0..kh {
                        let wr = wbase + i * kw;
                        let ar = abase + i * wi;
                        let wrow = &w[wr..wr + kw];
                        let arow = &a[ar..ar + kw];
                        for j in 0..kw {
                            acc += wrow[j] as f64 * arow[j] as f64;
                        }
                    }
                }
            } else {
                for ci in 0..ci_n {
                    for i in 0..kh {
                        for j in 0..kw {
                            let iy = (oy * stride + i) as isize - pad as isize;
                            let ix = (ox * stride + j) as isize - pad as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= wi as isize {
                                continue;
                            }
                            let widx = ((co * ci_n + ci) * kh + i) * kw + j;
                            let aidx = ((n * ci_n + ci) * h + iy as usize) * wi + ix as usize;
                            acc += w[widx] as f64 * a[aidx] as f64;
                        }
                    }
                }
            }
            z[oy * wo + ox] = acc as f32;
        }
    }
}

/// f32 reference weight-gradient conv (Alg. 1 `Conv(E, A)`):
/// `dW[co, ci, i, j] = sum_{n, oy, ox} E[n, co, oy, ox] *
/// A[n, ci, oy*stride + i - pad, ox*stride + j - pad]` over in-bounds
/// positions. f64 accumulation, sharded over `(co, ci)` output planes
/// (each plane's element order is fixed, so results are bit-identical
/// for every `threads`) — the independent reference the integer
/// [`super::spec::ConvSpec`] weight-gradient pass is fuzzed against, and
/// the backward conv of the native trainer's unquantized layers.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32_wgrad(
    e: &[f32],
    eshape: [usize; 4],
    a: &[f32],
    ashape: [usize; 4],
    stride: usize,
    pad: usize,
    kh: usize,
    kw: usize,
    threads: usize,
) -> (Vec<f32>, [usize; 4]) {
    let [_, co_n, _, _] = eshape;
    let [_, ci_n, _, _] = ashape;
    let mut out = vec![0.0f32; co_n * ci_n * kh * kw];
    let shape = conv2d_f32_wgrad_into(e, eshape, a, ashape, stride, pad, kh, kw, threads, &mut out);
    (out, shape)
}

/// [`conv2d_f32_wgrad`] into a caller-owned `[Co, Ci, Kh, Kw]` buffer
/// (every element is overwritten). Bit-identical to the allocating entry
/// point — same plane sharding, same per-plane element order.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32_wgrad_into(
    e: &[f32],
    eshape: [usize; 4],
    a: &[f32],
    ashape: [usize; 4],
    stride: usize,
    pad: usize,
    kh: usize,
    kw: usize,
    threads: usize,
    out: &mut [f32],
) -> [usize; 4] {
    let [n_n, co_n, ho, wo] = eshape;
    let [a_n, ci_n, h, wi] = ashape;
    assert_eq!(n_n, a_n, "error/activation batch mismatch");
    assert_eq!(e.len(), n_n * co_n * ho * wo);
    assert_eq!(a.len(), a_n * ci_n * h * wi);
    let kk = kh * kw;
    assert_eq!(out.len(), co_n * ci_n * kk, "wgrad output buffer length");
    let writer = DisjointWriter::new(out);
    parallel::for_ranges(threads, co_n * ci_n, |lo, hi| {
        for u in lo..hi {
            let (co, ci) = (u / ci_n, u % ci_n);
            // SAFETY: unit u owns exactly out[u*kk .. (u+1)*kk] and
            // map_ranges ranges are disjoint, so no two spans overlap
            let plane = unsafe { writer.span(u * kk, kk) };
            for i in 0..kh {
                for j in 0..kw {
                    let mut acc = 0.0f64;
                    for n in 0..n_n {
                        for oy in 0..ho {
                            let iy = (oy * stride + i) as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for ox in 0..wo {
                                let ix = (ox * stride + j) as isize - pad as isize;
                                if ix < 0 || ix >= wi as isize {
                                    continue;
                                }
                                let eidx = ((n * co_n + co) * ho + oy) * wo + ox;
                                let aidx =
                                    ((n * ci_n + ci) * h + iy as usize) * wi + ix as usize;
                                acc += e[eidx] as f64 * a[aidx] as f64;
                            }
                        }
                    }
                    plane[i * kw + j] = acc as f32;
                }
            }
        }
    });
    drop(writer);
    [co_n, ci_n, kh, kw]
}

/// f32 reference input-gradient conv (Alg. 1 `Conv^T(E, W)`):
/// `dA[n, ci, y, x] = sum_{co, i, j} E[n, co, (y + pad - i)/stride,
/// (x + pad - j)/stride] * W[co, ci, i, j]` over positions where the
/// divisions are exact and in range. f64 accumulation, sharded over
/// `(n, ci)` output planes (bit-identical for every `threads`) — the
/// independent reference for the integer input-gradient pass, and the
/// backward conv of the native trainer's unquantized layers. `in_h` /
/// `in_w` select the forward input dims (not recoverable from the
/// error-field shape alone).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32_dgrad(
    e: &[f32],
    eshape: [usize; 4],
    w: &[f32],
    wshape: [usize; 4],
    stride: usize,
    pad: usize,
    in_h: usize,
    in_w: usize,
    threads: usize,
) -> (Vec<f32>, [usize; 4]) {
    let [n_n, _, _, _] = eshape;
    let [_, ci_n, _, _] = wshape;
    let mut out = vec![0.0f32; n_n * ci_n * in_h * in_w];
    let shape = conv2d_f32_dgrad_into(e, eshape, w, wshape, stride, pad, in_h, in_w, threads, &mut out);
    (out, shape)
}

/// [`conv2d_f32_dgrad`] into a caller-owned `[N, Ci, in_h, in_w]` buffer
/// (every element is overwritten). Bit-identical to the allocating entry
/// point — same plane sharding, same per-plane element order.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32_dgrad_into(
    e: &[f32],
    eshape: [usize; 4],
    w: &[f32],
    wshape: [usize; 4],
    stride: usize,
    pad: usize,
    in_h: usize,
    in_w: usize,
    threads: usize,
    out: &mut [f32],
) -> [usize; 4] {
    let [n_n, co_n, ho, wo] = eshape;
    let [w_co, ci_n, kh, kw] = wshape;
    assert_eq!(co_n, w_co, "error/weight channel mismatch");
    assert_eq!(e.len(), n_n * co_n * ho * wo);
    assert_eq!(w.len(), w_co * ci_n * kh * kw);
    let plane_len = in_h * in_w;
    assert_eq!(out.len(), n_n * ci_n * plane_len, "dgrad output buffer length");
    let writer = DisjointWriter::new(out);
    parallel::for_ranges(threads, n_n * ci_n, |lo, hi| {
        for u in lo..hi {
            let (n, ci) = (u / ci_n, u % ci_n);
            // SAFETY: unit u owns exactly out[u*plane_len ..
            // (u+1)*plane_len] and map_ranges ranges are disjoint
            let plane = unsafe { writer.span(u * plane_len, plane_len) };
            for y in 0..in_h {
                for x in 0..in_w {
                    let mut acc = 0.0f64;
                    for co in 0..co_n {
                        for i in 0..kh {
                            let ty = y as isize + pad as isize - i as isize;
                            if ty < 0 || ty % stride as isize != 0 {
                                continue;
                            }
                            let oy = (ty / stride as isize) as usize;
                            if oy >= ho {
                                continue;
                            }
                            for j in 0..kw {
                                let tx = x as isize + pad as isize - j as isize;
                                if tx < 0 || tx % stride as isize != 0 {
                                    continue;
                                }
                                let ox = (tx / stride as isize) as usize;
                                if ox >= wo {
                                    continue;
                                }
                                let eidx = ((n * co_n + co) * ho + oy) * wo + ox;
                                let widx = ((co * ci_n + ci) * kh + i) * kw + j;
                                acc += e[eidx] as f64 * w[widx] as f64;
                            }
                        }
                    }
                    plane[y * in_w + x] = acc as f32;
                }
            }
        }
    });
    drop(writer);
    [n_n, ci_n, in_h, in_w]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mls::quantizer::{quantize, QuantConfig, Rounding};
    use crate::util::rng::Pcg32;

    fn rand_nchw(rng: &mut Pcg32, shape: [usize; 4]) -> Vec<f32> {
        crate::util::prop::grouped_tensor(rng, shape)
    }

    fn check_cfg(cfg: QuantConfig, seed: u64) {
        let mut rng = Pcg32::seeded(seed);
        let wshape = [4usize, 3, 3, 3];
        let ashape = [2usize, 3, 6, 6];
        let wf = rand_nchw(&mut rng, wshape);
        let af = rand_nchw(&mut rng, ashape);
        let tw = quantize(&wf, &wshape, &cfg, &[]);
        let ta = quantize(&af, &ashape, &cfg, &[]);
        let out = lowbit_conv(&tw, &ta, 1, 1);
        let (zf, zshape) = conv2d_f32(&tw.dequantize(), wshape, &ta.dequantize(), ashape, 1, 1);
        assert_eq!(out.shape, zshape);
        let scale = zf.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-9);
        for (i, (a, b)) in out.z.iter().zip(&zf).enumerate() {
            assert!(
                (a - b).abs() / scale < 1e-5,
                "idx {i}: int {a} vs float {b} (cfg {})",
                cfg.name()
            );
        }
    }

    #[test]
    fn integer_path_matches_float_path_e2m4() {
        let mut cfg = QuantConfig::new(2, 4);
        cfg.rounding = Rounding::Nearest;
        check_cfg(cfg, 20);
    }

    #[test]
    fn integer_path_matches_float_path_e2m1() {
        let mut cfg = QuantConfig::new(2, 1);
        cfg.rounding = Rounding::Nearest;
        check_cfg(cfg, 21);
    }

    #[test]
    fn integer_path_matches_float_path_int4() {
        let mut cfg = QuantConfig::new(0, 4);
        cfg.rounding = Rounding::Nearest;
        check_cfg(cfg, 22);
    }

    #[test]
    fn accumulator_stays_within_analysis() {
        let mut rng = Pcg32::seeded(23);
        let mut cfg = QuantConfig::new(2, 4);
        cfg.rounding = Rounding::Nearest;
        let wshape = [4usize, 4, 3, 3];
        let ashape = [2usize, 4, 5, 5];
        let tw = quantize(&rand_nchw(&mut rng, wshape), &wshape, &cfg, &[]);
        let ta = quantize(&rand_nchw(&mut rng, ashape), &ashape, &cfg, &[]);
        let out = lowbit_conv(&tw, &ta, 1, 1);
        // <2,4>: 14-bit products, 9 accumulations -> must fit the paper's
        // 32-bit integer accumulator with lots of headroom
        assert!(out.peak_acc_bits <= 14 + 4 + 1, "peak {}", out.peak_acc_bits);
        assert!(out.peak_acc_bits <= 32);
    }

    #[test]
    fn op_counters_match_geometry() {
        let mut rng = Pcg32::seeded(24);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let wshape = [2usize, 3, 3, 3];
        let ashape = [1usize, 3, 4, 4];
        let tw = quantize(&rand_nchw(&mut rng, wshape), &wshape, &cfg, &[]);
        let ta = quantize(&rand_nchw(&mut rng, ashape), &ashape, &cfg, &[]);
        let out = lowbit_conv(&tw, &ta, 1, 1);
        // ho=wo=4, n=1, co=2, ci=3: group scale ops = 1*2*16*3
        assert_eq!(out.group_scale_ops, 96);
        assert_eq!(out.float_add_ops, (3 - 1) * 2 * 16);
        // mul ops < full 3x3 window count because padding clips windows
        assert!(out.mul_ops <= 96 * 9);
    }

    fn assert_outputs_identical(x: &ConvOutput, y: &ConvOutput) {
        assert_eq!(x.shape, y.shape);
        for (i, (a, b)) in x.z.iter().zip(&y.z).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "z[{i}]");
        }
        assert_eq!(x.peak_acc_bits, y.peak_acc_bits);
        assert_eq!(x.mul_ops, y.mul_ops);
        assert_eq!(x.int_add_ops, y.int_add_ops);
        assert_eq!(x.float_add_ops, y.float_add_ops);
        assert_eq!(x.group_scale_ops, y.group_scale_ops);
    }

    #[test]
    fn packed_matches_planar_and_legacy_kernels() {
        let mut rng = Pcg32::seeded(25);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let wshape = [4usize, 3, 3, 3];
        let ashape = [2usize, 3, 6, 6];
        let tw = quantize(&rand_nchw(&mut rng, wshape), &wshape, &cfg, &[]);
        let ta = quantize(&rand_nchw(&mut rng, ashape), &ashape, &cfg, &[]);
        let packed = lowbit_conv_threaded(&tw, &ta, 1, 1, 1);
        let planar = lowbit_conv_planar_threaded(&tw, &ta, 1, 1, 1);
        let legacy = lowbit_conv_legacy_threaded(&tw, &ta, 1, 1, 1);
        assert_outputs_identical(&packed, &planar);
        assert_outputs_identical(&packed, &legacy);
    }

    #[test]
    fn caller_supplied_planes_match_internal_decode() {
        let mut rng = Pcg32::seeded(27);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let wshape = [3usize, 2, 3, 3];
        let ashape = [2usize, 2, 5, 5];
        let tw = quantize(&rand_nchw(&mut rng, wshape), &wshape, &cfg, &[]);
        let ta = quantize(&rand_nchw(&mut rng, ashape), &ashape, &cfg, &[]);
        let wp = tw.decoded_planes();
        let ap = ta.decoded_planes();
        let reused = lowbit_conv_with_planes(&tw, &wp, &ta, &ap, 1, 1, 2);
        let direct = lowbit_conv_threaded(&tw, &ta, 1, 1, 2);
        assert_outputs_identical(&reused, &direct);
    }

    #[test]
    fn conv2d_f32_identity_kernel() {
        // 1x1 identity kernel reproduces the input
        let w = vec![1.0f32];
        let a: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let (z, shape) = conv2d_f32(&w, [1, 1, 1, 1], &a, [1, 1, 4, 4], 1, 0);
        assert_eq!(shape, [1, 1, 4, 4]);
        assert_eq!(z, a);
    }

    #[test]
    fn f32_backward_convs_are_adjoints_of_forward() {
        // the defining property of the gradient convs: for any error
        // field E,  <Conv(W, A), E> == <W, wgrad(E, A)> == <A, dgrad(E, W)>
        // (the backward passes are the adjoints of the forward linear map)
        let mut rng = Pcg32::seeded(28);
        for (stride, pad, kh, kw, h, wi) in
            [(1usize, 1usize, 3usize, 3usize, 6usize, 6usize), (2, 1, 3, 3, 7, 5), (2, 0, 2, 2, 6, 6), (1, 2, 1, 1, 4, 4)]
        {
            let (n_n, co_n, ci_n) = (2usize, 3usize, 2usize);
            let wshape = [co_n, ci_n, kh, kw];
            let ashape = [n_n, ci_n, h, wi];
            let w = rand_nchw(&mut rng, wshape);
            let a = rand_nchw(&mut rng, ashape);
            let (z, zshape) = conv2d_f32(&w, wshape, &a, ashape, stride, pad);
            let e = rand_nchw(&mut rng, zshape);
            let (dw, dwshape) = conv2d_f32_wgrad(&e, zshape, &a, ashape, stride, pad, kh, kw, 1);
            let (da, dashape) = conv2d_f32_dgrad(&e, zshape, &w, wshape, stride, pad, h, wi, 1);
            // sharding is per independent output plane: bit-identical
            for threads in [2usize, 8] {
                let (dwt, _) = conv2d_f32_wgrad(&e, zshape, &a, ashape, stride, pad, kh, kw, threads);
                let (dat, _) = conv2d_f32_dgrad(&e, zshape, &w, wshape, stride, pad, h, wi, threads);
                assert!(dw.iter().zip(&dwt).all(|(x, y)| x.to_bits() == y.to_bits()), "t{threads}");
                assert!(da.iter().zip(&dat).all(|(x, y)| x.to_bits() == y.to_bits()), "t{threads}");
            }
            assert_eq!(dwshape, wshape);
            assert_eq!(dashape, ashape);
            let dot = |x: &[f32], y: &[f32]| -> f64 {
                x.iter().zip(y).map(|(p, q)| *p as f64 * *q as f64).sum()
            };
            let ze = dot(&z, &e);
            let wdw = dot(&w, &dw);
            let ada = dot(&a, &da);
            let scale = ze.abs().max(1.0);
            assert!(
                (ze - wdw).abs() / scale < 1e-5,
                "s{stride} p{pad}: <Z,E>={ze} vs <W,dW>={wdw}"
            );
            assert!(
                (ze - ada).abs() / scale < 1e-5,
                "s{stride} p{pad}: <Z,E>={ze} vs <A,dA>={ada}"
            );
        }
    }

    #[test]
    fn conv2d_f32_threads_bit_identical() {
        let mut rng = Pcg32::seeded(26);
        let wshape = [3usize, 2, 3, 2];
        let ashape = [2usize, 2, 7, 5];
        let w = rand_nchw(&mut rng, wshape);
        let a = rand_nchw(&mut rng, ashape);
        for (stride, pad) in [(1usize, 0usize), (1, 1), (2, 1), (1, 2)] {
            let (z1, s1) = conv2d_f32_threaded(&w, wshape, &a, ashape, stride, pad, 1);
            for threads in [2usize, 8] {
                let (zt, st) = conv2d_f32_threaded(&w, wshape, &a, ashape, stride, pad, threads);
                assert_eq!(s1, st);
                for (i, (x, y)) in z1.iter().zip(&zt).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "s{stride} p{pad} t{threads} z[{i}]");
                }
            }
        }
    }
}
