//! Full low-bit tensor convolution on the integer datapath (Eq. 6), the
//! composition intra-MAC -> group scale -> adder tree, plus the float
//! reference path used to validate it.
//!
//! Layouts follow the paper: weights `[Co, Ci, K, K]` grouped `(co, ci)`,
//! activations `[N, Ci, H, W]` grouped `(n, ci)`; the intra-group MAC runs
//! over the K x K window, the tree reduces over Ci.

use super::group_scale::GroupScaleFactor;
use super::intra::{intra_group_mac, Element};
use super::tree::tree_sum;
use crate::mls::format::EmFormat;
use crate::mls::{Grouping, MlsTensor};
use crate::util::parallel;

/// Outcome of an integer-path convolution, with hardware-audit counters.
pub struct ConvOutput {
    /// [N, Co, Ho, Wo] in row-major order
    pub z: Vec<f32>,
    pub shape: [usize; 4],
    /// peak intra-group accumulator magnitude observed (bit-width audit)
    pub peak_acc_bits: u32,
    /// operation counters for the energy model
    pub mul_ops: u64,
    pub int_add_ops: u64,
    pub float_add_ops: u64,
    pub group_scale_ops: u64,
}

/// Convolution geometry shared by all output tiles.
#[derive(Clone, Copy)]
struct ConvDims {
    ci_n: usize,
    kh: usize,
    kw: usize,
    h: usize,
    wi: usize,
    ho: usize,
    wo: usize,
    stride: usize,
    pad: usize,
}

/// One `(n, co)` output tile: its `[ho, wo]` plane plus the hardware-audit
/// counters it accumulated.
struct ConvTile {
    z: Vec<f32>,
    peak_bits: u32,
    muls: u64,
    iadds: u64,
    fadds: u64,
    gscales: u64,
}

/// `Conv(qW, qA)` on the integer path. `stride`/`pad` as usual; the result
/// INCLUDES the tensor scales `S_t^w * S_t^a` so it is directly comparable
/// with a float convolution of the dequantized tensors.
///
/// Sharded over `(n, co)` output tiles on the [`crate::util::parallel`]
/// pool (`MLS_THREADS` workers); see [`lowbit_conv_threaded`] for the
/// bit-identical-across-thread-counts guarantee.
pub fn lowbit_conv(w: &MlsTensor, a: &MlsTensor, stride: usize, pad: usize) -> ConvOutput {
    lowbit_conv_threaded(w, a, stride, pad, parallel::num_threads())
}

/// [`lowbit_conv`] with an explicit worker count.
///
/// Every `(n, co)` tile is computed independently with the exact serial
/// per-tile operation order, and tile results (values AND counters) are
/// merged in serial tile order, so the output is bit-identical for every
/// `threads` value (pinned by `rust/tests/parallel_equivalence.rs`).
pub fn lowbit_conv_threaded(
    w: &MlsTensor,
    a: &MlsTensor,
    stride: usize,
    pad: usize,
    threads: usize,
) -> ConvOutput {
    assert_eq!(w.shape.len(), 4, "weights must be [Co, Ci, K, K]");
    assert_eq!(a.shape.len(), 4, "activations must be [N, Ci, H, W]");
    assert_eq!(w.cfg.grouping, Grouping::Both);
    assert_eq!(a.cfg.grouping, Grouping::Both);
    assert_eq!(w.cfg.element, a.cfg.element, "operand formats must match");
    let [co_n, ci_n, kh, kw] = [w.shape[0], w.shape[1], w.shape[2], w.shape[3]];
    let [n_n, a_ci, h, wi] = [a.shape[0], a.shape[1], a.shape[2], a.shape[3]];
    assert_eq!(ci_n, a_ci);
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wi + 2 * pad - kw) / stride + 1;
    let dims = ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad };

    let fmt = w.cfg.element;
    let st = w.s_t * a.s_t;

    // shard over (n, co) output tiles; tile index order == serial loop order
    let tiles = parallel::map_collect(threads, n_n * co_n, |t| {
        conv_tile(w, a, t / co_n, t % co_n, dims, fmt, st)
    });

    // merge tiles in serial order: z planes concatenate into the row-major
    // [N, Co, Ho, Wo] layout; counters sum / max exactly
    let mut z = Vec::with_capacity(n_n * co_n * ho * wo);
    let mut peak_bits = 0u32;
    let (mut muls, mut iadds, mut fadds, mut gscales) = (0u64, 0u64, 0u64, 0u64);
    for tile in tiles {
        z.extend_from_slice(&tile.z);
        peak_bits = peak_bits.max(tile.peak_bits);
        muls += tile.muls;
        iadds += tile.iadds;
        fadds += tile.fadds;
        gscales += tile.gscales;
    }

    ConvOutput {
        z,
        shape: [n_n, co_n, ho, wo],
        peak_acc_bits: peak_bits,
        mul_ops: muls,
        int_add_ops: iadds,
        float_add_ops: fadds,
        group_scale_ops: gscales,
    }
}

/// Compute one `(n, co)` output tile: intra-MAC -> group scale -> tree over
/// every output pixel of the tile, with per-tile audit counters.
fn conv_tile(
    w: &MlsTensor,
    a: &MlsTensor,
    n: usize,
    co: usize,
    d: ConvDims,
    fmt: EmFormat,
    st: f32,
) -> ConvTile {
    let ConvDims { ci_n, kh, kw, h, wi, ho, wo, stride, pad } = d;
    let mut z = vec![0.0f32; ho * wo];
    let mut peak_bits = 0u32;
    let (mut muls, mut iadds, mut fadds, mut gscales) = (0u64, 0u64, 0u64, 0u64);

    let mut contribs = vec![0.0f32; ci_n];
    let mut wbuf: Vec<Element> = Vec::with_capacity(kh * kw);
    let mut abuf: Vec<Element> = Vec::with_capacity(kh * kw);

    for oy in 0..ho {
        for ox in 0..wo {
            for (ci, contrib) in contribs.iter_mut().enumerate() {
                wbuf.clear();
                abuf.clear();
                for i in 0..kh {
                    for j in 0..kw {
                        let iy = (oy * stride + i) as isize - pad as isize;
                        let ix = (ox * stride + j) as isize - pad as isize;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= wi as isize {
                            continue; // zero padding contributes nothing
                        }
                        let widx = ((co * ci_n + ci) * kh + i) * kw + j;
                        let aidx = ((n * ci_n + ci) * h + iy as usize) * wi + ix as usize;
                        wbuf.push(Element::of(w, widx));
                        abuf.push(Element::of(a, aidx));
                    }
                }
                let ps = intra_group_mac(&wbuf, &abuf, fmt);
                peak_bits = peak_bits.max(ps.peak_bits());
                muls += wbuf.len() as u64;
                iadds += wbuf.len() as u64;
                let wg = co * ci_n + ci;
                let ag = n * ci_n + ci;
                let factor = GroupScaleFactor::combine(
                    w.sg_exp[wg],
                    w.sg_man[wg],
                    a.sg_exp[ag],
                    a.sg_man[ag],
                );
                gscales += 1;
                *contrib = factor.apply(ps.p, ps.scale_log2);
            }
            fadds += (ci_n - 1) as u64;
            z[oy * wo + ox] = st * tree_sum(&contribs);
        }
    }

    ConvTile { z, peak_bits, muls, iadds, fadds, gscales }
}

/// Reference: plain f32 convolution (NCHW x OIHW), used for the float path
/// (conv of dequantized tensors) and by the data/nn substrates.
pub fn conv2d_f32(
    w: &[f32],
    wshape: [usize; 4],
    a: &[f32],
    ashape: [usize; 4],
    stride: usize,
    pad: usize,
) -> (Vec<f32>, [usize; 4]) {
    let [co_n, ci_n, kh, kw] = wshape;
    let [n_n, a_ci, h, wi] = ashape;
    assert_eq!(ci_n, a_ci);
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wi + 2 * pad - kw) / stride + 1;
    let mut z = vec![0.0f32; n_n * co_n * ho * wo];
    for n in 0..n_n {
        for co in 0..co_n {
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = 0.0f64;
                    for ci in 0..ci_n {
                        for i in 0..kh {
                            for j in 0..kw {
                                let iy = (oy * stride + i) as isize - pad as isize;
                                let ix = (ox * stride + j) as isize - pad as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= wi as isize {
                                    continue;
                                }
                                let widx = ((co * ci_n + ci) * kh + i) * kw + j;
                                let aidx =
                                    ((n * ci_n + ci) * h + iy as usize) * wi + ix as usize;
                                acc += w[widx] as f64 * a[aidx] as f64;
                            }
                        }
                    }
                    z[((n * co_n + co) * ho + oy) * wo + ox] = acc as f32;
                }
            }
        }
    }
    (z, [n_n, co_n, ho, wo])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mls::quantizer::{quantize, QuantConfig, Rounding};
    use crate::util::rng::Pcg32;

    fn rand_nchw(rng: &mut Pcg32, shape: [usize; 4]) -> Vec<f32> {
        crate::util::prop::grouped_tensor(rng, shape)
    }

    fn check_cfg(cfg: QuantConfig, seed: u64) {
        let mut rng = Pcg32::seeded(seed);
        let wshape = [4usize, 3, 3, 3];
        let ashape = [2usize, 3, 6, 6];
        let wf = rand_nchw(&mut rng, wshape);
        let af = rand_nchw(&mut rng, ashape);
        let tw = quantize(&wf, &wshape, &cfg, &[]);
        let ta = quantize(&af, &ashape, &cfg, &[]);
        let out = lowbit_conv(&tw, &ta, 1, 1);
        let (zf, zshape) = conv2d_f32(&tw.dequantize(), wshape, &ta.dequantize(), ashape, 1, 1);
        assert_eq!(out.shape, zshape);
        let scale = zf.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-9);
        for (i, (a, b)) in out.z.iter().zip(&zf).enumerate() {
            assert!(
                (a - b).abs() / scale < 1e-5,
                "idx {i}: int {a} vs float {b} (cfg {})",
                cfg.name()
            );
        }
    }

    #[test]
    fn integer_path_matches_float_path_e2m4() {
        let mut cfg = QuantConfig::new(2, 4);
        cfg.rounding = Rounding::Nearest;
        check_cfg(cfg, 20);
    }

    #[test]
    fn integer_path_matches_float_path_e2m1() {
        let mut cfg = QuantConfig::new(2, 1);
        cfg.rounding = Rounding::Nearest;
        check_cfg(cfg, 21);
    }

    #[test]
    fn integer_path_matches_float_path_int4() {
        let mut cfg = QuantConfig::new(0, 4);
        cfg.rounding = Rounding::Nearest;
        check_cfg(cfg, 22);
    }

    #[test]
    fn accumulator_stays_within_analysis() {
        let mut rng = Pcg32::seeded(23);
        let mut cfg = QuantConfig::new(2, 4);
        cfg.rounding = Rounding::Nearest;
        let wshape = [4usize, 4, 3, 3];
        let ashape = [2usize, 4, 5, 5];
        let tw = quantize(&rand_nchw(&mut rng, wshape), &wshape, &cfg, &[]);
        let ta = quantize(&rand_nchw(&mut rng, ashape), &ashape, &cfg, &[]);
        let out = lowbit_conv(&tw, &ta, 1, 1);
        // <2,4>: 14-bit products, 9 accumulations -> must fit the paper's
        // 32-bit integer accumulator with lots of headroom
        assert!(out.peak_acc_bits <= 14 + 4 + 1, "peak {}", out.peak_acc_bits);
        assert!(out.peak_acc_bits <= 32);
    }

    #[test]
    fn op_counters_match_geometry() {
        let mut rng = Pcg32::seeded(24);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let wshape = [2usize, 3, 3, 3];
        let ashape = [1usize, 3, 4, 4];
        let tw = quantize(&rand_nchw(&mut rng, wshape), &wshape, &cfg, &[]);
        let ta = quantize(&rand_nchw(&mut rng, ashape), &ashape, &cfg, &[]);
        let out = lowbit_conv(&tw, &ta, 1, 1);
        // ho=wo=4, n=1, co=2, ci=3: group scale ops = 1*2*16*3
        assert_eq!(out.group_scale_ops, 96);
        assert_eq!(out.float_add_ops, (3 - 1) * 2 * 16);
        // mul ops < full 3x3 window count because padding clips windows
        assert!(out.mul_ops <= 96 * 9);
    }

    #[test]
    fn conv2d_f32_identity_kernel() {
        // 1x1 identity kernel reproduces the input
        let w = vec![1.0f32];
        let a: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let (z, shape) = conv2d_f32(&w, [1, 1, 1, 1], &a, [1, 1, 4, 4], 1, 0);
        assert_eq!(shape, [1, 1, 4, 4]);
        assert_eq!(z, a);
    }
}
