//! Operand panel packing for the cache-blocked packed-GEMM conv kernel
//! ([`super::gemm`]), plus the per-worker scratch arena the panels live
//! in.
//!
//! The planar kernel ([`super::planes`]) already decodes each tensor once,
//! but its inner MAC still walks the `signed_frac`/`shift` planes in conv
//! order: the weight stream restarts every output pixel and the
//! activation stream jumps by `wi` every kernel row — strided,
//! cache-hostile loads that leave the Eq. 7 shift-MAC memory-bound. This
//! module repacks both operands the way a blocked GEMM wants them:
//!
//! * [`PackedWeights`] — the decoded weight planes laid out once per conv
//!   as `[co_blk][K]` panels (`K = Ci * Kh * Kw`), each panel interleaving
//!   [`MR`] output-channel lanes per reduction step
//!   (`comb[k * MR + m]`), so the microkernel reads one contiguous,
//!   forward-only stream no matter which output pixel it is producing.
//!   Lanes past `Co` are zero (a zero fraction contributes nothing to
//!   value, peak, or counters, so padded lanes are arithmetic no-ops).
//! * [`PackScratch::pack_row`] — one output row's gathered operand packed
//!   im2col-style into a `[K][Wo_p]` panel (`Wo_p` = `Wo` rounded up to
//!   [`NR`] lanes), zero-filled where the kernel window hangs over the
//!   input border — or, under the pass-generic geometry of
//!   [`super::spec::SpecDims`], where a dilated tap or a zero-upsampled
//!   input hole contributes nothing (the Alg. 1 backward passes).
//!
//! ## Pre-combined shift panels
//!
//! Earlier generations packed two struct-of-arrays streams per operand —
//! i32 `signed_frac` plus u8 `shift` — and the microkernel computed
//! Eq. 7's `acc += (wf * af) << (ws + as)` per lane. Vector ISAs dislike
//! that shape: pre-AVX2 x86 has no per-lane variable 64-bit shift at
//! all. Both panels therefore now carry ONE i32 plane of the
//! **pre-combined** operand from [`DecodedPlanes::scaled_frac`]:
//!
//! ```text
//! comb[i] = signed_frac[i] << shift[i]
//! ```
//!
//! so the MAC collapses to a plain widening multiply-add,
//! `acc += comb_w as i64 * comb_a as i64` — one `pmuldq`/`smlal` per
//! vector of lanes, no shifts in the inner loop. This is exact (same
//! i64 accumulator sequence, bit for bit) because decode asserts
//! `(M+1) + (2^E - 2) <= 31`, so each shifted operand stays in i32 and
//! each product in i64 — the same bound the shift-at-MAC form already
//! needed to not overflow. Halving the stream count also drops the
//! packed bytes per lane from 5 to 4.
//!
//! Both panels, the per-microtile contribution buffer, and the hoisted
//! group-scale factor table live in a [`PackScratch`] arena owned by each
//! pool worker (`thread_local`, see [`with_scratch`]) — with the
//! persistent pool in [`crate::util::parallel`] the buffers are allocated
//! once per worker and reused across rows, convs, and calls.

use super::group_scale::GroupScaleFactor;
use super::planes::DecodedPlanes;
use super::spec::SpecDims;
use crate::util::parallel;
use std::cell::RefCell;

/// Microkernel register-tile height: output-channel lanes per weight
/// panel reduction step.
pub const MR: usize = 4;
/// Microkernel register-tile width: output-pixel lanes per activation
/// panel reduction step.
pub const NR: usize = 8;

/// Decoded weight planes repacked into GEMM panels: `blocks` panels of
/// `kdim * MR` lanes each, `comb[b * kdim * MR + k * MR + m]` holding the
/// pre-combined operand `scaled_frac` (`signed_frac << shift`) of output
/// channel `b * MR + m` at reduction index `k` (zero for lanes past
/// `co_n`).
#[derive(Default)]
pub struct PackedWeights {
    pub comb: Vec<i32>,
    pub co_n: usize,
    /// reduction length `Ci * Kh * Kw`
    pub kdim: usize,
    /// number of MR-wide output-channel blocks (`ceil(co_n / MR)`)
    pub blocks: usize,
}

/// Pack the decoded weight planes of a `[Co, Ci, Kh, Kw]` tensor into
/// [`MR`]-lane panels, once per conv (parallel over channel blocks; the
/// layout is deterministic, so the thread count cannot matter).
pub fn pack_weights(wp: &DecodedPlanes, co_n: usize, kdim: usize, threads: usize) -> PackedWeights {
    let mut out = PackedWeights::default();
    pack_weights_into(wp, co_n, kdim, threads, &mut out);
    out
}

/// [`pack_weights`] into a caller-owned panel set: same layout, same
/// zeroed padding lanes, but reusing `out.comb`'s capacity, so the warm
/// step loop repacks persistent per-layer panels without allocating.
pub fn pack_weights_into(
    wp: &DecodedPlanes,
    co_n: usize,
    kdim: usize,
    threads: usize,
    out: &mut PackedWeights,
) {
    assert_eq!(wp.len(), co_n * kdim, "weight planes do not match [Co, Ci*Kh*Kw]");
    let blocks = co_n.div_ceil(MR);
    out.co_n = co_n;
    out.kdim = kdim;
    out.blocks = blocks;
    // zero-init covers the padded lanes; ranges write straight into the
    // final buffer at their block offsets (no collect-then-concat pass)
    out.comb.clear();
    out.comb.resize(blocks * kdim * MR, 0);
    {
        let comb_w = parallel::DisjointWriter::new(&mut out.comb);
        parallel::for_ranges(threads, blocks, |lo, hi| {
            // SAFETY: range [lo, hi) owns exactly the panel bytes
            // [lo*kdim*MR, hi*kdim*MR) and for_ranges ranges are disjoint
            let c = unsafe { comb_w.span(lo * kdim * MR, (hi - lo) * kdim * MR) };
            for b in lo..hi {
                let mr = (co_n - b * MR).min(MR);
                let base = (b - lo) * kdim * MR;
                for m in 0..mr {
                    let src = (b * MR + m) * kdim;
                    for k in 0..kdim {
                        c[base + k * MR + m] = wp.scaled_frac[src + k];
                    }
                }
            }
        });
    }
}

/// Reusable per-worker buffers for the packed kernel: the im2col row
/// panel, the microtile contribution buffer the group-scale epilogue
/// writes (`[MR * NR][ci_n]` rows the adder tree then reduces), and the
/// hoisted per-`(co, ci)` group-scale factor table.
#[derive(Default)]
pub struct PackScratch {
    /// activation row panel of pre-combined operands, `a_comb[k * wo_p + x]`
    pub a_comb: Vec<i32>,
    /// group-scale contributions per microtile lane, `[(m * NR + x)][ci]`
    pub cbuf: Vec<f32>,
    /// `factors[co * ci_n + ci]`, rebuilt per batch sample
    pub factors: Vec<GroupScaleFactor>,
}

impl PackScratch {
    /// Gather output row `oy` of gathered-operand index `u` into the
    /// im2col panel under the pass-generic geometry `d`
    /// ([`SpecDims`]): `a_comb[k * wo_p + x]` = pre-combined
    /// `scaled_frac` of the element under tap `k = (g * kh + i) * kw + j`
    /// at output column `x` — zero when the tap's logical position
    /// `x*stride + j*dil - pad_x` hangs over the border or (for
    /// `ups > 1`) falls in a zero-inserted upsampling hole — with
    /// `x < wo_p` zero-padded to the [`NR`] lane multiple. Every slot is
    /// (re)written, so the arena can be reused without clearing. Returns
    /// the number of physically in-bounds kernel rows for this `oy` (the
    /// analytic-counter input).
    pub(crate) fn pack_row(&mut self, ap: &DecodedPlanes, u: usize, oy: usize, d: &SpecDims) -> usize {
        let SpecDims { g_n, kh, kw, h, wi, wo, stride, dil, ups, pad_y, pad_x, .. } = *d;
        let wo_p = wo.div_ceil(NR) * NR;
        let kdim = g_n * kh * kw;
        self.a_comb.resize(kdim * wo_p, 0);
        let mut rows_ib = 0usize;
        for g in 0..g_n {
            for i in 0..kh {
                let iy_log = (oy * stride + i * dil) as isize - pad_y;
                let (row_ok, iy) = if iy_log >= 0 && iy_log % ups as isize == 0 {
                    let q = (iy_log / ups as isize) as usize;
                    (q < h, q)
                } else {
                    (false, 0)
                };
                if g == 0 && row_ok {
                    rows_ib += 1;
                }
                for j in 0..kw {
                    let k = (g * kh + i) * kw + j;
                    let dst = &mut self.a_comb[k * wo_p..(k + 1) * wo_p];
                    if !row_ok {
                        dst.fill(0);
                        continue;
                    }
                    let arow = ((u * g_n + g) * h + iy) * wi;
                    let off = (j * dil) as isize - pad_x;
                    if ups == 1 {
                        // the in-bounds output-column span for this tap:
                        // 0 <= x*stride + off < wi (cf. planes::interior_span)
                        let x_lo = if off >= 0 { 0 } else { ((-off) as usize).div_ceil(stride) };
                        let x_hi = if (wi as isize - 1 - off) < 0 {
                            0
                        } else {
                            (wi as isize - 1 - off) as usize / stride + 1
                        };
                        let x_lo = x_lo.min(wo);
                        let x_hi = x_hi.clamp(x_lo, wo);
                        dst[..x_lo].fill(0);
                        if x_hi > x_lo {
                            // x_lo*stride + off >= 0 and the last source
                            // index is < wi by the span construction above
                            let src0 = (arow as isize + (x_lo * stride) as isize + off) as usize;
                            if stride == 1 {
                                dst[x_lo..x_hi]
                                    .copy_from_slice(&ap.scaled_frac[src0..src0 + (x_hi - x_lo)]);
                            } else {
                                for (t, x) in (x_lo..x_hi).enumerate() {
                                    dst[x] = ap.scaled_frac[src0 + t * stride];
                                }
                            }
                        }
                        dst[x_hi..].fill(0);
                    } else {
                        // upsampled input view (stride == 1 by the engine
                        // invariant): tap j lands on a physical column only
                        // at x with (x + off) a non-negative multiple of
                        // `ups`; those x form an arithmetic progression of
                        // step `ups` whose source index advances by 1
                        dst.fill(0);
                        let lo = if off >= 0 { 0usize } else { (-off) as usize };
                        if lo < wo {
                            let t0 = (lo as isize + off) as usize;
                            let delta = (ups - t0 % ups) % ups;
                            let mut x = lo + delta;
                            let mut src = (x as isize + off) as usize / ups;
                            while x < wo && src < wi {
                                dst[x] = ap.scaled_frac[arow + src];
                                x += ups;
                                src += 1;
                            }
                        }
                    }
                }
            }
        }
        rows_ib
    }
}

thread_local! {
    static SCRATCH: RefCell<PackScratch> = RefCell::new(PackScratch::default());
}

/// Run `f` with this thread's packing scratch arena. Pool workers are
/// persistent, so the arena's buffers amortize across every conv a worker
/// ever runs; grow-only `resize` keeps them at the high-water mark.
pub fn with_scratch<R>(f: impl FnOnce(&mut PackScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mls::quantizer::{quantize, QuantConfig, Rounding};
    use crate::util::rng::Pcg32;

    #[test]
    fn weight_panels_hold_every_lane() {
        let wshape = [5usize, 3, 2, 3]; // co_n=5 exercises a ragged block
        let mut rng = Pcg32::seeded(71);
        let x = crate::util::prop::grouped_tensor(&mut rng, wshape);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let t = quantize(&x, &wshape, &cfg, &[]);
        let wp = t.decoded_planes();
        let kdim = 3 * 2 * 3;
        for threads in [1usize, 2, 8] {
            let pw = pack_weights(&wp, 5, kdim, threads);
            assert_eq!(pw.blocks, 2);
            assert_eq!(pw.comb.len(), 2 * kdim * MR);
            for b in 0..pw.blocks {
                for m in 0..MR {
                    let co = b * MR + m;
                    for k in 0..kdim {
                        let c = pw.comb[b * kdim * MR + k * MR + m];
                        if co < 5 {
                            assert_eq!(c, wp.scaled_frac[co * kdim + k], "t{threads} co{co} k{k}");
                            assert_eq!(
                                c,
                                wp.signed_frac[co * kdim + k] << wp.shift[co * kdim + k] as u32,
                                "t{threads} co{co} k{k}: pre-combined operand"
                            );
                        } else {
                            assert_eq!(c, 0, "padded lane co{co} k{k}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn row_panel_matches_direct_gather() {
        let ashape = [2usize, 3, 5, 7];
        let mut rng = Pcg32::seeded(72);
        let x = crate::util::prop::grouped_tensor(&mut rng, ashape);
        let cfg = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::new(2, 4) };
        let t = quantize(&x, &ashape, &cfg, &[]);
        let ap = t.decoded_planes();
        let [_, ci_n, h, wi] = ashape;
        // (kh, kw, stride, dil, ups, pad): forward geometries, a dilated
        // (wgrad-shaped) one, and an upsampled (dgrad-shaped) one
        let geoms: &[(usize, usize, usize, usize, usize, isize)] = &[
            (3, 3, 1, 1, 1, 1),
            (2, 3, 2, 1, 1, 0),
            (3, 2, 2, 1, 1, 2),
            (2, 3, 1, 2, 1, 1),
            (3, 3, 1, 1, 2, 2),
            (2, 2, 1, 1, 3, -1),
        ];
        for &(kh, kw, stride, dil, ups, pad) in geoms {
            // logical (upsampled) input extents
            let (hl, wl) = ((h - 1) * ups + 1, (wi - 1) * ups + 1);
            let span_h = hl as isize + 2 * pad - ((kh - 1) * dil) as isize;
            let span_w = wl as isize + 2 * pad - ((kw - 1) * dil) as isize;
            if span_h < 1 || span_w < 1 {
                continue;
            }
            let ho = (span_h - 1) as usize / stride + 1;
            let wo = (span_w - 1) as usize / stride + 1;
            let wo_p = wo.div_ceil(NR) * NR;
            let d = SpecDims {
                g_n: ci_n,
                kh,
                kw,
                h,
                wi,
                ho,
                wo,
                stride,
                dil,
                ups,
                pad_y: pad,
                pad_x: pad,
            };
            // exercise the production arena entry point rather than a
            // private scratch instance
            with_scratch(|scratch| {
                for u in 0..ashape[0] {
                    for oy in 0..ho {
                        scratch.pack_row(&ap, u, oy, &d);
                        for g in 0..ci_n {
                            for i in 0..kh {
                                for j in 0..kw {
                                    let k = (g * kh + i) * kw + j;
                                    for x in 0..wo_p {
                                        let iy = (oy * stride + i * dil) as isize - pad;
                                        let ix = (x * stride + j * dil) as isize - pad;
                                        let phys = |v: isize, len: usize| {
                                            if v >= 0 && v % ups as isize == 0 {
                                                let q = (v / ups as isize) as usize;
                                                if q < len {
                                                    return Some(q);
                                                }
                                            }
                                            None
                                        };
                                        let want = match (x < wo, phys(iy, h), phys(ix, wi)) {
                                            (true, Some(py), Some(px)) => {
                                                let idx = ((u * ci_n + g) * h + py) * wi + px;
                                                ap.scaled_frac[idx]
                                            }
                                            _ => 0,
                                        };
                                        let got = scratch.a_comb[k * wo_p + x];
                                        assert_eq!(
                                            got, want,
                                            "u{u} oy{oy} g{g} i{i} j{j} x{x} \
                                             (k{kh}x{kw} s{stride} d{dil} up{ups} p{pad})"
                                        );
                                    }
                                }
                            }
                        }
                    }
                }
            });
        }
    }
}
