//! Per-ISA Eq. 7 MAC segment kernels + the dispatch shim the packed
//! GEMM driver ([`super::gemm`]) calls per microtile group segment.
//!
//! One segment is the full reduction of a single `(x0, block, group)`
//! microtile: `kk = kh * kw` taps of an [`MR`]x[`NR`] register tile over
//! the pre-combined operand panels (see [`super::pack`]). With the
//! `(ws + as)` shifts folded into the packed operands at decode time,
//! each tap is a plain widening multiply-add
//!
//! ```text
//! acc[m][x] += wcomb[t*MR + m] as i64 * acomb[t*wo_p + x] as i64
//! pk[m][x]   = max(pk[m][x], |acc[m][x]|)      // after EVERY tap
//! ```
//!
//! which SSE4.1 (`pmuldq`), AVX2 and NEON (`smlal`) execute directly on
//! 2/4/2-wide i64 lanes. The bit-identity rules every vector path obeys:
//!
//! * vectorize ONLY across the `x` (output-pixel) axis; the tap loop `t`
//!   stays serial, so every lane's i64 accumulator passes through
//!   exactly the scalar sequence of partial sums — and therefore the
//!   running `|acc|` peak (the `peak_acc_bits` audit input) matches the
//!   scalar kernel at every step, not just at the end;
//! * always run the full padded [`MR`]x[`NR`] tile — padded lanes hold
//!   zero operands, contribute zero products and zero peaks, and the
//!   caller's masked-tail epilogue (`gemm::flush_group_tile`) ignores
//!   them for output while merging their (zero) peaks harmlessly;
//! * the Eq. 8 group-scale epilogue and the adder tree stay scalar in
//!   the caller — float ops are never reordered.
//!
//! `rust/tests/conv_fuzz.rs` pins every [`Level`](crate::util::simd::Level)
//! bit-identical (values + all five audit counters) against the legacy
//! kernel across the 200-geometry corpus; which path runs is decided by
//! [`crate::util::simd`].

use super::pack::{MR, NR};
use crate::util::simd::Level;

/// Run one microtile reduction segment at the given dispatch level.
/// `wcomb` is the weight panel segment (`kk * MR` lanes), `acomb` the
/// activation row panel starting at this group's first tap and pixel
/// column (`(kk - 1) * wo_p + NR` lanes reachable).
#[inline]
pub(crate) fn mac_segment(
    level: Level,
    wcomb: &[i32],
    acomb: &[i32],
    kk: usize,
    wo_p: usize,
    acc: &mut [[i64; NR]; MR],
    pk: &mut [[i64; NR]; MR],
) {
    debug_assert_eq!(wcomb.len(), kk * MR);
    debug_assert!(kk == 0 || (kk - 1) * wo_p + NR <= acomb.len());
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: dispatch invariant — `level` comes from util::simd,
        // which only yields a vector level the running CPU supports
        Level::Avx2 => unsafe { mac_segment_avx2(wcomb, acomb, kk, wo_p, acc, pk) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above (SSE4.1 verified by runtime detection)
        Level::Sse41 => unsafe { mac_segment_sse41(wcomb, acomb, kk, wo_p, acc, pk) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above (NEON verified by runtime detection)
        Level::Neon => unsafe { mac_segment_neon(wcomb, acomb, kk, wo_p, acc, pk) },
        _ => mac_segment_scalar(wcomb, acomb, kk, wo_p, acc, pk),
    }
}

/// Scalar reference segment — the bit-identity anchor every vector path
/// is pinned against (and the `Level::Off` / unsupported-arch path).
pub(crate) fn mac_segment_scalar(
    wcomb: &[i32],
    acomb: &[i32],
    kk: usize,
    wo_p: usize,
    acc: &mut [[i64; NR]; MR],
    pk: &mut [[i64; NR]; MR],
) {
    for t in 0..kk {
        let wrow = &wcomb[t * MR..t * MR + MR];
        let arow = &acomb[t * wo_p..t * wo_p + NR];
        for (accm, (pkm, &wc)) in acc.iter_mut().zip(pk.iter_mut().zip(wrow.iter())) {
            let wc = wc as i64;
            for (x, (a, p)) in accm.iter_mut().zip(pkm.iter_mut()).enumerate() {
                *a += wc * arow[x] as i64;
                *p = (*p).max(a.abs());
            }
        }
    }
}

/// AVX2 segment: the 8 pixel lanes split into two independent i64x4
/// halves, each taken through the whole tap loop in registers (halving
/// register pressure vs. interleaving; per-lane sequences unchanged).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::needless_range_loop)]
unsafe fn mac_segment_avx2(
    wcomb: &[i32],
    acomb: &[i32],
    kk: usize,
    wo_p: usize,
    acc: &mut [[i64; NR]; MR],
    pk: &mut [[i64; NR]; MR],
) {
    use core::arch::x86_64::*;
    let wptr = wcomb.as_ptr();
    let aptr = acomb.as_ptr();
    let zero = _mm256_setzero_si256();
    for h in 0..NR / 4 {
        let mut a = [zero; MR];
        let mut p = [zero; MR];
        for m in 0..MR {
            a[m] = _mm256_loadu_si256(acc[m].as_ptr().add(h * 4) as *const __m256i);
            p[m] = _mm256_loadu_si256(pk[m].as_ptr().add(h * 4) as *const __m256i);
        }
        for t in 0..kk {
            // widen 4 activation lanes to i64 once per tap: each qword
            // gets the value in its low dword, sign in the high dword —
            // exactly what pmuldq (mul_epi32) consumes
            let av = _mm256_cvtepi32_epi64(_mm_loadu_si128(
                aptr.add(t * wo_p + h * 4) as *const __m128i
            ));
            let wrow = wptr.add(t * MR);
            for m in 0..MR {
                let wv = _mm256_set1_epi32(*wrow.add(m));
                a[m] = _mm256_add_epi64(a[m], _mm256_mul_epi32(av, wv));
                // |acc|: two's-complement abs via sign mask (no abs_epi64
                // in AVX2); i64::MIN is unreachable (peaks would have
                // overflowed long before)
                let neg = _mm256_cmpgt_epi64(zero, a[m]);
                let abs = _mm256_sub_epi64(_mm256_xor_si256(a[m], neg), neg);
                // max(p, abs): no max_epi64 in AVX2 either
                let gt = _mm256_cmpgt_epi64(abs, p[m]);
                p[m] = _mm256_blendv_epi8(p[m], abs, gt);
            }
        }
        for m in 0..MR {
            _mm256_storeu_si256(acc[m].as_mut_ptr().add(h * 4) as *mut __m256i, a[m]);
            _mm256_storeu_si256(pk[m].as_mut_ptr().add(h * 4) as *mut __m256i, p[m]);
        }
    }
}

/// SSE4.1 segment: i64x2 quarters of the pixel axis. `pcmpgtq` is
/// SSE4.2, so 64-bit sign masks are built by replicating each qword's
/// high dword and arithmetic-shifting it.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse4.1")]
#[allow(clippy::needless_range_loop)]
unsafe fn mac_segment_sse41(
    wcomb: &[i32],
    acomb: &[i32],
    kk: usize,
    wo_p: usize,
    acc: &mut [[i64; NR]; MR],
    pk: &mut [[i64; NR]; MR],
) {
    use core::arch::x86_64::*;
    // replicate each qword's high dword into both of its dwords; srai
    // by 31 then yields the qword's full 64-bit sign mask
    #[inline]
    unsafe fn sign_mask(v: __m128i) -> __m128i {
        _mm_srai_epi32::<31>(_mm_shuffle_epi32::<0b11_11_01_01>(v))
    }
    let wptr = wcomb.as_ptr();
    let aptr = acomb.as_ptr();
    for h in 0..NR / 2 {
        let mut a = [_mm_setzero_si128(); MR];
        let mut p = [_mm_setzero_si128(); MR];
        for m in 0..MR {
            a[m] = _mm_loadu_si128(acc[m].as_ptr().add(h * 2) as *const __m128i);
            p[m] = _mm_loadu_si128(pk[m].as_ptr().add(h * 2) as *const __m128i);
        }
        for t in 0..kk {
            let av = _mm_cvtepi32_epi64(_mm_loadl_epi64(aptr.add(t * wo_p + h * 2) as *const __m128i));
            let wrow = wptr.add(t * MR);
            for m in 0..MR {
                let wv = _mm_set1_epi32(*wrow.add(m));
                a[m] = _mm_add_epi64(a[m], _mm_mul_epi32(av, wv));
                let neg = sign_mask(a[m]);
                let abs = _mm_sub_epi64(_mm_xor_si128(a[m], neg), neg);
                // p and abs are both non-negative, so p - abs fits i64 and
                // its sign says which is larger (pcmpgtq-free i64 max)
                let lt = sign_mask(_mm_sub_epi64(p[m], abs));
                p[m] = _mm_blendv_epi8(p[m], abs, lt);
            }
        }
        for m in 0..MR {
            _mm_storeu_si128(acc[m].as_mut_ptr().add(h * 2) as *mut __m128i, a[m]);
            _mm_storeu_si128(pk[m].as_mut_ptr().add(h * 2) as *mut __m128i, p[m]);
        }
    }
}

/// NEON segment: i64x2 quarters via the `smlal` widening multiply-add.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
#[allow(clippy::needless_range_loop)]
unsafe fn mac_segment_neon(
    wcomb: &[i32],
    acomb: &[i32],
    kk: usize,
    wo_p: usize,
    acc: &mut [[i64; NR]; MR],
    pk: &mut [[i64; NR]; MR],
) {
    use core::arch::aarch64::*;
    let wptr = wcomb.as_ptr();
    let aptr = acomb.as_ptr();
    for h in 0..NR / 2 {
        let mut a = [vdupq_n_s64(0); MR];
        let mut p = [vdupq_n_s64(0); MR];
        for m in 0..MR {
            a[m] = vld1q_s64(acc[m].as_ptr().add(h * 2));
            p[m] = vld1q_s64(pk[m].as_ptr().add(h * 2));
        }
        for t in 0..kk {
            let av = vld1_s32(aptr.add(t * wo_p + h * 2));
            for m in 0..MR {
                let wv = vdup_n_s32(*wptr.add(t * MR + m));
                a[m] = vmlal_s32(a[m], av, wv);
                let abs = vabsq_s64(a[m]);
                // no vmaxq_s64: compare-and-select
                p[m] = vbslq_s64(vcgtq_s64(abs, p[m]), abs, p[m]);
            }
        }
        for m in 0..MR {
            vst1q_s64(acc[m].as_mut_ptr().add(h * 2), a[m]);
            vst1q_s64(pk[m].as_mut_ptr().add(h * 2), p[m]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Direct microtile-level pin of every supported vector segment
    /// against the scalar segment — accumulators AND running peaks —
    /// over random operands at full conv magnitude (the integration
    /// suites pin the same invariant end to end through the engine).
    #[test]
    fn vector_segments_match_scalar_per_lane() {
        let mut rng = Pcg32::seeded(0x51_4D_D0);
        for case in 0..200 {
            let kk = 1 + (rng.next_u32() % 17) as usize;
            let wo_p = NR * (1 + (rng.next_u32() % 3) as usize);
            // full-scale pre-combined operands for e2m4: |frac| <= 31
            // shifted by up to 2 -> |comb| <= 124; scale up to stress
            // the i64 peak lanes too
            let amp = [1i32, 124, 1 << 20][case % 3];
            let mut draw = |n: usize| -> Vec<i32> {
                (0..n).map(|_| (rng.next_u32() as i32 % (2 * amp + 1)) - amp).collect()
            };
            let wcomb = draw(kk * MR);
            let acomb = draw(kk * wo_p);
            let mut acc_ref = [[0i64; NR]; MR];
            let mut pk_ref = [[0i64; NR]; MR];
            // nonzero warm start exercises the load-modify-store paths
            for m in 0..MR {
                for x in 0..NR {
                    acc_ref[m][x] = (rng.next_u32() as i32 % 1000) as i64;
                    pk_ref[m][x] = acc_ref[m][x].abs();
                }
            }
            let (acc0, pk0) = (acc_ref, pk_ref);
            mac_segment_scalar(&wcomb, &acomb, kk, wo_p, &mut acc_ref, &mut pk_ref);
            for level in crate::util::simd::Level::supported() {
                let (mut acc, mut pk) = (acc0, pk0);
                mac_segment(level, &wcomb, &acomb, kk, wo_p, &mut acc, &mut pk);
                assert_eq!(acc, acc_ref, "case {case} level {} acc", level.name());
                assert_eq!(pk, pk_ref, "case {case} level {} peak", level.name());
            }
        }
    }
}
