//! Intra-group integer MAC (paper Eq. 7).
//!
//! One group's partial sum:
//!
//! ```text
//! P = sum_i  s_i^w s_i^a * Frac_i^w * Frac_i^a * 2^(shift_i)
//! shift_i = (exp_i^w - emin) + (exp_i^a - emin)   in [0, 2*(2^E - 2)]
//! ```
//!
//! with `Frac` the (M+1)-bit integer fraction (mantissa plus implicit bit)
//! and the result aligned at the fixed point `2^(2*emin - 2M)`. The
//! accumulator is a plain signed integer — the paper's headline hardware
//! win over FP8's floating-point local accumulation.

use crate::mls::format::EmFormat;
use crate::mls::MlsTensor;

/// Stored fields of one element, as the hardware sees them.
#[derive(Clone, Copy, Debug)]
pub struct Element {
    pub sign: i8,
    pub exp_code: u8,
    pub man: u32,
}

impl Element {
    /// Read the stored fields of element `idx` of an MLS tensor.
    #[inline]
    pub fn of(t: &MlsTensor, idx: usize) -> Element {
        Element { sign: t.sign[idx], exp_code: t.exp_code[idx], man: t.man[idx] }
    }

    /// (M+1)-bit integer fraction: man + 2^M when normal, man when subnormal.
    #[inline]
    pub fn frac_int(&self, fmt: EmFormat) -> i64 {
        if self.exp_code >= 1 {
            (self.man + (1 << fmt.m)) as i64
        } else {
            self.man as i64
        }
    }

    /// Actual exponent: -code (normal), emin (subnormal).
    #[inline]
    pub fn exp_val(&self, fmt: EmFormat) -> i32 {
        if self.exp_code >= 1 {
            -(self.exp_code as i32)
        } else {
            fmt.emin()
        }
    }
}

/// Result of an intra-group MAC: integer partial sum + fixed-point position.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PartialSum {
    /// integer accumulator value
    pub p: i64,
    /// P_real = p * 2^scale_log2 (scale_log2 = 2*emin - 2*M)
    pub scale_log2: i32,
    /// maximum |accumulator| observed while summing (bit-width audit)
    pub peak_abs: i64,
}

impl PartialSum {
    pub fn value(&self) -> f32 {
        self.p as f32 * crate::mls::format::exp2i(self.scale_log2)
    }

    /// Bits needed for the peak accumulator value (plus sign bit).
    ///
    /// An empty group, or one whose accumulator never left zero (every
    /// product had a zero sign), reports **1**: the hardware register
    /// still holds the sign bit even when no magnitude bits were ever
    /// needed. The planar kernel ([`crate::arith::planes`]) reproduces
    /// this floor per processed tile, so `ConvOutput::peak_acc_bits` is 1
    /// (never 0) for any non-empty conv of all-zero operands — pinned by
    /// `peak_bits_all_zero_group_is_one` below and by the all-zero conv
    /// test in `rust/tests/conv_geometry.rs`.
    pub fn peak_bits(&self) -> u32 {
        64 - self.peak_abs.unsigned_abs().leading_zeros() + 1
    }
}

/// MAC over one group of element pairs (Eq. 7).
pub fn intra_group_mac(w: &[Element], a: &[Element], fmt: EmFormat) -> PartialSum {
    assert_eq!(w.len(), a.len());
    let emin = fmt.emin();
    let mut acc: i64 = 0;
    let mut peak: i64 = 0;
    for (we, ae) in w.iter().zip(a) {
        let sign = (we.sign as i64) * (ae.sign as i64);
        if sign == 0 {
            continue;
        }
        let prod = we.frac_int(fmt) * ae.frac_int(fmt);
        let shift = (we.exp_val(fmt) - emin) + (ae.exp_val(fmt) - emin);
        debug_assert!((0..=2 * ((1 << fmt.e) - 2)).contains(&shift), "shift {shift}");
        acc += sign * (prod << shift);
        peak = peak.max(acc.abs());
    }
    PartialSum { p: acc, scale_log2: 2 * emin - 2 * fmt.m as i32, peak_abs: peak }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mls::format;
    use crate::mls::quantizer::{quantize, QuantConfig, Rounding};
    use crate::util::rng::Pcg32;

    fn elems(t: &crate::mls::MlsTensor) -> Vec<Element> {
        (0..t.len())
            .map(|i| Element { sign: t.sign[i], exp_code: t.exp_code[i], man: t.man[i] })
            .collect()
    }

    #[test]
    fn single_product_exact() {
        let fmt = EmFormat::new(2, 4);
        // w = (1 + 3/16) * 2^-1, a = (1 + 5/16) * 2^-2
        let w = Element { sign: 1, exp_code: 1, man: 3 };
        let a = Element { sign: -1, exp_code: 2, man: 5 };
        let ps = intra_group_mac(&[w], &[a], fmt);
        let expect = -(1.0 + 3.0 / 16.0) * 0.5 * (1.0 + 5.0 / 16.0) * 0.25;
        assert!((ps.value() - expect as f32).abs() < 1e-7);
    }

    #[test]
    fn zero_elements_skip() {
        let fmt = EmFormat::new(2, 4);
        let w = Element { sign: 0, exp_code: 0, man: 0 };
        let a = Element { sign: 1, exp_code: 1, man: 7 };
        let ps = intra_group_mac(&[w], &[a], fmt);
        assert_eq!(ps.p, 0);
    }

    #[test]
    fn matches_float_path_on_random_groups() {
        let mut rng = Pcg32::seeded(11);
        let mut cfg = QuantConfig::new(2, 4);
        cfg.grouping = crate::mls::Grouping::First;
        cfg.rounding = Rounding::Nearest;
        let shape = [6usize, 9];
        let w: Vec<f32> = rng.normal_vec(54, 1.0);
        let a: Vec<f32> = rng.normal_vec(54, 1.0);
        let tw = quantize(&w, &shape, &cfg, &[]);
        let ta = quantize(&a, &shape, &cfg, &[]);
        let ew = elems(&tw);
        let ea = elems(&ta);
        for g in 0..6 {
            let ps = intra_group_mac(&ew[g * 9..(g + 1) * 9], &ea[g * 9..(g + 1) * 9], cfg.element);
            // float path: sum of xbar_w * xbar_a (no scales)
            let mut expect = 0.0f64;
            for i in g * 9..(g + 1) * 9 {
                let vw = tw.sign[i] as f64
                    * tw.cfg.element.decode(tw.exp_code[i], tw.man[i]) as f64;
                let va = ta.sign[i] as f64
                    * ta.cfg.element.decode(ta.exp_code[i], ta.man[i]) as f64;
                expect += vw * va;
            }
            assert!((ps.value() as f64 - expect).abs() < 1e-6, "group {g}");
        }
    }

    #[test]
    fn accumulator_respects_analysis() {
        // peak bits <= product_bits + ceil(log2(len)) + 1
        let mut rng = Pcg32::seeded(12);
        let fmt = EmFormat::new(2, 4);
        let n = 64;
        let mk = |rng: &mut Pcg32| Element {
            sign: if rng.uniform() < 0.5 { 1 } else { -1 },
            exp_code: rng.below(4) as u8,
            man: rng.below(16),
        };
        let w: Vec<Element> = (0..n).map(|_| mk(&mut rng)).collect();
        let a: Vec<Element> = (0..n).map(|_| mk(&mut rng)).collect();
        let ps = intra_group_mac(&w, &a, fmt);
        let bound = fmt.product_bits() + 6 + 1;
        assert!(ps.peak_bits() <= bound, "{} > {}", ps.peak_bits(), bound);
    }

    #[test]
    fn peak_bits_all_zero_group_is_one() {
        let fmt = EmFormat::new(2, 4);
        // empty group: accumulator never written, peak_abs stays 0
        let ps = intra_group_mac(&[], &[], fmt);
        assert_eq!(ps.peak_abs, 0);
        assert_eq!(ps.peak_bits(), 1);
        // all-zero group: every product is sign 0, accumulator stays 0
        let z = Element { sign: 0, exp_code: 0, man: 0 };
        let ps = intra_group_mac(&[z; 4], &[z; 4], fmt);
        assert_eq!(ps.p, 0);
        assert_eq!(ps.peak_bits(), 1);
        // and the floor is tight: one minimal nonzero product needs 2 bits
        let one = Element { sign: 1, exp_code: 0, man: 1 };
        let ps = intra_group_mac(&[one], &[one], fmt);
        assert_eq!(ps.peak_bits(), 2);
    }

    #[test]
    fn fixed_point_position() {
        let fmt = EmFormat::new(2, 4); // emin=-3, M=4
        let ps = intra_group_mac(&[], &[], fmt);
        assert_eq!(ps.scale_log2, -14);
        assert_eq!(format::exp2i(ps.scale_log2), 2.0f32.powi(-14));
    }
}
