//! The cache-blocked packed-GEMM lowering of the low-bit conv — the
//! default `lowbit_conv` kernel, and (via the pass-generic
//! [`super::spec::SpecDims`] geometry) the microkernel of ALL three
//! Alg. 1 passes: forward, weight-gradient and input-gradient convs run
//! this exact code over differently packed operands.
//!
//! [`super::planes`] removed the per-pixel decode; this module removes the
//! conv-order walk. The Eq. 7 shift-MAC runs as a blocked GEMM over the
//! panels [`super::pack`] builds:
//!
//! ```text
//!   Nc : one output row, im2col-packed as a [K][Wo_p] panel (built once
//!        per (n, oy), reused by every output channel)
//!   Mc : the MR-wide weight panels, swept per row — one contiguous
//!        forward stream per block, L1/L2-resident across the row
//!   Kc : the reduction runs in per-ci segments of kh*kw taps; each
//!        segment ends at a register-tile flush through the group-scale
//!        epilogue (Eq. 8), because the integer accumulator is per
//!        scaling group by construction
//! ```
//!
//! The microkernel is an [`MR`] x [`NR`] (4 x 8) register tile: MR output
//! channels x NR output pixels accumulate in `i64` registers while both
//! panel streams advance strictly forward; all trip counts are constants
//! so the compiler unrolls the tile. The tile reduction itself lives in
//! [`super::simd`] — a scalar reference segment plus SSE4.1/AVX2/NEON
//! vector segments over the pre-combined panels, selected per conv by
//! [`crate::util::simd`] runtime dispatch and pinned bit-identical.
//! Ragged edges (last channel block, last pixel block) run the same
//! full-tile code over zero-padded lanes — a zero operand is an
//! arithmetic no-op for values AND for the running `|acc|` peak, so no
//! separate edge kernel exists; one masked-tail epilogue
//! ([`flush_group_tile`] + [`write_tile_rows`], shared by every dispatch
//! level) applies the per-`(co, ci)` [`GroupScaleFactor`] table hoisted
//! per batch sample and the inter-group adder tree, writing each
//! finished pixel straight into its `[N, Co, Ho, Wo]` row offset (no
//! tile concatenation pass).
//!
//! ## Bit-identity
//!
//! Per (pixel, scaling group) the accumulated tap sequence is exactly the
//! legacy/planar order (`ci` outer, kernel rows, kernel columns), border
//! taps contribute zero, and the epilogue/tree arithmetic is the same f32
//! op sequence — so output values and all five hardware-audit counters
//! (`peak_acc_bits`, `mul_ops`, `int_add_ops`, `float_add_ops`,
//! `group_scale_ops`) are bit-identical to both older kernels for every
//! format, geometry, and thread count. `rust/tests/conv_fuzz.rs` sweeps
//! ~200 random geometries across all three kernels;
//! `rust/tests/conv_geometry.rs` pins the named edge cases. The
//! `mul_ops`/`int_add_ops` taps are counted analytically from the
//! geometry (the legacy counters are geometry-driven, never
//! value-driven), which is one more reason the padded-lane no-ops cost
//! nothing.
//!
//! [`GroupScaleFactor`]: super::group_scale::GroupScaleFactor

use super::group_scale::GroupScaleFactor;
use super::pack::{PackScratch, PackedWeights, MR, NR};
use super::planes::DecodedPlanes;
use super::spec::SpecDims;
use super::tree::tree_sum;
use crate::util::parallel::DisjointWriter;
use crate::util::simd::Level;

/// Physically in-bounds kernel *columns* summed over a row's output
/// positions — the geometry-only half of the analytic `mul_ops` count
/// (the other half, in-bounds kernel rows, depends on `oy` and comes from
/// [`PackScratch::pack_row`]). Computed once per conv by the driver. The
/// predicate is exactly [`PackScratch::pack_row`]'s column test: a tap's
/// logical position must be non-negative, land on a physical (not
/// zero-upsampled) column, and fall inside the plane — so backward-pass
/// counters stay geometry-driven just like the forward ones.
pub(crate) fn col_taps(d: SpecDims) -> u64 {
    let mut taps = 0u64;
    for x in 0..d.wo {
        for j in 0..d.kw {
            let ix = (x * d.stride + j * d.dil) as isize - d.pad_x;
            if ix >= 0 && ix % d.ups as isize == 0 && ((ix / d.ups as isize) as usize) < d.wi {
                taps += 1;
            }
        }
    }
    taps
}

/// Compute one output row `(u, oy, all v, all ox)` on the packed panels,
/// writing finished pixels straight into `zw` at their `[U, V, Ho, Wo]`
/// offsets. Returns `(row peak |acc|, in-bounds kernel rows for this
/// oy)` — the caller derives the audit counters analytically as
/// `rows_ib * col_taps * v_n * g_n` (clipping/upsampling is rectangular,
/// so the in-bounds window size separates into rows x columns).
///
/// `scratch.factors` must hold the `v_n * g_n` hoisted group-scale
/// factors for gathered index `u` (v-major), see the driver in
/// [`super::spec`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_row_packed(
    pw: &PackedWeights,
    ap: &DecodedPlanes,
    scratch: &mut PackScratch,
    u: usize,
    oy: usize,
    d: SpecDims,
    scale_log2: i32,
    st: f32,
    zw: &DisjointWriter<f32>,
    level: Level,
) -> (i64, usize) {
    let rows_ib = scratch.pack_row(ap, u, oy, &d);
    let SpecDims { g_n, kh, kw, ho, wo, .. } = d;

    let v_n = pw.co_n;
    let kdim = pw.kdim;
    let kk = kh * kw;
    let wo_p = wo.div_ceil(NR) * NR;
    // split the arena so the panel borrows stay disjoint
    let PackScratch { a_comb, cbuf, factors } = scratch;
    cbuf.resize(MR * NR * g_n, 0.0);
    let mut peak: i64 = 0;

    for x0 in (0..wo).step_by(NR) {
        let nr = (wo - x0).min(NR);
        for b in 0..pw.blocks {
            let m0 = b * MR;
            let mr = (v_n - m0).min(MR);
            let wcomb = &pw.comb[b * kdim * MR..(b + 1) * kdim * MR];
            for g in 0..g_n {
                // Kc segment: one scaling group's kh*kw taps, register
                // accumulators + lane-wise running |acc| peaks, at the
                // runtime-dispatched ISA level (bit-identical across all)
                let mut acc = [[0i64; NR]; MR];
                let mut pk = [[0i64; NR]; MR];
                super::simd::mac_segment(
                    level,
                    &wcomb[g * kk * MR..(g + 1) * kk * MR],
                    &a_comb[g * kk * wo_p + x0..],
                    kk,
                    wo_p,
                    &mut acc,
                    &mut pk,
                );
                peak = peak.max(flush_group_tile(
                    &acc, &pk, mr, nr, m0, g, g_n, factors, cbuf, scale_log2,
                ));
            }
            write_tile_rows(cbuf, mr, nr, m0, g_n, u, oy, x0, v_n, ho, wo, st, zw);
        }
    }
    (peak, rows_ib)
}

/// Masked-tail group epilogue shared by every dispatch level: apply the
/// Eq. 8 [`GroupScaleFactor`] to the `mr` x `nr` live lanes of the
/// finished register tile (scalar f32, never reordered) and return the
/// tile's max running-|acc| peak merged over ALL lanes — padded lanes
/// carry zero operands, hence zero peaks, so merging them is harmless
/// and keeps the merge branch-free.
#[allow(clippy::too_many_arguments)]
fn flush_group_tile(
    acc: &[[i64; NR]; MR],
    pk: &[[i64; NR]; MR],
    mr: usize,
    nr: usize,
    m0: usize,
    g: usize,
    g_n: usize,
    factors: &[GroupScaleFactor],
    cbuf: &mut [f32],
    scale_log2: i32,
) -> i64 {
    for m in 0..mr {
        let factor = factors[(m0 + m) * g_n + g];
        for x in 0..nr {
            cbuf[(m * NR + x) * g_n + g] = factor.apply(acc[m][x], scale_log2);
        }
    }
    let mut peak = 0i64;
    for pkm in pk {
        for &p in pkm {
            peak = peak.max(p);
        }
    }
    peak
}

/// Masked-tail output flush shared by every dispatch level: adder-tree
/// the `mr` x `nr` live contribution rows of a finished tile straight
/// into their `[U, V, Ho, Wo]` offsets.
#[allow(clippy::too_many_arguments)]
fn write_tile_rows(
    cbuf: &[f32],
    mr: usize,
    nr: usize,
    m0: usize,
    g_n: usize,
    u: usize,
    oy: usize,
    x0: usize,
    v_n: usize,
    ho: usize,
    wo: usize,
    st: f32,
    zw: &DisjointWriter<f32>,
) {
    for m in 0..mr {
        let v = m0 + m;
        // SAFETY: span (u, v, oy, x0..x0+nr) — work units own disjoint
        // oy rows and x0 blocks are disjoint within one call, so no two
        // live spans overlap
        let out = unsafe { zw.span(((u * v_n + v) * ho + oy) * wo + x0, nr) };
        for (x, slot) in out.iter_mut().enumerate() {
            let row = &cbuf[(m * NR + x) * g_n..(m * NR + x + 1) * g_n];
            *slot = st * tree_sum(row);
        }
    }
}
