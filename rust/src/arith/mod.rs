//! Low-bit tensor convolution arithmetic (paper Sec. V-B, Eq. 6-8) — a
//! bit-accurate simulator of the customized hardware unit of Fig. 1 (b):
//!
//! ```text
//!   low-bit MUL -> integer LocalACC (intra-group, Eq. 7)
//!              -> group-wise scale unit (shift-add, Eq. 8)
//!              -> inter-group adder tree (the only FloatAdd kept)
//! ```
//!
//! [`intra`] implements the integer MAC with shift alignment and tracks the
//! live accumulator range; [`group_scale`] applies `S_p = S_g^w * S_g^a` as
//! exact shift-adds; [`tree`] is the floating-point adder tree;
//! [`conv`] composes them into a full `Conv(qW, qA)` over NCHW tensors and
//! cross-checks against the dequantized float path; [`pack`] + [`gemm`]
//! are the cache-blocked packed-GEMM kernel the default conv path runs on
//! (operands decoded once AND repacked into MR-lane / im2col panels, the
//! Eq. 7 MAC register-tiled, group scales applied in the epilogue);
//! [`spec`] generalizes that engine to all three convolutions of the
//! Alg. 1 training step (forward, weight-gradient, input-gradient) via
//! the pass-generic [`spec::ConvSpec`] geometry; [`simd`] holds the
//! per-ISA (SSE4.1/AVX2/NEON) vector segment kernels the packed GEMM
//! dispatches to at runtime — every level pinned bit-identical to the
//! scalar reference; [`planes`] is the decode-once planar kernel kept as
//! the bench baseline — all three forward kernels are bit-identical;
//! [`bitwidth`] carries the Sec. V-C accumulation-width analysis.

pub mod bitwidth;
pub mod conv;
pub mod gemm;
pub mod group_scale;
pub mod intra;
pub mod pack;
pub mod planes;
pub mod simd;
pub mod spec;
pub mod tree;
