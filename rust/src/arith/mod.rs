//! Low-bit tensor convolution arithmetic (paper Sec. V-B, Eq. 6-8) — a
//! bit-accurate simulator of the customized hardware unit of Fig. 1 (b):
//!
//! ```text
//!   low-bit MUL -> integer LocalACC (intra-group, Eq. 7)
//!              -> group-wise scale unit (shift-add, Eq. 8)
//!              -> inter-group adder tree (the only FloatAdd kept)
//! ```
//!
//! [`intra`] implements the integer MAC with shift alignment and tracks the
//! live accumulator range; [`group_scale`] applies `S_p = S_g^w * S_g^a` as
//! exact shift-adds; [`tree`] is the floating-point adder tree;
//! [`conv`] composes them into a full `Conv(qW, qA)` over NCHW tensors and
//! cross-checks against the dequantized float path; [`planes`] is the
//! decode-once planar kernel the default conv path runs on (operands
//! decoded once per tensor, group scales hoisted per tile, interior/halo
//! pixel split — bit-identical to the legacy per-pixel path); [`bitwidth`]
//! carries the Sec. V-C accumulation-width analysis.

pub mod bitwidth;
pub mod conv;
pub mod group_scale;
pub mod intra;
pub mod planes;
pub mod tree;
