//! Artifact manifest: the metadata contract between `python/compile/aot.py`
//! and the Rust runtime. Everything the coordinator knows about a model —
//! state layout, shapes, quant config — comes from here; nothing is
//! hard-coded on the Rust side.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::mls::QuantConfig;
use crate::util::json::Json;

/// One tensor signature (name, shape, dtype).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSig {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(TensorSig {
            name: v.req("name")?.as_str().unwrap_or_default().to_string(),
            shape: v.req("shape")?.usizes()?,
            dtype: v.req("dtype")?.as_str().unwrap_or("f32").to_string(),
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One model variable in the flat state vector.
#[derive(Clone, Debug, PartialEq)]
pub struct VarSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: String, // "param" | "bn_stat"
    pub offset: usize,
}

impl VarSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Per-model metadata (python model.build_model meta dict).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub n_var: usize,
    pub state_dim: usize,
    pub batch: usize,
    pub img_shape: Vec<usize>,
    pub num_classes: usize,
    pub probe_names: Vec<String>,
    pub probe_a_shapes: BTreeMap<String, Vec<usize>>,
    pub probe_e_shapes: BTreeMap<String, Vec<usize>>,
    pub specs: Vec<VarSpec>,
    pub init_file: Option<String>,
}

impl ModelMeta {
    pub fn spec(&self, name: &str) -> Option<&VarSpec> {
        self.specs.iter().find(|s| s.name == name)
    }

    /// Slice a variable out of a flat state vector.
    pub fn read_var<'a>(&self, state: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let s = self.spec(name).ok_or_else(|| anyhow!("no var {name:?}"))?;
        Ok(&state[s.offset..s.offset + s.size()])
    }
}

/// One AOT artifact (an HLO file + its signature).
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub fn_kind: String, // train_step | eval_step | probe_step
    pub model: String,
    pub cfg: QuantConfig,
    pub cfg_name: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub img_shape: Vec<usize>,
    pub num_classes: usize,
    pub models: BTreeMap<String, ModelMeta>,
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;

        let mut models = BTreeMap::new();
        for (name, m) in v.req("models")?.as_obj().ok_or_else(|| anyhow!("models"))?.iter() {
            let specs = m
                .req("specs")?
                .as_arr()
                .ok_or_else(|| anyhow!("specs"))?
                .iter()
                .map(|s| {
                    Ok(VarSpec {
                        name: s.req("name")?.as_str().unwrap_or_default().to_string(),
                        shape: s.req("shape")?.usizes()?,
                        kind: s.req("kind")?.as_str().unwrap_or_default().to_string(),
                        offset: s.req("offset")?.as_usize().unwrap_or(0),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let probe_names: Vec<String> = m
                .req("probe_names")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|s| s.as_str().map(str::to_string))
                .collect();
            let read_shapes = |key: &str| -> BTreeMap<String, Vec<usize>> {
                m.get(key)
                    .and_then(|o| o.as_obj())
                    .map(|o| {
                        o.iter()
                            .filter_map(|(k, v)| v.usizes().ok().map(|s| (k.clone(), s)))
                            .collect()
                    })
                    .unwrap_or_default()
            };
            let init_file = v
                .get("init")
                .and_then(|i| i.get(name))
                .and_then(|i| i.get("file"))
                .and_then(|f| f.as_str())
                .map(str::to_string);
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    n_var: m.req("n_var")?.as_usize().unwrap_or(0),
                    state_dim: m.req("state_dim")?.as_usize().unwrap_or(0),
                    batch: m.req("batch")?.as_usize().unwrap_or(0),
                    img_shape: m.req("img_shape")?.usizes()?,
                    num_classes: m.req("num_classes")?.as_usize().unwrap_or(10),
                    probe_names,
                    probe_a_shapes: read_shapes("probe_a_shapes"),
                    probe_e_shapes: read_shapes("probe_e_shapes"),
                    specs,
                    init_file,
                },
            );
        }

        let artifacts = v
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts"))?
            .iter()
            .map(|a| {
                let cfg_json = a.req("cfg")?;
                Ok(Artifact {
                    name: a.req("name")?.as_str().unwrap_or_default().to_string(),
                    file: a.req("file")?.as_str().unwrap_or_default().to_string(),
                    fn_kind: a.req("fn")?.as_str().unwrap_or_default().to_string(),
                    model: a.req("model")?.as_str().unwrap_or_default().to_string(),
                    cfg: QuantConfig::from_json(cfg_json)?,
                    cfg_name: QuantConfig::from_json(cfg_json)?.name(),
                    inputs: a
                        .req("inputs")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSig::from_json)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: a
                        .req("outputs")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSig::from_json)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            dir,
            batch: v.req("batch")?.as_usize().unwrap_or(32),
            img_shape: v.req("img_shape")?.usizes()?,
            num_classes: v.req("num_classes")?.as_usize().unwrap_or(10),
            models,
            artifacts,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }

    /// Find an artifact by (model, fn kind, config name).
    pub fn find(&self, model: &str, fn_kind: &str, cfg_name: &str) -> Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| a.model == model && a.fn_kind == fn_kind && a.cfg_name == cfg_name)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact model={model} fn={fn_kind} cfg={cfg_name}; \
                     available for {model}: {:?} — run `make artifacts` (or artifacts-full)",
                    self.artifacts
                        .iter()
                        .filter(|a| a.model == model)
                        .map(|a| format!("{}:{}", a.fn_kind, a.cfg_name))
                        .collect::<Vec<_>>()
                )
            })
    }

    /// Artifacts of a given fn kind for a model (e.g. all train variants).
    pub fn variants(&self, model: &str, fn_kind: &str) -> Vec<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.model == model && a.fn_kind == fn_kind)
            .collect()
    }

    /// Load the initial state vector of a model.
    pub fn load_init(&self, model: &str) -> Result<Vec<f32>> {
        let meta = self.model(model)?;
        let file = meta
            .init_file
            .as_ref()
            .ok_or_else(|| anyhow!("no init blob for {model}"))?;
        let bytes = std::fs::read(self.dir.join(file))?;
        anyhow::ensure!(
            bytes.len() == meta.state_dim * 4,
            "init blob size {} != state_dim {} * 4",
            bytes.len(),
            meta.state_dim
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn artifact_path(&self, a: &Artifact) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // artifacts/ lives at the repo root, one level above the rust package
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("artifacts")
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
        for (_, meta) in &m.models {
            assert_eq!(meta.state_dim, 2 * meta.n_var);
            // specs must tile the variable region exactly
            let total: usize = meta.specs.iter().map(|s| s.size()).sum();
            assert_eq!(total, meta.n_var);
            let init = m.load_init(&meta.name).unwrap();
            assert_eq!(init.len(), meta.state_dim);
        }
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
