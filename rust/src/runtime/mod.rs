//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! Rust hot path.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (models, flat-state
//!   layouts, per-artifact input/output signatures, quant configs),
//! * [`engine`] — wraps the `xla` crate: one `PjRtClient::cpu()`, an
//!   executable cache keyed by artifact name, and typed step calls that
//!   move `Vec<f32>` in/out.
//!
//! HLO **text** is the interchange format: jax >= 0.5 emits
//! HloModuleProtos with 64-bit instruction ids that this xla_extension
//! (0.5.1) rejects; the text parser reassigns ids (see DESIGN.md).
//!
//! The real engine needs the external `xla` crate (a prebuilt
//! xla_extension), which the hermetic build environment cannot provide, so
//! it is gated behind the `pjrt` cargo feature. Without the feature an
//! API-compatible stub loads manifests and reports artifacts but returns a
//! descriptive error from every execution entry point, keeping the rest of
//! the crate (coordinator, CLI, benches, examples) fully buildable.

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod manifest;

pub use engine::Engine;
pub use manifest::{Artifact, Manifest, ModelMeta, VarSpec};
