//! The PJRT execution engine: compile artifacts once per process, then run
//! typed steps from the training hot loop.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use super::manifest::{Artifact, Manifest};

/// Output of one train step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub loss: f32,
    pub acc: f32,
}

/// A compiled artifact plus its signature (cached per process).
pub struct Compiled {
    pub artifact: Artifact,
    exe: xla::PjRtLoadedExecutable,
    pub compile_time: Duration,
}

/// PJRT CPU engine with an executable cache.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, Compiled>,
    /// cumulative device-execution time (perf accounting)
    pub exec_time: Duration,
    pub exec_steps: u64,
}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        Ok(Engine { manifest, client, cache: HashMap::new(), exec_time: Duration::ZERO, exec_steps: 0 })
    }

    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Engine::new(Manifest::load(dir)?)
    }

    /// Compile (or fetch from cache) an artifact by (model, fn, cfg).
    pub fn compiled(&mut self, model: &str, fn_kind: &str, cfg_name: &str) -> Result<&Compiled> {
        let art = self.manifest.find(model, fn_kind, cfg_name)?.clone();
        if !self.cache.contains_key(&art.name) {
            let path = self.manifest.artifact_path(&art);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", art.name))?;
            let compile_time = t0.elapsed();
            eprintln!("[engine] compiled {} in {:.1?}", art.name, compile_time);
            self.cache.insert(
                art.name.clone(),
                Compiled { artifact: art.clone(), exe, compile_time },
            );
        }
        Ok(&self.cache[&art.name])
    }

    /// Execute an artifact with f32/i32 inputs matched against its
    /// signature; returns each output flattened to f32.
    pub fn execute(
        &mut self,
        model: &str,
        fn_kind: &str,
        cfg_name: &str,
        inputs: &[Input<'_>],
    ) -> Result<Vec<Vec<f32>>> {
        // compile first (separate borrow scope)
        self.compiled(model, fn_kind, cfg_name)?;
        let art_name = self.manifest.find(model, fn_kind, cfg_name)?.name.clone();
        let compiled = &self.cache[&art_name];

        ensure!(
            inputs.len() == compiled.artifact.inputs.len(),
            "{}: expected {} inputs, got {}",
            art_name,
            compiled.artifact.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (sig, input) in compiled.artifact.inputs.iter().zip(inputs) {
            let lit = match (sig.dtype.as_str(), input) {
                ("f32", Input::F32(data)) => {
                    ensure!(
                        data.len() == sig.elements(),
                        "{}: input {} wants {} f32s, got {}",
                        art_name, sig.name, sig.elements(), data.len()
                    );
                    to_literal_f32(data, &sig.shape)?
                }
                ("i32", Input::I32(data)) => {
                    ensure!(
                        data.len() == sig.elements(),
                        "{}: input {} wants {} i32s, got {}",
                        art_name, sig.name, sig.elements(), data.len()
                    );
                    to_literal_i32(data, &sig.shape)?
                }
                (dt, got) => anyhow::bail!(
                    "{}: input {} dtype mismatch: artifact wants {dt}, caller passed {}",
                    art_name, sig.name,
                    match got { Input::F32(_) => "f32", Input::I32(_) => "i32" }
                ),
            };
            literals.push(lit);
        }

        let t0 = Instant::now();
        let result = compiled
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e}", art_name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", art_name))?;
        self.exec_time += t0.elapsed();
        self.exec_steps += 1;

        // jax lowering uses return_tuple=True: unpack N outputs
        let outs = tuple.to_tuple().map_err(|e| anyhow!("untupling: {e}"))?;
        ensure!(
            outs.len() == compiled.artifact.outputs.len(),
            "{}: expected {} outputs, got {}",
            art_name,
            compiled.artifact.outputs.len(),
            outs.len()
        );
        outs.into_iter()
            .map(|o| {
                o.to_vec::<f32>()
                    .map_err(|e| anyhow!("output fetch: {e}"))
            })
            .collect()
    }

    /// One training step: state' written in place; returns (loss, acc).
    pub fn train_step(
        &mut self,
        model: &str,
        cfg_name: &str,
        state: &mut Vec<f32>,
        images: &[f32],
        labels: &[i32],
        seed: i32,
        lr: f32,
    ) -> Result<StepOutput> {
        let outs = self.execute(
            model,
            "train_step",
            cfg_name,
            &[
                Input::F32(state),
                Input::F32(images),
                Input::I32(labels),
                Input::I32(&[seed]),
                Input::F32(&[lr]),
            ],
        )?;
        *state = outs[0].clone();
        Ok(StepOutput { loss: outs[1][0], acc: outs[2][0] })
    }

    /// Evaluation (runs the fp32 eval artifact of the model).
    pub fn eval_step(
        &mut self,
        model: &str,
        state: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<StepOutput> {
        let outs = self.execute(
            model,
            "eval_step",
            "fp32",
            &[Input::F32(state), Input::F32(images), Input::I32(labels)],
        )?;
        Ok(StepOutput { loss: outs[0][0], acc: outs[1][0] })
    }

    /// Probe step: per-layer A / E / W tensors (Fig. 6 / Fig. 7 inputs).
    pub fn probe_step(
        &mut self,
        model: &str,
        cfg_name: &str,
        state: &[f32],
        images: &[f32],
        labels: &[i32],
        seed: i32,
    ) -> Result<Vec<Vec<f32>>> {
        self.execute(
            model,
            "probe_step",
            cfg_name,
            &[Input::F32(state), Input::F32(images), Input::I32(labels), Input::I32(&[seed])],
        )
    }

    /// Mean device time per executed step.
    pub fn mean_exec_time(&self) -> Duration {
        if self.exec_steps == 0 {
            Duration::ZERO
        } else {
            self.exec_time / self.exec_steps as u32
        }
    }
}

/// A borrowed, typed input buffer.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

fn to_literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e}")).context("f32 literal")
}

fn to_literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e}")).context("i32 literal")
}
