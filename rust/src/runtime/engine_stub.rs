//! Stub execution engine, compiled when the `pjrt` feature is OFF.
//!
//! The real engine (`engine.rs`) wraps the external `xla` crate, which
//! needs a prebuilt xla_extension that the hermetic build environment
//! cannot supply. This stub keeps the full API surface — manifest loading,
//! artifact listing, the typed step signatures — so the coordinator, CLI,
//! benches and examples all build and degrade gracefully: anything that
//! would actually dispatch to PJRT returns a descriptive error instead.

use std::time::Duration;

use anyhow::{bail, Result};

use super::manifest::Manifest;

/// Output of one train step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub loss: f32,
    pub acc: f32,
}

/// A borrowed, typed input buffer.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// Manifest-only engine: execution requires the `pjrt` feature.
pub struct Engine {
    pub manifest: Manifest,
    /// cumulative device-execution time (always zero in the stub)
    pub exec_time: Duration,
    pub exec_steps: u64,
}

fn unavailable(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{what} needs the PJRT runtime, but mls_train was built without the \
         `pjrt` cargo feature (the external `xla` crate is not vendored — \
         see README \"PJRT backend\")"
    )
}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        Ok(Engine { manifest, exec_time: Duration::ZERO, exec_steps: 0 })
    }

    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Engine::new(Manifest::load(dir)?)
    }

    /// Execute an artifact — always an error in the stub.
    pub fn execute(
        &mut self,
        model: &str,
        fn_kind: &str,
        cfg_name: &str,
        inputs: &[Input<'_>],
    ) -> Result<Vec<Vec<f32>>> {
        // validate what we can without a device, so callers still get the
        // manifest-level errors the real engine would surface first
        let art = self.manifest.find(model, fn_kind, cfg_name)?;
        anyhow::ensure!(
            inputs.len() == art.inputs.len(),
            "{}: expected {} inputs, got {}",
            art.name,
            art.inputs.len(),
            inputs.len()
        );
        bail!("{}", unavailable(&format!("executing {}", art.name)))
    }

    /// One training step — always an error in the stub.
    pub fn train_step(
        &mut self,
        model: &str,
        cfg_name: &str,
        _state: &mut Vec<f32>,
        _images: &[f32],
        _labels: &[i32],
        _seed: i32,
        _lr: f32,
    ) -> Result<StepOutput> {
        Err(unavailable(&format!("train_step {model}/{cfg_name}")))
    }

    /// Evaluation step — always an error in the stub.
    pub fn eval_step(
        &mut self,
        model: &str,
        _state: &[f32],
        _images: &[f32],
        _labels: &[i32],
    ) -> Result<StepOutput> {
        Err(unavailable(&format!("eval_step {model}")))
    }

    /// Probe step — always an error in the stub.
    pub fn probe_step(
        &mut self,
        model: &str,
        cfg_name: &str,
        _state: &[f32],
        _images: &[f32],
        _labels: &[i32],
        _seed: i32,
    ) -> Result<Vec<Vec<f32>>> {
        Err(unavailable(&format!("probe_step {model}/{cfg_name}")))
    }

    /// Mean device time per executed step (zero in the stub).
    pub fn mean_exec_time(&self) -> Duration {
        Duration::ZERO
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_feature_gate() {
        let e = unavailable("train_step resnet_t/fp32");
        let msg = format!("{e:#}");
        assert!(msg.contains("pjrt"), "{msg}");
    }

    #[test]
    fn from_missing_dir_still_errors_on_manifest() {
        let err = Engine::from_dir("/nonexistent/path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
