//! The training loop: dataset -> train step -> metrics, over either of
//! two backends:
//!
//! * **native** (default) — the in-crate Alg. 1 trainer
//!   ([`crate::nn::train`]) over the composable module graph
//!   ([`crate::nn::graph`]): quantized forward / weight-gradient /
//!   input-gradient convs on the pass-generic packed-GEMM engine
//!   (residual joins included), BN / ReLU / FC and the pluggable
//!   optimizer (SGD / momentum) in f32, zero external dependencies;
//! * **pjrt** — the AOT train-step artifacts through the PJRT engine
//!   (needs `make artifacts` + the `pjrt` cargo feature).
//!
//! One `train()` call is one experiment run (one model x one quant config
//! x one seed); the Table II / Table IV harnesses call it in a grid. Both
//! backends share the step/seed/lr derivations, the metrics log, and the
//! CSV/checkpoint outputs, so runs are comparable across backends.

use std::time::Instant;

use anyhow::Result;

use super::checkpoint::{Checkpoint, CheckpointIo};
use super::config::{Backend, TrainConfig};
use super::metrics::{EvalRow, MetricsLog, StepRow};
use crate::data::{streams, SynthCifar};
use crate::mls::quantizer::QuantConfig;
use crate::mls::Grouping;
use crate::nn::health::{self, DivergencePolicy, HealthMonitor, HealthRecord, Verdict};
use crate::nn::optim::parse_optimizer;
use crate::nn::train::{native_model, NativeModel, StepAudit};
use crate::runtime::Engine;
use crate::util::fault::{FaultArm, FaultSite, FaultSpec};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TrainResult {
    pub config: TrainConfig,
    pub metrics: MetricsLog,
    pub final_state: Vec<f32>,
    pub test_acc: f32,
    pub test_loss: f32,
    pub diverged: bool,
    /// roll-up of the run's audit stream: per-pass counters summed over
    /// every audited step (`layers` is left empty — the per-step stream
    /// lives in `<tag>.audit.jsonl`). All-default for fp32 runs and the
    /// pjrt backend, which collect no executed audit.
    pub audit_totals: StepAudit,
    /// number of steps that contributed to `audit_totals`
    pub audit_steps: u64,
    /// `Some(k)` when the run resumed from a step checkpoint at step `k`
    /// instead of starting at 0 (native backend only)
    pub resumed_from: Option<u64>,
    /// training steps this call actually executed (replays after a
    /// health rollback included; resumed-past steps excluded)
    pub steps_executed: u64,
    /// health-policy rollback recoveries performed during this call
    pub rollbacks: u64,
}

impl TrainResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<10} {:<24} steps {:<5} final-loss {:<8.4} test-acc {:.3}{}",
            self.config.model,
            self.config.cfg_name,
            self.config.steps,
            self.metrics.final_loss(20),
            self.test_acc,
            if self.diverged { "  [DIVERGED]" } else { "" }
        )
    }
}

/// The training-stream batch index for `step` (shared by both backends so
/// a seed names the same data order everywhere).
fn train_batch_index(config: &TrainConfig, step: u64) -> u64 {
    config.seed.wrapping_mul(1_000_003).wrapping_add(step)
}

/// The per-step stochastic-rounding seed (shared by both backends).
fn step_seed(config: &TrainConfig, step: u64) -> i32 {
    (config.seed as i32).wrapping_mul(7919) ^ step as i32
}

/// Evaluate `state` over `n_batches` of a data stream (PJRT backend).
pub fn evaluate(
    engine: &mut Engine,
    model: &str,
    state: &[f32],
    ds: &SynthCifar,
    stream: u64,
    n_batches: u64,
) -> Result<(f32, f32)> {
    let batch = engine.manifest.model(model)?.batch;
    let (mut loss_sum, mut acc_sum) = (0.0f64, 0.0f64);
    for i in 0..n_batches {
        let (images, labels) = ds.batch(batch, stream, i);
        let out = engine.eval_step(model, state, &images, &labels)?;
        loss_sum += out.loss as f64;
        acc_sum += out.acc as f64;
    }
    Ok(((loss_sum / n_batches as f64) as f32, (acc_sum / n_batches as f64) as f32))
}

/// Evaluate a native model over `n_batches` of a data stream
/// (deterministic nearest-rounding forward, no parameter changes).
pub fn evaluate_native(
    model: &NativeModel,
    ds: &SynthCifar,
    stream: u64,
    n_batches: u64,
    batch: usize,
) -> (f32, f32) {
    let n = n_batches.max(1);
    let (mut loss_sum, mut acc_sum) = (0.0f64, 0.0f64);
    for i in 0..n {
        let (images, labels) = ds.batch(batch, stream, i);
        let (loss, acc) = model.eval_batch(&images, &labels);
        loss_sum += loss as f64;
        acc_sum += acc as f64;
    }
    ((loss_sum / n as f64) as f32, (acc_sum / n as f64) as f32)
}

/// The run tag that names every per-run output file
/// (`<model>_<cfg>_s<seed>.csv` / `.state.bin` / `.audit.jsonl`).
pub fn run_tag(config: &TrainConfig) -> String {
    format!("{}_{}_s{}", config.model, config.cfg_name, config.seed)
}

/// Write the metrics CSV + raw-f32 checkpoint for a finished run (the
/// audit stream is written incrementally during the run by
/// [`AuditStream`]).
fn write_outputs(config: &TrainConfig, metrics: &MetricsLog, state: &[f32]) -> Result<()> {
    if let Some(dir) = &config.out_dir {
        let tag = run_tag(config);
        metrics.write_csv(std::path::Path::new(dir).join(format!("{tag}.csv")))?;
        let bytes: Vec<u8> = state.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(std::path::Path::new(dir).join(format!("{tag}.state.bin")), bytes)?;
    }
    Ok(())
}

/// Incremental writer for the per-layer audit stream
/// (`<tag>.audit.jsonl`, one `schemas/audit_step.schema.json` record per
/// line: per-layer `"train_step"` counters for audited steps, plus
/// `"health"` events from the numeric guard). Streams each record to
/// disk as the step finishes — a long grid run holds no audit backlog in
/// memory, and a killed run leaves the stream readable up to its last
/// completed step. The file is opened lazily on the first record, so
/// runs that audit nothing (fp32, or no `out_dir`) leave no file, as
/// before.
///
/// Step-level resume support: constructed with `resume_from = Some(k)`,
/// the stream is first truncated back to records with `step < k`
/// (appending then continues exactly where the checkpoint stops — no
/// duplicate or out-of-order step indices, which
/// `scripts/validate_bench.py --monotonic-steps` rejects); a health
/// rollback does the same through [`Self::truncate_to`].
struct AuditStream {
    path: Option<std::path::PathBuf>,
    file: Option<std::io::BufWriter<std::fs::File>>,
}

/// Durably rewrite a `.audit.jsonl` file keeping only the records whose
/// `step` is below `before` (unparseable lines — e.g. a torn tail from a
/// crash mid-write — are dropped too).
fn truncate_stream(path: &std::path::Path, before: u64) -> Result<()> {
    let text = std::fs::read_to_string(path)?;
    let mut kept = String::new();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let keep = Json::parse(line)
            .ok()
            .and_then(|j| j.get("step").and_then(|s| s.as_f64()))
            .is_some_and(|s| (s as u64) < before);
        if keep {
            kept.push_str(line);
            kept.push('\n');
        }
    }
    crate::util::fsio::write_atomic(path, kept.as_bytes())
}

impl AuditStream {
    fn new(config: &TrainConfig, resume_from: Option<u64>) -> Result<AuditStream> {
        let path = config
            .out_dir
            .as_ref()
            .map(|dir| std::path::Path::new(dir).join(format!("{}.audit.jsonl", run_tag(config))));
        if let Some(p) = &path {
            if p.exists() {
                match resume_from {
                    // resume: drop records at/after the checkpoint step
                    Some(k) => truncate_stream(p, k)?,
                    // fresh run: a stale stream from a previous run must
                    // not survive (the old truncating File::create only
                    // fired on the first record)
                    None => std::fs::remove_file(p)?,
                }
            }
        }
        Ok(AuditStream { path, file: None })
    }

    fn write_line(&mut self, line: &str) -> Result<()> {
        use std::io::Write;
        let Some(path) = &self.path else { return Ok(()) };
        if self.file.is_none() {
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            let f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
            self.file = Some(std::io::BufWriter::new(f));
        }
        let f = self.file.as_mut().expect("just created");
        f.write_all(line.as_bytes())?;
        f.write_all(b"\n")?;
        Ok(())
    }

    fn record(&mut self, config: &TrainConfig, step: u64, audit: &StepAudit) -> Result<()> {
        let line = audit
            .to_json(&config.model, &config.cfg_name, config.batch, step)
            .to_string_compact();
        self.write_line(&line)
    }

    /// Append a health event and flush it immediately — a crash right
    /// after a verdict must not lose the record explaining it.
    fn health(&mut self, config: &TrainConfig, rec: &HealthRecord) -> Result<()> {
        let line = rec.to_json(&config.model, &config.cfg_name).to_string_compact();
        self.write_line(&line)?;
        self.flush()
    }

    /// Rollback support: drop every record at/after `step` (the stream
    /// is re-opened for append on the next record).
    fn truncate_to(&mut self, step: u64) -> Result<()> {
        self.flush()?;
        self.file = None;
        if let Some(p) = &self.path {
            if p.exists() {
                truncate_stream(p, step)?;
            }
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        use std::io::Write;
        if let Some(f) = &mut self.file {
            f.flush()?;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.flush()
    }
}

/// Validate a native-backend config BEFORE any model construction: an
/// unknown model name, an unsupported scaling grouping or an unknown
/// optimizer fails here with an error listing the supported values,
/// instead of erroring somewhere mid-construction. Each check delegates
/// to its single source of truth (`zoo::native_network`,
/// `QuantConfig::parse_name`, `optim::parse_optimizer`) so the supported
/// sets and error messages cannot drift.
pub fn validate_native_config(config: &TrainConfig) -> Result<QuantConfig> {
    crate::nn::zoo::native_network(&config.model)?;
    let qcfg = QuantConfig::parse_name(&config.cfg_name)?;
    // mirrors the construction-time guard in nn::train::native_model
    anyhow::ensure!(
        !qcfg.enabled || qcfg.grouping == Grouping::Both,
        "the native backend requires nc grouping (grouping=both) for quantized configs, \
         got {:?} in {:?} — run grouping ablations on the pjrt backend",
        qcfg.grouping,
        config.cfg_name
    );
    parse_optimizer(&config.optimizer, config.momentum, config.weight_decay)?;
    DivergencePolicy::parse(&config.on_divergence)?;
    anyhow::ensure!(
        config.divergence_factor.is_finite() && config.divergence_factor > 1.0,
        "divergence_factor must be a finite value > 1, got {}",
        config.divergence_factor
    );
    if let Some(spec) = &config.fault {
        FaultSpec::parse(spec)?;
    }
    Ok(qcfg)
}

/// Run one full training experiment on the backend `config` selects.
/// With `backend=native` the engine is not touched (it may be a
/// manifest-only stub); with `backend=pjrt` it must hold compiled
/// artifacts.
pub fn train(engine: &mut Engine, config: &TrainConfig) -> Result<TrainResult> {
    if config.backend == Backend::Native {
        return train_native(config);
    }
    let model = config.model.clone();
    let meta = engine.manifest.model(&model)?.clone();
    let ds = SynthCifar::new(config.data.clone());
    anyhow::ensure!(
        ds.sample_elems() == meta.img_shape.iter().product::<usize>(),
        "dataset image shape {:?} != artifact {:?}",
        (ds.cfg.channels, ds.cfg.height, ds.cfg.width),
        meta.img_shape
    );

    let mut state = engine.manifest.load_init(&model)?;
    let mut metrics = MetricsLog::default();

    for step in 0..config.steps {
        let (images, labels) = ds.batch(meta.batch, streams::TRAIN, train_batch_index(config, step));
        let lr = config.lr.at(step);
        let seed = step_seed(config, step);
        let t0 = Instant::now();
        let out = engine.train_step(&model, &config.cfg_name, &mut state, &images, &labels, seed, lr)?;
        metrics.record_step(StepRow {
            step,
            lr,
            loss: out.loss,
            acc: out.acc,
            step_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
        if !out.loss.is_finite() {
            break; // diverged — stop early, record as such (Table IV "Div.")
        }
        if config.eval_every > 0 && (step + 1) % config.eval_every == 0 {
            let (eloss, eacc) =
                evaluate(engine, &model, &state, &ds, streams::VAL, config.eval_batches)?;
            metrics.record_eval(EvalRow { step, loss: eloss, acc: eacc });
        }
    }

    let diverged = metrics.diverged();
    let (test_loss, test_acc) = if diverged {
        (f32::NAN, 0.0)
    } else {
        evaluate(engine, &model, &state, &ds, streams::TEST, config.eval_batches)?
    };

    write_outputs(config, &metrics, &state)?;

    Ok(TrainResult {
        config: config.clone(),
        metrics,
        final_state: state,
        test_acc,
        test_loss,
        diverged,
        audit_totals: StepAudit::default(),
        audit_steps: 0,
        resumed_from: None,
        steps_executed: config.steps,
        rollbacks: 0,
    })
}

/// Snapshot the full step-loop state at a step boundary (`next_step` =
/// the first step a resume would execute). Doubles as the in-memory
/// rollback anchor, so health rollbacks work even with
/// `checkpoint_every = 0` (they rewind to the run start / resume point).
#[allow(clippy::too_many_arguments)]
fn make_snapshot(
    next_step: u64,
    model: &NativeModel,
    metrics: &MetricsLog,
    audit_totals: &StepAudit,
    audit_steps: u64,
    lr_scale: f32,
    rollbacks: u64,
    monitor: &HealthMonitor,
    config_echo: &str,
) -> Checkpoint {
    let (health_best_loss, health_streak) = monitor.state();
    Checkpoint {
        next_step,
        state: model.state(),
        opt_name: model.optimizer_name().to_string(),
        opt_state: model.optimizer_state(),
        lr_scale,
        rollbacks,
        health_best_loss,
        health_streak,
        steps: metrics.steps.clone(),
        evals: metrics.evals.clone(),
        audit_steps,
        audit_totals: StepAudit {
            forward: audit_totals.forward,
            wgrad: audit_totals.wgrad,
            dgrad: audit_totals.dgrad,
            layers: Vec::new(),
        },
        config_echo: config_echo.to_string(),
    }
}

/// Run one full training experiment on the NATIVE backend: synthetic
/// CIFAR -> per-layer Alg. 1 low-bit forward/backward on the module
/// graph -> optimizer update, end to end in this crate — no PJRT, no
/// artifacts, no Python. With `out_dir` set, the per-layer audit stream
/// of every step is written alongside the metrics CSV as
/// `<tag>.audit.jsonl`.
///
/// Fault tolerance (PR 8): with `checkpoint_every > 0` (and `out_dir`
/// set) the full step-loop state is checkpointed durably every N steps
/// ([`super::checkpoint`]); with `resume = true` (default) a valid
/// checkpoint matching this exact config is loaded and the run continues
/// from its step — **bit-identical** to an uninterrupted run, because
/// every per-step random source is a pure function of `(config, step)`
/// and everything else rides in the checkpoint. A per-step numeric
/// health guard ([`crate::nn::health`]) checks loss/gradients before
/// each update and reacts per `on_divergence`
/// (abort | rollback | halve_lr); deterministic faults for testing all
/// of this come from `config.fault` or `MLS_FAULT`
/// ([`crate::util::fault`]).
pub fn train_native(config: &TrainConfig) -> Result<TrainResult> {
    // audit reproducibility: record which Eq. 7 microkernel (scalar or
    // which vector ISA) produced this run's numbers — they are all
    // bit-identical, but the log line pins what actually ran
    crate::util::simd::log_once();
    let qcfg = validate_native_config(config)?;
    let policy = DivergencePolicy::parse(&config.on_divergence)?;
    // in-process spec (tests; never part of the config echo) falls back
    // to the MLS_FAULT environment variable (CLI / CI)
    let fault_spec = match &config.fault {
        Some(s) => Some(FaultSpec::parse(s)?),
        None => FaultSpec::from_env()?,
    };
    let mut fault = FaultArm::new(fault_spec);

    let ds = SynthCifar::new(config.data.clone());
    let mut model = native_model(&config.model, qcfg, config.seed)?;
    model.set_optimizer(parse_optimizer(
        &config.optimizer,
        config.momentum,
        config.weight_decay,
    )?);
    let (c, h, w) = model.input;
    anyhow::ensure!(
        ds.sample_elems() == c * h * w,
        "dataset image shape {:?} != native model input {:?}",
        (ds.cfg.channels, ds.cfg.height, ds.cfg.width),
        model.input
    );

    let config_echo = config.to_json().to_string_compact();
    let ckpt_io = config
        .out_dir
        .as_ref()
        .map(|dir| CheckpointIo::new(std::path::Path::new(dir), &run_tag(config)));

    let mut metrics = MetricsLog::default();
    let mut audit_totals = StepAudit::default();
    let mut audit_steps = 0u64;
    let mut lr_scale = 1.0f32;
    let mut rollbacks = 0u64;
    let mut monitor = HealthMonitor::new(config.divergence_window, config.divergence_factor);
    let mut resumed_from: Option<u64> = None;

    if config.resume {
        if let Some(io) = &ckpt_io {
            if let Some(ckpt) = io.load_for_resume(&config_echo) {
                model.load_state(&ckpt.state)?;
                model.load_optimizer_state(&ckpt.opt_state)?;
                metrics.steps = ckpt.steps;
                metrics.evals = ckpt.evals;
                audit_totals = ckpt.audit_totals;
                audit_steps = ckpt.audit_steps;
                lr_scale = ckpt.lr_scale;
                rollbacks = ckpt.rollbacks;
                monitor.restore(ckpt.health_best_loss, ckpt.health_streak);
                resumed_from = Some(ckpt.next_step);
            }
        }
    }
    let start_step = resumed_from.unwrap_or(0);
    let mut audit_stream = AuditStream::new(config, resumed_from)?;

    // the rollback anchor: refreshed at every checkpoint boundary; until
    // then it holds the run start (or resume point)
    let mut last_good = make_snapshot(
        start_step,
        &model,
        &metrics,
        &audit_totals,
        audit_steps,
        lr_scale,
        rollbacks,
        &monitor,
        &config_echo,
    );

    let mut step = start_step;
    let mut steps_executed = 0u64;
    let mut health_aborted = false;
    while step < config.steps {
        let (images, labels) = ds.batch(config.batch, streams::TRAIN, train_batch_index(config, step));
        let lr = config.lr.at(step) * lr_scale;
        let seed = step_seed(config, step) as i64;
        let t0 = Instant::now();
        // the zero-alloc arena path (PR 9), split so the health guard and
        // fault injection see the gradients BEFORE the update commits:
        // forward/backward into the step arena, grads parked in the
        // model's step scratch until finish/discard below
        let (loss, acc) = model.forward_backward_quiet(&images, &labels, seed);
        steps_executed += 1;
        fault.poison_grads(step, model.step_grads_mut());
        let gstats = health::grad_stats(model.step_grads());
        let verdict = monitor.check(loss, &gstats);
        let streak = monitor.state().1;

        if let Some(verdict) = verdict {
            // a fault the anchor cannot clear replays deterministically
            // forever — cap the recoveries, then give up like `abort`
            if policy == DivergencePolicy::Abort || rollbacks >= health::MAX_ROLLBACKS {
                if verdict == Verdict::NonFiniteLoss {
                    // legacy diverged-run shape: the update ran before
                    // the loss check (pre-PR-8 `train_step` semantics)
                    model.finish_step_quiet(lr);
                } else {
                    model.discard_step_quiet();
                }
                metrics.record_step(StepRow {
                    step,
                    lr,
                    loss,
                    acc,
                    step_ms: t0.elapsed().as_secs_f64() * 1e3,
                });
                if let Some(step_audit) = model.last_audit() {
                    if !step_audit.layers.is_empty() {
                        audit_totals.merge_totals(step_audit);
                        audit_steps += 1;
                        audit_stream.record(config, step, step_audit)?;
                    }
                }
                audit_stream.health(
                    config,
                    &HealthRecord {
                        step,
                        verdict,
                        action: "abort",
                        loss,
                        grad_nonfinite: gstats.nonfinite,
                        grad_max_abs: gstats.max_abs,
                        streak,
                        rollback_to: None,
                        lr_scale,
                    },
                )?;
                health_aborted = true;
                break; // diverged — stop early, record as such (Table IV "Div.")
            }

            // rollback / halve_lr recovery: restore the anchor, rewind
            // the accumulators and the on-disk stream, replay. lr_scale
            // and the rollback count deliberately survive the restore —
            // repeated halvings must compound, and the cap must bind.
            rollbacks += 1;
            if policy == DivergencePolicy::HalveLr {
                lr_scale *= 0.5;
            }
            let target = last_good.next_step;
            model.discard_step_quiet();
            model.load_state(&last_good.state)?;
            model.load_optimizer_state(&last_good.opt_state)?;
            metrics.steps = last_good.steps.clone();
            metrics.evals = last_good.evals.clone();
            audit_totals = StepAudit {
                forward: last_good.audit_totals.forward,
                wgrad: last_good.audit_totals.wgrad,
                dgrad: last_good.audit_totals.dgrad,
                layers: Vec::new(),
            };
            audit_steps = last_good.audit_steps;
            monitor.restore(last_good.health_best_loss, last_good.health_streak);
            audit_stream.truncate_to(target)?;
            audit_stream.health(
                config,
                &HealthRecord {
                    step,
                    verdict,
                    action: policy.name(),
                    loss,
                    grad_nonfinite: gstats.nonfinite,
                    grad_max_abs: gstats.max_abs,
                    streak,
                    rollback_to: Some(target),
                    lr_scale,
                },
            )?;
            step = target;
            continue;
        }

        model.finish_step_quiet(lr);
        metrics.record_step(StepRow {
            step,
            lr,
            loss,
            acc,
            step_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
        // fp32 runs execute no quantized convs, so they have no audit
        // stream (a record with an empty layer list would be vacuous)
        if let Some(step_audit) = model.last_audit() {
            if !step_audit.layers.is_empty() {
                audit_totals.merge_totals(step_audit);
                audit_steps += 1;
                audit_stream.record(config, step, step_audit)?;
            }
        }
        // the eval must precede the checkpoint: its row belongs to this
        // step, and a resume at step+1 would otherwise never produce it
        if config.eval_every > 0 && (step + 1) % config.eval_every == 0 {
            let (eloss, eacc) =
                evaluate_native(&model, &ds, streams::VAL, config.eval_batches, config.batch);
            metrics.record_eval(EvalRow { step, loss: eloss, acc: eacc });
        }
        fault.crash_point(FaultSite::CrashBeforeCkpt, step)?;
        if config.checkpoint_every > 0 && (step + 1) % config.checkpoint_every == 0 {
            let snap = make_snapshot(
                step + 1,
                &model,
                &metrics,
                &audit_totals,
                audit_steps,
                lr_scale,
                rollbacks,
                &monitor,
                &config_echo,
            );
            if let Some(io) = &ckpt_io {
                // the on-disk stream must cover every step the
                // checkpoint claims before the checkpoint exists
                audit_stream.flush()?;
                io.save(&snap)?;
                if fault.corrupt_due(step) {
                    io.corrupt_latest()?;
                }
            }
            last_good = snap;
        }
        fault.crash_point(FaultSite::CrashAfterCkpt, step)?;
        step += 1;
    }

    let diverged = metrics.diverged() || health_aborted;
    let (test_loss, test_acc) = if diverged {
        (f32::NAN, 0.0)
    } else {
        evaluate_native(&model, &ds, streams::TEST, config.eval_batches, config.batch)
    };

    let state = model.state();
    audit_stream.finish()?;
    write_outputs(config, &metrics, &state)?;

    Ok(TrainResult {
        config: config.clone(),
        metrics,
        final_state: state,
        test_acc,
        test_loss,
        diverged,
        audit_totals,
        audit_steps,
        resumed_from,
        steps_executed,
        rollbacks,
    })
}
