//! The training loop: dataset -> PJRT train-step artifact -> metrics.
//!
//! One `train()` call is one experiment run (one model x one quant config x
//! one seed); the Table II / Table IV harnesses call it in a grid.

use std::time::Instant;

use anyhow::Result;

use super::config::TrainConfig;
use super::metrics::{EvalRow, MetricsLog, StepRow};
use crate::data::{streams, SynthCifar};
use crate::runtime::Engine;

#[derive(Clone, Debug)]
pub struct TrainResult {
    pub config: TrainConfig,
    pub metrics: MetricsLog,
    pub final_state: Vec<f32>,
    pub test_acc: f32,
    pub test_loss: f32,
    pub diverged: bool,
}

impl TrainResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<10} {:<24} steps {:<5} final-loss {:<8.4} test-acc {:.3}{}",
            self.config.model,
            self.config.cfg_name,
            self.config.steps,
            self.metrics.final_loss(20),
            self.test_acc,
            if self.diverged { "  [DIVERGED]" } else { "" }
        )
    }
}

/// Evaluate `state` over `n_batches` of a data stream.
pub fn evaluate(
    engine: &mut Engine,
    model: &str,
    state: &[f32],
    ds: &SynthCifar,
    stream: u64,
    n_batches: u64,
) -> Result<(f32, f32)> {
    let batch = engine.manifest.model(model)?.batch;
    let (mut loss_sum, mut acc_sum) = (0.0f64, 0.0f64);
    for i in 0..n_batches {
        let (images, labels) = ds.batch(batch, stream, i);
        let out = engine.eval_step(model, state, &images, &labels)?;
        loss_sum += out.loss as f64;
        acc_sum += out.acc as f64;
    }
    Ok(((loss_sum / n_batches as f64) as f32, (acc_sum / n_batches as f64) as f32))
}

/// Run one full training experiment.
pub fn train(engine: &mut Engine, config: &TrainConfig) -> Result<TrainResult> {
    let model = config.model.clone();
    let meta = engine.manifest.model(&model)?.clone();
    let ds = SynthCifar::new(config.data.clone());
    anyhow::ensure!(
        ds.sample_elems() == meta.img_shape.iter().product::<usize>(),
        "dataset image shape {:?} != artifact {:?}",
        (ds.cfg.channels, ds.cfg.height, ds.cfg.width),
        meta.img_shape
    );

    let mut state = engine.manifest.load_init(&model)?;
    let mut metrics = MetricsLog::default();

    for step in 0..config.steps {
        let (images, labels) = ds.batch(meta.batch, streams::TRAIN, config.seed.wrapping_mul(1_000_003).wrapping_add(step));
        let lr = config.lr.at(step);
        let seed = (config.seed as i32).wrapping_mul(7919) ^ step as i32;
        let t0 = Instant::now();
        let out = engine.train_step(&model, &config.cfg_name, &mut state, &images, &labels, seed, lr)?;
        metrics.record_step(StepRow {
            step,
            lr,
            loss: out.loss,
            acc: out.acc,
            step_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
        if !out.loss.is_finite() {
            break; // diverged — stop early, record as such (Table IV "Div.")
        }
        if config.eval_every > 0 && (step + 1) % config.eval_every == 0 {
            let (eloss, eacc) =
                evaluate(engine, &model, &state, &ds, streams::VAL, config.eval_batches)?;
            metrics.record_eval(EvalRow { step, loss: eloss, acc: eacc });
        }
    }

    let diverged = metrics.diverged();
    let (test_loss, test_acc) = if diverged {
        (f32::NAN, 0.0)
    } else {
        evaluate(engine, &model, &state, &ds, streams::TEST, config.eval_batches)?
    };

    if let Some(dir) = &config.out_dir {
        let tag = format!("{}_{}_s{}", model, config.cfg_name, config.seed);
        metrics.write_csv(std::path::Path::new(dir).join(format!("{tag}.csv")))?;
        // checkpoint: raw f32 LE state vector
        let bytes: Vec<u8> = state.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(std::path::Path::new(dir).join(format!("{tag}.state.bin")), bytes)?;
    }

    Ok(TrainResult {
        config: config.clone(),
        metrics,
        final_state: state,
        test_acc,
        test_loss,
        diverged,
    })
}
