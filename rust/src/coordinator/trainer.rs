//! The training loop: dataset -> train step -> metrics, over either of
//! two backends:
//!
//! * **native** (default) — the in-crate Alg. 1 trainer
//!   ([`crate::nn::train`]) over the composable module graph
//!   ([`crate::nn::graph`]): quantized forward / weight-gradient /
//!   input-gradient convs on the pass-generic packed-GEMM engine
//!   (residual joins included), BN / ReLU / FC and the pluggable
//!   optimizer (SGD / momentum) in f32, zero external dependencies;
//! * **pjrt** — the AOT train-step artifacts through the PJRT engine
//!   (needs `make artifacts` + the `pjrt` cargo feature).
//!
//! One `train()` call is one experiment run (one model x one quant config
//! x one seed); the Table II / Table IV harnesses call it in a grid. Both
//! backends share the step/seed/lr derivations, the metrics log, and the
//! CSV/checkpoint outputs, so runs are comparable across backends.

use std::time::Instant;

use anyhow::Result;

use super::config::{Backend, TrainConfig};
use super::metrics::{EvalRow, MetricsLog, StepRow};
use crate::data::{streams, SynthCifar};
use crate::mls::quantizer::QuantConfig;
use crate::mls::Grouping;
use crate::nn::optim::parse_optimizer;
use crate::nn::train::{native_model, NativeModel, StepAudit};
use crate::runtime::Engine;

#[derive(Clone, Debug)]
pub struct TrainResult {
    pub config: TrainConfig,
    pub metrics: MetricsLog,
    pub final_state: Vec<f32>,
    pub test_acc: f32,
    pub test_loss: f32,
    pub diverged: bool,
    /// roll-up of the run's audit stream: per-pass counters summed over
    /// every audited step (`layers` is left empty — the per-step stream
    /// lives in `<tag>.audit.jsonl`). All-default for fp32 runs and the
    /// pjrt backend, which collect no executed audit.
    pub audit_totals: StepAudit,
    /// number of steps that contributed to `audit_totals`
    pub audit_steps: u64,
}

impl TrainResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<10} {:<24} steps {:<5} final-loss {:<8.4} test-acc {:.3}{}",
            self.config.model,
            self.config.cfg_name,
            self.config.steps,
            self.metrics.final_loss(20),
            self.test_acc,
            if self.diverged { "  [DIVERGED]" } else { "" }
        )
    }
}

/// The training-stream batch index for `step` (shared by both backends so
/// a seed names the same data order everywhere).
fn train_batch_index(config: &TrainConfig, step: u64) -> u64 {
    config.seed.wrapping_mul(1_000_003).wrapping_add(step)
}

/// The per-step stochastic-rounding seed (shared by both backends).
fn step_seed(config: &TrainConfig, step: u64) -> i32 {
    (config.seed as i32).wrapping_mul(7919) ^ step as i32
}

/// Evaluate `state` over `n_batches` of a data stream (PJRT backend).
pub fn evaluate(
    engine: &mut Engine,
    model: &str,
    state: &[f32],
    ds: &SynthCifar,
    stream: u64,
    n_batches: u64,
) -> Result<(f32, f32)> {
    let batch = engine.manifest.model(model)?.batch;
    let (mut loss_sum, mut acc_sum) = (0.0f64, 0.0f64);
    for i in 0..n_batches {
        let (images, labels) = ds.batch(batch, stream, i);
        let out = engine.eval_step(model, state, &images, &labels)?;
        loss_sum += out.loss as f64;
        acc_sum += out.acc as f64;
    }
    Ok(((loss_sum / n_batches as f64) as f32, (acc_sum / n_batches as f64) as f32))
}

/// Evaluate a native model over `n_batches` of a data stream
/// (deterministic nearest-rounding forward, no parameter changes).
pub fn evaluate_native(
    model: &NativeModel,
    ds: &SynthCifar,
    stream: u64,
    n_batches: u64,
    batch: usize,
) -> (f32, f32) {
    let n = n_batches.max(1);
    let (mut loss_sum, mut acc_sum) = (0.0f64, 0.0f64);
    for i in 0..n {
        let (images, labels) = ds.batch(batch, stream, i);
        let (loss, acc) = model.eval_batch(&images, &labels);
        loss_sum += loss as f64;
        acc_sum += acc as f64;
    }
    ((loss_sum / n as f64) as f32, (acc_sum / n as f64) as f32)
}

/// The run tag that names every per-run output file
/// (`<model>_<cfg>_s<seed>.csv` / `.state.bin` / `.audit.jsonl`).
pub fn run_tag(config: &TrainConfig) -> String {
    format!("{}_{}_s{}", config.model, config.cfg_name, config.seed)
}

/// Write the metrics CSV + raw-f32 checkpoint for a finished run (the
/// audit stream is written incrementally during the run by
/// [`AuditStream`]).
fn write_outputs(config: &TrainConfig, metrics: &MetricsLog, state: &[f32]) -> Result<()> {
    if let Some(dir) = &config.out_dir {
        let tag = run_tag(config);
        metrics.write_csv(std::path::Path::new(dir).join(format!("{tag}.csv")))?;
        let bytes: Vec<u8> = state.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(std::path::Path::new(dir).join(format!("{tag}.state.bin")), bytes)?;
    }
    Ok(())
}

/// Incremental writer for the per-layer audit stream
/// (`<tag>.audit.jsonl`, one `schemas/audit_step.schema.json` record per
/// line per audited step). Streams each record to disk as the step
/// finishes — a long grid run holds no audit backlog in memory, and a
/// killed run leaves the stream readable up to its last completed step.
/// The file is created lazily on the first record, so runs that audit
/// nothing (fp32, or no `out_dir`) leave no file, as before.
struct AuditStream {
    path: Option<std::path::PathBuf>,
    file: Option<std::io::BufWriter<std::fs::File>>,
}

impl AuditStream {
    fn new(config: &TrainConfig) -> AuditStream {
        let path = config
            .out_dir
            .as_ref()
            .map(|dir| std::path::Path::new(dir).join(format!("{}.audit.jsonl", run_tag(config))));
        AuditStream { path, file: None }
    }

    fn record(&mut self, config: &TrainConfig, step: u64, audit: &StepAudit) -> Result<()> {
        use std::io::Write;
        let Some(path) = &self.path else { return Ok(()) };
        if self.file.is_none() {
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)?;
            }
            self.file = Some(std::io::BufWriter::new(std::fs::File::create(path)?));
        }
        let line = audit
            .to_json(&config.model, &config.cfg_name, config.batch, step)
            .to_string_compact();
        let f = self.file.as_mut().expect("just created");
        f.write_all(line.as_bytes())?;
        f.write_all(b"\n")?;
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        use std::io::Write;
        if let Some(f) = &mut self.file {
            f.flush()?;
        }
        Ok(())
    }
}

/// Validate a native-backend config BEFORE any model construction: an
/// unknown model name, an unsupported scaling grouping or an unknown
/// optimizer fails here with an error listing the supported values,
/// instead of erroring somewhere mid-construction. Each check delegates
/// to its single source of truth (`zoo::native_network`,
/// `QuantConfig::parse_name`, `optim::parse_optimizer`) so the supported
/// sets and error messages cannot drift.
pub fn validate_native_config(config: &TrainConfig) -> Result<QuantConfig> {
    crate::nn::zoo::native_network(&config.model)?;
    let qcfg = QuantConfig::parse_name(&config.cfg_name)?;
    // mirrors the construction-time guard in nn::train::native_model
    anyhow::ensure!(
        !qcfg.enabled || qcfg.grouping == Grouping::Both,
        "the native backend requires nc grouping (grouping=both) for quantized configs, \
         got {:?} in {:?} — run grouping ablations on the pjrt backend",
        qcfg.grouping,
        config.cfg_name
    );
    parse_optimizer(&config.optimizer, config.momentum, config.weight_decay)?;
    Ok(qcfg)
}

/// Run one full training experiment on the backend `config` selects.
/// With `backend=native` the engine is not touched (it may be a
/// manifest-only stub); with `backend=pjrt` it must hold compiled
/// artifacts.
pub fn train(engine: &mut Engine, config: &TrainConfig) -> Result<TrainResult> {
    if config.backend == Backend::Native {
        return train_native(config);
    }
    let model = config.model.clone();
    let meta = engine.manifest.model(&model)?.clone();
    let ds = SynthCifar::new(config.data.clone());
    anyhow::ensure!(
        ds.sample_elems() == meta.img_shape.iter().product::<usize>(),
        "dataset image shape {:?} != artifact {:?}",
        (ds.cfg.channels, ds.cfg.height, ds.cfg.width),
        meta.img_shape
    );

    let mut state = engine.manifest.load_init(&model)?;
    let mut metrics = MetricsLog::default();

    for step in 0..config.steps {
        let (images, labels) = ds.batch(meta.batch, streams::TRAIN, train_batch_index(config, step));
        let lr = config.lr.at(step);
        let seed = step_seed(config, step);
        let t0 = Instant::now();
        let out = engine.train_step(&model, &config.cfg_name, &mut state, &images, &labels, seed, lr)?;
        metrics.record_step(StepRow {
            step,
            lr,
            loss: out.loss,
            acc: out.acc,
            step_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
        if !out.loss.is_finite() {
            break; // diverged — stop early, record as such (Table IV "Div.")
        }
        if config.eval_every > 0 && (step + 1) % config.eval_every == 0 {
            let (eloss, eacc) =
                evaluate(engine, &model, &state, &ds, streams::VAL, config.eval_batches)?;
            metrics.record_eval(EvalRow { step, loss: eloss, acc: eacc });
        }
    }

    let diverged = metrics.diverged();
    let (test_loss, test_acc) = if diverged {
        (f32::NAN, 0.0)
    } else {
        evaluate(engine, &model, &state, &ds, streams::TEST, config.eval_batches)?
    };

    write_outputs(config, &metrics, &state)?;

    Ok(TrainResult {
        config: config.clone(),
        metrics,
        final_state: state,
        test_acc,
        test_loss,
        diverged,
        audit_totals: StepAudit::default(),
        audit_steps: 0,
    })
}

/// Run one full training experiment on the NATIVE backend: synthetic
/// CIFAR -> per-layer Alg. 1 low-bit forward/backward on the module
/// graph -> optimizer update, end to end in this crate — no PJRT, no
/// artifacts, no Python. With `out_dir` set, the per-layer audit stream
/// of every step is written alongside the metrics CSV as
/// `<tag>.audit.jsonl`.
pub fn train_native(config: &TrainConfig) -> Result<TrainResult> {
    // audit reproducibility: record which Eq. 7 microkernel (scalar or
    // which vector ISA) produced this run's numbers — they are all
    // bit-identical, but the log line pins what actually ran
    crate::util::simd::log_once();
    let qcfg = validate_native_config(config)?;
    let ds = SynthCifar::new(config.data.clone());
    let mut model = native_model(&config.model, qcfg, config.seed)?;
    model.set_optimizer(parse_optimizer(
        &config.optimizer,
        config.momentum,
        config.weight_decay,
    )?);
    let (c, h, w) = model.input;
    anyhow::ensure!(
        ds.sample_elems() == c * h * w,
        "dataset image shape {:?} != native model input {:?}",
        (ds.cfg.channels, ds.cfg.height, ds.cfg.width),
        model.input
    );

    let mut metrics = MetricsLog::default();
    let mut audit_stream = AuditStream::new(config);
    let mut audit_totals = StepAudit::default();
    let mut audit_steps = 0u64;
    for step in 0..config.steps {
        let (images, labels) = ds.batch(config.batch, streams::TRAIN, train_batch_index(config, step));
        let lr = config.lr.at(step);
        let seed = step_seed(config, step) as i64;
        let t0 = Instant::now();
        let out = model.train_step(&images, &labels, lr, seed);
        metrics.record_step(StepRow {
            step,
            lr,
            loss: out.loss,
            acc: out.acc,
            step_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
        // fp32 runs execute no quantized convs, so they have no audit
        // stream (a record with an empty layer list would be vacuous)
        if !out.audit.layers.is_empty() {
            audit_totals.merge_totals(&out.audit);
            audit_steps += 1;
            audit_stream.record(config, step, &out.audit)?;
        }
        if !out.loss.is_finite() {
            break; // diverged — stop early, record as such (Table IV "Div.")
        }
        if config.eval_every > 0 && (step + 1) % config.eval_every == 0 {
            let (eloss, eacc) =
                evaluate_native(&model, &ds, streams::VAL, config.eval_batches, config.batch);
            metrics.record_eval(EvalRow { step, loss: eloss, acc: eacc });
        }
    }

    let diverged = metrics.diverged();
    let (test_loss, test_acc) = if diverged {
        (f32::NAN, 0.0)
    } else {
        evaluate_native(&model, &ds, streams::TEST, config.eval_batches, config.batch)
    };

    let state = model.state();
    audit_stream.finish()?;
    write_outputs(config, &metrics, &state)?;

    Ok(TrainResult {
        config: config.clone(),
        metrics,
        final_state: state,
        test_acc,
        test_loss,
        diverged,
        audit_totals,
        audit_steps,
    })
}
