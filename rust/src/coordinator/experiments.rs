//! Experiment registry: one runnable harness per paper table/figure.
//!
//! `run(exp, artifacts_dir, overrides)` regenerates the table/figure and
//! returns the report text (also printed by the CLI). Analytic experiments
//! (Table I/V/VI, Fig. 2 energy, Eq. 12) need no artifacts; training
//! experiments (Table II/III sensitivity/IV, Fig. 6/7) drive the PJRT
//! engine. See DESIGN.md "Experiment index".

use anyhow::{anyhow, Result};

use super::config::TrainConfig;
use super::trainer::{train, TrainResult};
use crate::data::streams;
use crate::hw::report;
use crate::hw::units::EnergyModel;
use crate::mls::format::EmFormat;
use crate::mls::{error as qerror, Grouping, QuantConfig, Rounding};
use crate::runtime::Engine;

pub const EXPERIMENTS: &[&str] = &[
    "table1", "table2", "table3", "table4", "table5", "table6",
    "fig2", "fig6", "fig7", "eq12", "ratios",
];

/// Entry point used by the CLI and the examples.
pub fn run(exp: &str, artifacts_dir: &str, overrides: &[String]) -> Result<String> {
    let em = EnergyModel::fitted();
    let fmt = EmFormat::new(2, 4);
    match exp {
        "table1" => report::table1(64),
        "table5" => Ok(report::table5(&em)),
        "table6" => report::table6("resnet34", 64, fmt, &em),
        "eq12" => Ok(report::eq12(&em, fmt)),
        "ratios" => report::ratios(64, fmt, &em),
        "fig2" => fig2(artifacts_dir, overrides, &em, fmt),
        "table2" => table2(artifacts_dir, overrides),
        "table3" => table3(artifacts_dir, overrides),
        "table4" => table4(artifacts_dir, overrides),
        "fig6" => fig6(artifacts_dir, overrides),
        "fig7" => fig7(artifacts_dir, overrides),
        _ => Err(anyhow!("unknown experiment {exp:?}; have {EXPERIMENTS:?}")),
    }
}

fn base_config(overrides: &[String]) -> Result<TrainConfig> {
    let mut c = TrainConfig::default();
    // the training experiments historically target the PJRT artifacts
    // (their models include the residual resnet_t); pass backend=native
    // to run an experiment on the native Alg. 1 trainer instead
    c.backend = super::config::Backend::Pjrt;
    c.out_dir = Some("runs".to_string());
    for kv in overrides {
        c.set(kv)?;
    }
    Ok(c)
}

fn run_one(engine: &mut Engine, base: &TrainConfig, model: &str, cfg_name: &str) -> Result<TrainResult> {
    let mut c = base.clone();
    c.model = model.to_string();
    c.cfg_name = cfg_name.to_string();
    let r = train(engine, &c)?;
    eprintln!("[exp] {}", r.summary());
    Ok(r)
}

// -------------------------------------------------------------------------
// Table II — accuracy of low-bit training across models / formats (scaled)
// -------------------------------------------------------------------------

fn table2(artifacts_dir: &str, overrides: &[String]) -> Result<String> {
    let base = base_config(overrides)?;
    let mut engine = Engine::from_dir(artifacts_dir)?;
    let mut out = String::new();
    out.push_str(&format!(
        "Table II (scaled) — synthcifar, {} steps, seed {}\n\
         paper shape to reproduce: fp32 ~ <2,4> ~ <2,1> (drop <~1%), fixed-point\n\
         (E=0) worse, very low fixed-point much worse / diverging\n",
        base.steps, base.seed
    ));
    out.push_str(&format!(
        "{:<10} {:<26} {:>9} {:>10} {:>10}\n",
        "model", "bit-width (W/A/E)", "test acc", "fp32 base", "acc drop"
    ));
    // the paper's Table II format set (core configs; the full ablation grid
    // belongs to Table IV)
    let core = [
        "fp32",
        "e2m4_gnc_eg8mg1_sr",   // ImageNet headline <2,4>
        "e2m1_gnc_eg8mg1_sr",   // CIFAR headline <2,1>
        "e1m1_gnc_eg8mg1_sr",   // <1,1> / 8-bit accumulation row
        "e2m3_gnc_eg8mg1_sr",   // 6-bit (Table III sensitivity)
        "e0m4_gnc_eg8mg1_sr",   // fixed-point 4 ("4 4 4" row)
        "e0m2_gnc_eg8mg1_sr",   // fixed-point 2 ("2 2 2" row)
    ];
    for model in ["resnet_t", "cnn_s"] {
        let names: Vec<String> = core
            .iter()
            .filter(|n| engine.manifest.find(model, "train_step", n).is_ok())
            .map(|n| n.to_string())
            .collect();

        let mut baseline: Option<f32> = None;
        for cfg_name in &names {
            let r = run_one(&mut engine, &base, model, cfg_name)?;
            if cfg_name == "fp32" {
                baseline = Some(r.test_acc);
            }
            let base_acc = baseline.unwrap_or(f32::NAN);
            let drop = if r.diverged { "Div.".to_string() } else {
                format!("{:+.2}%", (base_acc - r.test_acc) * 100.0)
            };
            let acc = if r.diverged { "Div.".to_string() } else { format!("{:.3}", r.test_acc) };
            out.push_str(&format!(
                "{:<10} {:<26} {:>9} {:>10.3} {:>10}\n",
                model, cfg_name, acc, base_acc, drop
            ));
        }
    }
    Ok(out)
}

// -------------------------------------------------------------------------
// Table III — GOPs (exact) + 6-bit training sensitivity (scaled)
// -------------------------------------------------------------------------

fn table3(artifacts_dir: &str, overrides: &[String]) -> Result<String> {
    let mut out = String::new();
    out.push_str("Table III — inference GOPs (exact analytic) + 6-bit (<2,3>) sensitivity (scaled)\n");
    out.push_str(&format!("{:<12} {:>14} {:>22}\n", "model", "inference GOPs", "6-bit acc drop (scaled)"));
    // exact part: paper models
    for name in ["resnet18", "resnet34", "vgg16", "googlenet"] {
        let net = crate::nn::zoo::network(name)?;
        out.push_str(&format!(
            "{:<12} {:>14.2} {:>22}\n",
            name,
            net.inference_macs() as f64 / 1e9,
            "-"
        ));
    }
    // scaled sensitivity: train fp32 vs <2,3> on the trainable models
    let base = base_config(overrides)?;
    let mut engine = Engine::from_dir(artifacts_dir)?;
    for model in ["resnet_t", "cnn_s"] {
        let fp = run_one(&mut engine, &base, model, "fp32")?;
        let cfg6 = "e2m3_gnc_eg8mg1_sr";
        if engine.manifest.find(model, "train_step", cfg6).is_ok() {
            let q = run_one(&mut engine, &base, model, cfg6)?;
            let net = crate::nn::zoo::network(model)?;
            out.push_str(&format!(
                "{:<12} {:>14.4} {:>21.2}%\n",
                model,
                net.inference_macs() as f64 / 1e9,
                (fp.test_acc - q.test_acc) * 100.0
            ));
        }
    }
    out.push_str("(paper: 1.88 / 3.59 / 15.25 / 1.58 GOPs; drops 0.9 / 0.8 / 0.1 / -0.1%)\n");
    Ok(out)
}

// -------------------------------------------------------------------------
// Table IV — ablation grid: #group x M_g x E_x x M_x (scaled)
// -------------------------------------------------------------------------

fn table4(artifacts_dir: &str, overrides: &[String]) -> Result<String> {
    let base = base_config(overrides)?;
    let mut engine = Engine::from_dir(artifacts_dir)?;
    let model = "resnet_t";

    // the paper's 9 config rows x M_x in {4,3,2,1}
    let rows: Vec<(&str, Option<u32>, u32)> = vec![
        ("none", None, 0),
        ("second", Some(0), 0),
        ("first", Some(0), 0),
        ("both", Some(0), 0),
        ("both", Some(1), 0),
        ("none", None, 1),
        ("none", None, 2),
        ("both", Some(1), 1),
        ("both", Some(1), 2),
    ];

    let mut out = String::new();
    out.push_str(&format!(
        "Table IV (scaled) — training resnet_t on synthcifar, {} steps\n",
        base.steps
    ));
    out.push_str(&format!(
        "{:<8} {:>4} {:>4} | {:>8} {:>8} {:>8} {:>8}\n",
        "#group", "Mg", "Ex", "Mx=4", "Mx=3", "Mx=2", "Mx=1"
    ));
    let mut missing = 0;
    for (grouping, m_g, e_x) in rows {
        let mut cells = Vec::new();
        for m_x in [4u32, 3, 2, 1] {
            let cfg = QuantConfig {
                element: EmFormat::new(e_x, m_x),
                group: EmFormat::new(8, m_g.unwrap_or(0)),
                grouping: Grouping::parse(grouping)?,
                rounding: Rounding::Stochastic,
                enabled: true,
            };
            let name = cfg.name();
            if engine.manifest.find(model, "train_step", &name).is_err() {
                cells.push("n/a".to_string());
                missing += 1;
                continue;
            }
            let r = run_one(&mut engine, &base, model, &name)?;
            cells.push(if r.diverged {
                "Div.".to_string()
            } else {
                format!("{:.1}", r.test_acc * 100.0)
            });
        }
        out.push_str(&format!(
            "{:<8} {:>4} {:>4} | {:>8} {:>8} {:>8} {:>8}\n",
            grouping,
            m_g.map(|v| v.to_string()).unwrap_or_else(|| "-".into()),
            e_x,
            cells[0], cells[1], cells[2], cells[3]
        ));
    }
    if missing > 0 {
        out.push_str(&format!(
            "({missing} cells n/a — build the full ablation artifact set with `make artifacts-full`)\n"
        ));
    }
    out.push_str("(paper shape: both-grouping > single-dim > none; larger E_x rescues small M_x;\n");
    out.push_str(" group scaling with M_g=1 + E_x=0 ~ E_x=2 without grouping)\n");
    Ok(out)
}

// -------------------------------------------------------------------------
// Fig. 2 — energy (analytic) + measured accuracy drops from short runs
// -------------------------------------------------------------------------

fn fig2(artifacts_dir: &str, overrides: &[String], em: &EnergyModel, fmt: EmFormat) -> Result<String> {
    // energy part is analytic; attach measured accuracy drops when the
    // trainable artifacts exist.
    let drops = (|| -> Result<Vec<(String, f64)>> {
        let base = base_config(overrides)?;
        let mut engine = Engine::from_dir(artifacts_dir)?;
        let model = "resnet_t";
        let fp = run_one(&mut engine, &base, model, "fp32")?;
        let ours = run_one(&mut engine, &base, model, "e2m4_gnc_eg8mg1_sr")?;
        let int8ish = run_one(&mut engine, &base, model, "e0m4_gnc_eg8mg1_sr")
            .or_else(|_| run_one(&mut engine, &base, model, "e0m2_gnc_eg8mg1_sr"));
        let mut v = vec![
            ("fp32".to_string(), 0.0f64),
            ("mls<2,4>".to_string(), (fp.test_acc - ours.test_acc) as f64 * 100.0),
        ];
        if let Ok(r) = int8ish {
            v.push(("int8".to_string(), (fp.test_acc - r.test_acc) as f64 * 100.0));
        }
        Ok(v)
    })()
    .unwrap_or_default();
    report::fig2("resnet18", 64, fmt, em, if drops.is_empty() { None } else { Some(&drops) })
}

// -------------------------------------------------------------------------
// Fig. 6 — group maxima of activation / error, by channel and by sample
// -------------------------------------------------------------------------

/// Train briefly, then probe one batch and dump sorted group maxima.
fn fig6(artifacts_dir: &str, overrides: &[String]) -> Result<String> {
    let mut base = base_config(overrides)?;
    base.steps = base.steps.min(120); // probe needs a warmed-up model, not a converged one
    let mut engine = Engine::from_dir(artifacts_dir)?;
    let model = "resnet_t";
    let cfg_name = "e2m4_gnc_eg8mg1_sr";
    let r = run_one(&mut engine, &base, model, cfg_name)?;

    let meta = engine.manifest.model(model)?.clone();
    let ds = crate::data::SynthCifar::new(base.data.clone());
    let (images, labels) = ds.batch(meta.batch, streams::TEST, 0);
    let outs = engine.probe_step(model, cfg_name, &r.final_state, &images, &labels, 7)?;
    let k = meta.probe_names.len();

    let mut out = String::new();
    out.push_str("Fig. 6 — per-group maxima (normalized to overall max), mid-training model\n");
    for (li, name) in meta.probe_names.iter().enumerate().take(3) {
        let a = &outs[li];
        let e = &outs[k + li];
        let ashape = meta.probe_a_shapes[name].clone();
        let eshape = meta.probe_e_shapes[name].clone();
        for (tag, x, shape) in [("activation", a, &ashape), ("error", e, &eshape)] {
            for (gtag, grouping) in [("channel", Grouping::Second), ("sample", Grouping::First)] {
                let maxima = qerror::group_maxima(x, shape, grouping);
                let overall = maxima.first().copied().unwrap_or(0.0).max(1e-30);
                let frac = qerror::fraction_below_half_max(&maxima);
                let quart = |p: f64| maxima[((maxima.len() - 1) as f64 * p) as usize] / overall;
                out.push_str(&format!(
                    "layer {name:<12} {tag:<10} by {gtag:<7}: groups {:>4}  p25 {:.3}  p50 {:.3}  p75 {:.3}  frac<max/2 {:.2}\n",
                    maxima.len(), quart(0.25), quart(0.5), quart(0.75), frac
                ));
            }
        }
    }
    out.push_str("(paper Fig. 6: most group maxima sit well below the overall max --\n");
    out.push_str(" 'over half of the groups' below max/2, motivating group-wise scaling)\n");
    Ok(out)
}

// -------------------------------------------------------------------------
// Fig. 7 — per-layer AREs of W / E / A under format variants
// -------------------------------------------------------------------------

fn fig7(artifacts_dir: &str, overrides: &[String]) -> Result<String> {
    let mut base = base_config(overrides)?;
    base.steps = base.steps.min(120);
    let mut engine = Engine::from_dir(artifacts_dir)?;
    let model = "resnet_t";
    let cfg_name = "e2m4_gnc_eg8mg1_sr";
    let r = run_one(&mut engine, &base, model, cfg_name)?;

    let meta = engine.manifest.model(model)?.clone();
    let ds = crate::data::SynthCifar::new(base.data.clone());
    let (images, labels) = ds.batch(meta.batch, streams::TEST, 0);
    let outs = engine.probe_step(model, cfg_name, &r.final_state, &images, &labels, 7)?;
    let k = meta.probe_names.len();

    let mk = |e_x: u32, m_x: u32, grouping: Grouping, m_g: u32| QuantConfig {
        element: EmFormat::new(e_x, m_x),
        group: EmFormat::new(8, m_g),
        grouping,
        rounding: Rounding::Nearest,
        enabled: true,
    };

    let mut out = String::new();
    out.push_str("Fig. 7 — per-layer ARE of weight / error / activation\n");

    // Row 1: grouping dims, <0,3> elements, <8,1> groups
    out.push_str("row 1: grouping dims (<0,3> elements)\n");
    let row1: Vec<(&str, QuantConfig)> = vec![
        ("none", mk(0, 3, Grouping::None, 1)),
        ("first(n/co)", mk(0, 3, Grouping::First, 1)),
        ("second(c/ci)", mk(0, 3, Grouping::Second, 1)),
        ("both(nc)", mk(0, 3, Grouping::Both, 1)),
    ];
    // Row 2: E_x variants without grouping; Row 3: with nc grouping
    let row2: Vec<(&str, QuantConfig)> = vec![
        ("Ex=0", mk(0, 3, Grouping::None, 1)),
        ("Ex=1", mk(1, 3, Grouping::None, 1)),
        ("Ex=2", mk(2, 3, Grouping::None, 1)),
    ];
    let row3: Vec<(&str, QuantConfig)> = vec![
        ("Ex=0+nc", mk(0, 3, Grouping::Both, 1)),
        ("Ex=1+nc", mk(1, 3, Grouping::Both, 1)),
        ("Ex=2+nc", mk(2, 3, Grouping::Both, 1)),
    ];

    for (row_name, cfgs) in [("row 1 (grouping)", row1), ("row 2 (E_x, no grouping)", row2),
                             ("row 3 (E_x + nc grouping)", row3)] {
        out.push_str(&format!("-- {row_name} --\n"));
        out.push_str(&format!("{:<14}", "config"));
        for name in &meta.probe_names {
            out.push_str(&format!(" {:>10}", name.split('.').next_back().unwrap_or(name)));
        }
        out.push('\n');
        for kind in ["W", "E", "A"] {
            for (cname, cfg) in &cfgs {
                out.push_str(&format!("{:<14}", format!("{kind} {cname}")));
                for (li, pname) in meta.probe_names.iter().enumerate() {
                    let (x, shape): (&[f32], Vec<usize>) = match kind {
                        "A" => (&outs[li], meta.probe_a_shapes[pname].clone()),
                        "E" => (&outs[k + li], meta.probe_e_shapes[pname].clone()),
                        _ => {
                            let spec = meta.spec(&format!("{pname}.w")).unwrap();
                            (&outs[2 * k + li], spec.shape.clone())
                        }
                    };
                    let are = qerror::average_relative_error(x, &shape, cfg);
                    out.push_str(&format!(" {:>10.4}", are));
                }
                out.push('\n');
            }
        }
    }
    out.push_str("(paper shape: nc grouping smallest ARE; larger E_x -> smaller ARE;\n");
    out.push_str(" joint grouping + exponent best)\n");
    Ok(out)
}
